//! Quickstart: plan parameters, generate keys, encrypt a small
//! regression problem, fit it entirely on ciphertexts with ELS-GD-VWT,
//! decrypt, and compare with OLS.
//!
//!     cargo run --release --example quickstart

use std::sync::Arc;

use els::data::synth;
use els::els::encrypted::{decrypt_coefficients, fit, Accel, DatasetRef, FitConfig};
use els::els::exact::QuantisedData;
use els::els::float_ref::{linf, ols};
use els::els::model::encrypt_dataset;
use els::els::stepsize::nu_optimal;
use els::fhe::keys::keygen;
use els::fhe::noise::noise_budget_bits;
use els::fhe::params::{plan, Algo, PlanRequest};
use els::fhe::rng::ChaChaRng;
use els::fhe::FvContext;
use els::runtime::backend::NativeEngine;

fn main() -> els::util::error::Result<()> {
    // 1. The data holder's side: a small regression problem,
    //    standardised, quantised at φ = 2 (paper §3.1).
    let mut rng = ChaChaRng::from_seed(2024);
    let (x, y) = synth::gaussian_regression(&mut rng, 20, 3, 0.2);
    let q = QuantisedData::from_f64(&x, &y, 2);
    let (xq, yq) = q.dequantised();
    let nu = nu_optimal(&xq); // integer inverse step size ν = 1/δ (§7)
    let iters = 3;

    // 2. Plan FV parameters guaranteeing correct decryption (§4.5:
    //    Lemma-3 growth bounds + noise-depth budget + LP11 estimate).
    let params = plan(
        &PlanRequest::gd(q.n(), q.p(), iters, 2, nu).with_algo(Algo::GdVwt),
    )?;
    println!(
        "planned: d = {}, q = {} bits, t = 2^{}, λ ≈ {:.0} bits ({:?} profile)",
        params.d,
        params.q_bits(),
        params.t.bit_len() - 1,
        params.security_bits(),
        params.profile,
    );
    let ctx = FvContext::new(params);
    let keys = keygen(&ctx, &mut rng);

    // 3. Encrypt the dataset (one FV ciphertext per value).
    let data = encrypt_dataset(&ctx, &keys.pk, &q, &mut rng);
    println!(
        "encrypted {}×{} + {} values → {:.1} MiB of ciphertext",
        q.n(),
        q.p(),
        q.n(),
        data.size_bytes() as f64 / (1024.0 * 1024.0)
    );

    // 4. Fit on ciphertexts: K iterations of ELS-GD + the van
    //    Wijngaarden transformation (§5.2).
    let engine = NativeEngine::new(ctx.clone(), Arc::new(keys.rk.clone()));
    let cfg = FitConfig::gd(iters, nu).with_accel(Accel::Vwt);
    let t0 = std::time::Instant::now();
    let fitted = fit(&engine, &DatasetRef::Scalar(&data), &cfg)?.fit;
    println!(
        "encrypted fit: {:?} (paper MMD = {}, ct-mult depth = {})",
        t0.elapsed(),
        fitted.paper_mmd,
        fitted.noise_depth
    );
    for (j, ct) in fitted.betas.iter().enumerate() {
        println!("  β̃_{j}: noise budget {:.0} bits", noise_budget_bits(&ctx, ct, &keys.sk));
    }

    // 5. Secret-key holder decrypts and rescales.
    let betas = decrypt_coefficients(&ctx, &keys.sk, &fitted);
    let truth = ols(&xq, &yq);
    println!("\n{:>4} {:>10} {:>10}", "j", "ELS-VWT", "OLS");
    for j in 0..betas.len() {
        println!("{j:>4} {:>10.4} {:>10.4}", betas[j], truth[j]);
    }
    println!("\n‖β − β_ols‖∞ = {:.4} after {iters} encrypted iterations", linf(&betas, &truth));
    Ok(())
}
