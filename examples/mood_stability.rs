//! Mood-stability application (paper §6.2, Figure 6): AR(2) models of
//! weekly mood scores, fit pre- and post-treatment per patient.
//! N = 28, P = 2 — the paper's exact application size.
//!
//! The whole cohort is analysed with the exact encoded-integer backend
//! (bit-identical to encrypted evaluation), and one patient is run
//! end-to-end encrypted as a spot check.
//!
//!     cargo run --release --example mood_stability

use std::sync::Arc;

use els::data::mood;
use els::els::encrypted::{decrypt_coefficients, fit, DatasetRef, FitConfig};
use els::els::exact::{gd_exact, QuantisedData};
use els::els::float_ref::{linf, ols};
use els::els::model::encrypt_dataset;
use els::els::stepsize::nu_optimal;
use els::fhe::keys::keygen;
use els::fhe::params::{plan, PlanRequest};
use els::fhe::rng::ChaChaRng;
use els::fhe::FvContext;
use els::runtime::backend::NativeEngine;

fn fit_phase(x: &[Vec<f64>], y: &[f64], iters: usize) -> (Vec<f64>, Vec<f64>, f64) {
    let q = QuantisedData::from_f64(x, y, 2);
    let (xq, yq) = q.dequantised();
    let nu = nu_optimal(&xq);
    let enc = gd_exact(&q, nu, iters).decode_last();
    let truth = ols(&xq, &yq);
    let err = linf(&enc, &truth);
    (enc, truth, err)
}

fn main() -> els::util::error::Result<()> {
    let mut rng = ChaChaRng::from_seed(808);
    let cohort = mood::cohort(&mut rng, 6);
    let iters = 2; // paper: convergence within 2 iterations

    println!("AR(2) coefficients after {iters} encrypted-GD iterations (vs OLS):\n");
    println!(
        "{:>7} {:>22} {:>22} {:>10}",
        "patient", "pre  (lag1, lag2)", "post (lag1, lag2)", "max err"
    );
    for p in &cohort {
        let (pre, _, e1) = fit_phase(&p.pre.0, &p.pre.1, iters);
        let (post, _, e2) = fit_phase(&p.post.0, &p.post.1, iters);
        println!(
            "{:>7} {:>10.3} {:>10.3}  {:>10.3} {:>10.3} {:>10.3}",
            p.id,
            pre[0],
            pre[1],
            post[0],
            post[1],
            e1.max(e2)
        );
    }

    // Encrypted spot check on patient 0 (pre-treatment), full pipeline.
    println!("\nencrypted spot check (patient 0, pre-treatment):");
    let p0 = &cohort[0];
    let q = QuantisedData::from_f64(&p0.pre.0, &p0.pre.1, 2);
    let (xq, _) = q.dequantised();
    let nu = nu_optimal(&xq);
    let ctx = FvContext::new(plan(&PlanRequest::gd(q.n(), q.p(), iters, 2, nu))?);
    let keys = keygen(&ctx, &mut rng);
    let engine = NativeEngine::new(ctx.clone(), Arc::new(keys.rk.clone()));
    let data = encrypt_dataset(&ctx, &keys.pk, &q, &mut rng);
    let t0 = std::time::Instant::now();
    let fitted = fit(&engine, &DatasetRef::Scalar(&data), &FitConfig::gd(iters, nu))?.fit;
    let wall = t0.elapsed();
    let dec = decrypt_coefficients(&ctx, &keys.sk, &fitted);
    let exact = gd_exact(&q, nu, iters).decode_last();
    println!(
        "  fit in {wall:?} ({:.1} MiB ciphertext), β = ({:+.3}, {:+.3})",
        data.size_bytes() as f64 / (1024.0 * 1024.0),
        dec[0],
        dec[1]
    );
    println!("  encrypted == exact simulation: {}", linf(&dec, &exact) < 1e-9);
    Ok(())
}
