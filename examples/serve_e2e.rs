//! End-to-end serving driver (DESIGN.md §7): starts the coordinator
//! with the batching engine (XLA/PJRT backend when `artifacts/` exists,
//! falling back to the native backend), submits a wave of encrypted
//! regression jobs over the real TCP wire protocol from concurrent
//! clients, and reports latency, throughput, batching behaviour and
//! decrypted accuracy.
//!
//!     make artifacts && cargo run --release --example serve_e2e

use std::path::Path;
use std::sync::Arc;
use std::time::Instant;

use els::coordinator::batcher::{BatchConfig, BatchingEngine};
use els::coordinator::scheduler::Coordinator;
use els::coordinator::service::{Client, Server};
use els::data::synth;
use els::els::encrypted::{decrypt_coefficients, FitConfig};
use els::els::exact::{gd_exact, QuantisedData};
use els::els::float_ref::linf;
use els::els::model::encrypt_dataset;
use els::els::stepsize::nu_optimal;
use els::fhe::keys::keygen;
use els::fhe::params::FvParams;
use els::fhe::rng::ChaChaRng;
use els::fhe::FvContext;
use els::runtime::backend::{HeEngine, NativeEngine};
use els::runtime::pjrt::XlaEngine;

const JOBS: usize = 6;
const N: usize = 6;
const P: usize = 2;
const ITERS: usize = 1;

fn main() -> els::util::error::Result<()> {
    // Shared parameter set sized for the workload; d = 256 matches the
    // shipped artifact manifest so the XLA backend can serve it.
    let params = FvParams::custom(256, 3, 26);
    let ctx = FvContext::new(params);
    let mut rng = ChaChaRng::from_seed(42);
    let keys = keygen(&ctx, &mut rng);

    // Pick the backend: XLA artifacts if built, else native.
    let artifact_dir = Path::new("artifacts");
    let (inner, backend_name): (Arc<dyn HeEngine>, _) =
        match XlaEngine::new(ctx.clone(), &keys.rk, artifact_dir) {
            Ok(engine) => (Arc::new(engine), "xla/pjrt"),
            Err(e) => {
                eprintln!("[serve_e2e] XLA backend unavailable ({e:#}); using native");
                (
                    Arc::new(NativeEngine::new(ctx.clone(), Arc::new(keys.rk.clone()))),
                    "native",
                )
            }
        };
    let engine = BatchingEngine::new(inner, BatchConfig::default());
    let coord = Coordinator::new(engine.clone(), 4);
    let mut server = Server::start(coord, "127.0.0.1:0")?;
    let addr = server.addr.to_string();
    println!("coordinator up on {addr} (backend: {backend_name}, d={})", ctx.d());

    // Client side: build, encrypt and submit JOBS problems concurrently.
    let mut workloads = Vec::new();
    for i in 0..JOBS {
        let mut r = rng.split(100 + i as u64);
        let (x, y) = synth::gaussian_regression(&mut r, N, P, 0.2);
        let q = QuantisedData::from_f64(&x, &y, 2);
        let (xq, _) = q.dequantised();
        let nu = nu_optimal(&xq);
        workloads.push((q, nu, r));
    }
    let t0 = Instant::now();
    let results: Vec<(usize, f64, std::time::Duration)> = std::thread::scope(|s| {
        let handles: Vec<_> = workloads
            .iter_mut()
            .enumerate()
            .map(|(i, (q, nu, r))| {
                let ctx = ctx.clone();
                let keys = &keys;
                let addr = addr.clone();
                let nu = *nu;
                s.spawn(move || {
                    let data = encrypt_dataset(&ctx, &keys.pk, q, r);
                    let mut client = Client::connect(&addr).expect("connect");
                    let t = Instant::now();
                    let tenant = format!("clinic-{}", i % 3);
                    let id = client
                        .submit_with(&data, &FitConfig::gd(ITERS, nu), None, Some(&tenant), None)
                        .expect("submit");
                    let fit = client.result(&ctx, id).expect("result");
                    let latency = t.elapsed();
                    let dec = decrypt_coefficients(&ctx, &keys.sk, &fit);
                    let expect = gd_exact(q, nu, ITERS).decode_last();
                    (i, linf(&dec, &expect), latency)
                })
            })
            .collect();
        handles.into_iter().map(|h| h.join().unwrap()).collect()
    });
    let wall = t0.elapsed();

    println!("\n{:>4} {:>12} {:>14}", "job", "latency", "enc-vs-exact");
    let mut max_err: f64 = 0.0;
    for (i, err, lat) in &results {
        println!("{i:>4} {:>12.2?} {err:>14.2e}", lat);
        max_err = max_err.max(*err);
    }
    let (muls, plains, _adds, batches) = engine.stats().snapshot();
    println!("\n== end-to-end summary ==");
    println!("backend               : {backend_name}");
    println!("jobs                  : {JOBS} × (N={N}, P={P}, K={ITERS})");
    println!("wall clock            : {wall:.2?}");
    println!("throughput            : {:.2} jobs/s", JOBS as f64 / wall.as_secs_f64());
    println!("ct-muls / batches     : {muls} / {batches}  (avg batch {:.1})", muls as f64 / batches.max(1) as f64);
    println!("plaintext muls        : {plains}");
    println!("max enc-vs-exact drift: {max_err:.2e}");
    let mut client = Client::connect(&addr)?;
    println!("server metrics        : {}", client.metrics()?);
    assert!(max_err < 1e-9, "encrypted results must be exact");
    server.stop();
    engine.shutdown();
    println!("OK");
    Ok(())
}
