//! Prostate-cancer application (paper §6.2, Figures 7–8): ridge
//! regression via §4.4 data augmentation on the N = 97, P = 8 design,
//! fit with ELS-GD-VWT at K = 4, across α ∈ {0, 15, 30}.
//!
//!     cargo run --release --example prostate_ridge

use els::data::prostate;
use els::els::exact::vwt_exact;
use els::els::float_ref::{ridge, ridge_df, rms};
use els::els::model::quantise_ridge_augmented;
use els::els::scaling::ratio_f64;
use els::els::stepsize::nu_optimal;
use els::fhe::rng::ChaChaRng;

fn main() -> els::util::error::Result<()> {
    let mut rng = ChaChaRng::from_seed(1989); // Stamey et al., 1989
    let (x, y) = prostate::paper_size(&mut rng);
    let n = x.len();
    println!("synthetic prostate problem: N = {n}, P = 8 (see DESIGN.md §6)\n");

    println!(
        "{:>6} {:>6} | {:>60} | {:>9}",
        "alpha", "df", "coefficients (ELS-GD-VWT, K = 4)", "vs RLS"
    );
    for alpha in [0.0f64, 15.0, 30.0] {
        // §4.4: augment, quantise, fit OLS on the augmented system.
        let q = quantise_ridge_augmented(&x, &y, alpha, 2);
        let (xq, yq) = q.dequantised();
        let nu = nu_optimal(&xq);
        let (acc, div) = vwt_exact(&q, nu, 4); // exact == encrypted
        let betas: Vec<f64> = acc.iter().map(|v| ratio_f64(v, &div)).collect();
        // Reference: closed-form ridge on the quantised original data.
        let x_orig: Vec<Vec<f64>> = xq[..n].to_vec();
        let y_orig: Vec<f64> = yq[..n].to_vec();
        let rls = ridge(&x_orig, &y_orig, alpha);
        let df = ridge_df(&x_orig, alpha);
        let coef_str: String =
            betas.iter().map(|b| format!("{b:+.3}")).collect::<Vec<_>>().join(" ");
        println!("{alpha:>6.0} {df:>6.2} | {coef_str:>60} | {:>9.4}", rms(&betas, &rls));
    }

    println!("\ncovariates: {}", prostate::COVARIATES.join(", "));
    println!(
        "note: α shrinks ‖β‖ and df(α) = Σ λ/(λ+α); with regularisation the\n\
         K = 4 encrypted fit tracks RLS closely even before full convergence\n\
         (paper Figure 8). Absolute values differ from the paper's — the\n\
         dataset is a structural synthetic substitute."
    );
    Ok(())
}
