#!/usr/bin/env python3
"""Bench-regression gate for the `mul_pairs` hot path (dep-free).

Compares a fresh `cargo bench --bench fhe_ops` report against the
committed baseline `BENCH_fhe_ops.json` and fails (exit 1) if any
mul_pairs batch regressed beyond the threshold. The **hard gate** is
the machine-relative full-RNS-vs-bigint speedup ratio of each batch
(both backends run in the same process on the same machine, so the
ratio is stable across runner hardware); absolute full_rns mean_ns
drift is reported as a WARNING only, since cross-machine wall-clock
comparisons flake on runner variance. While the committed baseline is
still the pending-first-toolchain-run stub, the gate SKIPs loudly
(exit 0) — there is nothing to regress against until the first
measured run is committed.

Beyond mul_pairs, the report also carries a `mul_plain` section
(cold vs cached-operand timings — the cold/cached ratio is the same
machine-relative design as the backend speedup), a `dot_pairs` section
(one fused 8-pair inner-product group vs the pair-by-pair fold — the
fusion speedup ratio), a `rotations` section (packed Galois key switch
vs a full ct-mul on the same parameters) and a `gd_iteration`
end-to-end timing. All are
tracked **warn-only** until a measured baseline containing them lands;
they never fail the gate (gd_iteration has no in-run relative pair at
all, so it stays advisory forever).

Usage: bench_check.py BASELINE_JSON FRESH_JSON [--threshold=0.15]
       (--threshold 0.15 is also accepted)

Exit codes: 0 = ok or skip, 1 = regression, 2 = bad invocation/input.
"""

import json
import sys

DEFAULT_THRESHOLD = 0.15


def load(path):
    try:
        with open(path) as f:
            return json.load(f)
    except (OSError, ValueError) as e:
        print(f"bench_check: ERROR: cannot read {path}: {e}", file=sys.stderr)
        sys.exit(2)


def parse_args(argv):
    """Returns (positional_args, threshold) or exits 2."""
    positional, threshold = [], DEFAULT_THRESHOLD
    i = 1
    while i < len(argv):
        a = argv[i]
        if a.startswith("--threshold"):
            if "=" in a:
                raw = a.split("=", 1)[1]
            elif i + 1 < len(argv):
                i += 1
                raw = argv[i]
            else:
                print("bench_check: ERROR: --threshold needs a value", file=sys.stderr)
                sys.exit(2)
            try:
                threshold = float(raw)
            except ValueError:
                print(f"bench_check: ERROR: bad threshold {raw!r}", file=sys.stderr)
                sys.exit(2)
        elif a.startswith("--"):
            print(f"bench_check: ERROR: unknown option {a!r}", file=sys.stderr)
            sys.exit(2)
        else:
            positional.append(a)
        i += 1
    if len(positional) != 2:
        print(__doc__, file=sys.stderr)
        sys.exit(2)
    return positional, threshold


def main(argv):
    (baseline_path, fresh_path), threshold = parse_args(argv)
    baseline, fresh = load(baseline_path), load(fresh_path)

    if baseline.get("status") != "measured" or not baseline.get("batches"):
        print(
            "bench_check: SKIP — baseline is still the pending stub "
            f"(status={baseline.get('status')!r}); commit the first measured "
            "BENCH_fhe_ops.json to arm the regression gate."
        )
        return 0
    if fresh.get("status") != "measured" or not fresh.get("batches"):
        print(
            "bench_check: ERROR: fresh report is not a measured run "
            f"(status={fresh.get('status')!r}) — did cargo bench --bench "
            "fhe_ops run?",
            file=sys.stderr,
        )
        return 2

    base_by_pairs = {b["pairs"]: b for b in baseline["batches"]}
    fresh_pairs = {b["pairs"] for b in fresh["batches"]}
    failures, lines = [], []
    # A baseline batch with no fresh counterpart means the gated
    # surface itself disappeared — that must fail, not silently pass.
    for n in sorted(base_by_pairs):
        if n not in fresh_pairs:
            lines.append(f"  {int(n):>3}-pair: in baseline but MISSING from fresh run")
            failures.append(n)
    for batch in fresh["batches"]:
        n = batch["pairs"]
        base = base_by_pairs.get(n)
        if base is None:
            lines.append(f"  {int(n):>3}-pair: no baseline batch — skipped")
            continue
        old_ratio = base["exact_bigint"]["mean_ns"] / max(base["full_rns"]["mean_ns"], 1)
        new_ratio = batch["exact_bigint"]["mean_ns"] / max(batch["full_rns"]["mean_ns"], 1)
        verdict = "OK"
        # Hard gate: the full-RNS advantage over the in-run bigint
        # oracle must not shrink beyond the threshold.
        if new_ratio < old_ratio * (1.0 - threshold):
            verdict = "REGRESSION"
            failures.append(n)
        lines.append(
            f"  {int(n):>3}-pair rns/bigint speedup: {old_ratio:.2f}x -> "
            f"{new_ratio:.2f}x ({new_ratio / old_ratio - 1.0:+.1%})  {verdict}"
        )
        # Advisory only: absolute wall clock is machine-dependent.
        old_ns = base["full_rns"]["mean_ns"]
        new_ns = batch["full_rns"]["mean_ns"]
        if old_ns > 0 and new_ns / old_ns > 1.0 + threshold:
            lines.append(
                f"      WARNING: full_rns mean {old_ns:.0f} ns -> {new_ns:.0f} ns "
                f"({new_ns / old_ns - 1.0:+.1%}) — not gated (cross-machine noise)"
            )
    # mul_plain cold/cached ratio — warn-only (new metric; promote to a
    # hard gate once a few CI runs confirm the ratio is stable).
    base_mp, fresh_mp = baseline.get("mul_plain"), fresh.get("mul_plain")
    if base_mp and not fresh_mp:
        lines.append(
            "  mul_plain: WARNING — baseline has this section but the fresh "
            "run does not (did the bench stop measuring it?)"
        )
    elif fresh_mp and not base_mp:
        lines.append(
            "  mul_plain: no baseline section yet — tracked warn-only until "
            "a measured baseline containing it is committed"
        )
    elif base_mp and fresh_mp:
        old_ratio = base_mp["cold"]["mean_ns"] / max(base_mp["cached"]["mean_ns"], 1)
        new_ratio = fresh_mp["cold"]["mean_ns"] / max(fresh_mp["cached"]["mean_ns"], 1)
        verdict = "OK"
        if new_ratio < old_ratio * (1.0 - threshold):
            verdict = "WARNING: cached-operand advantage shrank (not gated yet)"
        lines.append(
            f"  mul_plain cold/cached speedup: {old_ratio:.2f}x -> "
            f"{new_ratio:.2f}x ({new_ratio / old_ratio - 1.0:+.1%})  {verdict}"
        )
    # dot_pairs fused/pairwise ratio — warn-only (same machine-relative
    # design as mul_plain: both legs run in the same process, so the
    # fusion speedup is stable across runner hardware; promote to a
    # hard gate once a few CI runs confirm it).
    base_dp, fresh_dp = baseline.get("dot_pairs"), fresh.get("dot_pairs")
    if base_dp and not fresh_dp:
        lines.append(
            "  dot_pairs: WARNING — baseline has this section but the fresh "
            "run does not (did the bench stop measuring it?)"
        )
    elif fresh_dp and not base_dp:
        lines.append(
            "  dot_pairs: no baseline section yet — fusion speedup tracked "
            "warn-only until a measured baseline containing it is committed"
        )
    elif base_dp and fresh_dp:
        old_ratio = base_dp["pairwise"]["mean_ns"] / max(base_dp["fused"]["mean_ns"], 1)
        new_ratio = fresh_dp["pairwise"]["mean_ns"] / max(fresh_dp["fused"]["mean_ns"], 1)
        verdict = "OK"
        if new_ratio < old_ratio * (1.0 - threshold):
            verdict = "WARNING: fusion advantage shrank (not gated yet)"
        lines.append(
            f"  dot_pairs fused/pairwise speedup (group "
            f"{int(base_dp.get('group', 0))}): {old_ratio:.2f}x -> "
            f"{new_ratio:.2f}x ({new_ratio / old_ratio - 1.0:+.1%})  {verdict}"
        )
    # rotations ct-mul/rotate ratio — warn-only (same machine-relative
    # design: one Galois key switch vs a full ct-mul, measured in the
    # same process on the same packed parameters).
    base_rot, fresh_rot = baseline.get("rotations"), fresh.get("rotations")
    if base_rot and not fresh_rot:
        lines.append(
            "  rotations: WARNING — baseline has this section but the fresh "
            "run does not (did the bench stop measuring it?)"
        )
    elif fresh_rot and not base_rot:
        lines.append(
            "  rotations: no baseline section yet — mul/rotate ratio tracked "
            "warn-only until a measured baseline containing it is committed"
        )
    elif base_rot and fresh_rot:
        old_ratio = base_rot["ct_mul"]["mean_ns"] / max(base_rot["rotate_1"]["mean_ns"], 1)
        new_ratio = fresh_rot["ct_mul"]["mean_ns"] / max(fresh_rot["rotate_1"]["mean_ns"], 1)
        verdict = "OK"
        if new_ratio < old_ratio * (1.0 - threshold):
            verdict = "WARNING: rotations got pricier vs ct-mul (not gated yet)"
        lines.append(
            f"  rotations ct-mul/rotate ratio (d={int(base_rot.get('d', 0))}): "
            f"{old_ratio:.2f}x -> {new_ratio:.2f}x "
            f"({new_ratio / old_ratio - 1.0:+.1%})  {verdict}"
        )
    # gd_iteration — absolute wall clock only, advisory forever.
    base_gd, fresh_gd = baseline.get("gd_iteration"), fresh.get("gd_iteration")
    if base_gd and not fresh_gd:
        lines.append(
            "  gd_iteration: WARNING — baseline has this section but the "
            "fresh run does not (did the bench stop measuring it?)"
        )
    elif fresh_gd and not base_gd:
        lines.append("  gd_iteration: no baseline section yet — tracked warn-only")
    elif base_gd and fresh_gd:
        old_ns, new_ns = base_gd["mean_ns"], fresh_gd["mean_ns"]
        note = ""
        if old_ns > 0 and new_ns / old_ns > 1.0 + threshold:
            note = "  WARNING: slower (not gated — cross-machine noise)"
        lines.append(
            f"  gd_iteration mean: {old_ns:.0f} ns -> {new_ns:.0f} ns "
            f"({new_ns / max(old_ns, 1) - 1.0:+.1%}){note}"
        )
    print(f"bench_check: mul_pairs vs baseline (threshold {threshold:.0%}):")
    print("\n".join(lines))
    if failures:
        print(
            f"bench_check: FAIL — {len(failures)} batch(es) went missing or "
            f"lost more than {threshold:.0%} of their full-RNS speedup: "
            f"{sorted(int(n) for n in failures)}",
            file=sys.stderr,
        )
        return 1
    print("bench_check: gate green")
    return 0


if __name__ == "__main__":
    sys.exit(main(sys.argv))
