#!/usr/bin/env python3
"""Validate an `els` write-ahead journal (`journal.wal`).

Dependency-free (stdlib only), in the same discipline as chaos_check.py
and trace_check.py. The journal is the durability substrate of the
serving tier (rust/src/coordinator/journal.rs): length-prefixed,
checksummed frames, each wrapping one lifecycle-record JSON document.

Frame format (little-endian):

    [u32 payload length][u64 FNV-1a 64 checksum of payload][payload]

Checks:

- every complete frame's checksum matches its payload (FNV-1a 64,
  offset 0xcbf29ce484222325, prime 0x100000001b3);
- every payload is valid JSON with `v` == 1, a known `event` tag and a
  non-negative integer `id`;
- per-event required fields are present with the right shapes
  (`accepted` carries tenant/cfg/data, `checkpoint` a ckpt document,
  `done` a fit document, `failed` a structured code);
- non-`accepted` records referencing an id with no prior `accepted`
  are reported (replay skips such orphans — a truncation repair can
  legally produce them, so they warn rather than fail);
- a torn tail (incomplete or checksum-failing final frame) is a
  warning, never a failure — recovery truncates it by design;
- with `--require`, the named events must each appear at least once.

Usage:
    journal_check.py JOURNAL [--require accepted,done] [--strict-orphans]

JOURNAL is the `journal.wal` file or the journal directory holding it.
Exit code 0 on success; 1 with a diagnostic on the first violation.
"""

import argparse
import json
import os
import struct
import sys

JOURNAL_VERSION = 1
HEADER_LEN = 12
MAX_RECORD_LEN = 1 << 30

FNV_OFFSET = 0xCBF29CE484222325
FNV_PRIME = 0x100000001B3

KNOWN_EVENTS = {"accepted", "started", "checkpoint", "done", "acked", "failed"}

# Error codes defined by rust/src/coordinator/protocol.rs.
KNOWN_CODES = {
    "bad_request",
    "bad_version",
    "unknown_job",
    "job_failed",
    "job_expired",
    "deadline_exceeded",
    "overloaded",
    "shutting_down",
    "transport",
    "internal",
}


def fail(msg):
    print(f"journal_check: FAIL: {msg}", file=sys.stderr)
    sys.exit(1)


def warn(msg):
    print(f"journal_check: warning: {msg}", file=sys.stderr)


def fnv1a64(data):
    h = FNV_OFFSET
    for b in data:
        h ^= b
        h = (h * FNV_PRIME) & 0xFFFFFFFFFFFFFFFF
    return h


def scan_frames(raw):
    """Yield (offset, payload bytes) for the clean prefix; mirror the
    Rust scanner's torn-tail semantics (truncate at the first
    incomplete/corrupt frame)."""
    frames = []
    at = 0
    torn = None
    while at < len(raw):
        rest = raw[at:]
        if len(rest) < HEADER_LEN:
            torn = f"incomplete header at byte {at} ({len(rest)} of {HEADER_LEN} bytes)"
            break
        length, checksum = struct.unpack_from("<IQ", rest)
        if length > MAX_RECORD_LEN:
            torn = f"implausible frame length {length} at byte {at}"
            break
        if len(rest) < HEADER_LEN + length:
            torn = (
                f"incomplete frame at byte {at} "
                f"({len(rest) - HEADER_LEN} of {length} payload bytes)"
            )
            break
        payload = rest[HEADER_LEN : HEADER_LEN + length]
        if fnv1a64(payload) != checksum:
            torn = f"checksum mismatch at byte {at}"
            break
        frames.append((at, payload))
        at += HEADER_LEN + length
    return frames, torn


def require_field(doc, event, offset, key, kinds, kind_name):
    v = doc.get(key)
    if not isinstance(v, kinds) or isinstance(v, bool):
        fail(f"'{event}' record at byte {offset}: '{key}' must be {kind_name}, got {v!r}")
    return v


def check_record(doc, offset, accepted_ids):
    if not isinstance(doc, dict):
        fail(f"record at byte {offset} is not a JSON object")
    v = doc.get("v")
    if v != JOURNAL_VERSION:
        fail(f"record at byte {offset}: version must be {JOURNAL_VERSION}, got {v!r}")
    event = doc.get("event")
    if event not in KNOWN_EVENTS:
        fail(f"record at byte {offset}: unknown event {event!r}")
    rid = doc.get("id")
    if not isinstance(rid, (int, float)) or isinstance(rid, bool) or rid < 0 or rid != int(rid):
        fail(f"'{event}' record at byte {offset}: 'id' must be a non-negative integer")
    rid = int(rid)

    orphan = False
    if event == "accepted":
        require_field(doc, event, offset, "tenant", str, "a string")
        require_field(doc, event, offset, "cfg", dict, "an object")
        require_field(doc, event, offset, "data", dict, "an object")
        tok = doc.get("token")
        if tok is not None and not isinstance(tok, str):
            fail(f"'accepted' record at byte {offset}: 'token' must be a string")
        dl = doc.get("deadline_ms")
        if dl is not None and (
            not isinstance(dl, (int, float)) or isinstance(dl, bool) or dl < 0
        ):
            fail(f"'accepted' record at byte {offset}: 'deadline_ms' must be non-negative")
        accepted_ids.add(rid)
    else:
        if event == "checkpoint":
            require_field(doc, event, offset, "ckpt", dict, "an object")
        elif event == "done":
            require_field(doc, event, offset, "fit", dict, "an object")
        elif event == "failed":
            code = require_field(doc, event, offset, "code", str, "a string")
            if code not in KNOWN_CODES:
                fail(f"'failed' record at byte {offset}: unknown error code {code!r}")
        orphan = rid not in accepted_ids
    return event, rid, orphan


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("journal", help="journal.wal file, or the journal directory")
    ap.add_argument(
        "--require",
        default="",
        help="comma-separated events that must each appear at least once",
    )
    ap.add_argument(
        "--strict-orphans",
        action="store_true",
        help="fail (instead of warn) on records whose id has no prior 'accepted'",
    )
    args = ap.parse_args()

    path = args.journal
    if os.path.isdir(path):
        path = os.path.join(path, "journal.wal")
    try:
        with open(path, "rb") as f:
            raw = f.read()
    except OSError as e:
        fail(f"cannot read {path}: {e}")

    frames, torn = scan_frames(raw)
    if not frames:
        fail(f"{path} holds no complete records" + (f" ({torn})" if torn else ""))

    counts = {e: 0 for e in KNOWN_EVENTS}
    accepted_ids = set()
    orphans = 0
    for offset, payload in frames:
        try:
            doc = json.loads(payload.decode("utf-8"))
        except (UnicodeDecodeError, ValueError) as e:
            fail(f"record at byte {offset} is checksummed but not valid JSON: {e}")
        event, rid, orphan = check_record(doc, offset, accepted_ids)
        counts[event] += 1
        if orphan:
            orphans += 1
            msg = f"'{event}' record at byte {offset} references id {rid} with no prior 'accepted'"
            if args.strict_orphans:
                fail(msg)
            warn(msg + " (replay skips it)")

    if torn:
        warn(f"torn tail after {len(frames)} good record(s): {torn}; recovery truncates it")

    for event in filter(None, (e.strip() for e in args.require.split(","))):
        if event not in KNOWN_EVENTS:
            fail(f"--require names unknown event {event!r}")
        if counts[event] == 0:
            fail(f"--require {event}: no '{event}' record in the journal")

    summary = ", ".join(f"{e}={counts[e]}" for e in sorted(counts) if counts[e])
    print(
        f"journal_check: OK: {len(frames)} record(s) over {len(accepted_ids)} job(s) "
        f"({summary}), {orphans} orphan(s)"
        + (", torn tail (truncated on recovery)" if torn else "")
    )


if __name__ == "__main__":
    main()
