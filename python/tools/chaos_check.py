#!/usr/bin/env python3
"""Validate an `els-chaos-v1` chaos-battery snapshot.

Dependency-free (stdlib only), in the same discipline as trace_check.py
and bench_check.py. The Rust chaos smoke test (`cargo test --release
--test chaos chaos_smoke` with `ELS_CHAOS_OUT=<path>`, optionally
`ELS_FAULTS=<spec>`) runs the saturation burst under injected faults
and writes the snapshot this script audits:

- schema is `els-chaos-v1`;
- every submission terminated: completed + failed == total;
- nothing leaked: jobs.leaked == 0;
- the scenario actually tested something: faults.injected > 0 and
  probe traffic (faults.checked) at least covers the injections;
- with `--expect-retries`, the retrying client really retried.

Usage:
    chaos_check.py SNAPSHOT.json [--expect-retries]

Exit code 0 on success; 1 with a diagnostic on the first violation.
"""

import argparse
import json
import sys

# Injection sites defined by rust/src/util/faults.rs (FaultSite::as_str).
KNOWN_SITES = {
    "wire_read",
    "wire_write",
    "lane",
    "timer",
    "cache",
    "batcher",
    "journal",
}


def fail(msg):
    print(f"chaos_check: FAIL: {msg}", file=sys.stderr)
    sys.exit(1)


def nonneg_int(obj, section, key):
    v = obj.get(key)
    if not isinstance(v, (int, float)) or isinstance(v, bool) or v < 0 or v != int(v):
        fail(f"{section}.{key} must be a non-negative integer, got {v!r}")
    return int(v)


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("snapshot", help="path to the chaos snapshot JSON")
    ap.add_argument(
        "--expect-retries",
        action="store_true",
        help="fail unless the retrying client performed at least one retry",
    )
    args = ap.parse_args()

    try:
        with open(args.snapshot, "r", encoding="utf-8") as f:
            doc = json.load(f)
    except (OSError, ValueError) as e:
        fail(f"cannot load {args.snapshot}: {e}")

    if not isinstance(doc, dict):
        fail("top level must be an object")
    if doc.get("schema") != "els-chaos-v1":
        fail(f"schema must be 'els-chaos-v1', got {doc.get('schema')!r}")

    jobs = doc.get("jobs")
    if not isinstance(jobs, dict):
        fail("jobs section missing or not an object")
    total = nonneg_int(jobs, "jobs", "total")
    completed = nonneg_int(jobs, "jobs", "completed")
    failed = nonneg_int(jobs, "jobs", "failed")
    leaked = nonneg_int(jobs, "jobs", "leaked")
    if total == 0:
        fail("jobs.total is 0 — the burst never ran")
    if completed + failed != total:
        fail(
            f"jobs must all terminate: completed={completed} + failed={failed} "
            f"!= total={total}"
        )
    if leaked != 0:
        fail(f"jobs.leaked={leaked} — server-side state survived the drain")
    if completed == 0:
        fail("jobs.completed is 0 — chaos starved every job")

    faults = doc.get("faults")
    if not isinstance(faults, dict):
        fail("faults section missing or not an object")
    checked = nonneg_int(faults, "faults", "checked")
    injected = nonneg_int(faults, "faults", "injected")
    if injected == 0:
        fail("faults.injected is 0 — the armed faults never fired")
    if checked < injected:
        fail(f"faults.checked={checked} < faults.injected={injected}")
    per_site = faults.get("per_site")
    if not isinstance(per_site, dict):
        fail("faults.per_site missing or not an object")
    for site, count in per_site.items():
        if site not in KNOWN_SITES:
            fail(f"faults.per_site names unknown site {site!r}")
        nonneg_int(per_site, "faults.per_site", site)

    retries = nonneg_int(doc, "<top>", "retries")
    if args.expect_retries and retries == 0:
        fail("--expect-retries: the retrying client never retried")

    fired = ", ".join(
        f"{k}={int(v)}" for k, v in sorted(per_site.items()) if int(v) > 0
    )
    print(
        f"chaos_check: OK: {total} jobs ({completed} completed, {failed} failed, "
        f"0 leaked), {injected} faults injected ({fired or 'none'}), "
        f"{retries} retries"
    )


if __name__ == "__main__":
    main()
