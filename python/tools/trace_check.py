#!/usr/bin/env python3
"""Validate an `ELS_TRACE` Chrome trace-event JSON document.

Dependency-free (stdlib only), mirroring the discipline of the Rust
side's zero-dep telemetry. Checks structural well-formedness (the
subset of the Chrome trace-event format the recorder emits: complete
"X" events with name/cat/ts/dur/pid/tid) and, with `--require`, phase
coverage — the CI smoke leg asserts that one encrypted fit actually
exercised the multiply pipeline end to end.

Usage:
    trace_check.py TRACE.json [--require phase1,phase2,...]

Exit code 0 on success; 1 with a diagnostic on the first violation.
"""

import argparse
import json
import sys

# Phase names emitted by rust/src/util/telemetry.rs (Phase::name).
KNOWN_PHASES = {
    "ntt_forward",
    "ntt_inverse",
    "base_extend",
    "scale_round",
    "shenoy_convert",
    "relinearise",
    "galois_keyswitch",
    "pool_worker",
    "descent_iteration",
    "job_admit",
    "job_queue",
    "job_execute",
    "batch_dispatch",
    "serve_reply",
}

KNOWN_CATEGORIES = {"ring", "mul", "pool", "els", "coordinator"}


def fail(msg):
    print(f"trace_check: FAIL: {msg}", file=sys.stderr)
    sys.exit(1)


def check_event(i, ev):
    if not isinstance(ev, dict):
        fail(f"event {i}: not an object")
    name = ev.get("name")
    if name not in KNOWN_PHASES:
        fail(f"event {i}: unknown phase name {name!r}")
    if ev.get("cat") not in KNOWN_CATEGORIES:
        fail(f"event {i}: unknown category {ev.get('cat')!r}")
    if ev.get("ph") != "X":
        fail(f"event {i}: ph must be 'X' (complete event), got {ev.get('ph')!r}")
    for key in ("ts", "dur", "pid", "tid"):
        v = ev.get(key)
        if not isinstance(v, (int, float)) or isinstance(v, bool):
            fail(f"event {i}: {key} must be numeric, got {v!r}")
    if ev["dur"] < 0:
        fail(f"event {i}: negative duration {ev['dur']}")
    if ev["ts"] < 0:
        fail(f"event {i}: negative timestamp {ev['ts']}")
    return name


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("trace", help="path to the trace JSON")
    ap.add_argument(
        "--require",
        default="",
        help="comma-separated phase names that must appear at least once",
    )
    args = ap.parse_args()

    try:
        with open(args.trace, "r", encoding="utf-8") as f:
            doc = json.load(f)
    except (OSError, ValueError) as e:
        fail(f"cannot load {args.trace}: {e}")

    if not isinstance(doc, dict):
        fail("top level must be an object")
    events = doc.get("traceEvents")
    if not isinstance(events, list) or not events:
        fail("traceEvents must be a non-empty list")

    seen = {}
    for i, ev in enumerate(events):
        name = check_event(i, ev)
        seen[name] = seen.get(name, 0) + 1

    required = [p for p in args.require.split(",") if p]
    for phase in required:
        if phase not in KNOWN_PHASES:
            fail(f"--require names unknown phase {phase!r}")
        if phase not in seen:
            fail(f"required phase {phase!r} never appears in the trace")

    other = doc.get("otherData", {})
    recorded = other.get("recorded")
    if recorded is not None and recorded < len(events):
        fail(f"otherData.recorded={recorded} < {len(events)} events present")

    summary = ", ".join(f"{k}={v}" for k, v in sorted(seen.items()))
    print(f"trace_check: OK: {len(events)} events ({summary})")


if __name__ == "__main__":
    main()
