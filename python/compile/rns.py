"""RNS basis generation — bit-for-bit mirror of `rust/src/math/primes.rs`.

The Rust runtime and the AOT-compiled XLA artifacts must agree on the
prime basis for every ring degree. Both sides generate primes
`p ≡ 1 (mod 2d)`, `p < 2^30`, **descending** from 2^30; the Rust side
cross-checks `artifacts/rns_meta.json` at load time.
"""

from __future__ import annotations

RNS_PRIME_BOUND = 1 << 30

_MR_BASES = (2, 3, 5, 7, 11, 13, 17, 19, 23, 29, 31, 37)


def is_prime(n: int) -> bool:
    """Deterministic Miller–Rabin for n < 3.3e24 (12-base set)."""
    if n < 2:
        return False
    for p in _MR_BASES:
        if n == p:
            return True
        if n % p == 0:
            return False
    d, s = n - 1, 0
    while d % 2 == 0:
        d //= 2
        s += 1
    for a in _MR_BASES:
        x = pow(a, d, n)
        if x in (1, n - 1):
            continue
        for _ in range(s - 1):
            x = x * x % n
            if x == n - 1:
                break
        else:
            return False
    return True


def ntt_primes_below(below: int, modulus: int, count: int) -> list[int]:
    """First `count` primes ≡ 1 (mod modulus) strictly below `below`,
    descending (mirror of `primes::ntt_primes_below`)."""
    out: list[int] = []
    c = (below - 2) // modulus * modulus + 1
    while len(out) < count:
        assert c > modulus, f"prime supply exhausted (modulus {modulus})"
        if is_prime(c):
            out.append(c)
        c -= modulus
    return out


def rns_basis_primes(d: int, count: int) -> list[int]:
    """The standard basis for ring degree d (mirror of
    `primes::rns_basis_primes`)."""
    assert d & (d - 1) == 0, "ring degree must be a power of two"
    return ntt_primes_below(RNS_PRIME_BOUND, 2 * d, count)


def primitive_root(p: int) -> int:
    """Smallest generator of Z_p^* (trial-division factoring of p-1)."""
    n = p - 1
    factors = []
    f = 2
    while f * f <= n:
        if n % f == 0:
            factors.append(f)
            while n % f == 0:
                n //= f
        f += 1
    if n > 1:
        factors.append(n)
    for g in range(2, p):
        if all(pow(g, (p - 1) // q, p) != 1 for q in factors):
            return g
    raise AssertionError(f"no primitive root for {p}")


def primitive_2d_root(p: int, d: int) -> int:
    """ψ with ψ^d ≡ -1 (mod p); requires p ≡ 1 (mod 2d)."""
    order = 2 * d
    assert (p - 1) % order == 0
    psi = pow(primitive_root(p), (p - 1) // order, p)
    assert pow(psi, d, p) == p - 1
    return psi


def bitrev(x: int, bits: int) -> int:
    return int(bin(x)[2:].zfill(bits)[::-1], 2) if bits else 0


def ntt_tables(p: int, d: int):
    """(psi_rev, psi_inv_rev, d_inv) — mirror of `NttTable::new`."""
    psi = primitive_2d_root(p, d)
    psi_inv = pow(psi, p - 2, p)
    bits = d.bit_length() - 1
    pow_f, pow_i = [1] * d, [1] * d
    for i in range(1, d):
        pow_f[i] = pow_f[i - 1] * psi % p
        pow_i[i] = pow_i[i - 1] * psi_inv % p
    psi_rev = [pow_f[bitrev(i, bits)] for i in range(d)]
    psi_inv_rev = [pow_i[bitrev(i, bits)] for i in range(d)]
    d_inv = pow(d, p - 2, p)
    return psi_rev, psi_inv_rev, d_inv
