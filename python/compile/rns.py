"""RNS basis generation and base conversion — bit-for-bit mirror of
`rust/src/math/primes.rs` and `rust/src/math/baseconv.rs`.

The Rust runtime and the AOT-compiled XLA artifacts must agree on the
prime basis for every ring degree. Both sides generate primes
`p ≡ 1 (mod 2d)`, `p < 2^30`, **descending** from 2^30; the Rust side
cross-checks `artifacts/rns_meta.json` at load time.

The base-conversion helpers mirror the full-RNS multiply subsystem:
`base_convert_signed` (fast base extension with the 64-bit fixed-point
α correction) and `shenoy_convert` (exact Shenoy–Kumaresan conversion
whose redundant-modulus residue plays the role of the γ-correction for
the fast conversion's overshoot). The fixed-point arithmetic is the
exact integer computation the Rust side performs in `u128`, so the two
implementations agree bit for bit.
"""

from __future__ import annotations

RNS_PRIME_BOUND = 1 << 30

_MR_BASES = (2, 3, 5, 7, 11, 13, 17, 19, 23, 29, 31, 37)


def is_prime(n: int) -> bool:
    """Deterministic Miller–Rabin for n < 3.3e24 (12-base set)."""
    if n < 2:
        return False
    for p in _MR_BASES:
        if n == p:
            return True
        if n % p == 0:
            return False
    d, s = n - 1, 0
    while d % 2 == 0:
        d //= 2
        s += 1
    for a in _MR_BASES:
        x = pow(a, d, n)
        if x in (1, n - 1):
            continue
        for _ in range(s - 1):
            x = x * x % n
            if x == n - 1:
                break
        else:
            return False
    return True


def ntt_primes_below(below: int, modulus: int, count: int) -> list[int]:
    """First `count` primes ≡ 1 (mod modulus) strictly below `below`,
    descending (mirror of `primes::ntt_primes_below`)."""
    out: list[int] = []
    c = (below - 2) // modulus * modulus + 1
    while len(out) < count:
        assert c > modulus, f"prime supply exhausted (modulus {modulus})"
        if is_prime(c):
            out.append(c)
        c -= modulus
    return out


def rns_basis_primes(d: int, count: int) -> list[int]:
    """The standard basis for ring degree d (mirror of
    `primes::rns_basis_primes`)."""
    assert d & (d - 1) == 0, "ring degree must be a power of two"
    return ntt_primes_below(RNS_PRIME_BOUND, 2 * d, count)


def primitive_root(p: int) -> int:
    """Smallest generator of Z_p^* (trial-division factoring of p-1)."""
    n = p - 1
    factors = []
    f = 2
    while f * f <= n:
        if n % f == 0:
            factors.append(f)
            while n % f == 0:
                n //= f
        f += 1
    if n > 1:
        factors.append(n)
    for g in range(2, p):
        if all(pow(g, (p - 1) // q, p) != 1 for q in factors):
            return g
    raise AssertionError(f"no primitive root for {p}")


def primitive_2d_root(p: int, d: int) -> int:
    """ψ with ψ^d ≡ -1 (mod p); requires p ≡ 1 (mod 2d)."""
    order = 2 * d
    assert (p - 1) % order == 0
    psi = pow(primitive_root(p), (p - 1) // order, p)
    assert pow(psi, d, p) == p - 1
    return psi


def bitrev(x: int, bits: int) -> int:
    return int(bin(x)[2:].zfill(bits)[::-1], 2) if bits else 0


def ntt_tables(p: int, d: int):
    """(psi_rev, psi_inv_rev, d_inv) — mirror of `NttTable::new`."""
    psi = primitive_2d_root(p, d)
    psi_inv = pow(psi, p - 2, p)
    bits = d.bit_length() - 1
    pow_f, pow_i = [1] * d, [1] * d
    for i in range(1, d):
        pow_f[i] = pow_f[i - 1] * psi % p
        pow_i[i] = pow_i[i - 1] * psi_inv % p
    psi_rev = [pow_f[bitrev(i, bits)] for i in range(d)]
    psi_inv_rev = [pow_i[bitrev(i, bits)] for i in range(d)]
    d_inv = pow(d, p - 2, p)
    return psi_rev, psi_inv_rev, d_inv


# ---- reduction constants (mirror of rust/src/math/modarith.rs) ---------

_U64_MASK = (1 << 64) - 1


def shoup_precompute(s: int, p: int) -> int:
    """`⌊s·2^64/p⌋` — the Shoup companion of an invariant operand
    (mirror of `modarith::shoup_precompute`; requires s < p < 2^63)."""
    assert 0 <= s < p < 1 << 63
    return (s << 64) // p


def mulmod_shoup_lazy(x: int, s: int, s_shoup: int, p: int) -> int:
    """The lazy Shoup product in `[0, 2p)` — exact wrapping-u64 mirror
    of `modarith::mulmod_shoup_lazy` (valid for any x < 2^64)."""
    assert 0 <= x <= _U64_MASK
    q = (x * s_shoup) >> 64
    return (x * s - q * p) & _U64_MASK


def mulmod_shoup(x: int, s: int, s_shoup: int, p: int) -> int:
    """`x·s mod p` via the precomputed companion (result in [0, p))."""
    r = mulmod_shoup_lazy(x, s, s_shoup, p)
    return r - p if r >= p else r


def barrett_constant(m: int) -> tuple[int, int]:
    """`(r_hi, r_lo)` words of `r = ⌊2^128/m⌋` — mirror of
    `modarith::BarrettConstant::new` (requires 2 ≤ m < 2^62)."""
    assert 2 <= m < 1 << 62
    r = (1 << 128) // m
    return r >> 64, r & _U64_MASK


def barrett_reduce(x: int, m: int, r_hi: int, r_lo: int) -> int:
    """`x mod m` for any x < 2^128 via the 128-bit reciprocal — the
    quotient estimate `⌊x·r/2^128⌋` is exact in the Rust mul-high
    formula, so `(x*r) >> 128` mirrors it bit for bit."""
    assert 0 <= x < 1 << 128
    q = (x * ((r_hi << 64) | r_lo)) >> 128
    rem = x - q * m  # q ≤ x/m, so this never underflows
    return rem - m if rem >= m else rem


def barrett_div_rem(x: int, m: int, r_hi: int, r_lo: int) -> tuple[int, int]:
    """Exact `(⌊x/m⌋, x mod m)` — mirror of `BarrettConstant::div_rem`
    (the division-free fixed-point `⌊y_i·2^64/p_i⌋` path)."""
    q = (x * ((r_hi << 64) | r_lo)) >> 128
    rem = x - q * m
    if rem >= m:
        rem -= m
        q += 1
    return q, rem


# ---- base conversion (mirror of rust/src/math/baseconv.rs) -------------


def crt_residues(v: int, primes: list[int]) -> list[int]:
    """Canonical residues of (possibly negative) v in each plane."""
    return [v % p for p in primes]


def base_convert_signed(
    residues: list[int], src: list[int], tgt: list[int]
) -> list[int]:
    """Fast base conversion of the *centered* representative.

    Given residues of x in the source basis (product M), returns the
    residues mod each target prime of the centered representative
    x_c ∈ (−M/2, M/2]. Uses the explicit CRT sum Σ y_i·M_i − α·M with
    the overshoot α recovered by 64-bit fixed-point accumulation of
    Σ y_i/p_i, rounded to nearest — the exact computation the Rust
    `BaseConverter` performs in `u128`. Exact whenever x_c is not
    within M·len(src)/2^64 of the ±M/2 boundary (and off by one
    multiple of M otherwise, which the FV noise analysis absorbs).
    """
    assert len(residues) == len(src)
    m_i = []  # M/p_i
    prod = 1
    for p in src:
        prod *= p
    y = []
    s_fix = 0  # Σ ⌊y_i·2^64/p_i⌋, exact u128 mirror
    for x, p in zip(residues, src):
        mi = prod // p
        yi = x * pow(mi % p, p - 2, p) % p
        m_i.append(mi)
        y.append(yi)
        s_fix += (yi << 64) // p
    alpha = (s_fix + (1 << 63)) >> 64
    return [
        (sum(yi * (mi % t) for yi, mi in zip(y, m_i)) - alpha * (prod % t)) % t
        for t in tgt
    ]


def shenoy_convert(
    residues_b: list[int],
    residue_msk: int,
    b_primes: list[int],
    msk: int,
    tgt: list[int],
) -> list[int]:
    """Exact Shenoy–Kumaresan base conversion B → tgt.

    `residue_msk` is the redundant-modulus residue of the true signed
    value x (|x| < B/2, carried through the pipeline alongside the B
    planes); it corrects the fast conversion's overshoot exactly:
    α′ = (Σ y_j·B_j − x) · B^{-1} mod m_sk equals the true overshoot
    count α + [x < 0] < len(B) ≪ m_sk, so the subtraction below
    reconstructs the centered representative with pure integer
    arithmetic (the γ-correction role of the redundant modulus).
    """
    assert len(residues_b) == len(b_primes)
    b_prod = 1
    for p in b_primes:
        b_prod *= p
    y = []
    s_msk = 0
    for x, p in zip(residues_b, b_primes):
        bj = b_prod // p
        yj = x * pow(bj % p, p - 2, p) % p
        y.append(yj)
        s_msk += yj * (bj % msk)
    alpha = (
        (s_msk - residue_msk) * pow(b_prod % msk, msk - 2, msk) % msk
    )
    assert alpha <= len(b_primes), "S-K overshoot out of range"
    return [
        (
            sum(yj * ((b_prod // p) % t) for yj, p in zip(y, b_primes))
            - alpha * (b_prod % t)
        )
        % t
        for t in tgt
    ]


def scale_round_rns(
    v_q: list[int],
    v_ext: list[int],
    v_msk: int,
    t: int,
    q_primes: list[int],
    b_primes: list[int],
    msk: int,
) -> list[int]:
    """Full-RNS ⌊t·v/q⌉ mod q (mirror of `fhe/rns_mul.rs`).

    `v` is known on Q (v_q), on the extension basis B (v_ext) and on
    the redundant modulus (v_msk). Computes z = centered [t·v]_q from
    the Q planes, extends it to B∪{m_sk}, forms r = (t·v − z)/q by
    exact division in the extension planes, and converts r back to Q
    via `shenoy_convert`.
    """
    z_q = [tv * vi % p for tv, vi, p in ((t % p, vi, p) for vi, p in zip(v_q, q_primes))]
    z_ext = base_convert_signed(z_q, q_primes, b_primes + [msk])
    q_prod = 1
    for p in q_primes:
        q_prod *= p
    r_planes = []
    for vi, zi, p in zip(v_ext + [v_msk], z_ext, b_primes + [msk]):
        num = (t % p) * vi % p - zi
        r_planes.append(num * pow(q_prod % p, p - 2, p) % p)
    return shenoy_convert(r_planes[:-1], r_planes[-1], b_primes, msk, q_primes)
