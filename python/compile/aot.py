"""AOT pipeline: lower the Layer-2 graphs to HLO **text** artifacts the
Rust PJRT runtime loads (`runtime::pjrt`).

HLO text — not serialized HloModuleProto — is the interchange format:
jax ≥ 0.5 emits protos with 64-bit instruction ids that the `xla`
crate's xla_extension 0.5.1 rejects; the text parser reassigns ids
(see /opt/xla-example/README.md).

Usage:  python -m compile.aot --out ../artifacts [--manifest small]
"""

from __future__ import annotations

import argparse
import json
import os
import sys

import jax

jax.config.update("jax_enable_x64", True)

from jax._src.lib import xla_client as xc  # noqa: E402

from . import model, rns  # noqa: E402

# Default manifest: (d, nlimb, batch) triples for both ops.
#   - d=256 l∈{3,7}: the toy test parameter set (Q and Q∪E bases);
#   - d=512 l∈{5,11}: the depth-2 test set;
#   - d=1024 l∈{12,25}: the demo application set.
MANIFESTS = {
    "small": {
        "polymul": [
            (256, 3, b) for b in (1, 8, 32)
        ] + [
            (256, 7, b) for b in (1, 8, 32)
        ] + [
            (512, 5, 8),
            (512, 11, 8),
        ],
        "ct_tensor": [
            (256, 7, b) for b in (1, 8)
        ],
    },
    "apps": {
        "polymul": [
            (1024, 12, b) for b in (1, 16)
        ] + [
            (1024, 25, b) for b in (1, 16)
        ],
        "ct_tensor": [],
    },
}


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    # CRITICAL: print_large_constants. The default printer elides big
    # literals as `constant({...})`, which xla_extension 0.5.1's text
    # parser silently turns into zeros — the baked NTT twiddle tables
    # would be destroyed.
    opts = xc._xla.HloPrintOptions()
    opts.print_large_constants = True
    # 0.5.1's parser rejects newer metadata attributes (source_end_line).
    opts.print_metadata = False
    text = comp.get_hlo_module().to_string(opts)
    assert "{...}" not in text, "elided constants survived printing"
    return text


def lower_op(op: str, d: int, nlimb: int, batch: int) -> str:
    if op == "polymul":
        fn, specs = model.build_polymul(d, nlimb, batch)
    elif op == "ct_tensor":
        fn, specs = model.build_ct_tensor(d, nlimb, batch)
    else:
        raise ValueError(f"unknown op {op}")
    return to_hlo_text(jax.jit(fn).lower(*specs))


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="../artifacts")
    ap.add_argument("--manifest", default="small", choices=sorted(MANIFESTS))
    args = ap.parse_args()
    outdir = args.out
    os.makedirs(outdir, exist_ok=True)

    manifest = MANIFESTS[args.manifest]
    meta: dict = {"prime_bound": rns.RNS_PRIME_BOUND, "ops": []}
    for op, shapes in manifest.items():
        for d, nlimb, batch in shapes:
            name = f"{op}_d{d}_l{nlimb}_b{batch}"
            path = os.path.join(outdir, f"{name}.hlo.txt")
            text = lower_op(op, d, nlimb, batch)
            with open(path, "w") as f:
                f.write(text)
            meta["ops"].append(
                {
                    "op": op,
                    "d": d,
                    "nlimb": nlimb,
                    "batch": batch,
                    "file": f"{name}.hlo.txt",
                    "primes": rns.rns_basis_primes(d, nlimb),
                }
            )
            print(f"wrote {path} ({len(text)} chars)", file=sys.stderr)

    with open(os.path.join(outdir, "rns_meta.json"), "w") as f:
        json.dump(meta, f, indent=1)
    print(f"wrote {outdir}/rns_meta.json", file=sys.stderr)


if __name__ == "__main__":
    main()
