"""Pure-numpy oracles for the Pallas kernels.

`polymul_ref` is the O(d²) schoolbook negacyclic product mod p — the
ground truth every kernel and the full AOT graph is validated against
(the Rust twin is `math::ntt::polymul_naive`).
"""

from __future__ import annotations

import numpy as np


def polymul_ref(a: np.ndarray, b: np.ndarray, p: int) -> np.ndarray:
    """Negacyclic product `a·b mod (x^d + 1, p)` for 1-D int arrays."""
    a = np.asarray(a, dtype=object)  # python ints: no overflow
    b = np.asarray(b, dtype=object)
    d = a.shape[0]
    assert b.shape[0] == d
    out = [0] * d
    for i in range(d):
        ai = int(a[i])
        if ai == 0:
            continue
        for j in range(d):
            prod = ai * int(b[j])
            k = i + j
            if k < d:
                out[k] = (out[k] + prod) % p
            else:
                out[k - d] = (out[k - d] - prod) % p
    return np.array(out, dtype=np.int64)


def polymul_ref_batch(a: np.ndarray, b: np.ndarray, primes) -> np.ndarray:
    """Oracle for the batched [B, L, D] layout."""
    a = np.asarray(a)
    b = np.asarray(b)
    assert a.shape == b.shape and a.ndim == 3
    bsz, nlimb, d = a.shape
    assert len(primes) == nlimb
    out = np.zeros_like(a)
    for i in range(bsz):
        for l, p in enumerate(primes):
            out[i, l] = polymul_ref(a[i, l], b[i, l], int(p))
    return out


def ntt_ref(a: np.ndarray, p: int, psi_rev) -> np.ndarray:
    """Scalar-loop forward negacyclic NTT (mirror of `NttTable::forward`)."""
    a = [int(v) for v in a]
    n = len(a)
    t = n
    m = 1
    while m < n:
        t //= 2
        for i in range(m):
            j1 = 2 * i * t
            s = psi_rev[m + i]
            for j in range(j1, j1 + t):
                u, v = a[j], a[j + t] * s % p
                a[j] = (u + v) % p
                a[j + t] = (u - v) % p
        m *= 2
    return np.array(a, dtype=np.int64)


def intt_ref(a: np.ndarray, p: int, psi_inv_rev, d_inv: int) -> np.ndarray:
    """Scalar-loop inverse negacyclic NTT (mirror of `NttTable::inverse`)."""
    a = [int(v) for v in a]
    n = len(a)
    t = 1
    m = n
    while m > 1:
        h = m // 2
        j1 = 0
        for i in range(h):
            s = psi_inv_rev[h + i]
            for j in range(j1, j1 + t):
                u, v = a[j], a[j + t]
                a[j] = (u + v) % p
                a[j + t] = (u - v) * s % p
            j1 += 2 * t
        t *= 2
        m = h
    return np.array([v * d_inv % p for v in a], dtype=np.int64)
