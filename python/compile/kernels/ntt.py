"""Layer-1 Pallas kernels: negacyclic NTT butterfly stages and the
NTT-domain Hadamard product, batched over [B, L, D] (batch × RNS limb ×
coefficient).

TPU mapping (DESIGN.md §Hardware-Adaptation): one grid step per
(batch, limb) pair holds a whole limb plane (≤ 128 KiB for d ≤ 16384) in
VMEM; each radix-2 stage is a lane-parallel masked multiply-add (VPU
integer work); twiddle tables and moduli stream in as small operands.
`interpret=True` everywhere — the CPU PJRT plugin cannot execute Mosaic
custom-calls, so the kernels lower to plain HLO (see /opt/xla-example).

All arithmetic is int64; residues are < 2^30 so products never exceed
2^60 and `%` keeps values canonical (jnp's remainder is non-negative for
positive moduli).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

INTERPRET = True  # Mosaic lowering unavailable on CPU PJRT


def _fwd_stage_kernel(x_ref, tw_ref, p_ref, o_ref, *, m: int, t: int):
    """One Cooley–Tukey stage: groups of 2t, twiddle ψ^bitrev(m+i)."""
    x = x_ref[0, 0, :].reshape(m, 2, t)
    p = p_ref[0]
    tw = tw_ref[...].reshape(m, 1)
    u = x[:, 0, :]
    v = (x[:, 1, :] * tw) % p
    o = jnp.stack([(u + v) % p, (u - v) % p], axis=1)
    o_ref[0, 0, :] = o.reshape(m * 2 * t)


def _inv_stage_kernel(x_ref, tw_ref, p_ref, o_ref, *, h: int, t: int):
    """One Gentleman–Sande stage: groups of 2t, twiddle ψ^{-bitrev(h+i)}."""
    x = x_ref[0, 0, :].reshape(h, 2, t)
    p = p_ref[0]
    tw = tw_ref[...].reshape(h, 1)
    u = x[:, 0, :]
    v = x[:, 1, :]
    o = jnp.stack([(u + v) % p, ((u - v) * tw) % p], axis=1)
    o_ref[0, 0, :] = o.reshape(h * 2 * t)


def _scale_kernel(x_ref, s_ref, p_ref, o_ref):
    """Pointwise scale by a per-limb scalar (the final d⁻¹ of the iNTT)."""
    o_ref[0, 0, :] = (x_ref[0, 0, :] * s_ref[0]) % p_ref[0]


def _stage_call(kernel, x, tw, primes, **kw):
    # `tw` arrives flattened to 1-D [L*m]: the xla_extension 0.5.1 HLO
    # text parser mis-lays-out ≥2-D s64 constants, so the AOT graphs
    # must only embed 1-D constant tables (layout-invariant).
    bsz, nlimb, d = x.shape
    m = tw.shape[0] // nlimb
    return pl.pallas_call(
        functools.partial(kernel, **kw),
        grid=(bsz, nlimb),
        in_specs=[
            pl.BlockSpec((1, 1, d), lambda b, l: (b, l, 0)),
            pl.BlockSpec((m,), lambda b, l: (l,)),
            pl.BlockSpec((1,), lambda b, l: (l,)),
        ],
        out_specs=pl.BlockSpec((1, 1, d), lambda b, l: (b, l, 0)),
        out_shape=jax.ShapeDtypeStruct(x.shape, x.dtype),
        interpret=INTERPRET,
    )(x, tw, primes)


def ntt_forward(x: jnp.ndarray, tables) -> jnp.ndarray:
    """Forward negacyclic NTT over [B, L, D].

    `tables` is a `RingTables` (see below) carrying per-limb twiddles.
    """
    d = x.shape[2]
    t, m = d, 1
    while m < d:
        t //= 2
        # Twiddles ψ_rev[m : 2m] per limb, flattened → [L·m].
        tw = tables.psi_rev[:, m : 2 * m].reshape(-1)
        x = _stage_call(_fwd_stage_kernel, x, tw, tables.primes, m=m, t=t)
        m *= 2
    return x


def ntt_inverse(x: jnp.ndarray, tables) -> jnp.ndarray:
    """Inverse negacyclic NTT over [B, L, D] (includes the d⁻¹ scale)."""
    d = x.shape[2]
    t, m = 1, d
    while m > 1:
        h = m // 2
        tw = tables.psi_inv_rev[:, h : 2 * h].reshape(-1)
        x = _stage_call(_inv_stage_kernel, x, tw, tables.primes, h=h, t=t)
        t *= 2
        m = h
    bsz, nlimb, _ = x.shape
    return pl.pallas_call(
        _scale_kernel,
        grid=(bsz, nlimb),
        in_specs=[
            pl.BlockSpec((1, 1, d), lambda b, l: (b, l, 0)),
            pl.BlockSpec((1,), lambda b, l: (l,)),
            pl.BlockSpec((1,), lambda b, l: (l,)),
        ],
        out_specs=pl.BlockSpec((1, 1, d), lambda b, l: (b, l, 0)),
        out_shape=jax.ShapeDtypeStruct(x.shape, x.dtype),
        interpret=INTERPRET,
    )(x, tables.d_inv, tables.primes)


class RingTables:
    """Per-ring constant tables, baked into the AOT graph as literals."""

    def __init__(self, d: int, primes: list[int]):
        from .. import rns

        self.d = d
        self.primes_list = list(primes)
        psi_rev, psi_inv_rev, d_inv = [], [], []
        for p in primes:
            f, i, di = rns.ntt_tables(p, d)
            psi_rev.append(f)
            psi_inv_rev.append(i)
            d_inv.append(di)
        self.primes = jnp.array(primes, dtype=jnp.int64)
        self.psi_rev = jnp.array(psi_rev, dtype=jnp.int64)
        self.psi_inv_rev = jnp.array(psi_inv_rev, dtype=jnp.int64)
        self.d_inv = jnp.array(d_inv, dtype=jnp.int64)
