"""MXU-path ablation: negacyclic polynomial multiplication as *matrix
multiplication* (DESIGN.md §4 Hardware-Adaptation).

The primary kernels (`ntt.py`) are O(d log d) VPU integer work. The MXU
systolic array instead wants dense matmuls with narrow inputs and wide
accumulation. This module expresses the O(d²) negacyclic convolution as
exact int8×int8→int32 matmuls — precisely the TPU MXU integer path:

    c = T(a) · b,   T(a)[k, j] = ±a[(k − j) mod d]   (sign from x^d = -1)

Residues are < 2^30, so each operand splits into four 8-bit limbs; the
16 limb-pair products accumulate exactly in int32 for d ≤ 256 (the
worst-case partial sum is d·255² < 2^24.02 ≤ int32), and the limb
recombination happens in int64 modulo p.

This is an *ablation*, not the production path: at FHE ring sizes
(d ≥ 4096) the O(d²) flop count loses to the NTT even at full MXU
utilisation (see EXPERIMENTS.md §Perf). It exists to document how the
paper's compute would map onto the systolic array and to pin the
numerics of that mapping with tests.
"""

from __future__ import annotations

import jax.numpy as jnp

#: Number of 8-bit limbs covering 30-bit residues.
N_LIMBS = 4

#: Largest ring degree with exact int32 accumulation (d·255² < 2^31).
MAX_D = 256


def negacyclic_matrix(a: jnp.ndarray) -> jnp.ndarray:
    """[d] → [d, d] negacyclic convolution matrix T with
    `T[k, j] = a[(k−j) mod d]`, negated where `k − j < 0` (x^d = −1).

    Built from gathers so it stays inside one jitted graph.
    """
    d = a.shape[0]
    k = jnp.arange(d)[:, None]
    j = jnp.arange(d)[None, :]
    idx = (k - j) % d
    sign = jnp.where(k >= j, 1, -1).astype(a.dtype)
    return a[idx] * sign


def polymul_mxu_single(a: jnp.ndarray, b: jnp.ndarray, p: int) -> jnp.ndarray:
    """Exact negacyclic `a·b mod (x^d + 1, p)` for one residue plane via
    limb-split int32 matmuls."""
    d = a.shape[0]
    assert d <= MAX_D, f"int32 accumulation only exact for d ≤ {MAX_D}"
    t = negacyclic_matrix(a)
    # 8-bit limb decompositions (sign lives in T's entries; split |T|).
    t_sign = jnp.sign(t).astype(jnp.int32)
    t_mag = jnp.abs(t)
    acc = jnp.zeros((d,), dtype=jnp.int64)
    for la in range(N_LIMBS):
        t_l = ((t_mag >> (8 * la)) & 255).astype(jnp.int32) * t_sign
        for lb in range(N_LIMBS):
            b_l = ((b >> (8 * lb)) & 255).astype(jnp.int32)
            # The MXU op: int8-range operands, int32 accumulation.
            part = jnp.matmul(t_l, b_l, preferred_element_type=jnp.int32)
            shift = 8 * (la + lb)
            # Recombine in int64 mod p ((2^shift mod p) keeps products
            # far below 2^63).
            weight = (1 << shift) % p
            acc = (acc + part.astype(jnp.int64) * weight) % p
    return acc


def polymul_mxu(a: jnp.ndarray, b: jnp.ndarray, primes) -> jnp.ndarray:
    """Batched [B, L, D] negacyclic product via the MXU formulation."""
    assert a.shape == b.shape and a.ndim == 3
    bsz, nlimb, _ = a.shape
    out = []
    for i in range(bsz):
        planes = [
            polymul_mxu_single(a[i, l], b[i, l], int(primes[l]))
            for l in range(nlimb)
        ]
        out.append(jnp.stack(planes))
    return jnp.stack(out)
