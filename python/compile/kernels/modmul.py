"""Layer-1 Pallas kernel: batched pointwise modular multiplication (the
NTT-domain Hadamard product). Residues < 2^30 ⇒ int64-exact."""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .ntt import INTERPRET


def _modmul_kernel(x_ref, y_ref, p_ref, o_ref):
    o_ref[0, 0, :] = (x_ref[0, 0, :] * y_ref[0, 0, :]) % p_ref[0]


def modmul(x: jnp.ndarray, y: jnp.ndarray, primes: jnp.ndarray) -> jnp.ndarray:
    """Elementwise `x∘y mod p_l` over [B, L, D]."""
    assert x.shape == y.shape and x.ndim == 3
    bsz, nlimb, d = x.shape
    return pl.pallas_call(
        _modmul_kernel,
        grid=(bsz, nlimb),
        in_specs=[
            pl.BlockSpec((1, 1, d), lambda b, l: (b, l, 0)),
            pl.BlockSpec((1, 1, d), lambda b, l: (b, l, 0)),
            pl.BlockSpec((1,), lambda b, l: (l,)),
        ],
        out_specs=pl.BlockSpec((1, 1, d), lambda b, l: (b, l, 0)),
        out_shape=jax.ShapeDtypeStruct(x.shape, x.dtype),
        interpret=INTERPRET,
    )(x, y, primes)
