"""Layer-2 JAX graphs: the batched homomorphic-op compute the Rust
coordinator dispatches to XLA.

The hot op is `polymul`: batched negacyclic polynomial multiplication in
RNS form — the inner kernel of every FV ciphertext multiplication
(tensor products and relinearisation digit products alike). Composed
from the Layer-1 Pallas kernels so the whole pipeline lowers into a
single HLO module per (B, L, D) shape.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from .kernels.modmul import modmul
from .kernels.ntt import RingTables, ntt_forward, ntt_inverse


def polymul(a: jnp.ndarray, b: jnp.ndarray, tables: RingTables) -> jnp.ndarray:
    """`a ⊛ b mod (x^d + 1, p_l)` over [B, L, D] (Pallas kernels)."""
    fa = ntt_forward(a, tables)
    fb = ntt_forward(b, tables)
    return ntt_inverse(modmul(fa, fb, tables.primes), tables)


# ---- fused (vectorised) variant -----------------------------------------
#
# The Pallas grid maps one (batch, limb) pair per step — the right shape
# for a real TPU, where Mosaic turns grid steps into parallel core work.
# Under `interpret=True` on CPU-PJRT, however, each grid step lowers to a
# sequential while-loop iteration with dynamic slices over the whole
# buffer, which costs O((B·L)²·D) memory traffic per stage. The fused
# variant below expresses each butterfly stage as one whole-tensor
# reshape/multiply over [B, L, D] — identical arithmetic (asserted by
# tests), one fully-vectorised XLA op sequence, no loops. `make
# artifacts` compiles this as the production `polymul` artifact; the
# Pallas kernels remain the TPU-lowering reference (EXPERIMENTS.md §Perf).


def _fwd_stage_fused(x, tw, primes, m, t):
    b, l, d = x.shape
    xr = x.reshape(b, l, m, 2, t)
    u = xr[:, :, :, 0, :]
    p = primes[None, :, None, None]
    v = (xr[:, :, :, 1, :] * tw.reshape(1, l, m, 1)) % p
    return jnp.stack(((u + v) % p, (u - v) % p), axis=3).reshape(b, l, d)


def _inv_stage_fused(x, tw, primes, h, t):
    b, l, d = x.shape
    xr = x.reshape(b, l, h, 2, t)
    u = xr[:, :, :, 0, :]
    v = xr[:, :, :, 1, :]
    p = primes[None, :, None, None]
    return jnp.stack(
        ((u + v) % p, ((u - v) * tw.reshape(1, l, h, 1)) % p), axis=3
    ).reshape(b, l, d)


def ntt_forward_fused(x, tables):
    d = x.shape[2]
    t, m = d, 1
    while m < d:
        t //= 2
        x = _fwd_stage_fused(x, tables.psi_rev[:, m : 2 * m], tables.primes, m, t)
        m *= 2
    return x


def ntt_inverse_fused(x, tables):
    d = x.shape[2]
    t, m = 1, d
    while m > 1:
        h = m // 2
        x = _inv_stage_fused(x, tables.psi_inv_rev[:, h : 2 * h], tables.primes, h, t)
        t *= 2
        m = h
    return (x * tables.d_inv[None, :, None]) % tables.primes[None, :, None]


def polymul_fused(a: jnp.ndarray, b: jnp.ndarray, tables: RingTables) -> jnp.ndarray:
    """Fused `a ⊛ b` over [B, L, D]: same math as `polymul`, vectorised
    whole-tensor stages instead of Pallas grid steps."""
    fa = ntt_forward_fused(a, tables)
    fb = ntt_forward_fused(b, tables)
    p = tables.primes[None, :, None]
    return ntt_inverse_fused((fa * fb) % p, tables)


def polymul_pair_accum(
    a0: jnp.ndarray,
    a1: jnp.ndarray,
    b0: jnp.ndarray,
    b1: jnp.ndarray,
    tables: RingTables,
) -> tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Fused BFV tensor product: given ciphertext component batches
    (a0, a1) × (b0, b1), return (a0b0, a0b1 + a1b0, a1b1) with the four
    forward NTTs shared — 4 NTTs + 1 iNTT×3 instead of 4 polymuls'
    8 NTTs + 4 iNTTs."""
    fa0 = ntt_forward(a0, tables)
    fa1 = ntt_forward(a1, tables)
    fb0 = ntt_forward(b0, tables)
    fb1 = ntt_forward(b1, tables)
    p = tables.primes
    c0 = modmul(fa0, fb0, p)
    mid = (modmul(fa0, fb1, p) + modmul(fa1, fb0, p)) % p[None, :, None]
    c2 = modmul(fa1, fb1, p)
    return (
        ntt_inverse(c0, tables),
        ntt_inverse(mid, tables),
        ntt_inverse(c2, tables),
    )


def build_polymul(d: int, nlimb: int, batch: int, fused: bool = True):
    """Jitted `polymul` closed over the ring tables for (d, nlimb).

    `fused=True` (default, used by the AOT manifest) compiles the
    vectorised variant; `fused=False` compiles the Pallas-kernel
    pipeline (TPU-lowering reference / kernel tests)."""
    from . import rns

    tables = RingTables(d, rns.rns_basis_primes(d, nlimb))
    impl = polymul_fused if fused else polymul

    @jax.jit
    def fn(a, b):
        return (impl(a, b, tables),)

    spec = jax.ShapeDtypeStruct((batch, nlimb, d), jnp.int64)
    return fn, (spec, spec)


def build_ct_tensor(d: int, nlimb: int, batch: int):
    """Jitted fused ciphertext tensor product for (d, nlimb, batch)."""
    from . import rns

    tables = RingTables(d, rns.rns_basis_primes(d, nlimb))

    @jax.jit
    def fn(a0, a1, b0, b1):
        return polymul_pair_accum(a0, a1, b0, b1, tables)

    spec = jax.ShapeDtypeStruct((batch, nlimb, d), jnp.int64)
    return fn, (spec, spec, spec, spec)
