"""Dependency-free subset of the RNS mirror checks (no hypothesis/jax):
keeps `python -m pytest python/tests` meaningful in offline CI, where
the property-based and kernel modules are skipped by conftest.py.

The values here are pinned against the Rust generator's unit tests
(rust/src/math/primes.rs) — the AOT artifacts bake these constants, so
the two generators must agree bit for bit."""

from compile import rns


def test_miller_rabin_known_values():
    assert rns.is_prime(998244353)  # 119 * 2^23 + 1
    assert rns.is_prime((1 << 30) - 35)
    assert not rns.is_prime(1 << 30)
    assert not rns.is_prime(3215031751)  # strong pseudoprime base 2,3,5,7
    assert not rns.is_prime(1)


def test_basis_mirrors_rust_rules():
    for d in (256, 1024, 8192):
        ps = rns.rns_basis_primes(d, 8)
        assert len(ps) == len(set(ps)) == 8
        assert ps == sorted(ps, reverse=True), "descending order (Rust mirror)"
        for p in ps:
            assert p < rns.RNS_PRIME_BOUND
            assert p % (2 * d) == 1
            assert rns.is_prime(p)


def test_generation_is_deterministic():
    assert rns.rns_basis_primes(4096, 4) == rns.rns_basis_primes(4096, 4)


def test_primitive_2d_root_orders():
    for d in (8, 256):
        p = rns.rns_basis_primes(d, 1)[0]
        psi = rns.primitive_2d_root(p, d)
        assert pow(psi, d, p) == p - 1, "psi^d = -1"
        assert pow(psi, 2 * d, p) == 1, "psi^2d = 1"


def test_base_convert_signed_exact_small_values():
    src = rns.rns_basis_primes(256, 3)
    tgt = rns.rns_basis_primes(256, 7)[3:]
    for v in (-10**12, -65537, -1, 0, 1, 7, 123456789, 10**14):
        got = rns.base_convert_signed([v % p for p in src], src, tgt)
        assert got == [v % t for t in tgt], f"v={v}"


def test_base_convert_signed_exact_inside_guard_band():
    import random

    src = rns.rns_basis_primes(64, 4)
    tgt = rns.rns_basis_primes(64, 9)[4:]
    m = 1
    for p in src:
        m *= p
    rnd = random.Random(11)
    for _ in range(300):
        # |x| < M/4: inside the fixed-point guard band, conversion is exact.
        x = rnd.randrange(-(m // 4), m // 4)
        got = rns.base_convert_signed([x % p for p in src], src, tgt)
        assert got == [x % t for t in tgt]


def test_shenoy_convert_exact_everywhere():
    import random

    b = rns.rns_basis_primes(128, 5)
    more = rns.rns_basis_primes(128, 9)
    msk, tgt = more[5], more[6:]
    bprod = 1
    for p in b:
        bprod *= p
    rnd = random.Random(12)
    # Exact over the whole symmetric range, boundaries included — the
    # redundant-modulus (γ-style) correction has no approximation.
    cases = [rnd.randrange(-(bprod // 2) + 1, bprod // 2) for _ in range(300)]
    cases += [0, 1, -1, bprod // 2, -(bprod // 2) + 1]
    for x in cases:
        got = rns.shenoy_convert([x % p for p in b], x % msk, b, msk, tgt)
        assert got == [x % t for t in tgt], f"x={x}"


def test_scale_round_rns_matches_exact_rounding():
    import random

    all_primes = rns.rns_basis_primes(64, 9)
    qp, bp, msk = all_primes[:3], all_primes[3:8], all_primes[8]
    q = 1
    for p in qp:
        q *= p
    bprod = 1
    for p in bp:
        bprod *= p
    t = 1 << 24
    d = 64
    lim = d * q * q // 4  # the tensor-coefficient range the bases are sized for
    assert t * (lim // q) < bprod // 2, "extension basis must cover the range"
    rnd = random.Random(13)
    for _ in range(200):
        v = rnd.randrange(-lim, lim)
        out = rns.scale_round_rns(
            [v % p for p in qp], [v % p for p in bp], v % msk, t, qp, bp, msk
        )
        exact = (2 * t * v + q) // (2 * q)  # round to nearest
        for o, p in zip(out, qp):
            diff = (o - exact) % p
            diff = diff - p if diff > p // 2 else diff
            assert abs(diff) <= 1, f"v={v}: off by {diff}"
