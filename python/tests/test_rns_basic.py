"""Dependency-free subset of the RNS mirror checks (no hypothesis/jax):
keeps `python -m pytest python/tests` meaningful in offline CI, where
the property-based and kernel modules are skipped by conftest.py.

The values here are pinned against the Rust generator's unit tests
(rust/src/math/primes.rs) — the AOT artifacts bake these constants, so
the two generators must agree bit for bit."""

from compile import rns


def test_miller_rabin_known_values():
    assert rns.is_prime(998244353)  # 119 * 2^23 + 1
    assert rns.is_prime((1 << 30) - 35)
    assert not rns.is_prime(1 << 30)
    assert not rns.is_prime(3215031751)  # strong pseudoprime base 2,3,5,7
    assert not rns.is_prime(1)


def test_basis_mirrors_rust_rules():
    for d in (256, 1024, 8192):
        ps = rns.rns_basis_primes(d, 8)
        assert len(ps) == len(set(ps)) == 8
        assert ps == sorted(ps, reverse=True), "descending order (Rust mirror)"
        for p in ps:
            assert p < rns.RNS_PRIME_BOUND
            assert p % (2 * d) == 1
            assert rns.is_prime(p)


def test_generation_is_deterministic():
    assert rns.rns_basis_primes(4096, 4) == rns.rns_basis_primes(4096, 4)


def test_primitive_2d_root_orders():
    for d in (8, 256):
        p = rns.rns_basis_primes(d, 1)[0]
        psi = rns.primitive_2d_root(p, d)
        assert pow(psi, d, p) == p - 1, "psi^d = -1"
        assert pow(psi, 2 * d, p) == 1, "psi^2d = 1"
