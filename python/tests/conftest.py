"""Test bootstrap: put `python/` on sys.path so `from compile import …`
works from any invocation directory, and skip collection of modules
whose optional dependencies (jax for the Pallas kernels, hypothesis for
the property sweeps) are absent — offline/sandboxed environments still
get a green, meaningful run from the dependency-free tests."""

import importlib.util
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), os.pardir))

def _missing(mod):
    return importlib.util.find_spec(mod) is None


collect_ignore = []

if _missing("jax"):
    collect_ignore += ["test_kernels.py", "test_aot.py"]

if _missing("hypothesis"):
    # test_kernels needs both jax and hypothesis.
    collect_ignore += ["test_rns.py", "test_kernels.py"]

collect_ignore = sorted(set(collect_ignore))
