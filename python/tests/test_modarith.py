"""Dependency-free mirror checks for the Barrett/Shoup reduction
primitives (`rust/src/math/modarith.rs` ↔ `compile/rns.py`).

The Rust side replaces every hot-loop `u128 %` with precomputed-constant
multiplication; these tests pin the precompute math (Shoup companions,
128-bit Barrett reciprocals) and the reduction identities against plain
integer arithmetic, across random 31-bit primes and the edge operands
(0, 1, m−1) the Rust property suite also sweeps.
"""

import random

from compile import rns


def _random_31bit_prime(rnd: random.Random) -> int:
    m = ((1 << 30) + rnd.randrange(1 << 30)) | 1
    while not rns.is_prime(m):
        m += 2
    return m


def test_shoup_matches_naive_mulmod():
    rnd = random.Random(301)
    for _ in range(200):
        p = _random_31bit_prime(rnd)
        for s in (0, 1, p - 1, rnd.randrange(p)):
            sh = rns.shoup_precompute(s, p)
            # Lazy butterflies feed operands up to 4p.
            for x in (0, 1, p - 1, rnd.randrange(4 * p)):
                assert rns.mulmod_shoup(x, s, sh, p) == x * s % p
                lazy = rns.mulmod_shoup_lazy(x, s, sh, p)
                assert lazy < 2 * p, "lazy Shoup must stay under 2p"
                assert lazy % p == x * s % p


def test_barrett_matches_naive_mulmod():
    rnd = random.Random(302)
    for _ in range(200):
        m = _random_31bit_prime(rnd)
        r_hi, r_lo = rns.barrett_constant(m)
        for a in (0, 1, m - 1, rnd.randrange(m)):
            for b in (0, 1, m - 1, rnd.randrange(m)):
                assert rns.barrett_reduce(a * b, m, r_hi, r_lo) == a * b % m


def test_barrett_reduce_and_div_rem_full_u128_range():
    rnd = random.Random(303)
    for _ in range(200):
        m = _random_31bit_prime(rnd)
        r_hi, r_lo = rns.barrett_constant(m)
        xs = [0, 1, m - 1, m, (1 << 128) - 1, rnd.randrange(1 << 128)]
        for x in xs:
            assert rns.barrett_reduce(x, m, r_hi, r_lo) == x % m
            q, r = rns.barrett_div_rem(x, m, r_hi, r_lo)
            assert (q, r) == (x // m, x % m)
        # The fixed-point use: ⌊y·2^64/p⌋ for canonical y.
        y = rnd.randrange(m)
        assert rns.barrett_div_rem(y << 64, m, r_hi, r_lo)[0] == (y << 64) // m


def test_barrett_constant_word_split_is_exact():
    # The hi/lo word split must reassemble to ⌊2^128/m⌋ — the form the
    # Rust struct stores.
    for m in (2, 3, (1 << 30) - 35, (1 << 62) - 57):
        r_hi, r_lo = rns.barrett_constant(m)
        assert (r_hi << 64) | r_lo == (1 << 128) // m
        assert 0 <= r_hi < 1 << 64 and 0 <= r_lo < 1 << 64


def test_lazy_butterfly_bounds_largest_basis():
    # The Harvey invariants for the largest RNS primes: 4p fits u64,
    # and the u128 relinearisation accumulator has headroom for far
    # more limbs than any supported q_count (mirror of the Rust
    # `lazy_accumulator_headroom_at_max_terms` test).
    for d in (256, 8192):
        p = rns.rns_basis_primes(d, 1)[0]
        assert 4 * p <= (1 << 64) - 1
    max_terms = 1 << 32  # poly::MAX_NTT_ACC_TERMS
    assert max_terms * (rns.RNS_PRIME_BOUND - 1) ** 2 < 1 << 128
