"""The Python prime/table generator must mirror the Rust one exactly
(the AOT artifacts bake these as constants)."""

from hypothesis import given, settings, strategies as st

from compile import rns


def test_miller_rabin_known_values():
    assert rns.is_prime(998244353)
    assert rns.is_prime((1 << 30) - 35)
    assert not rns.is_prime(1 << 30)
    assert not rns.is_prime(3215031751)  # strong pseudoprime base 2,3,5,7
    assert not rns.is_prime(1)


@given(d_log=st.integers(min_value=2, max_value=13), count=st.integers(1, 6))
@settings(max_examples=20, deadline=None)
def test_basis_properties(d_log, count):
    d = 1 << d_log
    ps = rns.rns_basis_primes(d, count)
    assert len(ps) == count
    assert len(set(ps)) == count
    assert all(p < rns.RNS_PRIME_BOUND for p in ps)
    assert all(p % (2 * d) == 1 for p in ps)
    assert all(rns.is_prime(p) for p in ps)
    assert ps == sorted(ps, reverse=True), "descending order (Rust mirror)"


def test_known_first_primes_d256():
    # Regression pin: these exact values are baked into artifacts and
    # asserted against rns_meta.json by the Rust runtime tests.
    ps = rns.rns_basis_primes(256, 3)
    for p in ps:
        assert p % 512 == 1
    assert ps[0] == max(ps)


@given(d_log=st.integers(min_value=2, max_value=9))
@settings(max_examples=12, deadline=None)
def test_psi_is_2d_root(d_log):
    d = 1 << d_log
    p = rns.rns_basis_primes(d, 1)[0]
    psi = rns.primitive_2d_root(p, d)
    assert pow(psi, d, p) == p - 1
    assert pow(psi, 2 * d, p) == 1


def test_tables_shapes():
    d = 32
    p = rns.rns_basis_primes(d, 1)[0]
    f, i, dinv = rns.ntt_tables(p, d)
    assert len(f) == d and len(i) == d
    assert f[0] == 1 and i[0] == 1
    assert dinv * d % p == 1


@given(
    d_log=st.integers(min_value=5, max_value=8),
    l_src=st.integers(2, 4),
    l_tgt=st.integers(1, 4),
    frac=st.fractions(min_value=-1, max_value=1),
)
@settings(max_examples=40, deadline=None)
def test_base_convert_signed_property(d_log, l_src, l_tgt, frac):
    d = 1 << d_log
    ps = rns.rns_basis_primes(d, l_src + l_tgt)
    src, tgt = ps[:l_src], ps[l_src:]
    m = 1
    for p in src:
        m *= p
    # Any |x| < M/4 (inside the fixed-point guard band) converts exactly.
    x = int(frac * (m // 4 - 1))
    got = rns.base_convert_signed([x % p for p in src], src, tgt)
    assert got == [x % t for t in tgt]


@given(
    l_b=st.integers(2, 5),
    frac=st.fractions(min_value=-1, max_value=1),
)
@settings(max_examples=40, deadline=None)
def test_shenoy_convert_property(l_b, frac):
    ps = rns.rns_basis_primes(256, l_b + 3)
    b, msk, tgt = ps[:l_b], ps[l_b], ps[l_b + 1 :]
    bprod = 1
    for p in b:
        bprod *= p
    # Exact over the whole symmetric range — no guard band needed.
    x = int(frac * (bprod // 2 - 1))
    got = rns.shenoy_convert([x % p for p in b], x % msk, b, msk, tgt)
    assert got == [x % t for t in tgt]
