"""End-of-pipeline checks: lowering to HLO text succeeds and the text is
loadable-shaped (parameter/tuple structure the Rust loader expects)."""

import jax

jax.config.update("jax_enable_x64", True)

from compile import aot


def test_lower_polymul_small():
    text = aot.lower_op("polymul", 16, 2, 2)
    assert "HloModule" in text
    assert "s64[2,2,16]" in text, "expected batched i64 parameter shape"
    # return_tuple=True wraps the root in a tuple
    assert "ROOT %tuple" in text or "ROOT tuple" in text


def test_lower_ct_tensor():
    text = aot.lower_op("ct_tensor", 16, 2, 1)
    assert "HloModule" in text
    assert text.count("s64[1,2,16]") >= 4, "four inputs + three outputs"


def test_manifest_entries_unique():
    for name, manifest in aot.MANIFESTS.items():
        seen = set()
        for op, shapes in manifest.items():
            for shape in shapes:
                key = (op, *shape)
                assert key not in seen, f"duplicate {key} in {name}"
                seen.add(key)
