"""Layer-1 kernel correctness: Pallas NTT/modmul vs the pure-numpy
oracle, hypothesis-swept over shapes, primes and values."""

import jax

jax.config.update("jax_enable_x64", True)

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile import rns
from compile.kernels import ref
from compile.kernels.modmul import modmul
from compile.kernels.ntt import RingTables, ntt_forward, ntt_inverse


def rand_batch(rng, bsz, primes, d):
    return np.stack(
        [
            np.stack([rng.integers(0, p, size=d, dtype=np.int64) for p in primes])
            for _ in range(bsz)
        ]
    )


@pytest.mark.parametrize("d", [4, 16, 64, 256])
def test_ntt_roundtrip(d):
    primes = rns.rns_basis_primes(d, 3)
    tables = RingTables(d, primes)
    rng = np.random.default_rng(d)
    x = rand_batch(rng, 2, primes, d)
    fwd = ntt_forward(jnp.asarray(x), tables)
    back = np.asarray(ntt_inverse(fwd, tables))
    np.testing.assert_array_equal(back, x)


@pytest.mark.parametrize("d", [8, 64])
def test_ntt_matches_scalar_reference(d):
    primes = rns.rns_basis_primes(d, 2)
    tables = RingTables(d, primes)
    rng = np.random.default_rng(d + 1)
    x = rand_batch(rng, 1, primes, d)
    fwd = np.asarray(ntt_forward(jnp.asarray(x), tables))
    for l, p in enumerate(primes):
        psi_rev, _, _ = rns.ntt_tables(p, d)
        expect = ref.ntt_ref(x[0, l], p, psi_rev)
        np.testing.assert_array_equal(fwd[0, l], expect)


def test_modmul_kernel():
    d = 32
    primes = rns.rns_basis_primes(d, 4)
    rng = np.random.default_rng(7)
    x = rand_batch(rng, 3, primes, d)
    y = rand_batch(rng, 3, primes, d)
    out = np.asarray(modmul(jnp.asarray(x), jnp.asarray(y), jnp.array(primes)))
    for l, p in enumerate(primes):
        np.testing.assert_array_equal(out[:, l], (x[:, l] * y[:, l]) % p)


@settings(max_examples=12, deadline=None)
@given(
    log_d=st.integers(min_value=2, max_value=7),
    nlimb=st.integers(min_value=1, max_value=3),
    bsz=st.integers(min_value=1, max_value=3),
    seed=st.integers(min_value=0, max_value=2**31),
)
def test_polymul_matches_oracle(log_d, nlimb, bsz, seed):
    from compile.model import polymul

    d = 1 << log_d
    primes = rns.rns_basis_primes(d, nlimb)
    tables = RingTables(d, primes)
    rng = np.random.default_rng(seed)
    a = rand_batch(rng, bsz, primes, d)
    b = rand_batch(rng, bsz, primes, d)
    got = np.asarray(polymul(jnp.asarray(a), jnp.asarray(b), tables))
    expect = ref.polymul_ref_batch(a, b, primes)
    np.testing.assert_array_equal(got, expect)


def test_polymul_negacyclic_wrap():
    # x^{d-1} · x ≡ -1 (mod x^d + 1)
    from compile.model import polymul

    d = 8
    primes = rns.rns_basis_primes(d, 2)
    tables = RingTables(d, primes)
    a = np.zeros((1, 2, d), dtype=np.int64)
    b = np.zeros((1, 2, d), dtype=np.int64)
    a[:, :, d - 1] = 1
    b[:, :, 1] = 1
    out = np.asarray(polymul(jnp.asarray(a), jnp.asarray(b), tables))
    for l, p in enumerate(primes):
        assert out[0, l, 0] == p - 1
        assert (out[0, l, 1:] == 0).all()


def test_ct_tensor_fused_matches_separate():
    from compile.model import polymul, polymul_pair_accum

    d = 16
    primes = rns.rns_basis_primes(d, 2)
    tables = RingTables(d, primes)
    rng = np.random.default_rng(11)
    a0, a1, b0, b1 = (jnp.asarray(rand_batch(rng, 2, primes, d)) for _ in range(4))
    c0, c1, c2 = polymul_pair_accum(a0, a1, b0, b1, tables)
    np.testing.assert_array_equal(np.asarray(c0), np.asarray(polymul(a0, b0, tables)))
    mid = (
        np.asarray(polymul(a0, b1, tables)) + np.asarray(polymul(a1, b0, tables))
    ) % np.array(primes)[None, :, None]
    np.testing.assert_array_equal(np.asarray(c1), mid)
    np.testing.assert_array_equal(np.asarray(c2), np.asarray(polymul(a1, b1, tables)))


@settings(max_examples=8, deadline=None)
@given(
    log_d=st.integers(min_value=2, max_value=6),
    nlimb=st.integers(min_value=1, max_value=3),
    seed=st.integers(min_value=0, max_value=2**31),
)
def test_fused_polymul_matches_pallas(log_d, nlimb, seed):
    # The fused (vectorised) AOT graph and the Pallas pipeline must be
    # arithmetically identical.
    from compile.model import polymul, polymul_fused

    d = 1 << log_d
    primes = rns.rns_basis_primes(d, nlimb)
    tables = RingTables(d, primes)
    rng = np.random.default_rng(seed)
    a = jnp.asarray(rand_batch(rng, 2, primes, d))
    b = jnp.asarray(rand_batch(rng, 2, primes, d))
    np.testing.assert_array_equal(
        np.asarray(polymul_fused(a, b, tables)), np.asarray(polymul(a, b, tables))
    )


@settings(max_examples=8, deadline=None)
@given(
    log_d=st.integers(min_value=2, max_value=8),
    nlimb=st.integers(min_value=1, max_value=2),
    seed=st.integers(min_value=0, max_value=2**31),
)
def test_mxu_conv_matches_ntt_polymul(log_d, nlimb, seed):
    # The MXU-ablation matmul formulation (int8-limb systolic mapping)
    # must agree exactly with the NTT pipeline up to its d ≤ 256 range.
    from compile.kernels.conv_mxu import polymul_mxu
    from compile.model import polymul_fused

    d = 1 << log_d
    primes = rns.rns_basis_primes(d, nlimb)
    tables = RingTables(d, primes)
    rng = np.random.default_rng(seed)
    a = jnp.asarray(rand_batch(rng, 1, primes, d))
    b = jnp.asarray(rand_batch(rng, 1, primes, d))
    np.testing.assert_array_equal(
        np.asarray(polymul_mxu(a, b, primes)),
        np.asarray(polymul_fused(a, b, tables)),
    )
