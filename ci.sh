#!/usr/bin/env bash
# Offline mirror of .github/workflows/ci.yml: the same gate, runnable in
# sandboxed environments with no network access. Requires a Rust
# toolchain; fmt/clippy/pytest stages degrade to loud skips when their
# tools are unavailable, but the tier-1 gate (build + test) is mandatory.
set -euo pipefail
cd "$(dirname "$0")"

note() { printf '\n== %s ==\n' "$*"; }

if ! command -v cargo >/dev/null 2>&1; then
    echo "error: cargo not found — the tier-1 gate (cargo build --release && cargo test -q) cannot run" >&2
    exit 1
fi

if cargo fmt --version >/dev/null 2>&1; then
    note "cargo fmt --check"
    cargo fmt --check
else
    note "SKIPPED: rustfmt not installed"
fi

if cargo clippy --version >/dev/null 2>&1; then
    note "cargo clippy -D warnings"
    cargo clippy --all-targets -- -D warnings
else
    note "SKIPPED: clippy not installed"
fi

note "tier-1: cargo build --release"
cargo build --release

note "tier-1: cargo test -q"
cargo test -q

# Serving-tier saturation smoke: 120 fits from 12 concurrent clients
# across 3 tenants against a small bounded queue — every submission
# must complete bit-identically to a solo fit or bounce with a
# structured wire code. Release mode so the burst is tight.
note "coordinator saturation smoke: cargo test --release --test saturation"
cargo test --release --test saturation

# Chaos battery: the saturation burst re-run under every deterministic
# fault site (wire, lane, timer, cache, batcher, journal) with retrying
# clients and idempotent tokens — terminate-or-structured-code, no
# leaks, no double execution. Includes the restart-recovery scenarios:
# a journal-backed coordinator crashed mid-burst and rebuilt from its
# journal dir must recover every accepted job. Then the env-driven
# smoke scenario writes an els-chaos-v1 snapshot for the dep-free
# validator: faults must have fired, nothing may leak, and the client
# must really have retried.
note "chaos battery: cargo test --release --test chaos"
cargo test --release --test chaos
if command -v python3 >/dev/null 2>&1; then
    note "chaos smoke: ELS_FAULTS burst + chaos_check.py"
    chaos_file="$(mktemp -t els-chaos-XXXXXX.json)"
    ELS_FAULTS="wire_write:disconnect:0.1:41,lane:panic:0.1:43" \
        ELS_CHAOS_OUT="$chaos_file" \
        cargo test --release --test chaos chaos_smoke_writes_snapshot_for_ci
    python3 python/tools/chaos_check.py "$chaos_file" --expect-retries
    rm -f "$chaos_file"

    # Durability smoke: a short journal-backed burst leaves its
    # write-ahead journal behind; journal_check.py audits the WAL
    # byte-for-byte (frame checksums, record schema, full lifecycle).
    note "journal smoke: ELS_JOURNAL_OUT burst + journal_check.py"
    journal_dir="$(mktemp -d -t els-journal-XXXXXX)"
    ELS_JOURNAL_OUT="$journal_dir" \
        cargo test --release --test chaos journal_smoke_writes_wal_for_ci
    python3 python/tools/journal_check.py "$journal_dir" \
        --require accepted,started,done,acked
    rm -rf "$journal_dir"
else
    note "SKIPPED: python3 not installed — chaos snapshot gate not run"
fi

# Also drives the dot_pairs fusion tests (unit + e2e parity) through
# the oracle's summed-tensor-before-CRT-lift path.
note "tier-1 (oracle backend): ELS_MUL_BACKEND=bigint cargo test -q"
ELS_MUL_BACKEND=bigint cargo test -q

note "tier-1 (serial pool): ELS_POOL_WORKERS=1 cargo test -q"
ELS_POOL_WORKERS=1 cargo test -q

# Routes the env-dispatch e2e fit (and any Encoding::from_env caller)
# through the packed slot path: CRT batching, Galois rotations,
# fit_packed vs the unpacked parity oracle.
note "tier-1 (packed encoding): ELS_ENCODING=packed cargo test -q"
ELS_ENCODING=packed cargo test -q

# Flight-recorder smoke leg: one end-to-end encrypted fit with the
# tracer armed, then structural + phase-coverage validation of the
# emitted Chrome trace. The required set is backend-agnostic (the RNS
# conversion phases only appear under the full-RNS backend).
if command -v python3 >/dev/null 2>&1; then
    note "ELS_TRACE smoke: els selftest + trace_check.py"
    trace_file="$(mktemp -t els-trace-XXXXXX.json)"
    ELS_TRACE="$trace_file" ./target/release/els selftest
    python3 python/tools/trace_check.py "$trace_file" \
        --require ntt_forward,ntt_inverse,scale_round,relinearise,descent_iteration
    rm -f "$trace_file"
else
    note "SKIPPED: python3 not installed — ELS_TRACE smoke leg not run"
fi

note "cargo bench (toy profile; must not panic)"
# fhe_ops overwrites BENCH_fhe_ops.json — stash the committed baseline
# for the regression gate below.
bench_baseline="$(mktemp)"
trap 'rm -f "$bench_baseline"' EXIT
cp BENCH_fhe_ops.json "$bench_baseline"
cargo bench

if command -v python3 >/dev/null 2>&1; then
    note "bench-regression gate (mul_pairs vs committed baseline)"
    python3 python/tools/bench_check.py "$bench_baseline" BENCH_fhe_ops.json
else
    note "SKIPPED: python3 not installed — bench-regression gate not run"
fi

if command -v python3 >/dev/null 2>&1 && python3 -c 'import pytest' >/dev/null 2>&1; then
    note "pytest python/tests"
    # test_rns_basic.py is dependency-free, so a healthy run always
    # collects tests — empty collection (exit 5) is a real failure.
    python3 -m pytest python/tests -q
else
    note "SKIPPED: python3/pytest not installed"
fi

note "CI gate green"
