//! Coordinator-layer benchmarks: dynamic-batching throughput across
//! concurrent jobs vs serial submission, arena churn, and wire-codec
//! throughput.

use std::sync::Arc;
use std::time::Duration;

use els::coordinator::arena::CtArena;
use els::coordinator::batcher::{BatchConfig, BatchingEngine};
use els::coordinator::protocol as proto;
use els::fhe::encoding::encode_int;
use els::fhe::keys::keygen;
use els::fhe::params::FvParams;
use els::fhe::rng::ChaChaRng;
use els::fhe::{Ciphertext, FvContext};
use els::runtime::backend::{HeEngine, NativeEngine};
use els::util::bench::{bench, black_box, header};
use els::util::json::Json;

fn main() {
    let ctx = FvContext::new(FvParams::custom(256, 3, 24));
    let mut rng = ChaChaRng::from_seed(9100);
    let keys = keygen(&ctx, &mut rng);
    let m = encode_int(321, ctx.d());
    let cts: Vec<(Ciphertext, Ciphertext)> = (0..8)
        .map(|_| {
            (
                ctx.encrypt(&m, &keys.pk, &mut rng),
                ctx.encrypt(&m, &keys.pk, &mut rng),
            )
        })
        .collect();

    header("batching: 4 threads × 8 ct-muls");
    let native = Arc::new(NativeEngine::new(ctx.clone(), Arc::new(keys.rk.clone())));
    for (label, max_batch, wait_ms) in
        [("batch=1 (no coalescing)", 1usize, 0u64), ("batch=64 wait=2ms", 64, 2)]
    {
        let engine = BatchingEngine::new(
            native.clone(),
            BatchConfig { max_batch, max_wait: Duration::from_millis(wait_ms) },
        );
        bench(label, 1, 3, || {
            std::thread::scope(|s| {
                for _ in 0..4 {
                    let engine = engine.clone();
                    let cts = &cts;
                    s.spawn(move || {
                        let pairs: Vec<_> = cts.iter().map(|(a, b)| (a, b)).collect();
                        black_box(engine.mul_pairs(&pairs));
                    });
                }
            });
        });
        let (muls, _, _, batches) = engine.stats().snapshot();
        println!("    → {muls} muls in {batches} submit calls");
        engine.shutdown();
    }

    header("ciphertext arena");
    let ct = cts[0].0.clone();
    bench("arena insert+release ×1000", 1, 20, || {
        let mut arena = CtArena::new();
        let mut ids = Vec::with_capacity(100);
        for _ in 0..10 {
            for _ in 0..100 {
                ids.push(arena.insert(ct.clone()));
            }
            for id in ids.drain(..) {
                arena.release(id);
            }
        }
        black_box(arena.high_water_bytes());
    });

    header("wire codec (one ciphertext)");
    let json = proto::ct_to_json(&cts[0].0);
    let text = json.to_string_json();
    println!("    ciphertext wire size: {:.1} KiB", text.len() as f64 / 1024.0);
    bench("serialise ct → JSON", 2, 50, || {
        black_box(proto::ct_to_json(&cts[0].0).to_string_json());
    });
    bench("parse JSON → ct", 2, 50, || {
        let j = Json::parse(&text).unwrap();
        black_box(proto::ct_from_json(&ctx, &j).unwrap());
    });
}
