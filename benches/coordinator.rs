//! Coordinator-layer benchmarks: dynamic-batching throughput across
//! concurrent jobs vs serial submission, arena churn, and wire-codec
//! throughput.

use std::sync::Arc;
use std::time::Duration;

use els::coordinator::arena::CtArena;
use els::coordinator::batcher::{BatchConfig, BatchingEngine};
use els::coordinator::protocol as proto;
use els::fhe::encoding::encode_int;
use els::fhe::keys::keygen;
use els::fhe::params::FvParams;
use els::fhe::rng::ChaChaRng;
use els::fhe::{Ciphertext, FvContext};
use els::runtime::backend::{HeEngine, NativeEngine};
use els::util::bench::{bench, black_box, header};
use els::util::json::Json;

fn main() {
    let ctx = FvContext::new(FvParams::custom(256, 3, 24));
    let mut rng = ChaChaRng::from_seed(9100);
    let keys = keygen(&ctx, &mut rng);
    let m = encode_int(321, ctx.d());
    let cts: Vec<(Ciphertext, Ciphertext)> = (0..8)
        .map(|_| {
            (
                ctx.encrypt(&m, &keys.pk, &mut rng),
                ctx.encrypt(&m, &keys.pk, &mut rng),
            )
        })
        .collect();

    header("batching: 4 threads × 8 ct-muls");
    let native = Arc::new(NativeEngine::new(ctx.clone(), Arc::new(keys.rk.clone())));
    for (label, max_batch, wait_ms) in
        [("batch=1 (no coalescing)", 1usize, 0u64), ("batch=64 wait=2ms", 64, 2)]
    {
        let engine = BatchingEngine::new(
            native.clone(),
            BatchConfig { max_batch, max_wait: Duration::from_millis(wait_ms) },
        );
        bench(label, 1, 3, || {
            std::thread::scope(|s| {
                for _ in 0..4 {
                    let engine = engine.clone();
                    let cts = &cts;
                    s.spawn(move || {
                        let pairs: Vec<_> = cts.iter().map(|(a, b)| (a, b)).collect();
                        black_box(engine.mul_pairs(&pairs));
                    });
                }
            });
        });
        let (muls, _, _, batches) = engine.stats().snapshot();
        println!("    → {muls} muls in {batches} submit calls");
        engine.shutdown();
    }

    header("ciphertext arena");
    let ct = cts[0].0.clone();
    bench("arena insert+release ×1000", 1, 20, || {
        let mut arena = CtArena::new();
        let mut ids = Vec::with_capacity(100);
        for _ in 0..10 {
            for _ in 0..100 {
                ids.push(arena.insert(ct.clone()));
            }
            for id in ids.drain(..) {
                arena.release(id);
            }
        }
        black_box(arena.high_water_bytes());
    });

    header("wire codec (one ciphertext)");
    let json = proto::ct_to_json(&cts[0].0);
    let text = json.to_string_json();
    println!("    ciphertext wire size: {:.1} KiB", text.len() as f64 / 1024.0);
    bench("serialise ct → JSON", 2, 50, || {
        black_box(proto::ct_to_json(&cts[0].0).to_string_json());
    });
    bench("parse JSON → ct", 2, 50, || {
        let j = Json::parse(&text).unwrap();
        black_box(proto::ct_from_json(&ctx, &j).unwrap());
    });

    // Saturation: hundreds of tiny fits from 3 tenants hammering a
    // 4-lane coordinator with a bounded queue — measures end-to-end
    // serving throughput (admission + fair queueing + per-tenant
    // caches + coalesced execution) and prints the served/overloaded
    // split with the latency histogram.
    header("coordinator saturation: 240 fits, 3 tenants, 4 lanes");
    {
        use els::coordinator::job::JobSpec;
        use els::coordinator::scheduler::{Coordinator, CoordinatorConfig};
        use els::coordinator::tenant::TenantId;
        use els::data::synth;
        use els::els::encrypted::FitConfig;
        use els::els::exact::QuantisedData;
        use els::els::model::encrypt_dataset;
        use els::els::stepsize::nu_optimal;
        use els::fhe::params::{plan, PlanRequest};

        let mut rng = ChaChaRng::from_seed(9104);
        let (x, y) = synth::gaussian_regression(&mut rng, 6, 2, 0.2);
        let q = QuantisedData::from_f64(&x, &y, 2);
        let (xq, _) = q.dequantised();
        let nu = nu_optimal(&xq);
        let fit_ctx = FvContext::new(plan(&PlanRequest::gd(6, 2, 1, 2, nu)).unwrap());
        let fit_keys = keygen(&fit_ctx, &mut rng);
        let native =
            Arc::new(NativeEngine::new(fit_ctx.clone(), Arc::new(fit_keys.rk.clone())));
        let engine = BatchingEngine::new(native, BatchConfig::default());
        let coord = Coordinator::with_config(
            engine.clone(),
            CoordinatorConfig {
                lanes: 4,
                queue_capacity: 64,
                cache_budget_bytes: 8 << 20,
                cache_shards: 4,
            },
        );
        let tenants: Vec<TenantId> =
            ["acme", "globex", "initech"].iter().map(|s| TenantId::new(*s)).collect();
        let datasets: Vec<_> = (0..3)
            .map(|_| encrypt_dataset(&fit_ctx, &fit_keys.pk, &q, &mut rng))
            .collect();
        let t0 = std::time::Instant::now();
        let mut accepted = Vec::new();
        let mut overloaded = 0usize;
        for i in 0..240 {
            let t = i % 3;
            let spec = JobSpec::new(datasets[t].clone(), FitConfig::gd(1, nu), None)
                .with_tenant(tenants[t].clone());
            match coord.submit(spec) {
                Ok(id) => accepted.push(id),
                Err(_) => overloaded += 1,
            }
        }
        for &id in &accepted {
            coord.wait(id, Duration::from_secs(600)).unwrap();
            let _ = coord.take_result(id);
        }
        let wall = t0.elapsed();
        println!(
            "    → {} served + {overloaded} overloaded in {wall:.2?} \
             ({:.1} jobs/s)",
            accepted.len(),
            accepted.len() as f64 / wall.as_secs_f64()
        );
        println!("    → {}", coord.metrics.summary());
        println!("    → histogram: {}", coord.metrics.job_latency.to_json().to_string_json());
        println!("    → tenants: {}", coord.tenants().to_json().to_string_json());
        engine.shutdown();
    }
}
