//! Regenerates every convergence table/figure (Figures 1–4, 6–8,
//! Table 1, supp. Fig 1, Lemma 3) and times each — `cargo bench`
//! therefore reproduces the paper's evaluation artefacts into
//! `results/`.

use std::path::Path;

use els::figures;
use els::util::bench::{bench, header};

fn main() {
    header("paper figure regeneration (CSV into results/)");
    let out = Path::new("results");
    for id in ["fig1", "fig2", "fig3", "fig4", "tab1", "fig6", "fig7", "fig8", "sfig1", "lemma3"] {
        bench(&format!("figures::{id}"), 0, 1, || {
            figures::run(id, out).unwrap_or_else(|e| panic!("{id}: {e:#}"));
        });
    }
    println!("\nCSV written to results/ — see EXPERIMENTS.md for the paper-vs-measured table.");
}
