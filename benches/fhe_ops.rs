//! Homomorphic-operation microbenchmarks: the L1/L3 hot paths (NTT,
//! polymul native vs XLA-batched, encrypt/decrypt, ct-mul, relin) —
//! the inputs to the EXPERIMENTS.md §Perf iteration log.
//!
//! The `mul_pairs` section runs the same 1/4/16-pair batches on both
//! arithmetic backends (full-RNS default vs the exact-bigint oracle)
//! and writes the comparison to `BENCH_fhe_ops.json` — the bench
//! trajectory the ROADMAP tracks for the `mul_pairs` cost centre. The
//! `dot_pairs` section times one fused 8-pair inner-product group
//! against the pair-by-pair fold it replaces, and the `rotations`
//! section times packed Galois rotations/slot_sum against a full
//! ct-mul (both ratios tracked warn-only by bench_check.py).

use std::path::Path;
use std::sync::Arc;

use els::fhe::encoding::encode_int;
use els::fhe::keys::keygen;
use els::fhe::params::{FvParams, MulBackend};
use els::fhe::rng::ChaChaRng;
use els::fhe::{Ciphertext, FvContext};
use els::runtime::backend::{HeEngine, NativeEngine};
use els::runtime::pjrt::XlaEngine;
use els::util::bench::{bench, black_box, header, BenchStats};
use els::util::json::Json;

fn stats_json(s: &BenchStats) -> Json {
    Json::obj(vec![
        ("name", Json::str(&s.name)),
        ("iters", Json::Num(s.iters as f64)),
        ("mean_ns", Json::Num(s.mean.as_nanos() as f64)),
        ("min_ns", Json::Num(s.min.as_nanos() as f64)),
        ("max_ns", Json::Num(s.max.as_nanos() as f64)),
    ])
}

fn main() {
    header("FHE primitive ops (d=256, Lq=3)");
    let ctx = FvContext::new(FvParams::custom(256, 3, 24));
    let mut rng = ChaChaRng::from_seed(9001);
    let keys = keygen(&ctx, &mut rng);

    // NTT / polymul on all three rings.
    for (ring, what) in [
        (&ctx.ring_q, "Q"),
        (&ctx.ring_big, "Q∪E oracle"),
        (&ctx.ring_ext, "B∪m_sk"),
    ] {
        let label = format!("{what} (L={})", ring.nlimbs());
        let a = ring.sample_uniform(&mut rng);
        let b = ring.sample_uniform(&mut rng);
        bench(&format!("ntt fwd+inv {label}"), 3, 50, || {
            let mut t = a.clone();
            ring.ntt_forward(&mut t);
            ring.ntt_inverse(&mut t);
            black_box(&t);
        });
        bench(&format!("polymul native {label}"), 3, 50, || {
            black_box(ring.polymul(&a, &b));
        });
    }

    // Encrypt / decrypt / homomorphic ops.
    let m = encode_int(123_456, ctx.d());
    let ct_a = ctx.encrypt(&m, &keys.pk, &mut rng);
    let ct_b = ctx.encrypt(&m, &keys.pk, &mut rng);
    bench("encrypt", 2, 20, || {
        black_box(ctx.encrypt(&m, &keys.pk, &mut rng));
    });
    bench("decrypt", 2, 20, || {
        black_box(ctx.decrypt(&ct_a, &keys.sk));
    });
    bench("ct add", 2, 100, || {
        black_box(ctx.add_ct(&ct_a, &ct_b));
    });
    // mul_plain: cold (encode + NTT the operand every call, Coeff
    // ciphertext) vs cached (PlaintextNtt operand, NTT-resident
    // ciphertext — the steady state of the GD/NAG loops).
    let s_plain_cold = bench("plain mul cold", 2, 20, || {
        black_box(ctx.mul_plain(&ct_a, &m));
    });
    let m_cached = ctx.prepare_plaintext(&m);
    let ct_resident = ctx.mul_plain_prepared(&ct_a, &m_cached);
    assert!(ct_resident.is_ntt_resident());
    let s_plain_cached = bench("plain mul cached+resident", 2, 20, || {
        black_box(ctx.mul_plain_prepared(&ct_resident, &m_cached));
    });
    println!(
        "  -> cached/resident mul_plain speedup: {:.2}x",
        s_plain_cold.mean.as_nanos() as f64 / s_plain_cached.mean.as_nanos().max(1) as f64
    );
    bench("ct mul rns (tensor+scale)", 2, 10, || {
        black_box(ctx.mul_no_relin_rns(&ct_a, &ct_b));
    });
    bench("ct mul bigint (tensor+scale)", 2, 10, || {
        black_box(ctx.mul_no_relin_bigint(&ct_a, &ct_b));
    });
    let raw = ctx.mul_no_relin(&ct_a, &ct_b);
    bench("relinearise (RNS gadget)", 2, 10, || {
        black_box(ctx.relinearize(&raw, &keys.rk));
    });
    bench("ct mul full", 2, 10, || {
        black_box(ctx.mul_ct(&ct_a, &ct_b, &keys.rk));
    });

    // mul_pairs: full-RNS vs exact-bigint oracle on 1/4/16-pair batches.
    header("mul_pairs: full-RNS vs bigint oracle");
    let pairs_owned: Vec<_> = (0..16)
        .map(|_| {
            (
                ctx.encrypt(&m, &keys.pk, &mut rng),
                ctx.encrypt(&m, &keys.pk, &mut rng),
            )
        })
        .collect();
    let pairs: Vec<(&Ciphertext, &Ciphertext)> =
        pairs_owned.iter().map(|(a, b)| (a, b)).collect();
    let rk = Arc::new(keys.rk.clone());
    let rns = NativeEngine::with_backend(ctx.clone(), rk.clone(), MulBackend::FullRns);
    let big = NativeEngine::with_backend(ctx.clone(), rk.clone(), MulBackend::ExactBigint);
    let mut comparison: Vec<Json> = Vec::new();
    for &n in &[1usize, 4, 16] {
        let batch = &pairs[..n];
        let s_rns = bench(&format!("native rns {n}×ct-mul"), 1, 5, || {
            black_box(rns.mul_pairs(batch));
        });
        let s_big = bench(&format!("native bigint {n}×ct-mul"), 1, 5, || {
            black_box(big.mul_pairs(batch));
        });
        let speedup = s_big.mean.as_nanos() as f64 / s_rns.mean.as_nanos().max(1) as f64;
        println!("  -> {n}-pair speedup rns/bigint: {speedup:.2}x");
        comparison.push(Json::obj(vec![
            ("pairs", Json::Num(n as f64)),
            ("full_rns", stats_json(&s_rns)),
            ("exact_bigint", stats_json(&s_big)),
            ("speedup", Json::Num(speedup)),
        ]));
    }
    // Fused inner product: one n=8 dot_pairs group (accumulate tensors,
    // one scale-and-round + relinearisation) vs the pair-by-pair
    // mul_pairs + add fold it replaces. Machine-relative ratio, tracked
    // warn-only by bench_check.py until a measured baseline lands.
    header("dot_pairs fused inner product (one 8-pair group)");
    let group: Vec<(&Ciphertext, &Ciphertext)> = pairs[..8].to_vec();
    let s_fused = bench("dot_pairs 1×8 fused", 1, 5, || {
        black_box(rns.dot_pairs(&[group.as_slice()]));
    });
    let s_pairwise = bench("mul_pairs 8 + 7 adds", 1, 5, || {
        let prods = rns.mul_pairs(&group);
        let mut acc = prods[0].clone();
        for pr in &prods[1..] {
            acc = rns.add(&acc, pr);
        }
        black_box(acc);
    });
    let fusion_speedup =
        s_pairwise.mean.as_nanos() as f64 / s_fused.mean.as_nanos().max(1) as f64;
    println!("  -> 8-term fusion speedup: {fusion_speedup:.2}x");

    // End-to-end GD iteration: the paper's per-iteration cost centre
    // (two dot_pairs batches + cached plaintext muls + adds), on a
    // small encrypted dataset through the native engine.
    header("gd_iteration end-to-end (N=6, P=2, K=1)");
    let s_gd = {
        use els::data::synth;
        use els::els::encrypted::{fit, DatasetRef, FitConfig};
        use els::els::exact::QuantisedData;
        use els::els::model::encrypt_dataset;
        use els::fhe::params::{plan, PlanRequest};
        let mut rng = ChaChaRng::from_seed(9002);
        let (x, y) = synth::gaussian_regression(&mut rng, 6, 2, 0.2);
        let q = QuantisedData::from_f64(&x, &y, 2);
        let nu = els::els::stepsize::nu_optimal(&q.dequantised().0);
        let gd_ctx = FvContext::new(plan(&PlanRequest::gd(6, 2, 1, 2, nu)).unwrap());
        let gd_keys = keygen(&gd_ctx, &mut rng);
        let engine = NativeEngine::new(gd_ctx.clone(), Arc::new(gd_keys.rk.clone()));
        let data = encrypt_dataset(&gd_ctx, &gd_keys.pk, &q, &mut rng);
        bench("gd_iteration (fit K=1)", 1, 5, || {
            black_box(fit(&engine, &DatasetRef::Scalar(&data), &FitConfig::gd(1, nu)).unwrap());
        })
    };

    // Slot rotations on a packed context: one Galois key switch
    // (rotate_rows by 1) and a full slot_sum (log₂(d/2)+1 switches)
    // against a full ct-mul on the same parameters. All three run in
    // the same process, so the mul/rotate ratio is machine-relative —
    // tracked warn-only by bench_check.py like dot_pairs.
    header("rotations: packed rotate_rows / slot_sum (d=256)");
    let pctx = FvContext::new(FvParams::custom_packed(256, 3, 24).unwrap());
    let mut prng = ChaChaRng::from_seed(9003);
    let pkeys = keygen(&pctx, &mut prng);
    let pct_a = pctx.encrypt(&m, &pkeys.pk, &mut prng);
    let pct_b = pctx.encrypt(&m, &pkeys.pk, &mut prng);
    let s_rot = bench("rotate_rows 1 step", 2, 10, || {
        black_box(pctx.rotate_rows(&pct_a, 1, &pkeys.gk));
    });
    let s_slot_sum = bench("slot_sum (full total)", 1, 5, || {
        black_box(pctx.slot_sum(&pct_a, &pkeys.gk));
    });
    let s_pmul = bench("packed ct mul full", 2, 10, || {
        black_box(pctx.mul_ct(&pct_a, &pct_b, &pkeys.rk));
    });
    let mul_over_rotate =
        s_pmul.mean.as_nanos() as f64 / s_rot.mean.as_nanos().max(1) as f64;
    println!("  -> ct-mul / 1-step-rotation cost ratio: {mul_over_rotate:.2}x");

    let report = Json::obj(vec![
        ("bench", Json::str("fhe_ops::mul_pairs")),
        ("status", Json::str("measured")),
        ("d", Json::Num(ctx.d() as f64)),
        ("q_count", Json::Num(ctx.params.q_count as f64)),
        ("ext_count", Json::Num(ctx.params.ext_count as f64)),
        ("t_bits", Json::Num((ctx.t.bit_len() - 1) as f64)),
        ("batches", Json::Arr(comparison)),
        (
            "mul_plain",
            Json::obj(vec![
                ("cold", stats_json(&s_plain_cold)),
                ("cached", stats_json(&s_plain_cached)),
            ]),
        ),
        (
            "dot_pairs",
            Json::obj(vec![
                ("group", Json::Num(8.0)),
                ("fused", stats_json(&s_fused)),
                ("pairwise", stats_json(&s_pairwise)),
                ("speedup", Json::Num(fusion_speedup)),
            ]),
        ),
        ("gd_iteration", stats_json(&s_gd)),
        (
            "rotations",
            Json::obj(vec![
                ("d", Json::Num(pctx.d() as f64)),
                ("rotate_1", stats_json(&s_rot)),
                ("slot_sum", stats_json(&s_slot_sum)),
                ("ct_mul", stats_json(&s_pmul)),
                ("mul_over_rotate", Json::Num(mul_over_rotate)),
            ]),
        ),
    ]);
    match std::fs::write("BENCH_fhe_ops.json", report.to_string_json()) {
        Ok(()) => println!("wrote BENCH_fhe_ops.json"),
        Err(e) => println!("(could not write BENCH_fhe_ops.json: {e})"),
    }

    // Batched engines: native vs XLA (ablation — DESIGN.md §8).
    match XlaEngine::new(ctx.clone(), &keys.rk, Path::new("artifacts")) {
        Ok(xla) => {
            header("mul_pairs batching: XLA");
            bench("xla engine 16×ct-mul", 1, 5, || {
                black_box(xla.mul_pairs(&pairs));
            });
            let single: Vec<_> = pairs[..1].to_vec();
            bench("xla engine 1×ct-mul", 1, 5, || {
                black_box(xla.mul_pairs(&single));
            });
        }
        Err(e) => println!("(xla benches skipped: {e:#})"),
    }
}
