//! Homomorphic-operation microbenchmarks: the L1/L3 hot paths (NTT,
//! polymul native vs XLA-batched, encrypt/decrypt, ct-mul, relin) —
//! the inputs to the EXPERIMENTS.md §Perf iteration log.

use std::path::Path;
use std::sync::Arc;

use els::fhe::encoding::encode_int;
use els::fhe::keys::keygen;
use els::fhe::params::FvParams;
use els::fhe::rng::ChaChaRng;
use els::fhe::FvContext;
use els::runtime::backend::{HeEngine, NativeEngine};
use els::runtime::pjrt::XlaEngine;
use els::util::bench::{bench, black_box, header};

fn main() {
    header("FHE primitive ops (d=256, Lq=3)");
    let ctx = FvContext::new(FvParams::custom(256, 3, 24));
    let mut rng = ChaChaRng::from_seed(9001);
    let keys = keygen(&ctx, &mut rng);

    // NTT / polymul on both rings.
    for (ring, label) in [(&ctx.ring_q, "Q (L=3)"), (&ctx.ring_big, "Q∪E (L=7)")] {
        let a = ring.sample_uniform(&mut rng);
        let b = ring.sample_uniform(&mut rng);
        bench(&format!("ntt fwd+inv {label}"), 3, 50, || {
            let mut t = a.clone();
            ring.ntt_forward(&mut t);
            ring.ntt_inverse(&mut t);
            black_box(&t);
        });
        bench(&format!("polymul native {label}"), 3, 50, || {
            black_box(ring.polymul(&a, &b));
        });
    }

    // Encrypt / decrypt / homomorphic ops.
    let m = encode_int(123_456, ctx.d());
    let ct_a = ctx.encrypt(&m, &keys.pk, &mut rng);
    let ct_b = ctx.encrypt(&m, &keys.pk, &mut rng);
    bench("encrypt", 2, 20, || {
        black_box(ctx.encrypt(&m, &keys.pk, &mut rng));
    });
    bench("decrypt", 2, 20, || {
        black_box(ctx.decrypt(&ct_a, &keys.sk));
    });
    bench("ct add", 2, 100, || {
        black_box(ctx.add_ct(&ct_a, &ct_b));
    });
    bench("plain mul", 2, 20, || {
        black_box(ctx.mul_plain(&ct_a, &m));
    });
    bench("ct mul (tensor+scale)", 2, 10, || {
        black_box(ctx.mul_no_relin(&ct_a, &ct_b));
    });
    let raw = ctx.mul_no_relin(&ct_a, &ct_b);
    bench("relinearise", 2, 10, || {
        black_box(ctx.relinearize(&raw, &keys.rk));
    });
    bench("ct mul full", 2, 10, || {
        black_box(ctx.mul_ct(&ct_a, &ct_b, &keys.rk));
    });

    // Batched engines: native vs XLA (ablation — DESIGN.md §8).
    header("mul_pairs batching (16 pairs)");
    let pairs_owned: Vec<_> = (0..16)
        .map(|_| {
            (
                ctx.encrypt(&m, &keys.pk, &mut rng),
                ctx.encrypt(&m, &keys.pk, &mut rng),
            )
        })
        .collect();
    let pairs: Vec<_> = pairs_owned.iter().map(|(a, b)| (a, b)).collect();
    let native = NativeEngine::new(ctx.clone(), Arc::new(keys.rk.clone()));
    bench("native engine 16×ct-mul", 1, 5, || {
        black_box(native.mul_pairs(&pairs));
    });
    match XlaEngine::new(ctx.clone(), &keys.rk, Path::new("artifacts")) {
        Ok(xla) => {
            bench("xla engine 16×ct-mul", 1, 5, || {
                black_box(xla.mul_pairs(&pairs));
            });
            let single: Vec<_> = pairs[..1].to_vec();
            bench("xla engine 1×ct-mul", 1, 5, || {
                black_box(xla.mul_pairs(&single));
            });
        }
        Err(e) => println!("(xla benches skipped: {e:#})"),
    }
}
