//! Homomorphic-operation microbenchmarks: the L1/L3 hot paths (NTT,
//! polymul native vs XLA-batched, encrypt/decrypt, ct-mul, relin) —
//! the inputs to the EXPERIMENTS.md §Perf iteration log.
//!
//! The `mul_pairs` section runs the same 1/4/16-pair batches on both
//! arithmetic backends (full-RNS default vs the exact-bigint oracle)
//! and writes the comparison to `BENCH_fhe_ops.json` — the bench
//! trajectory the ROADMAP tracks for the `mul_pairs` cost centre.

use std::path::Path;
use std::sync::Arc;

use els::fhe::encoding::encode_int;
use els::fhe::keys::keygen;
use els::fhe::params::{FvParams, MulBackend};
use els::fhe::rng::ChaChaRng;
use els::fhe::{Ciphertext, FvContext};
use els::runtime::backend::{HeEngine, NativeEngine};
use els::runtime::pjrt::XlaEngine;
use els::util::bench::{bench, black_box, header, BenchStats};
use els::util::json::Json;

fn stats_json(s: &BenchStats) -> Json {
    Json::obj(vec![
        ("name", Json::str(&s.name)),
        ("iters", Json::Num(s.iters as f64)),
        ("mean_ns", Json::Num(s.mean.as_nanos() as f64)),
        ("min_ns", Json::Num(s.min.as_nanos() as f64)),
        ("max_ns", Json::Num(s.max.as_nanos() as f64)),
    ])
}

fn main() {
    header("FHE primitive ops (d=256, Lq=3)");
    let ctx = FvContext::new(FvParams::custom(256, 3, 24));
    let mut rng = ChaChaRng::from_seed(9001);
    let keys = keygen(&ctx, &mut rng);

    // NTT / polymul on all three rings.
    for (ring, what) in [
        (&ctx.ring_q, "Q"),
        (&ctx.ring_big, "Q∪E oracle"),
        (&ctx.ring_ext, "B∪m_sk"),
    ] {
        let label = format!("{what} (L={})", ring.nlimbs());
        let a = ring.sample_uniform(&mut rng);
        let b = ring.sample_uniform(&mut rng);
        bench(&format!("ntt fwd+inv {label}"), 3, 50, || {
            let mut t = a.clone();
            ring.ntt_forward(&mut t);
            ring.ntt_inverse(&mut t);
            black_box(&t);
        });
        bench(&format!("polymul native {label}"), 3, 50, || {
            black_box(ring.polymul(&a, &b));
        });
    }

    // Encrypt / decrypt / homomorphic ops.
    let m = encode_int(123_456, ctx.d());
    let ct_a = ctx.encrypt(&m, &keys.pk, &mut rng);
    let ct_b = ctx.encrypt(&m, &keys.pk, &mut rng);
    bench("encrypt", 2, 20, || {
        black_box(ctx.encrypt(&m, &keys.pk, &mut rng));
    });
    bench("decrypt", 2, 20, || {
        black_box(ctx.decrypt(&ct_a, &keys.sk));
    });
    bench("ct add", 2, 100, || {
        black_box(ctx.add_ct(&ct_a, &ct_b));
    });
    bench("plain mul", 2, 20, || {
        black_box(ctx.mul_plain(&ct_a, &m));
    });
    bench("ct mul rns (tensor+scale)", 2, 10, || {
        black_box(ctx.mul_no_relin_rns(&ct_a, &ct_b));
    });
    bench("ct mul bigint (tensor+scale)", 2, 10, || {
        black_box(ctx.mul_no_relin_bigint(&ct_a, &ct_b));
    });
    let raw = ctx.mul_no_relin(&ct_a, &ct_b);
    bench("relinearise (RNS gadget)", 2, 10, || {
        black_box(ctx.relinearize(&raw, &keys.rk));
    });
    bench("ct mul full", 2, 10, || {
        black_box(ctx.mul_ct(&ct_a, &ct_b, &keys.rk));
    });

    // mul_pairs: full-RNS vs exact-bigint oracle on 1/4/16-pair batches.
    header("mul_pairs: full-RNS vs bigint oracle");
    let pairs_owned: Vec<_> = (0..16)
        .map(|_| {
            (
                ctx.encrypt(&m, &keys.pk, &mut rng),
                ctx.encrypt(&m, &keys.pk, &mut rng),
            )
        })
        .collect();
    let pairs: Vec<(&Ciphertext, &Ciphertext)> =
        pairs_owned.iter().map(|(a, b)| (a, b)).collect();
    let rk = Arc::new(keys.rk.clone());
    let rns = NativeEngine::with_backend(ctx.clone(), rk.clone(), MulBackend::FullRns);
    let big = NativeEngine::with_backend(ctx.clone(), rk.clone(), MulBackend::ExactBigint);
    let mut comparison: Vec<Json> = Vec::new();
    for &n in &[1usize, 4, 16] {
        let batch = &pairs[..n];
        let s_rns = bench(&format!("native rns {n}×ct-mul"), 1, 5, || {
            black_box(rns.mul_pairs(batch));
        });
        let s_big = bench(&format!("native bigint {n}×ct-mul"), 1, 5, || {
            black_box(big.mul_pairs(batch));
        });
        let speedup = s_big.mean.as_nanos() as f64 / s_rns.mean.as_nanos().max(1) as f64;
        println!("  -> {n}-pair speedup rns/bigint: {speedup:.2}x");
        comparison.push(Json::obj(vec![
            ("pairs", Json::Num(n as f64)),
            ("full_rns", stats_json(&s_rns)),
            ("exact_bigint", stats_json(&s_big)),
            ("speedup", Json::Num(speedup)),
        ]));
    }
    let report = Json::obj(vec![
        ("bench", Json::str("fhe_ops::mul_pairs")),
        ("status", Json::str("measured")),
        ("d", Json::Num(ctx.d() as f64)),
        ("q_count", Json::Num(ctx.params.q_count as f64)),
        ("ext_count", Json::Num(ctx.params.ext_count as f64)),
        ("t_bits", Json::Num((ctx.t.bit_len() - 1) as f64)),
        ("batches", Json::Arr(comparison)),
    ]);
    match std::fs::write("BENCH_fhe_ops.json", report.to_string_json()) {
        Ok(()) => println!("wrote BENCH_fhe_ops.json"),
        Err(e) => println!("(could not write BENCH_fhe_ops.json: {e})"),
    }

    // Batched engines: native vs XLA (ablation — DESIGN.md §8).
    match XlaEngine::new(ctx.clone(), &keys.rk, Path::new("artifacts")) {
        Ok(xla) => {
            header("mul_pairs batching: XLA");
            bench("xla engine 16×ct-mul", 1, 5, || {
                black_box(xla.mul_pairs(&pairs));
            });
            let single: Vec<_> = pairs[..1].to_vec();
            bench("xla engine 1×ct-mul", 1, 5, || {
                black_box(xla.mul_pairs(&single));
            });
        }
        Err(e) => println!("(xla benches skipped: {e:#})"),
    }
}
