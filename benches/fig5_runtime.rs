//! Figure 5 / supplementary Figure 2: the encrypted-cost curves.
//! Runs the real FV pipeline (keygen → encrypt → ELS-GD → decrypt) over
//! the paper's (P, MMD) grid and the two applications, writing
//! `results/fig5_costs.csv` and `results/sfig2_application_costs.csv`.

use std::path::Path;

use els::figures;
use els::util::bench::{bench, header};

fn main() {
    header("encrypted cost curves (real FV pipeline)");
    let out = Path::new("results");
    bench("figures::fig5 (P∈{2,25} × K∈{1..3})", 0, 1, || {
        figures::run("fig5", out).expect("fig5");
    });
    bench("figures::sfig2 (mood N=28 K=2; prostate N=97 K=1)", 0, 1, || {
        figures::run("sfig2", out).expect("sfig2");
    });
    // Print the resulting tables for the bench log.
    for f in ["fig5_costs.csv", "sfig2_application_costs.csv"] {
        if let Ok(text) = std::fs::read_to_string(out.join(f)) {
            println!("\n--- {f} ---\n{text}");
        }
    }
}
