//! Chaos battery: the saturation burst re-run under every deterministic
//! fault site (`util::faults`), with retrying clients and idempotent
//! submission tokens. The contract under every fault mix:
//!
//! - every submission terminates — a bit-identical fit or a structured
//!   error code from a per-scenario allowlist; never a hang;
//! - nothing leaks — queue slots, tracked jobs and timer handles all
//!   drain to zero (counter-asserted over the `health` verb);
//! - nothing double-executes — a resubmitted idempotency token
//!   re-attaches to the original job with the engine's ct-mul counter
//!   unchanged;
//! - with no faults armed the registry is a counter-asserted no-op and
//!   the burst's ciphertexts are bit-identical to solo fits.
//!
//! Scenarios serialise on the fault registry's exclusive session lock,
//! so armed faults never bleed into a neighbouring test.

use std::sync::{Arc, OnceLock};
use std::time::{Duration, Instant};

use els::coordinator::batcher::{BatchConfig, BatchingEngine};
use els::coordinator::job::JobId;
use els::coordinator::journal;
use els::coordinator::protocol::ErrorCode;
use els::coordinator::retry::{RetryPolicy, RetryingClient};
use els::coordinator::scheduler::{Coordinator, CoordinatorConfig};
use els::coordinator::service::{Client, Server};
use els::data::synth;
use els::els::encrypted::{fit, DatasetRef, FitConfig};
use els::els::exact::QuantisedData;
use els::els::model::{encrypt_dataset, EncryptedDataset};
use els::els::stepsize::nu_optimal;
use els::fhe::keys::keygen;
use els::fhe::params::{plan, PlanRequest};
use els::fhe::rng::ChaChaRng;
use els::fhe::{Ciphertext, FvContext, KeySet};
use els::math::poly::RnsPoly;
use els::runtime::backend::{HeEngine, NativeEngine};
use els::util::faults::{self, FaultKind, FaultSession, FaultSite, FaultSpec};
use els::util::json::Json;

const CLIENTS: usize = 12;
const PER_CLIENT: usize = 10;
const TENANTS: [&str; 3] = ["acme", "globex", "initech"];

/// Residency-normalised ciphertext bits (NTT-resident and coefficient
/// forms are exact representations of the same ciphertext).
fn coeff_polys(ctx: &FvContext, betas: &[Ciphertext]) -> Vec<Vec<RnsPoly>> {
    betas
        .iter()
        .map(|ct| ct.polys.iter().map(|p| ctx.ring_q.coeff_form(p).into_owned()).collect())
        .collect()
}

struct Fixture {
    ctx: Arc<FvContext>,
    keys: KeySet,
    cfg: FitConfig,
    datasets: Vec<EncryptedDataset>,
    solo: Vec<Vec<Vec<RnsPoly>>>,
}

/// Shared across scenarios: keygen + solo reference fits are the
/// expensive part and are fault-independent (solo fits run on a
/// private engine before any session arms).
fn fixture() -> &'static Fixture {
    static FX: OnceLock<Fixture> = OnceLock::new();
    FX.get_or_init(|| {
        let mut rng = ChaChaRng::from_seed(777);
        let (x, y) = synth::gaussian_regression(&mut rng, 6, 2, 0.2);
        let q = QuantisedData::from_f64(&x, &y, 2);
        let (xq, _) = q.dequantised();
        let nu = nu_optimal(&xq);
        let params = plan(&PlanRequest::gd(6, 2, 1, 2, nu)).unwrap();
        let ctx = FvContext::new(params);
        let keys = keygen(&ctx, &mut rng);
        let cfg = FitConfig::gd(1, nu);
        let datasets: Vec<_> =
            (0..TENANTS.len()).map(|_| encrypt_dataset(&ctx, &keys.pk, &q, &mut rng)).collect();
        let solo: Vec<_> = datasets
            .iter()
            .map(|d| {
                let engine = NativeEngine::new(ctx.clone(), Arc::new(keys.rk.clone()));
                let f = fit(&engine, &DatasetRef::Scalar(d), &cfg).unwrap().fit;
                coeff_polys(&ctx, &f.betas)
            })
            .collect();
        Fixture { ctx, keys, cfg, datasets, solo }
    })
}

/// Poll a predicate over the wire until it holds or ~5 s elapse.
fn eventually(mut probe: impl FnMut() -> bool, what: &str) {
    let t0 = Instant::now();
    while t0.elapsed() < Duration::from_secs(5) {
        if probe() {
            return;
        }
        std::thread::sleep(Duration::from_millis(10));
    }
    panic!("timed out waiting for {what}");
}

fn health_u64(h: &Json, key: &str) -> u64 {
    h.get(key).and_then(Json::as_u64).unwrap_or_else(|| panic!("health missing {key}"))
}

/// Fresh per-test journal directory (removed on success).
fn tmpdir(tag: &str) -> std::path::PathBuf {
    let dir = std::env::temp_dir().join(format!(
        "els-chaos-{tag}-{}-{:?}",
        std::process::id(),
        std::thread::current().id()
    ));
    let _ = std::fs::remove_dir_all(&dir);
    dir
}

/// The saturation burst under a fault mix. Every submission must
/// terminate with a bit-identical fit or a code from `allowed`; all
/// server-side state must drain to zero afterwards. Returns
/// `(completed, failed, retries)`.
fn run_scenario(
    name: &str,
    specs: &[FaultSpec],
    allowed: &[ErrorCode],
    deadline_ms: Option<u64>,
) -> (usize, usize, u64) {
    let fx = fixture();
    let native = Arc::new(NativeEngine::new(fx.ctx.clone(), Arc::new(fx.keys.rk.clone())));
    let engine = BatchingEngine::new(native.clone(), BatchConfig::default());
    let coord = Coordinator::with_config(
        engine.clone(),
        CoordinatorConfig {
            lanes: 2,
            queue_capacity: 8,
            cache_budget_bytes: 4 << 20,
            cache_shards: 2,
            checkpoint_every: 1,
        },
    );
    let mut server = Server::start(coord, "127.0.0.1:0").unwrap();
    let addr = server.addr.to_string();

    let injected_before = faults::injected_total();
    let session = FaultSession::activate(specs);

    // Outcome per submission: Ok(tenant, betas) or Err(code). Retrying
    // clients with per-client jitter seeds; tiny real backoffs (1..8ms)
    // so overload retries give the queue time to drain.
    type ClientRun = (Vec<Result<(usize, Vec<Vec<RnsPoly>>), ErrorCode>>, Vec<JobId>, u64);
    let results: Vec<ClientRun> =
        std::thread::scope(|s| {
            let handles: Vec<_> = (0..CLIENTS)
                .map(|c| {
                    let (addr, fx) = (&addr, fx);
                    s.spawn(move || {
                        let t = c % TENANTS.len();
                        let mut rc =
                            RetryingClient::new(addr, RetryPolicy::new(6, 1, 8, 5000 + c as u64));
                        let mut ids = Vec::new();
                        let mut out = Vec::new();
                        for j in 0..PER_CLIENT {
                            let token = format!("{name}-c{c}-j{j}");
                            match rc.submit(
                                &fx.datasets[t],
                                &fx.cfg,
                                None,
                                Some(TENANTS[t]),
                                deadline_ms,
                                &token,
                            ) {
                                Ok(id) => ids.push(id),
                                Err(e) => out.push(Err(e.code)),
                            }
                        }
                        for &id in &ids {
                            let r = rc.result(&fx.ctx, id);
                            // Defensive ack: `result` already acks on
                            // success, but under write faults that ack
                            // can be lost — and failed jobs need an
                            // explicit release. Idempotent either way.
                            let _ = rc.ack(id);
                            match r {
                                Ok(f) => out.push(Ok((t, coeff_polys(&fx.ctx, &f.betas)))),
                                Err(e) => out.push(Err(e.code)),
                            }
                        }
                        (out, ids, rc.retries())
                    })
                })
                .collect();
            handles.into_iter().map(|h| h.join().unwrap()).collect()
        });
    drop(session); // disarm before the drain assertions below

    let retries: u64 = results.iter().map(|(_, _, r)| r).sum();
    let all_ids: Vec<JobId> = results.iter().flat_map(|(_, ids, _)| ids.iter().copied()).collect();
    let outcomes: Vec<_> = results.into_iter().flat_map(|(out, _, _)| out).collect();
    assert_eq!(outcomes.len(), CLIENTS * PER_CLIENT, "[{name}] every submission must terminate");
    let mut completed = 0usize;
    let mut failed = 0usize;
    for o in &outcomes {
        match o {
            Ok((t, betas)) => {
                completed += 1;
                assert_eq!(betas, &fx.solo[*t], "[{name}] fit diverged from solo ciphertexts");
            }
            Err(code) => {
                failed += 1;
                assert!(allowed.contains(code), "[{name}] unexpected terminal code {code}");
            }
        }
    }
    assert!(completed >= 1, "[{name}] chaos must not starve every job");
    assert!(
        faults::injected_total() > injected_before,
        "[{name}] armed faults never fired — the scenario tested nothing"
    );

    // Nothing leaks: queue, lanes, tracked jobs and timer handles all
    // drain to zero once every outcome is acked. A client that
    // exhausted its retry budget on `result`/`ack` while faults were
    // armed leaves its job tracked, so each poll re-acks every id
    // (idempotent, faults now off — running jobs say `false` now and
    // release on a later poll) before reading `health`: the drain is
    // deterministic rather than hostage to how unlucky the faults were.
    let mut probe = Client::connect(&addr).unwrap();
    eventually(
        || {
            for &id in &all_ids {
                let _ = probe.ack(id);
            }
            let h = probe.health().unwrap();
            health_u64(&h, "queue_depth") == 0
                && health_u64(&h, "running") == 0
                && health_u64(&h, "tracked_jobs") == 0
                && health_u64(&h, "timers_live") == 0
        },
        "queue/lanes/jobs/timers to drain",
    );
    server.stop();
    engine.shutdown();
    (completed, failed, retries)
}

#[test]
fn chaos_wire_faults_resolve_via_retry_and_tokens() {
    let specs = [
        FaultSpec { site: FaultSite::WireRead, kind: FaultKind::Disconnect, rate: 0.05, seed: 11 },
        FaultSpec { site: FaultSite::WireRead, kind: FaultKind::IoError, rate: 0.05, seed: 12 },
        FaultSpec {
            site: FaultSite::WireWrite,
            kind: FaultKind::PartialWrite,
            rate: 0.05,
            seed: 13,
        },
        FaultSpec { site: FaultSite::WireWrite, kind: FaultKind::Disconnect, rate: 0.05, seed: 14 },
        FaultSpec { site: FaultSite::WireWrite, kind: FaultKind::IoError, rate: 0.05, seed: 15 },
    ];
    // Transport/overload errors are retried; a client that exhausts its
    // budget reports the transient code it last saw.
    let (completed, _failed, retries) = run_scenario(
        "wire",
        &specs,
        &[ErrorCode::Transport, ErrorCode::Overloaded],
        None,
    );
    assert!(completed >= TENANTS.len(), "wire chaos should still complete most jobs");
    assert!(retries >= 1, "5% fault rates over 120 jobs must trigger retries");
}

#[test]
fn chaos_lane_panics_fail_jobs_without_killing_lanes() {
    let specs =
        [FaultSpec { site: FaultSite::Lane, kind: FaultKind::Panic, rate: 0.3, seed: 13 }];
    let (completed, failed, _) = run_scenario(
        "lane",
        &specs,
        &[ErrorCode::JobFailed, ErrorCode::Overloaded, ErrorCode::Transport],
        None,
    );
    assert!(failed >= 1, "a 30% panic rate over 120 jobs must fail some");
    assert!(completed >= 1, "panics must be contained per-job, not kill the lanes");
}

#[test]
fn chaos_timer_late_and_spurious_fires_are_harmless() {
    let specs = [
        FaultSpec { site: FaultSite::Timer, kind: FaultKind::Late, rate: 0.2, seed: 17 },
        FaultSpec { site: FaultSite::Timer, kind: FaultKind::Spurious, rate: 0.2, seed: 19 },
    ];
    // Generous 60s deadlines park a timer per job: spurious fires must
    // re-check the real deadline (no premature expiry), late fires must
    // only delay. Every job completes.
    let (completed, failed, _) = run_scenario(
        "timer",
        &specs,
        &[ErrorCode::Overloaded, ErrorCode::Transport],
        Some(60_000),
    );
    assert!(completed >= TENANTS.len());
    assert_eq!(
        completed + failed,
        CLIENTS * PER_CLIENT,
        "timer chaos must never lose a submission"
    );
}

#[test]
fn chaos_forced_cache_eviction_never_changes_bits() {
    let specs =
        [FaultSpec { site: FaultSite::Cache, kind: FaultKind::Evict, rate: 0.5, seed: 23 }];
    // Operand-cache residency is a performance property, never a
    // correctness one: evicting half the lookups changes nothing but
    // rebuild work. The bit-identity assertion inside run_scenario is
    // the whole point here.
    let (completed, _, _) = run_scenario(
        "cache",
        &specs,
        &[ErrorCode::Overloaded, ErrorCode::Transport],
        None,
    );
    assert!(completed >= TENANTS.len());
}

#[test]
fn chaos_batcher_dispatch_failures_fail_only_their_jobs() {
    let specs =
        [FaultSpec { site: FaultSite::Batcher, kind: FaultKind::Fail, rate: 0.3, seed: 29 }];
    let (completed, failed, _) = run_scenario(
        "batcher",
        &specs,
        &[ErrorCode::JobFailed, ErrorCode::Overloaded, ErrorCode::Transport],
        None,
    );
    assert!(failed >= 1, "a 30% dispatch-failure rate must fail some jobs");
    assert!(completed >= 1, "the dispatcher must survive injected failures");
}

#[test]
fn idempotent_token_resubmission_over_the_wire_never_recomputes() {
    let _quiet = faults::exclusion();
    let fx = fixture();
    let native = Arc::new(NativeEngine::new(fx.ctx.clone(), Arc::new(fx.keys.rk.clone())));
    let engine = BatchingEngine::new(native.clone(), BatchConfig::default());
    let coord = Coordinator::with_config(
        engine.clone(),
        CoordinatorConfig {
            lanes: 2,
            queue_capacity: 8,
            cache_budget_bytes: 4 << 20,
            cache_shards: 2,
            checkpoint_every: 1,
        },
    );
    let mut server = Server::start(coord, "127.0.0.1:0").unwrap();
    let addr = server.addr.to_string();
    let mut client = Client::connect(&addr).unwrap();

    let id1 = client
        .submit_opts(&fx.datasets[0], &fx.cfg, None, Some(TENANTS[0]), None, Some("tok-1"))
        .unwrap();
    eventually(
        || matches!(client.status(id1).unwrap().as_str(), "done" | "failed"),
        "first submission to finish",
    );
    // Simulated lost reply: the client never saw `id1` land, so it
    // resubmits the same token. Same job id, zero extra engine work.
    let muls_before = native.stats().snapshot().0;
    let id2 = client
        .submit_opts(&fx.datasets[0], &fx.cfg, None, Some(TENANTS[0]), None, Some("tok-1"))
        .unwrap();
    assert_eq!(id2, id1, "token resubmission must re-attach to the original job");
    assert_eq!(
        native.stats().snapshot().0,
        muls_before,
        "token dedup must not re-execute the fit"
    );
    // The result survives a re-read (peek, not take) …
    let f1 = client.result(&fx.ctx, id1).unwrap(); // auto-acks on success
    assert_eq!(coeff_polys(&fx.ctx, &f1.betas), fx.solo[0]);
    // … and after the ack both the job and its token are gone: the
    // same token now names a fresh job.
    assert!(!client.ack(id1).unwrap(), "auto-ack already released the job");
    let id3 = client
        .submit_opts(&fx.datasets[0], &fx.cfg, None, Some(TENANTS[0]), None, Some("tok-1"))
        .unwrap();
    assert_ne!(id3, id1, "an acked token must not resurrect the released job");
    let f3 = client.result(&fx.ctx, id3).unwrap();
    assert_eq!(coeff_polys(&fx.ctx, &f3.betas), fx.solo[0]);

    let h = client.health().unwrap();
    assert_eq!(health_u64(&h, "tracked_jobs"), 0, "acked jobs must not leak");
    server.stop();
    engine.shutdown();
}

#[test]
fn fault_free_burst_is_a_counter_asserted_noop() {
    // Exclusion guard: no session can arm while this runs, so every
    // probe must take the disabled fast path — and the serving tier
    // must behave exactly as the pre-chaos stack did.
    let _quiet = faults::exclusion();
    let fx = fixture();
    let checked_before = faults::checked_total();
    let injected_before = faults::injected_total();

    let native = Arc::new(NativeEngine::new(fx.ctx.clone(), Arc::new(fx.keys.rk.clone())));
    let engine = BatchingEngine::new(native.clone(), BatchConfig::default());
    let coord = Coordinator::with_config(
        engine.clone(),
        CoordinatorConfig {
            lanes: 2,
            queue_capacity: 16,
            cache_budget_bytes: 4 << 20,
            cache_shards: 2,
            checkpoint_every: 1,
        },
    );
    let mut server = Server::start(coord, "127.0.0.1:0").unwrap();
    let addr = server.addr.to_string();
    let mut client = Client::connect(&addr).unwrap();
    let mut ids = Vec::new();
    for t in 0..TENANTS.len() {
        for j in 0..2 {
            let token = format!("noop-{t}-{j}");
            let data = &fx.datasets[t];
            let id = client
                .submit_opts(data, &fx.cfg, None, Some(TENANTS[t]), None, Some(&token))
                .unwrap();
            ids.push((t, id));
        }
    }
    for (t, id) in ids {
        let f = client.result(&fx.ctx, id).unwrap();
        assert_eq!(coeff_polys(&fx.ctx, &f.betas), fx.solo[t], "fault-free bits must match solo");
    }
    assert_eq!(
        faults::checked_total(),
        checked_before,
        "disabled probes must not even count — the no-op contract"
    );
    assert_eq!(faults::injected_total(), injected_before);
    server.stop();
    engine.shutdown();
}

/// Drop-and-rebuild restart under a mix spanning EVERY fault site —
/// the PR-9 sites (wire_read, wire_write, lane, timer, cache, batcher)
/// plus both `journal` fault kinds. A journal-backed coordinator is
/// crashed mid-saturation-burst (torn tail and all) and rebuilt from
/// its journal dir on a FRESH engine: every job that was accepted
/// (journaled before its id was returned) must be recovered and must
/// terminate — a bit-identical fit, or the structured failure the
/// journal recorded — with idempotency tokens re-attaching across the
/// restart and no job executing twice.
#[test]
fn chaos_restart_mid_burst_recovers_every_accepted_job() {
    let fx = fixture();
    let dir = tmpdir("restart");
    let specs = [
        FaultSpec { site: FaultSite::WireRead, kind: FaultKind::Disconnect, rate: 0.05, seed: 51 },
        FaultSpec {
            site: FaultSite::WireWrite,
            kind: FaultKind::PartialWrite,
            rate: 0.05,
            seed: 52,
        },
        FaultSpec { site: FaultSite::Lane, kind: FaultKind::Panic, rate: 0.1, seed: 53 },
        FaultSpec { site: FaultSite::Timer, kind: FaultKind::Late, rate: 0.2, seed: 54 },
        FaultSpec { site: FaultSite::Cache, kind: FaultKind::Evict, rate: 0.3, seed: 55 },
        FaultSpec { site: FaultSite::Batcher, kind: FaultKind::Fail, rate: 0.05, seed: 56 },
        FaultSpec { site: FaultSite::Journal, kind: FaultKind::IoError, rate: 0.2, seed: 57 },
        FaultSpec { site: FaultSite::Journal, kind: FaultKind::TornWrite, rate: 0.1, seed: 58 },
    ];
    let cfg = CoordinatorConfig {
        lanes: 1, // single lane keeps a backlog queued at crash time
        queue_capacity: 32,
        cache_budget_bytes: 4 << 20,
        cache_shards: 2,
        checkpoint_every: 1,
    };
    let native_a = Arc::new(NativeEngine::new(fx.ctx.clone(), Arc::new(fx.keys.rk.clone())));
    let engine_a = BatchingEngine::new(native_a, BatchConfig::default());
    let coord_a = Coordinator::recover(engine_a.clone(), cfg, &dir).unwrap();
    let mut server_a = Server::start(coord_a.clone(), "127.0.0.1:0").unwrap();
    let addr_a = server_a.addr.to_string();

    let journal_fires_before = faults::injected_at(FaultSite::Journal);
    let written_before = journal::records_written();
    let session = FaultSession::activate(&specs);
    // Mini saturation burst: one retrying client per tenant. A submit
    // whose journal append faults bounces retryable `Overloaded`
    // (WAL-first: unjournaled means unaccepted) and is retried; a
    // client that exhausts its budget simply never got that job in.
    type Accepted = (String, usize, JobId);
    let accepted: Vec<Accepted> = std::thread::scope(|s| {
        let handles: Vec<_> = (0..TENANTS.len())
            .map(|t| {
                let (addr, fx) = (&addr_a, fx);
                s.spawn(move || {
                    let mut rc =
                        RetryingClient::new(addr, RetryPolicy::new(6, 1, 8, 9000 + t as u64));
                    let mut got = Vec::new();
                    for j in 0..4 {
                        let token = format!("restart-t{t}-j{j}");
                        if let Ok(id) = rc.submit(
                            &fx.datasets[t],
                            &fx.cfg,
                            None,
                            Some(TENANTS[t]),
                            None,
                            &token,
                        ) {
                            got.push((token, t, id));
                        }
                    }
                    got
                })
            })
            .collect();
        handles.into_iter().flat_map(|h| h.join().unwrap()).collect()
    });
    assert!(!accepted.is_empty(), "the burst must land at least one job");
    // Crash once the journal holds at least one `done` record, so
    // recovery exercises both the restore and the requeue paths.
    eventually(
        || accepted.iter().any(|(_, _, id)| coord_a.state(*id).as_deref() == Some("done")),
        "a first job to finish before the crash",
    );
    drop(session); // disarm: the crash and the rebuild run fault-free
    assert!(
        faults::injected_at(FaultSite::Journal) > journal_fires_before,
        "journal faults never fired — the scenario tested nothing new"
    );
    assert!(journal::records_written() > written_before, "the burst must journal records");
    coord_a.crash(); // admission off, tail torn, queued work dropped
    server_a.stop();
    // Lanes cannot be preempted: let the in-flight fit finish (its
    // journal appends are suppressed) before tearing the engine down.
    eventually(|| coord_a.running_jobs() == 0, "the crashed coordinator's lane to quiesce");
    engine_a.shutdown();

    // Rebuild from the journal directory on a fresh engine.
    let native_b = Arc::new(NativeEngine::new(fx.ctx.clone(), Arc::new(fx.keys.rk.clone())));
    let engine_b = BatchingEngine::new(native_b, BatchConfig::default());
    let coord_b = Coordinator::recover(engine_b.clone(), cfg, &dir).unwrap();
    let recovered = coord_b.recovered();
    // `accepted` may undercount (a reply lost to a wire fault after the
    // retry budget still journaled the job) — but never overcount.
    assert!(
        recovered.total() as usize >= accepted.len(),
        "recovered {recovered:?} lost accepted jobs ({} expected)",
        accepted.len()
    );
    let mut server_b = Server::start(coord_b.clone(), "127.0.0.1:0").unwrap();
    let addr_b = server_b.addr.to_string();
    let mut client = Client::connect(&addr_b).unwrap();
    // Idempotency tokens survive the restart: resubmission re-attaches
    // to the recovered job instead of running a second fit.
    for (token, t, id) in &accepted {
        let rid = client
            .submit_opts(&fx.datasets[*t], &fx.cfg, None, Some(TENANTS[*t]), None, Some(token))
            .unwrap();
        assert_eq!(rid, *id, "token {token} must re-attach across the restart");
    }
    // Every known-accepted job terminates: a fit bit-identical to the
    // solo reference, or the lane-panic failure phase 1 journaled.
    let mut completed = 0usize;
    for (token, t, id) in &accepted {
        match client.result(&fx.ctx, *id) {
            Ok(f) => {
                completed += 1;
                assert_eq!(
                    coeff_polys(&fx.ctx, &f.betas),
                    fx.solo[*t],
                    "recovered fit for {token} diverged from solo ciphertexts"
                );
            }
            Err(e) => assert_eq!(e.code, ErrorCode::JobFailed, "unexpected code for {token}"),
        }
        let _ = client.ack(*id);
    }
    assert!(completed >= 1, "recovery must complete at least one job");
    // Drain to zero — including recovered jobs whose submit reply was
    // lost (ids are dense 1..=total, so ack them all).
    let all_ids: Vec<JobId> = (1..=recovered.total()).map(JobId).collect();
    eventually(
        || {
            for &id in &all_ids {
                let _ = client.ack(id);
            }
            let h = client.health().unwrap();
            health_u64(&h, "queue_depth") == 0
                && health_u64(&h, "running") == 0
                && health_u64(&h, "tracked_jobs") == 0
                && health_u64(&h, "timers_live") == 0
        },
        "the rebuilt coordinator to drain",
    );
    let h = client.health().unwrap();
    assert_eq!(h.get("journal").and_then(Json::as_bool), Some(true));
    assert_eq!(health_u64(&h, "recovered"), recovered.total());
    server_b.stop();
    engine_b.shutdown();
    let _ = std::fs::remove_dir_all(&dir);
}

/// Wire-level zero-work restore: jobs that finished but were never
/// acked are re-served from the journal after a crash — on a fresh
/// engine whose ct-mul counter proves no fit re-executed.
#[test]
fn chaos_restart_serves_unacked_results_with_zero_engine_work() {
    let _quiet = faults::exclusion();
    let fx = fixture();
    let dir = tmpdir("restart-zero");
    let cfg = CoordinatorConfig {
        lanes: 2,
        queue_capacity: 8,
        cache_budget_bytes: 4 << 20,
        cache_shards: 2,
        checkpoint_every: 1,
    };
    let native_a = Arc::new(NativeEngine::new(fx.ctx.clone(), Arc::new(fx.keys.rk.clone())));
    let engine_a = BatchingEngine::new(native_a, BatchConfig::default());
    let coord_a = Coordinator::recover(engine_a.clone(), cfg, &dir).unwrap();
    let mut server_a = Server::start(coord_a.clone(), "127.0.0.1:0").unwrap();
    let mut client_a = Client::connect(&server_a.addr.to_string()).unwrap();
    let ids: Vec<(usize, JobId)> = (0..TENANTS.len())
        .map(|t| {
            let token = format!("zero-{t}");
            let id = client_a
                .submit_opts(&fx.datasets[t], &fx.cfg, None, Some(TENANTS[t]), None, Some(&token))
                .unwrap();
            (t, id)
        })
        .collect();
    // Wait for completion by status only — fetching a result would ack
    // and release it; these must still be tracked at crash time.
    for &(_, id) in &ids {
        eventually(
            || client_a.status(id).unwrap() == "done",
            "phase-1 jobs to finish before the crash",
        );
    }
    coord_a.crash();
    server_a.stop();
    engine_a.shutdown();

    let native_b = Arc::new(NativeEngine::new(fx.ctx.clone(), Arc::new(fx.keys.rk.clone())));
    let engine_b = BatchingEngine::new(native_b.clone(), BatchConfig::default());
    let coord_b = Coordinator::recover(engine_b.clone(), cfg, &dir).unwrap();
    assert_eq!(coord_b.recovered().restored as usize, ids.len());
    assert_eq!(coord_b.recovered().requeued, 0);
    let mut server_b = Server::start(coord_b, "127.0.0.1:0").unwrap();
    let mut client_b = Client::connect(&server_b.addr.to_string()).unwrap();
    for &(t, id) in &ids {
        // Token resubmission first: it must dedup to the restored job.
        let rid = client_b
            .submit_opts(
                &fx.datasets[t],
                &fx.cfg,
                None,
                Some(TENANTS[t]),
                None,
                Some(&format!("zero-{t}")),
            )
            .unwrap();
        assert_eq!(rid, id, "restored token must dedup across restart");
        let f = client_b.result(&fx.ctx, id).unwrap(); // auto-acks
        assert_eq!(coeff_polys(&fx.ctx, &f.betas), fx.solo[t]);
    }
    assert_eq!(
        native_b.stats().snapshot().0,
        0,
        "re-serving journaled results must cost zero ct-muls"
    );
    let h = client_b.health().unwrap();
    assert_eq!(health_u64(&h, "tracked_jobs"), 0, "served-and-acked jobs must not leak");
    server_b.stop();
    engine_b.shutdown();
    let _ = std::fs::remove_dir_all(&dir);
}

/// CI smoke: when `ELS_JOURNAL_OUT` names a directory, run a short
/// journal-backed burst against it and leave `journal.wal` behind for
/// `python/tools/journal_check.py` to audit (frame checksums + record
/// schema). A no-op without the env var, so plain `cargo test` stays
/// hermetic.
#[test]
fn journal_smoke_writes_wal_for_ci() {
    let Ok(dir) = std::env::var("ELS_JOURNAL_OUT") else {
        eprintln!("journal_smoke: ELS_JOURNAL_OUT unset; skipping");
        return;
    };
    let _quiet = faults::exclusion();
    let fx = fixture();
    let cfg = CoordinatorConfig {
        lanes: 2,
        queue_capacity: 8,
        cache_budget_bytes: 4 << 20,
        cache_shards: 2,
        checkpoint_every: 1,
    };
    let native = Arc::new(NativeEngine::new(fx.ctx.clone(), Arc::new(fx.keys.rk.clone())));
    let engine = BatchingEngine::new(native, BatchConfig::default());
    let coord = Coordinator::recover(engine.clone(), cfg, &dir).unwrap();
    let mut server = Server::start(coord.clone(), "127.0.0.1:0").unwrap();
    let mut client = Client::connect(&server.addr.to_string()).unwrap();
    for t in 0..TENANTS.len() {
        let id = client
            .submit_opts(
                &fx.datasets[t],
                &fx.cfg,
                None,
                Some(TENANTS[t]),
                None,
                Some(&format!("wal-{t}")),
            )
            .unwrap();
        let f = client.result(&fx.ctx, id).unwrap();
        assert_eq!(coeff_polys(&fx.ctx, &f.betas), fx.solo[t]);
    }
    let _ = coord.shutdown(Duration::from_secs(10)); // final journal sync
    server.stop();
    engine.shutdown();
    eprintln!("journal_smoke: wrote {dir}/journal.wal");
}

/// CI smoke: when `ELS_CHAOS_OUT` is set, run a compact wire-fault
/// burst and write an `els-chaos-v1` snapshot for
/// `python/tools/chaos_check.py`. `ELS_FAULTS` (if set) supplies the
/// mix; otherwise a default wire mix applies. A no-op without the env
/// var, so plain `cargo test` stays hermetic.
#[test]
fn chaos_smoke_writes_snapshot_for_ci() {
    let Ok(out_path) = std::env::var("ELS_CHAOS_OUT") else {
        eprintln!("chaos_smoke: ELS_CHAOS_OUT unset; skipping");
        return;
    };
    let specs = match std::env::var("ELS_FAULTS") {
        Ok(s) if !s.is_empty() => faults::parse_spec(&s).expect("ELS_FAULTS"),
        _ => vec![
            FaultSpec {
                site: FaultSite::WireWrite,
                kind: FaultKind::Disconnect,
                rate: 0.1,
                seed: 41,
            },
            FaultSpec { site: FaultSite::Lane, kind: FaultKind::Panic, rate: 0.1, seed: 43 },
        ],
    };
    let checked_before = faults::checked_total();
    let injected_before = faults::injected_total();
    let (completed, failed, retries) = run_scenario(
        "smoke",
        &specs,
        &[
            ErrorCode::Transport,
            ErrorCode::Overloaded,
            ErrorCode::JobFailed,
            ErrorCode::DeadlineExceeded,
        ],
        None,
    );
    let per_site = Json::obj(
        els::util::faults::ALL_SITES
            .iter()
            .map(|&s| (s.as_str(), Json::Num(faults::injected_at(s) as f64)))
            .collect::<Vec<_>>(),
    );
    let doc = Json::obj(vec![
        ("schema", Json::str("els-chaos-v1")),
        (
            "jobs",
            Json::obj(vec![
                ("total", Json::Num((CLIENTS * PER_CLIENT) as f64)),
                ("completed", Json::Num(completed as f64)),
                ("failed", Json::Num(failed as f64)),
                ("leaked", Json::Num(0.0)), // run_scenario asserts the drain
            ]),
        ),
        (
            "faults",
            Json::obj(vec![
                (
                    "checked",
                    Json::Num((faults::checked_total() - checked_before) as f64),
                ),
                (
                    "injected",
                    Json::Num((faults::injected_total() - injected_before) as f64),
                ),
                ("per_site", per_site),
            ]),
        ),
        ("retries", Json::Num(retries as f64)),
    ]);
    std::fs::write(&out_path, doc.to_string_json()).expect("writing ELS_CHAOS_OUT");
    eprintln!("chaos_smoke: wrote {out_path}");
}
