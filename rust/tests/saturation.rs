//! Serving-tier saturation: a burst of 120 fits from 12 concurrent
//! clients across 3 tenants against a deliberately small queue. Every
//! submission must either complete — bit-identical to a solo fit of the
//! same ciphertexts on a private engine — or bounce with a structured
//! wire code. Nothing hangs, nothing is silently dropped, and deadline
//! rejections happen before any engine work.

use std::sync::Arc;

use els::coordinator::batcher::{BatchConfig, BatchingEngine};
use els::coordinator::protocol::ErrorCode;
use els::coordinator::scheduler::{Coordinator, CoordinatorConfig};
use els::coordinator::service::{Client, Server};
use els::data::synth;
use els::els::encrypted::{fit, DatasetRef, FitConfig};
use els::els::exact::QuantisedData;
use els::els::model::encrypt_dataset;
use els::els::stepsize::nu_optimal;
use els::fhe::keys::keygen;
use els::fhe::params::{plan, PlanRequest};
use els::fhe::rng::ChaChaRng;
use els::fhe::{Ciphertext, FvContext};
use els::math::poly::RnsPoly;
use els::runtime::backend::{HeEngine, NativeEngine};
use els::util::json::Json;

const CLIENTS: usize = 12;
const PER_CLIENT: usize = 10;
const TENANTS: [&str; 3] = ["acme", "globex", "initech"];

/// Residency-normalised ciphertext bits (NTT-resident and coefficient
/// forms are exact representations of the same ciphertext).
fn coeff_polys(ctx: &FvContext, betas: &[Ciphertext]) -> Vec<Vec<RnsPoly>> {
    betas
        .iter()
        .map(|ct| ct.polys.iter().map(|p| ctx.ring_q.coeff_form(p).into_owned()).collect())
        .collect()
}

#[test]
fn saturation_every_job_completes_or_rejects_structurally() {
    let mut rng = ChaChaRng::from_seed(901);
    let (x, y) = synth::gaussian_regression(&mut rng, 6, 2, 0.2);
    let q = QuantisedData::from_f64(&x, &y, 2);
    let (xq, _) = q.dequantised();
    let nu = nu_optimal(&xq);
    let params = plan(&PlanRequest::gd(6, 2, 1, 2, nu)).unwrap();
    let ctx = FvContext::new(params);
    let keys = keygen(&ctx, &mut rng);
    let cfg = FitConfig::gd(1, nu);

    // One encrypted dataset per tenant, submitted repeatedly: encrypted
    // GD is deterministic, so every accepted copy of a tenant's job
    // must produce the *same ciphertext bits* as fitting that dataset
    // alone on a private engine — coalescing and caching included.
    let datasets: Vec<_> =
        (0..TENANTS.len()).map(|_| encrypt_dataset(&ctx, &keys.pk, &q, &mut rng)).collect();
    let solo: Vec<_> = datasets
        .iter()
        .map(|d| {
            let engine = NativeEngine::new(ctx.clone(), Arc::new(keys.rk.clone()));
            let f = fit(&engine, &DatasetRef::Scalar(d), &cfg).unwrap().fit;
            coeff_polys(&ctx, &f.betas)
        })
        .collect();

    // Server: 2 lanes over a shared batching engine, queue capacity far
    // below the burst so overload rejections must occur.
    let native = Arc::new(NativeEngine::new(ctx.clone(), Arc::new(keys.rk.clone())));
    let engine = BatchingEngine::new(native.clone(), BatchConfig::default());
    let coord = Coordinator::with_config(
        engine.clone(),
        CoordinatorConfig {
            lanes: 2,
            queue_capacity: 8,
            cache_budget_bytes: 4 << 20,
            cache_shards: 2,
            checkpoint_every: 1,
        },
    );
    let mut server = Server::start(coord, "127.0.0.1:0").unwrap();
    let addr = server.addr.to_string();

    // 12 clients × 10 rapid submissions each; results fetched after the
    // burst so the queue really saturates. Outcome per submission:
    // Ok(tenant, betas) or Err(tenant, code).
    let outcomes: Vec<Result<(usize, Vec<Vec<RnsPoly>>), (usize, ErrorCode)>> =
        std::thread::scope(|s| {
            let handles: Vec<_> = (0..CLIENTS)
                .map(|c| {
                    let (addr, ctx, datasets, cfg) = (&addr, &ctx, &datasets, &cfg);
                    s.spawn(move || {
                        let t = c % TENANTS.len();
                        let mut client = Client::connect(addr).expect("connect");
                        let mut ids = Vec::new();
                        let mut out = Vec::new();
                        for _ in 0..PER_CLIENT {
                            let tenant = Some(TENANTS[t]);
                            match client.submit_with(&datasets[t], cfg, None, tenant, None) {
                                Ok(id) => ids.push(id),
                                Err(e) => out.push(Err((t, e.code))),
                            }
                        }
                        for id in ids {
                            match client.result(ctx, id) {
                                Ok(f) => out.push(Ok((t, coeff_polys(ctx, &f.betas)))),
                                Err(e) => out.push(Err((t, e.code))),
                            }
                        }
                        out
                    })
                })
                .collect();
            handles.into_iter().flat_map(|h| h.join().unwrap()).collect()
        });

    assert_eq!(outcomes.len(), CLIENTS * PER_CLIENT);
    let mut completed = 0usize;
    let mut rejected = 0usize;
    for o in &outcomes {
        match o {
            Ok((t, betas)) => {
                completed += 1;
                assert_eq!(betas, &solo[*t], "coalesced fit diverged from solo ciphertexts");
            }
            Err((_, code)) => {
                rejected += 1;
                assert_eq!(*code, ErrorCode::Overloaded, "unexpected rejection code {code}");
            }
        }
    }
    assert_eq!(completed + rejected, CLIENTS * PER_CLIENT);
    assert!(completed >= TENANTS.len(), "burst should complete at least one job per tenant");
    assert!(rejected >= 1, "capacity-8 queue never reported overload under a 120-job burst");

    // Deadline admission: with latency history in place and the queue
    // idle, a 0 ms deadline is provably infeasible — rejected at submit
    // with a structured code, before a single engine operation runs.
    let muls_before = native.stats().snapshot().0;
    let mut client = Client::connect(&addr).expect("connect");
    let err = client
        .submit_with(&datasets[0], &cfg, None, Some(TENANTS[0]), Some(0))
        .expect_err("0ms deadline must be rejected once the estimator is calibrated");
    assert_eq!(err.code, ErrorCode::DeadlineExceeded, "{err}");
    assert_eq!(native.stats().snapshot().0, muls_before, "rejection must precede engine work");

    // Telemetry round-trip: histogram, per-tenant counters and the
    // unified snapshot all arrive well-formed over the wire.
    let full = client.metrics_full().expect("metrics");
    let hist = full.get("histogram").expect("histogram section");
    let count = hist.get("count").and_then(Json::as_u64).expect("histogram count");
    assert_eq!(count as usize, completed, "histogram observed every completion");
    assert!(hist.get("bounds_ms").is_some() && hist.get("counts").is_some());
    let Some(Json::Arr(tenants)) = full.get("tenants") else {
        panic!("tenants section missing or not an array")
    };
    assert_eq!(tenants.len(), TENANTS.len());
    for t in tenants {
        let name = t.get("tenant").and_then(|j| j.as_str()).expect("tenant name");
        assert!(TENANTS.contains(&name), "unknown tenant {name}");
        assert!(t.get("jobs_submitted").and_then(Json::as_u64).unwrap() > 0);
    }
    let coord_counters =
        full.get("snapshot").and_then(|s| s.get("coordinator")).expect("coordinator counters");
    let overloaded =
        coord_counters.get("jobs_overloaded").and_then(Json::as_u64).expect("jobs_overloaded");
    assert_eq!(overloaded as usize, rejected);
    let expired =
        coord_counters.get("jobs_expired").and_then(Json::as_u64).expect("jobs_expired");
    assert!(expired >= 1, "the 0ms-deadline rejection must be counted");

    server.stop();
    engine.shutdown();
}
