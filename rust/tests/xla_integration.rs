//! Integration: the XLA/PJRT backend must agree bit-for-bit with the
//! native Rust backend, and an end-to-end encrypted GD fit through XLA
//! must equal the exact integer simulation.
//!
//! Requires the `xla` cargo feature *and* `make artifacts`; every test
//! prints an explicit `SKIPPED` marker and passes otherwise, so tier-1
//! stays deterministic on machines without the JAX/Pallas toolchain.

use std::path::{Path, PathBuf};
use std::sync::Arc;

use els::data::synth;
use els::els::encrypted::{decrypt_coefficients, fit, DatasetRef, FitConfig};
use els::els::exact::{self, QuantisedData};
use els::els::float_ref::linf;
use els::els::model::encrypt_dataset;
use els::els::stepsize::nu_optimal;
use els::fhe::keys::keygen;
use els::fhe::params::{FvParams, MulBackend};
use els::fhe::rng::ChaChaRng;
use els::fhe::FvContext;
use els::runtime::backend::{HeEngine, NativeEngine};
use els::runtime::pjrt::XlaEngine;

/// Locate usable AOT artifacts, or explain exactly why the test is
/// skipped. Returning `None` makes the caller pass vacuously — with a
/// marker on stderr, never a failure — so tier-1 is deterministic on
/// machines without the JAX/Pallas toolchain.
fn artifact_dir() -> Option<PathBuf> {
    if !cfg!(feature = "xla") {
        eprintln!(
            "SKIPPED: built without the `xla` feature (PJRT runtime is a stub); \
             running these tests requires vendoring the `xla` PJRT bindings as a \
             dependency and rebuilding with `--features xla`"
        );
        return None;
    }
    let dir = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if dir.join("rns_meta.json").exists() {
        Some(dir)
    } else {
        eprintln!(
            "SKIPPED: no AOT artifacts at {} (run `make artifacts` with the \
             JAX/Pallas toolchain first)",
            dir.display()
        );
        None
    }
}

#[test]
fn xla_polymul_matches_native_ntt() {
    let Some(dir) = artifact_dir() else { return };
    let ctx = FvContext::new(FvParams::custom(256, 3, 24));
    let mut rng = ChaChaRng::from_seed(401);
    let keys = keygen(&ctx, &mut rng);
    let engine = XlaEngine::new(ctx.clone(), &keys.rk, &dir).unwrap();
    // Random polynomial batch in the Q ring (3 limbs — artifact exists).
    let polys: Vec<_> = (0..11)
        .map(|_| {
            (
                ctx.ring_q.sample_uniform(&mut rng),
                ctx.ring_q.sample_uniform(&mut rng),
            )
        })
        .collect();
    let jobs: Vec<_> = polys.iter().map(|(a, b)| (a, b)).collect();
    let got = engine.polymul_batch(&ctx.ring_q, &jobs).unwrap();
    for (i, (a, b)) in polys.iter().enumerate() {
        let expect = ctx.ring_q.polymul(a, b);
        assert_eq!(got[i], expect, "job {i} diverges from native NTT");
    }
}

#[test]
fn xla_mul_pairs_matches_native_engine() {
    let Some(dir) = artifact_dir() else { return };
    let ctx = FvContext::new(FvParams::custom(256, 3, 24));
    let mut rng = ChaChaRng::from_seed(402);
    let keys = keygen(&ctx, &mut rng);
    let rk = Arc::new(keys.rk.clone());
    // The XLA pipeline is the exact-bigint tensor basis; run the native
    // engine on the same backend so the arithmetic is truly identical.
    let native = NativeEngine::with_backend(ctx.clone(), rk.clone(), MulBackend::ExactBigint);
    let xla = XlaEngine::new(ctx.clone(), &keys.rk, &dir).unwrap();
    let values = [(3i64, -7i64), (123, 456), (-1000, 999), (0, 5), (-12, -34)];
    let cts: Vec<_> = values
        .iter()
        .map(|&(a, b)| {
            (
                ctx.encrypt(&els::fhe::encoding::encode_int(a, ctx.d()), &keys.pk, &mut rng),
                ctx.encrypt(&els::fhe::encoding::encode_int(b, ctx.d()), &keys.pk, &mut rng),
            )
        })
        .collect();
    let pairs: Vec<_> = cts.iter().map(|(a, b)| (a, b)).collect();
    let out_n = native.mul_pairs(&pairs);
    let out_x = xla.mul_pairs(&pairs);
    for (i, &(a, b)) in values.iter().enumerate() {
        // The two backends perform identical arithmetic — ciphertexts
        // must be *equal*, not merely decrypt-equal. The native product
        // is NTT-resident and the XLA one coefficient-form, so
        // normalise residency before comparing (exact in both domains).
        let n_coeff: Vec<_> = out_n[i]
            .polys
            .iter()
            .map(|p| ctx.ring_q.coeff_form(p).into_owned())
            .collect();
        assert_eq!(n_coeff, out_x[i].polys, "pair {i} ciphertext mismatch");
        let pt = ctx.decrypt(&out_x[i], &keys.sk);
        assert_eq!(pt.eval_at_2().to_i128(), Some((a as i128) * (b as i128)));
    }
}

#[test]
fn encrypted_gd_through_xla_equals_exact_sim() {
    let Some(dir) = artifact_dir() else { return };
    let mut rng = ChaChaRng::from_seed(403);
    let (x, y) = synth::gaussian_regression(&mut rng, 6, 2, 0.2);
    let q = QuantisedData::from_f64(&x, &y, 2);
    let (xq, _) = q.dequantised();
    let nu = nu_optimal(&xq);
    // Custom params matching an available artifact pair (d=256: l=3 Q,
    // l=7 tensor).
    let ctx = FvContext::new(FvParams::custom(256, 3, 26));
    let keys = keygen(&ctx, &mut rng);
    let engine = XlaEngine::new(ctx.clone(), &keys.rk, &dir).unwrap();
    let data = encrypt_dataset(&ctx, &keys.pk, &q, &mut rng);
    let f = fit(&engine, &DatasetRef::Scalar(&data), &FitConfig::gd(1, nu)).unwrap().fit;
    let dec = decrypt_coefficients(&ctx, &keys.sk, &f);
    let expect = exact::gd_exact(&q, nu, 1).decode_last();
    let d = linf(&dec, &expect);
    assert!(d < 1e-9, "XLA-backed encrypted GD drift: {d}");
    let (_, _, _, batches) = engine.stats().snapshot();
    assert!(batches >= 2, "expected batched XLA dispatches, got {batches}");
}
