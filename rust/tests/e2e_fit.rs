//! End-to-end encrypted fits across the full algorithm matrix
//! (GD / GD-VWT / NAG / CD, ridge augmentation, prediction), each
//! validated against the exact encoded-integer simulation and against
//! the f64 reference where applicable.

use std::sync::Arc;

use els::data::{mood, synth};
use els::els::encrypted::{decrypt_coefficients, fit, fit_cd, Accel, DatasetRef, FitConfig};
use els::els::exact::{self, QuantisedData};
use els::els::float_ref::{self, linf};
use els::els::model::{encrypt_dataset, encrypt_dataset_packed, quantise_ridge_augmented};
use els::els::predict;
use els::els::scaling::ratio_f64;
use els::els::stepsize::nu_optimal;
use els::fhe::keys::keygen;
use els::fhe::noise::noise_budget_bits;
use els::fhe::params::{
    plan, Algo, Encoding, FvParams, MulBackend, PlanRequest, SecurityProfile,
};
use els::fhe::rng::ChaChaRng;
use els::fhe::FvContext;
use els::runtime::backend::{HeEngine, NativeEngine};

struct World {
    ctx: Arc<FvContext>,
    keys: els::fhe::KeySet,
    engine: NativeEngine,
    q: QuantisedData,
    nu: u64,
    rng: ChaChaRng,
}

fn world(seed: u64, n: usize, p: usize, iters: usize, algo: Algo, extra_depth: u32) -> World {
    let mut rng = ChaChaRng::from_seed(seed);
    let (x, y) = synth::gaussian_regression(&mut rng, n, p, 0.2);
    let q = QuantisedData::from_f64(&x, &y, 2);
    let (xq, _) = q.dequantised();
    let nu = nu_optimal(&xq);
    let mut req = PlanRequest::gd(q.n(), q.p(), iters, 2, nu)
        .with_algo(algo)
        .with_extra_depth(extra_depth);
    if algo == Algo::Nag {
        req.eta_abs_q = els::els::scaling::NagScaling::new(2, nu, iters).eta_abs();
    }
    let ctx = FvContext::new(plan(&req).unwrap());
    let keys = keygen(&ctx, &mut rng);
    let engine = NativeEngine::new(ctx.clone(), Arc::new(keys.rk.clone()));
    World { ctx, keys, engine, q, nu, rng }
}

#[test]
fn ridge_augmented_encrypted_fit_matches_rls() {
    // §4.4: encrypted OLS on augmented data == ridge on original.
    let mut rng = ChaChaRng::from_seed(811);
    let (x, y) = synth::gaussian_regression(&mut rng, 8, 2, 0.3);
    let alpha = 4.0;
    let q = quantise_ridge_augmented(&x, &y, alpha, 2);
    assert_eq!(q.n(), 10); // N + P rows
    let (xq, yq) = q.dequantised();
    let nu = nu_optimal(&xq);
    let ctx = FvContext::new(plan(&PlanRequest::gd(q.n(), q.p(), 2, 2, nu)).unwrap());
    let keys = keygen(&ctx, &mut rng);
    let engine = NativeEngine::new(ctx.clone(), Arc::new(keys.rk.clone()));
    let data = encrypt_dataset(&ctx, &keys.pk, &q, &mut rng);
    let f = fit(&engine, &DatasetRef::Scalar(&data), &FitConfig::gd(2, nu)).unwrap().fit;
    let dec = decrypt_coefficients(&ctx, &keys.sk, &f);
    // Must equal the exact simulation on augmented data...
    let expect = exact::gd_exact(&q, nu, 2).decode_last();
    assert!(linf(&dec, &expect) < 1e-9);
    // ...and converge toward the RLS solution of the quantised data.
    let rls = float_ref::ols(&xq, &yq);
    let deep = exact::gd_exact(&q, nu, 80).decode_last();
    assert!(linf(&deep, &rls) < 1e-4, "augmentation drives GD to RLS");
}

#[test]
fn prediction_composes_with_vwt_fit() {
    let mut w = world(812, 8, 2, 3, Algo::GdVwt, 1);
    let data = encrypt_dataset(&w.ctx, &w.keys.pk, &w.q, &mut w.rng);
    let cfg = FitConfig::gd(3, w.nu).with_accel(Accel::Vwt);
    let f = fit(&w.engine, &DatasetRef::Scalar(&data), &cfg).unwrap().fit;
    let preds =
        predict::predict(&w.engine, &f, &predict::NewDataRef::Scalar(&data.x[..3])).preds;
    let dec = predict::decrypt_predictions(&w.ctx, &w.keys.sk, &f, &preds);
    // Expected: quantised X rows times the decoded VWT coefficients.
    let (acc, div) = exact::vwt_exact(&w.q, w.nu, 3);
    let betas: Vec<f64> = acc.iter().map(|b| ratio_f64(b, &div)).collect();
    let (xq, _) = w.q.dequantised();
    for i in 0..3 {
        let expect: f64 = xq[i].iter().zip(&betas).map(|(a, b)| a * b).sum();
        assert!((dec[i] - expect).abs() < 1e-9, "row {i}");
    }
}

#[test]
fn noise_budget_stays_positive_at_planned_depth() {
    let mut w = world(813, 6, 2, 3, Algo::Gd, 0);
    let data = encrypt_dataset(&w.ctx, &w.keys.pk, &w.q, &mut w.rng);
    let f = fit(&w.engine, &DatasetRef::Scalar(&data), &FitConfig::gd(3, w.nu)).unwrap().fit;
    for (j, ct) in f.betas.iter().enumerate() {
        let budget = noise_budget_bits(&w.ctx, ct, &w.keys.sk);
        assert!(budget > 0.0, "β_{j} budget {budget} ≤ 0 at planned depth");
    }
}

#[test]
fn cd_and_gd_agree_on_the_limit_but_differ_in_depth() {
    let mut w = world(814, 6, 2, 2, Algo::Cd, 0);
    let data = encrypt_dataset(&w.ctx, &w.keys.pk, &w.q, &mut w.rng);
    let fc = fit_cd(&w.engine, &data, w.nu, 2);
    let dec = decrypt_coefficients(&w.ctx, &w.keys.sk, &fc);
    let expect = exact::cd_exact(&w.q, w.nu, 2).decode_last();
    assert!(linf(&dec, &expect) < 1e-9);
    // Depth contrast (§4.1): 2 CD updates = depth 3; 2 GD iterations
    // would also be depth 3 but update *all* P coordinates each time.
    assert_eq!(fc.noise_depth, 3);
}

#[test]
fn mood_application_end_to_end() {
    // The paper's first application at its real size (N=28, P=2, K=2),
    // encrypted end to end with a per-patient fit.
    let mut rng = ChaChaRng::from_seed(815);
    let patient = &mood::cohort(&mut rng, 1)[0];
    let (x, y) = &patient.pre;
    let q = QuantisedData::from_f64(x, y, 2);
    let (xq, yq) = q.dequantised();
    let nu = nu_optimal(&xq);
    let params = plan(&PlanRequest::gd(28, 2, 2, 2, nu)).unwrap();
    assert_eq!(params.profile, SecurityProfile::Toy);
    let ctx = FvContext::new(params);
    let keys = keygen(&ctx, &mut rng);
    let engine = NativeEngine::new(ctx.clone(), Arc::new(keys.rk.clone()));
    let data = encrypt_dataset(&ctx, &keys.pk, &q, &mut rng);
    let f = fit(&engine, &DatasetRef::Scalar(&data), &FitConfig::gd(2, nu)).unwrap().fit;
    let dec = decrypt_coefficients(&ctx, &keys.sk, &f);
    // Paper Figure 6: convergence within 2 iterations (‖·‖∞ ≤ 0.04 of
    // the eventual limit); we check proximity to the OLS solution.
    let truth = float_ref::ols(&xq, &yq);
    let err = linf(&dec, &truth);
    assert!(err < 0.25, "2-iteration mood fit error vs OLS: {err}");
    // And exactness versus the simulation, as always.
    let expect = exact::gd_exact(&q, nu, 2).decode_last();
    assert!(linf(&dec, &expect) < 1e-9);
}

#[test]
fn gd_and_nag_fits_decrypt_identically_across_backends() {
    // The cross-backend parity oracle at full e2e scope: the same
    // encrypted dataset and keys, fitted once on the full-RNS pipeline
    // and once on the exact-bigint oracle, must decrypt to *identical*
    // plaintext coefficient polynomials (both also equal the exact
    // integer simulation, as the other tests in this file assert).
    for (seed, algo, accel) in [
        (821u64, Algo::Gd, Accel::None),
        (822, Algo::Nag, Accel::Nag),
    ] {
        let mut w = world(seed, 6, 2, 2, algo, 0);
        let data = encrypt_dataset(&w.ctx, &w.keys.pk, &w.q, &mut w.rng);
        let cfg = FitConfig::gd(2, w.nu).with_accel(accel);
        let rk = Arc::new(w.keys.rk.clone());
        let eng_rns =
            NativeEngine::with_backend(w.ctx.clone(), rk.clone(), MulBackend::FullRns);
        let eng_big =
            NativeEngine::with_backend(w.ctx.clone(), rk.clone(), MulBackend::ExactBigint);
        let fit_rns = fit(&eng_rns, &DatasetRef::Scalar(&data), &cfg).unwrap().fit;
        let fit_big = fit(&eng_big, &DatasetRef::Scalar(&data), &cfg).unwrap().fit;
        assert_eq!(fit_rns.betas.len(), fit_big.betas.len());
        for (j, (br, bb)) in fit_rns.betas.iter().zip(&fit_big.betas).enumerate() {
            let pr = w.ctx.decrypt(br, &w.keys.sk);
            let pb = w.ctx.decrypt(bb, &w.keys.sk);
            assert_eq!(pr, pb, "{algo:?}: β_{j} decrypts differ across backends");
        }
        let dec_rns = decrypt_coefficients(&w.ctx, &w.keys.sk, &fit_rns);
        let dec_big = decrypt_coefficients(&w.ctx, &w.keys.sk, &fit_big);
        assert_eq!(dec_rns, dec_big, "{algo:?}: decoded coefficients differ");
    }
}

#[test]
fn gd_fit_is_bit_identical_across_pool_worker_counts() {
    // The parallel mul_pairs fan-out (batch-level + intra-multiply
    // plane dispatch) must not change a single bit of the fit: the
    // same encrypted dataset fitted under worker budgets 1, 4 and 8
    // yields identical ciphertext polynomials, and the NTT-resident
    // coefficients decrypt to the exact simulation as always.
    let mut w = world(823, 6, 2, 2, Algo::Gd, 0);
    let data = encrypt_dataset(&w.ctx, &w.keys.pk, &w.q, &mut w.rng);
    let cfg = FitConfig::gd(2, w.nu);
    let rk = Arc::new(w.keys.rk.clone());
    let serial_engine = NativeEngine::new(w.ctx.clone(), rk.clone()).with_pool_workers(1);
    let fit_serial = fit(&serial_engine, &DatasetRef::Scalar(&data), &cfg).unwrap().fit;
    // The descent loop's steady state is NTT residency.
    assert!(fit_serial.betas.iter().all(|b| b.is_ntt_resident()));
    for workers in [4usize, 8] {
        let engine = NativeEngine::new(w.ctx.clone(), rk.clone()).with_pool_workers(workers);
        let f = fit(&engine, &DatasetRef::Scalar(&data), &cfg).unwrap().fit;
        for (j, (a, b)) in f.betas.iter().zip(&fit_serial.betas).enumerate() {
            assert_eq!(a.polys, b.polys, "β_{j} differs at {workers} workers");
        }
    }
    let dec = decrypt_coefficients(&w.ctx, &w.keys.sk, &fit_serial);
    let expect = exact::gd_exact(&w.q, w.nu, 2).decode_last();
    assert!(linf(&dec, &expect) < 1e-9);
}

#[test]
fn fused_dots_match_mul_pairs_fold_at_e2e_scale() {
    // The fused inner-product parity contract at integration scale:
    // dot_pairs over GD-shaped groups (one per row, one per column,
    // plus a ragged remainder) must decrypt identically to the
    // mul_pairs + add fold, on the active multiply backend (CI re-runs
    // this under ELS_MUL_BACKEND=bigint) and for worker counts 1/2/4 —
    // with the fused outputs bit-identical across worker budgets.
    let mut w = world(824, 6, 2, 2, Algo::Gd, 0);
    let data = encrypt_dataset(&w.ctx, &w.keys.pk, &w.q, &mut w.rng);
    let rk = Arc::new(w.keys.rk.clone());
    type Pair<'a> = (&'a els::fhe::Ciphertext, &'a els::fhe::Ciphertext);
    let mut owned: Vec<Vec<Pair>> = Vec::new();
    // Row-shaped groups: Σ_j X̃_ij·ỹ_i-style (use y as the second leg).
    for i in 0..w.q.n() {
        owned.push((0..w.q.p()).map(|j| (&data.x[i][j], &data.y[i])).collect());
    }
    // Column-shaped groups: Σ_i X̃_ij·ỹ_i.
    for j in 0..w.q.p() {
        owned.push((0..w.q.n()).map(|i| (&data.x[i][j], &data.y[i])).collect());
    }
    // Ragged remainder: a singleton.
    owned.push(vec![(&data.x[0][0], &data.y[1])]);
    let groups: Vec<&[Pair]> = owned.iter().map(|g| g.as_slice()).collect();
    let serial = NativeEngine::new(w.ctx.clone(), rk.clone()).with_pool_workers(1);
    // Reference fold through the same engine.
    let folds: Vec<els::fhe::Ciphertext> = groups
        .iter()
        .map(|g| {
            let prods = serial.mul_pairs(g);
            let mut acc = prods[0].clone();
            for p in &prods[1..] {
                acc = serial.add(&acc, p);
            }
            acc
        })
        .collect();
    let reference = serial.dot_pairs(&groups);
    for workers in [1usize, 2, 4] {
        let engine = NativeEngine::new(w.ctx.clone(), rk.clone()).with_pool_workers(workers);
        let out = engine.dot_pairs(&groups);
        assert_eq!(out.len(), groups.len());
        for (gi, got) in out.iter().enumerate() {
            assert_eq!(
                got.polys, reference[gi].polys,
                "group {gi}: fused bits differ at {workers} workers"
            );
            assert_eq!(
                w.ctx.decrypt(got, &w.keys.sk),
                w.ctx.decrypt(&folds[gi], &w.keys.sk),
                "group {gi}: fused vs fold decrypt at {workers} workers"
            );
        }
    }
}

#[test]
fn random_products_decrypt_equally_across_planner_depths() {
    // Property: random ct×ct product chains, driven to each planner
    // depth, decrypt identically under both backends. Plans for GD
    // K=1 and K=2 give noise budgets for depths 2 and 4; we chain
    // fresh multiplications to exactly those depths.
    for (seed, iters) in [(831u64, 1usize), (832, 2)] {
        let mut rng = ChaChaRng::from_seed(seed);
        let (x, y) = synth::gaussian_regression(&mut rng, 6, 2, 0.2);
        let q = QuantisedData::from_f64(&x, &y, 2);
        let (xq, _) = q.dequantised();
        let nu = nu_optimal(&xq);
        let params = plan(&PlanRequest::gd(6, 2, iters, 2, nu)).unwrap();
        let depth = 2 * iters; // the planner's ct-mult depth for GD
        let ctx_rns = FvContext::new(params).with_backend(MulBackend::FullRns);
        let ctx_big = ctx_rns.clone().with_backend(MulBackend::ExactBigint);
        let keys = keygen(&ctx_rns, &mut rng);
        for case in 0..3 {
            let enc = |v: i64, rng: &mut ChaChaRng| {
                ctx_rns.encrypt(
                    &els::fhe::encoding::encode_int(v, ctx_rns.d()),
                    &keys.pk,
                    rng,
                )
            };
            // Small factors keep the chained message (and its ℓ1, which
            // drives noise growth) inside the GD plan's per-level model.
            let mut vals: Vec<i64> = Vec::new();
            let mut cts = Vec::new();
            for _ in 0..=depth {
                let v = (rng.uniform_below(7) as i64) - 3;
                vals.push(v);
                cts.push(enc(v, &mut rng));
            }
            let mut acc_rns = cts[0].clone();
            let mut acc_big = cts[0].clone();
            let mut expect = vals[0] as i128;
            for k in 1..=depth {
                acc_rns = ctx_rns.mul_ct(&acc_rns, &cts[k], &keys.rk);
                acc_big = ctx_big.mul_ct(&acc_big, &cts[k], &keys.rk);
                expect *= vals[k] as i128;
                let dr = ctx_rns.decrypt(&acc_rns, &keys.sk);
                let db = ctx_big.decrypt(&acc_big, &keys.sk);
                assert_eq!(dr, db, "case {case}: backends diverge at depth {k}");
                assert_eq!(
                    dr.eval_at_2().to_i128(),
                    Some(expect),
                    "case {case}: wrong product at depth {k}"
                );
            }
        }
    }
}

#[test]
fn packed_fit_matches_unpacked_oracle_across_backends() {
    // The tentpole acceptance criterion at e2e scope: a packed GD fit
    // (one slot-wise multiply covers all n observations; the Σ_i folds
    // are O(log d) rotations) must decrypt to the same coefficients as
    // the per-value parity oracle (O(n) multiply pipelines), on both
    // multiply backends, and both must equal the exact simulation.
    let mut rng = ChaChaRng::from_seed(841);
    let (x, y) = synth::gaussian_regression(&mut rng, 4, 2, 0.2);
    let q = QuantisedData::from_f64(&x, &y, 1);
    let (xq, _) = q.dequantised();
    let nu = nu_optimal(&xq);
    let iters = 2usize;
    let sctx = FvContext::new(plan(&PlanRequest::gd(4, 2, iters, 1, nu)).unwrap());
    let skeys = keygen(&sctx, &mut rng);
    let pctx = FvContext::new(FvParams::custom_packed(256, 14, 44).unwrap());
    let pkeys = keygen(&pctx, &mut rng);
    let sdata = encrypt_dataset(&sctx, &skeys.pk, &q, &mut rng);
    let pdata = encrypt_dataset_packed(&pctx, &pkeys.pk, &q, &mut rng).unwrap();
    let expect = exact::gd_exact(&q, nu, iters).decode_last();
    let p = q.p() as u64;
    for backend in [MulBackend::FullRns, MulBackend::ExactBigint] {
        let oracle =
            NativeEngine::with_backend(sctx.clone(), Arc::new(skeys.rk.clone()), backend);
        let packed =
            NativeEngine::with_backend(pctx.clone(), Arc::new(pkeys.rk.clone()), backend)
                .with_galois_keys(Arc::new(pkeys.gk.clone()));
        let (rel0, rot0) = (pctx.ring_q.relin_count(), pctx.ring_q.rotation_count());
        let pf = fit(&packed, &DatasetRef::Packed(&pdata), &FitConfig::gd(iters, nu))
            .unwrap()
            .fit;
        // Multiply-pipeline budget, n-free: iteration 1 has no live β̃
        // (p gradient products), every later iteration adds the fused
        // residual group (p+1) — versus the oracle's n+p per iteration.
        let expect_relins = iters as u64 * p + (iters as u64 - 1);
        assert_eq!(pctx.ring_q.relin_count() - rel0, expect_relins, "{backend:?}");
        let log_rot = (pctx.d() / 2).trailing_zeros() as u64 + 1;
        assert_eq!(
            pctx.ring_q.rotation_count() - rot0,
            iters as u64 * p * log_rot,
            "{backend:?}: O(log d) rotations per gradient coordinate"
        );
        let sf = fit(&oracle, &DatasetRef::Scalar(&sdata), &FitConfig::gd(iters, nu))
            .unwrap()
            .fit;
        let dec_s = decrypt_coefficients(&sctx, &skeys.sk, &sf);
        let dec_p = decrypt_coefficients(&pctx, &pkeys.sk, &pf);
        assert!(linf(&dec_s, &expect) < 1e-9, "{backend:?}: oracle vs exact");
        assert!(linf(&dec_p, &expect) < 1e-9, "{backend:?}: packed vs exact");
        assert!(linf(&dec_p, &dec_s) < 1e-12, "{backend:?}: packed vs oracle");
    }
}

#[test]
fn fit_honours_els_encoding_env() {
    // CI runs a tier-1 leg under ELS_ENCODING=packed; this test routes
    // through Encoding::from_env() the way production entry points do,
    // so that leg actually exercises the packed pipeline end to end.
    let mut rng = ChaChaRng::from_seed(842);
    let (x, y) = synth::gaussian_regression(&mut rng, 4, 2, 0.2);
    let q = QuantisedData::from_f64(&x, &y, 1);
    let (xq, _) = q.dequantised();
    let nu = nu_optimal(&xq);
    let expect = exact::gd_exact(&q, nu, 2).decode_last();
    let dec = match Encoding::from_env() {
        Encoding::Scalar => {
            let ctx = FvContext::new(plan(&PlanRequest::gd(4, 2, 2, 1, nu)).unwrap());
            let keys = keygen(&ctx, &mut rng);
            let engine = NativeEngine::new(ctx.clone(), Arc::new(keys.rk.clone()));
            let data = encrypt_dataset(&ctx, &keys.pk, &q, &mut rng);
            let f = fit(&engine, &DatasetRef::Scalar(&data), &FitConfig::gd(2, nu))
                .unwrap()
                .fit;
            decrypt_coefficients(&ctx, &keys.sk, &f)
        }
        Encoding::Packed => {
            let ctx = FvContext::new(FvParams::custom_packed(256, 14, 44).unwrap());
            assert_eq!(ctx.params.encoding, Encoding::Packed);
            let keys = keygen(&ctx, &mut rng);
            let engine = NativeEngine::new(ctx.clone(), Arc::new(keys.rk.clone()))
                .with_galois_keys(Arc::new(keys.gk.clone()));
            let data = encrypt_dataset_packed(&ctx, &keys.pk, &q, &mut rng).unwrap();
            let f = fit(&engine, &DatasetRef::Packed(&data), &FitConfig::gd(2, nu))
                .unwrap()
                .fit;
            decrypt_coefficients(&ctx, &keys.sk, &f)
        }
    };
    assert!(linf(&dec, &expect) < 1e-9);
}

#[test]
fn paper128_profile_parameters_are_secure_and_work() {
    // Full keygen + 1 encrypted GD iteration under the ≥128-bit LP11
    // profile (larger ring; this is the slowest test in the suite).
    let mut rng = ChaChaRng::from_seed(816);
    let (x, y) = synth::gaussian_regression(&mut rng, 4, 2, 0.2);
    let q = QuantisedData::from_f64(&x, &y, 2);
    let (xq, _) = q.dequantised();
    let nu = nu_optimal(&xq);
    let params = plan(
        &PlanRequest::gd(4, 2, 1, 2, nu).with_profile(SecurityProfile::Paper128),
    )
    .unwrap();
    assert!(params.security_bits() >= 128.0);
    let ctx = FvContext::new(params);
    let keys = keygen(&ctx, &mut rng);
    let engine = NativeEngine::new(ctx.clone(), Arc::new(keys.rk.clone()));
    let data = encrypt_dataset(&ctx, &keys.pk, &q, &mut rng);
    let f = fit(&engine, &DatasetRef::Scalar(&data), &FitConfig::gd(1, nu)).unwrap().fit;
    let dec = decrypt_coefficients(&ctx, &keys.sk, &f);
    let expect = exact::gd_exact(&q, nu, 1).decode_last();
    assert!(linf(&dec, &expect) < 1e-9);
}
