//! End-to-end service test: client encrypts locally, submits over TCP,
//! server fits on ciphertexts, client decrypts — and the result equals
//! the exact integer simulation.

use std::sync::Arc;
use std::time::Duration;

use els::coordinator::batcher::{BatchConfig, BatchingEngine};
use els::coordinator::scheduler::Coordinator;
use els::coordinator::service::{Client, Server};
use els::data::synth;
use els::els::encrypted::FitConfig;
use els::els::exact::{self, QuantisedData};
use els::els::float_ref::linf;
use els::els::model::encrypt_dataset;
use els::els::stepsize::nu_optimal;
use els::fhe::keys::keygen;
use els::fhe::params::{plan, PlanRequest};
use els::fhe::rng::ChaChaRng;
use els::fhe::FvContext;
use els::runtime::backend::NativeEngine;

#[test]
fn submit_fit_fetch_decrypt_roundtrip() {
    let mut rng = ChaChaRng::from_seed(801);
    let (x, y) = synth::gaussian_regression(&mut rng, 6, 2, 0.2);
    let q = QuantisedData::from_f64(&x, &y, 2);
    let (xq, _) = q.dequantised();
    let nu = nu_optimal(&xq);
    let params = plan(&PlanRequest::gd(6, 2, 2, 2, nu)).unwrap();
    let ctx = FvContext::new(params);
    let keys = keygen(&ctx, &mut rng);

    // Server side: engine + coordinator + TCP service (holds pk/rk only).
    let native = Arc::new(NativeEngine::new(ctx.clone(), Arc::new(keys.rk.clone())));
    let engine = BatchingEngine::new(native, BatchConfig::default());
    let coord = Coordinator::new(engine.clone(), 4);
    let mut server = Server::start(coord, "127.0.0.1:0").unwrap();
    let addr = server.addr.to_string();

    // Client side: encrypt locally, submit, poll, fetch, decrypt.
    let mut client = Client::connect(&addr).unwrap();
    client.ping().unwrap();
    let data = encrypt_dataset(&ctx, &keys.pk, &q, &mut rng);
    let id = client.submit(&data, &FitConfig::gd(2, nu), None).unwrap();
    // Status eventually progresses.
    let state = client.status(id).unwrap();
    assert!(["queued", "running", "done"].contains(&state.as_str()), "{state}");
    let fit = client.result(&ctx, id).unwrap();
    let dec = els::els::encrypted::decrypt_coefficients(&ctx, &keys.sk, &fit);
    let expect = exact::gd_exact(&q, nu, 2).decode_last();
    assert!(linf(&dec, &expect) < 1e-9, "{dec:?} vs {expect:?}");
    assert_eq!(fit.paper_mmd, 4);

    // Metrics answer.
    let m = client.metrics().unwrap();
    assert!(m.contains("completed=1"), "{m}");

    // Unknown job errors cleanly, with its structured code intact
    // across the wire.
    let err = client.status(els::coordinator::job::JobId(999)).unwrap_err();
    assert_eq!(err.code, els::coordinator::protocol::ErrorCode::UnknownJob, "{err}");

    server.stop();
    engine.shutdown();
    // Server is down: new connections must fail (may take a moment).
    std::thread::sleep(Duration::from_millis(50));
    assert!(
        Client::connect(&addr).and_then(|mut c| c.ping()).is_err(),
        "server should be stopped"
    );
}

#[test]
fn malformed_requests_get_error_responses() {
    use std::io::{BufRead, BufReader, Write};
    let ctx = FvContext::new(els::fhe::params::FvParams::custom(256, 2, 16));
    let mut rng = ChaChaRng::from_seed(802);
    let keys = keygen(&ctx, &mut rng);
    let native = Arc::new(NativeEngine::new(ctx.clone(), Arc::new(keys.rk)))
        as Arc<dyn els::runtime::backend::HeEngine>;
    let coord = Coordinator::new(native, 1);
    let mut server = Server::start(coord, "127.0.0.1:0").unwrap();
    let stream = std::net::TcpStream::connect(server.addr).unwrap();
    let mut reader = BufReader::new(stream.try_clone().unwrap());
    let mut w = stream;
    // Every rejection is versioned and carries a structured code:
    // unparseable JSON and schema violations are `bad_request`, while
    // a missing or wrong `"v"` bounces as `bad_version` before the
    // request is interpreted at all.
    for (bad, code) in [
        ("not json", "bad_request"),
        ("{\"v\":1,\"type\":\"bogus\"}", "bad_request"),
        ("{\"v\":1}", "bad_request"),
        ("{\"type\":\"ping\"}", "bad_version"),
        ("{\"v\":99,\"type\":\"ping\"}", "bad_version"),
    ] {
        w.write_all(bad.as_bytes()).unwrap();
        w.write_all(b"\n").unwrap();
        let mut line = String::new();
        reader.read_line(&mut line).unwrap();
        assert!(line.contains("\"ok\":false"), "{line}");
        assert!(line.contains("error"), "{line}");
        assert!(line.contains(&format!("\"code\":\"{code}\"")), "{bad}: {line}");
    }
    server.stop();
}
