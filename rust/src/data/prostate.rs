//! Synthetic prostate-cancer workload (substitute for Stamey et al.
//! 1989 — we ship no data files).
//!
//! The classic dataset has N = 97 patients, response `lpsa` and P = 8
//! covariates (lcavol, lweight, age, lbph, svi, lcp, gleason, pgg45)
//! with a well-known correlation structure (e.g. lcavol–lcp ≈ 0.68,
//! lcp–pgg45 ≈ 0.63). The generator draws a Gaussian design with that
//! published correlation matrix and a response from the published
//! OLS-fit-like coefficient profile, preserving what Figures 7–8
//! measure: convergence and ridge behaviour on an N = 97, P = 8,
//! moderately collinear design.

use crate::fhe::rng::ChaChaRng;

use super::standardise::standardise_xy;
use super::synth::correlated_design;

/// Covariate names, in order.
pub const COVARIATES: [&str; 8] =
    ["lcavol", "lweight", "age", "lbph", "svi", "lcp", "gleason", "pgg45"];

/// Published (rounded) correlation structure of the standardised
/// covariates — the collinearity pattern is what drives the paper's
/// convergence behaviour.
pub fn correlation_matrix() -> Vec<Vec<f64>> {
    let c: [[f64; 8]; 8] = [
        [1.00, 0.28, 0.22, 0.03, 0.54, 0.68, 0.43, 0.43],
        [0.28, 1.00, 0.35, 0.44, 0.16, 0.16, 0.06, 0.11],
        [0.22, 0.35, 1.00, 0.35, 0.12, 0.13, 0.27, 0.28],
        [0.03, 0.44, 0.35, 1.00, -0.09, -0.01, 0.08, 0.08],
        [0.54, 0.16, 0.12, -0.09, 1.00, 0.67, 0.32, 0.46],
        [0.68, 0.16, 0.13, -0.01, 0.67, 1.00, 0.51, 0.63],
        [0.43, 0.06, 0.27, 0.08, 0.32, 0.51, 1.00, 0.75],
        [0.43, 0.11, 0.28, 0.08, 0.46, 0.63, 0.75, 1.00],
    ];
    // Symmetrise-and-lift: add a small ridge to guarantee positive
    // definiteness of the rounded matrix.
    let mut m: Vec<Vec<f64>> = c.iter().map(|r| r.to_vec()).collect();
    for (i, row) in m.iter_mut().enumerate() {
        row[i] += 0.02;
    }
    m
}

/// Effect profile shaped like the published lpsa fit: lcavol dominates,
/// svi and lweight matter, lcp slightly negative.
pub const TRUE_BETA: [f64; 8] = [0.66, 0.27, -0.14, 0.21, 0.31, -0.29, 0.0, 0.27];

/// Generate the synthetic prostate problem: standardised X (N×8) and
/// centred y.
pub fn generate(rng: &mut ChaChaRng, n: usize) -> (Vec<Vec<f64>>, Vec<f64>) {
    let x = correlated_design(rng, n, &correlation_matrix());
    let y: Vec<f64> = x
        .iter()
        .map(|row| {
            row.iter().zip(&TRUE_BETA).map(|(a, b)| a * b).sum::<f64>()
                + 0.7 * rng.next_gaussian()
        })
        .collect();
    let s = standardise_xy(&x, &y);
    (s.x, s.y)
}

/// The paper's exact application size.
pub fn paper_size(rng: &mut ChaChaRng) -> (Vec<Vec<f64>>, Vec<f64>) {
    generate(rng, 97)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::els::float_ref::{gram_spectrum, ols};

    #[test]
    fn shape_and_conditioning() {
        let mut rng = ChaChaRng::from_seed(95);
        let (x, y) = paper_size(&mut rng);
        assert_eq!(x.len(), 97);
        assert_eq!(x[0].len(), 8);
        assert_eq!(y.len(), 97);
        let (lmin, lmax) = gram_spectrum(&x);
        let cond = lmax / lmin;
        // Collinear but invertible, like the real dataset.
        assert!(cond > 3.0 && cond < 1e4, "condition number {cond}");
    }

    #[test]
    fn dominant_effect_is_lcavol() {
        let mut rng = ChaChaRng::from_seed(96);
        let (x, y) = generate(&mut rng, 2000);
        let b = ols(&x, &y);
        let max_idx = (0..8).max_by(|&i, &j| b[i].abs().partial_cmp(&b[j].abs()).unwrap()).unwrap();
        assert_eq!(max_idx, 0, "lcavol dominates: {b:?}");
    }

    #[test]
    fn correlation_matrix_is_pd() {
        // Cholesky must succeed (panics otherwise).
        let _ = crate::els::float_ref::linalg::cholesky(&correlation_matrix());
    }
}
