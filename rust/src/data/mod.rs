//! Synthetic workload generators matching the paper's §6 experiments.
//!
//! The two application datasets (Bonsall et al. mood time-series;
//! Stamey et al. prostate data) are not shipped; `mood` and `prostate`
//! generate structurally matched synthetic equivalents (same N, P,
//! model class and correlation structure) — see DESIGN.md §6
//! Substitutions for the preservation argument.

pub mod mood;
pub mod prostate;
pub mod standardise;
pub mod synth;

pub use standardise::{standardise_xy, Standardised};
