//! Synthetic mood-stability workload (substitute for Bonsall et al.
//! 2012, which is not public).
//!
//! The paper models weekly self-reported mood scores of bipolar
//! patients as an AR(2) process, fit separately pre- and post-treatment
//! (N = 28 usable observations, P = 2). We generate AR(2) series with a
//! treatment-induced shift in the autoregressive coefficients
//! (pre: oscillatory/unstable mood; post: damped), which preserves what
//! the experiment actually studies — encrypted descent on an AR(2)
//! lagged design of the paper's size.

use crate::fhe::rng::ChaChaRng;

use super::standardise::standardise_xy;

/// One patient's series and its pre/post AR(2) regression problems.
#[derive(Clone, Debug)]
pub struct MoodPatient {
    pub id: usize,
    /// Pre-treatment design (lag-1, lag-2) and response.
    pub pre: (Vec<Vec<f64>>, Vec<f64>),
    /// Post-treatment design and response.
    pub post: (Vec<Vec<f64>>, Vec<f64>),
    /// True AR coefficients used by the generator.
    pub true_pre: [f64; 2],
    pub true_post: [f64; 2],
}

/// Simulate an AR(2) series of length `len` with coefficients `phi`.
fn ar2_series(rng: &mut ChaChaRng, phi: [f64; 2], len: usize, noise_sd: f64) -> Vec<f64> {
    let mut s = Vec::with_capacity(len + 20);
    s.push(rng.next_gaussian());
    s.push(rng.next_gaussian());
    for _ in 2..len + 20 {
        let t = s.len();
        let v = phi[0] * s[t - 1] + phi[1] * s[t - 2] + noise_sd * rng.next_gaussian();
        s.push(v);
    }
    s.split_off(20) // burn-in
}

/// Lagged AR(2) design: rows `(y_{t-1}, y_{t-2}) → y_t`. Standardised
/// and centred per §3.1.
pub fn ar2_design(series: &[f64]) -> (Vec<Vec<f64>>, Vec<f64>) {
    assert!(series.len() >= 3);
    let mut x = Vec::new();
    let mut y = Vec::new();
    for t in 2..series.len() {
        x.push(vec![series[t - 1], series[t - 2]]);
        y.push(series[t]);
    }
    let s = standardise_xy(&x, &y);
    (s.x, s.y)
}

/// Generate a cohort of synthetic patients. Each pre/post segment
/// yields N = 28 regression observations (30 raw points), P = 2 —
/// exactly the paper's application size.
pub fn cohort(rng: &mut ChaChaRng, n_patients: usize) -> Vec<MoodPatient> {
    (0..n_patients)
        .map(|id| {
            let mut r = rng.split(id as u64 + 1);
            // Pre-treatment: near-oscillatory dynamics (mood instability).
            let pre_phi = [
                0.2 + 0.2 * r.next_f64(),
                -0.75 + 0.2 * r.next_f64(),
            ];
            // Post-treatment: damped, stabilised dynamics.
            let post_phi = [0.45 + 0.2 * r.next_f64(), -0.15 + 0.15 * r.next_f64()];
            let pre_series = ar2_series(&mut r, pre_phi, 30, 1.0);
            let post_series = ar2_series(&mut r, post_phi, 30, 1.0);
            MoodPatient {
                id,
                pre: ar2_design(&pre_series),
                post: ar2_design(&post_series),
                true_pre: pre_phi,
                true_post: post_phi,
            }
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::els::float_ref::ols;

    #[test]
    fn design_shape_matches_paper() {
        let mut rng = ChaChaRng::from_seed(91);
        let patients = cohort(&mut rng, 3);
        assert_eq!(patients.len(), 3);
        for p in &patients {
            assert_eq!(p.pre.0.len(), 28, "N = 28 as in the paper");
            assert_eq!(p.pre.0[0].len(), 2, "P = 2 (AR(2))");
            assert_eq!(p.post.1.len(), 28);
        }
    }

    #[test]
    fn ols_recovers_ar_structure() {
        // With standardisation the sign/ordering of AR coefficients is
        // preserved even though their scale changes.
        let mut rng = ChaChaRng::from_seed(92);
        let phi = [0.5, -0.3];
        let series = ar2_series(&mut rng, phi, 3000, 1.0);
        let (x, y) = ar2_design(&series);
        let b = ols(&x, &y);
        assert!(b[0] > 0.2, "lag-1 effect positive: {}", b[0]);
        assert!(b[1] < -0.05, "lag-2 effect negative: {}", b[1]);
    }

    #[test]
    fn pre_post_differ() {
        let mut rng = ChaChaRng::from_seed(93);
        let p = &cohort(&mut rng, 1)[0];
        assert!(p.true_pre[1] < p.true_post[1], "treatment damps lag-2");
    }
}
