//! §3.1 preprocessing: covariates standardised (mean 0, sample sd 1),
//! response centred — performed by the data holder before encoding and
//! encryption.

/// Standardised data plus the statistics needed to map back.
#[derive(Clone, Debug)]
pub struct Standardised {
    pub x: Vec<Vec<f64>>,
    pub y: Vec<f64>,
    pub x_mean: Vec<f64>,
    pub x_sd: Vec<f64>,
    pub y_mean: f64,
}

/// Standardise columns of X and centre y.
pub fn standardise_xy(x: &[Vec<f64>], y: &[f64]) -> Standardised {
    let n = x.len();
    assert!(n > 1 && y.len() == n);
    let p = x[0].len();
    let mut x_mean = vec![0.0; p];
    for row in x {
        for j in 0..p {
            x_mean[j] += row[j];
        }
    }
    for m in x_mean.iter_mut() {
        *m /= n as f64;
    }
    let mut x_sd = vec![0.0; p];
    for row in x {
        for j in 0..p {
            x_sd[j] += (row[j] - x_mean[j]).powi(2);
        }
    }
    for s in x_sd.iter_mut() {
        *s = (*s / (n as f64 - 1.0)).sqrt();
        if *s == 0.0 {
            *s = 1.0; // constant column: leave centred
        }
    }
    let y_mean = y.iter().sum::<f64>() / n as f64;
    let xs: Vec<Vec<f64>> = x
        .iter()
        .map(|row| (0..p).map(|j| (row[j] - x_mean[j]) / x_sd[j]).collect())
        .collect();
    let ys: Vec<f64> = y.iter().map(|v| v - y_mean).collect();
    Standardised { x: xs, y: ys, x_mean, x_sd, y_mean }
}

/// Ridge data augmentation (§4.4, eq. 13): append `√α·I` rows to X and
/// zeros to y. OLS on the augmented data equals RLS on the original.
pub fn ridge_augment(x: &[Vec<f64>], y: &[f64], alpha: f64) -> (Vec<Vec<f64>>, Vec<f64>) {
    assert!(alpha >= 0.0);
    let p = x[0].len();
    let mut xa = x.to_vec();
    let mut ya = y.to_vec();
    let sa = alpha.sqrt();
    for j in 0..p {
        let mut row = vec![0.0; p];
        row[j] = sa;
        xa.push(row);
        ya.push(0.0);
    }
    (xa, ya)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::els::float_ref::{linf, ols, ridge};

    #[test]
    fn standardise_properties() {
        let x = vec![
            vec![1.0, 10.0],
            vec![2.0, 30.0],
            vec![3.0, 20.0],
            vec![4.0, 40.0],
        ];
        let y = vec![1.0, 2.0, 3.0, 4.0];
        let s = standardise_xy(&x, &y);
        for j in 0..2 {
            let mean: f64 = s.x.iter().map(|r| r[j]).sum::<f64>() / 4.0;
            let var: f64 =
                s.x.iter().map(|r| (r[j] - mean).powi(2)).sum::<f64>() / 3.0;
            assert!(mean.abs() < 1e-12);
            assert!((var - 1.0).abs() < 1e-12);
        }
        assert!(s.y.iter().sum::<f64>().abs() < 1e-12);
    }

    #[test]
    fn constant_column_does_not_blow_up() {
        let x = vec![vec![5.0, 1.0], vec![5.0, 2.0], vec![5.0, 3.0]];
        let s = standardise_xy(&x, &[1.0, 2.0, 3.0]);
        assert!(s.x.iter().all(|r| r[0] == 0.0));
    }

    #[test]
    fn augmentation_equals_ridge() {
        // Paper eq. 14: OLS(X̊, ẙ) == RLS(X, y; α).
        let x = vec![
            vec![1.0, 0.5],
            vec![-0.3, 1.2],
            vec![0.7, -0.8],
            vec![-1.5, 0.1],
            vec![0.4, 0.9],
        ];
        let y = vec![1.0, -0.5, 0.3, -1.2, 0.8];
        for alpha in [0.5, 5.0, 30.0] {
            let (xa, ya) = ridge_augment(&x, &y, alpha);
            assert_eq!(xa.len(), 7);
            let via_aug = ols(&xa, &ya);
            let direct = ridge(&x, &y, alpha);
            assert!(linf(&via_aug, &direct) < 1e-10, "α = {alpha}");
        }
    }
}
