//! §6.1 simulation designs: independent and equicorrelated Gaussian
//! regression problems.

use crate::els::float_ref::linalg::cholesky;
use crate::fhe::rng::ChaChaRng;

use super::standardise::standardise_xy;

/// Independent design: `β ~ N(0, I)`, `X ~ N(0, I)`,
/// `y ~ N(Xβ, σ²I)`. Returns standardised covariates and centred
/// response (as the paper assumes throughout, §3.1).
pub fn gaussian_regression(
    rng: &mut ChaChaRng,
    n: usize,
    p: usize,
    noise_sd: f64,
) -> (Vec<Vec<f64>>, Vec<f64>) {
    let beta: Vec<f64> = (0..p).map(|_| rng.next_gaussian()).collect();
    let x: Vec<Vec<f64>> = (0..n)
        .map(|_| (0..p).map(|_| rng.next_gaussian()).collect())
        .collect();
    let y: Vec<f64> = x
        .iter()
        .map(|row| {
            row.iter().zip(&beta).map(|(a, b)| a * b).sum::<f64>()
                + noise_sd * rng.next_gaussian()
        })
        .collect();
    let s = standardise_xy(&x, &y);
    (s.x, s.y)
}

/// Equicorrelated design (the paper's "Normal copula" with all pairwise
/// correlations equal to ρ): `X_i = √ρ·z·1 + √(1−ρ)·ε_i`.
pub fn correlated_regression(
    rng: &mut ChaChaRng,
    n: usize,
    p: usize,
    rho: f64,
    noise_sd: f64,
) -> (Vec<Vec<f64>>, Vec<f64>) {
    assert!((0.0..1.0).contains(&rho));
    let beta: Vec<f64> = (0..p).map(|_| rng.next_gaussian()).collect();
    let sr = rho.sqrt();
    let sc = (1.0 - rho).sqrt();
    let x: Vec<Vec<f64>> = (0..n)
        .map(|_| {
            let z = rng.next_gaussian();
            (0..p).map(|_| sr * z + sc * rng.next_gaussian()).collect()
        })
        .collect();
    let y: Vec<f64> = x
        .iter()
        .map(|row| {
            row.iter().zip(&beta).map(|(a, b)| a * b).sum::<f64>()
                + noise_sd * rng.next_gaussian()
        })
        .collect();
    let s = standardise_xy(&x, &y);
    (s.x, s.y)
}

/// Design with an arbitrary correlation matrix (via Cholesky), used by
/// the prostate-like generator.
pub fn correlated_design(
    rng: &mut ChaChaRng,
    n: usize,
    corr: &[Vec<f64>],
) -> Vec<Vec<f64>> {
    let p = corr.len();
    let l = cholesky(corr);
    (0..n)
        .map(|_| {
            let z: Vec<f64> = (0..p).map(|_| rng.next_gaussian()).collect();
            (0..p)
                .map(|i| (0..=i).map(|k| l[i][k] * z[k]).sum())
                .collect()
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample_corr(x: &[Vec<f64>], a: usize, b: usize) -> f64 {
        let n = x.len() as f64;
        let (ma, mb) = (
            x.iter().map(|r| r[a]).sum::<f64>() / n,
            x.iter().map(|r| r[b]).sum::<f64>() / n,
        );
        let (mut num, mut va, mut vb) = (0.0, 0.0, 0.0);
        for r in x {
            num += (r[a] - ma) * (r[b] - mb);
            va += (r[a] - ma).powi(2);
            vb += (r[b] - mb).powi(2);
        }
        num / (va * vb).sqrt()
    }

    #[test]
    fn standardised_output() {
        let mut rng = ChaChaRng::from_seed(81);
        let (x, y) = gaussian_regression(&mut rng, 200, 3, 1.0);
        for j in 0..3 {
            let mean = x.iter().map(|r| r[j]).sum::<f64>() / 200.0;
            assert!(mean.abs() < 1e-10, "column {j} centred");
        }
        assert!(y.iter().sum::<f64>().abs() < 1e-8, "response centred");
    }

    #[test]
    fn equicorrelation_close_to_rho() {
        let mut rng = ChaChaRng::from_seed(82);
        let (x, _) = correlated_regression(&mut rng, 4000, 4, 0.7, 0.1);
        for a in 0..4 {
            for b in a + 1..4 {
                let c = sample_corr(&x, a, b);
                assert!((c - 0.7).abs() < 0.06, "corr({a},{b}) = {c}");
            }
        }
    }

    #[test]
    fn cholesky_design_matches_target_corr() {
        let corr = vec![
            vec![1.0, 0.6, 0.2],
            vec![0.6, 1.0, 0.4],
            vec![0.2, 0.4, 1.0],
        ];
        let mut rng = ChaChaRng::from_seed(83);
        let x = correlated_design(&mut rng, 6000, &corr);
        assert!((sample_corr(&x, 0, 1) - 0.6).abs() < 0.05);
        assert!((sample_corr(&x, 1, 2) - 0.4).abs() < 0.05);
        assert!((sample_corr(&x, 0, 2) - 0.2).abs() < 0.05);
    }
}
