//! Rescaling bookkeeping for the encrypted update equations
//! (paper eqs. 10, 18, 20a/20b).
//!
//! Division is impossible under FHE, so every algorithm runs on
//! integer-rescaled quantities; the scale factors are data-independent,
//! known a priori, and divided out by the secret-key holder after
//! decryption. This module is the single source of truth for those
//! constants, shared by the encrypted driver, the exact integer
//! simulator and the parameter planner.
//!
//! Under slot packing the same constants are emitted as slot-broadcast
//! plaintexts, i.e. reduced mod `t`; correctness then requires every
//! true scaled intermediate — constants included — to stay below `t/2`
//! as a *value* (see the packed accounting note in
//! [`crate::fhe::noise`]), so packed parameter sets must pick `t` to
//! cover the largest constant produced here.

use crate::math::bigint::{BigInt, BigUint};

use super::float_ref::nag_etas;
#[allow(unused_imports)]
use crate::math::bigint::BigInt as _BigIntKeep;
use crate::fhe::encoding::quantize;
use crate::fhe::params::binomial;

/// ELS-GD (eq. 10): `β̃^[k] = c_carry·β̃^[k-1] + X̃ᵀ(c_y(k)·ỹ − X̃β̃^[k-1])`
/// with `β̃^[k] = 10^{(2k+1)φ}·ν^k·β^[k]`.
#[derive(Clone, Debug)]
pub struct GdScaling {
    pub phi: u32,
    pub nu: u64,
}

impl GdScaling {
    pub fn new(phi: u32, nu: u64) -> Self {
        assert!(nu >= 1);
        GdScaling { phi, nu }
    }

    /// Carry constant `10^{2φ}·ν` (paper's `10^φ·ν̃`).
    pub fn c_carry(&self) -> BigUint {
        BigUint::pow10(2 * self.phi).mul_u64(self.nu)
    }

    /// Response constant at iteration k (1-based):
    /// `10^{(2k−1)φ}·ν^{k−1}` (paper's `10^{kφ}·ν̃^{k−1}`).
    pub fn c_y(&self, k: usize) -> BigUint {
        assert!(k >= 1);
        BigUint::pow10((2 * k as u32 - 1) * self.phi)
            .mul(&BigUint::from_u64(self.nu).pow(k as u32 - 1))
    }

    /// Decode divisor after K iterations: `10^{(2K+1)φ}·ν^K`.
    pub fn divisor(&self, iters: usize) -> BigUint {
        BigUint::pow10((2 * iters as u32 + 1) * self.phi)
            .mul(&BigUint::from_u64(self.nu).pow(iters as u32))
    }
}

/// VWT (eq. 18) applied on top of GD: per-iterate plaintext weights
/// `w_k = C(K−k*, k−k*)·10^{2(K−k)φ}·ν^{K−k}` (the binomial weight fused
/// with the scale-unification constant), and decode divisor
/// `10^{(2K+1)φ}·ν^K·2^{K−k*}`.
#[derive(Clone, Debug)]
pub struct VwtScaling {
    pub gd: GdScaling,
    pub iters: usize,
    pub kstar: usize,
}

impl VwtScaling {
    pub fn new(phi: u32, nu: u64, iters: usize) -> Self {
        assert!(iters >= 1);
        VwtScaling { gd: GdScaling::new(phi, nu), iters, kstar: iters / 3 + 1 }
    }

    /// Weight for iterate k (1-based); zero below k*.
    pub fn weight(&self, k: usize) -> BigUint {
        if k < self.kstar || k > self.iters {
            return BigUint::zero();
        }
        binomial(self.iters - self.kstar, k - self.kstar)
            .mul(&BigUint::pow10(2 * (self.iters - k) as u32 * self.gd.phi))
            .mul(&BigUint::from_u64(self.gd.nu).pow((self.iters - k) as u32))
    }

    pub fn divisor(&self) -> BigUint {
        self.gd
            .divisor(self.iters)
            .mul(&BigUint::one().shl_bits(self.iters - self.kstar))
    }
}

/// ELS-NAG (eqs. 20a/20b, accelerating sign — see
/// [`super::float_ref::nag_path`]):
/// `s̃^[k] = c_carry·β̃^[k-1] + X̃ᵀ(c_y(k)·ỹ − X̃β̃^[k-1])`,
/// `β̃^[k] = w1_k·s̃^[k] − w2_k·s̃^[k-1]` with non-negative weights
/// `w1 = 10^φ·(1+|η_k|)`-quantised and `w2 = 10^{3φ}ν·|η̃_k|`
/// (w1 − w2/(10^{2φ}ν) scale-balances to 1), and
/// `β̃^[K] = 10^{(3K+1)φ}·ν^K·β^[K]`.
#[derive(Clone, Debug)]
pub struct NagScaling {
    pub phi: u32,
    pub nu: u64,
    /// Quantised η̃_k = ⌊10^φ·η_k⌉ ≤ 0.
    pub eta_q: Vec<i64>,
}

impl NagScaling {
    pub fn new(phi: u32, nu: u64, iters: usize) -> Self {
        let eta_q: Vec<i64> =
            nag_etas(iters).iter().map(|&e| quantize(e, phi)).collect();
        assert!(eta_q.iter().all(|&e| e <= 0), "η_k must be ≤ 0");
        NagScaling { phi, nu, eta_q }
    }

    /// `|η̃_k|` as planner input.
    pub fn eta_abs(&self) -> Vec<u64> {
        self.eta_q.iter().map(|&e| e.unsigned_abs()).collect()
    }

    /// Carry constant for the gradient step. The β̃-scale ratio between
    /// NAG iterations is `10^{3φ}ν / 10^φ = 10^{2φ}ν`, same as GD.
    pub fn c_carry(&self) -> BigUint {
        BigUint::pow10(2 * self.phi).mul_u64(self.nu)
    }

    /// Response constant at iteration k: with
    /// `β̃^[k−1] = 10^{(3k−2)φ}ν^{k−1}β`, matching eq. 20a requires
    /// `c_y(k) = 10^{(3k−2)φ}·ν^{k−1}`.
    pub fn c_y(&self, k: usize) -> BigUint {
        assert!(k >= 1);
        BigUint::pow10((3 * k as u32 - 2) * self.phi)
            .mul(&BigUint::from_u64(self.nu).pow(k as u32 - 1))
    }

    /// Acceleration weight on `s̃^[k]`: `10^φ + |η̃_k| ∈ [10^φ, 2·10^φ)`.
    pub fn w1(&self, k: usize) -> BigUint {
        BigUint::pow10(self.phi).add_u64(self.eta_q[k - 1].unsigned_abs())
    }

    /// Magnitude of the (subtracted) weight on `s̃^[k−1]`:
    /// `10^{3φ}·ν·|η̃_k|`.
    pub fn w2(&self, k: usize) -> BigUint {
        BigUint::pow10(3 * self.phi)
            .mul_u64(self.nu)
            .mul_u64(self.eta_q[k - 1].unsigned_abs())
    }

    /// Decode divisor after K iterations: `10^{(3K+1)φ}·ν^K`.
    pub fn divisor(&self, iters: usize) -> BigUint {
        BigUint::pow10((3 * iters as u32 + 1) * self.phi)
            .mul(&BigUint::from_u64(self.nu).pow(iters as u32))
    }
}

/// ELS-CD (eq. 7, incremental-residual form): every coordinate update u
/// multiplies all coefficients and the residual by `c = 10^{2φ}·ν`;
/// after U updates `β̃ = 10^{2Uφ}·ν^U·β`.
#[derive(Clone, Debug)]
pub struct CdScaling {
    pub phi: u32,
    pub nu: u64,
}

impl CdScaling {
    pub fn new(phi: u32, nu: u64) -> Self {
        CdScaling { phi, nu }
    }

    /// Per-update carry constant for both β̃ and the residual r̃.
    pub fn c_step(&self) -> BigUint {
        BigUint::pow10(2 * self.phi).mul_u64(self.nu)
    }

    /// Decode divisor after `updates` coordinate updates.
    pub fn divisor(&self, updates: usize) -> BigUint {
        BigUint::pow10(2 * updates as u32 * self.phi)
            .mul(&BigUint::from_u64(self.nu).pow(updates as u32))
    }
}

/// Exact f64 of a big ratio `num/den` (handles magnitudes beyond f64).
pub fn ratio_f64(num: &BigInt, den: &BigUint) -> f64 {
    if num.is_zero() {
        return 0.0;
    }
    let (nm, ne) = num.mag.to_f64_exp();
    let (dm, de) = den.to_f64_exp();
    let v = (nm / dm) * 2f64.powi((ne - de) as i32);
    if num.neg {
        -v
    } else {
        v
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn gd_constants_small_case() {
        let s = GdScaling::new(2, 7);
        assert_eq!(s.c_carry().to_decimal(), "70000"); // 10^4·7
        assert_eq!(s.c_y(1).to_decimal(), "100"); // 10^2
        assert_eq!(s.c_y(2).to_decimal(), "7000000"); // 10^6·7
        assert_eq!(s.divisor(1).to_decimal(), "7000000"); // 10^6·7
    }

    /// The defining invariant: divisor(k) = c_carry·divisor(k−1)
    /// and c_y(k)·10^{2φ} = divisor(k−1)·10^... — concretely, the
    /// per-iteration identity 10^{2φ}·c_y(k) = c_carry·c_y(k−1).
    #[test]
    fn gd_scale_recursion_consistency() {
        let s = GdScaling::new(2, 13);
        for k in 2..8 {
            let lhs = s.c_y(k);
            let rhs = s.c_y(k - 1).mul(&s.c_carry());
            assert_eq!(lhs.to_decimal(), rhs.to_decimal(), "k = {k}");
            // divisor(k) = divisor(k-1) · c_carry
            assert_eq!(
                s.divisor(k).to_decimal(),
                s.divisor(k - 1).mul(&s.c_carry()).to_decimal()
            );
            // divisor(k) = 10^{2φ} · c_y(k) · ν  (gradient-term scale
            // match: X̃ᵀ(c_y·ỹ) carries 10^{2φ}·c_y and enters with 1/ν)
            assert_eq!(
                s.divisor(k).to_decimal(),
                BigUint::pow10(2 * s.phi)
                    .mul(&s.c_y(k))
                    .mul_u64(s.nu)
                    .to_decimal()
            );
        }
    }

    #[test]
    fn vwt_weights_sum_to_divisor_ratio() {
        // Σ_k w_k·divisor_gd(k)... the simpler invariant: weights at
        // k = K is C(K−k*,K−k*)·1 = 1, and Σ binomials = 2^{K−k*}.
        let v = VwtScaling::new(2, 5, 9);
        assert_eq!(v.kstar, 4);
        assert_eq!(v.weight(9).to_u64(), Some(1));
        assert_eq!(v.weight(3), BigUint::zero());
        // Each term w_k·β̃^[k] must sit at the common scale
        // divisor_gd(K): w_k·divisor(k) = divisor(K)·C(...).
        for k in v.kstar..=9 {
            let lhs = v.weight(k).mul(&v.gd.divisor(k));
            let c = binomial(9 - v.kstar, k - v.kstar);
            let rhs = v.gd.divisor(9).mul(&c);
            assert_eq!(lhs.to_decimal(), rhs.to_decimal(), "k = {k}");
        }
    }

    #[test]
    fn nag_weights_nonnegative_and_scaled() {
        let s = NagScaling::new(2, 11, 6);
        assert_eq!(s.eta_q[0], 0, "η̃₁ = 0");
        for k in 1..=6 {
            let _ = s.w1(k);
            let _ = s.w2(k);
        }
        // Scale identity: divisor(k) = w1-scale relation
        // 10^{(3k+1)φ}ν^k = (10^φ)·(10^{3kφ}ν^k) — s̃^[k] has scale
        // 10^{3kφ}ν^k; check c_y matches: 10^{2φ}·c_y(k)·N-side —
        // minimal check: c_y(k)·10^{2φ} = c_carry · (previous β̃ scale /
        // previous... ) → c_y(k)·10^{2φ} = 10^{3kφ}ν^{k-1}.
        for k in 1..=6 {
            let lhs = s.c_y(k).mul(&BigUint::pow10(2 * s.phi));
            let rhs = BigUint::pow10(3 * k as u32 * s.phi)
                .mul(&BigUint::from_u64(s.nu).pow(k as u32 - 1));
            assert_eq!(lhs.to_decimal(), rhs.to_decimal(), "k = {k}");
        }
    }

    #[test]
    fn cd_divisor_composes() {
        let s = CdScaling::new(2, 9);
        assert_eq!(
            s.divisor(5).to_decimal(),
            s.divisor(4).mul(&s.c_step()).to_decimal()
        );
    }

    #[test]
    fn ratio_f64_handles_huge_values() {
        // (3·10^80) / (2·10^80) = 1.5
        let num = BigInt::from_biguint(BigUint::pow10(80).mul_u64(3));
        let den = BigUint::pow10(80).mul_u64(2);
        assert!((ratio_f64(&num, &den) - 1.5).abs() < 1e-12);
        let neg = num.neg_value();
        assert!((ratio_f64(&neg, &den) + 1.5).abs() < 1e-12);
    }
}
