//! Encrypted least squares: the paper's algorithms (§4–§5) in three
//! interchangeable backends.
//!
//! - [`encrypted`] — the real thing: ELS-GD / ELS-GD-VWT / ELS-NAG /
//!   ELS-CD on FV ciphertexts through a pluggable [`crate::runtime`]
//!   engine.
//! - [`exact`] — exact encoded-integer simulation (bit-identical to the
//!   decryption of the encrypted run; the fast backend for figures).
//! - [`float_ref`] — f64 reference algorithms + the OLS/RLS truth.
//! - [`scaling`] — the rescaling constants of eqs. (10), (18), (20).
//! - [`mmd`] — Table-1 multiplicative-depth accounting.
//! - [`stepsize`] — Lemma-1 / §7 step-size selection.
//! - [`predict`] / [`inference`] — §4.2 prediction, §4.3 bootstrap SEs.
//! - [`probe`] — secret-key-side noise-trajectory diagnostics (measured
//!   budget vs the §4.5 planner floor, per iteration).

pub mod encrypted;
pub mod exact;
pub mod float_ref;
pub mod inference;
pub mod mmd;
pub mod model;
pub mod predict;
pub mod probe;
pub mod scaling;
pub mod stepsize;

pub use encrypted::{
    decrypt_coefficients, fit, fit_cd, Accel, DatasetRef, EncryptedFit, FitConfig, FitOutcome,
};
#[allow(deprecated)]
pub use encrypted::{fit_packed, fit_packed_reported, fit_reported};
pub use predict::{predict, NewDataRef, PredictOutcome};
#[allow(deprecated)]
pub use predict::{predict_packed, predict_reported};
pub use probe::{noise_trajectory, NoiseTrajectory};
pub use exact::QuantisedData;
pub use model::{encrypt_dataset, encrypt_dataset_packed, EncryptedDataset, PackedDataset};
