//! The encrypted descent drivers (paper §4.1, §5): ELS-GD, ELS-GD-VWT,
//! ELS-NAG and ELS-CD running entirely on ciphertexts through an
//! [`HeEngine`].
//!
//! Every ciphertext multiplication in an iteration is emitted as one
//! batched engine call — the contract that lets the coordinator/XLA
//! backends amortise fixed-shape kernel launches (and the native
//! backend fan across cores). Inner-product sums (`Σ_j X̃_ij β̃_j`,
//! `Σ_i X̃_ij r̃_i`, the CD gradient) go through `dot_pairs` groups, so
//! a native engine relinearises and scale-and-rounds once per output
//! sum — `n+p` pipelines per GD iteration instead of `2·n·p`; only the
//! CD residual update, whose products are not summed, stays on
//! `mul_pairs`.
//!
//! With CRT slot packing (a [`DatasetRef::Packed`] over a
//! [`PackedDataset`](super::model::PackedDataset)) the observation
//! axis disappears from the multiply count entirely: one slot-wise
//! product covers all `n ≤ d` observations, and the `Σ_i` folds become
//! `O(log d)` Galois rotations — `p + 1` multiply pipelines per GD
//! iteration, independent of `n`. The per-value path stays as the
//! decrypt-parity oracle.
//!
//! The entry point is one function: [`fit`] takes a [`DatasetRef`]
//! (scalar or packed layout), returns a [`FitOutcome`] that always
//! carries the fit **and** its op-budget report. The former
//! `fit`/`fit_reported`/`fit_packed`/`fit_packed_reported` quartet
//! survives as `#[deprecated]` shims over this single path.

use crate::fhe::encoding::{encode_biguint, Encoder};
use crate::fhe::{Ciphertext, FvContext, PlaintextNtt, SecretKey};
use crate::math::bigint::BigUint;
use crate::runtime::backend::HeEngine;
use crate::util::error::{bail, Result};
use crate::util::telemetry::{self, MetricsSnapshot, Phase};

use super::mmd;
use super::model::{EncryptedDataset, PackedDataset};
use super::scaling::{ratio_f64, CdScaling, GdScaling, NagScaling, VwtScaling};

/// Acceleration mode (paper §5).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Accel {
    /// Plain (preconditioned) gradient descent.
    None,
    /// Van Wijngaarden transformation on the GD iterates (§5.2).
    Vwt,
    /// Nesterov's accelerated gradient (§5.3).
    Nag,
}

/// Fit configuration.
#[derive(Clone, Debug)]
pub struct FitConfig {
    /// Iterations K.
    pub iters: usize,
    /// Integer inverse step size ν (δ = 1/ν).
    pub nu: u64,
    /// Acceleration mode.
    pub accel: Accel,
    /// Keep the full iterate path (implied by `Vwt`).
    pub keep_path: bool,
}

impl FitConfig {
    pub fn gd(iters: usize, nu: u64) -> Self {
        FitConfig { iters, nu, accel: Accel::None, keep_path: false }
    }

    pub fn with_accel(mut self, accel: Accel) -> Self {
        self.accel = accel;
        self
    }
}

/// An encrypted fit: coefficient ciphertexts plus decode metadata.
/// `Clone` because the wire `result` verb peeks (the job keeps the
/// original until the client acks delivery).
#[derive(Clone)]
pub struct EncryptedFit {
    /// β̃ ciphertexts (one per covariate).
    pub betas: Vec<Ciphertext>,
    /// Decode divisor for [`decrypt_coefficients`].
    pub divisor: BigUint,
    /// Iterate path (βs per iteration) if requested.
    pub path: Option<Vec<Vec<Ciphertext>>>,
    /// Quantisation exponent.
    pub phi: u32,
    /// Paper Table-1 MMD of the computation performed.
    pub paper_mmd: u32,
    /// Ciphertext-multiplication depth actually consumed.
    pub noise_depth: u32,
}

/// Transparent zero ciphertext (decrypts to 0, valid operand).
fn zero_ct(ctx: &FvContext) -> Ciphertext {
    Ciphertext::new(vec![ctx.ring_q.zero(), ctx.ring_q.zero()])
}

/// One inner-product group: the borrowed pairs whose products are
/// summed into a single ciphertext by `HeEngine::dot_pairs`.
type PairGroup<'a> = Vec<(&'a Ciphertext, &'a Ciphertext)>;

/// Borrow a grid of owned pair groups as the slice-of-slices shape
/// `HeEngine::dot_pairs` takes.
fn as_groups<'a>(owned: &'a [PairGroup<'a>]) -> Vec<&'a [(&'a Ciphertext, &'a Ciphertext)]> {
    owned.iter().map(|g| g.as_slice()).collect()
}

/// One GD/NAG gradient step: returns `g_j = Σ_i X̃_ij·r̃_i` where
/// `r̃ = c_y·ỹ − X̃·β̃` (two `dot_pairs` batches: one group per row for
/// the residual, one group per column for the gradient — `n+p`
/// relinearisation + scale-and-round pipelines per iteration on a
/// fusing engine, where the flat `mul_pairs` emission paid `2·n·p`).
///
/// `c_y` changes every iteration, but within one step it multiplies
/// all N response ciphertexts — so it is NTT-cached once here and the
/// N multiplies are pure pointwise passes.
fn gradient_step(
    engine: &dyn HeEngine,
    data: &EncryptedDataset,
    beta: &[Ciphertext],
    c_y: &BigUint,
) -> Vec<Ciphertext> {
    let ctx = engine.ctx();
    let (n, p) = (data.n(), data.p());
    let cy_pt = engine.prepare_plaintext(&encode_biguint(c_y, ctx.d()));
    // r̃_i = c_y·ỹ_i − Σ_j X̃_ij β̃_j — the Σ_j is one fused group.
    let mut r: Vec<Ciphertext> =
        data.y.iter().map(|y| engine.mul_plain_prepared(y, &cy_pt)).collect();
    if !beta.is_empty() {
        let owned: Vec<PairGroup> = (0..n)
            .map(|i| (0..p).map(|j| (&data.x[i][j], &beta[j])).collect())
            .collect();
        let dots = engine.dot_pairs(&as_groups(&owned));
        for (ri, dot) in r.iter_mut().zip(&dots) {
            *ri = engine.sub(ri, dot);
        }
    }
    // g_j = Σ_i X̃_ij·r̃_i — one fused group per coordinate.
    let r_ref = &r;
    let owned: Vec<PairGroup> = (0..p)
        .map(|j| (0..n).map(|i| (&data.x[i][j], &r_ref[i])).collect())
        .collect();
    engine.dot_pairs(&as_groups(&owned))
}

/// A dataset in either ciphertext layout, borrowed for one fit. The
/// layout decides the descent path — per-value ciphertexts or CRT
/// slot-packed columns — while the update equations, decode metadata
/// and decrypted coefficients stay identical.
#[derive(Clone, Copy)]
pub enum DatasetRef<'a> {
    /// One ciphertext per value (`x[i][j]`, `y[i]`) — the parity
    /// oracle; works on any engine.
    Scalar(&'a EncryptedDataset),
    /// CRT slot-packed columns — `p + 1` multiply pipelines per
    /// iteration, but needs a rotation-capable engine (Galois keys).
    Packed(&'a PackedDataset),
}

/// What a fit returns: the coefficient ciphertexts plus the op-budget
/// report. The report is the [`MetricsSnapshot`] diff of everything
/// the fit consumed (ring transforms/relins/scale-rounds/rotations,
/// engine ct/plain muls); it is per-fit even on a shared engine as
/// long as no other work runs concurrently — the `pool`/`trace`
/// sections are process-global and only meaningful for a quiet
/// process.
pub struct FitOutcome {
    /// The fitted coefficients and decode metadata.
    pub fit: EncryptedFit,
    /// Op-budget diff for this fit.
    pub report: MetricsSnapshot,
}

// ---- mid-fit checkpoints ------------------------------------------------

/// The per-algorithm loop state a resume needs. All scaling constants
/// are deterministic functions of `(φ, ν, K)` and are re-derived on
/// resume — only ciphertext state is carried.
#[derive(Clone)]
pub enum CheckpointState {
    /// ELS-GD (and the VWT variant — VWT differs only post-loop):
    /// iterate plus the kept path so far.
    Gd { beta: Vec<Ciphertext>, path: Vec<Vec<Ciphertext>> },
    /// ELS-NAG: iterate, previous s-sequence, kept path so far.
    Nag { beta: Vec<Ciphertext>, s_prev: Vec<Ciphertext>, path: Vec<Vec<Ciphertext>> },
    /// ELS-CD: per-coordinate iterate (`None` = not yet touched) and
    /// the incremental residual.
    Cd { beta: Vec<Option<Ciphertext>>, r: Vec<Ciphertext> },
}

/// An opaque mid-fit resume point: everything a descent loop needs to
/// continue from iteration `done + 1` and produce a fit bit-identical
/// to an uninterrupted run. Emitted by [`fit_with_checkpoints`] through
/// a [`CheckpointHook`]; journaled by the coordinator.
#[derive(Clone)]
pub struct DescentCheckpoint {
    /// Quantisation exponent of the dataset the fit ran on.
    pub phi: u32,
    /// Inverse step size ν of the config the fit ran under.
    pub nu: u64,
    /// Completed iterations (GD/NAG) or coordinate updates (CD).
    pub done: usize,
    /// Algorithm-specific ciphertext state.
    pub state: CheckpointState,
}

impl DescentCheckpoint {
    /// Guard a resume against a config it was not taken under — a
    /// mismatched ν or φ would silently decode garbage.
    fn validate(&self, phi: u32, nu: u64, total: usize) -> Result<()> {
        if self.phi != phi {
            bail!("checkpoint phi {} does not match dataset phi {phi}", self.phi);
        }
        if self.nu != nu {
            bail!("checkpoint nu {} does not match config nu {nu}", self.nu);
        }
        if self.done > total {
            bail!("checkpoint at iteration {} beyond configured {total}", self.done);
        }
        Ok(())
    }
}

/// Checkpoint emission: after every `every` completed iterations
/// (except the last — a finished fit journals `done`, not a
/// checkpoint) the sink receives the current resume point.
pub struct CheckpointHook<'a> {
    /// Take a checkpoint every this many iterations (0 = never).
    pub every: usize,
    /// Receives each emitted checkpoint (e.g. a journal append).
    pub sink: Box<dyn FnMut(DescentCheckpoint) + 'a>,
}

/// Shared every-k emission gate for the four descent loops.
fn take_checkpoint(
    hook: &mut Option<&mut CheckpointHook<'_>>,
    done: usize,
    total: usize,
    make: impl FnOnce() -> DescentCheckpoint,
) {
    if let Some(h) = hook.as_deref_mut() {
        if h.every > 0 && done % h.every == 0 && done < total {
            (h.sink)(make());
        }
    }
}

/// Fit by ELS-GD (eq. 10), optionally with VWT (eq. 18) or NAG
/// (eqs. 20a/20b) acceleration, on either ciphertext layout. This is
/// the one fit entry point: the layout is carried by the
/// [`DatasetRef`], and the [`FitOutcome`] always includes the
/// op-budget report. Fails only when a packed dataset meets an engine
/// that cannot rotate (no Galois keys).
pub fn fit(engine: &dyn HeEngine, data: &DatasetRef, cfg: &FitConfig) -> Result<FitOutcome> {
    fit_with_checkpoints(engine, data, cfg, None, None)
}

/// [`fit`] with the durability seam: resume from a prior
/// [`DescentCheckpoint`] and/or emit checkpoints through a
/// [`CheckpointHook`] while iterating. A resumed fit is bit-identical
/// to an uninterrupted one — descent is deterministic, ciphertexts
/// round-trip exactly, and scaling state re-derives from `(φ, ν, K)`.
/// Checkpoints cover the per-value layout (the one the serving tier
/// journals); a packed fit with a resume point or hook is an error.
pub fn fit_with_checkpoints(
    engine: &dyn HeEngine,
    data: &DatasetRef,
    cfg: &FitConfig,
    resume: Option<&DescentCheckpoint>,
    mut hook: Option<CheckpointHook<'_>>,
) -> Result<FitOutcome> {
    let before = MetricsSnapshot::capture(engine.ctx(), engine.stats());
    let fit = match data {
        DatasetRef::Scalar(d) => fit_scalar(engine, d, cfg, resume, hook.as_mut())?,
        DatasetRef::Packed(d) => {
            if resume.is_some() || hook.is_some() {
                bail!("descent checkpoints support the per-value layout only");
            }
            fit_packed_inner(engine, d, cfg)?
        }
    };
    let after = MetricsSnapshot::capture(engine.ctx(), engine.stats());
    Ok(FitOutcome { fit, report: after.diff(&before) })
}

/// Per-value fit dispatch (fails only on a mismatched resume point).
fn fit_scalar(
    engine: &dyn HeEngine,
    data: &EncryptedDataset,
    cfg: &FitConfig,
    resume: Option<&DescentCheckpoint>,
    hook: Option<&mut CheckpointHook<'_>>,
) -> Result<EncryptedFit> {
    match cfg.accel {
        Accel::None | Accel::Vwt => fit_gd(engine, data, cfg, resume, hook),
        Accel::Nag => fit_nag(engine, data, cfg, resume, hook),
    }
}

/// Pre-unification shim.
#[deprecated(note = "use fit(engine, &DatasetRef::Scalar(data), cfg) — the \
                     FitOutcome always carries the report")]
pub fn fit_reported(
    engine: &dyn HeEngine,
    data: &EncryptedDataset,
    cfg: &FitConfig,
) -> (EncryptedFit, MetricsSnapshot) {
    let out = fit(engine, &DatasetRef::Scalar(data), cfg)
        .expect("scalar fits are infallible");
    (out.fit, out.report)
}

/// A rescaling constant as a slot-broadcast plaintext, NTT-cached.
/// Packed constants live in the *value* domain: the encoder reduces
/// them mod `t`, which is exact as long as every true intermediate
/// value stays below `t/2` (the packed correctness bound — see
/// `fhe::noise`).
fn packed_const(engine: &dyn HeEngine, v: &BigUint) -> PlaintextNtt {
    engine.prepare_plaintext(&engine.ctx().encoder().encode_const_biguint(v))
}

/// One packed GD/NAG gradient step over column ciphertexts: the
/// residual `r̃ = c_y·ỹ − Σ_j X̃_j ⊙ β̃_j` is **one** fused `dot_pairs`
/// group of `p` slot-wise products (one relinearisation + one
/// scale-and-round for all `n` observations at once), and each
/// gradient coordinate `g̃_j = slot_sum(X̃_j ⊙ r̃)` is one slot-wise
/// multiply plus `log₂(d/2) + 1` rotations — `p + 1` multiply
/// pipelines and `p·O(log d)` rotations per iteration, where the
/// per-value layout pays `n + p` pipelines. `slot_sum` leaves the
/// total in *every* slot, so `g̃_j` (and hence `β̃_j`) stays
/// slot-broadcast across iterations with no extra work.
fn gradient_step_packed(
    engine: &dyn HeEngine,
    data: &PackedDataset,
    beta: &[Ciphertext],
    c_y: &BigUint,
) -> Result<Vec<Ciphertext>> {
    let cy_pt = packed_const(engine, c_y);
    let mut r = engine.mul_plain_prepared(&data.y, &cy_pt);
    if !beta.is_empty() {
        let pairs: PairGroup =
            data.x_cols.iter().zip(beta.iter()).map(|(x, b)| (x, b)).collect();
        let dot = engine.dot_pairs(&[pairs.as_slice()]).pop().unwrap();
        r = engine.sub(&r, &dot);
    }
    let r_ref = &r;
    let pairs: PairGroup = data.x_cols.iter().map(|x| (x, r_ref)).collect();
    let prods = engine.mul_pairs(&pairs);
    prods.iter().map(|ct| engine.slot_sum(ct)).collect()
}

/// Slot-packed fit dispatch — ELS-GD, optionally VWT- or
/// NAG-accelerated, with identical update equations and decode
/// metadata to the per-value path (the unpacked path is the parity
/// oracle: both decrypt to the same coefficients). ELS-CD stays
/// scalar-only — its incremental residual is never summed, so packing
/// buys nothing there. Fails if the engine cannot rotate (no Galois
/// keys).
fn fit_packed_inner(
    engine: &dyn HeEngine,
    data: &PackedDataset,
    cfg: &FitConfig,
) -> Result<EncryptedFit> {
    match cfg.accel {
        Accel::None | Accel::Vwt => fit_gd_packed(engine, data, cfg),
        Accel::Nag => fit_nag_packed(engine, data, cfg),
    }
}

/// Pre-unification shim.
#[deprecated(note = "use fit(engine, &DatasetRef::Packed(data), cfg)")]
pub fn fit_packed(
    engine: &dyn HeEngine,
    data: &PackedDataset,
    cfg: &FitConfig,
) -> Result<EncryptedFit> {
    fit(engine, &DatasetRef::Packed(data), cfg).map(|out| out.fit)
}

/// Pre-unification shim.
#[deprecated(note = "use fit(engine, &DatasetRef::Packed(data), cfg) — the \
                     FitOutcome always carries the report")]
pub fn fit_packed_reported(
    engine: &dyn HeEngine,
    data: &PackedDataset,
    cfg: &FitConfig,
) -> Result<(EncryptedFit, MetricsSnapshot)> {
    fit(engine, &DatasetRef::Packed(data), cfg).map(|out| (out.fit, out.report))
}

fn fit_gd_packed(
    engine: &dyn HeEngine,
    data: &PackedDataset,
    cfg: &FitConfig,
) -> Result<EncryptedFit> {
    let ctx = engine.ctx();
    let p = data.p();
    let s = GdScaling::new(data.phi, cfg.nu);
    let keep_path = cfg.keep_path || cfg.accel == Accel::Vwt;
    let cc_pt = packed_const(engine, &s.c_carry());
    let mut beta: Vec<Ciphertext> = Vec::new();
    let mut path: Vec<Vec<Ciphertext>> = Vec::new();
    for k in 1..=cfg.iters {
        let _iter = telemetry::span(Phase::DescentIteration);
        let g = gradient_step_packed(engine, data, &beta, &s.c_y(k))?;
        beta = if beta.is_empty() {
            g
        } else {
            (0..p)
                .map(|j| engine.add(&engine.mul_plain_prepared(&beta[j], &cc_pt), &g[j]))
                .collect()
        };
        if keep_path {
            path.push(beta.clone());
        }
    }
    let (betas, divisor, paper) = if cfg.accel == Accel::Vwt {
        let v = VwtScaling::new(data.phi, cfg.nu, cfg.iters);
        let mut acc: Vec<Ciphertext> = vec![zero_ct(ctx); p];
        for k in v.kstar..=cfg.iters {
            let w = v.weight(k);
            if w.is_zero() {
                continue;
            }
            let w_pt = packed_const(engine, &w);
            for j in 0..p {
                let term = engine.mul_plain_prepared(&path[k - 1][j], &w_pt);
                acc[j] = engine.add(&acc[j], &term);
            }
        }
        (acc, v.divisor(), mmd::paper_mmd(Accel::Vwt, cfg.iters))
    } else {
        (beta, s.divisor(cfg.iters), mmd::paper_mmd(Accel::None, cfg.iters))
    };
    Ok(EncryptedFit {
        noise_depth: betas.iter().map(|b| b.ct_depth).max().unwrap_or(0),
        betas,
        divisor,
        path: if cfg.keep_path { Some(path) } else { None },
        phi: data.phi,
        paper_mmd: paper,
    })
}

fn fit_nag_packed(
    engine: &dyn HeEngine,
    data: &PackedDataset,
    cfg: &FitConfig,
) -> Result<EncryptedFit> {
    let ctx = engine.ctx();
    let p = data.p();
    let s = NagScaling::new(data.phi, cfg.nu, cfg.iters);
    let cc_pt = packed_const(engine, &s.c_carry());
    let mut beta: Vec<Ciphertext> = Vec::new();
    let mut s_prev: Vec<Ciphertext> = vec![zero_ct(ctx); p];
    let mut path: Vec<Vec<Ciphertext>> = Vec::new();
    for k in 1..=cfg.iters {
        let _iter = telemetry::span(Phase::DescentIteration);
        let g = gradient_step_packed(engine, data, &beta, &s.c_y(k))?;
        let s_cur: Vec<Ciphertext> = if beta.is_empty() {
            g
        } else {
            (0..p)
                .map(|j| engine.add(&engine.mul_plain_prepared(&beta[j], &cc_pt), &g[j]))
                .collect()
        };
        let w1_pt = packed_const(engine, &s.w1(k));
        let w2 = s.w2(k);
        let w2_pt = if w2.is_zero() { None } else { Some(packed_const(engine, &w2)) };
        beta = (0..p)
            .map(|j| {
                let a = engine.mul_plain_prepared(&s_cur[j], &w1_pt);
                match &w2_pt {
                    None => a,
                    Some(w2_pt) => {
                        engine.sub(&a, &engine.mul_plain_prepared(&s_prev[j], w2_pt))
                    }
                }
            })
            .collect();
        s_prev = s_cur;
        if cfg.keep_path {
            path.push(beta.clone());
        }
    }
    Ok(EncryptedFit {
        noise_depth: beta.iter().map(|b| b.ct_depth).max().unwrap_or(0),
        betas: beta,
        divisor: s.divisor(cfg.iters),
        path: if cfg.keep_path { Some(path) } else { None },
        phi: data.phi,
        paper_mmd: mmd::paper_mmd(Accel::Nag, cfg.iters),
    })
}

fn fit_gd(
    engine: &dyn HeEngine,
    data: &EncryptedDataset,
    cfg: &FitConfig,
    resume: Option<&DescentCheckpoint>,
    mut hook: Option<&mut CheckpointHook<'_>>,
) -> Result<EncryptedFit> {
    let ctx = engine.ctx();
    let p = data.p();
    let s = GdScaling::new(data.phi, cfg.nu);
    let keep_path = cfg.keep_path || cfg.accel == Accel::Vwt;
    let (mut beta, mut path, start) = match resume {
        Some(c) => {
            c.validate(data.phi, cfg.nu, cfg.iters)?;
            let CheckpointState::Gd { beta, path } = &c.state else {
                bail!("checkpoint algorithm mismatch (expected gd state)");
            };
            if c.done > 0 && beta.len() != p {
                bail!("checkpoint iterate arity {} != covariates {p}", beta.len());
            }
            if keep_path && path.len() != c.done {
                bail!("checkpoint path holds {} iterates, expected {}", path.len(), c.done);
            }
            (beta.clone(), path.clone(), c.done)
        }
        None => (Vec::new(), Vec::new(), 0),
    };
    // The carry constant is iteration-invariant: NTT-cached once for
    // the whole fit (P multiplies per iteration, K iterations).
    let cc_pt = engine.prepare_plaintext(&encode_biguint(&s.c_carry(), ctx.d()));
    for k in start + 1..=cfg.iters {
        let _iter = telemetry::span(Phase::DescentIteration);
        let g = gradient_step(engine, data, &beta, &s.c_y(k));
        beta = if beta.is_empty() {
            g
        } else {
            (0..p)
                .map(|j| engine.add(&engine.mul_plain_prepared(&beta[j], &cc_pt), &g[j]))
                .collect()
        };
        if keep_path {
            path.push(beta.clone());
        }
        take_checkpoint(&mut hook, k, cfg.iters, || DescentCheckpoint {
            phi: data.phi,
            nu: cfg.nu,
            done: k,
            state: CheckpointState::Gd {
                beta: beta.clone(),
                path: if keep_path { path.clone() } else { Vec::new() },
            },
        });
    }
    let (betas, divisor, paper) = if cfg.accel == Accel::Vwt {
        // β̃_vwt = Σ_{k≥k*} w_k·β̃^[k] at the unified K-scale.
        let v = VwtScaling::new(data.phi, cfg.nu, cfg.iters);
        let mut acc: Vec<Ciphertext> = vec![zero_ct(ctx); p];
        for k in v.kstar..=cfg.iters {
            let w = v.weight(k);
            if w.is_zero() {
                continue;
            }
            // w_k is per-k but multiplies all P path ciphertexts.
            let w_pt = engine.prepare_plaintext(&encode_biguint(&w, ctx.d()));
            for j in 0..p {
                let term = engine.mul_plain_prepared(&path[k - 1][j], &w_pt);
                acc[j] = engine.add(&acc[j], &term);
            }
        }
        (acc, v.divisor(), mmd::paper_mmd(Accel::Vwt, cfg.iters))
    } else {
        (beta, s.divisor(cfg.iters), mmd::paper_mmd(Accel::None, cfg.iters))
    };
    Ok(EncryptedFit {
        noise_depth: betas.iter().map(|b| b.ct_depth).max().unwrap_or(0),
        betas,
        divisor,
        path: if cfg.keep_path { Some(path) } else { None },
        phi: data.phi,
        paper_mmd: paper,
    })
}

fn fit_nag(
    engine: &dyn HeEngine,
    data: &EncryptedDataset,
    cfg: &FitConfig,
    resume: Option<&DescentCheckpoint>,
    mut hook: Option<&mut CheckpointHook<'_>>,
) -> Result<EncryptedFit> {
    let ctx = engine.ctx();
    let p = data.p();
    let s = NagScaling::new(data.phi, cfg.nu, cfg.iters);
    // Iteration-invariant carry constant: cached once for the fit.
    let cc_pt = engine.prepare_plaintext(&encode_biguint(&s.c_carry(), ctx.d()));
    let (mut beta, mut s_prev, mut path, start) = match resume {
        Some(c) => {
            c.validate(data.phi, cfg.nu, cfg.iters)?;
            let CheckpointState::Nag { beta, s_prev, path } = &c.state else {
                bail!("checkpoint algorithm mismatch (expected nag state)");
            };
            if s_prev.len() != p || (c.done > 0 && beta.len() != p) {
                bail!("checkpoint iterate arity mismatch ({} covariates)", p);
            }
            if cfg.keep_path && path.len() != c.done {
                bail!("checkpoint path holds {} iterates, expected {}", path.len(), c.done);
            }
            (beta.clone(), s_prev.clone(), path.clone(), c.done)
        }
        None => (Vec::new(), vec![zero_ct(ctx); p], Vec::new(), 0),
    };
    for k in start + 1..=cfg.iters {
        let _iter = telemetry::span(Phase::DescentIteration);
        let g = gradient_step(engine, data, &beta, &s.c_y(k));
        // s̃^[k] = c_carry·β̃^[k−1] + g
        let s_cur: Vec<Ciphertext> = if beta.is_empty() {
            g
        } else {
            (0..p)
                .map(|j| engine.add(&engine.mul_plain_prepared(&beta[j], &cc_pt), &g[j]))
                .collect()
        };
        // β̃^[k] = w1·s̃^[k] − w2·s̃^[k−1] (accelerating extrapolation).
        // w1/w2 are per-k but multiply all P coordinates: cache each
        // once per iteration instead of transforming P times.
        let w1_pt = engine.prepare_plaintext(&encode_biguint(&s.w1(k), ctx.d()));
        let w2 = s.w2(k);
        let w2_pt = if w2.is_zero() {
            None
        } else {
            Some(engine.prepare_plaintext(&encode_biguint(&w2, ctx.d())))
        };
        beta = (0..p)
            .map(|j| {
                let a = engine.mul_plain_prepared(&s_cur[j], &w1_pt);
                match &w2_pt {
                    None => a,
                    Some(w2_pt) => {
                        engine.sub(&a, &engine.mul_plain_prepared(&s_prev[j], w2_pt))
                    }
                }
            })
            .collect();
        s_prev = s_cur;
        if cfg.keep_path {
            path.push(beta.clone());
        }
        take_checkpoint(&mut hook, k, cfg.iters, || DescentCheckpoint {
            phi: data.phi,
            nu: cfg.nu,
            done: k,
            state: CheckpointState::Nag {
                beta: beta.clone(),
                s_prev: s_prev.clone(),
                path: path.clone(),
            },
        });
    }
    Ok(EncryptedFit {
        noise_depth: beta.iter().map(|b| b.ct_depth).max().unwrap_or(0),
        betas: beta,
        divisor: s.divisor(cfg.iters),
        path: if cfg.keep_path { Some(path) } else { None },
        phi: data.phi,
        paper_mmd: mmd::paper_mmd(Accel::Nag, cfg.iters),
    })
}

/// Fit by ELS-CD (eq. 7, incremental-residual form, cyclic schedule).
/// `updates` counts individual coordinate updates (K sweeps = K·P).
pub fn fit_cd(
    engine: &dyn HeEngine,
    data: &EncryptedDataset,
    nu: u64,
    updates: usize,
) -> EncryptedFit {
    fit_cd_with_checkpoints(engine, data, nu, updates, None, None)
        .expect("resume-free CD fit is infallible")
}

/// [`fit_cd`] with the durability seam (resume point + checkpoint
/// hook); fails only on a mismatched resume point.
pub fn fit_cd_with_checkpoints(
    engine: &dyn HeEngine,
    data: &EncryptedDataset,
    nu: u64,
    updates: usize,
    resume: Option<&DescentCheckpoint>,
    mut hook: Option<&mut CheckpointHook<'_>>,
) -> Result<EncryptedFit> {
    let ctx = engine.ctx();
    let (n, p) = (data.n(), data.p());
    let s = CdScaling::new(data.phi, nu);
    // The step constant is update-invariant and multiplies P + N
    // ciphertexts per update: cached once for the whole fit.
    let c_pt = engine.prepare_plaintext(&encode_biguint(&s.c_step(), ctx.d()));
    let (mut beta, mut r, start) = match resume {
        Some(c) => {
            c.validate(data.phi, nu, updates)?;
            let CheckpointState::Cd { beta, r } = &c.state else {
                bail!("checkpoint algorithm mismatch (expected cd state)");
            };
            if beta.len() != p || r.len() != n {
                bail!("checkpoint arity mismatch ({p} covariates, {n} residuals)");
            }
            (beta.clone(), r.clone(), c.done)
        }
        None => (vec![None; p], data.y.to_vec(), 0),
    };
    for u in start + 1..=updates {
        let _iter = telemetry::span(Phase::DescentIteration);
        let j = (u - 1) % p;
        // ĝ_j = Σ_i X̃_ij·r̃_i — one fused group (one relinearisation
        // per coordinate update instead of N).
        let pairs: Vec<(&Ciphertext, &Ciphertext)> =
            (0..n).map(|i| (&data.x[i][j], &r[i])).collect();
        let g = engine.dot_pairs(&[pairs.as_slice()]).pop().unwrap();
        // Carry all coefficients, add ĝ to coordinate j.
        for (l, b) in beta.iter_mut().enumerate() {
            *b = match (b.take(), l == j) {
                (None, false) => None,
                (None, true) => Some(g.clone()),
                (Some(prev), false) => Some(engine.mul_plain_prepared(&prev, &c_pt)),
                (Some(prev), true) => {
                    Some(engine.add(&engine.mul_plain_prepared(&prev, &c_pt), &g))
                }
            };
        }
        // r̃ ← c·r̃ − X̃_j·ĝ
        let pairs: Vec<(&Ciphertext, &Ciphertext)> =
            (0..n).map(|i| (&data.x[i][j], &g)).collect();
        let xg = engine.mul_pairs(&pairs);
        r = (0..n)
            .map(|i| engine.sub(&engine.mul_plain_prepared(&r[i], &c_pt), &xg[i]))
            .collect();
        take_checkpoint(&mut hook, u, updates, || DescentCheckpoint {
            phi: data.phi,
            nu,
            done: u,
            state: CheckpointState::Cd { beta: beta.clone(), r: r.clone() },
        });
    }
    let betas: Vec<Ciphertext> =
        beta.into_iter().map(|b| b.unwrap_or_else(|| zero_ct(ctx))).collect();
    Ok(EncryptedFit {
        noise_depth: betas.iter().map(|b| b.ct_depth).max().unwrap_or(0),
        betas,
        divisor: s.divisor(updates),
        path: None,
        phi: data.phi,
        paper_mmd: mmd::paper_mmd_cd(updates.div_ceil(p), p),
    })
}

/// Secret-key holder: decrypt and rescale the fitted coefficients.
/// Encoding-aware: scalar fits evaluate the coefficient polynomial at
/// 2 (the §3.1 decode); packed fits read slot 0 — `slot_sum` left the
/// same total in every slot, so any slot would do — and rescale by the
/// identical divisor.
pub fn decrypt_coefficients(ctx: &FvContext, sk: &SecretKey, fit: &EncryptedFit) -> Vec<f64> {
    fit.betas
        .iter()
        .map(|ct| {
            let pt = ctx.decrypt(ct, sk);
            match ctx.slot_encoder() {
                Some(enc) => ratio_f64(&enc.decode_slot(&pt, 0), &fit.divisor),
                None => pt.eval_at_2_scaled(&fit.divisor),
            }
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use std::sync::Arc;

    use super::*;
    use crate::data::synth;
    use crate::els::exact::{self, QuantisedData};
    use crate::els::float_ref::{self, linf};
    use crate::els::model::{encrypt_dataset, encrypt_dataset_packed};
    use crate::fhe::keys::keygen;
    use crate::fhe::params::{plan, Algo, PlanRequest};
    use crate::fhe::rng::ChaChaRng;
    use crate::fhe::FvContext;
    use crate::runtime::backend::NativeEngine;

    struct Setup {
        ctx: Arc<FvContext>,
        keys: crate::fhe::KeySet,
        engine: NativeEngine,
        data: EncryptedDataset,
        q: QuantisedData,
        nu: u64,
    }

    fn setup(seed: u64, n: usize, p: usize, iters: usize, algo: Algo) -> Setup {
        let mut rng = ChaChaRng::from_seed(seed);
        let (x, y) = synth::gaussian_regression(&mut rng, n, p, 0.2);
        let q = QuantisedData::from_f64(&x, &y, 2);
        let (xq, _) = q.dequantised();
        let (lmin, lmax) = float_ref::gram_spectrum(&xq);
        let nu = ((lmin + lmax) / 2.0).ceil() as u64;
        let mut req = PlanRequest::gd(n, p, iters, 2, nu).with_algo(algo);
        if algo == Algo::Nag {
            req.eta_abs_q =
                crate::els::scaling::NagScaling::new(2, nu, iters).eta_abs();
        }
        let params = plan(&req).unwrap();
        let ctx = FvContext::new(params);
        let keys = keygen(&ctx, &mut rng);
        let engine = NativeEngine::new(ctx.clone(), Arc::new(keys.rk.clone()));
        let data = encrypt_dataset(&ctx, &keys.pk, &q, &mut rng);
        Setup { ctx, keys, engine, data, q, nu }
    }

    #[test]
    fn encrypted_gd_equals_exact_simulation() {
        let s = setup(301, 8, 2, 2, Algo::Gd);
        let fit = super::fit(&s.engine, &DatasetRef::Scalar(&s.data), &FitConfig::gd(2, s.nu))
            .unwrap()
            .fit;
        let dec = decrypt_coefficients(&s.ctx, &s.keys.sk, &fit);
        let exact = exact::gd_exact(&s.q, s.nu, 2);
        let expect = exact.decode_last();
        let d = linf(&dec, &expect);
        assert!(d < 1e-9, "encrypted vs exact drift: {d} ({dec:?} vs {expect:?})");
        assert_eq!(fit.paper_mmd, 4);
        assert_eq!(fit.noise_depth, 3); // 2K−1
    }

    #[test]
    fn gradient_step_relin_budget_is_n_plus_p() {
        // The fusion acceptance criterion: one relinearisation + one
        // scale-and-round pipeline per output *sum* — n+p per GD
        // iteration under dot_pairs, where the flat mul_pairs emission
        // paid 2·n·p of each.
        let s = setup(305, 5, 2, 2, Algo::Gd);
        // One fitted iteration materialises a live β̃ so the next
        // gradient step runs both fused batches.
        let f1 = super::fit(&s.engine, &DatasetRef::Scalar(&s.data), &FitConfig::gd(1, s.nu))
            .unwrap()
            .fit;
        let (n, p) = (s.data.n(), s.data.p());
        let ring = &s.ctx.ring_q;
        let (r0, s0) = (ring.relin_count(), ring.scale_round_count());
        let gs = GdScaling::new(s.data.phi, s.nu);
        let g = gradient_step(&s.engine, &s.data, &f1.betas, &gs.c_y(2));
        assert_eq!(g.len(), p);
        assert_eq!(ring.relin_count() - r0, (n + p) as u64, "n+p relinearisations");
        assert_eq!(
            ring.scale_round_count() - s0,
            (n + p) as u64,
            "n+p scale-and-round pipelines (no chunking at this scale)"
        );
    }

    #[test]
    fn encrypted_vwt_equals_exact() {
        let s = setup(302, 6, 2, 3, Algo::GdVwt);
        let cfg = FitConfig::gd(3, s.nu).with_accel(Accel::Vwt);
        let fit = super::fit(&s.engine, &DatasetRef::Scalar(&s.data), &cfg).unwrap().fit;
        let dec = decrypt_coefficients(&s.ctx, &s.keys.sk, &fit);
        let (acc, div) = exact::vwt_exact(&s.q, s.nu, 3);
        let expect: Vec<f64> = acc
            .iter()
            .map(|b| crate::els::scaling::ratio_f64(b, &div))
            .collect();
        assert!(linf(&dec, &expect) < 1e-9);
        assert_eq!(fit.paper_mmd, 7); // 2K+1
    }

    #[test]
    fn encrypted_nag_equals_exact() {
        let s = setup(303, 6, 2, 2, Algo::Nag);
        let cfg = FitConfig::gd(2, s.nu).with_accel(Accel::Nag);
        let fit = super::fit(&s.engine, &DatasetRef::Scalar(&s.data), &cfg).unwrap().fit;
        let dec = decrypt_coefficients(&s.ctx, &s.keys.sk, &fit);
        let expect = exact::nag_exact(&s.q, s.nu, 2).decode_last();
        assert!(linf(&dec, &expect) < 1e-9);
        assert_eq!(fit.paper_mmd, 6); // 3K
    }

    #[test]
    fn encrypted_cd_equals_exact() {
        let s = setup(304, 6, 2, 2, Algo::Cd); // plan depth covers 2·updates
        let fit = fit_cd(&s.engine, &s.data, s.nu, 2);
        let dec = decrypt_coefficients(&s.ctx, &s.keys.sk, &fit);
        let expect = exact::cd_exact(&s.q, s.nu, 2).decode_last();
        assert!(linf(&dec, &expect) < 1e-9, "{dec:?} vs {expect:?}");
    }

    fn assert_fit_identical(a: &EncryptedFit, b: &EncryptedFit, tag: &str) {
        assert_eq!(a.betas.len(), b.betas.len(), "{tag}: coefficient count");
        for (j, (x, y)) in a.betas.iter().zip(&b.betas).enumerate() {
            assert_eq!(x.polys, y.polys, "{tag}: β_{j} polys differ");
            assert_eq!(x.ct_depth, y.ct_depth, "{tag}: β_{j} depth differs");
        }
        assert_eq!(a.divisor, b.divisor, "{tag}: divisor");
        assert_eq!(a.paper_mmd, b.paper_mmd, "{tag}: paper_mmd");
        assert_eq!(a.noise_depth, b.noise_depth, "{tag}: noise_depth");
    }

    #[test]
    fn resumed_fits_are_bit_identical_to_uninterrupted() {
        // The durability acceptance criterion: for every descent loop,
        // resuming from ANY mid-fit checkpoint reproduces the
        // uninterrupted fit bit-for-bit — same ciphertext polys, same
        // depth, same decode metadata.
        for (algo, accel) in
            [(Algo::Gd, Accel::None), (Algo::GdVwt, Accel::Vwt), (Algo::Nag, Accel::Nag)]
        {
            let s = setup(321, 5, 2, 3, algo);
            let cfg = FitConfig::gd(3, s.nu).with_accel(accel);
            let reference =
                super::fit(&s.engine, &DatasetRef::Scalar(&s.data), &cfg).unwrap().fit;
            let mut ckpts: Vec<DescentCheckpoint> = Vec::new();
            let hook =
                CheckpointHook { every: 1, sink: Box::new(|c| ckpts.push(c)) };
            let hooked = fit_with_checkpoints(
                &s.engine,
                &DatasetRef::Scalar(&s.data),
                &cfg,
                None,
                Some(hook),
            )
            .unwrap()
            .fit;
            assert_fit_identical(&hooked, &reference, "hooked run");
            assert_eq!(ckpts.len(), 2, "every=1 over 3 iterations emits at k=1,2");
            for c in &ckpts {
                let resumed = fit_with_checkpoints(
                    &s.engine,
                    &DatasetRef::Scalar(&s.data),
                    &cfg,
                    Some(c),
                    None,
                )
                .unwrap()
                .fit;
                assert_fit_identical(
                    &resumed,
                    &reference,
                    &format!("{accel:?} resumed at {}", c.done),
                );
            }
        }
    }

    #[test]
    fn resumed_cd_fit_is_bit_identical() {
        let s = setup(323, 6, 2, 2, Algo::Cd);
        let reference = fit_cd(&s.engine, &s.data, s.nu, 2);
        let mut ckpts: Vec<DescentCheckpoint> = Vec::new();
        let mut hook = CheckpointHook { every: 1, sink: Box::new(|c| ckpts.push(c)) };
        let hooked =
            fit_cd_with_checkpoints(&s.engine, &s.data, s.nu, 2, None, Some(&mut hook))
                .unwrap();
        drop(hook);
        assert_fit_identical(&hooked, &reference, "hooked cd run");
        assert_eq!(ckpts.len(), 1, "every=1 over 2 updates emits at u=1");
        let resumed =
            fit_cd_with_checkpoints(&s.engine, &s.data, s.nu, 2, Some(&ckpts[0]), None)
                .unwrap();
        assert_fit_identical(&resumed, &reference, "cd resumed at 1");
    }

    #[test]
    fn checkpoint_resume_validates_config() {
        let s = setup(322, 5, 2, 2, Algo::Gd);
        let cfg = FitConfig::gd(2, s.nu);
        let mut ckpts: Vec<DescentCheckpoint> = Vec::new();
        let hook = CheckpointHook { every: 1, sink: Box::new(|c| ckpts.push(c)) };
        fit_with_checkpoints(&s.engine, &DatasetRef::Scalar(&s.data), &cfg, None, Some(hook))
            .unwrap();
        let c = &ckpts[0];
        // A checkpoint taken under a different ν must not resume.
        let bad_nu = FitConfig::gd(2, s.nu + 1);
        assert!(fit_with_checkpoints(
            &s.engine,
            &DatasetRef::Scalar(&s.data),
            &bad_nu,
            Some(c),
            None
        )
        .is_err());
        // Nor may a GD checkpoint resume a NAG fit.
        let nag = FitConfig::gd(2, s.nu).with_accel(Accel::Nag);
        assert!(fit_with_checkpoints(
            &s.engine,
            &DatasetRef::Scalar(&s.data),
            &nag,
            Some(c),
            None
        )
        .is_err());
        // Nor beyond the configured iteration budget.
        let short = FitConfig::gd(ckpts.last().unwrap().done - 1, s.nu);
        assert!(fit_with_checkpoints(
            &s.engine,
            &DatasetRef::Scalar(&s.data),
            &short,
            Some(ckpts.last().unwrap()),
            None
        )
        .is_err());
    }

    struct PackedSetup {
        ctx: Arc<FvContext>,
        keys: crate::fhe::KeySet,
        engine: NativeEngine,
        data: crate::els::model::PackedDataset,
        q: QuantisedData,
        nu: u64,
    }

    /// Packed worlds quantise at φ = 1 and take a generous limb count:
    /// packed correctness is a *value* bound (every true intermediate
    /// < t/2, since constants and results live mod t), so t must cover
    /// the largest scaled gradient, and the modulus must cover the
    /// noise of depth 2K−1 multiplies at that t.
    fn setup_packed(seed: u64, n: usize, p: usize) -> PackedSetup {
        let mut rng = ChaChaRng::from_seed(seed);
        let (x, y) = synth::gaussian_regression(&mut rng, n, p, 0.2);
        let q = QuantisedData::from_f64(&x, &y, 1);
        let (xq, _) = q.dequantised();
        let (lmin, lmax) = float_ref::gram_spectrum(&xq);
        let nu = ((lmin + lmax) / 2.0).ceil() as u64;
        let params = crate::fhe::params::FvParams::custom_packed(256, 14, 44).unwrap();
        let ctx = FvContext::new(params);
        let keys = keygen(&ctx, &mut rng);
        let engine = NativeEngine::new(ctx.clone(), Arc::new(keys.rk.clone()))
            .with_galois_keys(Arc::new(keys.gk.clone()));
        let data = encrypt_dataset_packed(&ctx, &keys.pk, &q, &mut rng).unwrap();
        PackedSetup { ctx, keys, engine, data, q, nu }
    }

    #[test]
    fn packed_gd_equals_exact_simulation() {
        let s = setup_packed(311, 4, 2);
        let fit = super::fit(&s.engine, &DatasetRef::Packed(&s.data), &FitConfig::gd(2, s.nu))
            .unwrap()
            .fit;
        let dec = decrypt_coefficients(&s.ctx, &s.keys.sk, &fit);
        let expect = exact::gd_exact(&s.q, s.nu, 2).decode_last();
        let d = linf(&dec, &expect);
        assert!(d < 1e-9, "packed vs exact drift: {d} ({dec:?} vs {expect:?})");
        assert_eq!(fit.noise_depth, 3); // same 2K−1 depth as the scalar path
    }

    #[test]
    fn packed_gradient_budget_is_constant_in_n() {
        // The tentpole acceptance criterion: one packed gradient step
        // costs p+1 multiply pipelines (1 fused residual group + p
        // gradient products) and p·(log₂(d/2)+1) rotations — the
        // observation count n appears in neither, where the per-value
        // oracle pays n+p relinearisations (see
        // `gradient_step_relin_budget_is_n_plus_p`).
        let s = setup_packed(312, 6, 2);
        let p = s.data.p();
        let f1 = super::fit(&s.engine, &DatasetRef::Packed(&s.data), &FitConfig::gd(1, s.nu))
            .unwrap()
            .fit;
        let ring = &s.ctx.ring_q;
        let gs = GdScaling::new(s.data.phi, s.nu);
        let (r0, s0, rot0) =
            (ring.relin_count(), ring.scale_round_count(), ring.rotation_count());
        let g = gradient_step_packed(&s.engine, &s.data, &f1.betas, &gs.c_y(2)).unwrap();
        assert_eq!(g.len(), p);
        assert_eq!(ring.relin_count() - r0, (p + 1) as u64, "p+1 relins, n-free");
        assert_eq!(ring.scale_round_count() - s0, (p + 1) as u64, "p+1 scale-rounds");
        let log_rot = (s.ctx.d() / 2).trailing_zeros() as u64 + 1;
        assert_eq!(
            ring.rotation_count() - rot0,
            p as u64 * log_rot,
            "log₂(d/2)+1 rotations per coordinate"
        );
    }

    #[test]
    fn packed_fit_parity_across_backends_and_workers() {
        // The packed half of the satellite battery: the same packed
        // dataset and keys fitted on the full-RNS pipeline and the
        // exact-bigint oracle must decrypt identically, and each
        // backend must be bit-identical across worker budgets.
        let s = setup_packed(313, 4, 2);
        let rk = Arc::new(s.keys.rk.clone());
        let gk = Arc::new(s.keys.gk.clone());
        let cfg = FitConfig::gd(2, s.nu);
        let mut per_backend: Vec<Vec<crate::fhe::Plaintext>> = Vec::new();
        for backend in
            [crate::fhe::MulBackend::FullRns, crate::fhe::MulBackend::ExactBigint]
        {
            let reference =
                NativeEngine::with_backend(s.ctx.clone(), rk.clone(), backend)
                    .with_galois_keys(gk.clone())
                    .with_pool_workers(1);
            let fit_ref =
                super::fit(&reference, &DatasetRef::Packed(&s.data), &cfg).unwrap().fit;
            for workers in [2usize, 4] {
                let engine =
                    NativeEngine::with_backend(s.ctx.clone(), rk.clone(), backend)
                        .with_galois_keys(gk.clone())
                        .with_pool_workers(workers);
                let f =
                    super::fit(&engine, &DatasetRef::Packed(&s.data), &cfg).unwrap().fit;
                for (j, (a, b)) in f.betas.iter().zip(&fit_ref.betas).enumerate() {
                    assert_eq!(
                        a.polys, b.polys,
                        "{backend:?}: β_{j} differs at {workers} workers"
                    );
                }
            }
            per_backend.push(
                fit_ref.betas.iter().map(|b| s.ctx.decrypt(b, &s.keys.sk)).collect(),
            );
        }
        assert_eq!(
            per_backend[0], per_backend[1],
            "packed fits decrypt differently across multiply backends"
        );
    }

    #[test]
    fn packed_vwt_equals_exact() {
        let s = setup_packed(314, 4, 2);
        let cfg = FitConfig::gd(3, s.nu).with_accel(Accel::Vwt);
        let fit = super::fit(&s.engine, &DatasetRef::Packed(&s.data), &cfg).unwrap().fit;
        let dec = decrypt_coefficients(&s.ctx, &s.keys.sk, &fit);
        let (acc, div) = exact::vwt_exact(&s.q, s.nu, 3);
        let expect: Vec<f64> = acc
            .iter()
            .map(|b| crate::els::scaling::ratio_f64(b, &div))
            .collect();
        assert!(linf(&dec, &expect) < 1e-9);
        assert_eq!(fit.paper_mmd, 7); // 2K+1, same as the scalar path
    }

    #[test]
    fn packed_nag_equals_exact() {
        let s = setup_packed(315, 4, 2);
        let cfg = FitConfig::gd(2, s.nu).with_accel(Accel::Nag);
        let fit = super::fit(&s.engine, &DatasetRef::Packed(&s.data), &cfg).unwrap().fit;
        let dec = decrypt_coefficients(&s.ctx, &s.keys.sk, &fit);
        let expect = exact::nag_exact(&s.q, s.nu, 2).decode_last();
        assert!(linf(&dec, &expect) < 1e-9);
        assert_eq!(fit.paper_mmd, 6); // 3K
    }

    #[test]
    fn packed_fit_trace_is_well_formed_and_phase_complete() {
        // The acceptance-criteria trace: one packed GD fit must emit
        // every phase its pipeline is built from. Programmatic capture
        // (never the ELS_TRACE env var — tests must not mutate the
        // process environment) serialised against the other telemetry
        // tests by the session lock inside `Capture`.
        use crate::fhe::params::MulBackend;
        use crate::util::telemetry::{Capture, Phase};
        let s = setup_packed(317, 4, 2);
        let cap = Capture::begin();
        let fit = super::fit(&s.engine, &DatasetRef::Packed(&s.data), &FitConfig::gd(2, s.nu))
            .unwrap()
            .fit;
        let trace = cap.finish();
        assert_eq!(fit.betas.len(), 2);
        assert_eq!(trace.phase_count(Phase::DescentIteration), 2, "one span per iteration");
        for phase in [
            Phase::NttForward,
            Phase::NttInverse,
            Phase::ScaleRound,
            Phase::Relinearise,
            Phase::GaloisKeySwitch,
        ] {
            assert!(trace.phase_count(phase) > 0, "missing phase {}", phase.name());
        }
        // The RNS-only conversion phases appear iff that backend ran.
        let rns = s.ctx.params.mul_backend == MulBackend::FullRns;
        assert_eq!(trace.phase_count(Phase::BaseExtend) > 0, rns);
        assert_eq!(trace.phase_count(Phase::ShenoyConvert) > 0, rns);
        // And the export must be a valid Chrome trace document.
        let json = trace.to_chrome_json().to_string_json();
        let back = crate::util::json::Json::parse(&json).unwrap();
        let events = match back.get("traceEvents") {
            Some(crate::util::json::Json::Arr(a)) => a,
            _ => panic!("missing traceEvents"),
        };
        assert!(!events.is_empty());
    }

    #[test]
    fn fit_outcome_carries_per_fit_op_budget() {
        let s = setup(306, 5, 2, 2, Algo::Gd);
        let FitOutcome { fit, report } =
            super::fit(&s.engine, &DatasetRef::Scalar(&s.data), &FitConfig::gd(2, s.nu))
                .unwrap();
        let dec = decrypt_coefficients(&s.ctx, &s.keys.sk, &fit);
        let expect = exact::gd_exact(&s.q, s.nu, 2).decode_last();
        assert!(linf(&dec, &expect) < 1e-9);
        // 2 iterations × (n+p) fused pipelines, plus the β-carry and
        // c_y plain multiplies — the report must show real work.
        assert!(report.engine.ct_muls > 0, "ct_muls in the budget report");
        assert!(report.engine.plain_muls > 0, "plain_muls in the budget report");
        let relins: u64 = report.rings.iter().map(|r| r.relins).sum();
        assert!(relins >= (s.data.n() + s.data.p()) as u64, "at least one iteration of relins");
    }

    #[test]
    fn packed_fit_requires_rotation_capable_engine() {
        // A keyless engine must surface a descriptive error, not panic.
        let s = setup_packed(316, 4, 2);
        let keyless = NativeEngine::new(s.ctx.clone(), Arc::new(s.keys.rk.clone()));
        let err =
            super::fit(&keyless, &DatasetRef::Packed(&s.data), &FitConfig::gd(1, s.nu))
                .unwrap_err();
        assert!(err.to_string().contains("Galois keys"), "{err}");
    }
}
