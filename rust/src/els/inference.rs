//! Inference (§4.3): standard errors of the regression coefficients.
//!
//! Homomorphic matrix inversion for `V[β̂] = σ̂²(XᵀX)⁻¹` is intractable,
//! so the paper proposes the nonparametric bootstrap: resample rows
//! (resampling indices are public — they carry no information about the
//! data values) and refit. We provide the fast exact-simulation
//! bootstrap used for figures/examples, plus the closed-form OLS
//! standard errors as the reference the bootstrap is validated against.

use crate::fhe::rng::ChaChaRng;

use super::exact::{gd_exact, QuantisedData};
use super::float_ref::{self, linalg};

/// Closed-form OLS standard errors `√(σ̂²·diag((XᵀX)⁻¹))`.
pub fn ols_standard_errors(x: &[Vec<f64>], y: &[f64]) -> Vec<f64> {
    let n = x.len();
    let p = x[0].len();
    assert!(n > p, "need N > P for σ̂²");
    let beta = float_ref::ols(x, y);
    let resid: Vec<f64> = x
        .iter()
        .zip(y)
        .map(|(row, &yi)| yi - row.iter().zip(&beta).map(|(a, b)| a * b).sum::<f64>())
        .collect();
    let sigma2 = resid.iter().map(|r| r * r).sum::<f64>() / (n - p) as f64;
    // diag((XᵀX)⁻¹) via P solves against unit vectors.
    let g = linalg::gram(x);
    (0..p)
        .map(|j| {
            let mut e = vec![0.0; p];
            e[j] = 1.0;
            let col = linalg::solve(&g, &e);
            (sigma2 * col[j]).sqrt()
        })
        .collect()
}

/// Bootstrap standard errors via the exact encoded-domain GD (the
/// arithmetic the encrypted run performs). `reps` resamples, `iters`
/// GD iterations each.
pub fn bootstrap_se(
    data: &QuantisedData,
    nu: u64,
    iters: usize,
    reps: usize,
    rng: &mut ChaChaRng,
) -> Vec<f64> {
    let (n, p) = (data.n(), data.p());
    let mut fits: Vec<Vec<f64>> = Vec::with_capacity(reps);
    for _ in 0..reps {
        let idx: Vec<usize> =
            (0..n).map(|_| rng.uniform_below(n as u64) as usize).collect();
        let resampled = QuantisedData {
            x: idx.iter().map(|&i| data.x[i].clone()).collect(),
            y: idx.iter().map(|&i| data.y[i]).collect(),
            phi: data.phi,
        };
        fits.push(gd_exact(&resampled, nu, iters).decode_last());
    }
    (0..p)
        .map(|j| {
            let mean: f64 = fits.iter().map(|f| f[j]).sum::<f64>() / reps as f64;
            let var: f64 = fits.iter().map(|f| (f[j] - mean).powi(2)).sum::<f64>()
                / (reps - 1) as f64;
            var.sqrt()
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth;
    use crate::els::stepsize::nu_optimal;

    #[test]
    fn bootstrap_tracks_closed_form() {
        let mut rng = ChaChaRng::from_seed(241);
        let (x, y) = synth::gaussian_regression(&mut rng, 120, 3, 0.5);
        let q = QuantisedData::from_f64(&x, &y, 2);
        let (xq, yq) = q.dequantised();
        let closed = ols_standard_errors(&xq, &yq);
        let nu = nu_optimal(&xq);
        let boot = bootstrap_se(&q, nu, 40, 60, &mut rng);
        for j in 0..3 {
            let ratio = boot[j] / closed[j];
            assert!(
                (0.5..2.0).contains(&ratio),
                "bootstrap SE {} vs closed-form {} (j={j})",
                boot[j],
                closed[j]
            );
        }
    }

    #[test]
    fn se_positive_and_scale_with_noise() {
        let mut rng = ChaChaRng::from_seed(242);
        let (x, y_lo) = synth::gaussian_regression(&mut rng, 100, 2, 0.1);
        let se_lo = ols_standard_errors(&x, &y_lo);
        // Rebuild with larger noise on same X.
        let y_hi: Vec<f64> =
            y_lo.iter().map(|&v| v + 2.0 * rng.next_gaussian()).collect();
        let se_hi = ols_standard_errors(&x, &y_hi);
        for j in 0..2 {
            assert!(se_lo[j] > 0.0);
            assert!(se_hi[j] > se_lo[j]);
        }
    }
}
