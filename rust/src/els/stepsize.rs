//! Step-size selection (Lemma 1, §5.1, §7).
//!
//! The data holder — who sees the plaintext — chooses the integer
//! inverse step size ν = 1/δ before encryption:
//!
//! - optimal: `δ* = 2/(λ_max + λ_min)` of `XᵀX` (minimises the spectral
//!   radius of the iteration matrix), so `ν* = ⌈(λ_max + λ_min)/2⌉`;
//! - without an eigensolver: §7's bound `B(m) = ‖(XᵀX)^m‖^{1/m} ≥ S`,
//!   giving the safe choice `ν = ⌈B(m)⌉` (since `1/B ≤ 1/λ_max < 2/S`).
//! - preconditioned (§5.1): with standardised columns `D ≈ N·I`, the
//!   effective step is `δ/N` — equivalently scaling ν by N.

use super::float_ref::{gram_spectrum, spectral_bound};

/// Optimal integer ν from the exact spectrum.
pub fn nu_optimal(x: &[Vec<f64>]) -> u64 {
    let (lmin, lmax) = gram_spectrum(x);
    ((lmax + lmin) / 2.0).ceil().max(1.0) as u64
}

/// Safe ν from the §7 norm bound with power m.
pub fn nu_from_bound(x: &[Vec<f64>], m: u32) -> u64 {
    spectral_bound(x, m).ceil().max(1.0) as u64
}

/// A deliberately conservative (slow) ν — used by Figure 1 to show the
/// unpreconditioned zig-zag: step near the stability boundary of the
/// *largest* eigenvalue only.
pub fn nu_naive(x: &[Vec<f64>]) -> u64 {
    let (_, lmax) = gram_spectrum(x);
    (lmax / 1.9).ceil().max(1.0) as u64
}

/// Lemma 1 convergence check: δ = 1/ν must lie in (0, 2/S(XᵀX)).
pub fn converges(x: &[Vec<f64>], nu: u64) -> bool {
    let (_, lmax) = gram_spectrum(x);
    (nu as f64) > lmax / 2.0
}

/// Optimal spectral radius `S* = (λ_max − λ_min)/(λ_max + λ_min)`
/// (rate of linear convergence at δ*).
pub fn optimal_radius(x: &[Vec<f64>]) -> f64 {
    let (lmin, lmax) = gram_spectrum(x);
    (lmax - lmin) / (lmax + lmin)
}

/// Iterations needed to shrink the error by a factor e at the optimal
/// step (reciprocal average convergence rate; supplementary Figure 1).
pub fn iters_per_efold(x: &[Vec<f64>]) -> f64 {
    let r = optimal_radius(x);
    if r <= 0.0 {
        1.0
    } else {
        -1.0 / r.ln()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth;
    use crate::els::float_ref::{gd_path, ols, rms};
    use crate::fhe::rng::ChaChaRng;

    #[test]
    fn optimal_nu_converges_fast() {
        let mut rng = ChaChaRng::from_seed(221);
        let (x, y) = synth::gaussian_regression(&mut rng, 80, 4, 0.2);
        let nu = nu_optimal(&x);
        assert!(converges(&x, nu));
        let truth = ols(&x, &y);
        let path = gd_path(&x, &y, 1.0 / nu as f64, 200);
        assert!(rms(path.last().unwrap(), &truth) < 1e-6);
    }

    #[test]
    fn bound_nu_is_safe_but_slower() {
        let mut rng = ChaChaRng::from_seed(222);
        let (x, _) = synth::correlated_regression(&mut rng, 80, 4, 0.5, 0.2);
        let nu_b = nu_from_bound(&x, 4);
        let nu_o = nu_optimal(&x);
        assert!(nu_b >= nu_o, "bound-based step can only be smaller");
        assert!(converges(&x, nu_b));
    }

    #[test]
    fn efold_grows_with_correlation() {
        let mut rng = ChaChaRng::from_seed(223);
        let (x_lo, _) = synth::correlated_regression(&mut rng, 200, 5, 0.1, 0.2);
        let (x_hi, _) = synth::correlated_regression(&mut rng, 200, 5, 0.8, 0.2);
        assert!(iters_per_efold(&x_hi) > iters_per_efold(&x_lo));
    }
}
