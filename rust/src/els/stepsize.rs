//! Step-size selection (Lemma 1, §5.1, §7).
//!
//! The data holder — who sees the plaintext — chooses the integer
//! inverse step size ν = 1/δ before encryption:
//!
//! - optimal: `δ* = 2/(λ_max + λ_min)` of `XᵀX` (minimises the spectral
//!   radius of the iteration matrix), so `ν* = ⌈(λ_max + λ_min)/2⌉`;
//! - without an eigensolver: §7's bound `B(m) = ‖(XᵀX)^m‖^{1/m} ≥ S`,
//!   giving the safe choice `ν = ⌈B(m)⌉` (since `1/B ≤ 1/λ_max < 2/S`).
//! - preconditioned (§5.1): with standardised columns `D ≈ N·I`, the
//!   effective step is `δ/N` — equivalently scaling ν by N.

use super::float_ref::{gram_spectrum, spectral_bound};

/// Optimal integer ν from the exact spectrum.
pub fn nu_optimal(x: &[Vec<f64>]) -> u64 {
    let (lmin, lmax) = gram_spectrum(x);
    ((lmax + lmin) / 2.0).ceil().max(1.0) as u64
}

/// Safe ν from the §7 norm bound with power m.
pub fn nu_from_bound(x: &[Vec<f64>], m: u32) -> u64 {
    spectral_bound(x, m).ceil().max(1.0) as u64
}

/// A deliberately conservative (slow) ν — used by Figure 1 to show the
/// unpreconditioned zig-zag: step near the stability boundary of the
/// *largest* eigenvalue only.
pub fn nu_naive(x: &[Vec<f64>]) -> u64 {
    let (_, lmax) = gram_spectrum(x);
    (lmax / 1.9).ceil().max(1.0) as u64
}

/// Lemma 1 convergence check: δ = 1/ν must lie in (0, 2/S(XᵀX)).
pub fn converges(x: &[Vec<f64>], nu: u64) -> bool {
    let (_, lmax) = gram_spectrum(x);
    (nu as f64) > lmax / 2.0
}

/// Optimal spectral radius `S* = (λ_max − λ_min)/(λ_max + λ_min)`
/// (rate of linear convergence at δ*).
pub fn optimal_radius(x: &[Vec<f64>]) -> f64 {
    let (lmin, lmax) = gram_spectrum(x);
    (lmax - lmin) / (lmax + lmin)
}

/// Iterations needed to shrink the error by a factor e at the optimal
/// step (reciprocal average convergence rate; supplementary Figure 1).
pub fn iters_per_efold(x: &[Vec<f64>]) -> f64 {
    let r = optimal_radius(x);
    if r <= 0.0 {
        1.0
    } else {
        -1.0 / r.ln()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth;
    use crate::els::float_ref::{gd_path, ols, rms};
    use crate::fhe::rng::ChaChaRng;

    #[test]
    fn optimal_nu_converges_fast() {
        let mut rng = ChaChaRng::from_seed(221);
        let (x, y) = synth::gaussian_regression(&mut rng, 80, 4, 0.2);
        let nu = nu_optimal(&x);
        assert!(converges(&x, nu));
        let truth = ols(&x, &y);
        let path = gd_path(&x, &y, 1.0 / nu as f64, 200);
        assert!(rms(path.last().unwrap(), &truth) < 1e-6);
    }

    #[test]
    fn bound_nu_is_safe_but_slower() {
        let mut rng = ChaChaRng::from_seed(222);
        let (x, _) = synth::correlated_regression(&mut rng, 80, 4, 0.5, 0.2);
        let nu_b = nu_from_bound(&x, 4);
        let nu_o = nu_optimal(&x);
        assert!(nu_b >= nu_o, "bound-based step can only be smaller");
        assert!(converges(&x, nu_b));
    }

    #[test]
    fn nu_optimal_tracks_spectrum_property() {
        // Lemma 1 / §5.1: ν* = ⌈(λ_max + λ_min)/2⌉ — the integer must
        // bracket the spectral midpoint and always satisfy the Lemma-1
        // convergence condition δ = 1/ν < 2/λ_max.
        use crate::util::prop::PropRunner;
        let mut run = PropRunner::new("nu_optimal_bounds", 12);
        run.run(|rng| {
            let n = 20 + (rng.next_u64() % 60) as usize;
            let p = 2 + (rng.next_u64() % 4) as usize;
            let (x, _) = synth::gaussian_regression(rng, n, p, 0.3);
            let (lmin, lmax) = crate::els::float_ref::gram_spectrum(&x);
            let mid = (lmin + lmax) / 2.0;
            let nu = nu_optimal(&x);
            assert!(nu >= 1);
            assert!((nu as f64) >= mid && (nu as f64) < mid + 1.0, "ν = ⌈mid⌉");
            assert!(converges(&x, nu), "optimal ν must satisfy Lemma 1");
        });
    }

    #[test]
    fn planned_parameters_cover_nu_optimal_growth_property() {
        // §4.5 closes the loop: parameters planned for the data-holder's
        // ν must dominate the exact message growth of the run — the
        // plaintext modulus holds the tracked coefficient bound
        // symmetrically and the ring holds the degree bound.
        use crate::fhe::params::{plan, track_gd_growth, PlanRequest};
        use crate::util::prop::PropRunner;
        let mut run = PropRunner::new("nu_optimal_plan_bounds", 8);
        run.run(|rng| {
            let n = 6 + (rng.next_u64() % 20) as usize;
            let p = 2 + (rng.next_u64() % 3) as usize;
            let (x, _) = synth::gaussian_regression(rng, n, p, 0.2);
            let nu = nu_optimal(&x);
            let iters = 2;
            let params = plan(&PlanRequest::gd(n, p, iters, 2, nu)).unwrap();
            let g = track_gd_growth(n, p, iters, 2, nu);
            let t_need = g.coeff_bound.mul_u64(2).add_u64(1);
            assert!(
                params.t.cmp_big(&t_need) != std::cmp::Ordering::Less,
                "t must hold the §4.5 growth bound symmetrically"
            );
            assert!(params.d > g.deg_bound, "ring degree must hold the message degree");
            assert!(params.q_bits() > params.t.bit_len() + 40, "noise headroom");
        });
    }

    #[test]
    fn efold_grows_with_correlation() {
        let mut rng = ChaChaRng::from_seed(223);
        let (x_lo, _) = synth::correlated_regression(&mut rng, 200, 5, 0.1, 0.2);
        let (x_hi, _) = synth::correlated_regression(&mut rng, 200, 5, 0.8, 0.2);
        assert!(iters_per_efold(&x_hi) > iters_per_efold(&x_lo));
    }
}
