//! f64 reference implementations: dense linear algebra and the paper's
//! descent algorithms in exact real arithmetic.
//!
//! These serve three roles: (i) the OLS/RLS "truth" every error norm in
//! the figures is measured against, (ii) the fast backend for the
//! convergence figures (FHE is exact, so the encrypted iterates equal
//! these up to data quantisation — which we apply explicitly), and
//! (iii) the data-holder-side computations the paper assigns to the
//! plaintext domain (step size via spectral bounds, §7).

/// Dense column-major-free matrix helpers on `Vec<Vec<f64>>` (row major).
pub mod linalg {
    /// `Aᵀ`.
    pub fn transpose(a: &[Vec<f64>]) -> Vec<Vec<f64>> {
        if a.is_empty() {
            return Vec::new();
        }
        let (n, m) = (a.len(), a[0].len());
        let mut out = vec![vec![0.0; n]; m];
        for i in 0..n {
            for j in 0..m {
                out[j][i] = a[i][j];
            }
        }
        out
    }

    /// `A·v`.
    pub fn matvec(a: &[Vec<f64>], v: &[f64]) -> Vec<f64> {
        a.iter().map(|row| row.iter().zip(v).map(|(x, y)| x * y).sum()).collect()
    }

    /// `Aᵀ·v`.
    pub fn tmatvec(a: &[Vec<f64>], v: &[f64]) -> Vec<f64> {
        let m = if a.is_empty() { 0 } else { a[0].len() };
        let mut out = vec![0.0; m];
        for (row, &vi) in a.iter().zip(v) {
            for (j, &x) in row.iter().enumerate() {
                out[j] += x * vi;
            }
        }
        out
    }

    /// `AᵀA` (symmetric Gram matrix).
    pub fn gram(a: &[Vec<f64>]) -> Vec<Vec<f64>> {
        let m = if a.is_empty() { 0 } else { a[0].len() };
        let mut out = vec![vec![0.0; m]; m];
        for row in a {
            for j in 0..m {
                for k in j..m {
                    out[j][k] += row[j] * row[k];
                }
            }
        }
        for j in 0..m {
            for k in 0..j {
                out[j][k] = out[k][j];
            }
        }
        out
    }

    /// Solve `A·x = b` by Gauss–Jordan with partial pivoting.
    /// Panics on (numerically) singular systems.
    pub fn solve(a: &[Vec<f64>], b: &[f64]) -> Vec<f64> {
        let n = a.len();
        assert!(n > 0 && a[0].len() == n && b.len() == n);
        let mut m: Vec<Vec<f64>> = a
            .iter()
            .zip(b)
            .map(|(row, &bi)| {
                let mut r = row.clone();
                r.push(bi);
                r
            })
            .collect();
        for col in 0..n {
            // Pivot.
            let piv = (col..n)
                .max_by(|&i, &j| m[i][col].abs().partial_cmp(&m[j][col].abs()).unwrap())
                .unwrap();
            assert!(m[piv][col].abs() > 1e-12, "singular system");
            m.swap(col, piv);
            let diag = m[col][col];
            for x in m[col].iter_mut() {
                *x /= diag;
            }
            for row in 0..n {
                if row != col && m[row][col] != 0.0 {
                    let f = m[row][col];
                    for k in col..=n {
                        let v = m[col][k];
                        m[row][k] -= f * v;
                    }
                }
            }
        }
        m.into_iter().map(|row| row[n]).collect()
    }

    /// Eigenvalues of a symmetric matrix by the cyclic Jacobi method.
    /// Returns eigenvalues sorted ascending.
    pub fn eigvals_sym(a: &[Vec<f64>]) -> Vec<f64> {
        let n = a.len();
        let mut m: Vec<Vec<f64>> = a.to_vec();
        for _sweep in 0..100 {
            let mut off = 0.0;
            for i in 0..n {
                for j in i + 1..n {
                    off += m[i][j] * m[i][j];
                }
            }
            if off.sqrt() < 1e-12 {
                break;
            }
            for p in 0..n {
                for q in p + 1..n {
                    if m[p][q].abs() < 1e-300 {
                        continue;
                    }
                    let theta = (m[q][q] - m[p][p]) / (2.0 * m[p][q]);
                    let t = theta.signum() / (theta.abs() + (theta * theta + 1.0).sqrt());
                    let c = 1.0 / (t * t + 1.0).sqrt();
                    let s = t * c;
                    for k in 0..n {
                        let (mkp, mkq) = (m[k][p], m[k][q]);
                        m[k][p] = c * mkp - s * mkq;
                        m[k][q] = s * mkp + c * mkq;
                    }
                    for k in 0..n {
                        let (mpk, mqk) = (m[p][k], m[q][k]);
                        m[p][k] = c * mpk - s * mqk;
                        m[q][k] = s * mpk + c * mqk;
                    }
                }
            }
        }
        let mut ev: Vec<f64> = (0..n).map(|i| m[i][i]).collect();
        ev.sort_by(|a, b| a.partial_cmp(b).unwrap());
        ev
    }

    /// Cholesky factor L (lower) of a positive-definite matrix.
    pub fn cholesky(a: &[Vec<f64>]) -> Vec<Vec<f64>> {
        let n = a.len();
        let mut l = vec![vec![0.0; n]; n];
        for i in 0..n {
            for j in 0..=i {
                let mut s = a[i][j];
                for k in 0..j {
                    s -= l[i][k] * l[j][k];
                }
                if i == j {
                    assert!(s > 0.0, "matrix not positive definite");
                    l[i][j] = s.sqrt();
                } else {
                    l[i][j] = s / l[j][j];
                }
            }
        }
        l
    }
}

use linalg::*;

/// OLS: `β̂ = (XᵀX)⁻¹Xᵀy` via the normal equations.
pub fn ols(x: &[Vec<f64>], y: &[f64]) -> Vec<f64> {
    solve(&gram(x), &tmatvec(x, y))
}

/// Ridge: `β̂(α) = (XᵀX + αI)⁻¹Xᵀy`.
pub fn ridge(x: &[Vec<f64>], y: &[f64], alpha: f64) -> Vec<f64> {
    let mut g = gram(x);
    for (i, row) in g.iter_mut().enumerate() {
        row[i] += alpha;
    }
    solve(&g, &tmatvec(x, y))
}

/// Effective degrees of freedom `df(α) = tr(X(XᵀX+αI)⁻¹Xᵀ)`
/// = Σ λᵢ/(λᵢ+α) (paper Figure 8).
pub fn ridge_df(x: &[Vec<f64>], alpha: f64) -> f64 {
    eigvals_sym(&gram(x)).iter().map(|&l| l / (l + alpha)).sum()
}

/// Spectral extremes (λ_min, λ_max) of `XᵀX`.
pub fn gram_spectrum(x: &[Vec<f64>]) -> (f64, f64) {
    let ev = eigvals_sym(&gram(x));
    (ev[0], ev[ev.len() - 1])
}

/// The paper §7 data-holder bound `B(m) = ‖(XᵀX)^m‖^{1/m} ≥ S(XᵀX)`
/// (Frobenius norm; monotone non-increasing in m, → spectral radius).
pub fn spectral_bound(x: &[Vec<f64>], m: u32) -> f64 {
    assert!(m >= 1);
    let g = gram(x);
    let mut acc = g.clone();
    for _ in 1..m {
        // acc = acc · g
        let p = acc.len();
        let mut next = vec![vec![0.0; p]; p];
        for i in 0..p {
            for k in 0..p {
                let a = acc[i][k];
                if a != 0.0 {
                    for j in 0..p {
                        next[i][j] += a * g[k][j];
                    }
                }
            }
        }
        acc = next;
    }
    let frob: f64 = acc.iter().flatten().map(|v| v * v).sum::<f64>().sqrt();
    frob.powf(1.0 / m as f64)
}

/// Full GD iterate path: `β^[k] = β^[k-1] + δ·Xᵀ(y − Xβ^[k-1])`,
/// `β^[0] = 0`, returning `β^[1..=K]`.
pub fn gd_path(x: &[Vec<f64>], y: &[f64], delta: f64, iters: usize) -> Vec<Vec<f64>> {
    let p = x[0].len();
    let mut beta = vec![0.0; p];
    let mut out = Vec::with_capacity(iters);
    for _ in 0..iters {
        let r: Vec<f64> = matvec(x, &beta).iter().zip(y).map(|(f, &yi)| yi - f).collect();
        let g = tmatvec(x, &r);
        for j in 0..p {
            beta[j] += delta * g[j];
        }
        out.push(beta.clone());
    }
    out
}

/// Cyclic coordinate-descent path with the paper's fixed-step variant
/// (eq. 7): one coordinate per step, cycling 0..P. Returns the iterate
/// after every *individual coordinate update* (length `iters`).
pub fn cd_path(x: &[Vec<f64>], y: &[f64], delta: f64, steps: usize) -> Vec<Vec<f64>> {
    let p = x[0].len();
    let mut beta = vec![0.0; p];
    let mut out = Vec::with_capacity(steps);
    for u in 0..steps {
        let j = u % p;
        let r: Vec<f64> = matvec(x, &beta).iter().zip(y).map(|(f, &yi)| yi - f).collect();
        let gj: f64 = x.iter().zip(&r).map(|(row, &ri)| row[j] * ri).sum();
        beta[j] += delta * gj;
        out.push(beta.clone());
    }
    out
}

/// Nesterov momentum coefficients η_k < 0 for k = 1..=K
/// (λ₀ = 0, λ_k = (1+√(1+4λ_{k-1}²))/2, η_k = (1−λ_k)/λ_{k+1}).
pub fn nag_etas(iters: usize) -> Vec<f64> {
    let mut lambda = 0.0f64;
    let mut lambdas = Vec::with_capacity(iters + 2);
    lambdas.push(lambda);
    for _ in 0..=iters + 1 {
        lambda = (1.0 + (1.0 + 4.0 * lambda * lambda).sqrt()) / 2.0;
        lambdas.push(lambda);
    }
    (1..=iters).map(|k| (1.0 - lambdas[k]) / lambdas[k + 1]).collect()
}

/// NAG path (eqs. 19a/19b): returns `β^[1..=K]`.
///
/// Sign convention: we apply the *accelerating* Nesterov extrapolation
/// `β^[k] = s^[k] + |η_k|·(s^[k] − s^[k-1])` (equivalently Bubeck's
/// `x_{s+1} = (1−γ_s)y_{s+1} + γ_s·y_s` with γ_s = η_k < 0). The paper's
/// eq. (19b) as printed (`+η_k(s−s_prev)`, η_k < 0) reverses the
/// momentum and demonstrably decelerates; we follow Nesterov.
pub fn nag_path(x: &[Vec<f64>], y: &[f64], delta: f64, iters: usize) -> Vec<Vec<f64>> {
    let p = x[0].len();
    let etas = nag_etas(iters);
    let mut beta = vec![0.0; p];
    let mut s_prev = vec![0.0; p];
    let mut out = Vec::with_capacity(iters);
    for &eta in etas.iter() {
        let r: Vec<f64> = matvec(x, &beta).iter().zip(y).map(|(f, &yi)| yi - f).collect();
        let g = tmatvec(x, &r);
        let s: Vec<f64> = (0..p).map(|j| beta[j] + delta * g[j]).collect();
        let m = -eta; // momentum ≥ 0
        beta = (0..p).map(|j| s[j] + m * (s[j] - s_prev[j])).collect();
        s_prev = s;
        out.push(beta.clone());
    }
    out
}

/// Van Wijngaarden transformation (eq. 18) applied to a GD iterate path:
/// `β_vwt = 2^{-(K-k*)} Σ_{k=k*}^K C(K−k*, k−k*) β^[k]`, `k* = ⌊K/3⌋+1`.
pub fn vwt_estimate(path: &[Vec<f64>]) -> Vec<f64> {
    let k_total = path.len();
    assert!(k_total >= 1);
    let kstar = k_total / 3 + 1;
    let p = path[0].len();
    let m = k_total - kstar; // binomial order
    let mut acc = vec![0.0; p];
    // C(m, i) iteratively to avoid overflow for K ≲ 60.
    let mut coef = 1.0f64;
    for (i, beta) in path[kstar - 1..].iter().enumerate() {
        if i > 0 {
            coef = coef * (m - i + 1) as f64 / i as f64;
        }
        for j in 0..p {
            acc[j] += coef * beta[j];
        }
    }
    let norm = 2f64.powi(m as i32);
    acc.iter().map(|v| v / norm).collect()
}

/// RMS deviation between two coefficient vectors (the paper's error
/// norm w.r.t. OLS).
pub fn rms(a: &[f64], b: &[f64]) -> f64 {
    assert_eq!(a.len(), b.len());
    let s: f64 = a.iter().zip(b).map(|(x, y)| (x - y) * (x - y)).sum();
    (s / a.len() as f64).sqrt()
}

/// ∞-norm distance.
pub fn linf(a: &[f64], b: &[f64]) -> f64 {
    a.iter().zip(b).map(|(x, y)| (x - y).abs()).fold(0.0, f64::max)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth;
    use crate::fhe::rng::ChaChaRng;

    fn toy_data() -> (Vec<Vec<f64>>, Vec<f64>) {
        let mut rng = ChaChaRng::from_seed(71);
        synth::gaussian_regression(&mut rng, 60, 4, 0.1)
    }

    #[test]
    fn solve_known_system() {
        let a = vec![vec![2.0, 1.0], vec![1.0, 3.0]];
        let x = linalg::solve(&a, &[5.0, 10.0]);
        assert!((x[0] - 1.0).abs() < 1e-12);
        assert!((x[1] - 3.0).abs() < 1e-12);
    }

    #[test]
    fn ols_recovers_exact_fit() {
        // y exactly linear -> OLS must recover coefficients.
        let x = vec![
            vec![1.0, 0.0],
            vec![0.0, 1.0],
            vec![1.0, 1.0],
            vec![2.0, -1.0],
        ];
        let beta_true = [3.0, -2.0];
        let y: Vec<f64> = x.iter().map(|r| r[0] * 3.0 + r[1] * -2.0).collect();
        let b = ols(&x, &y);
        assert!(linf(&b, &beta_true) < 1e-10);
    }

    #[test]
    fn eigvals_of_diagonal() {
        let a = vec![
            vec![3.0, 0.0, 0.0],
            vec![0.0, 1.0, 0.0],
            vec![0.0, 0.0, 2.0],
        ];
        let ev = linalg::eigvals_sym(&a);
        assert!((ev[0] - 1.0).abs() < 1e-10);
        assert!((ev[1] - 2.0).abs() < 1e-10);
        assert!((ev[2] - 3.0).abs() < 1e-10);
    }

    #[test]
    fn eigvals_match_trace_and_det_2x2() {
        let a = vec![vec![4.0, 1.0], vec![1.0, 3.0]];
        let ev = linalg::eigvals_sym(&a);
        assert!((ev[0] + ev[1] - 7.0).abs() < 1e-10, "trace");
        assert!((ev[0] * ev[1] - 11.0).abs() < 1e-9, "det");
    }

    #[test]
    fn cholesky_reconstructs() {
        let a = vec![vec![4.0, 2.0], vec![2.0, 3.0]];
        let l = linalg::cholesky(&a);
        // L·Lᵀ == A
        for i in 0..2 {
            for j in 0..2 {
                let v: f64 = (0..2).map(|k| l[i][k] * l[j][k]).sum();
                assert!((v - a[i][j]).abs() < 1e-12);
            }
        }
    }

    #[test]
    fn gd_converges_to_ols() {
        let (x, y) = toy_data();
        let truth = ols(&x, &y);
        let (lmin, lmax) = gram_spectrum(&x);
        let delta = 2.0 / (lmin + lmax);
        let path = gd_path(&x, &y, delta, 400);
        assert!(rms(path.last().unwrap(), &truth) < 1e-8);
        // Lemma 1: any δ ∈ (0, 2/S) converges; δ beyond diverges.
        let bad = gd_path(&x, &y, 2.2 / lmax, 200);
        assert!(rms(bad.last().unwrap(), &truth) > 1.0, "should diverge");
    }

    #[test]
    fn cd_converges_to_ols() {
        let (x, y) = toy_data();
        let truth = ols(&x, &y);
        let (lmin, lmax) = gram_spectrum(&x);
        let path = cd_path(&x, &y, 2.0 / (lmin + lmax), 4 * 400);
        assert!(rms(path.last().unwrap(), &truth) < 1e-6);
    }

    #[test]
    fn nag_beats_gd_at_fixed_iters() {
        let mut rng = ChaChaRng::from_seed(72);
        let (x, y) = synth::correlated_regression(&mut rng, 100, 5, 0.7, 0.1);
        let truth = ols(&x, &y);
        // NAG's guarantees are for δ = 1/L; compare both methods there.
        let (_, lmax) = gram_spectrum(&x);
        let delta = 1.0 / lmax;
        let k = 25;
        let gd = gd_path(&x, &y, delta, k);
        let nag = nag_path(&x, &y, delta, k);
        let e_gd = rms(gd.last().unwrap(), &truth);
        let e_nag = rms(nag.last().unwrap(), &truth);
        assert!(
            e_nag < e_gd,
            "unencrypted NAG should beat GD (paper §5.3): {e_nag} vs {e_gd}"
        );
    }

    #[test]
    fn vwt_accelerates_gd() {
        // Figure 2 right: VWT/GD error ratio < 1.
        let mut rng = ChaChaRng::from_seed(73);
        let (x, y) = synth::correlated_regression(&mut rng, 100, 5, 0.1, 0.1);
        let truth = ols(&x, &y);
        // VWT damps the oscillatory mode (Lemma 2): with an aggressive
        // step the dominant eigen-component alternates in sign and the
        // binomial averaging annihilates it (ratio ≪ 1, paper Fig 2R).
        let (_, lmax) = gram_spectrum(&x);
        let path = gd_path(&x, &y, 1.9 / lmax, 10);
        let vwt = vwt_estimate(&path);
        let e_vwt = rms(&vwt, &truth);
        let e_gd = rms(path.last().unwrap(), &truth);
        assert!(e_vwt < e_gd, "VWT {e_vwt} should beat GD {e_gd}");
    }

    #[test]
    fn nag_etas_negative_decreasing() {
        let etas = nag_etas(10);
        assert_eq!(etas.len(), 10);
        assert!(etas[0].abs() < 1e-12, "η₁ = 0");
        for w in etas.windows(2).skip(1) {
            assert!(w[1] < w[0], "η decreasing (more momentum)");
        }
        assert!(etas.iter().all(|&e| e <= 0.0), "η_k ≤ 0 (paper eq. 19b)");
    }

    #[test]
    fn spectral_bound_upper_bounds_radius() {
        let (x, _) = toy_data();
        let (_, lmax) = gram_spectrum(&x);
        let mut prev = f64::INFINITY;
        for m in [1u32, 2, 4, 8] {
            let b = spectral_bound(&x, m);
            assert!(b >= lmax - 1e-6, "B({m}) ≥ S");
            assert!(b <= prev + 1e-9, "B(m) non-increasing");
            prev = b;
        }
        // §7: B(m) → S(XᵀX)
        assert!((spectral_bound(&x, 16) - lmax) / lmax < 0.2);
    }

    #[test]
    fn ridge_shrinks_norm_and_df() {
        let (x, y) = toy_data();
        let b0 = ridge(&x, &y, 0.0);
        let b30 = ridge(&x, &y, 30.0);
        let n0: f64 = b0.iter().map(|v| v * v).sum();
        let n30: f64 = b30.iter().map(|v| v * v).sum();
        assert!(n30 < n0, "ridge shrinks");
        assert!((ridge_df(&x, 0.0) - 4.0).abs() < 1e-9, "df(0) = P");
        assert!(ridge_df(&x, 30.0) < 4.0);
    }
}
