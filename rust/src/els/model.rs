//! Encrypted regression datasets, in two layouts:
//!
//! - [`EncryptedDataset`] — per-value FV ciphertexts of the quantised
//!   design matrix and response (the paper's data layout — one
//!   ciphertext per number).
//! - [`PackedDataset`] — CRT slot packing: one ciphertext per
//!   covariate column, holding all `n ≤ d` observations slot-wise
//!   (requires a [`Encoding::Packed`] context). The packed descent
//!   loop replaces the `O(n)` per-observation multiply pipelines with
//!   `O(1)` slot-wise multiplies plus `O(log d)` rotations.

use crate::fhe::encoding::{encode_int, Encoder};
use crate::fhe::params::Encoding;
use crate::fhe::rng::ChaChaRng;
use crate::fhe::{Ciphertext, FvContext, PublicKey};
use crate::util::error::Result;

use super::exact::QuantisedData;

/// Encrypted `(X̃, ỹ)`.
#[derive(Clone)]
pub struct EncryptedDataset {
    /// `x[i][j]` encrypts `X̃_ij`.
    pub x: Vec<Vec<Ciphertext>>,
    /// `y[i]` encrypts `ỹ_i`.
    pub y: Vec<Ciphertext>,
    /// Quantisation exponent φ.
    pub phi: u32,
}

impl EncryptedDataset {
    pub fn n(&self) -> usize {
        self.x.len()
    }

    pub fn p(&self) -> usize {
        self.x.first().map_or(0, |r| r.len())
    }

    /// Total ciphertext bytes (the paper's Figure-5 memory metric).
    pub fn size_bytes(&self) -> usize {
        self.x
            .iter()
            .flatten()
            .chain(self.y.iter())
            .map(|c| c.size_bytes())
            .sum()
    }
}

/// Encrypt a quantised dataset under a public key (data-holder side).
pub fn encrypt_dataset(
    ctx: &FvContext,
    pk: &PublicKey,
    data: &QuantisedData,
    rng: &mut ChaChaRng,
) -> EncryptedDataset {
    let d = ctx.d();
    let x = data
        .x
        .iter()
        .map(|row| {
            row.iter()
                .map(|&v| ctx.encrypt(&encode_int(v, d), pk, rng))
                .collect()
        })
        .collect();
    let y = data
        .y
        .iter()
        .map(|&v| ctx.encrypt(&encode_int(v, d), pk, rng))
        .collect();
    EncryptedDataset { x, y, phi: data.phi }
}

/// Slot-packed encrypted `(X̃, ỹ)`: ciphertext `x_cols[j]` holds column
/// `j` of the design matrix with observation `i` in slot `i` (slots
/// `n..d` are zero and stay zero through the descent algebra), and `y`
/// holds the response the same way.
pub struct PackedDataset {
    /// `x_cols[j]` encrypts `(X̃_0j, …, X̃_{n−1,j}, 0, …)` slot-wise.
    pub x_cols: Vec<Ciphertext>,
    /// Slot-packed response `(ỹ_0, …, ỹ_{n−1}, 0, …)`.
    pub y: Ciphertext,
    /// Observation count (`≤ d`).
    pub n: usize,
    /// Quantisation exponent φ.
    pub phi: u32,
}

impl PackedDataset {
    pub fn n(&self) -> usize {
        self.n
    }

    pub fn p(&self) -> usize {
        self.x_cols.len()
    }

    /// Total ciphertext bytes — `p + 1` ciphertexts regardless of `n`,
    /// versus the per-value layout's `n·(p + 1)`.
    pub fn size_bytes(&self) -> usize {
        self.x_cols.iter().chain(std::iter::once(&self.y)).map(|c| c.size_bytes()).sum()
    }
}

/// Pack-and-encrypt one slot vector per column (data-holder side).
/// Each inner vector is one ciphertext's slot contents; shorter
/// vectors are zero-padded to `d` slots by the encoder.
pub fn encrypt_packed_columns(
    ctx: &FvContext,
    pk: &PublicKey,
    cols: &[Vec<i64>],
    rng: &mut ChaChaRng,
) -> Result<Vec<Ciphertext>> {
    if ctx.params.encoding != Encoding::Packed {
        crate::bail!("slot packing needs a packed context (FvParams::custom_packed)");
    }
    let slots = ctx.params.slot_count();
    if let Some(over) = cols.iter().find(|c| c.len() > slots) {
        crate::bail!(
            "cannot pack {} values into {} slots (d = {})",
            over.len(),
            slots,
            ctx.d()
        );
    }
    Ok(cols.iter().map(|c| ctx.encrypt(&ctx.encoder().encode_vec(c), pk, rng)).collect())
}

/// Encrypt a quantised dataset column-packed (data-holder side):
/// `p + 1` ciphertexts total. Fails on scalar contexts and on
/// `n > d` (pack more observations than slots).
pub fn encrypt_dataset_packed(
    ctx: &FvContext,
    pk: &PublicKey,
    data: &QuantisedData,
    rng: &mut ChaChaRng,
) -> Result<PackedDataset> {
    let (n, p) = (data.n(), data.p());
    let cols: Vec<Vec<i64>> =
        (0..p).map(|j| data.x.iter().map(|row| row[j]).collect()).collect();
    let mut cts = encrypt_packed_columns(ctx, pk, &cols, rng)?;
    cts.extend(encrypt_packed_columns(ctx, pk, std::slice::from_ref(&data.y), rng)?);
    let y = cts.pop().unwrap();
    Ok(PackedDataset { x_cols: cts, y, n, phi: data.phi })
}

/// Ridge (§4.4): augment the *quantised* data with `⌊10^φ·√α⌉·e_j` rows
/// and zero responses, then encrypt. OLS on the augmented ciphertexts
/// equals RLS on the original data (eq. 14).
pub fn quantise_ridge_augmented(
    x: &[Vec<f64>],
    y: &[f64],
    alpha: f64,
    phi: u32,
) -> QuantisedData {
    let (xa, ya) = crate::data::standardise::ridge_augment(x, y, alpha);
    QuantisedData::from_f64(&xa, &ya, phi)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fhe::keys::keygen;
    use crate::fhe::params::FvParams;

    #[test]
    fn dataset_shapes_and_decryption() {
        let ctx = FvContext::new(FvParams::custom(256, 3, 24));
        let mut rng = ChaChaRng::from_seed(211);
        let keys = keygen(&ctx, &mut rng);
        let q = QuantisedData {
            x: vec![vec![123, -45], vec![-7, 89]],
            y: vec![100, -200],
            phi: 2,
        };
        let enc = encrypt_dataset(&ctx, &keys.pk, &q, &mut rng);
        assert_eq!(enc.n(), 2);
        assert_eq!(enc.p(), 2);
        assert!(enc.size_bytes() > 0);
        let pt = ctx.decrypt(&enc.x[0][1], &keys.sk);
        assert_eq!(pt.eval_at_2().to_i128(), Some(-45));
        let pt = ctx.decrypt(&enc.y[1], &keys.sk);
        assert_eq!(pt.eval_at_2().to_i128(), Some(-200));
    }

    #[test]
    fn packed_dataset_shapes_and_slot_decryption() {
        let ctx = FvContext::new(FvParams::custom_packed(256, 3, 24).unwrap());
        let mut rng = ChaChaRng::from_seed(212);
        let keys = keygen(&ctx, &mut rng);
        let q = QuantisedData {
            x: vec![vec![123, -45], vec![-7, 89]],
            y: vec![100, -200],
            phi: 2,
        };
        let enc = encrypt_dataset_packed(&ctx, &keys.pk, &q, &mut rng).unwrap();
        assert_eq!(enc.n(), 2);
        assert_eq!(enc.p(), 2);
        assert!(enc.size_bytes() > 0);
        // Column 1 packs (X̃_01, X̃_11, 0, …) slot-wise.
        let slots = ctx.encoder().decode_vec(&ctx.decrypt(&enc.x_cols[1], &keys.sk), ctx.d());
        assert_eq!(slots[0].to_i128(), Some(-45));
        assert_eq!(slots[1].to_i128(), Some(89));
        assert!(slots[2..].iter().all(|v| v.is_zero()), "padding slots are zero");
        let ys = ctx.encoder().decode_vec(&ctx.decrypt(&enc.y, &keys.sk), ctx.d());
        assert_eq!(ys[1].to_i128(), Some(-200));
    }

    #[test]
    fn packed_encrypt_rejects_scalar_context_and_overflow() {
        let q = QuantisedData { x: vec![vec![1]], y: vec![2], phi: 0 };
        let sctx = FvContext::new(FvParams::custom(256, 3, 24));
        let mut rng = ChaChaRng::from_seed(213);
        let keys = keygen(&sctx, &mut rng);
        let err = encrypt_dataset_packed(&sctx, &keys.pk, &q, &mut rng).unwrap_err();
        assert!(err.to_string().contains("packed context"), "{err}");
        // More observations than slots.
        let pctx = FvContext::new(FvParams::custom_packed(256, 3, 24).unwrap());
        let pkeys = keygen(&pctx, &mut rng);
        let d = pctx.d();
        let big = QuantisedData {
            x: (0..d + 1).map(|_| vec![1i64]).collect(),
            y: vec![0; d + 1],
            phi: 0,
        };
        let err = encrypt_dataset_packed(&pctx, &pkeys.pk, &big, &mut rng).unwrap_err();
        assert!(err.to_string().contains("slots"), "{err}");
    }

    #[test]
    fn ridge_augmentation_rows() {
        let x = vec![vec![1.0, 2.0], vec![3.0, 4.0]];
        let y = vec![0.5, -0.5];
        let q = quantise_ridge_augmented(&x, &y, 9.0, 2);
        assert_eq!(q.n(), 4); // N + P rows
        assert_eq!(q.x[2], vec![300, 0]); // √9·10² = 300
        assert_eq!(q.x[3], vec![0, 300]);
        assert_eq!(q.y[2], 0);
    }
}
