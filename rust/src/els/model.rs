//! Encrypted regression datasets: per-value FV ciphertexts of the
//! quantised design matrix and response (the paper's data layout — one
//! ciphertext per number).

use crate::fhe::encoding::encode_int;
use crate::fhe::rng::ChaChaRng;
use crate::fhe::{Ciphertext, FvContext, PublicKey};

use super::exact::QuantisedData;

/// Encrypted `(X̃, ỹ)`.
pub struct EncryptedDataset {
    /// `x[i][j]` encrypts `X̃_ij`.
    pub x: Vec<Vec<Ciphertext>>,
    /// `y[i]` encrypts `ỹ_i`.
    pub y: Vec<Ciphertext>,
    /// Quantisation exponent φ.
    pub phi: u32,
}

impl EncryptedDataset {
    pub fn n(&self) -> usize {
        self.x.len()
    }

    pub fn p(&self) -> usize {
        self.x.first().map_or(0, |r| r.len())
    }

    /// Total ciphertext bytes (the paper's Figure-5 memory metric).
    pub fn size_bytes(&self) -> usize {
        self.x
            .iter()
            .flatten()
            .chain(self.y.iter())
            .map(|c| c.size_bytes())
            .sum()
    }
}

/// Encrypt a quantised dataset under a public key (data-holder side).
pub fn encrypt_dataset(
    ctx: &FvContext,
    pk: &PublicKey,
    data: &QuantisedData,
    rng: &mut ChaChaRng,
) -> EncryptedDataset {
    let d = ctx.d();
    let x = data
        .x
        .iter()
        .map(|row| {
            row.iter()
                .map(|&v| ctx.encrypt(&encode_int(v, d), pk, rng))
                .collect()
        })
        .collect();
    let y = data
        .y
        .iter()
        .map(|&v| ctx.encrypt(&encode_int(v, d), pk, rng))
        .collect();
    EncryptedDataset { x, y, phi: data.phi }
}

/// Ridge (§4.4): augment the *quantised* data with `⌊10^φ·√α⌉·e_j` rows
/// and zero responses, then encrypt. OLS on the augmented ciphertexts
/// equals RLS on the original data (eq. 14).
pub fn quantise_ridge_augmented(
    x: &[Vec<f64>],
    y: &[f64],
    alpha: f64,
    phi: u32,
) -> QuantisedData {
    let (xa, ya) = crate::data::standardise::ridge_augment(x, y, alpha);
    QuantisedData::from_f64(&xa, &ya, phi)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fhe::keys::keygen;
    use crate::fhe::params::FvParams;

    #[test]
    fn dataset_shapes_and_decryption() {
        let ctx = FvContext::new(FvParams::custom(256, 3, 24));
        let mut rng = ChaChaRng::from_seed(211);
        let keys = keygen(&ctx, &mut rng);
        let q = QuantisedData {
            x: vec![vec![123, -45], vec![-7, 89]],
            y: vec![100, -200],
            phi: 2,
        };
        let enc = encrypt_dataset(&ctx, &keys.pk, &q, &mut rng);
        assert_eq!(enc.n(), 2);
        assert_eq!(enc.p(), 2);
        assert!(enc.size_bytes() > 0);
        let pt = ctx.decrypt(&enc.x[0][1], &keys.sk);
        assert_eq!(pt.eval_at_2().to_i128(), Some(-45));
        let pt = ctx.decrypt(&enc.y[1], &keys.sk);
        assert_eq!(pt.eval_at_2().to_i128(), Some(-200));
    }

    #[test]
    fn ridge_augmentation_rows() {
        let x = vec![vec![1.0, 2.0], vec![3.0, 4.0]];
        let y = vec![0.5, -0.5];
        let q = quantise_ridge_augmented(&x, &y, 9.0, 2);
        assert_eq!(q.n(), 4); // N + P rows
        assert_eq!(q.x[2], vec![300, 0]); // √9·10² = 300
        assert_eq!(q.x[3], vec![0, 300]);
        assert_eq!(q.y[2], 0);
    }
}
