//! Maximum Multiplicative Depth accounting (paper Table 1 and §4.1).
//!
//! Two notions are tracked and deliberately kept distinct:
//!
//! - **Paper MMD** (Table 1): GD/preconditioned-GD `2K`, GD-VWT `2K+1`,
//!   NAG `3K`, CD `2KP`. This is the complexity *proxy* every
//!   complexity-fair figure (2, 4) is plotted against. The paper's
//!   accounting charges NAG's acceleration step one level per
//!   iteration (its constants are encoded polynomials whose products
//!   deepen the evaluated polynomial — footnote 1's degree-based
//!   definition).
//! - **Noise depth**: ciphertext×ciphertext multiplications on the
//!   critical path, which is what actually consumes FV noise budget
//!   (plaintext-constant multiplications grow noise additively, not
//!   multiplicatively). GD/NAG: `2K − 1`; CD: `2U − 1` for U updates.
//!   The parameter planner sizes `q` by this number (plus slack).

use super::encrypted::Accel;

/// Paper Table-1 MMD for `iters` iterations of each algorithm.
pub fn paper_mmd(accel: Accel, iters: usize) -> u32 {
    match accel {
        Accel::None => 2 * iters as u32,
        Accel::Vwt => 2 * iters as u32 + 1,
        Accel::Nag => 3 * iters as u32,
    }
}

/// Paper MMD for coordinate descent: `2·K·P` (K full sweeps over P
/// coordinates) — the scalability contrast at the heart of §4.1.
pub fn paper_mmd_cd(sweeps: usize, p_vars: usize) -> u32 {
    2 * sweeps as u32 * p_vars as u32
}

/// Ciphertext-multiplication (noise) depth actually consumed by the
/// critical path of `iters` GD/NAG iterations.
pub fn noise_depth(iters: usize) -> u32 {
    if iters == 0 {
        0
    } else {
        2 * iters as u32 - 1
    }
}

/// Noise depth of `updates` CD coordinate updates.
pub fn noise_depth_cd(updates: usize) -> u32 {
    if updates == 0 {
        0
    } else {
        2 * updates as u32 - 1
    }
}

/// Smallest iteration count whose paper MMD does not exceed `budget` —
/// used to compare algorithms at *fixed encrypted cost* (Figures 2, 4).
pub fn iters_within_mmd(accel: Accel, budget: u32) -> usize {
    match accel {
        Accel::None => (budget / 2) as usize,
        Accel::Vwt => (budget.saturating_sub(1) / 2) as usize,
        Accel::Nag => (budget / 3) as usize,
    }
}

/// CD coordinate updates affordable within an MMD budget.
pub fn cd_updates_within_mmd(budget: u32) -> usize {
    (budget / 2) as usize
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table_1_values() {
        // The exact rows of paper Table 1.
        for k in 1..=20 {
            assert_eq!(paper_mmd(Accel::None, k), 2 * k as u32);
            assert_eq!(paper_mmd(Accel::Vwt, k), 2 * k as u32 + 1);
            assert_eq!(paper_mmd(Accel::Nag, k), 3 * k as u32);
        }
        assert_eq!(paper_mmd_cd(3, 5), 30);
    }

    #[test]
    fn cd_grows_with_p_gd_does_not() {
        // §4.1.2's key scalability claim.
        let gd_small = paper_mmd(Accel::None, 10);
        let gd_large = paper_mmd(Accel::None, 10);
        assert_eq!(gd_small, gd_large, "GD MMD independent of P");
        assert!(paper_mmd_cd(10, 50) == 10 * paper_mmd_cd(10, 5));
    }

    #[test]
    fn fixed_budget_iterations() {
        // At MMD 12: GD affords 6 iterations, NAG only 4, VWT 5.
        assert_eq!(iters_within_mmd(Accel::None, 12), 6);
        assert_eq!(iters_within_mmd(Accel::Nag, 12), 4);
        assert_eq!(iters_within_mmd(Accel::Vwt, 12), 5);
        assert_eq!(cd_updates_within_mmd(12), 6);
    }

    #[test]
    fn noise_depth_below_paper_mmd() {
        for k in 1..=10 {
            assert!(noise_depth(k) <= paper_mmd(Accel::None, k));
            assert!(noise_depth_cd(k * 3) <= paper_mmd_cd(k, 3));
        }
    }
}
