//! Noise-trajectory probe: measured invariant-noise budget vs the
//! §4.5 planner's predicted floor, per descent iteration.
//!
//! **Trust model**: this is a *diagnostic*, exactly like
//! [`fhe::noise`](crate::fhe::noise) which it builds on — it holds the
//! secret key, so it runs on the key holder's side (or in tests),
//! never inside the evaluating server. It exists to make the paper's
//! correctness argument *observable*: decryption is exact only while
//! invariant noise stays under `q/2` (budget > 0), and the planner
//! sizes `q` so the whole descent stays above a predicted floor. The
//! probe replays a kept iterate path and records both numbers side by
//! side, so a planner regression (or an unexpectedly noisy pipeline)
//! shows up as a crossed trajectory instead of a corrupted decrypt
//! three PRs later.

use crate::els::encrypted::EncryptedFit;
use crate::fhe::noise::noise_budget_bits;
use crate::fhe::params::{per_level_noise_bits, FvParams, PlanRequest};
use crate::fhe::{FvContext, SecretKey};
use crate::util::error::{anyhow, Result};
use crate::util::json::Json;

/// One descent iteration's noise observation.
#[derive(Clone, Debug)]
pub struct NoisePoint {
    /// Iteration number k (1-based).
    pub iteration: usize,
    /// Ciphertext-multiplication depth of the deepest iterate at k.
    pub depth: u32,
    /// Worst (minimum) measured budget over the iterate's coordinates.
    pub measured_bits: f64,
    /// The planner's predicted budget floor at this depth.
    pub predicted_floor_bits: f64,
}

/// A fit's full noise trajectory.
#[derive(Clone, Debug)]
pub struct NoiseTrajectory {
    pub points: Vec<NoisePoint>,
    /// `log2(q)` context the budgets are relative to.
    pub q_bits: usize,
}

/// The §4.5 planner's predicted budget floor for a ciphertext at
/// multiplication depth `depth`, mirrored from [`plan`]'s noise model:
/// a fresh encryption spends `t_bits + log2(d) + σ_bits + 7` bits, and
/// every multiplication level spends
/// [`per_level_noise_bits`] more. Conservative by construction — the
/// planner additionally reserves a 40-bit safety margin, so measured
/// budgets should sit well above this line.
///
/// [`plan`]: crate::fhe::params::plan
pub fn predicted_floor_bits(params: &FvParams, req: &PlanRequest, depth: u32) -> f64 {
    let growth = req.growth();
    let t_bits = params.t.bit_len();
    let log_d = params.d.trailing_zeros() as usize;
    let sigma_bits = 2; // σ ≈ 3.2, as in the planner
    let const_bits = 64 - (growth.max_const_l1.max(1) - 1).leading_zeros() as usize;
    let fresh_bits = t_bits + log_d + sigma_bits + 7;
    let per_level = per_level_noise_bits(t_bits, params.d, const_bits);
    let q_bits = params.q_bits();
    q_bits as f64 - 1.0 - fresh_bits as f64 - depth as f64 * per_level as f64
}

/// Replay a kept iterate path and measure the worst per-coordinate
/// invariant-noise budget at every iteration, against the planner's
/// predicted floor for the iterate's recorded depth. Requires a fit
/// run with `keep_path` (or VWT); `req` must be the plan request the
/// context was built from.
pub fn noise_trajectory(
    ctx: &FvContext,
    sk: &SecretKey,
    fit: &EncryptedFit,
    req: &PlanRequest,
) -> Result<NoiseTrajectory> {
    let path = fit
        .path
        .as_ref()
        .ok_or_else(|| anyhow!("noise_trajectory needs a fit with keep_path = true"))?;
    let points = path
        .iter()
        .enumerate()
        .map(|(i, betas)| {
            let depth = betas.iter().map(|b| b.ct_depth).max().unwrap_or(0);
            let measured = betas
                .iter()
                .map(|b| noise_budget_bits(ctx, b, sk))
                .fold(f64::INFINITY, f64::min);
            NoisePoint {
                iteration: i + 1,
                depth,
                measured_bits: measured,
                predicted_floor_bits: predicted_floor_bits(&ctx.params, req, depth),
            }
        })
        .collect();
    Ok(NoiseTrajectory { points, q_bits: ctx.q.bit_len() })
}

impl NoiseTrajectory {
    /// Does every iteration's measured budget sit on or above the
    /// planner's floor? (The planner-conservativeness invariant.)
    pub fn is_conservative(&self) -> bool {
        self.points.iter().all(|p| p.measured_bits >= p.predicted_floor_bits)
    }

    /// Deterministic JSON export (schema `els-noise-trajectory-v1`).
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("schema", Json::str("els-noise-trajectory-v1")),
            ("q_bits", Json::Num(self.q_bits as f64)),
            (
                "points",
                Json::Arr(
                    self.points
                        .iter()
                        .map(|p| {
                            Json::obj(vec![
                                ("iteration", Json::Num(p.iteration as f64)),
                                ("depth", Json::Num(p.depth as f64)),
                                ("measured_bits", Json::Num(p.measured_bits)),
                                (
                                    "predicted_floor_bits",
                                    Json::Num(p.predicted_floor_bits),
                                ),
                            ])
                        })
                        .collect(),
                ),
            ),
        ])
    }
}

#[cfg(test)]
mod tests {
    use std::sync::Arc;

    use super::*;
    use crate::data::synth;
    use crate::els::encrypted::{decrypt_coefficients, fit, DatasetRef, FitConfig};
    use crate::els::exact::{self, QuantisedData};
    use crate::els::float_ref::linf;
    use crate::els::model::encrypt_dataset;
    use crate::fhe::keys::keygen;
    use crate::fhe::params::plan;
    use crate::fhe::rng::ChaChaRng;
    use crate::fhe::FvContext;
    use crate::runtime::backend::NativeEngine;

    #[test]
    fn planner_floor_is_conservative_along_a_gd_trajectory() {
        // The acceptance-criteria invariant: at every iteration of a
        // planned GD fit, the measured budget must not fall below the
        // §4.5 predicted floor (the planner carries a 40-bit margin on
        // top of the floor, so a crossing means the noise model broke).
        let mut rng = ChaChaRng::from_seed(701);
        let (x, y) = synth::gaussian_regression(&mut rng, 6, 2, 0.2);
        let q = QuantisedData::from_f64(&x, &y, 2);
        let (xq, _) = q.dequantised();
        let nu = crate::els::stepsize::nu_optimal(&xq);
        let req = PlanRequest::gd(6, 2, 3, 2, nu);
        let params = plan(&req).unwrap();
        let ctx = FvContext::new(params);
        let keys = keygen(&ctx, &mut rng);
        let engine = NativeEngine::new(ctx.clone(), Arc::new(keys.rk.clone()));
        let data = encrypt_dataset(&ctx, &keys.pk, &q, &mut rng);
        let mut cfg = FitConfig::gd(3, nu);
        cfg.keep_path = true;
        let f = fit(&engine, &DatasetRef::Scalar(&data), &cfg).unwrap().fit;
        // The probed fit must still decrypt correctly.
        let dec = decrypt_coefficients(&ctx, &keys.sk, &f);
        let expect = exact::gd_exact(&q, nu, 3).decode_last();
        assert!(linf(&dec, &expect) < 1e-9);

        let traj = noise_trajectory(&ctx, &keys.sk, &f, &req).unwrap();
        assert_eq!(traj.points.len(), 3, "one point per iteration");
        for p in &traj.points {
            assert!(
                p.measured_bits >= p.predicted_floor_bits,
                "iteration {} (depth {}): measured {:.1} < floor {:.1}",
                p.iteration,
                p.depth,
                p.measured_bits,
                p.predicted_floor_bits
            );
            assert!(p.measured_bits > 0.0, "budget exhausted at iteration {}", p.iteration);
        }
        assert!(traj.is_conservative());
        // Depth (and hence the floor) moves monotonically down-path.
        for w in traj.points.windows(2) {
            assert!(w[1].depth >= w[0].depth);
            assert!(w[1].predicted_floor_bits <= w[0].predicted_floor_bits);
        }
        // And the export reparses with the advertised schema.
        let back = Json::parse(&traj.to_json().to_string_json()).unwrap();
        assert_eq!(back.get("schema").and_then(Json::as_str), Some("els-noise-trajectory-v1"));
        assert_eq!(back.get("points").and_then(|p| p.idx(0)).is_some(), true);
    }

    #[test]
    fn trajectory_requires_a_kept_path() {
        let mut rng = ChaChaRng::from_seed(702);
        let (x, y) = synth::gaussian_regression(&mut rng, 4, 2, 0.2);
        let q = QuantisedData::from_f64(&x, &y, 2);
        let (xq, _) = q.dequantised();
        let nu = crate::els::stepsize::nu_optimal(&xq);
        let req = PlanRequest::gd(4, 2, 1, 2, nu);
        let params = plan(&req).unwrap();
        let ctx = FvContext::new(params);
        let keys = keygen(&ctx, &mut rng);
        let engine = NativeEngine::new(ctx.clone(), Arc::new(keys.rk.clone()));
        let data = encrypt_dataset(&ctx, &keys.pk, &q, &mut rng);
        // keep_path = false
        let f = fit(&engine, &DatasetRef::Scalar(&data), &FitConfig::gd(1, nu)).unwrap().fit;
        let err = noise_trajectory(&ctx, &keys.sk, &f, &req).unwrap_err();
        assert!(err.to_string().contains("keep_path"), "{err}");
    }
}
