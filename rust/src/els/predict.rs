//! Encrypted prediction (§4.2): `ỹ* = X̃*ᵀ·β̃^[K]`, a single encrypted
//! dot product per new observation (+1 MMD), with the common GD scale
//! factor making rescaling trivial for the key holder.
//!
//! Mirrors the unified fit API: one [`predict`] entry point over a
//! [`NewDataRef`] (scalar rows or a packed column batch), returning a
//! [`PredictOutcome`] that always carries the op-budget report. The
//! former `predict`/`predict_reported`/`predict_packed` trio survives
//! as `#[deprecated]` shims.

use crate::fhe::encoding::Encoder;
use crate::fhe::{Ciphertext, FvContext, SecretKey};
use crate::math::bigint::BigUint;
use crate::runtime::backend::HeEngine;
use crate::util::telemetry::MetricsSnapshot;

use super::encrypted::EncryptedFit;
use super::scaling::ratio_f64;

/// New observations in either ciphertext layout, borrowed for one
/// prediction call.
#[derive(Clone, Copy)]
pub enum NewDataRef<'a> {
    /// Per-value rows `x_new[i][j]`, quantised at the fit's φ — one
    /// prediction ciphertext per row.
    Scalar(&'a [Vec<Ciphertext>]),
    /// Packed columns: `x_new_cols[j]` packs covariate `j` of all new
    /// observations slot-wise (the [`super::model::PackedDataset`]
    /// column layout, quantised at the fit's φ) — one prediction
    /// ciphertext total, slot `i` carrying observation `i`.
    Packed(&'a [Ciphertext]),
}

/// What a prediction returns: the prediction ciphertexts (one per
/// scalar row, or a single slot-packed ciphertext) plus the op-budget
/// report for the call — per-call only on a quiet engine, like
/// [`super::encrypted::FitOutcome`].
pub struct PredictOutcome {
    /// Prediction ciphertexts.
    pub preds: Vec<Ciphertext>,
    /// Op-budget diff for this call.
    pub report: MetricsSnapshot,
}

/// Predict on either layout through the one entry point. Scalar rows
/// fuse into one `dot_pairs` group per row (the dot product
/// relinearises and scale-and-rounds once per prediction instead of
/// once per term); a packed batch is one fused group of `p` slot-wise
/// products for every observation at once, with **no rotations** —
/// the sum runs over covariates, which sit in separate ciphertexts,
/// not separate slots, and a packed fit's β̃ are slot-broadcast so the
/// products align by construction.
pub fn predict(
    engine: &dyn HeEngine,
    fit: &EncryptedFit,
    x_new: &NewDataRef,
) -> PredictOutcome {
    let before = MetricsSnapshot::capture(engine.ctx(), engine.stats());
    let preds = match x_new {
        NewDataRef::Scalar(rows) => predict_scalar(engine, fit, rows),
        NewDataRef::Packed(cols) => vec![predict_packed_inner(engine, fit, cols)],
    };
    let after = MetricsSnapshot::capture(engine.ctx(), engine.stats());
    PredictOutcome { preds, report: after.diff(&before) }
}

fn predict_scalar(
    engine: &dyn HeEngine,
    fit: &EncryptedFit,
    x_new: &[Vec<Ciphertext>],
) -> Vec<Ciphertext> {
    let p = fit.betas.len();
    let owned: Vec<Vec<(&Ciphertext, &Ciphertext)>> = x_new
        .iter()
        .map(|row| {
            assert_eq!(row.len(), p);
            row.iter().zip(&fit.betas).collect()
        })
        .collect();
    let groups: Vec<&[(&Ciphertext, &Ciphertext)]> =
        owned.iter().map(|g| g.as_slice()).collect();
    engine.dot_pairs(&groups)
}

fn predict_packed_inner(
    engine: &dyn HeEngine,
    fit: &EncryptedFit,
    x_new_cols: &[Ciphertext],
) -> Ciphertext {
    assert_eq!(x_new_cols.len(), fit.betas.len(), "one packed column per covariate");
    let pairs: Vec<(&Ciphertext, &Ciphertext)> =
        x_new_cols.iter().zip(&fit.betas).collect();
    engine.dot_pairs(&[pairs.as_slice()]).pop().unwrap()
}

/// Pre-unification shim.
#[deprecated(note = "use predict(engine, fit, &NewDataRef::Scalar(x_new)) — the \
                     PredictOutcome always carries the report")]
pub fn predict_reported(
    engine: &dyn HeEngine,
    fit: &EncryptedFit,
    x_new: &[Vec<Ciphertext>],
) -> (Vec<Ciphertext>, MetricsSnapshot) {
    let out = predict(engine, fit, &NewDataRef::Scalar(x_new));
    (out.preds, out.report)
}

/// Pre-unification shim.
#[deprecated(note = "use predict(engine, fit, &NewDataRef::Packed(x_new_cols))")]
pub fn predict_packed(
    engine: &dyn HeEngine,
    fit: &EncryptedFit,
    x_new_cols: &[Ciphertext],
) -> Ciphertext {
    predict(engine, fit, &NewDataRef::Packed(x_new_cols)).preds.pop().unwrap()
}

/// Key-holder decode of a packed prediction ciphertext: slots
/// `0..n_new` rescaled by the prediction divisor.
pub fn decrypt_predictions_packed(
    ctx: &FvContext,
    sk: &SecretKey,
    fit: &EncryptedFit,
    pred: &Ciphertext,
    n_new: usize,
) -> Vec<f64> {
    let enc = ctx.slot_encoder().expect("packed predictions need a packed context");
    let div = prediction_divisor(fit);
    let pt = ctx.decrypt(pred, sk);
    enc.decode_vec(&pt, n_new).iter().map(|v| ratio_f64(v, &div)).collect()
}

/// Divisor for decoded predictions: fit divisor × 10^φ.
pub fn prediction_divisor(fit: &EncryptedFit) -> BigUint {
    fit.divisor.mul(&BigUint::pow10(fit.phi))
}

/// Key-holder decode of predictions.
pub fn decrypt_predictions(
    ctx: &FvContext,
    sk: &SecretKey,
    fit: &EncryptedFit,
    preds: &[Ciphertext],
) -> Vec<f64> {
    let div = prediction_divisor(fit);
    preds
        .iter()
        .map(|ct| ctx.decrypt(ct, sk).eval_at_2_scaled(&div))
        .collect()
}

#[cfg(test)]
mod tests {
    use std::sync::Arc;

    use super::*;
    use crate::data::synth;
    use crate::els::encrypted::{decrypt_coefficients, fit, DatasetRef, FitConfig};
    use crate::els::exact::QuantisedData;
    use crate::els::float_ref;
    use crate::els::model::{encrypt_dataset, encrypt_dataset_packed};
    use crate::fhe::keys::keygen;
    use crate::fhe::params::{plan, FvParams, PlanRequest};
    use crate::fhe::rng::ChaChaRng;
    use crate::fhe::FvContext;
    use crate::runtime::backend::NativeEngine;

    #[test]
    fn encrypted_prediction_matches_decoded_dot_product() {
        let mut rng = ChaChaRng::from_seed(231);
        let (x, y) = synth::gaussian_regression(&mut rng, 8, 2, 0.2);
        let q = QuantisedData::from_f64(&x, &y, 2);
        let (xq, _) = q.dequantised();
        let nu = crate::els::stepsize::nu_optimal(&xq);
        let params =
            plan(&PlanRequest::gd(8, 2, 2, 2, nu).with_extra_depth(1)).unwrap();
        let ctx = FvContext::new(params);
        let keys = keygen(&ctx, &mut rng);
        let engine = NativeEngine::new(ctx.clone(), Arc::new(keys.rk.clone()));
        let data = encrypt_dataset(&ctx, &keys.pk, &q, &mut rng);
        let f = fit(&engine, &DatasetRef::Scalar(&data), &FitConfig::gd(2, nu)).unwrap().fit;
        // Predict on the first two training rows (already encrypted).
        let out = predict(&engine, &f, &NewDataRef::Scalar(&data.x[..2]));
        assert!(out.report.engine.ct_muls > 0, "report rides along with every call");
        let preds = out.preds;
        let dec = decrypt_predictions(&ctx, &keys.sk, &f, &preds);
        // Expected: X_quantised · β_decoded.
        let betas = decrypt_coefficients(&ctx, &keys.sk, &f);
        for (i, &pred) in dec.iter().enumerate() {
            let expect: f64 = xq[i].iter().zip(&betas).map(|(a, b)| a * b).sum();
            assert!((pred - expect).abs() < 1e-9, "row {i}: {pred} vs {expect}");
        }
        let _ = float_ref::ols(&xq, &q.dequantised().1);
    }

    #[test]
    fn packed_prediction_fills_slots_without_rotations() {
        // One fused group of p slot-wise products predicts for every
        // packed observation at once — and never rotates: the Σ_j runs
        // across ciphertexts, not slots.
        let mut rng = ChaChaRng::from_seed(232);
        let (x, y) = synth::gaussian_regression(&mut rng, 4, 2, 0.2);
        let q = QuantisedData::from_f64(&x, &y, 1);
        let (xq, _) = q.dequantised();
        let nu = crate::els::stepsize::nu_optimal(&xq);
        let ctx = FvContext::new(FvParams::custom_packed(256, 14, 44).unwrap());
        let keys = keygen(&ctx, &mut rng);
        let engine = NativeEngine::new(ctx.clone(), Arc::new(keys.rk.clone()))
            .with_galois_keys(Arc::new(keys.gk.clone()));
        let data = encrypt_dataset_packed(&ctx, &keys.pk, &q, &mut rng).unwrap();
        let f = fit(&engine, &DatasetRef::Packed(&data), &FitConfig::gd(2, nu)).unwrap().fit;
        // Predict on the training columns themselves (already packed).
        let rot0 = ctx.ring_q.rotation_count();
        let pred =
            predict(&engine, &f, &NewDataRef::Packed(&data.x_cols)).preds.pop().unwrap();
        assert_eq!(ctx.ring_q.rotation_count() - rot0, 0, "prediction is rotation-free");
        let dec = decrypt_predictions_packed(&ctx, &keys.sk, &f, &pred, data.n());
        let betas = decrypt_coefficients(&ctx, &keys.sk, &f);
        for (i, &p) in dec.iter().enumerate() {
            let expect: f64 = xq[i].iter().zip(&betas).map(|(a, b)| a * b).sum();
            assert!((p - expect).abs() < 1e-9, "row {i}: {p} vs {expect}");
        }
    }
}
