//! Encrypted prediction (§4.2): `ỹ* = X̃*ᵀ·β̃^[K]`, a single encrypted
//! dot product per new observation (+1 MMD), with the common GD scale
//! factor making rescaling trivial for the key holder.

use crate::fhe::{Ciphertext, FvContext, SecretKey};
use crate::math::bigint::BigUint;
use crate::runtime::backend::HeEngine;

use super::encrypted::EncryptedFit;

/// Predict for encrypted new rows `x_new[i][j]` (quantised at the same
/// φ as the fit). Returns one ciphertext per row.
pub fn predict(
    engine: &dyn HeEngine,
    fit: &EncryptedFit,
    x_new: &[Vec<Ciphertext>],
) -> Vec<Ciphertext> {
    let p = fit.betas.len();
    // One fused group per new row: the dot product relinearises and
    // scale-and-rounds once per prediction instead of once per term.
    let owned: Vec<Vec<(&Ciphertext, &Ciphertext)>> = x_new
        .iter()
        .map(|row| {
            assert_eq!(row.len(), p);
            row.iter().zip(&fit.betas).collect()
        })
        .collect();
    let groups: Vec<&[(&Ciphertext, &Ciphertext)]> =
        owned.iter().map(|g| g.as_slice()).collect();
    engine.dot_pairs(&groups)
}

/// Divisor for decoded predictions: fit divisor × 10^φ.
pub fn prediction_divisor(fit: &EncryptedFit) -> BigUint {
    fit.divisor.mul(&BigUint::pow10(fit.phi))
}

/// Key-holder decode of predictions.
pub fn decrypt_predictions(
    ctx: &FvContext,
    sk: &SecretKey,
    fit: &EncryptedFit,
    preds: &[Ciphertext],
) -> Vec<f64> {
    let div = prediction_divisor(fit);
    preds
        .iter()
        .map(|ct| ctx.decrypt(ct, sk).eval_at_2_scaled(&div))
        .collect()
}

#[cfg(test)]
mod tests {
    use std::sync::Arc;

    use super::*;
    use crate::data::synth;
    use crate::els::encrypted::{decrypt_coefficients, fit, FitConfig};
    use crate::els::exact::QuantisedData;
    use crate::els::float_ref;
    use crate::els::model::encrypt_dataset;
    use crate::fhe::keys::keygen;
    use crate::fhe::params::{plan, PlanRequest};
    use crate::fhe::rng::ChaChaRng;
    use crate::fhe::FvContext;
    use crate::runtime::backend::NativeEngine;

    #[test]
    fn encrypted_prediction_matches_decoded_dot_product() {
        let mut rng = ChaChaRng::from_seed(231);
        let (x, y) = synth::gaussian_regression(&mut rng, 8, 2, 0.2);
        let q = QuantisedData::from_f64(&x, &y, 2);
        let (xq, _) = q.dequantised();
        let nu = crate::els::stepsize::nu_optimal(&xq);
        let params =
            plan(&PlanRequest::gd(8, 2, 2, 2, nu).with_extra_depth(1)).unwrap();
        let ctx = FvContext::new(params);
        let keys = keygen(&ctx, &mut rng);
        let engine = NativeEngine::new(ctx.clone(), Arc::new(keys.rk.clone()));
        let data = encrypt_dataset(&ctx, &keys.pk, &q, &mut rng);
        let f = fit(&engine, &data, &FitConfig::gd(2, nu));
        // Predict on the first two training rows (already encrypted).
        let preds = predict(&engine, &f, &data.x[..2].to_vec());
        let dec = decrypt_predictions(&ctx, &keys.sk, &f, &preds);
        // Expected: X_quantised · β_decoded.
        let betas = decrypt_coefficients(&ctx, &keys.sk, &f);
        for (i, &pred) in dec.iter().enumerate() {
            let expect: f64 = xq[i].iter().zip(&betas).map(|(a, b)| a * b).sum();
            assert!((pred - expect).abs() < 1e-9, "row {i}: {pred} vs {expect}");
        }
        let _ = float_ref::ols(&xq, &q.dequantised().1);
    }
}
