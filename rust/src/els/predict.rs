//! Encrypted prediction (§4.2): `ỹ* = X̃*ᵀ·β̃^[K]`, a single encrypted
//! dot product per new observation (+1 MMD), with the common GD scale
//! factor making rescaling trivial for the key holder.

use crate::fhe::encoding::Encoder;
use crate::fhe::{Ciphertext, FvContext, SecretKey};
use crate::math::bigint::BigUint;
use crate::runtime::backend::HeEngine;
use crate::util::telemetry::MetricsSnapshot;

use super::encrypted::EncryptedFit;
use super::scaling::ratio_f64;

/// Predict for encrypted new rows `x_new[i][j]` (quantised at the same
/// φ as the fit). Returns one ciphertext per row.
pub fn predict(
    engine: &dyn HeEngine,
    fit: &EncryptedFit,
    x_new: &[Vec<Ciphertext>],
) -> Vec<Ciphertext> {
    let p = fit.betas.len();
    // One fused group per new row: the dot product relinearises and
    // scale-and-rounds once per prediction instead of once per term.
    let owned: Vec<Vec<(&Ciphertext, &Ciphertext)>> = x_new
        .iter()
        .map(|row| {
            assert_eq!(row.len(), p);
            row.iter().zip(&fit.betas).collect()
        })
        .collect();
    let groups: Vec<&[(&Ciphertext, &Ciphertext)]> =
        owned.iter().map(|g| g.as_slice()).collect();
    engine.dot_pairs(&groups)
}

/// [`predict`] plus its op budget report — the prediction counterpart
/// of [`super::encrypted::fit_reported`]. Same caveat: the diff is
/// per-call only on a quiet engine.
pub fn predict_reported(
    engine: &dyn HeEngine,
    fit: &EncryptedFit,
    x_new: &[Vec<Ciphertext>],
) -> (Vec<Ciphertext>, MetricsSnapshot) {
    let before = MetricsSnapshot::capture(engine.ctx(), engine.stats());
    let preds = predict(engine, fit, x_new);
    let after = MetricsSnapshot::capture(engine.ctx(), engine.stats());
    (preds, after.diff(&before))
}

/// Packed prediction: `x_new_cols[j]` packs covariate `j` of all new
/// observations slot-wise (same column layout as
/// [`super::model::PackedDataset`], quantised at the fit's φ), and the
/// returned single ciphertext carries prediction `i` in slot `i` —
/// one fused group of `p` slot-wise products for the whole batch,
/// with **no rotations**: the sum runs over covariates, which sit in
/// separate ciphertexts, not separate slots. A packed fit's β̃ are
/// slot-broadcast, so the slot-wise products align by construction.
pub fn predict_packed(
    engine: &dyn HeEngine,
    fit: &EncryptedFit,
    x_new_cols: &[Ciphertext],
) -> Ciphertext {
    assert_eq!(x_new_cols.len(), fit.betas.len(), "one packed column per covariate");
    let pairs: Vec<(&Ciphertext, &Ciphertext)> =
        x_new_cols.iter().zip(&fit.betas).collect();
    engine.dot_pairs(&[pairs.as_slice()]).pop().unwrap()
}

/// Key-holder decode of a packed prediction ciphertext: slots
/// `0..n_new` rescaled by the prediction divisor.
pub fn decrypt_predictions_packed(
    ctx: &FvContext,
    sk: &SecretKey,
    fit: &EncryptedFit,
    pred: &Ciphertext,
    n_new: usize,
) -> Vec<f64> {
    let enc = ctx.slot_encoder().expect("packed predictions need a packed context");
    let div = prediction_divisor(fit);
    let pt = ctx.decrypt(pred, sk);
    enc.decode_vec(&pt, n_new).iter().map(|v| ratio_f64(v, &div)).collect()
}

/// Divisor for decoded predictions: fit divisor × 10^φ.
pub fn prediction_divisor(fit: &EncryptedFit) -> BigUint {
    fit.divisor.mul(&BigUint::pow10(fit.phi))
}

/// Key-holder decode of predictions.
pub fn decrypt_predictions(
    ctx: &FvContext,
    sk: &SecretKey,
    fit: &EncryptedFit,
    preds: &[Ciphertext],
) -> Vec<f64> {
    let div = prediction_divisor(fit);
    preds
        .iter()
        .map(|ct| ctx.decrypt(ct, sk).eval_at_2_scaled(&div))
        .collect()
}

#[cfg(test)]
mod tests {
    use std::sync::Arc;

    use super::*;
    use crate::data::synth;
    use crate::els::encrypted::{decrypt_coefficients, fit, fit_packed, FitConfig};
    use crate::els::exact::QuantisedData;
    use crate::els::float_ref;
    use crate::els::model::{encrypt_dataset, encrypt_dataset_packed};
    use crate::fhe::keys::keygen;
    use crate::fhe::params::{plan, FvParams, PlanRequest};
    use crate::fhe::rng::ChaChaRng;
    use crate::fhe::FvContext;
    use crate::runtime::backend::NativeEngine;

    #[test]
    fn encrypted_prediction_matches_decoded_dot_product() {
        let mut rng = ChaChaRng::from_seed(231);
        let (x, y) = synth::gaussian_regression(&mut rng, 8, 2, 0.2);
        let q = QuantisedData::from_f64(&x, &y, 2);
        let (xq, _) = q.dequantised();
        let nu = crate::els::stepsize::nu_optimal(&xq);
        let params =
            plan(&PlanRequest::gd(8, 2, 2, 2, nu).with_extra_depth(1)).unwrap();
        let ctx = FvContext::new(params);
        let keys = keygen(&ctx, &mut rng);
        let engine = NativeEngine::new(ctx.clone(), Arc::new(keys.rk.clone()));
        let data = encrypt_dataset(&ctx, &keys.pk, &q, &mut rng);
        let f = fit(&engine, &data, &FitConfig::gd(2, nu));
        // Predict on the first two training rows (already encrypted).
        let preds = predict(&engine, &f, &data.x[..2].to_vec());
        let dec = decrypt_predictions(&ctx, &keys.sk, &f, &preds);
        // Expected: X_quantised · β_decoded.
        let betas = decrypt_coefficients(&ctx, &keys.sk, &f);
        for (i, &pred) in dec.iter().enumerate() {
            let expect: f64 = xq[i].iter().zip(&betas).map(|(a, b)| a * b).sum();
            assert!((pred - expect).abs() < 1e-9, "row {i}: {pred} vs {expect}");
        }
        let _ = float_ref::ols(&xq, &q.dequantised().1);
    }

    #[test]
    fn packed_prediction_fills_slots_without_rotations() {
        // One fused group of p slot-wise products predicts for every
        // packed observation at once — and never rotates: the Σ_j runs
        // across ciphertexts, not slots.
        let mut rng = ChaChaRng::from_seed(232);
        let (x, y) = synth::gaussian_regression(&mut rng, 4, 2, 0.2);
        let q = QuantisedData::from_f64(&x, &y, 1);
        let (xq, _) = q.dequantised();
        let nu = crate::els::stepsize::nu_optimal(&xq);
        let ctx = FvContext::new(FvParams::custom_packed(256, 14, 44).unwrap());
        let keys = keygen(&ctx, &mut rng);
        let engine = NativeEngine::new(ctx.clone(), Arc::new(keys.rk.clone()))
            .with_galois_keys(Arc::new(keys.gk.clone()));
        let data = encrypt_dataset_packed(&ctx, &keys.pk, &q, &mut rng).unwrap();
        let f = fit_packed(&engine, &data, &FitConfig::gd(2, nu)).unwrap();
        // Predict on the training columns themselves (already packed).
        let rot0 = ctx.ring_q.rotation_count();
        let pred = predict_packed(&engine, &f, &data.x_cols);
        assert_eq!(ctx.ring_q.rotation_count() - rot0, 0, "prediction is rotation-free");
        let dec = decrypt_predictions_packed(&ctx, &keys.sk, &f, &pred, data.n());
        let betas = decrypt_coefficients(&ctx, &keys.sk, &f);
        for (i, &p) in dec.iter().enumerate() {
            let expect: f64 = xq[i].iter().zip(&betas).map(|(a, b)| a * b).sum();
            assert!((p - expect).abs() < 1e-9, "row {i}: {p} vs {expect}");
        }
    }
}
