//! Exact encoded-domain simulation of the encrypted algorithms.
//!
//! FHE evaluation is *exact*: the decrypted result equals the same
//! integer arithmetic performed in the clear. This module runs the
//! rescaled update equations on quantised integer data with bigint
//! scalars — bit-identical to what decryption of the encrypted run
//! yields (asserted by integration tests) — and is the fast backend for
//! the convergence figures.

use crate::fhe::encoding::quantize;
use crate::math::bigint::{BigInt, BigUint};

use super::scaling::{ratio_f64, CdScaling, GdScaling, NagScaling, VwtScaling};

/// Quantised dataset: `X̃ = ⌊10^φ X⌉`, `ỹ = ⌊10^φ y⌉`.
#[derive(Clone, Debug)]
pub struct QuantisedData {
    pub x: Vec<Vec<i64>>,
    pub y: Vec<i64>,
    pub phi: u32,
}

impl QuantisedData {
    pub fn from_f64(x: &[Vec<f64>], y: &[f64], phi: u32) -> Self {
        QuantisedData {
            x: x.iter().map(|r| r.iter().map(|&v| quantize(v, phi)).collect()).collect(),
            y: y.iter().map(|&v| quantize(v, phi)).collect(),
            phi,
        }
    }

    pub fn n(&self) -> usize {
        self.x.len()
    }

    pub fn p(&self) -> usize {
        self.x.first().map_or(0, |r| r.len())
    }

    /// The real-valued data the algorithm effectively sees
    /// (quantisation applied) — what figure error norms are computed on.
    pub fn dequantised(&self) -> (Vec<Vec<f64>>, Vec<f64>) {
        let s = 10f64.powi(self.phi as i32);
        (
            self.x
                .iter()
                .map(|r| r.iter().map(|&v| v as f64 / s).collect())
                .collect(),
            self.y.iter().map(|&v| v as f64 / s).collect(),
        )
    }
}

/// Result of an exact encoded run: raw iterates (β̃ per iteration) and
/// their decode divisors.
#[derive(Clone, Debug)]
pub struct ExactPath {
    /// `iterates[k][j]` = coefficient j of β̃ after k+1 iterations.
    pub iterates: Vec<Vec<BigInt>>,
    /// Divisor turning iterate k into β^[k+1].
    pub divisors: Vec<BigUint>,
}

impl ExactPath {
    /// Decode iterate `k` (0-based) into f64 coefficients.
    pub fn decode(&self, k: usize) -> Vec<f64> {
        self.iterates[k]
            .iter()
            .map(|b| ratio_f64(b, &self.divisors[k]))
            .collect()
    }

    pub fn decode_last(&self) -> Vec<f64> {
        self.decode(self.iterates.len() - 1)
    }
}

fn big(v: i64) -> BigInt {
    BigInt::from_i64(v)
}

/// Exact ELS-GD (eq. 10).
pub fn gd_exact(data: &QuantisedData, nu: u64, iters: usize) -> ExactPath {
    let s = GdScaling::new(data.phi, nu);
    let (n, p) = (data.n(), data.p());
    let mut beta = vec![BigInt::zero(); p];
    let mut iterates = Vec::with_capacity(iters);
    let mut divisors = Vec::with_capacity(iters);
    let c_carry = BigInt::from_biguint(s.c_carry());
    for k in 1..=iters {
        let cy = BigInt::from_biguint(s.c_y(k));
        // r_i = c_y·ỹ_i − Σ_j X̃_ij·β̃_j
        let r: Vec<BigInt> = (0..n)
            .map(|i| {
                let mut acc = cy.mul(&big(data.y[i]));
                for j in 0..p {
                    acc = acc.sub(&beta[j].mul_i64(data.x[i][j]));
                }
                acc
            })
            .collect();
        // β̃_j = c_carry·β̃_j + Σ_i X̃_ij·r_i
        beta = (0..p)
            .map(|j| {
                let mut acc = c_carry.mul(&beta[j]);
                for i in 0..n {
                    acc = acc.add(&r[i].mul_i64(data.x[i][j]));
                }
                acc
            })
            .collect();
        iterates.push(beta.clone());
        divisors.push(s.divisor(k));
    }
    ExactPath { iterates, divisors }
}

/// Exact VWT (eq. 18) on top of a GD path: returns (β̃_vwt, divisor).
pub fn vwt_exact(data: &QuantisedData, nu: u64, iters: usize) -> (Vec<BigInt>, BigUint) {
    let path = gd_exact(data, nu, iters);
    let v = VwtScaling::new(data.phi, nu, iters);
    let p = data.p();
    let mut acc = vec![BigInt::zero(); p];
    for k in v.kstar..=iters {
        let w = BigInt::from_biguint(v.weight(k));
        for j in 0..p {
            acc[j] = acc[j].add(&w.mul(&path.iterates[k - 1][j]));
        }
    }
    (acc, v.divisor())
}

/// Exact ELS-NAG (eqs. 20a/20b).
pub fn nag_exact(data: &QuantisedData, nu: u64, iters: usize) -> ExactPath {
    let s = NagScaling::new(data.phi, nu, iters);
    let (n, p) = (data.n(), data.p());
    let mut beta = vec![BigInt::zero(); p];
    let mut s_prev = vec![BigInt::zero(); p];
    let c_carry = BigInt::from_biguint(s.c_carry());
    let mut iterates = Vec::with_capacity(iters);
    let mut divisors = Vec::with_capacity(iters);
    for k in 1..=iters {
        let cy = BigInt::from_biguint(s.c_y(k));
        let r: Vec<BigInt> = (0..n)
            .map(|i| {
                let mut acc = cy.mul(&big(data.y[i]));
                for j in 0..p {
                    acc = acc.sub(&beta[j].mul_i64(data.x[i][j]));
                }
                acc
            })
            .collect();
        let s_cur: Vec<BigInt> = (0..p)
            .map(|j| {
                let mut acc = c_carry.mul(&beta[j]);
                for i in 0..n {
                    acc = acc.add(&r[i].mul_i64(data.x[i][j]));
                }
                acc
            })
            .collect();
        let w1 = BigInt::from_biguint(s.w1(k));
        let w2 = BigInt::from_biguint(s.w2(k));
        // Accelerating extrapolation: β̃ = w1·s̃^[k] − w2·s̃^[k−1].
        beta = (0..p)
            .map(|j| w1.mul(&s_cur[j]).sub(&w2.mul(&s_prev[j])))
            .collect();
        s_prev = s_cur;
        iterates.push(beta.clone());
        divisors.push(s.divisor(k));
    }
    ExactPath { iterates, divisors }
}

/// Exact ELS-CD (eq. 7, incremental-residual form, cyclic schedule).
/// `steps` is the number of *individual coordinate updates*.
pub fn cd_exact(data: &QuantisedData, nu: u64, steps: usize) -> ExactPath {
    let s = CdScaling::new(data.phi, nu);
    let (n, p) = (data.n(), data.p());
    let c = BigInt::from_biguint(s.c_step());
    let mut beta = vec![BigInt::zero(); p];
    // r̃ starts as ỹ (scale 10^φ).
    let mut r: Vec<BigInt> = data.y.iter().map(|&v| big(v)).collect();
    let mut iterates = Vec::with_capacity(steps);
    let mut divisors = Vec::with_capacity(steps);
    for u in 1..=steps {
        let j = (u - 1) % p;
        // ĝ_j = X̃_jᵀ r̃
        let mut g = BigInt::zero();
        for i in 0..n {
            g = g.add(&r[i].mul_i64(data.x[i][j]));
        }
        // All coefficients carry by c; the updated one adds ĝ.
        for (l, b) in beta.iter_mut().enumerate() {
            *b = c.mul(b);
            if l == j {
                *b = b.add(&g);
            }
        }
        // r̃ ← c·r̃ − X̃_j·ĝ_j
        for i in 0..n {
            r[i] = c.mul(&r[i]).sub(&g.mul_i64(data.x[i][j]));
        }
        iterates.push(beta.clone());
        divisors.push(s.divisor(u));
    }
    ExactPath { iterates, divisors }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth;
    use crate::els::float_ref::{self, linf};
    use crate::fhe::rng::ChaChaRng;

    fn setup(seed: u64, n: usize, p: usize) -> (QuantisedData, Vec<Vec<f64>>, Vec<f64>, u64) {
        let mut rng = ChaChaRng::from_seed(seed);
        let (x, y) = synth::gaussian_regression(&mut rng, n, p, 0.2);
        let q = QuantisedData::from_f64(&x, &y, 2);
        let (xq, yq) = q.dequantised();
        let (lmin, lmax) = float_ref::gram_spectrum(&xq);
        let nu = ((lmin + lmax) / 2.0).ceil() as u64;
        (q, xq, yq, nu)
    }

    #[test]
    fn gd_exact_matches_f64_reference() {
        let (q, xq, yq, nu) = setup(101, 40, 3);
        let iters = 6;
        let exact = gd_exact(&q, nu, iters);
        let float = float_ref::gd_path(&xq, &yq, 1.0 / nu as f64, iters);
        for k in 0..iters {
            let d = linf(&exact.decode(k), &float[k]);
            assert!(d < 1e-9, "iterate {k}: drift {d}");
        }
    }

    #[test]
    fn gd_exact_converges_to_ols() {
        let (q, xq, yq, nu) = setup(102, 50, 2);
        let truth = float_ref::ols(&xq, &yq);
        let exact = gd_exact(&q, nu, 60);
        assert!(linf(&exact.decode_last(), &truth) < 1e-4);
    }

    #[test]
    fn vwt_exact_matches_float_vwt() {
        let (q, xq, yq, nu) = setup(103, 60, 4);
        let iters = 12;
        let (acc, div) = vwt_exact(&q, nu, iters);
        let dec: Vec<f64> = acc.iter().map(|b| ratio_f64(b, &div)).collect();
        let float_path = float_ref::gd_path(&xq, &yq, 1.0 / nu as f64, iters);
        let float_vwt = float_ref::vwt_estimate(&float_path);
        assert!(linf(&dec, &float_vwt) < 1e-9, "{dec:?} vs {float_vwt:?}");
    }

    #[test]
    fn nag_exact_close_to_float_nag() {
        // NAG uses quantised η̃ (φ = 2) so agreement is at quantisation
        // precision, not machine precision.
        let (q, xq, yq, nu) = setup(104, 50, 3);
        let iters = 8;
        let exact = nag_exact(&q, nu, iters);
        let float = float_ref::nag_path(&xq, &yq, 1.0 / nu as f64, iters);
        let d = linf(&exact.decode_last(), &float[iters - 1]);
        assert!(d < 0.05, "NAG drift from unquantised momentum: {d}");
    }

    #[test]
    fn cd_exact_matches_f64_cd() {
        let (q, xq, yq, nu) = setup(105, 30, 3);
        let steps = 9;
        let exact = cd_exact(&q, nu, steps);
        let float = float_ref::cd_path(&xq, &yq, 1.0 / nu as f64, steps);
        for u in 0..steps {
            let d = linf(&exact.decode(u), &float[u]);
            assert!(d < 1e-9, "step {u}: drift {d}");
        }
    }

    #[test]
    fn growth_bounds_hold_empirically() {
        // The planner's exact-constant growth recursion must dominate
        // the actually realised message coefficients. We check the
        // decoded *value* bound: |β̃| ≤ coeff_bound·2^{deg_bound+1}
        // is loose; instead check ‖β̃‖ against the value implied by the
        // tracked coefficient bound times the degree budget.
        use crate::fhe::params::track_gd_growth;
        let (q, _, _, nu) = setup(106, 30, 3);
        let iters = 4;
        let exact = gd_exact(&q, nu, iters);
        let g = track_gd_growth(30, 3, iters, 2, nu);
        // m(2) ≤ ‖m‖∞ · (2^{deg+1} − 1)
        let value_bound = g.coeff_bound.mul(&BigUint::one().shl_bits(g.deg_bound + 1));
        for b in &exact.iterates[iters - 1] {
            assert!(
                b.mag.cmp_big(&value_bound) != std::cmp::Ordering::Greater,
                "realised message value exceeds planner bound"
            );
        }
    }
}
