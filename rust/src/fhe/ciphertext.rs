//! FV ciphertexts.

use crate::math::poly::RnsPoly;

/// An FV ciphertext: 2 polynomials (3 transiently, before
/// relinearisation), always stored in coefficient representation over
/// the Q basis, plus depth metadata used by admission control and the
/// paper's MMD accounting.
#[derive(Clone, Debug)]
pub struct Ciphertext {
    pub polys: Vec<RnsPoly>,
    /// Ciphertext-multiplication depth (noise levels consumed).
    pub ct_depth: u32,
}

impl Ciphertext {
    pub fn new(polys: Vec<RnsPoly>) -> Self {
        Ciphertext { polys, ct_depth: 0 }
    }

    pub fn len(&self) -> usize {
        self.polys.len()
    }

    pub fn is_empty(&self) -> bool {
        self.polys.is_empty()
    }

    /// Heap bytes (the paper's Figure-5 memory metric).
    pub fn size_bytes(&self) -> usize {
        self.polys.iter().map(|p| p.size_bytes()).sum()
    }
}
