//! FV ciphertexts.

use crate::math::poly::{Rep, RnsPoly};

/// An FV ciphertext: 2 polynomials (3 transiently, before
/// relinearisation) over the Q basis, plus depth metadata used by
/// admission control and the paper's MMD accounting.
///
/// Each component carries its own [`Rep`] and may legally live in
/// either representation between operations: fresh encryptions are
/// `Coeff`, while `mul_plain_prepared` and relinearised `mul_pairs`
/// products stay **NTT-resident** so consecutive pointwise operations
/// (adds, cached plaintext multiplies) pay zero transforms. Only the
/// `rns_mul` base-conversion boundary and decryption force `Coeff`
/// (lazily, per component). All operations are exact in both domains,
/// so residency never changes decrypted values.
#[derive(Clone, Debug)]
pub struct Ciphertext {
    pub polys: Vec<RnsPoly>,
    /// Ciphertext-multiplication depth (noise levels consumed).
    pub ct_depth: u32,
}

impl Ciphertext {
    pub fn new(polys: Vec<RnsPoly>) -> Self {
        Ciphertext { polys, ct_depth: 0 }
    }

    pub fn len(&self) -> usize {
        self.polys.len()
    }

    pub fn is_empty(&self) -> bool {
        self.polys.is_empty()
    }

    /// Heap bytes (the paper's Figure-5 memory metric).
    pub fn size_bytes(&self) -> usize {
        self.polys.iter().map(|p| p.size_bytes()).sum()
    }

    /// True when every component is NTT-resident (diagnostics and the
    /// transform-budget tests).
    pub fn is_ntt_resident(&self) -> bool {
        self.polys.iter().all(|p| p.rep == Rep::Ntt)
    }
}
