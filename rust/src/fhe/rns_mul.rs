//! Full-RNS BFV multiply: tensor product, `⌊t·v/q⌉` scale-and-round
//! and relinearisation digits entirely in `u64` residue planes — zero
//! `BigInt`/`BigUint` allocations on the `mul_pairs` hot path.
//!
//! Pipeline (the default [`MulBackend::FullRns`] branch of
//! [`FvContext::mul_no_relin`](super::context::FvContext)):
//!
//! 1. **Extend** the four operand polynomials from Q to the extension
//!    ring `B ∪ {m_sk}` with [`BaseConverter`] (centered
//!    representatives; the fixed-point α correction keeps the
//!    extension exact except within `2^-56·q` of the ±q/2 boundary,
//!    where it is off by one multiple of `q` — an operand perturbation
//!    whose phase contribution is `t·u·(Δm + e) ≡ −(q mod t)·u·m +
//!    t·u·e (mod q)`, i.e. ordinary multiplication-noise-sized).
//! 2. **Tensor** per plane on both rings (the planes of Q∪B∪{m_sk}
//!    jointly represent the exact integer tensor coefficients, since
//!    `|v| ≤ d·q²/4 < q·B/8` by the extension-basis sizing).
//! 3. **Scale-and-round**: `z = centered [t·v]_q` from the Q planes,
//!    extended to `B ∪ {m_sk}`; then `r = (t·v − z)/q` by exact
//!    division in the extension planes (`|r| ≤ t·d·q/4 < B/8`); then
//!    [`ShenoyConverter`] brings `r` back to Q exactly, the redundant
//!    `m_sk` plane supplying the γ-correction.
//!
//! The numeric behaviour (including the `u128` fixed point) is
//! mirrored by `python/compile/rns.py::scale_round_rns` and validated
//! there against exact integer arithmetic.

use crate::math::baseconv::{BaseConverter, ShenoyConverter};
use crate::math::bigint::BigUint;
use crate::math::modarith::{invmod_prime, submod, ShoupConstant};
use crate::math::poly::{NttAccumulator, Rep, RingContext, RnsPoly};
use crate::util::telemetry::{self, Phase};

use super::ciphertext::Ciphertext;
use super::context::FvContext;
use super::params::MulBackend;

/// Reusable working buffers for the tensor/scale path: one per worker,
/// created once per `mul_pairs` batch (see
/// `util::pool::parallel_map_with`) instead of three plane-major
/// `Vec<Vec<u64>>` allocations per scale-and-round call (nine per
/// multiply).
pub struct MulScratch {
    /// `[t·v]_q` canonical residues per Q plane.
    z_q: Vec<Vec<u64>>,
    /// `z` extended to `B ∪ {m_sk}`.
    z_ext: Vec<Vec<u64>>,
    /// `r = (t·v − z)/q` on the extension planes.
    r_ext: Vec<Vec<u64>>,
    /// Fused-dot tensor accumulators on the Q ring (c₀/c₁/c₂), built
    /// on first `dot_pairs` use and reset (not reallocated) per chunk
    /// — `mul_pairs`-only workers never pay the `u128` planes.
    acc_q: Vec<NttAccumulator>,
    /// The extension-ring counterparts.
    acc_e: Vec<NttAccumulator>,
}

impl MulScratch {
    /// Pre-sized buffers for `ctx` (allocates immediately; the dot
    /// accumulators stay lazy — see [`ensure_accs`](Self::ensure_accs)).
    pub fn new(ctx: &FvContext) -> Self {
        let d = ctx.d();
        MulScratch {
            z_q: vec![vec![0u64; d]; ctx.ring_q.nlimbs()],
            z_ext: vec![vec![0u64; d]; ctx.ring_ext.nlimbs()],
            r_ext: vec![vec![0u64; d]; ctx.ring_ext.nlimbs()],
            acc_q: Vec::new(),
            acc_e: Vec::new(),
        }
    }

    /// Empty holder: buffers are sized on first full-RNS use, so a
    /// worker on the `ExactBigint` oracle backend (which never touches
    /// the scratch) costs a handful of empty `Vec`s, not
    /// `(L_q + 2·L_ext)·d` words.
    pub fn empty() -> Self {
        MulScratch {
            z_q: Vec::new(),
            z_ext: Vec::new(),
            r_ext: Vec::new(),
            acc_q: Vec::new(),
            acc_e: Vec::new(),
        }
    }

    /// Size (or reset) the six fused-dot tensor accumulators for `ctx`:
    /// first use per context allocates them, every later chunk zeroes
    /// the existing `u128` planes in place — no per-group allocation in
    /// the hot path.
    fn ensure_accs(&mut self, ctx: &FvContext) {
        let (rq, re) = (&ctx.ring_q, &ctx.ring_ext);
        let sized = self.acc_q.len() == 3
            && self.acc_e.len() == 3
            && self.acc_q[0].matches(rq.nlimbs(), rq.d)
            && self.acc_e[0].matches(re.nlimbs(), re.d);
        if sized {
            for acc in self.acc_q.iter_mut().chain(self.acc_e.iter_mut()) {
                acc.reset();
            }
        } else {
            self.acc_q = (0..3).map(|_| rq.ntt_accumulator()).collect();
            self.acc_e = (0..3).map(|_| re.ntt_accumulator()).collect();
        }
    }

    /// Size the buffers for `ctx` if they are not already. Checks all
    /// three buffer sets, so a scratch reused across contexts that
    /// happen to share the Q shape but differ in the extension basis
    /// is resized rather than passed through stale. Touches only the
    /// scale-and-round buffers — the dot accumulators may hold a live
    /// in-chunk sum when this runs (the fused pipeline scale-and-rounds
    /// component c₀ while c₁/c₂ still sit in the accumulators), so they
    /// are managed exclusively by [`ensure_accs`](Self::ensure_accs).
    fn ensure(&mut self, ctx: &FvContext) {
        let sized = self.z_q.len() == ctx.ring_q.nlimbs()
            && self.z_ext.len() == ctx.ring_ext.nlimbs()
            && self.r_ext.len() == ctx.ring_ext.nlimbs()
            && self.z_q.first().is_some_and(|pl| pl.len() == ctx.d());
        if !sized {
            let d = ctx.d();
            self.z_q = vec![vec![0u64; d]; ctx.ring_q.nlimbs()];
            self.z_ext = vec![vec![0u64; d]; ctx.ring_ext.nlimbs()];
            self.r_ext = vec![vec![0u64; d]; ctx.ring_ext.nlimbs()];
        }
    }
}

/// Precomputed tables for the full-RNS multiply under one context.
#[derive(Clone, Debug)]
pub struct RnsMulPrecomp {
    /// Q → B ∪ {m_sk} signed base extension.
    pub fwd: BaseConverter,
    /// B → Q exact Shenoy–Kumaresan back conversion.
    pub back: ShenoyConverter,
    /// `t mod q_i` per Q prime (Shoup form — invariant across the
    /// per-coefficient `t·v` loops).
    pub t_mod_q: Vec<ShoupConstant>,
    /// `t mod p` per extension-ring prime (B order, then `m_sk`).
    pub t_mod_ext: Vec<ShoupConstant>,
    /// `q^{-1} mod p` per extension-ring prime (Shoup form).
    pub q_inv_ext: Vec<ShoupConstant>,
}

impl RnsMulPrecomp {
    /// Build from the Q ring, the extension ring (`B ∪ {m_sk}`, with
    /// `m_sk` last) and the plaintext modulus. Bigint arithmetic is
    /// allowed here — this runs once per context, not per multiply.
    pub fn new(ring_q: &RingContext, ring_ext: &RingContext, t: &BigUint) -> Self {
        let q_primes = &ring_q.basis.primes;
        let ext_primes = &ring_ext.basis.primes;
        let lb = ext_primes.len() - 1;
        let q = &ring_q.basis.modulus;
        let fwd = BaseConverter::new(q_primes, ext_primes);
        let back = ShenoyConverter::new(&ext_primes[..lb], ext_primes[lb], q_primes);
        let t_mod_q = q_primes.iter().map(|&p| ShoupConstant::new(t.mod_u64(p), p)).collect();
        let t_mod_ext =
            ext_primes.iter().map(|&p| ShoupConstant::new(t.mod_u64(p), p)).collect();
        let q_inv_ext = ext_primes
            .iter()
            .map(|&p| ShoupConstant::new(invmod_prime(q.mod_u64(p), p), p))
            .collect();
        RnsMulPrecomp { fwd, back, t_mod_q, t_mod_ext, q_inv_ext }
    }
}

impl FvContext {
    /// Extend a Q-basis polynomial (coefficient rep) to the extension
    /// ring `B ∪ {m_sk}`, centered representatives per coefficient.
    pub fn q_to_ext(&self, poly: &RnsPoly) -> RnsPoly {
        self.q_to_ext_workers(poly, 1)
    }

    /// [`q_to_ext`](Self::q_to_ext) with the per-coefficient conversion
    /// fanned across up to `workers` threads.
    pub fn q_to_ext_workers(&self, poly: &RnsPoly, workers: usize) -> RnsPoly {
        let _span = telemetry::span(Phase::BaseExtend);
        assert_eq!(poly.rep, Rep::Coeff);
        let mut out = self.ring_ext.zero();
        self.rns.fwd.convert_signed_workers(&poly.planes, &mut out.planes, workers);
        out
    }

    /// Full-RNS `⌊t·v/q⌉ mod q`: the tensor component is given on the
    /// Q planes (`c_q`) and the extension planes (`c_ext`), both in
    /// coefficient rep; the result lands back on Q.
    pub fn scale_round_rns(&self, c_q: &RnsPoly, c_ext: &RnsPoly) -> RnsPoly {
        self.scale_round_rns_with(c_q, c_ext, &mut MulScratch::new(self), 1)
    }

    /// [`scale_round_rns`](Self::scale_round_rns) against caller-owned
    /// scratch buffers (reused across a batch) with the base
    /// conversions fanned across up to `workers` threads.
    pub fn scale_round_rns_with(
        &self,
        c_q: &RnsPoly,
        c_ext: &RnsPoly,
        scratch: &mut MulScratch,
        workers: usize,
    ) -> RnsPoly {
        let _span = telemetry::span(Phase::ScaleRound);
        assert_eq!(c_q.rep, Rep::Coeff);
        assert_eq!(c_ext.rep, Rep::Coeff);
        scratch.ensure(self);
        let rq = &self.ring_q;
        let re = &self.ring_ext;
        let d = rq.d;
        // z = [t·v]_q per Q plane (canonical residues of the centered z).
        for (i, tm) in self.rns.t_mod_q.iter().enumerate() {
            let (src, dst) = (&c_q.planes[i], &mut scratch.z_q[i]);
            for c in 0..d {
                dst[c] = tm.mul(src[c]);
            }
        }
        // Extend z to B ∪ {m_sk} (centered: |z| ≤ q/2).
        self.rns.fwd.convert_signed_workers(&scratch.z_q, &mut scratch.z_ext, workers);
        // r = (t·v − z)·q^{-1} on every extension plane — exact
        // division, since t·v ≡ z (mod q) as integers.
        for (e, &p) in re.basis.primes.iter().enumerate() {
            let tm = &self.rns.t_mod_ext[e];
            let qi = &self.rns.q_inv_ext[e];
            let (src, zs, dst) = (&c_ext.planes[e], &scratch.z_ext[e], &mut scratch.r_ext[e]);
            for c in 0..d {
                let tv = tm.mul(src[c]);
                dst[c] = qi.mul(submod(tv, zs[c], p));
            }
        }
        // Exact Shenoy–Kumaresan conversion back to Q.
        let lb = re.nlimbs() - 1;
        let mut out = rq.zero();
        {
            let _shenoy = telemetry::span(Phase::ShenoyConvert);
            self.rns.back.convert_workers(
                &scratch.r_ext[..lb],
                &scratch.r_ext[lb],
                &mut out.planes,
                workers,
            );
        }
        out
    }

    /// The full-RNS tensor product **without** relinearisation — the
    /// [`MulBackend::FullRns`] counterpart of
    /// [`mul_no_relin_bigint`](FvContext::mul_no_relin_bigint).
    pub fn mul_no_relin_rns(&self, a: &Ciphertext, b: &Ciphertext) -> Ciphertext {
        self.mul_no_relin_rns_with(a, b, &mut MulScratch::new(self), 1)
    }

    /// [`mul_no_relin_rns`](Self::mul_no_relin_rns) with caller-owned
    /// scratch and an intra-multiply worker budget (`workers` fans the
    /// per-limb NTT planes and the base-conversion coefficient ranges;
    /// results are bit-identical for every worker count).
    ///
    /// Operands may arrive in either residency: a `Coeff` component
    /// pays one forward NTT for its Q planes (base extension reads it
    /// directly), an NTT-resident component pays one inverse for the
    /// base extension (its Q planes are reused as-is) — the transform
    /// bill is the same, so residency upstream is never penalised here.
    pub fn mul_no_relin_rns_with(
        &self,
        a: &Ciphertext,
        b: &Ciphertext,
        scratch: &mut MulScratch,
        workers: usize,
    ) -> Ciphertext {
        let rq = &self.ring_q;
        let re = &self.ring_ext;
        let (q_ops, e_ops) = self.tensor_operands(a, b, workers);
        // Tensor product on both rings.
        fn tensor(ring: &RingContext, ops: &[RnsPoly], workers: usize) -> [RnsPoly; 3] {
            let mut c0 = ring.mul_ntt(&ops[0], &ops[2]);
            let mut c1 =
                ring.add(&ring.mul_ntt(&ops[0], &ops[3]), &ring.mul_ntt(&ops[1], &ops[2]));
            let mut c2 = ring.mul_ntt(&ops[1], &ops[3]);
            ring.ntt_inverse_workers(&mut c0, workers);
            ring.ntt_inverse_workers(&mut c1, workers);
            ring.ntt_inverse_workers(&mut c2, workers);
            [c0, c1, c2]
        }
        let cq = tensor(rq, &q_ops, workers);
        let ce = tensor(re, &e_ops, workers);
        // Scale each component by t/q back into Q.
        let polys = cq
            .iter()
            .zip(ce.iter())
            .map(|(q_part, e_part)| self.scale_round_rns_with(q_part, e_part, scratch, workers))
            .collect();
        rq.note_scale_round();
        let mut out = Ciphertext::new(polys);
        out.ct_depth = a.ct_depth.max(b.ct_depth) + 1;
        out
    }

    /// Bring one relinearised operand pair's four polynomials into the
    /// two tensor domains: NTT-form Q planes and NTT-form extension
    /// planes (residency-lazy; see
    /// [`mul_no_relin_rns_with`](Self::mul_no_relin_rns_with) for the
    /// transform bill). Shared by the single multiply and the fused
    /// inner-product accumulation.
    fn tensor_operands(
        &self,
        a: &Ciphertext,
        b: &Ciphertext,
        workers: usize,
    ) -> (Vec<RnsPoly>, Vec<RnsPoly>) {
        assert_eq!(a.len(), 2, "operands must be relinearised");
        assert_eq!(b.len(), 2);
        let rq = &self.ring_q;
        let re = &self.ring_ext;
        let operands = [&a.polys[0], &a.polys[1], &b.polys[0], &b.polys[1]];
        let mut q_ops: Vec<RnsPoly> = Vec::with_capacity(4);
        let mut e_ops: Vec<RnsPoly> = Vec::with_capacity(4);
        for p in operands {
            let mut ext = match p.rep {
                Rep::Coeff => {
                    let mut n = p.clone();
                    rq.ntt_forward_workers(&mut n, workers);
                    q_ops.push(n);
                    self.q_to_ext_workers(p, workers)
                }
                Rep::Ntt => {
                    let mut c = p.clone();
                    rq.ntt_inverse_workers(&mut c, workers);
                    q_ops.push(p.clone());
                    self.q_to_ext_workers(&c, workers)
                }
            };
            re.ntt_forward_workers(&mut ext, workers);
            e_ops.push(ext);
        }
        (q_ops, e_ops)
    }

    /// Fused inner-product tensor `Σ_k a_k ⊗ b_k` **without**
    /// relinearisation: every pair is base-extended and tensored
    /// exactly as in [`mul_no_relin_rns_with`](Self::mul_no_relin_rns_with),
    /// but the three degree-2 tensor components accumulate *unreduced*
    /// in `u128` residue planes (one [`crate::math::poly::NttAccumulator`]
    /// per component per ring) across the whole group, and the
    /// `⌊t·v/q⌉` scale-and-round + Shenoy–Kumaresan back conversion run
    /// once per chunk of [`fuse_chunk`](Self::fuse_chunk) terms instead
    /// of once per pair. A one-pair group is bit-identical to
    /// [`mul_no_relin_rns`](Self::mul_no_relin_rns).
    pub fn dot_no_relin_rns(&self, pairs: &[(&Ciphertext, &Ciphertext)]) -> Ciphertext {
        self.dot_no_relin_rns_with(pairs, &mut MulScratch::new(self), 1)
    }

    /// [`dot_no_relin_rns`](Self::dot_no_relin_rns) with caller-owned
    /// scratch and an intra-group worker budget (fans the NTT limb
    /// planes and base-conversion coefficient ranges; bit-identical
    /// for every worker count).
    pub fn dot_no_relin_rns_with(
        &self,
        pairs: &[(&Ciphertext, &Ciphertext)],
        scratch: &mut MulScratch,
        workers: usize,
    ) -> Ciphertext {
        self.dot_no_relin_rns_chunked(pairs, self.fuse_chunk_rns, scratch, workers)
    }

    /// [`dot_no_relin_rns_with`](Self::dot_no_relin_rns_with) with an
    /// explicit accumulation-chunk size. Production callers use the
    /// context-computed headroom bound (`fuse_chunk_rns`: the summed
    /// `⌊t·v/q⌉` output must keep `|r| ≤ k·t·d·q/4 < B/8` for the
    /// Shenoy–Kumaresan conversion to stay exact); the chunk-boundary
    /// parity tests drive smaller chunks directly. Groups longer than
    /// one chunk pay one extra scale-and-round per chunk — the chunk
    /// sums are added back in Q — but still relinearise once.
    pub fn dot_no_relin_rns_chunked(
        &self,
        pairs: &[(&Ciphertext, &Ciphertext)],
        chunk: usize,
        scratch: &mut MulScratch,
        workers: usize,
    ) -> Ciphertext {
        assert!(!pairs.is_empty(), "dot group must be non-empty");
        assert!(chunk >= 1, "chunk must be positive");
        let mut acc: Option<Ciphertext> = None;
        for part in pairs.chunks(chunk) {
            let ct = self.dot_chunk_rns(part, scratch, workers);
            acc = Some(match acc {
                None => ct,
                Some(prev) => self.add_ct(&prev, &ct),
            });
        }
        acc.unwrap()
    }

    /// One accumulation chunk: tensor every pair into the scratch's
    /// reusable `u128` accumulators, then reduce, inverse-transform and
    /// scale-and-round the three summed components once.
    fn dot_chunk_rns(
        &self,
        pairs: &[(&Ciphertext, &Ciphertext)],
        scratch: &mut MulScratch,
        workers: usize,
    ) -> Ciphertext {
        let rq = &self.ring_q;
        let re = &self.ring_ext;
        scratch.ensure_accs(self);
        let mut depth = 0u32;
        for (a, b) in pairs {
            depth = depth.max(a.ct_depth).max(b.ct_depth);
            let (q_ops, e_ops) = self.tensor_operands(a, b, workers);
            for (ring, ops, acc) in [
                (rq, &q_ops, &mut scratch.acc_q),
                (re, &e_ops, &mut scratch.acc_e),
            ] {
                ring.acc_mul_ntt(&mut acc[0], &ops[0], &ops[2]);
                ring.acc_mul_ntt(&mut acc[1], &ops[0], &ops[3]);
                ring.acc_mul_ntt(&mut acc[1], &ops[1], &ops[2]);
                ring.acc_mul_ntt(&mut acc[2], &ops[1], &ops[3]);
            }
        }
        let mut polys = Vec::with_capacity(3);
        for c in 0..3 {
            let mut vq = rq.acc_reduce(&scratch.acc_q[c]);
            rq.ntt_inverse_workers(&mut vq, workers);
            let mut ve = re.acc_reduce(&scratch.acc_e[c]);
            re.ntt_inverse_workers(&mut ve, workers);
            polys.push(self.scale_round_rns_with(&vq, &ve, scratch, workers));
        }
        rq.note_scale_round();
        let mut out = Ciphertext::new(polys);
        out.ct_depth = depth + 1;
        out
    }

    /// The backend this context's `mul_no_relin`/`mul_ct` dispatch to.
    pub fn backend(&self) -> MulBackend {
        self.params.mul_backend
    }
}

#[cfg(test)]
mod tests {
    use std::sync::Arc;

    use super::super::keys::keygen;
    use super::super::params::{FvParams, MulBackend};
    use super::super::rng::ChaChaRng;
    use super::*;
    use crate::fhe::encoding::encode_int;

    fn ctx_pair(
        d: usize,
        l: usize,
        t_bits: usize,
    ) -> (Arc<FvContext>, Arc<FvContext>) {
        let mut params = FvParams::custom(d, l, t_bits);
        params.mul_backend = MulBackend::FullRns;
        let rns = FvContext::new(params.clone());
        params.mul_backend = MulBackend::ExactBigint;
        (rns, FvContext::new(params))
    }

    #[test]
    fn q_to_ext_matches_bigint_lift() {
        let (ctx, _) = ctx_pair(256, 3, 24);
        let mut rng = ChaChaRng::from_seed(91);
        // Encryption-shaped data: uniform residues.
        let poly = ctx.ring_q.sample_uniform(&mut rng);
        let ext = ctx.q_to_ext(&poly);
        let lifted = FvContext::lift_signed_poly(&ctx.ring_q, &poly);
        for (e, &p) in ctx.ring_ext.basis.primes.iter().enumerate() {
            for (c, v) in lifted.iter().enumerate() {
                assert_eq!(ext.planes[e][c], v.mod_u64(p), "plane {e} coeff {c}");
            }
        }
    }

    #[test]
    fn rns_and_bigint_tensor_decrypt_identically() {
        // The cross-backend parity oracle at the single-multiply level:
        // identical ciphertext inputs, decrypt-equal outputs, on both
        // the 3-component tensor and the relinearised product.
        let (rns_ctx, big_ctx) = ctx_pair(256, 3, 24);
        let mut rng = ChaChaRng::from_seed(92);
        let keys = keygen(&rns_ctx, &mut rng);
        use crate::util::prop::{gen, PropRunner};
        let mut run = PropRunner::new("rns_mul_parity", 8);
        run.run(|rng| {
            let a = gen::int_in(rng, -2000, 2000);
            let b = gen::int_in(rng, -2000, 2000);
            let ca = rns_ctx.encrypt(&encode_int(a, rns_ctx.d()), &keys.pk, rng);
            let cb = rns_ctx.encrypt(&encode_int(b, rns_ctx.d()), &keys.pk, rng);
            let raw_rns = rns_ctx.mul_no_relin_rns(&ca, &cb);
            let raw_big = big_ctx.mul_no_relin_bigint(&ca, &cb);
            assert_eq!(
                rns_ctx.decrypt(&raw_rns, &keys.sk),
                big_ctx.decrypt(&raw_big, &keys.sk),
                "3-component tensors must decrypt identically"
            );
            let full_rns = rns_ctx.mul_ct(&ca, &cb, &keys.rk);
            let full_big = big_ctx.mul_ct(&ca, &cb, &keys.rk);
            let dec = rns_ctx.decrypt(&full_rns, &keys.sk);
            assert_eq!(dec, big_ctx.decrypt(&full_big, &keys.sk));
            assert_eq!(dec.eval_at_2().to_i128(), Some(a as i128 * b as i128));
        });
    }

    #[test]
    fn intra_multiply_workers_are_bit_identical() {
        // The inner fan-out (plane-parallel NTTs + chunked base
        // conversions) must reproduce the serial multiply exactly, for
        // fresh (Coeff) and NTT-resident operands alike. The engine
        // only engages this path on large rings, so drive it directly.
        let (ctx, _) = ctx_pair(256, 3, 24);
        let mut rng = ChaChaRng::from_seed(94);
        let keys = keygen(&ctx, &mut rng);
        let ca = ctx.encrypt(&encode_int(123, ctx.d()), &keys.pk, &mut rng);
        let cb = ctx.encrypt(&encode_int(-45, ctx.d()), &keys.pk, &mut rng);
        let mut cb_ntt = cb.clone();
        for p in cb_ntt.polys.iter_mut() {
            ctx.ring_q.ensure_ntt(p);
        }
        let serial = ctx.mul_no_relin_rns(&ca, &cb);
        for workers in [2usize, 4, 8] {
            let mut scratch = MulScratch::new(&ctx);
            let par = ctx.mul_no_relin_rns_with(&ca, &cb, &mut scratch, workers);
            assert_eq!(par.polys, serial.polys, "coeff operands, workers {workers}");
            // Mixed residency through the same scratch (reuse check).
            let par_mixed = ctx.mul_no_relin_rns_with(&ca, &cb_ntt, &mut scratch, workers);
            assert_eq!(par_mixed.polys, serial.polys, "mixed operands, workers {workers}");
        }
    }

    fn encrypt_pairs(
        ctx: &FvContext,
        keys: &super::super::keys::KeySet,
        rng: &mut ChaChaRng,
        vals: &[(i64, i64)],
    ) -> Vec<(Ciphertext, Ciphertext)> {
        vals.iter()
            .map(|&(a, b)| {
                (
                    ctx.encrypt(&encode_int(a, ctx.d()), &keys.pk, rng),
                    ctx.encrypt(&encode_int(b, ctx.d()), &keys.pk, rng),
                )
            })
            .collect()
    }

    #[test]
    fn fused_dot_matches_fold_of_single_multiplies() {
        let (ctx, _) = ctx_pair(256, 3, 24);
        let mut rng = ChaChaRng::from_seed(95);
        let keys = keygen(&ctx, &mut rng);
        let vals = [(3i64, 5i64), (-7, 11), (100, -2), (9, 4), (-1, -8)];
        let cts = encrypt_pairs(&ctx, &keys, &mut rng, &vals);
        let pairs: Vec<(&Ciphertext, &Ciphertext)> = cts.iter().map(|(a, b)| (a, b)).collect();
        // Reference: per-pair tensors summed in Q.
        let mut fold = ctx.mul_no_relin_rns(pairs[0].0, pairs[0].1);
        for (a, b) in &pairs[1..] {
            fold = ctx.add_ct(&fold, &ctx.mul_no_relin_rns(a, b));
        }
        let fused = ctx.dot_no_relin_rns(&pairs);
        assert_eq!(fused.len(), 3);
        assert_eq!(fused.ct_depth, 1);
        let df = ctx.decrypt(&fused, &keys.sk);
        assert_eq!(df, ctx.decrypt(&fold, &keys.sk), "fused vs fold decrypt");
        let expect: i128 = vals.iter().map(|&(a, b)| a as i128 * b as i128).sum();
        assert_eq!(df.eval_at_2().to_i128(), Some(expect));
        // A one-pair group is the single multiply, bit for bit — the
        // batcher relies on this to route mul_pairs through the group
        // seam unchanged.
        let single = ctx.dot_no_relin_rns(&pairs[..1]);
        assert_eq!(single.polys, ctx.mul_no_relin_rns(pairs[0].0, pairs[0].1).polys);
    }

    #[test]
    fn fused_dot_chunk_boundary_parity() {
        // Groups beyond the accumulation chunk must split, scale-round
        // once per chunk, and still decrypt to the same inner product.
        let (ctx, _) = ctx_pair(256, 3, 24);
        let mut rng = ChaChaRng::from_seed(96);
        let keys = keygen(&ctx, &mut rng);
        let vals = [(12i64, -3i64), (4, 4), (-9, 7), (30, 2), (-5, -5)];
        let cts = encrypt_pairs(&ctx, &keys, &mut rng, &vals);
        let pairs: Vec<(&Ciphertext, &Ciphertext)> = cts.iter().map(|(a, b)| (a, b)).collect();
        assert!(ctx.fuse_chunk_rns >= pairs.len(), "toy set must not chunk by itself");
        let dec = ctx.decrypt(&ctx.dot_no_relin_rns(&pairs), &keys.sk);
        let ring = &ctx.ring_q;
        for chunk in [1usize, 2, 3, 5, 7] {
            let mut scratch = MulScratch::new(&ctx);
            let before = ring.scale_round_count();
            let out = ctx.dot_no_relin_rns_chunked(&pairs, chunk, &mut scratch, 1);
            assert_eq!(
                ring.scale_round_count() - before,
                pairs.len().div_ceil(chunk) as u64,
                "one scale-round pipeline per chunk (chunk {chunk})"
            );
            assert_eq!(ctx.decrypt(&out, &keys.sk), dec, "chunk {chunk}");
        }
        // chunk = 1 degenerates to the pair-by-pair fold, bit for bit.
        let mut scratch = MulScratch::new(&ctx);
        let per_pair = ctx.dot_no_relin_rns_chunked(&pairs, 1, &mut scratch, 1);
        let mut fold = ctx.mul_no_relin_rns(pairs[0].0, pairs[0].1);
        for (a, b) in &pairs[1..] {
            fold = ctx.add_ct(&fold, &ctx.mul_no_relin_rns(a, b));
        }
        assert_eq!(per_pair.polys, fold.polys);
    }

    #[test]
    fn fused_dot_workers_are_bit_identical() {
        let (ctx, _) = ctx_pair(256, 3, 24);
        let mut rng = ChaChaRng::from_seed(97);
        let keys = keygen(&ctx, &mut rng);
        let vals = [(21i64, 2i64), (-6, 13), (7, 7)];
        let cts = encrypt_pairs(&ctx, &keys, &mut rng, &vals);
        let mut pairs: Vec<(&Ciphertext, &Ciphertext)> =
            cts.iter().map(|(a, b)| (a, b)).collect();
        let serial = ctx.dot_no_relin_rns(&pairs);
        // Mixed residency (NTT-resident b of the middle pair) through
        // the same scratch, as the descent loops produce.
        let mut b1_ntt = cts[1].1.clone();
        for p in b1_ntt.polys.iter_mut() {
            ctx.ring_q.ensure_ntt(p);
        }
        pairs[1].1 = &b1_ntt;
        let serial_mixed = ctx.dot_no_relin_rns(&pairs);
        assert_eq!(serial_mixed.polys, serial.polys, "residency must not change bits");
        for workers in [2usize, 4, 8] {
            let mut scratch = MulScratch::new(&ctx);
            let par = ctx.dot_no_relin_rns_with(&pairs, &mut scratch, workers);
            assert_eq!(par.polys, serial.polys, "workers {workers}");
        }
    }

    #[test]
    fn scale_round_matches_oracle_planes() {
        // Beyond decrypt-equality: on in-range random tensor data the
        // two scale-and-rounds agree coefficient-for-coefficient up to
        // the ±1 rounding-tie ulp.
        let (ctx, _) = ctx_pair(256, 3, 20);
        let mut rng = ChaChaRng::from_seed(93);
        // Build an in-range v by tensoring two fresh-ciphertext-like
        // polynomials through the oracle lift.
        let x = ctx.ring_q.sample_uniform(&mut rng);
        let y = ctx.ring_q.sample_uniform(&mut rng);
        let vq = ctx.ring_q.polymul(&x, &y);
        let v_ext = {
            let xe = ctx.q_to_ext(&x);
            let ye = ctx.q_to_ext(&y);
            ctx.ring_ext.polymul(&xe, &ye)
        };
        let rns_out = ctx.scale_round_rns(&vq, &v_ext);
        let big_out = {
            let xb = ctx.q_to_big(&x);
            let yb = ctx.q_to_big(&y);
            ctx.scale_round_to_q(&ctx.ring_big.polymul(&xb, &yb))
        };
        let primes = &ctx.ring_q.basis.primes;
        for (l, &p) in primes.iter().enumerate() {
            for c in 0..ctx.d() {
                let a = rns_out.planes[l][c];
                let b = big_out.planes[l][c];
                let diff = crate::math::modarith::center(
                    crate::math::modarith::submod(a, b, p),
                    p,
                );
                assert!(diff.abs() <= 1, "plane {l} coeff {c}: diff {diff}");
            }
        }
    }
}
