//! Full-RNS BFV multiply: tensor product, `⌊t·v/q⌉` scale-and-round
//! and relinearisation digits entirely in `u64` residue planes — zero
//! `BigInt`/`BigUint` allocations on the `mul_pairs` hot path.
//!
//! Pipeline (the default [`MulBackend::FullRns`] branch of
//! [`FvContext::mul_no_relin`](super::context::FvContext)):
//!
//! 1. **Extend** the four operand polynomials from Q to the extension
//!    ring `B ∪ {m_sk}` with [`BaseConverter`] (centered
//!    representatives; the fixed-point α correction keeps the
//!    extension exact except within `2^-56·q` of the ±q/2 boundary,
//!    where it is off by one multiple of `q` — an operand perturbation
//!    whose phase contribution is `t·u·(Δm + e) ≡ −(q mod t)·u·m +
//!    t·u·e (mod q)`, i.e. ordinary multiplication-noise-sized).
//! 2. **Tensor** per plane on both rings (the planes of Q∪B∪{m_sk}
//!    jointly represent the exact integer tensor coefficients, since
//!    `|v| ≤ d·q²/4 < q·B/8` by the extension-basis sizing).
//! 3. **Scale-and-round**: `z = centered [t·v]_q` from the Q planes,
//!    extended to `B ∪ {m_sk}`; then `r = (t·v − z)/q` by exact
//!    division in the extension planes (`|r| ≤ t·d·q/4 < B/8`); then
//!    [`ShenoyConverter`] brings `r` back to Q exactly, the redundant
//!    `m_sk` plane supplying the γ-correction.
//!
//! The numeric behaviour (including the `u128` fixed point) is
//! mirrored by `python/compile/rns.py::scale_round_rns` and validated
//! there against exact integer arithmetic.

use crate::math::baseconv::{BaseConverter, ShenoyConverter};
use crate::math::bigint::BigUint;
use crate::math::modarith::{invmod_prime, submod, ShoupConstant};
use crate::math::poly::{RingContext, RnsPoly};

use super::ciphertext::Ciphertext;
use super::context::FvContext;
use super::params::MulBackend;

/// Precomputed tables for the full-RNS multiply under one context.
#[derive(Clone, Debug)]
pub struct RnsMulPrecomp {
    /// Q → B ∪ {m_sk} signed base extension.
    pub fwd: BaseConverter,
    /// B → Q exact Shenoy–Kumaresan back conversion.
    pub back: ShenoyConverter,
    /// `t mod q_i` per Q prime (Shoup form — invariant across the
    /// per-coefficient `t·v` loops).
    pub t_mod_q: Vec<ShoupConstant>,
    /// `t mod p` per extension-ring prime (B order, then `m_sk`).
    pub t_mod_ext: Vec<ShoupConstant>,
    /// `q^{-1} mod p` per extension-ring prime (Shoup form).
    pub q_inv_ext: Vec<ShoupConstant>,
}

impl RnsMulPrecomp {
    /// Build from the Q ring, the extension ring (`B ∪ {m_sk}`, with
    /// `m_sk` last) and the plaintext modulus. Bigint arithmetic is
    /// allowed here — this runs once per context, not per multiply.
    pub fn new(ring_q: &RingContext, ring_ext: &RingContext, t: &BigUint) -> Self {
        let q_primes = &ring_q.basis.primes;
        let ext_primes = &ring_ext.basis.primes;
        let lb = ext_primes.len() - 1;
        let q = &ring_q.basis.modulus;
        let fwd = BaseConverter::new(q_primes, ext_primes);
        let back = ShenoyConverter::new(&ext_primes[..lb], ext_primes[lb], q_primes);
        let t_mod_q = q_primes.iter().map(|&p| ShoupConstant::new(t.mod_u64(p), p)).collect();
        let t_mod_ext =
            ext_primes.iter().map(|&p| ShoupConstant::new(t.mod_u64(p), p)).collect();
        let q_inv_ext = ext_primes
            .iter()
            .map(|&p| ShoupConstant::new(invmod_prime(q.mod_u64(p), p), p))
            .collect();
        RnsMulPrecomp { fwd, back, t_mod_q, t_mod_ext, q_inv_ext }
    }
}

impl FvContext {
    /// Extend a Q-basis polynomial (coefficient rep) to the extension
    /// ring `B ∪ {m_sk}`, centered representatives per coefficient.
    pub fn q_to_ext(&self, poly: &RnsPoly) -> RnsPoly {
        assert_eq!(poly.rep, crate::math::poly::Rep::Coeff);
        let mut out = self.ring_ext.zero();
        self.rns.fwd.convert_signed(&poly.planes, &mut out.planes);
        out
    }

    /// Full-RNS `⌊t·v/q⌉ mod q`: the tensor component is given on the
    /// Q planes (`c_q`) and the extension planes (`c_ext`), both in
    /// coefficient rep; the result lands back on Q.
    pub fn scale_round_rns(&self, c_q: &RnsPoly, c_ext: &RnsPoly) -> RnsPoly {
        assert_eq!(c_q.rep, crate::math::poly::Rep::Coeff);
        assert_eq!(c_ext.rep, crate::math::poly::Rep::Coeff);
        let rq = &self.ring_q;
        let re = &self.ring_ext;
        let d = rq.d;
        // z = [t·v]_q per Q plane (canonical residues of the centered z).
        let mut z_planes = vec![vec![0u64; d]; rq.nlimbs()];
        for (i, tm) in self.rns.t_mod_q.iter().enumerate() {
            let (src, dst) = (&c_q.planes[i], &mut z_planes[i]);
            for c in 0..d {
                dst[c] = tm.mul(src[c]);
            }
        }
        // Extend z to B ∪ {m_sk} (centered: |z| ≤ q/2).
        let mut z_ext = vec![vec![0u64; d]; re.nlimbs()];
        self.rns.fwd.convert_signed(&z_planes, &mut z_ext);
        // r = (t·v − z)·q^{-1} on every extension plane — exact
        // division, since t·v ≡ z (mod q) as integers.
        let mut r_planes = vec![vec![0u64; d]; re.nlimbs()];
        for (e, &p) in re.basis.primes.iter().enumerate() {
            let tm = &self.rns.t_mod_ext[e];
            let qi = &self.rns.q_inv_ext[e];
            let (src, zs, dst) = (&c_ext.planes[e], &z_ext[e], &mut r_planes[e]);
            for c in 0..d {
                let tv = tm.mul(src[c]);
                dst[c] = qi.mul(submod(tv, zs[c], p));
            }
        }
        // Exact Shenoy–Kumaresan conversion back to Q.
        let lb = re.nlimbs() - 1;
        let mut out = rq.zero();
        self.rns.back.convert(&r_planes[..lb], &r_planes[lb], &mut out.planes);
        out
    }

    /// The full-RNS tensor product **without** relinearisation — the
    /// [`MulBackend::FullRns`] counterpart of
    /// [`mul_no_relin_bigint`](FvContext::mul_no_relin_bigint).
    pub fn mul_no_relin_rns(&self, a: &Ciphertext, b: &Ciphertext) -> Ciphertext {
        assert_eq!(a.len(), 2, "operands must be relinearised");
        assert_eq!(b.len(), 2);
        let rq = &self.ring_q;
        let re = &self.ring_ext;
        let operands = [&a.polys[0], &a.polys[1], &b.polys[0], &b.polys[1]];
        // Q planes: the original residues, NTT'd.
        let mut q_ops: Vec<RnsPoly> = operands.iter().map(|p| (**p).clone()).collect();
        for p in q_ops.iter_mut() {
            rq.ntt_forward(p);
        }
        // Extension planes: centered base extension, then NTT.
        let mut e_ops: Vec<RnsPoly> = operands.iter().map(|p| self.q_to_ext(p)).collect();
        for p in e_ops.iter_mut() {
            re.ntt_forward(p);
        }
        // Tensor product on both rings.
        fn tensor(ring: &RingContext, ops: &[RnsPoly]) -> [RnsPoly; 3] {
            let mut c0 = ring.mul_ntt(&ops[0], &ops[2]);
            let mut c1 =
                ring.add(&ring.mul_ntt(&ops[0], &ops[3]), &ring.mul_ntt(&ops[1], &ops[2]));
            let mut c2 = ring.mul_ntt(&ops[1], &ops[3]);
            ring.ntt_inverse(&mut c0);
            ring.ntt_inverse(&mut c1);
            ring.ntt_inverse(&mut c2);
            [c0, c1, c2]
        }
        let cq = tensor(rq, &q_ops);
        let ce = tensor(re, &e_ops);
        // Scale each component by t/q back into Q.
        let polys = cq
            .iter()
            .zip(ce.iter())
            .map(|(q_part, e_part)| self.scale_round_rns(q_part, e_part))
            .collect();
        let mut out = Ciphertext::new(polys);
        out.ct_depth = a.ct_depth.max(b.ct_depth) + 1;
        out
    }

    /// The backend this context's `mul_no_relin`/`mul_ct` dispatch to.
    pub fn backend(&self) -> MulBackend {
        self.params.mul_backend
    }
}

#[cfg(test)]
mod tests {
    use std::sync::Arc;

    use super::super::keys::keygen;
    use super::super::params::{FvParams, MulBackend};
    use super::super::rng::ChaChaRng;
    use super::*;
    use crate::fhe::encoding::encode_int;

    fn ctx_pair(
        d: usize,
        l: usize,
        t_bits: usize,
    ) -> (Arc<FvContext>, Arc<FvContext>) {
        let mut params = FvParams::custom(d, l, t_bits);
        params.mul_backend = MulBackend::FullRns;
        let rns = FvContext::new(params.clone());
        params.mul_backend = MulBackend::ExactBigint;
        (rns, FvContext::new(params))
    }

    #[test]
    fn q_to_ext_matches_bigint_lift() {
        let (ctx, _) = ctx_pair(256, 3, 24);
        let mut rng = ChaChaRng::from_seed(91);
        // Encryption-shaped data: uniform residues.
        let poly = ctx.ring_q.sample_uniform(&mut rng);
        let ext = ctx.q_to_ext(&poly);
        let lifted = FvContext::lift_signed_poly(&ctx.ring_q, &poly);
        for (e, &p) in ctx.ring_ext.basis.primes.iter().enumerate() {
            for (c, v) in lifted.iter().enumerate() {
                assert_eq!(ext.planes[e][c], v.mod_u64(p), "plane {e} coeff {c}");
            }
        }
    }

    #[test]
    fn rns_and_bigint_tensor_decrypt_identically() {
        // The cross-backend parity oracle at the single-multiply level:
        // identical ciphertext inputs, decrypt-equal outputs, on both
        // the 3-component tensor and the relinearised product.
        let (rns_ctx, big_ctx) = ctx_pair(256, 3, 24);
        let mut rng = ChaChaRng::from_seed(92);
        let keys = keygen(&rns_ctx, &mut rng);
        use crate::util::prop::{gen, PropRunner};
        let mut run = PropRunner::new("rns_mul_parity", 8);
        run.run(|rng| {
            let a = gen::int_in(rng, -2000, 2000);
            let b = gen::int_in(rng, -2000, 2000);
            let ca = rns_ctx.encrypt(&encode_int(a, rns_ctx.d()), &keys.pk, rng);
            let cb = rns_ctx.encrypt(&encode_int(b, rns_ctx.d()), &keys.pk, rng);
            let raw_rns = rns_ctx.mul_no_relin_rns(&ca, &cb);
            let raw_big = big_ctx.mul_no_relin_bigint(&ca, &cb);
            assert_eq!(
                rns_ctx.decrypt(&raw_rns, &keys.sk),
                big_ctx.decrypt(&raw_big, &keys.sk),
                "3-component tensors must decrypt identically"
            );
            let full_rns = rns_ctx.mul_ct(&ca, &cb, &keys.rk);
            let full_big = big_ctx.mul_ct(&ca, &cb, &keys.rk);
            let dec = rns_ctx.decrypt(&full_rns, &keys.sk);
            assert_eq!(dec, big_ctx.decrypt(&full_big, &keys.sk));
            assert_eq!(dec.eval_at_2().to_i128(), Some(a as i128 * b as i128));
        });
    }

    #[test]
    fn scale_round_matches_oracle_planes() {
        // Beyond decrypt-equality: on in-range random tensor data the
        // two scale-and-rounds agree coefficient-for-coefficient up to
        // the ±1 rounding-tie ulp.
        let (ctx, _) = ctx_pair(256, 3, 20);
        let mut rng = ChaChaRng::from_seed(93);
        // Build an in-range v by tensoring two fresh-ciphertext-like
        // polynomials through the oracle lift.
        let x = ctx.ring_q.sample_uniform(&mut rng);
        let y = ctx.ring_q.sample_uniform(&mut rng);
        let vq = ctx.ring_q.polymul(&x, &y);
        let v_ext = {
            let xe = ctx.q_to_ext(&x);
            let ye = ctx.q_to_ext(&y);
            ctx.ring_ext.polymul(&xe, &ye)
        };
        let rns_out = ctx.scale_round_rns(&vq, &v_ext);
        let big_out = {
            let xb = ctx.q_to_big(&x);
            let yb = ctx.q_to_big(&y);
            ctx.scale_round_to_q(&ctx.ring_big.polymul(&xb, &yb))
        };
        let primes = &ctx.ring_q.basis.primes;
        for (l, &p) in primes.iter().enumerate() {
            for c in 0..ctx.d() {
                let a = rns_out.planes[l][c];
                let b = big_out.planes[l][c];
                let diff = crate::math::modarith::center(
                    crate::math::modarith::submod(a, b, p),
                    p,
                );
                assert!(diff.abs() <= 1, "plane {l} coeff {c}: diff {diff}");
            }
        }
    }
}
