//! Plaintext polynomials in the message ring `R_t = Z_t[x]/(x^d + 1)`.
//!
//! Messages are polynomials with (potentially huge) signed coefficients,
//! stored symmetric mod t. Fresh encodings have coefficients in
//! {-1, 0, 1} (§3.1 binary decomposition with `m(2) = ż`); homomorphic
//! arithmetic grows both degree and coefficients, exactly as bounded by
//! the paper's Lemma 3.

use std::sync::Arc;

use crate::math::bigint::{BigInt, BigUint};
use crate::math::poly::RnsPoly;

/// A plaintext polynomial: signed coefficients, length = ring degree
/// (trailing zeros allowed), reduced to the symmetric range mod t.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct Plaintext {
    pub coeffs: Vec<BigInt>,
}

/// A plaintext operand cached in evaluation form: the message reduced
/// to Q-basis residues and NTT'd **once**, then `Arc`-shared across
/// iterations and worker threads. Built by
/// [`FvContext::prepare_plaintext`](super::context::FvContext::prepare_plaintext);
/// consumed by `mul_plain_prepared`, which therefore spends zero NTT
/// transforms on the plaintext side no matter how many ciphertexts the
/// operand multiplies (the GD/NAG/VWT step constants and the CD carry
/// constant are reused `O(N·K)` times each).
#[derive(Clone, Debug)]
pub struct PlaintextNtt {
    /// The cached evaluation-form operand (always `Rep::Ntt`, Q basis).
    pub m_ntt: Arc<RnsPoly>,
}

impl Plaintext {
    pub fn zero(d: usize) -> Self {
        Plaintext { coeffs: vec![BigInt::zero(); d] }
    }

    pub fn from_signed(d: usize, small: &[i64]) -> Self {
        assert!(small.len() <= d);
        let mut coeffs = vec![BigInt::zero(); d];
        for (i, &c) in small.iter().enumerate() {
            coeffs[i] = BigInt::from_i64(c);
        }
        Plaintext { coeffs }
    }

    /// Degree of the highest nonzero coefficient (-1 for the zero poly).
    pub fn degree(&self) -> isize {
        for i in (0..self.coeffs.len()).rev() {
            if !self.coeffs[i].is_zero() {
                return i as isize;
            }
        }
        -1
    }

    /// `max_i |c_i|`.
    pub fn linf(&self) -> BigUint {
        let mut best = BigUint::zero();
        for c in &self.coeffs {
            if c.mag.cmp_big(&best) == std::cmp::Ordering::Greater {
                best = c.mag.clone();
            }
        }
        best
    }

    /// `Σ_i |c_i|` — controls plaintext-multiplication noise growth.
    pub fn l1(&self) -> BigUint {
        let mut acc = BigUint::zero();
        for c in &self.coeffs {
            acc = acc.add(&c.mag);
        }
        acc
    }

    /// Exact evaluation at x = 2 (the §3.1 decode point).
    pub fn eval_at_2(&self) -> BigInt {
        // Horner from the top.
        let mut acc = BigInt::zero();
        for c in self.coeffs.iter().rev() {
            acc = acc.mul_i64(2).add(c);
        }
        acc
    }

    /// Evaluation at 2 divided by an exact big scale, as f64 — the secret
    /// key holder's final rescaling step. Works even when both numerator
    /// and denominator far exceed f64 range.
    pub fn eval_at_2_scaled(&self, divisor: &BigUint) -> f64 {
        let v = self.eval_at_2();
        let (nm, ne) = v.mag.to_f64_exp();
        let (dm, de) = divisor.to_f64_exp();
        if nm == 0.0 {
            return 0.0;
        }
        let val = (nm / dm) * 2f64.powi((ne - de) as i32);
        if v.neg {
            -val
        } else {
            val
        }
    }

    /// Reduce coefficients into the symmetric range mod t.
    pub fn reduce_sym(&mut self, t: &BigUint) {
        let half = t.shr_bits(1);
        for c in self.coeffs.iter_mut() {
            let r = c.rem_euclid_big(t);
            *c = if r.cmp_big(&half) == std::cmp::Ordering::Greater {
                BigInt { neg: true, mag: t.sub(&r) }
            } else {
                BigInt::from_biguint(r)
            };
        }
    }

    /// Message-space addition (no modular reduction — callers reduce).
    pub fn add(&self, other: &Self) -> Self {
        assert_eq!(self.coeffs.len(), other.coeffs.len());
        Plaintext {
            coeffs: (0..self.coeffs.len())
                .map(|i| self.coeffs[i].add(&other.coeffs[i]))
                .collect(),
        }
    }

    /// Message-space negacyclic product (exact, schoolbook) — the oracle
    /// for what homomorphic multiplication must do to messages.
    pub fn mul(&self, other: &Self) -> Self {
        let d = self.coeffs.len();
        assert_eq!(other.coeffs.len(), d);
        let mut out = vec![BigInt::zero(); d];
        for i in 0..d {
            if self.coeffs[i].is_zero() {
                continue;
            }
            for j in 0..d {
                if other.coeffs[j].is_zero() {
                    continue;
                }
                let prod = self.coeffs[i].mul(&other.coeffs[j]);
                let k = i + j;
                if k < d {
                    out[k] = out[k].add(&prod);
                } else {
                    out[k - d] = out[k - d].sub(&prod); // x^d = -1
                }
            }
        }
        Plaintext { coeffs: out }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn eval_at_2_binary() {
        // 1 + x + x^3 at 2 = 1 + 2 + 8 = 11.
        let p = Plaintext::from_signed(8, &[1, 1, 0, 1]);
        assert_eq!(p.eval_at_2().to_i128(), Some(11));
        assert_eq!(p.degree(), 3);
    }

    #[test]
    fn eval_negative() {
        let p = Plaintext::from_signed(8, &[-1, -1, 0, -1]);
        assert_eq!(p.eval_at_2().to_i128(), Some(-11));
    }

    #[test]
    fn mul_preserves_eval_at_2() {
        // As long as no negacyclic wrap happens, (p·q)(2) = p(2)·q(2).
        let p = Plaintext::from_signed(32, &[1, 0, 1]); // 5
        let q = Plaintext::from_signed(32, &[1, 1, 1]); // 7
        let r = p.mul(&q);
        assert_eq!(r.eval_at_2().to_i128(), Some(35));
        assert_eq!(r.degree(), 4);
    }

    #[test]
    fn negacyclic_wrap_changes_eval() {
        // Degree overflow wraps with a sign: x^3 · x^1 = -1 in d = 4.
        let p = Plaintext::from_signed(4, &[0, 0, 0, 1]);
        let q = Plaintext::from_signed(4, &[0, 1]);
        let r = p.mul(&q);
        assert_eq!(r.coeffs[0].to_i128(), Some(-1));
    }

    #[test]
    fn linf_l1() {
        let p = Plaintext::from_signed(8, &[3, -4, 0, 2]);
        assert_eq!(p.linf().to_u64(), Some(4));
        assert_eq!(p.l1().to_u64(), Some(9));
    }

    #[test]
    fn reduce_sym_wraps() {
        let t = BigUint::from_u64(7);
        let mut p = Plaintext::from_signed(4, &[6, -6, 10, 3]);
        p.reduce_sym(&t);
        assert_eq!(p.coeffs[0].to_i128(), Some(-1)); // 6 ≡ -1 mod 7
        assert_eq!(p.coeffs[1].to_i128(), Some(1));
        assert_eq!(p.coeffs[2].to_i128(), Some(3));
        assert_eq!(p.coeffs[3].to_i128(), Some(3));
    }

    #[test]
    fn scaled_eval() {
        let p = Plaintext::from_signed(8, &[0, 0, 0, 0, 0, 1]); // 32
        let v = p.eval_at_2_scaled(&BigUint::from_u64(64));
        assert!((v - 0.5).abs() < 1e-15);
    }
}
