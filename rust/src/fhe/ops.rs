//! Homomorphic operations: encryption, decryption, ⊕, ⊗, plaintext ops
//! and relinearisation (textbook FV, RNS ciphertexts).
//!
//! The ⊗ tensor/scale pipeline dispatches on the context's
//! [`MulBackend`]: the default full-RNS path
//! ([`FvContext::mul_no_relin_rns`], see `fhe/rns_mul.rs`) and the
//! exact-bigint oracle ([`FvContext::mul_no_relin_bigint`]).
//! Relinearisation uses the per-limb RNS gadget on both backends, so
//! [`FvContext::relin_digits`] never lifts; the key-limb inner
//! products accumulate lazily in `u128` and pay one Barrett reduction
//! per coefficient for the whole digit sum.

use crate::math::poly::{Rep, RnsPoly};
use crate::util::telemetry::{self, Phase};

use super::ciphertext::Ciphertext;
use super::context::FvContext;
use super::keys::{GaloisKey, GaloisKeys, PublicKey, RelinKey, SecretKey};
use super::params::MulBackend;
use super::plaintext::Plaintext;
use super::rng::ChaChaRng;
use super::sampler::{sample_error, sample_ternary};

impl FvContext {
    /// Public-key encryption: `(Δm + b·u + e₁, a·u + e₂)`.
    pub fn encrypt(&self, pt: &Plaintext, pk: &PublicKey, rng: &mut ChaChaRng) -> Ciphertext {
        let ring = &self.ring_q;
        let mut u_ntt = sample_ternary(ring, rng);
        ring.ntt_forward(&mut u_ntt);
        let e1 = sample_error(ring, rng, self.params.cbd_k);
        let e2 = sample_error(ring, rng, self.params.cbd_k);
        let mut c0 = ring.mul_ntt(&pk.b_ntt, &u_ntt);
        ring.ntt_inverse(&mut c0);
        ring.add_assign(&mut c0, &e1);
        ring.add_assign(&mut c0, &self.delta_times_pt(pt));
        let mut c1 = ring.mul_ntt(&pk.a_ntt, &u_ntt);
        ring.ntt_inverse(&mut c1);
        ring.add_assign(&mut c1, &e2);
        Ciphertext::new(vec![c0, c1])
    }

    /// Secret-key (symmetric) encryption: `(Δm - (a·s + e), a)`.
    pub fn encrypt_sym(&self, pt: &Plaintext, sk: &SecretKey, rng: &mut ChaChaRng) -> Ciphertext {
        let ring = &self.ring_q;
        let a = ring.sample_uniform(rng);
        let mut a_ntt = a.clone();
        ring.ntt_forward(&mut a_ntt);
        let e = sample_error(ring, rng, self.params.cbd_k);
        let mut as_prod = ring.mul_ntt(&a_ntt, &sk.s_ntt);
        ring.ntt_inverse(&mut as_prod);
        let mut c0 = self.delta_times_pt(pt);
        c0 = ring.sub(&c0, &ring.add(&as_prod, &e));
        Ciphertext::new(vec![c0, a])
    }

    /// Decryption: `⌊t·[c₀ + c₁s (+ c₂s²)]_q / q⌉ mod t`.
    pub fn decrypt(&self, ct: &Ciphertext, sk: &SecretKey) -> Plaintext {
        self.decrypt_scale(&self.raw_phase(ct, sk))
    }

    /// `[c₀ + c₁s (+ c₂s²)]_q` — the decryption phase polynomial (also
    /// used by the noise meter). Accepts any component residency: an
    /// NTT-resident `c₁`/`c₂` skips its forward transform, a
    /// NTT-resident `c₀` pays one lazy inverse. Always returns `Coeff`
    /// (the CRT lift that follows needs power-basis coefficients).
    pub fn raw_phase(&self, ct: &Ciphertext, sk: &SecretKey) -> RnsPoly {
        let ring = &self.ring_q;
        assert!(ct.len() >= 2 && ct.len() <= 3, "ciphertext must have 2 or 3 polys");
        let c1 = ring.ntt_form(&ct.polys[1]);
        let mut v = ring.mul_ntt(c1.as_ref(), &sk.s_ntt);
        if ct.len() == 3 {
            let c2 = ring.ntt_form(&ct.polys[2]);
            let c2s2 = ring.mul_ntt(c2.as_ref(), &sk.s2_ntt);
            v = ring.add(&v, &c2s2);
        }
        ring.ntt_inverse(&mut v);
        ring.add(&v, ring.coeff_form(&ct.polys[0]).as_ref())
    }

    /// Shared component-matching walk for ⊕/⊖ (supports mixed 2/3-
    /// component operands and mixed per-component residency):
    /// plane-wise, no zero-polynomial temporaries — `both` combines
    /// components present on both sides, `only_b` handles a component
    /// `b` has and `a` lacks (identity for add, negation for sub).
    fn zip_ct(
        &self,
        a: &Ciphertext,
        b: &Ciphertext,
        both: impl Fn(&RnsPoly, &RnsPoly) -> RnsPoly,
        only_b: impl Fn(&RnsPoly) -> RnsPoly,
    ) -> Ciphertext {
        let n = a.len().max(b.len());
        let mut polys = Vec::with_capacity(n);
        for i in 0..n {
            polys.push(match (a.polys.get(i), b.polys.get(i)) {
                (Some(pa), Some(pb)) => both(pa, pb),
                (Some(pa), None) => pa.clone(),
                (None, Some(pb)) => only_b(pb),
                (None, None) => unreachable!("component below max(len)"),
            });
        }
        let mut out = Ciphertext::new(polys);
        out.ct_depth = a.ct_depth.max(b.ct_depth);
        out
    }

    /// Homomorphic addition.
    pub fn add_ct(&self, a: &Ciphertext, b: &Ciphertext) -> Ciphertext {
        let ring = &self.ring_q;
        self.zip_ct(a, b, |pa, pb| ring.add_mixed(pa, pb), |pb| pb.clone())
    }

    /// Homomorphic subtraction — without materialising a negated
    /// temporary ciphertext.
    pub fn sub_ct(&self, a: &Ciphertext, b: &Ciphertext) -> Ciphertext {
        let ring = &self.ring_q;
        self.zip_ct(a, b, |pa, pb| ring.sub_mixed(pa, pb), |pb| ring.neg(pb))
    }

    /// Homomorphic negation (representation-agnostic: negation is
    /// element-wise in both domains).
    pub fn neg_ct(&self, a: &Ciphertext) -> Ciphertext {
        let mut out = a.clone();
        for p in out.polys.iter_mut() {
            *p = self.ring_q.neg(p);
        }
        out
    }

    /// Add a plaintext: `c₀ += Δ·m` (if `c₀` is NTT-resident the Δ·m
    /// term is transformed instead, keeping the residency).
    pub fn add_plain(&self, a: &Ciphertext, pt: &Plaintext) -> Ciphertext {
        let mut out = a.clone();
        out.polys[0] = self.ring_q.add_mixed(&out.polys[0], &self.delta_times_pt(pt));
        out
    }

    /// Multiply by a plaintext polynomial (noise grows by ℓ1(m); message
    /// degree grows by deg(m); **no** ciphertext-depth level consumed).
    /// One-shot form: encodes + transforms the plaintext here. For
    /// operands reused across calls, cache with
    /// [`prepare_plaintext`](Self::prepare_plaintext) and call
    /// [`mul_plain_prepared`](Self::mul_plain_prepared).
    pub fn mul_plain(&self, a: &Ciphertext, pt: &Plaintext) -> Ciphertext {
        self.mul_plain_prepared(a, &self.prepare_plaintext(pt))
    }

    /// Multiply by a cached NTT-form plaintext operand: zero transforms
    /// on the plaintext, at most one forward per ciphertext component
    /// that is not already NTT-resident, and **no inverse** — the
    /// product stays NTT-resident for the next pointwise op.
    pub fn mul_plain_prepared(
        &self,
        a: &Ciphertext,
        m: &crate::fhe::plaintext::PlaintextNtt,
    ) -> Ciphertext {
        let ring = &self.ring_q;
        let mut out = a.clone();
        for p in out.polys.iter_mut() {
            ring.ensure_ntt(p);
            *p = ring.mul_ntt(p, &m.m_ntt);
        }
        out
    }

    /// The BFV tensor product **without** relinearisation: returns a
    /// 3-component ciphertext. Exposed for tests and for fused
    /// inner-product accumulation (relinearise once per sum).
    /// Dispatches on the context's [`MulBackend`].
    pub fn mul_no_relin(&self, a: &Ciphertext, b: &Ciphertext) -> Ciphertext {
        match self.params.mul_backend {
            MulBackend::FullRns => self.mul_no_relin_rns(a, b),
            MulBackend::ExactBigint => self.mul_no_relin_bigint(a, b),
        }
    }

    /// [`mul_no_relin`](Self::mul_no_relin) with caller-owned scratch
    /// and an intra-multiply worker budget (full-RNS backend only; the
    /// bigint oracle ignores both).
    pub fn mul_no_relin_with(
        &self,
        a: &Ciphertext,
        b: &Ciphertext,
        scratch: &mut crate::fhe::rns_mul::MulScratch,
        workers: usize,
    ) -> Ciphertext {
        match self.params.mul_backend {
            MulBackend::FullRns => self.mul_no_relin_rns_with(a, b, scratch, workers),
            MulBackend::ExactBigint => self.mul_no_relin_bigint(a, b),
        }
    }

    /// The exact-bigint tensor product (per-coefficient CRT lifts into
    /// the joint Q∪E basis, exact `⌊t·v/q⌉`). Kept as the correctness
    /// oracle for the full-RNS pipeline.
    pub fn mul_no_relin_bigint(&self, a: &Ciphertext, b: &Ciphertext) -> Ciphertext {
        assert_eq!(a.len(), 2, "operands must be relinearised");
        assert_eq!(b.len(), 2);
        let big = &self.ring_big;
        // Tensor product (exact over the joint basis).
        let [a0, a1, b0, b1] = self.big_tensor_operands(a, b);
        let mut c0 = big.mul_ntt(&a0, &b0);
        let mut c1 = big.add(&big.mul_ntt(&a0, &b1), &big.mul_ntt(&a1, &b0));
        let mut c2 = big.mul_ntt(&a1, &b1);
        big.ntt_inverse(&mut c0);
        big.ntt_inverse(&mut c1);
        big.ntt_inverse(&mut c2);
        // Scale each by t/q with exact rounding, back in the Q basis.
        let _span = telemetry::span(Phase::ScaleRound);
        let polys = vec![
            self.scale_round_to_q(&c0),
            self.scale_round_to_q(&c1),
            self.scale_round_to_q(&c2),
        ];
        self.ring_q.note_scale_round();
        let mut out = Ciphertext::new(polys);
        out.ct_depth = a.ct_depth.max(b.ct_depth) + 1;
        out
    }

    /// Lift one operand pair's four polynomials into the joint Q∪E
    /// basis in NTT form (the CRT lift needs power-basis coefficients,
    /// so NTT-resident operands are lazily brought back first).
    fn big_tensor_operands(&self, a: &Ciphertext, b: &Ciphertext) -> [RnsPoly; 4] {
        assert_eq!(a.len(), 2, "operands must be relinearised");
        assert_eq!(b.len(), 2);
        let rq = &self.ring_q;
        let big = &self.ring_big;
        [&a.polys[0], &a.polys[1], &b.polys[0], &b.polys[1]].map(|p| {
            let mut lifted = self.q_to_big(rq.coeff_form(p).as_ref());
            big.ntt_forward(&mut lifted);
            lifted
        })
    }

    /// Fused inner product `Σ_k a_k·b_k` **without** relinearisation:
    /// returns one 3-component ciphertext for the whole group, paying
    /// the scale-and-round pipeline once per accumulation chunk (see
    /// [`fuse_chunk`](Self::fuse_chunk)) instead of once per pair.
    /// Dispatches on the context's [`MulBackend`]. A one-pair group is
    /// bit-identical to [`mul_no_relin`](Self::mul_no_relin).
    pub fn dot_no_relin(&self, pairs: &[(&Ciphertext, &Ciphertext)]) -> Ciphertext {
        match self.params.mul_backend {
            MulBackend::FullRns => self.dot_no_relin_rns(pairs),
            MulBackend::ExactBigint => self.dot_no_relin_bigint(pairs),
        }
    }

    /// [`dot_no_relin`](Self::dot_no_relin) with caller-owned scratch
    /// and an intra-group worker budget (full-RNS backend only; the
    /// bigint oracle ignores both).
    pub fn dot_no_relin_with(
        &self,
        pairs: &[(&Ciphertext, &Ciphertext)],
        scratch: &mut crate::fhe::rns_mul::MulScratch,
        workers: usize,
    ) -> Ciphertext {
        match self.params.mul_backend {
            MulBackend::FullRns => self.dot_no_relin_rns_with(pairs, scratch, workers),
            MulBackend::ExactBigint => self.dot_no_relin_bigint(pairs),
        }
    }

    /// The exact-bigint fused inner product: the parity oracle sums
    /// the per-pair tensors **in the joint Q∪E basis, before the
    /// per-coefficient CRT lift**, so the summed value is scaled and
    /// rounded exactly once per chunk — the reference semantics the
    /// full-RNS accumulation is tested against.
    pub fn dot_no_relin_bigint(&self, pairs: &[(&Ciphertext, &Ciphertext)]) -> Ciphertext {
        assert!(!pairs.is_empty(), "dot group must be non-empty");
        let mut acc: Option<Ciphertext> = None;
        for part in pairs.chunks(self.fuse_chunk_big) {
            let ct = self.dot_chunk_bigint(part);
            acc = Some(match acc {
                None => ct,
                Some(prev) => self.add_ct(&prev, &ct),
            });
        }
        acc.unwrap()
    }

    /// One oracle accumulation chunk: `u128` lazy tensor accumulation
    /// over the joint-basis NTT planes, one exact scale-and-round for
    /// the three summed components.
    fn dot_chunk_bigint(&self, pairs: &[(&Ciphertext, &Ciphertext)]) -> Ciphertext {
        let big = &self.ring_big;
        let mut accs =
            [big.ntt_accumulator(), big.ntt_accumulator(), big.ntt_accumulator()];
        let mut depth = 0u32;
        for (a, b) in pairs {
            depth = depth.max(a.ct_depth).max(b.ct_depth);
            let [a0, a1, b0, b1] = self.big_tensor_operands(a, b);
            big.acc_mul_ntt(&mut accs[0], &a0, &b0);
            big.acc_mul_ntt(&mut accs[1], &a0, &b1);
            big.acc_mul_ntt(&mut accs[1], &a1, &b0);
            big.acc_mul_ntt(&mut accs[2], &a1, &b1);
        }
        let _span = telemetry::span(Phase::ScaleRound);
        let polys = accs
            .iter()
            .map(|acc| {
                let mut v = big.acc_reduce(acc);
                big.ntt_inverse(&mut v);
                self.scale_round_to_q(&v)
            })
            .collect();
        self.ring_q.note_scale_round();
        let mut out = Ciphertext::new(polys);
        out.ct_depth = depth + 1;
        out
    }

    /// Relinearised fused inner product `Σ_k a_k·b_k` — the per-group
    /// primitive behind `HeEngine::dot_pairs`: one gadget
    /// relinearisation for the whole group, whatever its length.
    pub fn dot_group(&self, pairs: &[(&Ciphertext, &Ciphertext)], rk: &RelinKey) -> Ciphertext {
        self.relinearize(&self.dot_no_relin(pairs), rk)
    }

    /// [`dot_group`](Self::dot_group) with caller-owned scratch and an
    /// intra-group worker budget — the per-worker form the native
    /// engine's `dot_pairs` fan-out drives.
    pub fn dot_group_with(
        &self,
        pairs: &[(&Ciphertext, &Ciphertext)],
        rk: &RelinKey,
        scratch: &mut crate::fhe::rns_mul::MulScratch,
        workers: usize,
    ) -> Ciphertext {
        self.relinearize(&self.dot_no_relin_with(pairs, scratch, workers), rk)
    }

    /// Per-limb RNS gadget decomposition: `poly = Σ_i D_i·(q/q_i)
    /// (mod q)` with `D_i = [poly·(q/q_i)^{-1}]_{q_i}` read straight
    /// off residue plane `i` — `‖D_i‖∞ < q_i < 2^30`, no CRT lift.
    /// Returned in coefficient representation (shared by the native
    /// and XLA relinearisation paths).
    pub fn relin_digits(&self, poly: &RnsPoly) -> Vec<RnsPoly> {
        debug_assert_eq!(poly.rep, Rep::Coeff);
        let ring = &self.ring_q;
        (0..ring.nlimbs())
            .map(|i| {
                let inv = &ring.basis.crt_inv_shoup[i];
                let mut di = ring.zero();
                for c in 0..ring.d {
                    let digit = inv.mul(poly.planes[i][c]);
                    for (l, br) in ring.basis.barrett.iter().enumerate() {
                        di.planes[l][c] =
                            if digit < br.modulus() { digit } else { br.reduce(digit as u128) };
                    }
                }
                di
            })
            .collect()
    }

    /// Fold the degree-2 component back onto (c₀, c₁) with the
    /// relinearisation key (per-limb RNS gadget decomposition). The
    /// digit×key-limb products accumulate unreduced in `u128`; the
    /// whole sum pays one Barrett reduction per coefficient. The
    /// result stays **NTT-resident**: instead of inverse-transforming
    /// the two accumulators, the two tensor components are forward-
    /// transformed into them (same transform count, and the product is
    /// immediately consumable by the pointwise ops that follow it in
    /// the descent loops).
    pub fn relinearize(&self, ct: &Ciphertext, rk: &RelinKey) -> Ciphertext {
        let _span = telemetry::span(Phase::Relinearise);
        assert_eq!(ct.len(), 3, "nothing to relinearise");
        let ring = &self.ring_q;
        ring.note_relin();
        let mut lazy0 = ring.ntt_accumulator();
        let mut lazy1 = ring.ntt_accumulator();
        for (j, mut dj) in
            self.relin_digits(ring.coeff_form(&ct.polys[2]).as_ref()).into_iter().enumerate()
        {
            ring.ntt_forward(&mut dj);
            ring.acc_mul_ntt(&mut lazy0, &dj, &rk.b_ntt[j]);
            ring.acc_mul_ntt(&mut lazy1, &dj, &rk.a_ntt[j]);
        }
        let mut acc0 = ring.acc_reduce(&lazy0);
        let mut acc1 = ring.acc_reduce(&lazy1);
        ring.add_assign(&mut acc0, ring.ntt_form(&ct.polys[0]).as_ref());
        ring.add_assign(&mut acc1, ring.ntt_form(&ct.polys[1]).as_ref());
        let mut out = Ciphertext::new(vec![acc0, acc1]);
        out.ct_depth = ct.ct_depth;
        out
    }

    /// Apply the Galois automorphism `x ↦ x^g` to a 2-component
    /// ciphertext and key-switch the rotated `σ(c₁)` back to the
    /// original secret key with the matching [`GaloisKey`]. Reuses the
    /// per-limb gadget pipeline of [`relinearize`](Self::relinearize):
    /// digits of `σ(c₁)` accumulate lazily against the key limbs, one
    /// Barrett reduction per coefficient for the whole sum, and the
    /// output stays **NTT-resident** (σ(c₀) is forward-transformed
    /// into the accumulator instead of inverse-transforming it).
    /// Rotation costs no ciphertext-depth level; noise grows
    /// additively like a relinearisation.
    pub fn apply_galois(&self, ct: &Ciphertext, gk: &GaloisKey) -> Ciphertext {
        let _span = telemetry::span(Phase::GaloisKeySwitch);
        assert_eq!(ct.len(), 2, "rotate a relinearised (2-component) ciphertext");
        let ring = &self.ring_q;
        ring.note_rotation();
        let c0 = ring.automorphism(ring.coeff_form(&ct.polys[0]).as_ref(), gk.galois);
        let c1 = ring.automorphism(ring.coeff_form(&ct.polys[1]).as_ref(), gk.galois);
        let mut lazy0 = ring.ntt_accumulator();
        let mut lazy1 = ring.ntt_accumulator();
        for (j, mut dj) in self.relin_digits(&c1).into_iter().enumerate() {
            ring.ntt_forward(&mut dj);
            ring.acc_mul_ntt(&mut lazy0, &dj, &gk.b_ntt[j]);
            ring.acc_mul_ntt(&mut lazy1, &dj, &gk.a_ntt[j]);
        }
        let mut acc0 = ring.acc_reduce(&lazy0);
        let acc1 = ring.acc_reduce(&lazy1);
        ring.add_assign(&mut acc0, ring.ntt_form(&c0).as_ref());
        let mut out = Ciphertext::new(vec![acc0, acc1]);
        out.ct_depth = ct.ct_depth;
        out
    }

    /// Rotate both packed rows left by `steps` slots: slot `j` of the
    /// result holds slot `j + steps (mod d/2)` of the input, within
    /// each row. Binary step decomposition over the cached `3^{2^k}`
    /// keys — at most `log₂(d/2)` key-switches for any step count.
    pub fn rotate_rows(&self, ct: &Ciphertext, steps: usize, gks: &GaloisKeys) -> Ciphertext {
        let half = self.d() / 2;
        let m = 2 * self.d();
        let mut steps = steps % half.max(1);
        let mut out = ct.clone();
        let mut g = 3 % m;
        let mut span = 1usize;
        while steps > 0 && span < half {
            if steps & span != 0 {
                let key = gks
                    .get(g)
                    .unwrap_or_else(|| panic!("missing Galois key for x ↦ x^{g} (packed keygen?)"));
                out = self.apply_galois(&out, key);
                steps &= !span;
            }
            g = (g * g) % m;
            span <<= 1;
        }
        out
    }

    /// Swap the two packed rows (the `x ↦ x^{2d−1}` automorphism):
    /// slot `j` trades places with slot `d/2 + j`.
    pub fn swap_rows(&self, ct: &Ciphertext, gks: &GaloisKeys) -> Ciphertext {
        let g = 2 * self.d() - 1;
        let key = gks
            .get(g)
            .unwrap_or_else(|| panic!("missing Galois key for x ↦ x^{g} (packed keygen?)"));
        self.apply_galois(ct, key)
    }

    /// Sum every slot into every slot: `log₂(d/2)` doubling rotations
    /// fold each row onto itself, one row swap folds the rows
    /// together — `log₂(d/2) + 1` key-switches total, versus `d − 1`
    /// for naive slot extraction. The packed inner product reads the
    /// total from any slot afterwards.
    pub fn slot_sum(&self, ct: &Ciphertext, gks: &GaloisKeys) -> Ciphertext {
        let half = self.d() / 2;
        let mut acc = ct.clone();
        let mut span = 1usize;
        while span < half {
            acc = self.add_ct(&acc, &self.rotate_rows(&acc, span, gks));
            span <<= 1;
        }
        self.add_ct(&acc, &self.swap_rows(&acc, gks))
    }

    /// Full homomorphic multiplication: tensor, scale, relinearise.
    /// The product comes back NTT-resident (see
    /// [`relinearize`](Self::relinearize)).
    pub fn mul_ct(&self, a: &Ciphertext, b: &Ciphertext, rk: &RelinKey) -> Ciphertext {
        self.relinearize(&self.mul_no_relin(a, b), rk)
    }

    /// [`mul_ct`](Self::mul_ct) with caller-owned scratch and an
    /// intra-multiply worker budget — the per-worker form the native
    /// engine's `mul_pairs` fan-out drives.
    pub fn mul_ct_with(
        &self,
        a: &Ciphertext,
        b: &Ciphertext,
        rk: &RelinKey,
        scratch: &mut crate::fhe::rns_mul::MulScratch,
        workers: usize,
    ) -> Ciphertext {
        self.relinearize(&self.mul_no_relin_with(a, b, scratch, workers), rk)
    }
}

#[cfg(test)]
mod tests {
    use std::sync::Arc;

    use super::*;
    use crate::fhe::encoding::Encoder;
    use crate::fhe::keys::keygen;
    use crate::fhe::noise::noise_budget_bits;
    use crate::fhe::params::FvParams;

    fn setup(
        d: usize,
        l: usize,
        t_bits: usize,
        seed: u64,
    ) -> (Arc<FvContext>, super::super::keys::KeySet, ChaChaRng) {
        let ctx = FvContext::new(FvParams::custom(d, l, t_bits));
        let mut rng = ChaChaRng::from_seed(seed);
        let keys = keygen(&ctx, &mut rng);
        (ctx, keys, rng)
    }

    fn pt(ctx: &FvContext, coeffs: &[i64]) -> Plaintext {
        Plaintext::from_signed(ctx.d(), coeffs)
    }

    #[test]
    fn encrypt_decrypt_roundtrip() {
        let (ctx, keys, mut rng) = setup(256, 3, 24, 41);
        let m = pt(&ctx, &[1, -1, 0, 1, 1, 0, -1, 42, -99]);
        let ct = ctx.encrypt(&m, &keys.pk, &mut rng);
        let out = ctx.decrypt(&ct, &keys.sk);
        assert_eq!(out, {
            let mut e = m.clone();
            e.reduce_sym(&ctx.t);
            e
        });
        assert!(noise_budget_bits(&ctx, &ct, &keys.sk) > 20.0);
    }

    #[test]
    fn symmetric_encryption_roundtrip() {
        let (ctx, keys, mut rng) = setup(256, 3, 24, 42);
        let m = pt(&ctx, &[7, 0, -3]);
        let ct = ctx.encrypt_sym(&m, &keys.sk, &mut rng);
        assert_eq!(ctx.decrypt(&ct, &keys.sk).coeffs[0].to_i128(), Some(7));
        assert_eq!(ctx.decrypt(&ct, &keys.sk).coeffs[2].to_i128(), Some(-3));
    }

    #[test]
    fn homomorphic_addition() {
        let (ctx, keys, mut rng) = setup(256, 3, 24, 43);
        let (ma, mb) = (pt(&ctx, &[1, 2, -3]), pt(&ctx, &[10, -20, 30]));
        let ca = ctx.encrypt(&ma, &keys.pk, &mut rng);
        let cb = ctx.encrypt(&mb, &keys.pk, &mut rng);
        let sum = ctx.decrypt(&ctx.add_ct(&ca, &cb), &keys.sk);
        assert_eq!(sum.coeffs[0].to_i128(), Some(11));
        assert_eq!(sum.coeffs[1].to_i128(), Some(-18));
        assert_eq!(sum.coeffs[2].to_i128(), Some(27));
        let diff = ctx.decrypt(&ctx.sub_ct(&ca, &cb), &keys.sk);
        assert_eq!(diff.coeffs[0].to_i128(), Some(-9));
    }

    #[test]
    fn homomorphic_multiplication_matches_message_product() {
        let (ctx, keys, mut rng) = setup(256, 3, 24, 44);
        let ma = pt(&ctx, &[1, 1, 0, -1]); // m_a(2) = 1+2-8 = -5
        let mb = pt(&ctx, &[0, 1, 1]); // m_b(2) = 6
        let ca = ctx.encrypt(&ma, &keys.pk, &mut rng);
        let cb = ctx.encrypt(&mb, &keys.pk, &mut rng);
        let prod = ctx.mul_ct(&ca, &cb, &keys.rk);
        assert_eq!(prod.ct_depth, 1);
        let out = ctx.decrypt(&prod, &keys.sk);
        let mut expect = ma.mul(&mb);
        expect.reduce_sym(&ctx.t);
        assert_eq!(out, expect);
        assert_eq!(out.eval_at_2().to_i128(), Some(-30));
    }

    #[test]
    fn three_component_decryption_before_relin() {
        let (ctx, keys, mut rng) = setup(256, 3, 24, 45);
        let ma = pt(&ctx, &[3]);
        let mb = pt(&ctx, &[0, 1]);
        let ca = ctx.encrypt(&ma, &keys.pk, &mut rng);
        let cb = ctx.encrypt(&mb, &keys.pk, &mut rng);
        let raw = ctx.mul_no_relin(&ca, &cb);
        assert_eq!(raw.len(), 3);
        let out = ctx.decrypt(&raw, &keys.sk);
        assert_eq!(out.coeffs[1].to_i128(), Some(3)); // 3·x
    }

    #[test]
    fn plaintext_multiplication() {
        let (ctx, keys, mut rng) = setup(256, 3, 24, 46);
        let m = pt(&ctx, &[1, 0, -1]); // -3 at 2
        let c = ctx.encrypt(&m, &keys.pk, &mut rng);
        let k = pt(&ctx, &[1, 0, 1, 1]); // 13 at 2
        let out = ctx.decrypt(&ctx.mul_plain(&c, &k), &keys.sk);
        assert_eq!(out.eval_at_2().to_i128(), Some(-39));
        // No ciphertext depth consumed.
        assert_eq!(ctx.mul_plain(&c, &k).ct_depth, 0);
    }

    #[test]
    fn add_plain() {
        let (ctx, keys, mut rng) = setup(256, 3, 24, 47);
        let m = pt(&ctx, &[5]);
        let c = ctx.encrypt(&m, &keys.pk, &mut rng);
        let out = ctx.decrypt(&ctx.add_plain(&c, &pt(&ctx, &[-2, 1])), &keys.sk);
        assert_eq!(out.coeffs[0].to_i128(), Some(3));
        assert_eq!(out.coeffs[1].to_i128(), Some(1));
    }

    #[test]
    fn depth_two_chain() {
        // ((a·b)·c) with t small enough to leave budget.
        let (ctx, keys, mut rng) = setup(512, 5, 16, 48);
        let ma = pt(&ctx, &[0, 1]); // 2
        let mb = pt(&ctx, &[1, 1]); // 3
        let mc = pt(&ctx, &[1, 0, 1]); // 5
        let ca = ctx.encrypt(&ma, &keys.pk, &mut rng);
        let cb = ctx.encrypt(&mb, &keys.pk, &mut rng);
        let cc = ctx.encrypt(&mc, &keys.pk, &mut rng);
        let ab = ctx.mul_ct(&ca, &cb, &keys.rk);
        let abc = ctx.mul_ct(&ab, &cc, &keys.rk);
        assert_eq!(abc.ct_depth, 2);
        let out = ctx.decrypt(&abc, &keys.sk);
        assert_eq!(out.eval_at_2().to_i128(), Some(30));
    }

    #[test]
    fn mixed_circuit_property() {
        // Random circuits mixing add, sub, plaintext mul and one ct-mul
        // must track the reference integer computation exactly.
        use crate::util::prop::PropRunner;
        let (ctx, keys, _) = setup(256, 4, 22, 50);
        let mut run = PropRunner::new("fv_mixed_circuit", 8);
        run.run(|rng| {
            let vals: Vec<i64> =
                (0..3).map(|_| rng.uniform_below(401) as i64 - 200).collect();
            let cts: Vec<Ciphertext> = vals
                .iter()
                .map(|&v| {
                    ctx.encrypt(&crate::fhe::encoding::encode_int(v, ctx.d()), &keys.pk, rng)
                })
                .collect();
            let k = rng.uniform_below(31) as i64 - 15;
            let kp = crate::fhe::encoding::encode_int(k, ctx.d());
            // enc: ((a*b) - c) + k*a   (one ct-mul level)
            let ab = ctx.mul_ct(&cts[0], &cts[1], &keys.rk);
            let t1 = ctx.sub_ct(&ab, &cts[2]);
            let t2 = ctx.mul_plain(&cts[0], &kp);
            let out = ctx.decrypt(&ctx.add_ct(&t1, &t2), &keys.sk);
            let expect = (vals[0] as i128) * (vals[1] as i128) - vals[2] as i128
                + (k as i128) * (vals[0] as i128);
            assert_eq!(out.eval_at_2().to_i128(), Some(expect));
        });
    }

    #[test]
    fn cached_mul_plain_transform_budget() {
        // The acceptance contract for PlaintextNtt: zero transforms on
        // the plaintext per call, at most one per non-resident
        // ciphertext component, none at all once the ciphertext is
        // NTT-resident — verified through the ring's transform counter.
        let (ctx, keys, mut rng) = setup(256, 3, 24, 52);
        let ring = &ctx.ring_q;
        let m = pt(&ctx, &[1, 0, -1]); // -3 at 2
        let c = ctx.encrypt(&m, &keys.pk, &mut rng); // Coeff-resident
        let k = pt(&ctx, &[1, 0, 1, 1]); // 13 at 2
        let before = ring.transform_count();
        let cached = ctx.prepare_plaintext(&k);
        assert_eq!(ring.transform_count() - before, 1, "cache costs one transform, ever");
        // Cold ciphertext: one forward per component, nothing else.
        let before = ring.transform_count();
        let out = ctx.mul_plain_prepared(&c, &cached);
        assert_eq!(ring.transform_count() - before, c.len() as u64);
        assert!(out.is_ntt_resident());
        // NTT-resident ciphertext: zero transforms.
        let before = ring.transform_count();
        let out2 = ctx.mul_plain_prepared(&out, &cached);
        assert_eq!(ring.transform_count() - before, 0, "resident ct × cached pt is free");
        // And the arithmetic is the one-shot path's, bit for bit.
        let expect = ctx.decrypt(&ctx.mul_plain(&ctx.mul_plain(&c, &k), &k), &keys.sk);
        assert_eq!(ctx.decrypt(&out2, &keys.sk), expect);
        assert_eq!(expect.eval_at_2().to_i128(), Some(-3 * 13 * 13));
    }

    #[test]
    fn representation_invariance_exhaustive() {
        // Run one mixed circuit — ((a·b) − c) + k·a — with the five
        // ciphertext slots (3 inputs + 2 intermediates) forced into
        // every Coeff/Ntt residency combination, on both multiply
        // backends. Decryption must be bit-identical to the all-Coeff
        // path: representation is a managed property, never a value.
        use crate::fhe::encoding::encode_int;
        for backend in [MulBackend::FullRns, MulBackend::ExactBigint] {
            let mut params = crate::fhe::params::FvParams::custom(256, 4, 22);
            params.mul_backend = backend;
            let ctx = FvContext::new(params);
            let mut rng = ChaChaRng::from_seed(53);
            let keys = keygen(&ctx, &mut rng);
            let vals = [137i64, -89, 41];
            let k = -7i64;
            let kp = encode_int(k, ctx.d());
            let cts: Vec<Ciphertext> = vals
                .iter()
                .map(|&v| ctx.encrypt(&encode_int(v, ctx.d()), &keys.pk, &mut rng))
                .collect();
            let force = |ct: Ciphertext, to_ntt: bool| -> Ciphertext {
                let mut c = ct;
                for p in c.polys.iter_mut() {
                    if to_ntt {
                        ctx.ring_q.ensure_ntt(p);
                    } else {
                        ctx.ring_q.ensure_coeff(p);
                    }
                }
                c
            };
            let circuit = |mask: u32| -> Plaintext {
                let bit = |i: u32| (mask >> i) & 1 == 1;
                let a = force(cts[0].clone(), bit(0));
                let b = force(cts[1].clone(), bit(1));
                let c = force(cts[2].clone(), bit(2));
                let ab = force(ctx.mul_ct(&a, &b, &keys.rk), bit(3));
                let t1 = force(ctx.sub_ct(&ab, &c), bit(4));
                let t2 = ctx.mul_plain(&a, &kp);
                ctx.decrypt(&ctx.add_ct(&t1, &t2), &keys.sk)
            };
            let reference = circuit(0); // the all-Coeff path
            let expect = vals[0] as i128 * vals[1] as i128 - vals[2] as i128
                + k as i128 * vals[0] as i128;
            assert_eq!(reference.eval_at_2().to_i128(), Some(expect));
            for mask in 1u32..32 {
                assert_eq!(
                    circuit(mask),
                    reference,
                    "backend {backend:?} residency mask {mask:#07b}"
                );
            }
        }
    }

    #[test]
    fn fused_dot_group_parity_across_backends() {
        // dot_group on both multiply backends: decrypt-equal to the
        // fold of relinearised products, exactly one relinearisation
        // and one scale-and-round pipeline for the whole group.
        use crate::fhe::encoding::encode_int;
        let vals = [(31i64, -2i64), (5, 5), (-12, 3), (8, -9)];
        for backend in [MulBackend::FullRns, MulBackend::ExactBigint] {
            let mut params = FvParams::custom(256, 3, 24);
            params.mul_backend = backend;
            let ctx = FvContext::new(params);
            let mut rng = ChaChaRng::from_seed(54);
            let keys = keygen(&ctx, &mut rng);
            let cts: Vec<(Ciphertext, Ciphertext)> = vals
                .iter()
                .map(|&(a, b)| {
                    (
                        ctx.encrypt(&encode_int(a, ctx.d()), &keys.pk, &mut rng),
                        ctx.encrypt(&encode_int(b, ctx.d()), &keys.pk, &mut rng),
                    )
                })
                .collect();
            let pairs: Vec<(&Ciphertext, &Ciphertext)> =
                cts.iter().map(|(a, b)| (a, b)).collect();
            let ring = &ctx.ring_q;
            let (r0, s0) = (ring.relin_count(), ring.scale_round_count());
            let fused = ctx.dot_group(&pairs, &keys.rk);
            assert_eq!(ring.relin_count() - r0, 1, "{backend:?}: one relin per group");
            assert_eq!(
                ring.scale_round_count() - s0,
                1,
                "{backend:?}: one scale-round per group (no chunking at toy scale)"
            );
            assert_eq!(fused.len(), 2);
            assert!(fused.is_ntt_resident(), "relinearised output stays NTT-resident");
            let mut fold = ctx.mul_ct(pairs[0].0, pairs[0].1, &keys.rk);
            for (a, b) in &pairs[1..] {
                fold = ctx.add_ct(&fold, &ctx.mul_ct(a, b, &keys.rk));
            }
            let df = ctx.decrypt(&fused, &keys.sk);
            assert_eq!(df, ctx.decrypt(&fold, &keys.sk), "{backend:?}: fused vs fold");
            let expect: i128 = vals.iter().map(|&(a, b)| a as i128 * b as i128).sum();
            assert_eq!(df.eval_at_2().to_i128(), Some(expect), "{backend:?}");
        }
    }

    fn setup_packed(
        d: usize,
        l: usize,
        t_bits: usize,
        seed: u64,
    ) -> (Arc<FvContext>, super::super::keys::KeySet, ChaChaRng) {
        let params = FvParams::custom_packed(d, l, t_bits).expect("packed params");
        let ctx = FvContext::new(params);
        let mut rng = ChaChaRng::from_seed(seed);
        let keys = keygen(&ctx, &mut rng);
        (ctx, keys, rng)
    }

    #[test]
    fn rotation_decrypt_parity_with_slot_permutation() {
        // Encrypted rotate_rows must realise exactly the message-space
        // slot permutation the SlotEncoder promises: slot j of the
        // rotated ciphertext holds slot j+r (mod d/2) of the input,
        // rows independently.
        let (ctx, keys, mut rng) = setup_packed(256, 3, 24, 61);
        let d = ctx.d();
        let half = d / 2;
        let vals: Vec<i64> = (0..d as i64).map(|j| (j * j + 3) % 997).collect();
        let pt = ctx.encoder().encode_vec(&vals);
        let ct = ctx.encrypt(&pt, &keys.pk, &mut rng);
        for r in [1usize, 2, 37, half - 1] {
            let rot = ctx.rotate_rows(&ct, r, &keys.gk);
            assert_eq!(rot.len(), 2, "rotation preserves component count");
            assert_eq!(rot.ct_depth, ct.ct_depth, "rotation consumes no depth");
            let got = ctx.encoder().decode_vec(&ctx.decrypt(&rot, &keys.sk), d);
            for j in 0..half {
                assert_eq!(got[j].to_i128(), Some(vals[(j + r) % half] as i128), "row0 r={r}");
                assert_eq!(
                    got[half + j].to_i128(),
                    Some(vals[half + (j + r) % half] as i128),
                    "row1 r={r}"
                );
            }
        }
        // Row swap trades the two halves wholesale.
        let swap = ctx.swap_rows(&ct, &keys.gk);
        let swapped = ctx.encoder().decode_vec(&ctx.decrypt(&swap, &keys.sk), d);
        for j in 0..half {
            assert_eq!(swapped[j].to_i128(), Some(vals[half + j] as i128));
            assert_eq!(swapped[half + j].to_i128(), Some(vals[j] as i128));
        }
    }

    #[test]
    fn slot_sum_totals_every_slot_in_log_rotations() {
        // slot_sum leaves Σ vals in all d slots and pays exactly
        // log₂(d/2) + 1 key-switches — the O(log d) budget the packed
        // inner product is built on.
        let (ctx, keys, mut rng) = setup_packed(256, 3, 24, 62);
        let d = ctx.d();
        let vals: Vec<i64> = (0..d as i64).map(|j| j + 1).collect();
        let total: i128 = vals.iter().map(|&v| v as i128).sum();
        let ct = ctx.encrypt(&ctx.encoder().encode_vec(&vals), &keys.pk, &mut rng);
        let ring = &ctx.ring_q;
        let before = ring.rotation_count();
        let summed = ctx.slot_sum(&ct, &keys.gk);
        let expect_rot = (d / 2).trailing_zeros() as u64 + 1;
        assert_eq!(ring.rotation_count() - before, expect_rot, "log₂(d/2)+1 key-switches");
        let got = ctx.encoder().decode_vec(&ctx.decrypt(&summed, &keys.sk), d);
        for (j, v) in got.iter().enumerate() {
            assert_eq!(v.to_i128(), Some(total), "slot {j}");
        }
        assert!(
            noise_budget_bits(&ctx, &summed, &keys.sk) > 10.0,
            "key-switch noise stays within budget"
        );
    }

    #[test]
    fn rotate_rows_zero_steps_and_full_cycle() {
        let (ctx, keys, mut rng) = setup_packed(256, 3, 24, 63);
        let d = ctx.d();
        let vals: Vec<i64> = (0..d as i64).map(|j| 7 * j - 100).collect();
        let ct = ctx.encrypt(&ctx.encoder().encode_vec(&vals), &keys.pk, &mut rng);
        let ring = &ctx.ring_q;
        let before = ring.rotation_count();
        let same = ctx.rotate_rows(&ct, 0, &keys.gk);
        assert_eq!(ring.rotation_count() - before, 0, "zero steps is key-switch-free");
        assert_eq!(ctx.decrypt(&same, &keys.sk), ctx.decrypt(&ct, &keys.sk));
        // d/2 steps wrap to the identity permutation (mod half-row).
        let cycled = ctx.rotate_rows(&ct, d / 2, &keys.gk);
        assert_eq!(ctx.decrypt(&cycled, &keys.sk), ctx.decrypt(&ct, &keys.sk));
    }

    #[test]
    fn rotation_commutes_with_slotwise_ops() {
        // σ_g is a ring homomorphism, so rotating a sum/product equals
        // the sum/product of rotations — checked through encryption.
        let (ctx, keys, mut rng) = setup_packed(256, 3, 22, 64);
        let d = ctx.d();
        let va: Vec<i64> = (0..d as i64).map(|j| j % 23 - 11).collect();
        let vb: Vec<i64> = (0..d as i64).map(|j| (j * 5) % 17 - 8).collect();
        let ca = ctx.encrypt(&ctx.encoder().encode_vec(&va), &keys.pk, &mut rng);
        let cb = ctx.encrypt(&ctx.encoder().encode_vec(&vb), &keys.pk, &mut rng);
        let r = 5usize;
        let prod_then_rot =
            ctx.rotate_rows(&ctx.mul_ct(&ca, &cb, &keys.rk), r, &keys.gk);
        let rot_then_prod = ctx.mul_ct(
            &ctx.rotate_rows(&ca, r, &keys.gk),
            &ctx.rotate_rows(&cb, r, &keys.gk),
            &keys.rk,
        );
        assert_eq!(
            ctx.decrypt(&prod_then_rot, &keys.sk),
            ctx.decrypt(&rot_then_prod, &keys.sk)
        );
    }

    #[test]
    fn homomorphism_property_random() {
        use crate::util::prop::PropRunner;
        let (ctx, keys, _) = setup(256, 4, 20, 49);
        let mut run = PropRunner::new("fv_homomorphism", 12);
        run.run(|rng| {
            let a = (rng.uniform_below(2001) as i64) - 1000;
            let b = (rng.uniform_below(2001) as i64) - 1000;
            let ma = crate::fhe::encoding::encode_int(a, ctx.d());
            let mb = crate::fhe::encoding::encode_int(b, ctx.d());
            let ca = ctx.encrypt(&ma, &keys.pk, rng);
            let cb = ctx.encrypt(&mb, &keys.pk, rng);
            let sum = ctx.decrypt(&ctx.add_ct(&ca, &cb), &keys.sk);
            assert_eq!(sum.eval_at_2().to_i128(), Some((a + b) as i128), "add");
            let prod = ctx.decrypt(&ctx.mul_ct(&ca, &cb, &keys.rk), &keys.sk);
            assert_eq!(prod.eval_at_2().to_i128(), Some((a as i128) * (b as i128)), "mul");
        });
    }
}
