//! The Fan–Vercauteren (FV/BFV) fully homomorphic encryption scheme,
//! implemented from scratch (the paper used the authors'
//! `HomomorphicEncryption` R package; none of its stack is available
//! offline, so this is a complete substrate reimplementation).
//!
//! Structure:
//! - [`rng`] / [`sampler`] — ChaCha20 stream + RLWE samplers.
//! - [`params`] — §4.5 parameter selection: Lemma 3 growth bounds,
//!   Lindner–Peikert security, noise-depth budgeting.
//! - [`context`] — precomputed rings/moduli and basis conversions.
//! - [`keys`] — secret/public/relinearisation/Galois key generation.
//! - [`plaintext`] / [`encoding`] — message ring, §3.1 scalar
//!   encoding, and CRT slot packing (the [`encoding::Encoder`] seam).
//! - [`ciphertext`] / [`ops`] — ⊕, ⊗, plaintext ops, relinearisation.
//! - [`rns_mul`] — the full-RNS ⊗ pipeline (default
//!   [`MulBackend`](params::MulBackend)): base extension,
//!   residue-plane scale-and-round, Shenoy–Kumaresan back conversion.
//! - [`noise`] — exact invariant-noise measurement (diagnostics).

pub mod ciphertext;
pub mod context;
pub mod encoding;
pub mod keys;
pub mod noise;
pub mod ops;
pub mod params;
pub mod plaintext;
pub mod rng;
pub mod rns_mul;
pub mod sampler;

pub use ciphertext::Ciphertext;
pub use context::FvContext;
pub use encoding::{Encoder, ScalarEncoder, SlotEncoder};
pub use keys::{
    galois_keygen, keygen, packed_galois_elements, GaloisKey, GaloisKeys, KeySet, PublicKey,
    RelinKey, SecretKey,
};
pub use params::{plan, Algo, Encoding, FvParams, MulBackend, PlanRequest, SecurityProfile};
pub use plaintext::{Plaintext, PlaintextNtt};
