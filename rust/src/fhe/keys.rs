//! Key generation: secret, public and relinearisation keys.

use crate::math::poly::RnsPoly;

use super::context::FvContext;
use super::params::Encoding;
use super::rng::ChaChaRng;
use super::sampler::{sample_error, sample_ternary};

/// Ternary RLWE secret.
#[derive(Clone)]
pub struct SecretKey {
    /// s in coefficient representation (Q basis).
    pub s: RnsPoly,
    /// s in NTT representation (hot path for decryption).
    pub s_ntt: RnsPoly,
    /// s² in NTT representation (decrypting 3-component ciphertexts).
    pub s2_ntt: RnsPoly,
}

/// Standard RLWE public key `(b, a)` with `b = -(a·s + e)`.
#[derive(Clone)]
pub struct PublicKey {
    pub b_ntt: RnsPoly,
    pub a_ntt: RnsPoly,
}

/// FV-v1 relinearisation key over the per-limb RNS gadget: for each
/// Q limb i, `(b_i, a_i)` with `b_i = -(a_i·s + e_i) + g_i·s² (mod q)`
/// where `g_i = q/q_i mod q` (zero on every residue plane except i).
#[derive(Clone)]
pub struct RelinKey {
    pub b_ntt: Vec<RnsPoly>,
    pub a_ntt: Vec<RnsPoly>,
}

/// Key-switching key for one Galois automorphism `x → x^g`: the same
/// per-limb RNS gadget as [`RelinKey`], but digit i encodes
/// `g_i·σ_g(s)` instead of `g_i·s²`. Rotating a ciphertext applies the
/// automorphism to both components and key-switches `σ_g(c₁)` back
/// under `s` (see `fhe/ops.rs::apply_galois`).
#[derive(Clone)]
pub struct GaloisKey {
    /// The Galois element `g` (odd, a unit mod 2d).
    pub galois: usize,
    pub b_ntt: Vec<RnsPoly>,
    pub a_ntt: Vec<RnsPoly>,
}

/// The set of Galois keys a party publishes (empty under scalar
/// encoding — rotations are a packed-only operation).
#[derive(Clone, Default)]
pub struct GaloisKeys {
    keys: Vec<GaloisKey>,
}

impl GaloisKeys {
    /// The key for Galois element `g`, if generated.
    pub fn get(&self, galois: usize) -> Option<&GaloisKey> {
        self.keys.iter().find(|k| k.galois == galois)
    }

    /// Galois elements covered by this key set.
    pub fn elements(&self) -> impl Iterator<Item = usize> + '_ {
        self.keys.iter().map(|k| k.galois)
    }

    pub fn is_empty(&self) -> bool {
        self.keys.is_empty()
    }

    /// Iterate the keys themselves (wire codec, diagnostics).
    pub fn iter(&self) -> impl Iterator<Item = &GaloisKey> {
        self.keys.iter()
    }

    /// Rebuild a set from deserialised keys (wire codec).
    pub fn from_keys(keys: Vec<GaloisKey>) -> Self {
        GaloisKeys { keys }
    }
}

/// The Galois elements the packed engine needs for degree `d`: the
/// row-rotation generators `3^{2^k} (mod 2d)` (binary rotation
/// schedule over the d/2-slot rows) plus the row-swap element `2d−1`.
pub fn packed_galois_elements(d: usize) -> Vec<usize> {
    assert!(d.is_power_of_two() && d >= 2);
    let m = 2 * d;
    let mut els = Vec::new();
    let mut g = 3usize % m;
    let mut span = 1usize;
    while span < d / 2 {
        els.push(g);
        g = g * g % m;
        span *= 2;
    }
    els.push(m - 1);
    els
}

/// All keys for one party.
pub struct KeySet {
    pub sk: SecretKey,
    pub pk: PublicKey,
    pub rk: RelinKey,
    /// Galois rotation keys (populated only for packed parameter sets).
    pub gk: GaloisKeys,
}

/// Generate a full key set.
pub fn keygen(ctx: &FvContext, rng: &mut ChaChaRng) -> KeySet {
    let ring = &ctx.ring_q;

    // Secret.
    let s = sample_ternary(ring, rng);
    let mut s_ntt = s.clone();
    ring.ntt_forward(&mut s_ntt);
    let s2_ntt = ring.mul_ntt(&s_ntt, &s_ntt);

    // Public key: a ← U(R_q), e ← χ, b = -(a·s + e). The key only
    // ever lives in NTT form, so the whole identity is evaluated in
    // the evaluation domain — the error is transformed *forward* once
    // instead of round-tripping a·s through an inverse and b back
    // through a forward (NTT is linear, so the sample is identical).
    let a = ring.sample_uniform(rng);
    let mut a_ntt = a.clone();
    ring.ntt_forward(&mut a_ntt);
    let mut e_ntt = sample_error(ring, rng, ctx.params.cbd_k);
    ring.ntt_forward(&mut e_ntt);
    let b_ntt = ring.neg(&ring.add(&ring.mul_ntt(&a_ntt, &s_ntt), &e_ntt));
    let pk = PublicKey { b_ntt, a_ntt };

    // Relinearisation keys over the per-limb RNS gadget: digit i
    // encodes g_i·s² with g_i = q/q_i mod q, whose residue vector is
    // zero except [q/q_i]_{q_i} on plane i. Same all-NTT evaluation:
    // one forward per error sample, no cancelling inverse/forward
    // pairs on a_i·s or g_i·s².
    let (rb, ra) = gadget_key(ctx, rng, &s_ntt, &s2_ntt);

    let sk = SecretKey { s, s_ntt, s2_ntt };

    // Galois rotation keys: packed sets only — scalar keygen draws the
    // exact same rng stream (and pays the exact same cost) as before
    // slot packing existed.
    let gk = match ctx.params.encoding {
        Encoding::Packed => galois_keygen(ctx, rng, &sk, &packed_galois_elements(ctx.d())),
        Encoding::Scalar => GaloisKeys::default(),
    };

    KeySet { sk, pk, rk: RelinKey { b_ntt: rb, a_ntt: ra }, gk }
}

/// One per-limb-gadget key-switching key: for each Q limb i,
/// `(b_i, a_i)` with `b_i = −(a_i·s + e_i) + g_i·target (mod q)`.
/// `target = s²` gives the relinearisation key, `target = σ_g(s)` a
/// Galois key — the digit-decomposition side (`relin_digits`) is
/// shared too, so both consume identical noise per digit.
fn gadget_key(
    ctx: &FvContext,
    rng: &mut ChaChaRng,
    s_ntt: &RnsPoly,
    target_ntt: &RnsPoly,
) -> (Vec<RnsPoly>, Vec<RnsPoly>) {
    let ring = &ctx.ring_q;
    let primes = &ring.basis.primes;
    let mut kb = Vec::with_capacity(ctx.relin_ndigits);
    let mut ka = Vec::with_capacity(ctx.relin_ndigits);
    for i in 0..ctx.relin_ndigits {
        let ai = ring.sample_uniform(rng);
        let mut ai_ntt = ai.clone();
        ring.ntt_forward(&mut ai_ntt);
        let mut ei_ntt = sample_error(ring, rng, ctx.params.cbd_k);
        ring.ntt_forward(&mut ei_ntt);
        let ais_ntt = ring.mul_ntt(&ai_ntt, s_ntt);
        let gi_rns: Vec<u64> = primes
            .iter()
            .enumerate()
            .map(|(l, &p)| if l == i { ring.basis.crt_m[i].mod_u64(p) } else { 0 })
            .collect();
        let gi_target_ntt = ring.mul_scalar_rns(target_ntt, &gi_rns);
        let bi_ntt = ring.add(&ring.neg(&ring.add(&ais_ntt, &ei_ntt)), &gi_target_ntt);
        kb.push(bi_ntt);
        ka.push(ai_ntt);
    }
    (kb, ka)
}

/// Generate Galois keys for the given elements (a per-limb gadget key
/// switching `σ_g(s)` back under `s`, for each `g`).
pub fn galois_keygen(
    ctx: &FvContext,
    rng: &mut ChaChaRng,
    sk: &SecretKey,
    elements: &[usize],
) -> GaloisKeys {
    let ring = &ctx.ring_q;
    let keys = elements
        .iter()
        .map(|&g| {
            let mut sg_ntt = ring.automorphism(&sk.s, g);
            ring.ntt_forward(&mut sg_ntt);
            let (b_ntt, a_ntt) = gadget_key(ctx, rng, &sk.s_ntt, &sg_ntt);
            GaloisKey { galois: g, b_ntt, a_ntt }
        })
        .collect();
    GaloisKeys { keys }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fhe::context::FvContext;
    use crate::fhe::params::FvParams;
    use crate::math::modarith::center;

    #[test]
    fn public_key_is_rlwe_sample() {
        // b + a·s = -e must have tiny coefficients.
        let ctx = FvContext::new(FvParams::custom(256, 3, 20));
        let mut rng = ChaChaRng::from_seed(31);
        let keys = keygen(&ctx, &mut rng);
        let ring = &ctx.ring_q;
        let sum_ntt = {
            let prod = ring.mul_ntt(&keys.pk.a_ntt, &keys.sk.s_ntt);
            ring.add(&keys.pk.b_ntt, &prod)
        };
        let mut sum = sum_ntt;
        ring.ntt_inverse(&mut sum);
        let bound = ctx.params.cbd_k as i64;
        for (l, &p) in ring.basis.primes.iter().enumerate() {
            for &v in &sum.planes[l] {
                assert!(center(v, p).abs() <= bound, "pk residual too large");
            }
        }
    }

    #[test]
    fn relin_key_count_matches_digits() {
        let ctx = FvContext::new(FvParams::custom(256, 2, 16));
        let mut rng = ChaChaRng::from_seed(32);
        let keys = keygen(&ctx, &mut rng);
        assert_eq!(keys.rk.b_ntt.len(), ctx.relin_ndigits);
        assert_eq!(keys.rk.a_ntt.len(), ctx.relin_ndigits);
        // One digit per RNS limb of q.
        assert_eq!(ctx.relin_ndigits, ctx.params.q_count);
    }

    #[test]
    fn packed_galois_element_schedule() {
        // d = 16 (2d = 32): doubling rotations 3, 3² = 9, 3⁴ = 17,
        // then the row swap 31 = −1.
        assert_eq!(packed_galois_elements(16), vec![3, 9, 17, 31]);
        // Degenerate single-slot rows: only the swap remains.
        assert_eq!(packed_galois_elements(2), vec![3]);
        for d in [2usize, 8, 256] {
            let els = packed_galois_elements(d);
            assert_eq!(els.len(), (d / 2).trailing_zeros() as usize + 1, "O(log d) keys");
            for g in els {
                assert_eq!(g % 2, 1, "Galois elements are odd units mod 2d");
                assert!(g < 2 * d);
            }
        }
    }

    #[test]
    fn scalar_keygen_has_no_galois_keys() {
        let ctx = FvContext::new(FvParams::custom(256, 2, 16));
        let mut rng = ChaChaRng::from_seed(35);
        let keys = keygen(&ctx, &mut rng);
        assert!(keys.gk.is_empty());
        assert!(keys.gk.get(3).is_none());
    }

    #[test]
    fn galois_key_encodes_gadget_multiples_of_rotated_s() {
        // b_i + a_i·s - g_i·σ_g(s) = -e_i (small) for every digit of
        // every packed Galois element.
        let ctx = FvContext::new(FvParams::custom_packed(256, 3, 20).unwrap());
        let mut rng = ChaChaRng::from_seed(34);
        let keys = keygen(&ctx, &mut rng);
        assert!(!keys.gk.is_empty());
        let ring = &ctx.ring_q;
        for g in packed_galois_elements(ctx.d()) {
            let key = keys.gk.get(g).expect("packed keygen covers the schedule");
            let mut sg_ntt = ring.automorphism(&keys.sk.s, g);
            ring.ntt_forward(&mut sg_ntt);
            for i in [0usize, ctx.relin_ndigits - 1] {
                let prod = ring.mul_ntt(&key.a_ntt[i], &keys.sk.s_ntt);
                let gi: Vec<u64> = ring
                    .basis
                    .primes
                    .iter()
                    .map(|&p| ring.basis.crt_m[i].mod_u64(p))
                    .collect();
                let gisg = ring.mul_scalar_rns(&sg_ntt, &gi);
                let mut res = ring.sub(&ring.add(&key.b_ntt[i], &prod), &gisg);
                ring.ntt_inverse(&mut res);
                let bound = ctx.params.cbd_k as i64;
                for (l, &p) in ring.basis.primes.iter().enumerate() {
                    for &v in &res.planes[l] {
                        assert!(center(v, p).abs() <= bound, "galois digit {i} of g = {g}");
                    }
                }
            }
        }
    }

    #[test]
    fn relin_key_encodes_gadget_multiples_of_s2() {
        // b_i + a_i·s - g_i·s² = -e_i (small), with g_i = q/q_i mod q.
        let ctx = FvContext::new(FvParams::custom(256, 3, 20));
        let mut rng = ChaChaRng::from_seed(33);
        let keys = keygen(&ctx, &mut rng);
        let ring = &ctx.ring_q;
        for i in [0usize, ctx.relin_ndigits - 1] {
            let prod = ring.mul_ntt(&keys.rk.a_ntt[i], &keys.sk.s_ntt);
            let gi: Vec<u64> = ring
                .basis
                .primes
                .iter()
                .map(|&p| ring.basis.crt_m[i].mod_u64(p))
                .collect();
            // g_i vanishes on every plane except i.
            for (l, &g) in gi.iter().enumerate() {
                assert_eq!(g == 0, l != i, "gadget residue structure");
            }
            let gis2 = ring.mul_scalar_rns(&keys.sk.s2_ntt, &gi);
            let mut res = ring.sub(&ring.add(&keys.rk.b_ntt[i], &prod), &gis2);
            ring.ntt_inverse(&mut res);
            let bound = ctx.params.cbd_k as i64;
            for (l, &p) in ring.basis.primes.iter().enumerate() {
                for &v in &res.planes[l] {
                    assert!(center(v, p).abs() <= bound, "relin digit {i} malformed");
                }
            }
        }
    }
}
