//! Key generation: secret, public and relinearisation keys.

use crate::math::poly::RnsPoly;

use super::context::FvContext;
use super::rng::ChaChaRng;
use super::sampler::{sample_error, sample_ternary};

/// Ternary RLWE secret.
#[derive(Clone)]
pub struct SecretKey {
    /// s in coefficient representation (Q basis).
    pub s: RnsPoly,
    /// s in NTT representation (hot path for decryption).
    pub s_ntt: RnsPoly,
    /// s² in NTT representation (decrypting 3-component ciphertexts).
    pub s2_ntt: RnsPoly,
}

/// Standard RLWE public key `(b, a)` with `b = -(a·s + e)`.
#[derive(Clone)]
pub struct PublicKey {
    pub b_ntt: RnsPoly,
    pub a_ntt: RnsPoly,
}

/// FV-v1 relinearisation key: for each digit j,
/// `(b_j, a_j)` with `b_j = -(a_j·s + e_j) + w^j·s²  (mod q)`.
#[derive(Clone)]
pub struct RelinKey {
    pub b_ntt: Vec<RnsPoly>,
    pub a_ntt: Vec<RnsPoly>,
}

/// All keys for one party.
pub struct KeySet {
    pub sk: SecretKey,
    pub pk: PublicKey,
    pub rk: RelinKey,
}

/// Generate a full key set.
pub fn keygen(ctx: &FvContext, rng: &mut ChaChaRng) -> KeySet {
    let ring = &ctx.ring_q;

    // Secret.
    let s = sample_ternary(ring, rng);
    let mut s_ntt = s.clone();
    ring.ntt_forward(&mut s_ntt);
    let s2_ntt = ring.mul_ntt(&s_ntt, &s_ntt);

    // Public key: a ← U(R_q), e ← χ, b = -(a·s + e).
    let a = ring.sample_uniform(rng);
    let mut a_ntt = a.clone();
    ring.ntt_forward(&mut a_ntt);
    let e = sample_error(ring, rng, ctx.params.cbd_k);
    let mut as_prod = ring.mul_ntt(&a_ntt, &s_ntt);
    ring.ntt_inverse(&mut as_prod);
    let b = ring.neg(&ring.add(&as_prod, &e));
    let mut b_ntt = b;
    ring.ntt_forward(&mut b_ntt);
    let pk = PublicKey { b_ntt, a_ntt };

    // Relinearisation keys over base-w digits of q.
    let mut rb = Vec::with_capacity(ctx.relin_ndigits);
    let mut ra = Vec::with_capacity(ctx.relin_ndigits);
    // w^j mod each prime, iteratively.
    let primes = &ring.basis.primes;
    let mut wj_rns: Vec<u64> = vec![1; primes.len()];
    let w_mod: Vec<u64> = primes
        .iter()
        .map(|&p| {
            // w = 2^w_bits mod p
            crate::math::modarith::powmod(2, ctx.relin_w_bits as u64, p)
        })
        .collect();
    for _j in 0..ctx.relin_ndigits {
        let aj = ring.sample_uniform(rng);
        let mut aj_ntt = aj.clone();
        ring.ntt_forward(&mut aj_ntt);
        let ej = sample_error(ring, rng, ctx.params.cbd_k);
        let mut ajs = ring.mul_ntt(&aj_ntt, &s_ntt);
        ring.ntt_inverse(&mut ajs);
        // w^j·s² in coefficient form.
        let mut wjs2 = ring.mul_scalar_rns(&s2_ntt, &wj_rns);
        ring.ntt_inverse(&mut wjs2);
        let bj = ring.add(&ring.neg(&ring.add(&ajs, &ej)), &wjs2);
        let mut bj_ntt = bj;
        ring.ntt_forward(&mut bj_ntt);
        rb.push(bj_ntt);
        ra.push(aj_ntt);
        for (l, &p) in primes.iter().enumerate() {
            wj_rns[l] = crate::math::modarith::mulmod(wj_rns[l], w_mod[l], p);
        }
    }

    KeySet { sk: SecretKey { s, s_ntt, s2_ntt }, pk, rk: RelinKey { b_ntt: rb, a_ntt: ra } }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fhe::context::FvContext;
    use crate::fhe::params::FvParams;
    use crate::math::modarith::center;

    #[test]
    fn public_key_is_rlwe_sample() {
        // b + a·s = -e must have tiny coefficients.
        let ctx = FvContext::new(FvParams::custom(256, 3, 20));
        let mut rng = ChaChaRng::from_seed(31);
        let keys = keygen(&ctx, &mut rng);
        let ring = &ctx.ring_q;
        let sum_ntt = {
            let prod = ring.mul_ntt(&keys.pk.a_ntt, &keys.sk.s_ntt);
            ring.add(&keys.pk.b_ntt, &prod)
        };
        let mut sum = sum_ntt;
        ring.ntt_inverse(&mut sum);
        let bound = ctx.params.cbd_k as i64;
        for (l, &p) in ring.basis.primes.iter().enumerate() {
            for &v in &sum.planes[l] {
                assert!(center(v, p).abs() <= bound, "pk residual too large");
            }
        }
    }

    #[test]
    fn relin_key_count_matches_digits() {
        let ctx = FvContext::new(FvParams::custom(256, 2, 16));
        let mut rng = ChaChaRng::from_seed(32);
        let keys = keygen(&ctx, &mut rng);
        assert_eq!(keys.rk.b_ntt.len(), ctx.relin_ndigits);
        assert_eq!(keys.rk.a_ntt.len(), ctx.relin_ndigits);
        assert!(ctx.relin_ndigits >= ctx.q.bit_len() / ctx.relin_w_bits as usize);
    }

    #[test]
    fn relin_key_encodes_w_powers_of_s2() {
        // b_j + a_j·s - w^j·s² = -e_j (small).
        let ctx = FvContext::new(FvParams::custom(256, 3, 20));
        let mut rng = ChaChaRng::from_seed(33);
        let keys = keygen(&ctx, &mut rng);
        let ring = &ctx.ring_q;
        for j in [0usize, ctx.relin_ndigits - 1] {
            let prod = ring.mul_ntt(&keys.rk.a_ntt[j], &keys.sk.s_ntt);
            // w^j mod each prime
            let wj: Vec<u64> = ring
                .basis
                .primes
                .iter()
                .map(|&p| {
                    crate::math::modarith::powmod(2, (ctx.relin_w_bits as u64) * j as u64, p)
                })
                .collect();
            let wjs2 = ring.mul_scalar_rns(&keys.sk.s2_ntt, &wj);
            let mut res = ring.sub(&ring.add(&keys.rk.b_ntt[j], &prod), &wjs2);
            ring.ntt_inverse(&mut res);
            let bound = ctx.params.cbd_k as i64;
            for (l, &p) in ring.basis.primes.iter().enumerate() {
                for &v in &res.planes[l] {
                    assert!(center(v, p).abs() <= bound, "relin digit {j} malformed");
                }
            }
        }
    }
}
