//! Key generation: secret, public and relinearisation keys.

use crate::math::poly::RnsPoly;

use super::context::FvContext;
use super::rng::ChaChaRng;
use super::sampler::{sample_error, sample_ternary};

/// Ternary RLWE secret.
#[derive(Clone)]
pub struct SecretKey {
    /// s in coefficient representation (Q basis).
    pub s: RnsPoly,
    /// s in NTT representation (hot path for decryption).
    pub s_ntt: RnsPoly,
    /// s² in NTT representation (decrypting 3-component ciphertexts).
    pub s2_ntt: RnsPoly,
}

/// Standard RLWE public key `(b, a)` with `b = -(a·s + e)`.
#[derive(Clone)]
pub struct PublicKey {
    pub b_ntt: RnsPoly,
    pub a_ntt: RnsPoly,
}

/// FV-v1 relinearisation key over the per-limb RNS gadget: for each
/// Q limb i, `(b_i, a_i)` with `b_i = -(a_i·s + e_i) + g_i·s² (mod q)`
/// where `g_i = q/q_i mod q` (zero on every residue plane except i).
#[derive(Clone)]
pub struct RelinKey {
    pub b_ntt: Vec<RnsPoly>,
    pub a_ntt: Vec<RnsPoly>,
}

/// All keys for one party.
pub struct KeySet {
    pub sk: SecretKey,
    pub pk: PublicKey,
    pub rk: RelinKey,
}

/// Generate a full key set.
pub fn keygen(ctx: &FvContext, rng: &mut ChaChaRng) -> KeySet {
    let ring = &ctx.ring_q;

    // Secret.
    let s = sample_ternary(ring, rng);
    let mut s_ntt = s.clone();
    ring.ntt_forward(&mut s_ntt);
    let s2_ntt = ring.mul_ntt(&s_ntt, &s_ntt);

    // Public key: a ← U(R_q), e ← χ, b = -(a·s + e). The key only
    // ever lives in NTT form, so the whole identity is evaluated in
    // the evaluation domain — the error is transformed *forward* once
    // instead of round-tripping a·s through an inverse and b back
    // through a forward (NTT is linear, so the sample is identical).
    let a = ring.sample_uniform(rng);
    let mut a_ntt = a.clone();
    ring.ntt_forward(&mut a_ntt);
    let mut e_ntt = sample_error(ring, rng, ctx.params.cbd_k);
    ring.ntt_forward(&mut e_ntt);
    let b_ntt = ring.neg(&ring.add(&ring.mul_ntt(&a_ntt, &s_ntt), &e_ntt));
    let pk = PublicKey { b_ntt, a_ntt };

    // Relinearisation keys over the per-limb RNS gadget: digit i
    // encodes g_i·s² with g_i = q/q_i mod q, whose residue vector is
    // zero except [q/q_i]_{q_i} on plane i. Same all-NTT evaluation:
    // one forward per error sample, no cancelling inverse/forward
    // pairs on a_i·s or g_i·s².
    let mut rb = Vec::with_capacity(ctx.relin_ndigits);
    let mut ra = Vec::with_capacity(ctx.relin_ndigits);
    let primes = &ring.basis.primes;
    for i in 0..ctx.relin_ndigits {
        let ai = ring.sample_uniform(rng);
        let mut ai_ntt = ai.clone();
        ring.ntt_forward(&mut ai_ntt);
        let mut ei_ntt = sample_error(ring, rng, ctx.params.cbd_k);
        ring.ntt_forward(&mut ei_ntt);
        let ais_ntt = ring.mul_ntt(&ai_ntt, &s_ntt);
        let gi_rns: Vec<u64> = primes
            .iter()
            .enumerate()
            .map(|(l, &p)| if l == i { ring.basis.crt_m[i].mod_u64(p) } else { 0 })
            .collect();
        let gis2_ntt = ring.mul_scalar_rns(&s2_ntt, &gi_rns);
        let bi_ntt = ring.add(&ring.neg(&ring.add(&ais_ntt, &ei_ntt)), &gis2_ntt);
        rb.push(bi_ntt);
        ra.push(ai_ntt);
    }

    KeySet { sk: SecretKey { s, s_ntt, s2_ntt }, pk, rk: RelinKey { b_ntt: rb, a_ntt: ra } }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fhe::context::FvContext;
    use crate::fhe::params::FvParams;
    use crate::math::modarith::center;

    #[test]
    fn public_key_is_rlwe_sample() {
        // b + a·s = -e must have tiny coefficients.
        let ctx = FvContext::new(FvParams::custom(256, 3, 20));
        let mut rng = ChaChaRng::from_seed(31);
        let keys = keygen(&ctx, &mut rng);
        let ring = &ctx.ring_q;
        let sum_ntt = {
            let prod = ring.mul_ntt(&keys.pk.a_ntt, &keys.sk.s_ntt);
            ring.add(&keys.pk.b_ntt, &prod)
        };
        let mut sum = sum_ntt;
        ring.ntt_inverse(&mut sum);
        let bound = ctx.params.cbd_k as i64;
        for (l, &p) in ring.basis.primes.iter().enumerate() {
            for &v in &sum.planes[l] {
                assert!(center(v, p).abs() <= bound, "pk residual too large");
            }
        }
    }

    #[test]
    fn relin_key_count_matches_digits() {
        let ctx = FvContext::new(FvParams::custom(256, 2, 16));
        let mut rng = ChaChaRng::from_seed(32);
        let keys = keygen(&ctx, &mut rng);
        assert_eq!(keys.rk.b_ntt.len(), ctx.relin_ndigits);
        assert_eq!(keys.rk.a_ntt.len(), ctx.relin_ndigits);
        // One digit per RNS limb of q.
        assert_eq!(ctx.relin_ndigits, ctx.params.q_count);
    }

    #[test]
    fn relin_key_encodes_gadget_multiples_of_s2() {
        // b_i + a_i·s - g_i·s² = -e_i (small), with g_i = q/q_i mod q.
        let ctx = FvContext::new(FvParams::custom(256, 3, 20));
        let mut rng = ChaChaRng::from_seed(33);
        let keys = keygen(&ctx, &mut rng);
        let ring = &ctx.ring_q;
        for i in [0usize, ctx.relin_ndigits - 1] {
            let prod = ring.mul_ntt(&keys.rk.a_ntt[i], &keys.sk.s_ntt);
            let gi: Vec<u64> = ring
                .basis
                .primes
                .iter()
                .map(|&p| ring.basis.crt_m[i].mod_u64(p))
                .collect();
            // g_i vanishes on every plane except i.
            for (l, &g) in gi.iter().enumerate() {
                assert_eq!(g == 0, l != i, "gadget residue structure");
            }
            let gis2 = ring.mul_scalar_rns(&keys.sk.s2_ntt, &gi);
            let mut res = ring.sub(&ring.add(&keys.rk.b_ntt[i], &prod), &gis2);
            ring.ntt_inverse(&mut res);
            let bound = ctx.params.cbd_k as i64;
            for (l, &p) in ring.basis.primes.iter().enumerate() {
                for &v in &res.planes[l] {
                    assert!(center(v, p).abs() <= bound, "relin digit {i} malformed");
                }
            }
        }
    }
}
