//! §3.1 data representation and encoding.
//!
//! Real data is quantised to integers by `ż = ⌊10^φ·z⌉` and each integer
//! is encoded as a signed-binary polynomial `m(x)` with coefficients in
//! {-1, 0, 1} such that `m(2) = ż` (§4.5). Decoding evaluates at `x = 2`
//! and divides by the algorithm's known global scale factor.

use crate::math::bigint::{BigInt, BigUint};

use super::plaintext::Plaintext;

/// Quantise a real value to `⌊10^φ·z⌉`.
pub fn quantize(z: f64, phi: u32) -> i64 {
    let scaled = z * 10f64.powi(phi as i32);
    scaled.round() as i64
}

/// Inverse of [`quantize`] (the value the algorithm actually sees).
pub fn dequantize(zq: i64, phi: u32) -> f64 {
    zq as f64 / 10f64.powi(phi as i32)
}

/// Signed-binary coefficients of an integer: `Σ c_i 2^i = v`,
/// `c_i ∈ {-1, 0, 1}` (plain binary of |v| with the sign distributed).
pub fn int_to_signed_binary(v: i64) -> Vec<i64> {
    let neg = v < 0;
    let mut mag = v.unsigned_abs();
    let mut out = Vec::new();
    while mag > 0 {
        let bit = (mag & 1) as i64;
        out.push(if neg { -bit } else { bit });
        mag >>= 1;
    }
    out
}

/// Encode an already-quantised integer as a plaintext polynomial.
pub fn encode_int(v: i64, d: usize) -> Plaintext {
    let coeffs = int_to_signed_binary(v);
    assert!(coeffs.len() <= d, "encoded integer exceeds ring degree");
    Plaintext::from_signed(d, &coeffs)
}

/// Encode a real value: quantise then binary-decompose.
pub fn encode_value(z: f64, phi: u32, d: usize) -> Plaintext {
    encode_int(quantize(z, phi), d)
}

/// Encode a non-negative big constant (the pre-groupable rescaling
/// factors like `10^{kφ}·ν̃^{k-1}`, which can exceed u64).
pub fn encode_biguint(v: &BigUint, d: usize) -> Plaintext {
    let bits = v.bit_len();
    assert!(bits <= d, "constant exceeds ring degree");
    let mut coeffs = vec![BigInt::zero(); d];
    for (i, c) in coeffs.iter_mut().enumerate().take(bits) {
        if v.bit(i) {
            *c = BigInt::from_i64(1);
        }
    }
    Plaintext { coeffs }
}

/// Encode a signed big constant.
pub fn encode_bigint(v: &BigInt, d: usize) -> Plaintext {
    let mut pt = encode_biguint(&v.mag, d);
    if v.neg {
        for c in pt.coeffs.iter_mut() {
            *c = c.neg_value();
        }
    }
    pt
}

/// Decode: evaluate the message at 2 and divide by the global scale.
pub fn decode(pt: &Plaintext, scale: &BigUint) -> f64 {
    pt.eval_at_2_scaled(scale)
}

/// Decode an integer exactly (no scale division).
pub fn decode_exact(pt: &Plaintext) -> BigInt {
    pt.eval_at_2()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::{gen, PropRunner};

    #[test]
    fn quantize_examples() {
        assert_eq!(quantize(1.234, 2), 123);
        assert_eq!(quantize(1.235, 2), 124); // round half away handled by f64 round
        assert_eq!(quantize(-0.555, 2), -56);
        assert_eq!(quantize(0.0, 2), 0);
        assert_eq!(quantize(3.0, 0), 3);
    }

    #[test]
    fn encode_decode_int_roundtrip() {
        let mut run = PropRunner::new("encoding_int_roundtrip", 500);
        run.run(|rng| {
            let v = gen::int_in(rng, -1_000_000_000, 1_000_000_000);
            let pt = encode_int(v, 64);
            assert_eq!(decode_exact(&pt).to_i128(), Some(v as i128));
            // coefficients really are in {-1, 0, 1} and share v's sign
            for c in &pt.coeffs {
                assert!(c.mag.to_u64().unwrap_or(2) <= 1);
            }
        });
    }

    #[test]
    fn encode_value_quantisation_error() {
        let mut run = PropRunner::new("encoding_value", 300);
        run.run(|rng| {
            let z = gen::f64_in(rng, -100.0, 100.0);
            let phi = 2;
            let pt = encode_value(z, phi, 64);
            let back =
                decode(&pt, &BigUint::from_u64(100)); // scale 10^phi
            assert!((back - z).abs() <= 0.5 / 100.0 + 1e-12, "z={z} back={back}");
        });
    }

    #[test]
    fn encode_biguint_large_constant() {
        let v = BigUint::pow10(30); // far beyond u64
        let pt = encode_biguint(&v, 256);
        let val = decode_exact(&pt);
        assert!(!val.neg);
        assert_eq!(val.mag.to_decimal(), v.to_decimal());
    }

    #[test]
    fn encode_bigint_negative() {
        let v = BigInt::from_i64(-123456789);
        let pt = encode_bigint(&v, 64);
        assert_eq!(decode_exact(&pt).to_i128(), Some(-123456789));
    }

    #[test]
    fn degree_is_bit_length() {
        let pt = encode_int(1 << 20, 64);
        assert_eq!(pt.degree(), 20);
        assert_eq!(encode_int(0, 16).degree(), -1);
    }

    #[test]
    #[should_panic(expected = "exceeds ring degree")]
    fn overflow_degree_panics() {
        let _ = encode_int(i64::MAX, 8);
    }
}
