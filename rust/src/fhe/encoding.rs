//! §3.1 data representation and encoding.
//!
//! Real data is quantised to integers by `ż = ⌊10^φ·z⌉`. Two plaintext
//! representations are supported, selected by
//! [`Encoding`](super::params::Encoding) and surfaced uniformly through
//! the [`Encoder`] trait:
//!
//! - **Scalar** ([`ScalarEncoder`]): each integer becomes a
//!   signed-binary polynomial `m(x)` with coefficients in {-1, 0, 1}
//!   such that `m(2) = ż` (§4.5). Decoding evaluates at `x = 2` and
//!   divides by the algorithm's known global scale factor. The
//!   original free functions ([`encode_int`], [`encode_biguint`], …)
//!   remain and the encoder delegates to them bit-identically.
//! - **Slot packing** ([`SlotEncoder`]): when `t` is a prime
//!   ≡ 1 (mod 2d), `Z_t[x]/(x^d + 1)` CRT-factors into `d` linear
//!   factors, so one plaintext carries `d` independent values with
//!   slot-wise add/mul semantics (the classic SIMD batching of the
//!   encrypted-statistical-ML line). Encoding is an inverse NTT over
//!   `Z_t`, decoding a forward NTT — reusing the
//!   [`NttTable`](crate::math::ntt::NttTable) machinery at the `t`
//!   level. Values are carried mod t, so correctness needs the *final*
//!   true value below `t/2` (a value bound, vs the scalar path's
//!   coefficient bound).

use std::collections::HashMap;

use crate::math::bigint::{BigInt, BigUint};
use crate::math::modarith::mulmod;
use crate::math::ntt::NttTable;
use crate::math::primes::{is_prime, primitive_2d_root};

use super::plaintext::Plaintext;

/// Quantise a real value to `⌊10^φ·z⌉`.
pub fn quantize(z: f64, phi: u32) -> i64 {
    let scaled = z * 10f64.powi(phi as i32);
    scaled.round() as i64
}

/// Inverse of [`quantize`] (the value the algorithm actually sees).
pub fn dequantize(zq: i64, phi: u32) -> f64 {
    zq as f64 / 10f64.powi(phi as i32)
}

/// Signed-binary coefficients of an integer: `Σ c_i 2^i = v`,
/// `c_i ∈ {-1, 0, 1}` (plain binary of |v| with the sign distributed).
pub fn int_to_signed_binary(v: i64) -> Vec<i64> {
    let neg = v < 0;
    let mut mag = v.unsigned_abs();
    let mut out = Vec::new();
    while mag > 0 {
        let bit = (mag & 1) as i64;
        out.push(if neg { -bit } else { bit });
        mag >>= 1;
    }
    out
}

/// Encode an already-quantised integer as a plaintext polynomial.
pub fn encode_int(v: i64, d: usize) -> Plaintext {
    let coeffs = int_to_signed_binary(v);
    assert!(coeffs.len() <= d, "encoded integer exceeds ring degree");
    Plaintext::from_signed(d, &coeffs)
}

/// Encode a real value: quantise then binary-decompose.
pub fn encode_value(z: f64, phi: u32, d: usize) -> Plaintext {
    encode_int(quantize(z, phi), d)
}

/// Encode a non-negative big constant (the pre-groupable rescaling
/// factors like `10^{kφ}·ν̃^{k-1}`, which can exceed u64).
pub fn encode_biguint(v: &BigUint, d: usize) -> Plaintext {
    let bits = v.bit_len();
    assert!(bits <= d, "constant exceeds ring degree");
    let mut coeffs = vec![BigInt::zero(); d];
    for (i, c) in coeffs.iter_mut().enumerate().take(bits) {
        if v.bit(i) {
            *c = BigInt::from_i64(1);
        }
    }
    Plaintext { coeffs }
}

/// Encode a signed big constant.
pub fn encode_bigint(v: &BigInt, d: usize) -> Plaintext {
    let mut pt = encode_biguint(&v.mag, d);
    if v.neg {
        for c in pt.coeffs.iter_mut() {
            *c = c.neg_value();
        }
    }
    pt
}

/// Decode: evaluate the message at 2 and divide by the global scale.
pub fn decode(pt: &Plaintext, scale: &BigUint) -> f64 {
    pt.eval_at_2_scaled(scale)
}

/// Decode an integer exactly (no scale division).
pub fn decode_exact(pt: &Plaintext) -> BigInt {
    pt.eval_at_2()
}

/// Unified encoding API: one interface over the scalar signed-binary
/// representation and CRT slot packing, so the descent loops and
/// `els/scaling.rs` never hard-code a representation. Obtain the
/// active implementation from
/// [`FvContext::encoder`](super::context::FvContext::encoder).
pub trait Encoder: Send + Sync {
    /// Logical values one plaintext carries (1 scalar, `d` packed).
    fn slots(&self) -> usize;

    /// Encode one already-quantised integer (broadcast to every slot
    /// in packed mode).
    fn encode_int(&self, v: i64) -> Plaintext;

    /// Encode one integer per slot (`vs.len() ≤ slots()`, remaining
    /// slots zero). Scalar encoders accept at most one value.
    fn encode_vec(&self, vs: &[i64]) -> Plaintext;

    /// Encode a non-negative big constant (broadcast in packed mode,
    /// where it is carried mod t).
    fn encode_const_biguint(&self, v: &BigUint) -> Plaintext;

    /// Encode a signed big constant (broadcast in packed mode).
    fn encode_const_bigint(&self, v: &BigInt) -> Plaintext;

    /// Exact integer carried by `slot` of a (decrypted) plaintext.
    fn decode_slot(&self, pt: &Plaintext, slot: usize) -> BigInt;

    /// Exact integers carried by the first `n` slots.
    fn decode_vec(&self, pt: &Plaintext, n: usize) -> Vec<BigInt> {
        (0..n).map(|s| self.decode_slot(pt, s)).collect()
    }
}

/// The original §3.1 signed-binary encoding behind the [`Encoder`]
/// interface — delegates to the free functions, so behaviour is
/// bit-identical to the pre-trait API.
#[derive(Clone, Debug)]
pub struct ScalarEncoder {
    /// Ring degree.
    pub d: usize,
}

impl Encoder for ScalarEncoder {
    fn slots(&self) -> usize {
        1
    }

    fn encode_int(&self, v: i64) -> Plaintext {
        encode_int(v, self.d)
    }

    fn encode_vec(&self, vs: &[i64]) -> Plaintext {
        assert!(vs.len() <= 1, "scalar encoding carries one value per plaintext");
        encode_int(vs.first().copied().unwrap_or(0), self.d)
    }

    fn encode_const_biguint(&self, v: &BigUint) -> Plaintext {
        encode_biguint(v, self.d)
    }

    fn encode_const_bigint(&self, v: &BigInt) -> Plaintext {
        encode_bigint(v, self.d)
    }

    fn decode_slot(&self, pt: &Plaintext, slot: usize) -> BigInt {
        assert_eq!(slot, 0, "scalar encoding has a single slot");
        decode_exact(pt)
    }
}

/// CRT slot packing over a prime `t ≡ 1 (mod 2d)`.
///
/// Slot layout: two rows of `d/2`. Row-0 slot `j` is the evaluation of
/// the message polynomial at `ψ^{3^j}`, row-1 slot `d/2 + j` the
/// evaluation at `ψ^{−3^j}` (exponents mod 2d, ψ a fixed primitive
/// 2d-th root of unity mod t). Because ⟨3⟩ and −1 together generate
/// the odd residues mod 2d, the Galois map `x → x^{3^r}` rotates each
/// row left by `r` and `x → x^{2d−1}` swaps the rows — exactly the
/// `rotate_rows`/`slot_sum` engine operations
/// (`fhe/ops.rs`).
#[derive(Clone, Debug)]
pub struct SlotEncoder {
    /// Plaintext modulus (prime ≡ 1 mod 2d, below 2^62).
    pub t: u64,
    /// Ring degree = slot count.
    pub d: usize,
    /// Negacyclic NTT over `Z_t`: coefficient ↔ evaluation form.
    table: NttTable,
    /// `slot_to_index[s]` = the transform-output index carrying slot
    /// `s`'s evaluation (the transform's output order is an
    /// implementation detail of `math/ntt`; see [`SlotEncoder::new`]).
    slot_to_index: Vec<usize>,
}

impl SlotEncoder {
    /// Build the slot maps for `(t, d)` (panics unless `t` is a prime
    /// ≡ 1 mod 2d and `d` a power of two ≥ 2 — [`super::params::FvParams::validate_encoding`]
    /// checks the same conditions fallibly).
    pub fn new(t: u64, d: usize) -> Self {
        assert!(d.is_power_of_two() && d >= 2, "slot packing needs a power-of-two d ≥ 2");
        assert!(
            t % (2 * d as u64) == 1 && is_prime(t),
            "slot packing needs a prime t ≡ 1 (mod 2d), got t = {t}, d = {d}"
        );
        let table = NttTable::new(t, d);
        // The transform's output permutation (bit-reversal, base-root
        // convention) is private to math/ntt. Recover the index ↔
        // root-exponent map empirically: the monomial x evaluates at
        // ψ^e to ψ^e itself, so one forward transform plus a discrete
        // log against the known ψ labels every output index.
        let mut mono = vec![0u64; d];
        mono[1] = 1;
        table.forward(&mut mono);
        let psi = primitive_2d_root(t, d);
        let psi_sq = mulmod(psi, psi, t);
        let mut exp_of_power = HashMap::with_capacity(d);
        let mut cur = psi; // ψ^1, ψ^3, ψ^5, … (the d odd powers)
        for k in 0..d {
            exp_of_power.insert(cur, 2 * k + 1);
            cur = mulmod(cur, psi_sq, t);
        }
        let mut index_of_exp = vec![usize::MAX; 2 * d];
        for (i, v) in mono.iter().enumerate() {
            let e = *exp_of_power.get(v).expect("NTT output of x must be an odd power of ψ");
            index_of_exp[e] = i;
        }
        let m = 2 * d as u64;
        let mut slot_to_index = vec![0usize; d];
        let mut g = 1u64; // 3^j mod 2d
        for j in 0..d / 2 {
            slot_to_index[j] = index_of_exp[g as usize];
            slot_to_index[d / 2 + j] = index_of_exp[(m - g) as usize];
            g = g * 3 % m;
        }
        SlotEncoder { t, d, table, slot_to_index }
    }

    /// Canonical `[0, t)` residues of a plaintext's coefficients.
    fn canonical_coeffs(&self, pt: &Plaintext) -> Vec<u64> {
        assert!(pt.coeffs.len() <= self.d, "plaintext longer than ring degree");
        let mut out = vec![0u64; self.d];
        for (i, c) in pt.coeffs.iter().enumerate() {
            out[i] = c.mod_u64(self.t);
        }
        out
    }

    /// Plaintext from canonical `[0, t)` coefficients, re-centered to
    /// the symmetric range (matching what decryption produces).
    fn plaintext_from_canonical(&self, coeffs: Vec<u64>) -> Plaintext {
        Plaintext { coeffs: coeffs.into_iter().map(|c| self.center(c)).collect() }
    }

    /// Centered representative of a canonical residue (t < 2^62, so
    /// both halves fit i64).
    fn center(&self, v: u64) -> BigInt {
        debug_assert!(v < self.t);
        if v > self.t / 2 {
            BigInt::from_i64(-((self.t - v) as i64))
        } else {
            BigInt::from_i64(v as i64)
        }
    }

    /// Signed value → canonical residue mod t.
    fn to_canonical_i64(&self, v: i64) -> u64 {
        v.rem_euclid(self.t as i64) as u64
    }

    /// Canonical `[0, t)` values of every slot (one forward transform).
    pub fn slot_values(&self, pt: &Plaintext) -> Vec<u64> {
        let mut evals = self.canonical_coeffs(pt);
        self.table.forward(&mut evals);
        self.slot_to_index.iter().map(|&i| evals[i]).collect()
    }

    /// Encode canonical `[0, t)` slot values (length ≤ d, rest zero;
    /// one inverse transform).
    pub fn encode_slots_u64(&self, vals: &[u64]) -> Plaintext {
        assert!(vals.len() <= self.d, "more slot values than slots");
        let mut evals = vec![0u64; self.d];
        for (s, &v) in vals.iter().enumerate() {
            assert!(v < self.t, "slot value {v} out of range for t = {}", self.t);
            evals[self.slot_to_index[s]] = v;
        }
        self.table.inverse(&mut evals);
        self.plaintext_from_canonical(evals)
    }
}

impl Encoder for SlotEncoder {
    fn slots(&self) -> usize {
        self.d
    }

    fn encode_int(&self, v: i64) -> Plaintext {
        // Broadcast: a constant polynomial evaluates to the same value
        // in every slot — no transform needed.
        let mut coeffs = vec![0u64; self.d];
        coeffs[0] = self.to_canonical_i64(v);
        self.plaintext_from_canonical(coeffs)
    }

    fn encode_vec(&self, vs: &[i64]) -> Plaintext {
        let half = self.t / 2;
        let vals: Vec<u64> = vs
            .iter()
            .map(|&v| {
                assert!(v.unsigned_abs() <= half, "packed value |{v}| exceeds t/2");
                self.to_canonical_i64(v)
            })
            .collect();
        self.encode_slots_u64(&vals)
    }

    fn encode_const_biguint(&self, v: &BigUint) -> Plaintext {
        let mut coeffs = vec![0u64; self.d];
        coeffs[0] = v.mod_u64(self.t);
        self.plaintext_from_canonical(coeffs)
    }

    fn encode_const_bigint(&self, v: &BigInt) -> Plaintext {
        let mut coeffs = vec![0u64; self.d];
        coeffs[0] = v.mod_u64(self.t);
        self.plaintext_from_canonical(coeffs)
    }

    fn decode_slot(&self, pt: &Plaintext, slot: usize) -> BigInt {
        assert!(slot < self.d, "slot {slot} out of range for d = {}", self.d);
        self.center(self.slot_values(pt)[slot])
    }

    fn decode_vec(&self, pt: &Plaintext, n: usize) -> Vec<BigInt> {
        assert!(n <= self.d, "asked for {n} slots, have {}", self.d);
        let vals = self.slot_values(pt);
        vals[..n].iter().map(|&v| self.center(v)).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::{gen, PropRunner};

    #[test]
    fn quantize_examples() {
        assert_eq!(quantize(1.234, 2), 123);
        assert_eq!(quantize(1.235, 2), 124); // round half away handled by f64 round
        assert_eq!(quantize(-0.555, 2), -56);
        assert_eq!(quantize(0.0, 2), 0);
        assert_eq!(quantize(3.0, 0), 3);
    }

    #[test]
    fn encode_decode_int_roundtrip() {
        let mut run = PropRunner::new("encoding_int_roundtrip", 500);
        run.run(|rng| {
            let v = gen::int_in(rng, -1_000_000_000, 1_000_000_000);
            let pt = encode_int(v, 64);
            assert_eq!(decode_exact(&pt).to_i128(), Some(v as i128));
            // coefficients really are in {-1, 0, 1} and share v's sign
            for c in &pt.coeffs {
                assert!(c.mag.to_u64().unwrap_or(2) <= 1);
            }
        });
    }

    #[test]
    fn encode_value_quantisation_error() {
        let mut run = PropRunner::new("encoding_value", 300);
        run.run(|rng| {
            let z = gen::f64_in(rng, -100.0, 100.0);
            let phi = 2;
            let pt = encode_value(z, phi, 64);
            let back =
                decode(&pt, &BigUint::from_u64(100)); // scale 10^phi
            assert!((back - z).abs() <= 0.5 / 100.0 + 1e-12, "z={z} back={back}");
        });
    }

    #[test]
    fn encode_biguint_large_constant() {
        let v = BigUint::pow10(30); // far beyond u64
        let pt = encode_biguint(&v, 256);
        let val = decode_exact(&pt);
        assert!(!val.neg);
        assert_eq!(val.mag.to_decimal(), v.to_decimal());
    }

    #[test]
    fn encode_bigint_negative() {
        let v = BigInt::from_i64(-123456789);
        let pt = encode_bigint(&v, 64);
        assert_eq!(decode_exact(&pt).to_i128(), Some(-123456789));
    }

    #[test]
    fn degree_is_bit_length() {
        let pt = encode_int(1 << 20, 64);
        assert_eq!(pt.degree(), 20);
        assert_eq!(encode_int(0, 16).degree(), -1);
    }

    #[test]
    #[should_panic(expected = "exceeds ring degree")]
    fn overflow_degree_panics() {
        let _ = encode_int(i64::MAX, 8);
    }

    /// Largest prime ≡ 1 (mod 2d) below 2^30 — a packing-friendly t.
    fn slot_t(d: usize) -> u64 {
        crate::math::primes::ntt_primes_below(1 << 30, 2 * d as u64, 1)[0]
    }

    /// Coefficient-side Galois map `x → x^g` on a plaintext (the
    /// message-space oracle for what `fhe/ops.rs::apply_galois` does to
    /// ciphertexts).
    fn apply_auto(pt: &Plaintext, g: usize, d: usize) -> Plaintext {
        let mut out = vec![BigInt::zero(); d];
        for i in 0..d {
            let e = (i * g) % (2 * d);
            let c = pt.coeffs.get(i).cloned().unwrap_or_else(BigInt::zero);
            if e < d {
                out[e] = c;
            } else {
                out[e - d] = c.neg_value();
            }
        }
        Plaintext { coeffs: out }
    }

    #[test]
    fn scalar_encoder_matches_free_functions() {
        let enc = ScalarEncoder { d: 64 };
        assert_eq!(enc.slots(), 1);
        assert_eq!(enc.encode_int(-123456), encode_int(-123456, 64));
        assert_eq!(enc.encode_vec(&[42]), encode_int(42, 64));
        assert_eq!(enc.encode_vec(&[]), encode_int(0, 64));
        let big = BigUint::pow10(12);
        assert_eq!(enc.encode_const_biguint(&big), encode_biguint(&big, 64));
        let pt = enc.encode_int(-987);
        assert_eq!(enc.decode_slot(&pt, 0).to_i128(), Some(-987));
    }

    #[test]
    fn slot_roundtrip_property() {
        let d = 16usize;
        let t = slot_t(d);
        let enc = SlotEncoder::new(t, d);
        let half = (t / 2) as i64;
        let mut run = PropRunner::new("slot_roundtrip", 200);
        run.run(|rng| {
            let n = gen::int_in(rng, 0, d as i64) as usize;
            let vs: Vec<i64> = (0..n).map(|_| gen::int_in(rng, -half, half)).collect();
            let pt = enc.encode_vec(&vs);
            // Encoded coefficients are centered mod t.
            for c in &pt.coeffs {
                assert!(c.mag.to_u64().unwrap() <= t / 2);
            }
            let back = enc.decode_vec(&pt, d);
            for s in 0..d {
                let expect = vs.get(s).copied().unwrap_or(0);
                assert_eq!(back[s].to_i128(), Some(expect as i128), "slot {s}");
            }
        });
    }

    #[test]
    fn slotwise_mul_and_add_semantics() {
        // Ring ops on packed plaintexts act slot-wise mod t: the CRT
        // isomorphism in action, with zero changes to the arithmetic.
        let d = 8usize;
        let t = slot_t(d);
        let enc = SlotEncoder::new(t, d);
        let a: Vec<i64> = vec![3, -7, 0, 123_456, -99_999, 1, 2, -3];
        let b: Vec<i64> = vec![-5, 11, 42, 2, 100_003, -1, 0, 7];
        let (pa, pb) = (enc.encode_vec(&a), enc.encode_vec(&b));
        let prod = pa.mul(&pb);
        let sum = pa.add(&pb);
        let sp = enc.decode_vec(&prod, d);
        let ss = enc.decode_vec(&sum, d);
        for s in 0..d {
            assert_eq!(sp[s].to_i128(), Some(a[s] as i128 * b[s] as i128), "mul slot {s}");
            assert_eq!(ss[s].to_i128(), Some((a[s] + b[s]) as i128), "add slot {s}");
        }
    }

    #[test]
    fn broadcast_constant_fills_every_slot() {
        let d = 16usize;
        let t = slot_t(d);
        let enc = SlotEncoder::new(t, d);
        let pt = enc.encode_int(-4242);
        for s in 0..d {
            assert_eq!(enc.decode_slot(&pt, s).to_i128(), Some(-4242));
        }
        // Big constants are carried mod t.
        let big = BigUint::pow10(25);
        let pt = enc.encode_const_biguint(&big);
        let want = big.mod_u64(t);
        let want = if want > t / 2 { want as i128 - t as i128 } else { want as i128 };
        assert_eq!(enc.decode_slot(&pt, 3).to_i128(), Some(want));
    }

    #[test]
    fn automorphism_rotates_rows_and_swaps() {
        // The slot layout promise behind rotate_rows/slot_sum:
        // x → x^{3^r} rotates each d/2-row left by r; x → x^{2d−1}
        // swaps the rows.
        let d = 16usize;
        let half = d / 2;
        let t = slot_t(d);
        let enc = SlotEncoder::new(t, d);
        let vs: Vec<i64> = (0..d as i64).map(|i| 10 * i + 1).collect();
        let pt = enc.encode_vec(&vs);
        let mut g = 1usize;
        for r in 0..half {
            let rot = apply_auto(&pt, g, d);
            let got = enc.decode_vec(&rot, d);
            for j in 0..half {
                let src = (j + r) % half;
                assert_eq!(got[j].to_i128(), Some(vs[src] as i128), "row0 r={r} j={j}");
                assert_eq!(
                    got[half + j].to_i128(),
                    Some(vs[half + src] as i128),
                    "row1 r={r} j={j}"
                );
            }
            g = g * 3 % (2 * d);
        }
        let swapped = apply_auto(&pt, 2 * d - 1, d);
        let got = enc.decode_vec(&swapped, d);
        for j in 0..half {
            assert_eq!(got[j].to_i128(), Some(vs[half + j] as i128));
            assert_eq!(got[half + j].to_i128(), Some(vs[j] as i128));
        }
    }

    #[test]
    fn slot_sum_via_row_rotations_and_swap() {
        // The message-space proof of the O(log d) slot_sum schedule:
        // log2(d/2) doubling rotations + one row swap leave the total
        // in every slot.
        let d = 8usize;
        let half = d / 2;
        let t = slot_t(d);
        let enc = SlotEncoder::new(t, d);
        let vs: Vec<i64> = vec![5, -3, 11, 7, 2, 0, -6, 4];
        let total: i64 = vs.iter().sum();
        let mut acc = enc.encode_vec(&vs);
        let mut step = 1usize;
        while step < half {
            let g = {
                let mut g = 1usize;
                for _ in 0..step {
                    g = g * 3 % (2 * d);
                }
                g
            };
            acc = acc.add(&apply_auto(&acc, g, d));
            step *= 2;
        }
        acc = acc.add(&apply_auto(&acc, 2 * d - 1, d));
        for s in 0..d {
            assert_eq!(enc.decode_slot(&acc, s).to_i128(), Some(total as i128), "slot {s}");
        }
    }
}
