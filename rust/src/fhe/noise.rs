//! Exact noise measurement (requires the secret key; test/diagnostic
//! tool and the empirical validator for the §4.5 parameter planner).
//!
//! **Trust caveat**: everything here decrypts, so it runs only where
//! the secret key legitimately lives — the data holder's side, or
//! tests. The per-iteration trajectory built on top of this module
//! ([`els::probe`](crate::els::probe), measured budget vs the planner's
//! predicted floor) inherits exactly the same trust model: it is a
//! diagnostic observer, never part of the evaluating server.
//!
//! Uses the *invariant noise* convention: for phase
//! `v = [c₀ + c₁s]_q = Δm + e`, the quantity `[t·v]_q` equals
//! `t·e − (q mod t)·m`, whose ∞-norm must stay below `q/2` for correct
//! decryption. The budget is `log2(q) − log2(2·‖[t·v]_q‖∞)` bits.
//!
//! ## Fused inner-product accounting
//!
//! A `dot_pairs` group of `k` terms performs the same `k` tensor
//! products as the pair-by-pair fold — the *multiplicative* noise
//! growth (≈ `2·d·t` per operand pair) is identical — but the
//! *additive* terms differ: the fold pays `k` scale-and-round
//! roundings (≈ `(1 + d·‖s‖₁ + d·‖s‖₁²)/2` invariant-noise ulps each)
//! plus `k` relinearisation noises (≈ `ℓ·d·2^29·B/q` each), where the
//! fused pipeline pays `⌈k/chunk⌉` roundings and exactly one
//! relinearisation noise — rounding **the sum** rather than summing
//! the roundings. [`fused_noise_terms`] is the counting form of that
//! statement; since both counts are ≤ the fold's `(k, k)` for every
//! `k ≥ 1`, fusing only tightens the §4 correctness bounds (the
//! planner's flat additive reserve stays valid unchanged).
//!
//! ## Packed (slot) accounting
//!
//! Two things change under slot packing, one per side of the budget:
//!
//! - **Noise growth.** A scalar-mode rescaling constant is encoded in
//!   signed binary, so its ℓ₁-norm is its popcount and the planner's
//!   `const_bits` term is small. A packed constant is slot-*broadcast*
//!   — a single degree-0 coefficient `c mod t` (centred) — so its
//!   ℓ₁-norm is the centred value itself, up to `t/2`. Plain-mul noise
//!   growth in packed mode is therefore bounded by the generic
//!   `d·t`-style factor already charged per level, not the tighter
//!   popcount refinement; `FvParams::custom_packed` sizes `q` for the
//!   generic bound. Rotations add only relinearisation-shaped noise
//!   (`≈ ℓ·d·2^29·B/q` per key switch, no depth), so a `slot_sum`'s
//!   `log₂(d/2)+1` switches cost far less than one multiplication.
//! - **Correctness bound.** Scalar mode needs every *coefficient* of
//!   the encoded product below `t/2`; packed mode evaluates at the CRT
//!   roots, so it needs every true slot *value* (each a full inner
//!   product, not a convolution coefficient) below `t/2`. Values grow
//!   much faster than coefficients — packed `t` must cover the largest
//!   scaled intermediate of the whole descent, which is why
//!   `custom_packed` takes `t_bits` explicitly instead of reusing the
//!   scalar planner's coefficient-growth model.

use super::ciphertext::Ciphertext;
use super::context::FvContext;
use super::keys::SecretKey;

/// Additive-noise term counts `(relinearisations, roundings)` for a
/// fused inner product of `k` pairs accumulated in chunks of `chunk`
/// terms: one relinearisation for the whole group, one scale-and-round
/// rounding per accumulation chunk. The pair-by-pair fold's counts are
/// `(k, k)`; the fused counts are never larger, so every §4 bound that
/// sums additive noise over these events is tightened by fusion.
pub fn fused_noise_terms(k: u64, chunk: u64) -> (u64, u64) {
    assert!(k >= 1 && chunk >= 1);
    (1, k.div_ceil(chunk))
}

/// Remaining noise budget in bits (≤ 0 means decryption may fail).
pub fn noise_budget_bits(ctx: &FvContext, ct: &Ciphertext, sk: &SecretKey) -> f64 {
    let v = ctx.raw_phase(ct, sk);
    let coeffs = FvContext::lift_signed_poly(&ctx.ring_q, &v);
    let mut max_bits = 0usize;
    for c in coeffs {
        // [t·v]_q symmetric
        let tv = crate::math::bigint::BigInt { neg: c.neg, mag: c.mag.mul(&ctx.t) };
        let r = tv.rem_euclid_big(&ctx.q);
        let sym = if r.cmp_big(&ctx.q.shr_bits(1)) == std::cmp::Ordering::Greater {
            ctx.q.sub(&r)
        } else {
            r
        };
        max_bits = max_bits.max(sym.bit_len());
    }
    ctx.q.bit_len() as f64 - 1.0 - max_bits as f64
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fhe::keys::keygen;
    use crate::fhe::params::FvParams;
    use crate::fhe::plaintext::Plaintext;
    use crate::fhe::rng::ChaChaRng;

    #[test]
    fn budget_decreases_monotonically() {
        let ctx = FvContext::new(FvParams::custom(512, 5, 16));
        let mut rng = ChaChaRng::from_seed(61);
        let keys = keygen(&ctx, &mut rng);
        let m = Plaintext::from_signed(ctx.d(), &[0, 1, 1]);
        let fresh = ctx.encrypt(&m, &keys.pk, &mut rng);
        let b0 = noise_budget_bits(&ctx, &fresh, &keys.sk);
        let m1 = ctx.mul_ct(&fresh, &fresh, &keys.rk);
        let b1 = noise_budget_bits(&ctx, &m1, &keys.sk);
        let m2 = ctx.mul_ct(&m1, &fresh, &keys.rk);
        let b2 = noise_budget_bits(&ctx, &m2, &keys.sk);
        assert!(b0 > b1 && b1 > b2, "budgets {b0} {b1} {b2}");
        assert!(b2 > 0.0, "depth-2 chain should still decrypt");
    }

    #[test]
    fn positive_budget_implies_correct_decryption_property() {
        // The §4.5 decryption-correctness invariant, as a property test:
        // whenever the measured invariant-noise budget is positive, the
        // decrypted message must equal the exact integer product.
        use crate::fhe::encoding::encode_int;
        use crate::util::prop::{gen, PropRunner};
        let ctx = FvContext::new(FvParams::custom(256, 4, 22));
        let mut rng = ChaChaRng::from_seed(64);
        let keys = keygen(&ctx, &mut rng);
        let mut run = PropRunner::new("noise_budget_correctness", 6);
        run.run(|rng| {
            let a = gen::int_in(rng, -1000, 1000);
            let b = gen::int_in(rng, -1000, 1000);
            let ca = ctx.encrypt(&encode_int(a, ctx.d()), &keys.pk, rng);
            let cb = ctx.encrypt(&encode_int(b, ctx.d()), &keys.pk, rng);
            let prod = ctx.mul_ct(&ca, &cb, &keys.rk);
            let budget = noise_budget_bits(&ctx, &prod, &keys.sk);
            assert!(budget > 0.0, "depth-1 product must stay in budget ({budget})");
            let dec = ctx.decrypt(&prod, &keys.sk);
            assert_eq!(
                dec.eval_at_2().to_i128(),
                Some(a as i128 * b as i128),
                "positive budget ({budget} bits) must imply exact decryption"
            );
        });
    }

    #[test]
    fn per_level_budget_loss_matches_planner_model() {
        // The §4.5 planner sizes q by the shared per-level consumption
        // model (fhe::params::per_level_noise_bits). Measure the realised
        // per-level loss on a depth-2 chain and check it stays under the
        // planner's allowance (with slack), and is not trivially zero.
        use crate::fhe::params::per_level_noise_bits;
        let params = FvParams::custom(512, 6, 16);
        let t_bits = params.t.bit_len();
        // ℓ1(m) = 2 for the message below — same const-bits rule as the
        // planner: bits of (ℓ1 − 1).
        let const_bits = 64 - (2u64 - 1).leading_zeros() as usize;
        let allowance = per_level_noise_bits(t_bits, params.d, const_bits) as f64;
        let ctx = FvContext::new(params);
        let mut rng = ChaChaRng::from_seed(65);
        let keys = keygen(&ctx, &mut rng);
        let m = Plaintext::from_signed(ctx.d(), &[0, 1, 1]); // ℓ1 = 2
        let fresh = ctx.encrypt(&m, &keys.pk, &mut rng);
        let mut budgets = vec![noise_budget_bits(&ctx, &fresh, &keys.sk)];
        let mut cur = fresh.clone();
        for _ in 0..2 {
            cur = ctx.mul_ct(&cur, &fresh, &keys.rk);
            budgets.push(noise_budget_bits(&ctx, &cur, &keys.sk));
        }
        for w in budgets.windows(2) {
            let loss = w[0] - w[1];
            assert!(loss > 2.0, "a ct-mult must consume real budget (loss {loss})");
            assert!(
                loss <= allowance + 10.0,
                "per-level loss {loss} exceeds the planner allowance {allowance}"
            );
        }
        assert!(*budgets.last().unwrap() > 0.0, "depth-2 chain should still decrypt");
    }

    #[test]
    fn fused_noise_terms_never_exceed_the_fold() {
        for k in 1..=20u64 {
            for chunk in 1..=8u64 {
                let (relins, roundings) = fused_noise_terms(k, chunk);
                assert_eq!(relins, 1);
                assert!(roundings <= k, "k={k} chunk={chunk}");
                assert_eq!(roundings, k.div_ceil(chunk));
            }
        }
        // Un-chunked (the production case): exactly one of each.
        assert_eq!(fused_noise_terms(16, 1 << 20), (1, 1));
    }

    #[test]
    fn fused_inner_product_is_no_noisier_than_fold() {
        // The empirical form of the accounting above: on the same
        // operands, the fused dot's measured invariant-noise budget
        // must be at least the pair-by-pair fold's (one relin + one
        // rounding versus k of each). Checked on both backends.
        use crate::fhe::encoding::encode_int;
        use crate::fhe::params::MulBackend;
        for backend in [MulBackend::FullRns, MulBackend::ExactBigint] {
            let mut params = FvParams::custom(256, 3, 24);
            params.mul_backend = backend;
            let ctx = FvContext::new(params);
            let mut rng = ChaChaRng::from_seed(66);
            let keys = keygen(&ctx, &mut rng);
            let cts: Vec<(Ciphertext, Ciphertext)> = (0..6i64)
                .map(|k| {
                    (
                        ctx.encrypt(&encode_int(k - 2, ctx.d()), &keys.pk, &mut rng),
                        ctx.encrypt(&encode_int(3 - k, ctx.d()), &keys.pk, &mut rng),
                    )
                })
                .collect();
            let pairs: Vec<(&Ciphertext, &Ciphertext)> =
                cts.iter().map(|(a, b)| (a, b)).collect();
            let fused = ctx.dot_group(&pairs, &keys.rk);
            let mut fold = ctx.mul_ct(pairs[0].0, pairs[0].1, &keys.rk);
            for (a, b) in &pairs[1..] {
                fold = ctx.add_ct(&fold, &ctx.mul_ct(a, b, &keys.rk));
            }
            assert_eq!(ctx.decrypt(&fused, &keys.sk), ctx.decrypt(&fold, &keys.sk));
            let b_fused = noise_budget_bits(&ctx, &fused, &keys.sk);
            let b_fold = noise_budget_bits(&ctx, &fold, &keys.sk);
            assert!(b_fused > 0.0, "{backend:?}: fused budget exhausted ({b_fused})");
            // One rounding + one relin noise versus k of each: within
            // the integer-bit measurement granularity, fusion is never
            // materially noisier (and is typically strictly better).
            assert!(
                b_fused >= b_fold - 1.0,
                "{backend:?}: fused budget {b_fused} below fold budget {b_fold}"
            );
        }
    }

    #[test]
    fn addition_costs_little() {
        let ctx = FvContext::new(FvParams::custom(256, 3, 20));
        let mut rng = ChaChaRng::from_seed(62);
        let keys = keygen(&ctx, &mut rng);
        let m = Plaintext::from_signed(ctx.d(), &[1]);
        let c = ctx.encrypt(&m, &keys.pk, &mut rng);
        let b0 = noise_budget_bits(&ctx, &c, &keys.sk);
        let mut acc = c.clone();
        for _ in 0..16 {
            acc = ctx.add_ct(&acc, &c);
        }
        let b1 = noise_budget_bits(&ctx, &acc, &keys.sk);
        assert!(b0 - b1 < 6.0, "16 additions cost {} bits", b0 - b1);
    }
}
