//! FV parameter selection (paper §4.5).
//!
//! Combines three published ingredients, exactly as the paper
//! prescribes:
//!
//! 1. **Lemma 3** — growth bounds on the degree and coefficients of the
//!    encrypted regression coefficients, which lower-bound the ring
//!    degree `d` and the plaintext modulus `t`. We implement both the
//!    lemma's stated recursion (`lemma3_*`, used by the `lemma3`
//!    experiment) and a tighter exact-constant recursion
//!    (`MessageGrowth`, used for actual planning and validated
//!    empirically by the test-suite).
//! 2. **Lindner–Peikert '11** — the security estimate used by the FV
//!    paper: a scheme with ring degree `d`, modulus `q`, noise width σ
//!    attains roughly `λ ≈ 7.2·d / log2(q/σ) − 110` bits of security.
//! 3. **Lepoint–Naehrig '14-style noise budgeting** — per-level noise
//!    consumption sizes the ciphertext modulus `q` for a target
//!    multiplicative depth without bootstrapping.

use crate::util::error::{bail, Result};

use crate::math::bigint::BigUint;
use crate::math::primes::{is_prime, ntt_primes_below, rns_basis_primes};

use super::sampler::DEFAULT_CBD_K;

/// Which ciphertext-multiplication pipeline [`crate::fhe::FvContext`]
/// dispatches to (see `fhe/rns_mul.rs` and ROADMAP's `mul_pairs` cost
/// note).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Default)]
pub enum MulBackend {
    /// Per-coefficient bigint CRT lifts with exact `⌊t·v/q⌉` rounding —
    /// the original pipeline, kept as the cross-backend correctness
    /// oracle (`ELS_MUL_BACKEND=bigint` forces it suite-wide in CI).
    ExactBigint,
    /// Full-RNS pipeline (default): fast base extension, residue-plane
    /// tensor product and scale-and-round, Shenoy–Kumaresan conversion
    /// back — zero bigint allocations per multiply.
    #[default]
    FullRns,
}

impl MulBackend {
    /// Process-wide default, overridable via `ELS_MUL_BACKEND`
    /// (`bigint`/`oracle` or `rns`). Used by the CI oracle gate, so a
    /// typo must fail loudly rather than silently test the default
    /// backend twice.
    pub fn from_env() -> Self {
        match std::env::var("ELS_MUL_BACKEND").as_deref() {
            Ok("bigint") | Ok("oracle") | Ok("exact") => MulBackend::ExactBigint,
            Ok("rns") | Ok("fullrns") | Ok("") | Err(_) => MulBackend::FullRns,
            Ok(other) => {
                panic!("unknown ELS_MUL_BACKEND '{other}' (expected rns|bigint)")
            }
        }
    }
}

/// How plaintext polynomials carry messages (see `fhe/encoding.rs`).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Default)]
pub enum Encoding {
    /// One logical scalar per ciphertext, signed-binary coefficient
    /// encoding (the original paper pipeline). Works for any `t`.
    #[default]
    Scalar,
    /// CRT slot packing: `Z_t[x]/(x^d+1)` factors into `d` independent
    /// slots when `t` is a prime ≡ 1 (mod 2d), so one ciphertext
    /// carries `d` values with slot-wise add/mul semantics. Requires
    /// [`FvParams::validate_encoding`] to pass.
    Packed,
}

impl Encoding {
    /// Process-wide default, overridable via `ELS_ENCODING`
    /// (`packed`/`slot` or `scalar`). Used by the CI packed leg, so a
    /// typo must fail loudly rather than silently test the default
    /// encoding twice.
    pub fn from_env() -> Self {
        match std::env::var("ELS_ENCODING").as_deref() {
            Ok("packed") | Ok("slot") | Ok("simd") => Encoding::Packed,
            Ok("scalar") | Ok("") | Err(_) => Encoding::Scalar,
            Ok(other) => {
                panic!("unknown ELS_ENCODING '{other}' (expected scalar|packed)")
            }
        }
    }
}

/// How strictly to enforce the LP11 security floor.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SecurityProfile {
    /// No security floor: smallest ring that is *correct*. For tests,
    /// CI and fast demos only — never for real data.
    Toy,
    /// ≥ 128-bit security per the Lindner–Peikert estimate.
    Paper128,
}

/// Concrete FV parameter set.
#[derive(Clone, Debug)]
pub struct FvParams {
    /// Ring degree (power of two).
    pub d: usize,
    /// Number of RNS primes in the ciphertext modulus `q`.
    pub q_count: usize,
    /// Number of extension primes for the bigint-oracle tensor basis
    /// (`q·ext > d·q²`, i.e. `ext > d·q`). The full-RNS pipeline uses
    /// the longer [`rns_ext_primes`](Self::rns_ext_primes) superset,
    /// sized separately so enlarging it never bloats the oracle ring
    /// (or invalidates `polymul` artifacts keyed on its limb count).
    pub ext_count: usize,
    /// Plaintext modulus.
    pub t: BigUint,
    /// Centered-binomial error parameter (σ = √(k/2)).
    pub cbd_k: u32,
    /// Ciphertext-multiplication pipeline this set runs on.
    pub mul_backend: MulBackend,
    /// How plaintexts carry messages (scalar signed-binary or CRT
    /// slot packing). Purely an encoding property: the ciphertext
    /// pipelines are identical either way.
    pub encoding: Encoding,
    /// The profile this set was planned under.
    pub profile: SecurityProfile,
}

impl FvParams {
    /// Hand-rolled parameter set (tests / experiments).
    pub fn custom(d: usize, q_count: usize, t_bits: usize) -> Self {
        let mut params = FvParams {
            d,
            q_count,
            ext_count: 0,
            t: BigUint::one().shl_bits(t_bits),
            cbd_k: DEFAULT_CBD_K,
            mul_backend: MulBackend::from_env(),
            encoding: Encoding::Scalar,
            profile: SecurityProfile::Toy,
        };
        params.ext_count = params.required_ext_count();
        params
    }

    /// Hand-rolled *packed* parameter set: like [`custom`](Self::custom)
    /// but `t` is the largest prime ≡ 1 (mod 2d) below `2^t_bits` (so
    /// the plaintext ring CRT-factors into `d` slots) and the encoding
    /// is [`Encoding::Packed`]. Fails when no such prime exists or the
    /// resulting set does not validate.
    pub fn custom_packed(d: usize, q_count: usize, t_bits: usize) -> Result<Self> {
        if t_bits >= 62 {
            bail!("packed t must fit the NTT engine: t_bits = {t_bits} ≥ 62");
        }
        if 1u64 << t_bits <= 2 * d as u64 + 1 {
            bail!(
                "packed t_bits = {t_bits} leaves no prime ≡ 1 (mod 2d) below 2^{t_bits} \
                 for d = {d}"
            );
        }
        let t = ntt_primes_below(1u64 << t_bits, 2 * d as u64, 1)[0];
        let mut params = FvParams {
            d,
            q_count,
            ext_count: 0,
            t: BigUint::from_u64(t),
            cbd_k: DEFAULT_CBD_K,
            mul_backend: MulBackend::from_env(),
            encoding: Encoding::Packed,
            profile: SecurityProfile::Toy,
        };
        params.ext_count = params.required_ext_count();
        params.validate_encoding()?;
        Ok(params)
    }

    /// Re-tag an existing set with `encoding`, re-validating the
    /// plaintext modulus against the packing constraint.
    pub fn with_encoding(mut self, encoding: Encoding) -> Result<Self> {
        self.encoding = encoding;
        self.validate_encoding()?;
        Ok(self)
    }

    /// Check the plaintext modulus against the encoding's constraint:
    /// packed sets need a prime `t ≡ 1 (mod 2d)` with `t < 2^62` so
    /// that `Z_t[x]/(x^d+1)` splits into `d` linear factors and the
    /// slot NTT engine applies. Scalar sets always pass.
    pub fn validate_encoding(&self) -> Result<()> {
        if self.encoding == Encoding::Scalar {
            return Ok(());
        }
        let Some(t) = self.t.to_u64() else {
            bail!(
                "packed encoding needs a plaintext modulus below 2^64 \
                 (got t with {} bits); use Encoding::Scalar or shrink t",
                self.t.bit_len()
            );
        };
        if t >= 1 << 62 {
            bail!("packed encoding needs t < 2^62 for the slot NTT (got t = {t})");
        }
        if t % (2 * self.d as u64) != 1 {
            bail!(
                "packed encoding needs t ≡ 1 (mod 2d) so Z_t[x]/(x^d+1) splits into d slots \
                 (got t = {t}, d = {}, t mod 2d = {}); pick t via FvParams::custom_packed",
                self.d,
                t % (2 * self.d as u64)
            );
        }
        if !is_prime(t) {
            bail!("packed encoding needs a prime plaintext modulus (got composite t = {t})");
        }
        Ok(())
    }

    /// Number of plaintext slots a single ciphertext carries: `d` when
    /// packed, 1 otherwise.
    pub fn slot_count(&self) -> usize {
        match self.encoding {
            Encoding::Packed => self.d,
            Encoding::Scalar => 1,
        }
    }

    /// The RNS primes of `q` (deterministic; mirrored in Python).
    pub fn q_primes(&self) -> Vec<u64> {
        rns_basis_primes(self.d, self.q_count)
    }

    /// Extension primes (continue the same descending sequence).
    pub fn ext_primes(&self) -> Vec<u64> {
        let all = rns_basis_primes(self.d, self.q_count + self.ext_count);
        all[self.q_count..].to_vec()
    }

    /// Extension primes of the full-RNS multiply basis `B`: a superset
    /// of [`ext_primes`](Self::ext_primes) (same descending sequence)
    /// sized so the `⌊t·v/q⌉` output (`|r| ≤ t·d·q/4`) fits `B`
    /// symmetrically with slack; see
    /// [`required_rns_ext_count`](Self::required_rns_ext_count).
    pub fn rns_ext_primes(&self) -> Vec<u64> {
        let all = rns_basis_primes(self.d, self.q_count + self.required_rns_ext_count());
        all[self.q_count..].to_vec()
    }

    /// The redundant Shenoy–Kumaresan modulus `m_sk`: the next prime in
    /// the same deterministic sequence after the full-RNS extension
    /// basis, so it is NTT-friendly and disjoint from Q∪B (hence also
    /// from the oracle's E ⊆ B) by construction.
    pub fn msk_prime(&self) -> u64 {
        *rns_basis_primes(self.d, self.q_count + self.required_rns_ext_count() + 1)
            .last()
            .unwrap()
    }

    pub fn q(&self) -> BigUint {
        let mut q = BigUint::one();
        for p in self.q_primes() {
            q = q.mul_u64(p);
        }
        q
    }

    pub fn q_bits(&self) -> usize {
        self.q().bit_len()
    }

    /// Minimum extension primes so that `q_ext > d·q` (tensor-product
    /// coefficients `≤ d·q²/4` then fit the joint oracle basis
    /// symmetrically).
    pub fn required_ext_count(&self) -> usize {
        let target_bits = self.q_bits() + self.d.trailing_zeros() as usize + 2;
        // Primes are just under 2^30; be conservative with 29 bits each.
        target_bits.div_ceil(29)
    }

    /// Minimum primes in the full-RNS extension basis `B`, covering
    /// both the tensor range (`B > d·q`, as for the oracle) and the
    /// scale-and-round range (`B > t·d·q/2`, since the `⌊t·v/q⌉`
    /// output lives in `B` before the Shenoy–Kumaresan conversion
    /// back). The `+2` bits of slack keep the fast forward extension's
    /// fixed-point correction provably exact for the scale-and-round
    /// operand.
    pub fn required_rns_ext_count(&self) -> usize {
        let target_bits =
            self.q_bits() + self.t.bit_len() + self.d.trailing_zeros() as usize + 2;
        // Never smaller than the oracle basis, so E stays a prefix of B.
        target_bits.div_ceil(29).max(self.ext_count).max(self.required_ext_count())
    }

    /// Error standard deviation σ = √(k/2).
    pub fn sigma(&self) -> f64 {
        (self.cbd_k as f64 / 2.0).sqrt()
    }

    /// Lindner–Peikert security estimate in bits (as used by the FV
    /// paper, §6): λ ≈ 7.2·d / log2(q/σ) − 110.
    pub fn security_bits(&self) -> f64 {
        let log_q_over_sigma = self.q_bits() as f64 - self.sigma().log2();
        7.2 * self.d as f64 / log_q_over_sigma - 110.0
    }

    /// Number of relinearisation digits: one per RNS limb of `q`. The
    /// gadget is the CRT decomposition `c₂ = Σ_i [c₂·(q/q_i)^{-1}]_{q_i}
    /// ·(q/q_i) mod q`, whose digits (`< 2^30`) are read straight off
    /// the residue planes — no lift, no base-w carry chains.
    pub fn relin_ndigits(&self) -> usize {
        self.q_count
    }

    /// Bytes of one ciphertext (2 polys × limbs × d × 8B).
    pub fn ciphertext_bytes(&self) -> usize {
        2 * self.q_count * self.d * 8
    }
}

/// Noise-budget bits one ciphertext-multiplication level consumes:
/// each ct-mult multiplies invariant noise by ≈ 2·d·t·ℓ1(const), and
/// relinearisation/slack adds a few bits. Single source of truth for
/// the planner ([`plan`]), admission control
/// ([`crate::coordinator::admission::supported_depth`]) and the noise
/// test-suite.
pub fn per_level_noise_bits(t_bits: usize, d: usize, msg_const_bits: usize) -> usize {
    t_bits + d.trailing_zeros() as usize + msg_const_bits + 6
}

/// Lemma 3 `n ≡ (φ+1)·log2(10)`, rounded up to an integer bit count.
pub fn lemma3_n(phi: u32) -> usize {
    (((phi + 1) as f64) * 10f64.log2()).ceil() as usize
}

/// Lemma 3 degree bound for ELS-GD after `k` iterations:
/// `deg(β̃^[k]) ≤ (4k − 1)·n` (closed form of the stated recursion).
pub fn lemma3_deg_bound(k: usize, phi: u32) -> usize {
    let n = lemma3_n(phi);
    (4 * k).saturating_sub(1) * n
}

/// Lemma 3 coefficient bounds `‖β̃^[k]‖_∞` for k = 1..=K (exact bigint
/// evaluation of the stated recursion).
pub fn lemma3_coeff_bounds(n_obs: usize, p_vars: usize, iters: usize, phi: u32) -> Vec<BigUint> {
    let n = lemma3_n(phi) as u64;
    let n_big = n_obs as u64;
    let p_big = p_vars as u64;
    // C_1 = n(n+1)N
    let mut bounds = Vec::with_capacity(iters);
    let mut c = BigUint::from_u64(n * (n + 1)).mul_u64(n_big);
    bounds.push(c.clone());
    for k in 2..=iters {
        // C_k = (4n + (n+1)²)·N·P·C_{k-1} + (4k−3)·n·(n+1)·N
        let factor = 4 * n + (n + 1) * (n + 1);
        let add = BigUint::from_u64((4 * k as u64 - 3) * n * (n + 1)).mul_u64(n_big);
        c = c.mul_u64(factor).mul_u64(n_big).mul_u64(p_big).add(&add);
        bounds.push(c.clone());
    }
    bounds
}

/// Which descent algorithm a parameter plan is for.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Algo {
    Gd,
    GdVwt,
    Nag,
    Cd,
}

/// Exact message-growth tracker: mirrors the homomorphic message
/// arithmetic of each algorithm using the *actual* constants
/// (`ν`, `10^{kφ}`, binomial weights), giving tighter—but still
/// guaranteed—bounds than the generic Lemma 3 recursion. The test-suite
/// validates `exact simulation ≤ these bounds` on random problems.
pub struct MessageGrowth {
    /// ℓ∞ bound on the coefficients of β̃ (or the deepest live message).
    pub coeff_bound: BigUint,
    /// Degree bound of the message polynomial.
    pub deg_bound: usize,
    /// Largest ℓ1 of any plaintext constant multiplied in (noise model).
    pub max_const_l1: u64,
}

/// ℓ1 of the signed-binary encoding of `v` = its popcount.
fn popcount_big(v: &BigUint) -> u64 {
    v.limbs().iter().map(|l| l.count_ones() as u64).sum()
}

/// Track GD (eq. 10) message growth for `iters` iterations.
/// `nu` is the integer inverse step size δ = 1/ν.
pub fn track_gd_growth(
    n_obs: usize,
    p_vars: usize,
    iters: usize,
    phi: u32,
    nu: u64,
) -> MessageGrowth {
    let n = lemma3_n(phi); // data encodings have ≤ n+1 terms
    let data_l1 = (n + 1) as u64;
    let data_deg = n;
    // c1 = 10^{2φ}·ν (per-iteration carry constant)
    let c1 = BigUint::pow10(2 * phi).mul_u64(nu);
    let c1_l1 = popcount_big(&c1);
    let c1_deg = c1.bit_len().saturating_sub(1);
    let mut coeff = BigUint::zero(); // ‖β̃^[0]‖ = 0
    let mut deg = 0usize;
    let mut max_l1 = c1_l1;
    for k in 1..=iters {
        // c_k = 10^{(2k−1)φ}·ν^{k−1}
        let ck = BigUint::pow10((2 * k as u32 - 1) * phi).mul(&BigUint::from_u64(nu).pow(k as u32 - 1));
        max_l1 = max_l1.max(popcount_big(&ck));
        // r = c_k·ỹ − Σ_j X̃β̃ : ‖r‖ ≤ ℓ1(ỹ)·1 ... c_k has ±1 coeffs? No:
        // c_k is the plaintext constant (0/1 coeffs), ỹ has ≤ n+1 ±1 terms:
        // ‖c_k·ỹ‖∞ ≤ ℓ1(ỹ) = n+1. ‖Σ X̃β̃‖∞ ≤ P·(n+1)·coeff.
        let r_bound = BigUint::from_u64(data_l1)
            .add(&coeff.mul_u64(p_vars as u64).mul_u64(data_l1));
        let r_deg = (ck.bit_len().saturating_sub(1) + data_deg).max(data_deg + deg);
        // g = X̃ᵀ r : ‖g‖ ≤ N·(n+1)·‖r‖ ; deg + n
        let g_bound = r_bound.mul_u64(n_obs as u64).mul_u64(data_l1);
        let g_deg = r_deg + data_deg;
        // β̃ = c1·β̃ + g
        coeff = coeff.mul_u64(c1_l1).add(&g_bound);
        deg = (deg + c1_deg).max(g_deg);
    }
    MessageGrowth { coeff_bound: coeff, deg_bound: deg, max_const_l1: max_l1 }
}

/// Binomial coefficient C(n, k) in bigint.
pub fn binomial(n: usize, k: usize) -> BigUint {
    if k > n {
        return BigUint::zero();
    }
    let k = k.min(n - k);
    let mut num = BigUint::one();
    for i in 0..k {
        num = num.mul_u64((n - i) as u64);
    }
    let mut den = BigUint::one();
    for i in 1..=k {
        den = den.mul_u64(i as u64);
    }
    num.div_rem(&den).0
}

/// Track GD+VWT growth: the VWT estimate (eq. 18) is a binomially
/// weighted sum of scale-unified iterates.
pub fn track_vwt_growth(
    n_obs: usize,
    p_vars: usize,
    iters: usize,
    phi: u32,
    nu: u64,
) -> MessageGrowth {
    // Growth of each β̃^[k] via the GD recursion, then the weighted sum
    // Σ_k C(K−k*, k−k*)·10^{2(K−k)φ}·ν^{K−k} · β̃^[k].
    let kstar = iters / 3 + 1;
    let mut per_iter = Vec::with_capacity(iters);
    for k in 1..=iters {
        per_iter.push(track_gd_growth(n_obs, p_vars, k, phi, nu));
    }
    let mut coeff = BigUint::zero();
    let mut deg = 0usize;
    let mut max_l1 = per_iter.last().map(|g| g.max_const_l1).unwrap_or(1);
    for k in kstar..=iters {
        let w = binomial(iters - kstar, k - kstar)
            .mul(&BigUint::pow10(2 * (iters - k) as u32 * phi))
            .mul(&BigUint::from_u64(nu).pow((iters - k) as u32));
        max_l1 = max_l1.max(popcount_big(&w));
        let g = &per_iter[k - 1];
        coeff = coeff.add(&g.coeff_bound.mul_u64(popcount_big(&w).max(1)));
        deg = deg.max(g.deg_bound + w.bit_len().saturating_sub(1));
    }
    MessageGrowth { coeff_bound: coeff, deg_bound: deg, max_const_l1: max_l1 }
}

/// Track NAG (eqs. 20a/20b) message growth. `eta_abs_q` are the
/// quantised |η̃_k| = |⌊10^φ·η_k⌉| momentum constants.
pub fn track_nag_growth(
    n_obs: usize,
    p_vars: usize,
    iters: usize,
    phi: u32,
    nu: u64,
    eta_abs_q: &[u64],
) -> MessageGrowth {
    let n = lemma3_n(phi);
    let data_l1 = (n + 1) as u64;
    let data_deg = n;
    let c_a = BigUint::pow10(2 * phi).mul_u64(nu); // 10^φ·ν̃
    let ca_l1 = popcount_big(&c_a);
    let ca_deg = c_a.bit_len().saturating_sub(1);
    let mut beta_coeff = BigUint::zero();
    let mut beta_deg = 0usize;
    let mut s_prev_coeff = BigUint::zero();
    let mut s_prev_deg = 0usize;
    let mut max_l1 = ca_l1;
    for k in 1..=iters {
        let ck = BigUint::pow10((2 * k as u32 - 1) * phi)
            .mul(&BigUint::from_u64(nu).pow(k as u32 - 1));
        max_l1 = max_l1.max(popcount_big(&ck));
        // s̃ = c_a·β̃ + X̃ᵀ(c_k ỹ − X̃ β̃)
        let r_bound = BigUint::from_u64(data_l1)
            .add(&beta_coeff.mul_u64(p_vars as u64).mul_u64(data_l1));
        let r_deg = (ck.bit_len().saturating_sub(1) + data_deg).max(data_deg + beta_deg);
        let s_coeff = beta_coeff
            .mul_u64(ca_l1)
            .add(&r_bound.mul_u64(n_obs as u64).mul_u64(data_l1));
        let s_deg = (beta_deg + ca_deg).max(r_deg + data_deg);
        // β̃ = (10^φ + η̃_k)·s̃^[k] − 10^{2φ}ν̃η̃_k·s̃^{[k−1]}
        let eta = eta_abs_q.get(k - 1).copied().unwrap_or(0);
        let w1 = BigUint::pow10(phi).add_u64(eta); // upper bound on |10^φ + η̃|
        let w2 = BigUint::pow10(3 * phi).mul_u64(nu).mul_u64(eta.max(1));
        max_l1 = max_l1.max(popcount_big(&w1)).max(popcount_big(&w2));
        beta_coeff = s_coeff
            .mul_u64(popcount_big(&w1).max(1))
            .add(&s_prev_coeff.mul_u64(popcount_big(&w2).max(1)));
        beta_deg = (s_deg + w1.bit_len()).max(s_prev_deg + w2.bit_len());
        s_prev_coeff = s_coeff;
        s_prev_deg = s_deg;
    }
    MessageGrowth { coeff_bound: beta_coeff, deg_bound: beta_deg, max_const_l1: max_l1 }
}

/// A request for parameter planning.
#[derive(Clone, Debug)]
pub struct PlanRequest {
    pub algo: Algo,
    pub n_obs: usize,
    pub p_vars: usize,
    pub iters: usize,
    pub phi: u32,
    pub nu: u64,
    /// Quantised |η̃_k| for NAG (empty otherwise).
    pub eta_abs_q: Vec<u64>,
    /// Extra multiplicative depth to reserve (e.g. +1 for prediction).
    pub extra_depth: u32,
    pub profile: SecurityProfile,
}

impl PlanRequest {
    pub fn gd(n_obs: usize, p_vars: usize, iters: usize, phi: u32, nu: u64) -> Self {
        PlanRequest {
            algo: Algo::Gd,
            n_obs,
            p_vars,
            iters,
            phi,
            nu,
            eta_abs_q: Vec::new(),
            extra_depth: 0,
            profile: SecurityProfile::Toy,
        }
    }

    pub fn with_profile(mut self, profile: SecurityProfile) -> Self {
        self.profile = profile;
        self
    }

    pub fn with_algo(mut self, algo: Algo) -> Self {
        self.algo = algo;
        self
    }

    pub fn with_extra_depth(mut self, extra: u32) -> Self {
        self.extra_depth = extra;
        self
    }

    /// Ciphertext-multiplication depth this algorithm needs (noise
    /// levels; distinct from the paper's Table-1 MMD accounting, which
    /// [`crate::els::mmd`] reproduces).
    pub fn ct_depth(&self) -> u32 {
        let base = match self.algo {
            Algo::Gd | Algo::GdVwt | Algo::Nag => 2 * self.iters as u32,
            Algo::Cd => 2 * self.iters as u32 * self.p_vars as u32,
        };
        base + self.extra_depth
    }

    pub fn growth(&self) -> MessageGrowth {
        match self.algo {
            Algo::Gd => track_gd_growth(self.n_obs, self.p_vars, self.iters, self.phi, self.nu),
            Algo::GdVwt => {
                track_vwt_growth(self.n_obs, self.p_vars, self.iters, self.phi, self.nu)
            }
            Algo::Nag => track_nag_growth(
                self.n_obs,
                self.p_vars,
                self.iters,
                self.phi,
                self.nu,
                &self.eta_abs_q,
            ),
            // CD sweeps: message growth per coordinate update mirrors one
            // GD iteration over a single column; bound by GD with
            // iters·p_vars steps (conservative).
            Algo::Cd => track_gd_growth(
                self.n_obs,
                self.p_vars,
                self.iters * self.p_vars,
                self.phi,
                self.nu,
            ),
        }
    }
}

/// Plan a parameter set guaranteeing correct decryption for the request
/// (paper §4.5: Lemma 3 bounds + LP11 security + noise-depth budget).
pub fn plan(req: &PlanRequest) -> Result<FvParams> {
    let growth = req.growth();
    // t must hold the final message coefficients symmetrically.
    let t_bits = growth.coeff_bound.mul_u64(2).add_u64(1).bit_len().max(8);
    let depth = req.ct_depth();
    let sigma_bits = 2; // σ ≈ 3.2
    let const_bits = 64 - (growth.max_const_l1.max(1) - 1).leading_zeros() as usize;

    // Fixpoint over d: per-level cost and security both depend on d.
    let mut d = 256usize;
    loop {
        let log_d = d.trailing_zeros() as usize;
        // Fresh noise ≈ 2·d·B·t → bits ≈ t_bits + log d + σ + 7.
        let fresh_bits = t_bits + log_d + sigma_bits + 7;
        // Each ct-mul multiplies noise by ≈ 2·d·t·ℓ1(m); plain-const
        // muls add ≈ const_bits per iteration on top.
        let per_level = per_level_noise_bits(t_bits, d, const_bits);
        // The flat reserve absorbs the additive per-mul terms: RNS
        // relinearisation (≈ L·d·2^30·B, dwarfed by the first level's
        // multiplicative growth) and the full-RNS pipeline's ±1
        // approximate-conversion roundings per component (≾ d² on the
        // phase, likewise below the per-level terms).
        let q_bits = fresh_bits + depth as usize * per_level + 40;
        let q_count = q_bits.div_ceil(29);

        // Ring degree floor: message degree bound + security + NTT room.
        let deg_need = (growth.deg_bound + 8).next_power_of_two().max(256);
        let sec_need = match req.profile {
            SecurityProfile::Toy => 256,
            SecurityProfile::Paper128 => {
                // λ ≥ 128 ⟺ d ≥ (128+110)·log2(q/σ)/7.2
                let need = (238.0 * (q_bits as f64 + 2.0) / 7.2).ceil() as usize;
                need.next_power_of_two()
            }
        };
        let d_need = deg_need.max(sec_need);
        if d_need <= d {
            let mut params = FvParams {
                d,
                q_count,
                ext_count: 0,
                t: BigUint::one().shl_bits(t_bits),
                cbd_k: DEFAULT_CBD_K,
                mul_backend: MulBackend::from_env(),
                encoding: Encoding::Scalar,
                profile: req.profile,
            };
            params.ext_count = params.required_ext_count();
            if params.d > 1 << 16 {
                bail!(
                    "planned ring degree d = {} exceeds 2^16; reduce K or P (paper §4.1.1: \
                     this is where CD becomes impractical)",
                    params.d
                );
            }
            return Ok(params);
        }
        d = d_need;
        if d > 1 << 20 {
            bail!("parameter search diverged (d > 2^20) for request {req:?}");
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn lemma3_n_value() {
        // φ = 2 → n = ⌈3·log2 10⌉ = 10, as in the paper's examples.
        assert_eq!(lemma3_n(2), 10);
        assert_eq!(lemma3_n(0), 4);
    }

    #[test]
    fn lemma3_deg_closed_form() {
        // deg ≤ 3n at k=1, grows by 4n per iteration.
        let n = lemma3_n(2);
        assert_eq!(lemma3_deg_bound(1, 2), 3 * n);
        assert_eq!(lemma3_deg_bound(2, 2), 7 * n);
        assert_eq!(lemma3_deg_bound(5, 2), 19 * n);
    }

    #[test]
    fn lemma3_coeff_recursion() {
        let n = lemma3_n(2) as u64;
        let bounds = lemma3_coeff_bounds(100, 5, 3, 2);
        assert_eq!(bounds[0].to_u64(), Some(n * (n + 1) * 100));
        // C_2 = (4n+(n+1)^2)·N·P·C_1 + 5n(n+1)N
        let expect = (4 * n + (n + 1) * (n + 1)) as u128 * 500 * (n * (n + 1) * 100) as u128
            + (5 * n * (n + 1) * 100) as u128;
        assert_eq!(bounds[1].to_u128(), Some(expect));
        assert!(bounds[2].cmp_big(&bounds[1]) == std::cmp::Ordering::Greater);
    }

    #[test]
    fn binomial_values() {
        assert_eq!(binomial(5, 2).to_u64(), Some(10));
        assert_eq!(binomial(10, 0).to_u64(), Some(1));
        assert_eq!(binomial(10, 10).to_u64(), Some(1));
        assert_eq!(binomial(3, 5).to_u64(), Some(0));
        assert_eq!(binomial(20, 10).to_u64(), Some(184_756));
    }

    #[test]
    fn growth_monotone_in_iters() {
        let g1 = track_gd_growth(28, 2, 1, 2, 100);
        let g3 = track_gd_growth(28, 2, 3, 2, 100);
        assert!(g3.coeff_bound.cmp_big(&g1.coeff_bound) == std::cmp::Ordering::Greater);
        assert!(g3.deg_bound > g1.deg_bound);
    }

    #[test]
    fn tighter_than_lemma3() {
        // The exact-constant recursion should not exceed the generic
        // Lemma 3 bound (same structure, tighter constants).
        let g = track_gd_growth(100, 5, 4, 2, 128);
        let lemma = lemma3_coeff_bounds(100, 5, 4, 2);
        assert!(
            g.coeff_bound.cmp_big(&lemma[3]) != std::cmp::Ordering::Greater,
            "exact {} vs lemma3 {}",
            g.coeff_bound,
            lemma[3]
        );
    }

    #[test]
    fn plan_produces_consistent_params() {
        let req = PlanRequest::gd(28, 2, 2, 2, 64);
        let p = plan(&req).unwrap();
        assert!(p.d >= 256 && p.d.is_power_of_two());
        // q must be comfortably larger than t.
        assert!(p.q_bits() > p.t.bit_len() + 40);
        // Oracle extension basis large enough for the tensor product.
        let ext_bits: usize = p
            .ext_primes()
            .iter()
            .map(|&pr| 64 - pr.leading_zeros() as usize - 1)
            .sum();
        assert!(ext_bits >= p.q_bits() + p.d.trailing_zeros() as usize);
        // Full-RNS extension basis also covers the scale-and-round
        // output (|r| ≤ t·d·q/4), and extends the oracle basis.
        let rns_ext = p.rns_ext_primes();
        let rns_ext_bits: usize =
            rns_ext.iter().map(|&pr| 64 - pr.leading_zeros() as usize - 1).sum();
        assert!(rns_ext_bits >= p.q_bits() + p.t.bit_len() + p.d.trailing_zeros() as usize);
        assert_eq!(&rns_ext[..p.ext_count], &p.ext_primes()[..], "E ⊆ B prefix");
        // Ring degree covers the message degree bound.
        assert!(p.d > track_gd_growth(28, 2, 2, 2, 64).deg_bound);
    }

    #[test]
    fn paper128_profile_is_bigger() {
        let toy = plan(&PlanRequest::gd(28, 2, 2, 2, 64)).unwrap();
        let sec = plan(
            &PlanRequest::gd(28, 2, 2, 2, 64).with_profile(SecurityProfile::Paper128),
        )
        .unwrap();
        assert!(sec.d >= toy.d);
        assert!(sec.security_bits() >= 128.0, "λ = {}", sec.security_bits());
    }

    #[test]
    fn cd_depth_scales_with_p() {
        let gd = PlanRequest::gd(100, 5, 3, 2, 64);
        let cd = gd.clone().with_algo(Algo::Cd);
        assert_eq!(gd.ct_depth(), 6);
        assert_eq!(cd.ct_depth(), 30); // 2KP — the paper's headline contrast
    }

    #[test]
    fn primes_are_distinct_between_q_ext_and_msk() {
        let p = FvParams::custom(512, 3, 40);
        let q = p.q_primes();
        let e = p.rns_ext_primes();
        assert!(p.ext_count > 0);
        assert!(e.len() >= p.ext_count);
        for x in &e {
            assert!(!q.contains(x));
        }
        let msk = p.msk_prime();
        assert!(!q.contains(&msk) && !e.contains(&msk));
        assert_eq!(msk % (2 * p.d as u64), 1, "m_sk must be NTT-friendly");
    }

    #[test]
    fn relin_digit_count_is_limb_count() {
        let p = FvParams::custom(256, 4, 20);
        assert_eq!(p.relin_ndigits(), 4);
    }

    #[test]
    fn custom_packed_selects_crt_friendly_prime_t() {
        let p = FvParams::custom_packed(256, 4, 26).unwrap();
        let t = p.t.to_u64().unwrap();
        assert_eq!(p.encoding, Encoding::Packed);
        assert_eq!(t % (2 * 256), 1, "t ≡ 1 mod 2d");
        assert!(is_prime(t));
        assert!(t < 1 << 26);
        assert_eq!(p.slot_count(), 256);
        assert_eq!(FvParams::custom(256, 4, 26).slot_count(), 1);
        p.validate_encoding().unwrap();
    }

    #[test]
    fn packed_validation_rejects_bad_t() {
        // Power-of-two t (the scalar default) is ≢ 1 mod 2d.
        let e = FvParams::custom(256, 4, 20).with_encoding(Encoding::Packed).unwrap_err();
        assert!(e.to_string().contains("t ≡ 1 (mod 2d)"), "got: {e}");
        // Composite t ≡ 1 mod 2d: 2d·k + 1 with a forced factor.
        let mut p = FvParams::custom(256, 4, 20);
        let composite = (2 * 256 * 9 + 1) as u64 * (2 * 256 * 25 + 1) as u64;
        assert_eq!(composite % 512, 1);
        assert!(!is_prime(composite));
        p.t = BigUint::from_u64(composite);
        let e = p.with_encoding(Encoding::Packed).unwrap_err();
        assert!(e.to_string().contains("prime plaintext modulus"), "got: {e}");
        // Oversized t cannot index the slot NTT.
        let mut p = FvParams::custom(256, 4, 20);
        p.t = BigUint::one().shl_bits(80);
        let e = p.with_encoding(Encoding::Packed).unwrap_err();
        assert!(e.to_string().contains("below 2^64"), "got: {e}");
        // Scalar sets never fail validation.
        FvParams::custom(256, 4, 20).validate_encoding().unwrap();
    }

    #[test]
    fn encoding_default_is_scalar() {
        // `Encoding::default()` is the compiled-in default; from_env
        // may differ when the CI packed leg sets ELS_ENCODING.
        assert_eq!(Encoding::default(), Encoding::Scalar);
        assert_eq!(FvParams::custom(256, 4, 20).encoding, Encoding::Scalar);
        assert_eq!(plan(&PlanRequest::gd(8, 2, 2, 1, 4)).unwrap().encoding, Encoding::Scalar);
    }

    #[test]
    fn backend_default_is_full_rns() {
        // `MulBackend::default()` is the compiled-in default; from_env
        // may differ when the CI oracle gate sets ELS_MUL_BACKEND.
        assert_eq!(MulBackend::default(), MulBackend::FullRns);
    }
}
