//! The FV evaluation context: precomputed rings, moduli and conversions.

use std::sync::Arc;

use crate::math::bigint::{BigInt, BigUint};
use crate::math::poly::{RingContext, RnsPoly};

use super::encoding::{Encoder, ScalarEncoder, SlotEncoder};
use super::params::{Encoding, FvParams, MulBackend};
use super::plaintext::Plaintext;
use super::rns_mul::RnsMulPrecomp;

/// Precomputation shared by every key, ciphertext and operation under
/// one parameter set.
pub struct FvContext {
    pub params: FvParams,
    /// Ring over the ciphertext modulus basis Q.
    pub ring_q: Arc<RingContext>,
    /// Ring over the joint tensor basis Q ∪ E (the bigint-oracle ⊗).
    pub ring_big: Arc<RingContext>,
    /// Ring over the extension basis B ∪ {m_sk} (`m_sk` last) — the
    /// full-RNS ⊗ working basis.
    pub ring_ext: Arc<RingContext>,
    /// q = Π Q-primes.
    pub q: BigUint,
    /// Plaintext modulus t.
    pub t: BigUint,
    /// Δ = ⌊q/t⌋.
    pub delta: BigUint,
    /// Δ mod each Q-prime (fresh-encryption fast path).
    pub delta_rns: Vec<u64>,
    /// Relinearisation digit count (one per Q limb — the RNS gadget).
    pub relin_ndigits: usize,
    /// Base-conversion tables for the full-RNS multiply.
    pub rns: RnsMulPrecomp,
    /// Largest number of tensor products the full-RNS `dot_pairs`
    /// pipeline may accumulate before one shared `⌊t·v/q⌉`: bounded by
    /// the Shenoy–Kumaresan range (`|r| ≤ k·t·d·q/4` must stay under
    /// `B/8`, keeping the single-multiply slack margin). Computed from
    /// the *actual* extension-basis product, so the 29-vs-30-bit prime
    /// granularity slack is harvested rather than assumed away.
    pub(crate) fuse_chunk_rns: usize,
    /// The same bound for the exact-bigint oracle: the summed tensor
    /// (`|Σv| ≤ k·d·q²/4`) must stay inside the joint Q∪E basis range
    /// with the same 2 bits of slack.
    pub(crate) fuse_chunk_big: usize,
    /// `log2 t` when t is a power of two (always true for planned
    /// parameter sets): turns the hot `t·v` big-multiply of the BFV
    /// scale-and-round into a shift.
    t_shift: Option<usize>,
    /// The scalar (signed-binary) encoder — always available.
    scalar_encoder: ScalarEncoder,
    /// The slot encoder, built once per context for packed parameter
    /// sets (`t` prime ≡ 1 mod 2d).
    slot_encoder: Option<SlotEncoder>,
}

impl FvContext {
    pub fn new(params: FvParams) -> Arc<Self> {
        params.validate_encoding().expect("FvParams encoding invalid for this modulus");
        let q_primes = params.q_primes();
        let mut big_primes = q_primes.clone();
        big_primes.extend(params.ext_primes());
        let mut ext_all = params.rns_ext_primes();
        ext_all.push(params.msk_prime());
        let ring_q = RingContext::new(params.d, q_primes.clone());
        let ring_big = RingContext::new(params.d, big_primes);
        let ring_ext = RingContext::new(params.d, ext_all);
        let q = ring_q.basis.modulus.clone();
        let t = params.t.clone();
        let delta = q.div_rem(&t).0;
        let delta_rns = q_primes.iter().map(|&p| delta.mod_u64(p)).collect();
        let relin_ndigits = params.relin_ndigits();
        let rns = RnsMulPrecomp::new(&ring_q, &ring_ext, &t);
        let t_shift = if t.is_power_of_two() { Some(t.bit_len() - 1) } else { None };
        let fuse_chunk_rns = {
            // B = Π extension primes without the redundant m_sk plane.
            let ext = &ring_ext.basis.primes;
            let mut b = BigUint::one();
            for &p in &ext[..ext.len() - 1] {
                b = b.mul_u64(p);
            }
            // cap = B/8 (the symmetric B/2 range plus the same 2 slack
            // bits the single-multiply sizing reserves); each fused
            // term contributes at most t·d·q/4 to |r| = |(t·Σv − z)/q|.
            Self::fuse_terms(&b, &t.mul(&q).mul_u64(params.d as u64))
        };
        let fuse_chunk_big = {
            // Joint basis Q∪E must hold |Σv| ≤ k·d·q²/4 with 2 bits of
            // slack: cap = (q·E)/8, per-term d·q²/4.
            Self::fuse_terms(&ring_big.basis.modulus, &q.mul(&q).mul_u64(params.d as u64))
        };
        let scalar_encoder = ScalarEncoder { d: params.d };
        let slot_encoder = match params.encoding {
            Encoding::Packed => {
                let t_u64 = t.to_u64().expect("validate_encoding guarantees t < 2^62");
                Some(SlotEncoder::new(t_u64, params.d))
            }
            Encoding::Scalar => None,
        };
        Arc::new(FvContext {
            params,
            ring_q,
            ring_big,
            ring_ext,
            q,
            t,
            delta,
            delta_rns,
            relin_ndigits,
            rns,
            fuse_chunk_rns,
            fuse_chunk_big,
            t_shift,
            scalar_encoder,
            slot_encoder,
        })
    }

    /// The active message encoder: slot packing when
    /// `params.encoding == Packed`, signed-binary scalars otherwise.
    /// Call sites stay encoding-agnostic by going through this.
    pub fn encoder(&self) -> &dyn Encoder {
        match &self.slot_encoder {
            Some(s) => s,
            None => &self.scalar_encoder,
        }
    }

    /// The slot encoder, when this is a packed context (direct access
    /// for slot-level tests and diagnostics).
    pub fn slot_encoder(&self) -> Option<&SlotEncoder> {
        self.slot_encoder.as_ref()
    }

    /// `⌊(cap/8) / (per4/4)⌋` clamped to `[1, 2^31]`: how many fused
    /// terms fit a basis of modulus `cap` when each term contributes at
    /// most `per4/4` (callers pass the un-divided `4×` products so the
    /// shifts stay exact). The ≥ 1 floor is guaranteed by the existing
    /// single-multiply basis sizing; the 2^31 ceiling keeps the count
    /// far under the `u128` accumulator guard
    /// [`crate::math::poly::MAX_NTT_ACC_TERMS`].
    fn fuse_terms(cap: &BigUint, per4: &BigUint) -> usize {
        let cap = cap.shr_bits(3);
        let per = per4.shr_bits(2).add_u64(1);
        let k = cap.div_rem(&per).0;
        match k.to_u64() {
            Some(v) => v.clamp(1, 1 << 31) as usize,
            None => 1 << 31,
        }
    }

    /// How many tensor products the active multiply backend may fuse
    /// into one scale-and-round (see the field docs). `dot_pairs`
    /// groups longer than this are accumulated in chunks of this size
    /// — still a single relinearisation per group.
    pub fn fuse_chunk(&self) -> usize {
        match self.params.mul_backend {
            MulBackend::FullRns => self.fuse_chunk_rns,
            MulBackend::ExactBigint => self.fuse_chunk_big,
        }
    }

    /// A context identical to this one except for the multiply backend
    /// (keys remain compatible, since they live entirely in the Q
    /// basis). This is how the parity tests and benches run both
    /// pipelines against one key set. When the backend already
    /// matches, the same context is returned — no ring/table rebuild.
    pub fn with_backend(self: Arc<Self>, backend: MulBackend) -> Arc<Self> {
        if backend == self.params.mul_backend {
            return self;
        }
        let mut params = self.params.clone();
        params.mul_backend = backend;
        FvContext::new(params)
    }

    /// `t·v` via shift when t = 2^k (hot path of ⊗ and decryption).
    #[inline]
    fn t_times(&self, v: &crate::math::bigint::BigUint) -> crate::math::bigint::BigUint {
        match self.t_shift {
            Some(k) => v.shl_bits(k),
            None => v.mul(&self.t),
        }
    }

    pub fn d(&self) -> usize {
        self.params.d
    }

    /// Reduce a plaintext polynomial into Q-basis residues.
    pub fn pt_to_rns(&self, pt: &Plaintext) -> RnsPoly {
        assert!(pt.coeffs.len() <= self.d(), "plaintext longer than ring degree");
        let mut out = self.ring_q.zero();
        for (l, &p) in self.ring_q.basis.primes.iter().enumerate() {
            for (i, c) in pt.coeffs.iter().enumerate() {
                out.planes[l][i] = c.mod_u64(p);
            }
        }
        out
    }

    /// `Δ·m mod q` in residue form (valid because `p_i | q` makes
    /// per-plane scaling exact).
    pub fn delta_times_pt(&self, pt: &Plaintext) -> RnsPoly {
        let m = self.pt_to_rns(pt);
        self.ring_q.mul_scalar_rns(&m, &self.delta_rns)
    }

    /// Cache a plaintext operand in NTT form (one forward transform,
    /// ever). The result is `Arc`-shared, so cloning it per call or
    /// per thread is free; see
    /// [`mul_plain_prepared`](Self::mul_plain_prepared).
    pub fn prepare_plaintext(&self, pt: &Plaintext) -> crate::fhe::plaintext::PlaintextNtt {
        let mut m = self.pt_to_rns(pt);
        self.ring_q.ensure_ntt(&mut m);
        crate::fhe::plaintext::PlaintextNtt { m_ntt: std::sync::Arc::new(m) }
    }

    /// Lift every coefficient of a coefficient-form polynomial to its
    /// symmetric big-integer representative.
    pub fn lift_signed_poly(ring: &RingContext, poly: &RnsPoly) -> Vec<BigInt> {
        assert_eq!(poly.rep, crate::math::poly::Rep::Coeff);
        let mut residues = vec![0u64; ring.nlimbs()];
        (0..ring.d)
            .map(|i| {
                for l in 0..ring.nlimbs() {
                    residues[l] = poly.planes[l][i];
                }
                ring.basis.lift_signed(&residues)
            })
            .collect()
    }

    /// Move a polynomial from the Q basis into the joint Q∪E basis
    /// (exact CRT lift per coefficient).
    pub fn q_to_big(&self, poly: &RnsPoly) -> RnsPoly {
        let coeffs = Self::lift_signed_poly(&self.ring_q, poly);
        let mut out = self.ring_big.zero();
        for (i, v) in coeffs.iter().enumerate() {
            for (l, &p) in self.ring_big.basis.primes.iter().enumerate() {
                out.planes[l][i] = v.mod_u64(p);
            }
        }
        out
    }

    /// BFV scale-and-round: given a tensor-product polynomial over the
    /// joint basis, compute `⌊t·v/q⌉ mod q` back in the Q basis.
    pub fn scale_round_to_q(&self, poly: &RnsPoly) -> RnsPoly {
        let coeffs = Self::lift_signed_poly(&self.ring_big, poly);
        let mut out = self.ring_q.zero();
        for (i, v) in coeffs.iter().enumerate() {
            let scaled = BigInt { neg: v.neg, mag: self.t_times(&v.mag) }.div_round(&self.q);
            for (l, &p) in self.ring_q.basis.primes.iter().enumerate() {
                out.planes[l][i] = scaled.mod_u64(p);
            }
        }
        out
    }

    /// Round `t·v/q` for a Q-basis polynomial and reduce symmetric mod t
    /// — the decryption post-processing.
    pub fn decrypt_scale(&self, poly: &RnsPoly) -> Plaintext {
        let coeffs = Self::lift_signed_poly(&self.ring_q, poly);
        let mut pt = Plaintext {
            coeffs: coeffs
                .into_iter()
                .map(|v| {
                    BigInt { neg: v.neg, mag: self.t_times(&v.mag) }.div_round(&self.q)
                })
                .collect(),
        };
        pt.reduce_sym(&self.t);
        pt
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fhe::params::FvParams;
    use crate::fhe::plaintext::Plaintext;

    fn ctx() -> Arc<FvContext> {
        FvContext::new(FvParams::custom(256, 3, 24))
    }

    #[test]
    fn delta_definition() {
        let c = ctx();
        // Δ·t ≤ q < (Δ+1)·t
        let dt = c.delta.mul(&c.t);
        assert!(dt.cmp_big(&c.q) != std::cmp::Ordering::Greater);
        assert!(c.delta.add_u64(1).mul(&c.t).cmp_big(&c.q) == std::cmp::Ordering::Greater);
    }

    #[test]
    fn pt_to_rns_and_back() {
        let c = ctx();
        let pt = Plaintext::from_signed(c.d(), &[1, -1, 0, 5, -7]);
        let poly = c.pt_to_rns(&pt);
        let lifted = FvContext::lift_signed_poly(&c.ring_q, &poly);
        assert_eq!(lifted[0].to_i128(), Some(1));
        assert_eq!(lifted[1].to_i128(), Some(-1));
        assert_eq!(lifted[3].to_i128(), Some(5));
        assert_eq!(lifted[4].to_i128(), Some(-7));
    }

    #[test]
    fn q_to_big_preserves_values() {
        let c = ctx();
        let pt = Plaintext::from_signed(c.d(), &[3, -4, 123456]);
        let poly = c.pt_to_rns(&pt);
        let big = c.q_to_big(&poly);
        let lifted = FvContext::lift_signed_poly(&c.ring_big, &big);
        assert_eq!(lifted[0].to_i128(), Some(3));
        assert_eq!(lifted[1].to_i128(), Some(-4));
        assert_eq!(lifted[2].to_i128(), Some(123456));
    }

    #[test]
    fn fuse_chunk_has_headroom_on_both_backends() {
        // The single-multiply basis sizing guarantees ≥ 2 fused terms
        // (one extra bit of slack beyond one tensor); the realised
        // 29-vs-30-bit prime granularity gives far more on real sets.
        let c = ctx();
        assert!(c.fuse_chunk_rns >= 2, "rns chunk {}", c.fuse_chunk_rns);
        assert!(c.fuse_chunk_big >= 2, "bigint chunk {}", c.fuse_chunk_big);
        // fuse_chunk() follows the active backend (which CI may pin
        // via ELS_MUL_BACKEND).
        let expect = match c.params.mul_backend {
            crate::fhe::params::MulBackend::FullRns => c.fuse_chunk_rns,
            crate::fhe::params::MulBackend::ExactBigint => c.fuse_chunk_big,
        };
        assert_eq!(c.fuse_chunk(), expect);
        // And the u128 accumulator guard dwarfs the clamp ceiling.
        assert!((c.fuse_chunk_rns as u64) < crate::math::poly::MAX_NTT_ACC_TERMS);
    }

    #[test]
    fn decrypt_scale_recovers_delta_multiples() {
        // v = Δ·m (noise-free) must decode to exactly m.
        let c = ctx();
        let pt = Plaintext::from_signed(c.d(), &[1, 0, -1, 9, -13]);
        let v = c.delta_times_pt(&pt);
        let out = c.decrypt_scale(&v);
        for i in 0..8 {
            assert_eq!(out.coeffs[i].to_i128(), pt.coeffs[i].to_i128(), "coeff {i}");
        }
    }
}
