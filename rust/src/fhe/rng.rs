//! ChaCha20-based cryptographic pseudo-random generator.
//!
//! The RLWE samplers (uniform-mod-q, ternary secrets, centered-binomial
//! errors) all draw from this stream. No `rand` crate is vendored, so the
//! ChaCha20 block function (djb's original 64-bit-counter variant) is
//! implemented here from the specification; test vectors from RFC 7539
//! §2.3.2 (adapted to the original nonce layout) pin the permutation.

const CHACHA_CONSTANTS: [u32; 4] = [0x61707865, 0x3320646e, 0x79622d32, 0x6b206574];

#[inline(always)]
fn quarter_round(s: &mut [u32; 16], a: usize, b: usize, c: usize, d: usize) {
    s[a] = s[a].wrapping_add(s[b]);
    s[d] = (s[d] ^ s[a]).rotate_left(16);
    s[c] = s[c].wrapping_add(s[d]);
    s[b] = (s[b] ^ s[c]).rotate_left(12);
    s[a] = s[a].wrapping_add(s[b]);
    s[d] = (s[d] ^ s[a]).rotate_left(8);
    s[c] = s[c].wrapping_add(s[d]);
    s[b] = (s[b] ^ s[c]).rotate_left(7);
}

/// ChaCha20 keystream generator exposing a `u64` / `f64` RNG interface.
#[derive(Clone)]
pub struct ChaChaRng {
    /// Input block: constants ‖ key ‖ counter ‖ nonce.
    state: [u32; 16],
    /// Buffered keystream block (16 words).
    buf: [u32; 16],
    /// Next unread word index in `buf` (16 = exhausted).
    idx: usize,
}

impl ChaChaRng {
    /// Construct from a full 256-bit key and 64-bit nonce.
    pub fn from_key(key: [u32; 8], nonce: u64) -> Self {
        let mut state = [0u32; 16];
        state[..4].copy_from_slice(&CHACHA_CONSTANTS);
        state[4..12].copy_from_slice(&key);
        state[12] = 0; // counter low
        state[13] = 0; // counter high
        state[14] = nonce as u32;
        state[15] = (nonce >> 32) as u32;
        ChaChaRng { state, buf: [0; 16], idx: 16 }
    }

    /// Construct from a 64-bit seed, expanded to a key via SplitMix64
    /// (deterministic; used for tests, simulations and demo keys —
    /// production key material should use `from_key` with OS entropy).
    pub fn from_seed(seed: u64) -> Self {
        let mut sm = seed;
        let mut next = || {
            sm = sm.wrapping_add(0x9e3779b97f4a7c15);
            let mut z = sm;
            z = (z ^ (z >> 30)).wrapping_mul(0xbf58476d1ce4e5b9);
            z = (z ^ (z >> 27)).wrapping_mul(0x94d049bb133111eb);
            z ^ (z >> 31)
        };
        let mut key = [0u32; 8];
        for i in 0..4 {
            let v = next();
            key[2 * i] = v as u32;
            key[2 * i + 1] = (v >> 32) as u32;
        }
        Self::from_key(key, next())
    }

    /// Derive an independent child stream (distinct nonce).
    pub fn split(&mut self, stream: u64) -> Self {
        let mut key = [0u32; 8];
        for k in key.iter_mut() {
            *k = self.next_u32();
        }
        Self::from_key(key, stream)
    }

    fn refill(&mut self) {
        let mut w = self.state;
        for _ in 0..10 {
            // column rounds
            quarter_round(&mut w, 0, 4, 8, 12);
            quarter_round(&mut w, 1, 5, 9, 13);
            quarter_round(&mut w, 2, 6, 10, 14);
            quarter_round(&mut w, 3, 7, 11, 15);
            // diagonal rounds
            quarter_round(&mut w, 0, 5, 10, 15);
            quarter_round(&mut w, 1, 6, 11, 12);
            quarter_round(&mut w, 2, 7, 8, 13);
            quarter_round(&mut w, 3, 4, 9, 14);
        }
        for i in 0..16 {
            self.buf[i] = w[i].wrapping_add(self.state[i]);
        }
        // 64-bit block counter in words 12..13.
        let (lo, carry) = self.state[12].overflowing_add(1);
        self.state[12] = lo;
        if carry {
            self.state[13] = self.state[13].wrapping_add(1);
        }
        self.idx = 0;
    }

    #[inline]
    pub fn next_u32(&mut self) -> u32 {
        if self.idx >= 16 {
            self.refill();
        }
        let v = self.buf[self.idx];
        self.idx += 1;
        v
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let lo = self.next_u32() as u64;
        let hi = self.next_u32() as u64;
        lo | (hi << 32)
    }

    /// Uniform in `[0, bound)` by rejection sampling (unbiased).
    pub fn uniform_below(&mut self, bound: u64) -> u64 {
        assert!(bound > 0);
        if bound.is_power_of_two() {
            return self.next_u64() & (bound - 1);
        }
        let zone = (u64::MAX / bound) * bound;
        loop {
            let x = self.next_u64();
            if x < zone {
                return x % bound;
            }
        }
    }

    /// Uniform f64 in `[0, 1)` with 53-bit precision.
    #[inline]
    pub fn next_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Standard normal via Box–Muller (used for synthetic data only; the
    /// RLWE error sampler uses an exact centered-binomial instead).
    pub fn next_gaussian(&mut self) -> f64 {
        loop {
            let u1 = self.next_f64();
            if u1 <= f64::EPSILON {
                continue;
            }
            let u2 = self.next_f64();
            return (-2.0 * u1.ln()).sqrt() * (std::f64::consts::TAU * u2).cos();
        }
    }

    /// Fill a slice with uniform residues mod `p`.
    pub fn fill_uniform_mod(&mut self, out: &mut [u64], p: u64) {
        for x in out.iter_mut() {
            *x = self.uniform_below(p);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn rfc7539_block_function() {
        // RFC 7539 §2.3.2 test vector: key 00..1f, counter 1,
        // nonce 00 00 00 09 00 00 00 4a 00 00 00 00 mapped onto the
        // djb layout words 13..15 = (1? ...). The RFC uses the IETF
        // layout (32-bit counter + 96-bit nonce); reproduce it by
        // setting our words directly.
        let key: [u32; 8] = [
            0x03020100, 0x07060504, 0x0b0a0908, 0x0f0e0d0c, 0x13121110, 0x17161514,
            0x1b1a1918, 0x1f1e1d1c,
        ];
        let mut rng = ChaChaRng::from_key(key, 0);
        rng.state[12] = 1; // counter = 1
        rng.state[13] = 0x09000000; // nonce words per RFC layout
        rng.state[14] = 0x4a000000;
        rng.state[15] = 0x00000000;
        rng.refill();
        let expect: [u32; 16] = [
            0xe4e7f110, 0x15593bd1, 0x1fdd0f50, 0xc47120a3, 0xc7f4d1c7, 0x0368c033,
            0x9aaa2204, 0x4e6cd4c3, 0x466482d2, 0x09aa9f07, 0x05d7c214, 0xa2028bd9,
            0xd19c12b5, 0xb94e16de, 0xe883d0cb, 0x4e3c50a2,
        ];
        assert_eq!(rng.buf, expect, "ChaCha20 block mismatch vs RFC 7539");
    }

    #[test]
    fn deterministic_and_seed_sensitive() {
        let mut a = ChaChaRng::from_seed(42);
        let mut b = ChaChaRng::from_seed(42);
        let mut c = ChaChaRng::from_seed(43);
        let va: Vec<u64> = (0..8).map(|_| a.next_u64()).collect();
        let vb: Vec<u64> = (0..8).map(|_| b.next_u64()).collect();
        let vc: Vec<u64> = (0..8).map(|_| c.next_u64()).collect();
        assert_eq!(va, vb);
        assert_ne!(va, vc);
    }

    #[test]
    fn uniform_below_is_in_range_and_covers() {
        let mut rng = ChaChaRng::from_seed(1);
        let bound = 97u64;
        let mut seen = vec![false; bound as usize];
        for _ in 0..20_000 {
            let v = rng.uniform_below(bound);
            assert!(v < bound);
            seen[v as usize] = true;
        }
        assert!(seen.iter().all(|&s| s), "all residues hit");
    }

    #[test]
    fn f64_unit_interval() {
        let mut rng = ChaChaRng::from_seed(2);
        let mut sum = 0.0;
        for _ in 0..10_000 {
            let v = rng.next_f64();
            assert!((0.0..1.0).contains(&v));
            sum += v;
        }
        let mean = sum / 10_000.0;
        assert!((mean - 0.5).abs() < 0.02, "mean {mean}");
    }

    #[test]
    fn gaussian_moments() {
        let mut rng = ChaChaRng::from_seed(3);
        let n = 50_000;
        let (mut s1, mut s2) = (0.0, 0.0);
        for _ in 0..n {
            let g = rng.next_gaussian();
            s1 += g;
            s2 += g * g;
        }
        let mean = s1 / n as f64;
        let var = s2 / n as f64 - mean * mean;
        assert!(mean.abs() < 0.02, "mean {mean}");
        assert!((var - 1.0).abs() < 0.03, "var {var}");
    }

    #[test]
    fn split_streams_differ() {
        let mut base = ChaChaRng::from_seed(5);
        let mut s1 = base.split(1);
        let mut s2 = base.split(2);
        let v1: Vec<u64> = (0..4).map(|_| s1.next_u64()).collect();
        let v2: Vec<u64> = (0..4).map(|_| s2.next_u64()).collect();
        assert_ne!(v1, v2);
    }
}
