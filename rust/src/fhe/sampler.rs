//! RLWE noise and secret samplers.
//!
//! - Secrets are uniform **ternary** polynomials (coefficients in
//!   {-1, 0, 1}), the standard choice in FV implementations.
//! - Errors use an exact **centered binomial** CBD(k): the difference of
//!   two k-bit popcounts, variance k/2. With the default k = 21 the
//!   standard deviation is √10.5 ≈ 3.24, matching the σ ≈ 3.2 discrete
//!   Gaussian used by the paper's `HomomorphicEncryption` R package
//!   (substituting CBD for a discrete Gaussian is standard practice —
//!   NewHope/Kyber — and keeps sampling exact, float-free and
//!   constant-time-friendly).

use crate::math::poly::{RingContext, RnsPoly};

use super::rng::ChaChaRng;

/// Default centered-binomial parameter: CBD(21) → σ = √10.5 ≈ 3.24.
pub const DEFAULT_CBD_K: u32 = 21;

/// Worst-case error magnitude bound for CBD(k): |e| ≤ k.
pub fn cbd_bound(k: u32) -> u64 {
    k as u64
}

/// One centered-binomial sample in `[-k, k]`.
pub fn cbd_sample(rng: &mut ChaChaRng, k: u32) -> i64 {
    assert!(k <= 64);
    let mask = if k == 64 { u64::MAX } else { (1u64 << k) - 1 };
    let a = (rng.next_u64() & mask).count_ones() as i64;
    let b = (rng.next_u64() & mask).count_ones() as i64;
    a - b
}

/// Ternary secret polynomial with i.i.d. coefficients in {-1, 0, 1}.
pub fn sample_ternary(ctx: &RingContext, rng: &mut ChaChaRng) -> RnsPoly {
    let coeffs: Vec<i64> = (0..ctx.d).map(|_| rng.uniform_below(3) as i64 - 1).collect();
    ctx.from_signed_coeffs(&coeffs)
}

/// Error polynomial with i.i.d. CBD(k) coefficients.
pub fn sample_error(ctx: &RingContext, rng: &mut ChaChaRng, k: u32) -> RnsPoly {
    let coeffs: Vec<i64> = (0..ctx.d).map(|_| cbd_sample(rng, k)).collect();
    ctx.from_signed_coeffs(&coeffs)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::math::primes::rns_basis_primes;
    use crate::math::modarith::center;

    #[test]
    fn cbd_moments_and_range() {
        let mut rng = ChaChaRng::from_seed(21);
        let k = DEFAULT_CBD_K;
        let n = 100_000;
        let (mut s1, mut s2) = (0f64, 0f64);
        for _ in 0..n {
            let e = cbd_sample(&mut rng, k);
            assert!(e.unsigned_abs() <= cbd_bound(k), "|e| ≤ k");
            s1 += e as f64;
            s2 += (e * e) as f64;
        }
        let mean = s1 / n as f64;
        let var = s2 / n as f64 - mean * mean;
        assert!(mean.abs() < 0.05, "mean {mean}");
        let expect = k as f64 / 2.0;
        assert!((var - expect).abs() / expect < 0.05, "var {var} vs {expect}");
    }

    #[test]
    fn ternary_distribution() {
        let ctx = crate::math::poly::RingContext::new(1024, rns_basis_primes(1024, 2));
        let mut rng = ChaChaRng::from_seed(22);
        let s = sample_ternary(&ctx, &mut rng);
        let p = ctx.basis.primes[0];
        let mut counts = [0usize; 3];
        for &v in &s.planes[0] {
            let c = center(v, p);
            assert!((-1..=1).contains(&c));
            counts[(c + 1) as usize] += 1;
        }
        for c in counts {
            let frac = c as f64 / 1024.0;
            assert!((frac - 1.0 / 3.0).abs() < 0.08, "frac {frac}");
        }
        // Residue planes must agree (same underlying integer).
        let p1 = ctx.basis.primes[1];
        for i in 0..ctx.d {
            assert_eq!(center(s.planes[0][i], p), center(s.planes[1][i], p1));
        }
    }

    #[test]
    fn error_poly_bounded() {
        let ctx = crate::math::poly::RingContext::new(256, rns_basis_primes(256, 1));
        let mut rng = ChaChaRng::from_seed(23);
        let e = sample_error(&ctx, &mut rng, DEFAULT_CBD_K);
        let p = ctx.basis.primes[0];
        for &v in &e.planes[0] {
            assert!(center(v, p).unsigned_abs() <= DEFAULT_CBD_K as u64);
        }
    }
}
