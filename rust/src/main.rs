//! `els` — command-line interface for the encrypted least squares
//! system.
//!
//! ```text
//! els params   --n 28 --p 2 --iters 2 [--nu 30] [--accel gd|vwt|nag] [--profile toy|paper128]
//! els keygen   --n 28 --p 2 --iters 2 --nu 30 --out keys.json [--seed 7]
//! els serve    --keys keys.json [--addr 127.0.0.1:7461] [--xla artifacts] [--backend rns|bigint]
//!              [--lanes 4] [--queue-cap 64] [--cache-mb 8]
//!              [--journal-dir DIR] [--checkpoint-every K] [--drain-ms 10000]
//! els client   --keys keys.json --addr HOST:PORT [--n 8 --p 2 --iters 2] [--accel vwt]
//!              [--tenant NAME] [--deadline-ms N]
//! els figures  (--all | --id fig4) [--out results]
//! els selftest [--xla artifacts] [--backend rns|bigint]
//! els metrics  [--addr HOST:PORT] [--backend rns|bigint]
//! els health   --addr HOST:PORT
//! els shutdown --addr HOST:PORT [--drain-ms 10000]
//! ```
//!
//! Set `ELS_TRACE=<path>` on any command to record a Chrome trace-event
//! JSON of the run (see README § Observability), and
//! `ELS_FAULTS=<site>:<kind>:<rate>:<seed>[,...]` to arm deterministic
//! fault injection (README § Resilience).

use std::path::Path;
use std::sync::Arc;

use els::util::error::{anyhow, bail, Context, Result};

use els::coordinator::batcher::{BatchConfig, BatchingEngine};
use els::coordinator::protocol as proto;
use els::coordinator::scheduler::{Coordinator, CoordinatorConfig};
use els::coordinator::service::{Client, Server};
use els::data::synth;
use els::els::encrypted::{decrypt_coefficients, fit, DatasetRef, FitConfig};
use els::els::exact::{self, QuantisedData};
use els::els::float_ref::{linf, ols};
use els::els::model::encrypt_dataset;
use els::els::stepsize::nu_optimal;
use els::fhe::keys::keygen;
use els::fhe::params::{plan, Algo, PlanRequest, SecurityProfile};
use els::fhe::rng::ChaChaRng;
use els::fhe::FvContext;
use els::runtime::backend::{HeEngine, NativeEngine};
use els::runtime::pjrt::XlaEngine;
use els::util::cli::Args;
use els::util::json::Json;

fn main() {
    let args = match Args::parse(std::env::args().skip(1)) {
        Ok(a) => a,
        Err(e) => {
            eprintln!("error: {e}");
            std::process::exit(2);
        }
    };
    // ELS_TRACE=<path> arms the flight recorder for the whole run;
    // ELS_FAULTS=<spec> arms deterministic chaos injection.
    els::util::telemetry::init_from_env();
    els::util::faults::init_from_env();
    let result = match args.command.as_deref() {
        Some("params") => cmd_params(&args),
        Some("keygen") => cmd_keygen(&args),
        Some("serve") => cmd_serve(&args),
        Some("client") => cmd_client(&args),
        Some("figures") => cmd_figures(&args),
        Some("selftest") => cmd_selftest(&args),
        Some("metrics") => cmd_metrics(&args),
        Some("health") => cmd_health(&args),
        Some("shutdown") => cmd_shutdown(&args),
        Some(other) => Err(anyhow!("unknown command '{other}'")),
        None if args.flag("metrics") => cmd_metrics(&args),
        None => {
            eprintln!("{USAGE}");
            return;
        }
    };
    if let Some(path) = els::util::telemetry::finish_env_trace() {
        eprintln!("[els] wrote trace {path}");
    }
    if let Err(e) = result {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

const USAGE: &str = "els — encrypted least squares (Esperança, Aslett & Holmes, AISTATS 2017)

commands:
  params    plan FV parameters for a regression job (§4.5)
  keygen    plan parameters and write a key file
  serve     run the coordinator service; --journal-dir DIR makes it
            durable (write-ahead journal + crash/restart recovery,
            checkpointing fits every --checkpoint-every iterations);
            SIGTERM/SIGINT drain gracefully (--drain-ms budget)
  client    submit an encrypted job (synthetic demo data)
  figures   regenerate the paper's tables and figures as CSV
  selftest  end-to-end encrypted fit on this machine
  metrics   print a unified MetricsSnapshot JSON (also: els --metrics);
            with --addr, fetch the live snapshot from a server
  health    print a running server's health report (--addr)
  shutdown  drain a running server: stop admission, bounce the queue,
            wait for in-flight jobs (--addr [--drain-ms 10000])

env: ELS_TRACE=<path> records a Chrome trace of any command;
     ELS_FAULTS=<site>:<kind>:<rate>:<seed>[,...] arms fault injection
every option has a default; see the doc comment in rust/src/main.rs.";

fn plan_from_args(args: &Args) -> Result<(PlanRequest, u64)> {
    let n = args.get_usize("n", 28)?;
    let p = args.get_usize("p", 2)?;
    let iters = args.get_usize("iters", 2)?;
    let phi = args.get_u64("phi", 2)? as u32;
    let nu = args.get_u64("nu", 0)?;
    let nu = if nu > 0 {
        nu
    } else {
        // Derive from a synthetic dataset of the same shape.
        let mut rng = ChaChaRng::from_seed(args.get_u64("seed", 7)?);
        let (x, _) = synth::gaussian_regression(&mut rng, n, p, 0.2);
        nu_optimal(&x)
    };
    let accel = proto::accel_from_str(args.get("accel").unwrap_or("gd"))?;
    let algo = match accel {
        els::els::encrypted::Accel::None => Algo::Gd,
        els::els::encrypted::Accel::Vwt => Algo::GdVwt,
        els::els::encrypted::Accel::Nag => Algo::Nag,
    };
    let profile = match args.get("profile").unwrap_or("toy") {
        "paper128" => SecurityProfile::Paper128,
        "toy" => SecurityProfile::Toy,
        other => bail!("unknown profile '{other}' (toy|paper128)"),
    };
    let mut req = PlanRequest::gd(n, p, iters, phi, nu)
        .with_algo(algo)
        .with_profile(profile)
        .with_extra_depth(args.get_u64("extra-depth", 0)? as u32);
    if algo == Algo::Nag {
        req.eta_abs_q = els::els::scaling::NagScaling::new(phi, nu, iters).eta_abs();
    }
    Ok((req, nu))
}

fn cmd_params(args: &Args) -> Result<()> {
    let (req, nu) = plan_from_args(args)?;
    let params = plan(&req)?;
    println!(
        "plan for N={} P={} K={} φ={} ν={nu} ({:?}):",
        req.n_obs, req.p_vars, req.iters, req.phi, req.algo
    );
    println!("  ring degree d        = {}", params.d);
    println!("  q primes             = {} ({} bits)", params.q_count, params.q_bits());
    println!("  tensor-basis primes  = {}", params.ext_count);
    println!("  plaintext modulus t  = 2^{}", params.t.bit_len() - 1);
    println!(
        "  relin digits         = {} (per-limb RNS gadget)",
        params.relin_ndigits()
    );
    println!("  mul backend          = {:?}", params.mul_backend);
    println!("  LP11 security        ≈ {:.0} bits", params.security_bits());
    println!("  ct-mult depth needed = {}", req.ct_depth());
    let mmd = match req.algo {
        Algo::Cd => els::els::mmd::paper_mmd_cd(req.iters, req.p_vars),
        Algo::GdVwt => els::els::mmd::paper_mmd(els::els::encrypted::Accel::Vwt, req.iters),
        Algo::Nag => els::els::mmd::paper_mmd(els::els::encrypted::Accel::Nag, req.iters),
        Algo::Gd => els::els::mmd::paper_mmd(els::els::encrypted::Accel::None, req.iters),
    };
    println!("  paper MMD            = {mmd}");
    println!(
        "  ciphertext size      = {:.2} MiB",
        params.ciphertext_bytes() as f64 / (1024.0 * 1024.0)
    );
    Ok(())
}

fn cmd_keygen(args: &Args) -> Result<()> {
    let (req, _) = plan_from_args(args)?;
    let params = plan(&req)?;
    let ctx = FvContext::new(params.clone());
    let mut rng = ChaChaRng::from_seed(args.get_u64("seed", 7)?);
    let keys = keygen(&ctx, &mut rng);
    let out = args.get("out").unwrap_or("keys.json");
    std::fs::write(out, proto::keyset_to_json(&params, &keys).to_string_json())?;
    println!(
        "wrote {out} (d={}, {} q-primes, λ≈{:.0} bits)",
        params.d,
        params.q_count,
        params.security_bits()
    );
    println!("WARNING: this file contains the secret key — keep it on the data-holder side.");
    Ok(())
}

fn load_keys(args: &Args) -> Result<(Arc<FvContext>, els::fhe::KeySet)> {
    let path = args.get("keys").unwrap_or("keys.json");
    let text = std::fs::read_to_string(path)
        .with_context(|| format!("reading {path} (run `els keygen` first)"))?;
    proto::keyset_from_json(&Json::parse(&text)?)
}

fn make_engine(
    args: &Args,
    ctx: Arc<FvContext>,
    rk: &els::fhe::RelinKey,
) -> Result<Arc<dyn HeEngine>> {
    // Arithmetic backend: default full-RNS; `--backend bigint` forces
    // the exact-bigint oracle (ELS_MUL_BACKEND overrides the default).
    let ctx = match args.get("backend") {
        Some("bigint") | Some("oracle") => {
            ctx.with_backend(els::fhe::MulBackend::ExactBigint)
        }
        Some("rns") => ctx.with_backend(els::fhe::MulBackend::FullRns),
        Some(other) => bail!("unknown backend '{other}' (rns|bigint)"),
        None => ctx,
    };
    match args.get("xla") {
        Some(dir) => {
            let engine = XlaEngine::new(ctx, rk, Path::new(dir))?;
            eprintln!("[els] using XLA/PJRT backend ({dir})");
            Ok(Arc::new(engine))
        }
        None => Ok(Arc::new(NativeEngine::new(ctx, Arc::new(rk.clone())))),
    }
}

/// Set by the `SIGTERM`/`SIGINT` handler; the serve loop polls it and
/// drains the coordinator when it flips. Async-signal-safe: the handler
/// only stores a relaxed atomic.
static STOP_REQUESTED: std::sync::atomic::AtomicBool = std::sync::atomic::AtomicBool::new(false);

#[cfg(unix)]
fn install_stop_handler() {
    extern "C" fn on_stop(_sig: i32) {
        STOP_REQUESTED.store(true, std::sync::atomic::Ordering::Relaxed);
    }
    // Dep-free raw libc binding: SIGINT=2, SIGTERM=15 (POSIX).
    extern "C" {
        fn signal(signum: i32, handler: extern "C" fn(i32)) -> usize;
    }
    unsafe {
        signal(2, on_stop);
        signal(15, on_stop);
    }
}

#[cfg(not(unix))]
fn install_stop_handler() {}

fn cmd_serve(args: &Args) -> Result<()> {
    let (ctx, keys) = load_keys(args)?;
    let inner = make_engine(args, ctx.clone(), &keys.rk)?;
    let engine = BatchingEngine::new(
        inner,
        BatchConfig {
            max_batch: args.get_usize("max-batch", 64)?,
            max_wait: std::time::Duration::from_millis(args.get_u64("max-wait-ms", 2)?),
        },
    );
    // `--max-jobs` stays as a legacy alias for `--lanes`.
    let lanes = args.get_usize("lanes", args.get_usize("max-jobs", 4)?)?;
    let cfg = CoordinatorConfig {
        lanes,
        queue_capacity: args.get_usize("queue-cap", 64)?,
        cache_budget_bytes: args.get_usize("cache-mb", 8)? << 20,
        cache_shards: 4,
        checkpoint_every: args.get_usize("checkpoint-every", 1)?,
    };
    // `--journal-dir` makes the coordinator durable: every accepted job
    // hits the write-ahead journal before its id is returned, and a
    // restart replays the log — queued jobs re-run, checkpointed fits
    // resume, finished-but-unacked results are served from the journal.
    let coord = match args.get("journal-dir") {
        Some(dir) => {
            let c = Coordinator::recover(engine, cfg, dir)
                .with_context(|| format!("recovering journal from {dir}"))?;
            let r = c.recovered();
            println!(
                "journal {dir}: recovered {} job(s) ({} requeued, {} resumed \
                 from checkpoints, {} restored, {} failed)",
                r.total(),
                r.requeued,
                r.resumed,
                r.restored,
                r.failed
            );
            c
        }
        None => Coordinator::with_config(engine, cfg),
    };
    install_stop_handler();
    let addr = args.get("addr").unwrap_or("127.0.0.1:7461");
    let server = Server::start(coord.clone(), addr)?;
    println!(
        "els coordinator listening on {} (d={}, {} q-primes, {lanes} lanes)",
        server.addr,
        ctx.d(),
        ctx.params.q_count
    );
    println!("SIGTERM or Ctrl-C drains and stops");
    while !STOP_REQUESTED.load(std::sync::atomic::Ordering::Relaxed) {
        if !coord.is_accepting() && coord.queue_depth() == 0 && coord.running_jobs() == 0 {
            // A wire `shutdown` already drained the coordinator — no
            // point spinning on a dead service.
            break;
        }
        std::thread::sleep(std::time::Duration::from_millis(200));
    }
    // Graceful termination: stop admission, bounce queued jobs with
    // `shutting_down` (retryable against a replacement server), let
    // in-flight fits finish within the drain budget, then sync the
    // journal so a restart sees every lifecycle record.
    let drain = std::time::Duration::from_millis(args.get_u64("drain-ms", 10_000)?);
    let report = coord.shutdown(drain);
    println!(
        "drain: bounced {} queued job(s), in-flight drained = {}",
        report.bounced, report.drained
    );
    Ok(())
}

fn cmd_client(args: &Args) -> Result<()> {
    let (ctx, keys) = load_keys(args)?;
    let addr = args.req("addr")?;
    let n = args.get_usize("n", 8)?;
    let p = args.get_usize("p", 2)?;
    let iters = args.get_usize("iters", 2)?;
    let accel = proto::accel_from_str(args.get("accel").unwrap_or("gd"))?;
    let mut rng = ChaChaRng::from_seed(args.get_u64("data-seed", 99)?);
    let (x, y) = synth::gaussian_regression(&mut rng, n, p, 0.2);
    let q = QuantisedData::from_f64(&x, &y, 2);
    let (xq, yq) = q.dequantised();
    let nu = nu_optimal(&xq);

    println!("encrypting {n}×{p} dataset locally ...");
    let data = encrypt_dataset(&ctx, &keys.pk, &q, &mut rng);
    let mut client = Client::connect(addr)?;
    let cfg = FitConfig { iters, nu, accel, keep_path: false };
    let tenant = args.get("tenant");
    let deadline_ms = match args.get_u64("deadline-ms", 0)? {
        0 => None,
        ms => Some(ms),
    };
    let t0 = std::time::Instant::now();
    let id = match client.submit_with(&data, &cfg, None, tenant, deadline_ms) {
        Ok(id) => id,
        Err(e) => bail!("submit rejected with code '{}': {}", e.code, e.message),
    };
    println!("submitted as {id}; waiting ...");
    let fitted = client.result(&ctx, id)?;
    let wall = t0.elapsed();
    let dec = decrypt_coefficients(&ctx, &keys.sk, &fitted);
    let truth = ols(&xq, &yq);
    println!("decrypted coefficients after {iters} iterations ({wall:.2?}):");
    for (j, (b, t)) in dec.iter().zip(&truth).enumerate() {
        println!("  β_{j} = {b:+.4}   (OLS {t:+.4})");
    }
    println!("‖β − β_ols‖∞ = {:.4}", linf(&dec, &truth));
    println!("server metrics: {}", client.metrics()?);
    Ok(())
}

fn cmd_figures(args: &Args) -> Result<()> {
    let out = Path::new(args.get("out").unwrap_or("results")).to_path_buf();
    let paths = if args.flag("all") || args.get("id").is_none() {
        els::figures::run_all(&out)?
    } else {
        els::figures::run(args.req("id")?, &out)?
    };
    for p in paths {
        println!("wrote {}", p.display());
    }
    Ok(())
}

/// `els metrics` / `els --metrics`: the unified counter snapshot. With
/// `--addr`, fetch the live `els-metrics-v1` document from a running
/// coordinator; otherwise run a small local encrypted fit and print its
/// per-fit op budget report.
fn cmd_metrics(args: &Args) -> Result<()> {
    if let Some(addr) = args.get("addr") {
        let mut client = Client::connect(addr)?;
        println!("{}", client.metrics_snapshot()?.to_string_json());
        return Ok(());
    }
    // Local mode: a micro-fit so the counters describe real work.
    let mut rng = ChaChaRng::from_seed(args.get_u64("seed", 7)?);
    let (x, y) = synth::gaussian_regression(&mut rng, 6, 2, 0.2);
    let q = QuantisedData::from_f64(&x, &y, 2);
    let (xq, _) = q.dequantised();
    let nu = nu_optimal(&xq);
    let params = plan(&PlanRequest::gd(6, 2, 2, 2, nu))?;
    let ctx = FvContext::new(params);
    let keys = keygen(&ctx, &mut rng);
    let engine = make_engine(args, ctx.clone(), &keys.rk)?;
    let data = encrypt_dataset(&ctx, &keys.pk, &q, &mut rng);
    let out = fit(engine.as_ref(), &DatasetRef::Scalar(&data), &FitConfig::gd(2, nu))?;
    eprintln!("[els] op budget of one 6×2, 2-iteration GD fit:");
    println!("{}", out.report.to_json().to_string_json());
    Ok(())
}

/// `els health --addr HOST:PORT`: the server's liveness/pressure
/// report, verbatim (accepting, lanes, queue depth, running, tracked
/// jobs, live timers, uptime).
fn cmd_health(args: &Args) -> Result<()> {
    let addr = args.req("addr")?;
    let mut client = Client::connect(addr)?;
    println!("{}", client.health()?.to_string_json());
    Ok(())
}

/// `els shutdown --addr HOST:PORT [--drain-ms N]`: ask the server to
/// drain — admission stops, queued jobs bounce with `shutting_down`,
/// in-flight jobs get up to the drain budget to finish.
fn cmd_shutdown(args: &Args) -> Result<()> {
    let addr = args.req("addr")?;
    let drain_ms = match args.get_u64("drain-ms", 0)? {
        0 => None,
        ms => Some(ms),
    };
    let mut client = Client::connect(addr)?;
    let (bounced, drained) = client.shutdown_server(drain_ms)?;
    println!("drain: bounced {bounced} queued job(s), in-flight drained = {drained}");
    Ok(())
}

fn cmd_selftest(args: &Args) -> Result<()> {
    println!("[1/3] planning parameters + keygen ...");
    let mut rng = ChaChaRng::from_seed(3);
    let (x, y) = synth::gaussian_regression(&mut rng, 8, 2, 0.2);
    let q = QuantisedData::from_f64(&x, &y, 2);
    let (xq, _) = q.dequantised();
    let nu = nu_optimal(&xq);
    let params = plan(&PlanRequest::gd(8, 2, 2, 2, nu))?;
    let ctx = FvContext::new(params);
    let keys = keygen(&ctx, &mut rng);
    println!(
        "      d={}, q={} bits, λ≈{:.0} bits",
        ctx.d(),
        ctx.q.bit_len(),
        ctx.params.security_bits()
    );
    println!("[2/3] encrypting + fitting 2 GD iterations ...");
    let engine = make_engine(args, ctx.clone(), &keys.rk)?;
    let data = encrypt_dataset(&ctx, &keys.pk, &q, &mut rng);
    let fitted = fit(engine.as_ref(), &DatasetRef::Scalar(&data), &FitConfig::gd(2, nu))?.fit;
    println!("[3/3] decrypting + validating against the exact simulation ...");
    let dec = decrypt_coefficients(&ctx, &keys.sk, &fitted);
    let expect = exact::gd_exact(&q, nu, 2).decode_last();
    let drift = linf(&dec, &expect);
    if drift < 1e-9 {
        println!("OK: encrypted == exact (drift {drift:.2e}); β = {dec:?}");
        Ok(())
    } else {
        bail!("selftest FAILED: drift {drift}")
    }
}
