//! Micro-benchmark harness (criterion is not vendored offline).
//!
//! Used by the `benches/*.rs` targets (`harness = false`): warmup, then
//! timed iterations with mean/min/max reporting, plus a row printer for
//! table-style end-to-end benches.

use std::time::{Duration, Instant};

/// Result of one benchmark.
#[derive(Clone, Debug)]
pub struct BenchStats {
    pub name: String,
    pub iters: usize,
    pub mean: Duration,
    pub min: Duration,
    pub max: Duration,
}

impl BenchStats {
    pub fn report(&self) {
        println!(
            "{:<48} {:>12} {:>12} {:>12}   x{}",
            self.name,
            fmt_dur(self.mean),
            fmt_dur(self.min),
            fmt_dur(self.max),
            self.iters
        );
    }
}

pub fn fmt_dur(d: Duration) -> String {
    let ns = d.as_nanos();
    if ns < 1_000 {
        format!("{ns} ns")
    } else if ns < 1_000_000 {
        format!("{:.2} µs", ns as f64 / 1e3)
    } else if ns < 1_000_000_000 {
        format!("{:.2} ms", ns as f64 / 1e6)
    } else {
        format!("{:.2} s", ns as f64 / 1e9)
    }
}

/// Print the standard header once per bench binary.
pub fn header(title: &str) {
    println!("\n== {title} ==");
    println!("{:<48} {:>12} {:>12} {:>12}", "benchmark", "mean", "min", "max");
}

/// Time `f` over `iters` iterations after `warmup` warmup runs.
pub fn bench<F: FnMut()>(name: &str, warmup: usize, iters: usize, mut f: F) -> BenchStats {
    assert!(iters >= 1);
    for _ in 0..warmup {
        f();
    }
    let mut times = Vec::with_capacity(iters);
    for _ in 0..iters {
        let t = Instant::now();
        f();
        times.push(t.elapsed());
    }
    let total: Duration = times.iter().sum();
    let stats = BenchStats {
        name: name.to_string(),
        iters,
        mean: total / iters as u32,
        min: *times.iter().min().unwrap(),
        max: *times.iter().max().unwrap(),
    };
    stats.report();
    stats
}

/// Prevent the optimiser from discarding a value.
#[inline]
pub fn black_box<T>(x: T) -> T {
    std::hint::black_box(x)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stats_ordering() {
        let s = bench("noop", 1, 5, || {
            black_box(1 + 1);
        });
        assert!(s.min <= s.mean && s.mean <= s.max);
        assert_eq!(s.iters, 5);
    }

    #[test]
    fn duration_formatting() {
        assert!(fmt_dur(Duration::from_nanos(10)).contains("ns"));
        assert!(fmt_dur(Duration::from_micros(10)).contains("µs"));
        assert!(fmt_dur(Duration::from_millis(10)).contains("ms"));
        assert!(fmt_dur(Duration::from_secs(10)).contains("s"));
    }
}
