//! Minimal data-parallelism helper (no rayon offline): chunked
//! `parallel_map` over scoped threads.

/// Map `f` over `items` using up to `available_parallelism` threads.
/// Preserves input order. Falls back to serial for tiny inputs.
pub fn parallel_map<T, U, F>(items: Vec<T>, f: F) -> Vec<U>
where
    T: Send,
    U: Send,
    F: Fn(T) -> U + Send + Sync,
{
    let n = items.len();
    let workers = std::thread::available_parallelism().map(|v| v.get()).unwrap_or(1);
    if n <= 1 || workers <= 1 {
        return items.into_iter().map(f).collect();
    }
    let workers = workers.min(n);
    let chunk = n.div_ceil(workers);
    let mut chunks: Vec<Vec<T>> = Vec::with_capacity(workers);
    let mut it = items.into_iter();
    loop {
        let c: Vec<T> = it.by_ref().take(chunk).collect();
        if c.is_empty() {
            break;
        }
        chunks.push(c);
    }
    let f = &f;
    let mut results: Vec<Vec<U>> = Vec::with_capacity(chunks.len());
    std::thread::scope(|s| {
        let handles: Vec<_> = chunks
            .into_iter()
            .map(|c| s.spawn(move || c.into_iter().map(f).collect::<Vec<U>>()))
            .collect();
        for h in handles {
            match h.join() {
                Ok(r) => results.push(r),
                // Preserve the original panic payload.
                Err(e) => std::panic::resume_unwind(e),
            }
        }
    });
    results.into_iter().flatten().collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn preserves_order() {
        let out = parallel_map((0..1000).collect::<Vec<_>>(), |x| x * 2);
        assert_eq!(out, (0..1000).map(|x| x * 2).collect::<Vec<_>>());
    }

    #[test]
    fn empty_and_single() {
        let out: Vec<i32> = parallel_map(Vec::<i32>::new(), |x| x);
        assert!(out.is_empty());
        assert_eq!(parallel_map(vec![7], |x| x + 1), vec![8]);
    }

    #[test]
    #[should_panic(expected = "boom")]
    fn propagates_panics() {
        let _ = parallel_map(vec![1, 2, 3, 4, 5, 6, 7, 8], |x| {
            if x == 5 {
                panic!("boom");
            }
            x
        });
    }
}
