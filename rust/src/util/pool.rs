//! Minimal data-parallelism helper (no rayon offline): chunked
//! `parallel_map` over scoped threads.

/// Map `f` over `items` using up to `available_parallelism` threads.
/// Preserves input order. Falls back to serial for tiny inputs.
pub fn parallel_map<T, U, F>(items: Vec<T>, f: F) -> Vec<U>
where
    T: Send,
    U: Send,
    F: Fn(T) -> U + Send + Sync,
{
    let workers =
        std::thread::available_parallelism().map(|v| v.get()).unwrap_or(1);
    parallel_map_workers(items, workers, f)
}

/// [`parallel_map`] with an explicit worker budget. `workers` is
/// clamped to `[1, items.len()]`, so any value (0, or more workers than
/// items) is safe; `workers <= 1`, empty and single-element inputs run
/// serially on the caller thread.
pub fn parallel_map_workers<T, U, F>(items: Vec<T>, workers: usize, f: F) -> Vec<U>
where
    T: Send,
    U: Send,
    F: Fn(T) -> U + Send + Sync,
{
    let n = items.len();
    if n == 0 {
        return Vec::new();
    }
    let workers = workers.clamp(1, n);
    if n == 1 || workers == 1 {
        return items.into_iter().map(f).collect();
    }
    let chunk = n.div_ceil(workers);
    let mut chunks: Vec<Vec<T>> = Vec::with_capacity(workers);
    let mut it = items.into_iter();
    loop {
        let c: Vec<T> = it.by_ref().take(chunk).collect();
        if c.is_empty() {
            break;
        }
        chunks.push(c);
    }
    let f = &f;
    let mut results: Vec<Vec<U>> = Vec::with_capacity(chunks.len());
    std::thread::scope(|s| {
        // Spawn everything first, then join in spawn order — joining
        // in order is what preserves the input order in `results`.
        let handles: Vec<_> = chunks
            .into_iter()
            .map(|c| s.spawn(move || c.into_iter().map(f).collect::<Vec<U>>()))
            .collect();
        for h in handles {
            match h.join() {
                Ok(r) => results.push(r),
                // Preserve the original panic payload.
                Err(e) => std::panic::resume_unwind(e),
            }
        }
    });
    results.into_iter().flatten().collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn preserves_order() {
        let out = parallel_map((0..1000).collect::<Vec<_>>(), |x| x * 2);
        assert_eq!(out, (0..1000).map(|x| x * 2).collect::<Vec<_>>());
    }

    #[test]
    fn preserves_order_for_every_worker_count() {
        // Sweep worker counts around the chunking edge cases: 1 (serial),
        // even/odd splits, workers == n, workers > n, and absurd values.
        let n = 101usize;
        let expect: Vec<usize> = (0..n).map(|x| x * x).collect();
        for workers in [0usize, 1, 2, 3, 7, 16, 100, 101, 102, 10_000] {
            let out =
                parallel_map_workers((0..n).collect::<Vec<_>>(), workers, |x| x * x);
            assert_eq!(out, expect, "workers = {workers}");
        }
    }

    #[test]
    fn empty_and_single() {
        let out: Vec<i32> = parallel_map(Vec::<i32>::new(), |x| x);
        assert!(out.is_empty());
        assert_eq!(parallel_map(vec![7], |x| x + 1), vec![8]);
        // Explicit-worker variants of the same edges.
        let out: Vec<i32> = parallel_map_workers(Vec::<i32>::new(), 8, |x| x);
        assert!(out.is_empty());
        let out: Vec<i32> = parallel_map_workers(Vec::<i32>::new(), 0, |x| x);
        assert!(out.is_empty());
        assert_eq!(parallel_map_workers(vec![7], 64, |x| x + 1), vec![8]);
    }

    #[test]
    fn workers_beyond_items_use_one_item_chunks() {
        // With workers ≥ n every chunk has exactly one element; order
        // must still come back intact.
        let out = parallel_map_workers((0..8).collect::<Vec<_>>(), 64, |x| x + 1);
        assert_eq!(out, (1..9).collect::<Vec<_>>());
    }

    #[test]
    #[should_panic(expected = "boom")]
    fn propagates_panics() {
        let _ = parallel_map(vec![1, 2, 3, 4, 5, 6, 7, 8], |x| {
            if x == 5 {
                panic!("boom");
            }
            x
        });
    }

    #[test]
    #[should_panic(expected = "boom")]
    fn propagates_panics_with_explicit_workers() {
        let _ = parallel_map_workers(vec![1, 2, 3, 4], 4, |x| {
            if x == 3 {
                panic!("boom");
            }
            x
        });
    }
}
