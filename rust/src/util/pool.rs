//! Minimal data-parallelism helper (no rayon offline): chunked
//! `parallel_map` over scoped threads, with an optional per-worker
//! scratch state and an `ELS_POOL_WORKERS`-controlled worker budget.

use std::sync::atomic::{AtomicU64, Ordering};

use crate::util::telemetry::{self, Phase};

/// Fan-out invocations since process start (every `parallel_map_with`
/// entry with at least one item, serial path included). Always-on
/// metrics counters — not gated by tracing, like the ring counters.
/// Excluded from the snapshot's cross-worker bit-identity contract:
/// some call sites legally bypass the pool entirely when their own
/// budget is serial.
static DISPATCHES: AtomicU64 = AtomicU64::new(0);
/// Total items fanned out across all dispatches.
static TASKS: AtomicU64 = AtomicU64::new(0);

pub fn dispatch_count() -> u64 {
    DISPATCHES.load(Ordering::Relaxed)
}

pub fn dispatched_task_count() -> u64 {
    TASKS.load(Ordering::Relaxed)
}

/// The process-wide worker budget: `ELS_POOL_WORKERS` when set (≥ 1),
/// otherwise `available_parallelism`. The env var is how CI pins the
/// serial (`=1`) vs parallel engine paths; an unparsable or zero value
/// panics loudly rather than silently degrading to serial.
pub fn pool_workers() -> usize {
    match std::env::var("ELS_POOL_WORKERS") {
        Ok(v) => parse_pool_workers(&v),
        Err(_) => std::thread::available_parallelism().map(|v| v.get()).unwrap_or(1),
    }
}

/// Parse an `ELS_POOL_WORKERS` value (pure — testable without touching
/// the process environment, which is not thread-safe to mutate).
fn parse_pool_workers(v: &str) -> usize {
    match v.trim().parse::<usize>() {
        Ok(n) if n >= 1 => n,
        _ => panic!("invalid ELS_POOL_WORKERS '{v}' (expected an integer >= 1)"),
    }
}

/// Map `f` over `items` using up to [`pool_workers`] threads (so
/// `ELS_POOL_WORKERS=1` really pins *every* fan-out in the process,
/// not just the native engine's). Preserves input order. Falls back to
/// serial for tiny inputs.
pub fn parallel_map<T, U, F>(items: Vec<T>, f: F) -> Vec<U>
where
    T: Send,
    U: Send,
    F: Fn(T) -> U + Send + Sync,
{
    parallel_map_workers(items, pool_workers(), f)
}

/// [`parallel_map`] with an explicit worker budget. `workers` is
/// clamped to `[1, items.len()]`, so any value (0, or more workers than
/// items) is safe; `workers <= 1`, empty and single-element inputs run
/// serially on the caller thread. Output order always equals input
/// order, independent of the worker count.
pub fn parallel_map_workers<T, U, F>(items: Vec<T>, workers: usize, f: F) -> Vec<U>
where
    T: Send,
    U: Send,
    F: Fn(T) -> U + Send + Sync,
{
    parallel_map_with(items, workers, || (), move |(), t| f(t))
}

/// [`parallel_map_workers`] with a per-worker scratch state: `init`
/// runs once on each worker thread (and once on the caller thread for
/// the serial path), and `f` receives `&mut` to that worker's scratch
/// for every item of its chunk. This is how the multiply pipeline
/// reuses its tensor/scale buffers across a batch instead of
/// reallocating per call.
pub fn parallel_map_with<T, U, S, I, F>(items: Vec<T>, workers: usize, init: I, f: F) -> Vec<U>
where
    T: Send,
    U: Send,
    I: Fn() -> S + Send + Sync,
    F: Fn(&mut S, T) -> U + Send + Sync,
{
    let n = items.len();
    if n == 0 {
        return Vec::new();
    }
    DISPATCHES.fetch_add(1, Ordering::Relaxed);
    TASKS.fetch_add(n as u64, Ordering::Relaxed);
    let workers = workers.clamp(1, n);
    if n == 1 || workers == 1 {
        let mut scratch = init();
        return items.into_iter().map(|t| f(&mut scratch, t)).collect();
    }
    let chunk = n.div_ceil(workers);
    let mut chunks: Vec<Vec<T>> = Vec::with_capacity(workers);
    let mut it = items.into_iter();
    loop {
        let c: Vec<T> = it.by_ref().take(chunk).collect();
        if c.is_empty() {
            break;
        }
        chunks.push(c);
    }
    let f = &f;
    let init = &init;
    let mut results: Vec<Vec<U>> = Vec::with_capacity(chunks.len());
    std::thread::scope(|s| {
        // Spawn everything first, then join in spawn order — joining
        // in order is what preserves the input order in `results`.
        let handles: Vec<_> = chunks
            .into_iter()
            .map(|c| {
                s.spawn(move || {
                    // One span per worker lane: fan-out utilisation is
                    // visible per thread in the trace viewer.
                    let _lane = telemetry::span(Phase::PoolWorker);
                    let mut scratch = init();
                    c.into_iter().map(|t| f(&mut scratch, t)).collect::<Vec<U>>()
                })
            })
            .collect();
        for h in handles {
            match h.join() {
                Ok(r) => results.push(r),
                // Preserve the original panic payload.
                Err(e) => std::panic::resume_unwind(e),
            }
        }
    });
    results.into_iter().flatten().collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn preserves_order() {
        let out = parallel_map((0..1000).collect::<Vec<_>>(), |x| x * 2);
        assert_eq!(out, (0..1000).map(|x| x * 2).collect::<Vec<_>>());
    }

    #[test]
    fn preserves_order_for_every_worker_count() {
        // Sweep worker counts around the chunking edge cases: 1 (serial),
        // even/odd splits, workers == n, workers > n, and absurd values.
        let n = 101usize;
        let expect: Vec<usize> = (0..n).map(|x| x * x).collect();
        for workers in [0usize, 1, 2, 3, 7, 16, 100, 101, 102, 10_000] {
            let out =
                parallel_map_workers((0..n).collect::<Vec<_>>(), workers, |x| x * x);
            assert_eq!(out, expect, "workers = {workers}");
        }
    }

    #[test]
    fn empty_and_single() {
        let out: Vec<i32> = parallel_map(Vec::<i32>::new(), |x| x);
        assert!(out.is_empty());
        assert_eq!(parallel_map(vec![7], |x| x + 1), vec![8]);
        // Explicit-worker variants of the same edges.
        let out: Vec<i32> = parallel_map_workers(Vec::<i32>::new(), 8, |x| x);
        assert!(out.is_empty());
        let out: Vec<i32> = parallel_map_workers(Vec::<i32>::new(), 0, |x| x);
        assert!(out.is_empty());
        assert_eq!(parallel_map_workers(vec![7], 64, |x| x + 1), vec![8]);
    }

    #[test]
    fn workers_beyond_items_use_one_item_chunks() {
        // With workers ≥ n every chunk has exactly one element; order
        // must still come back intact.
        let out = parallel_map_workers((0..8).collect::<Vec<_>>(), 64, |x| x + 1);
        assert_eq!(out, (1..9).collect::<Vec<_>>());
    }

    #[test]
    #[should_panic(expected = "boom")]
    fn propagates_panics() {
        let _ = parallel_map(vec![1, 2, 3, 4, 5, 6, 7, 8], |x| {
            if x == 5 {
                panic!("boom");
            }
            x
        });
    }

    #[test]
    fn scratch_state_is_per_worker_and_order_preserving() {
        // Each worker counts the items it processed in its scratch; the
        // output carries (item, count-so-far-on-this-worker). Order must
        // match input order and per-worker counts must partition n.
        let n = 64usize;
        for workers in [1usize, 3, 8, 64] {
            let out = parallel_map_with(
                (0..n).collect::<Vec<_>>(),
                workers,
                || 0usize,
                |seen, x| {
                    *seen += 1;
                    (x, *seen)
                },
            );
            assert_eq!(
                out.iter().map(|&(x, _)| x).collect::<Vec<_>>(),
                (0..n).collect::<Vec<_>>(),
                "workers = {workers}"
            );
            let total: usize = out.iter().filter(|&&(_, c)| c == 1).count();
            assert_eq!(total, workers.min(n), "one scratch per worker (workers = {workers})");
        }
    }

    #[test]
    fn dispatch_counters_advance() {
        // ≥, not ==: other tests fan out concurrently in this process.
        let d0 = dispatch_count();
        let t0 = dispatched_task_count();
        let _ = parallel_map_workers((0..10).collect::<Vec<_>>(), 2, |x| x);
        assert!(dispatch_count() >= d0 + 1);
        assert!(dispatched_task_count() >= t0 + 10);
        // Empty input is not a dispatch.
        let d1 = dispatch_count();
        let _: Vec<i32> = parallel_map_workers(Vec::new(), 4, |x| x);
        assert!(dispatch_count() >= d1);
    }

    #[test]
    fn pool_workers_is_at_least_one() {
        // Whatever the test environment sets (CI pins "1"; developers
        // usually leave it unset → available_parallelism), the
        // contract is >= 1. Never mutate the env here: setenv racing
        // getenv across test threads is UB on glibc.
        assert!(pool_workers() >= 1);
    }

    #[test]
    fn pool_workers_parsing() {
        assert_eq!(parse_pool_workers("1"), 1);
        assert_eq!(parse_pool_workers(" 8 "), 8);
        assert_eq!(parse_pool_workers("32"), 32);
    }

    #[test]
    #[should_panic(expected = "invalid ELS_POOL_WORKERS")]
    fn pool_workers_rejects_zero() {
        let _ = parse_pool_workers("0");
    }

    #[test]
    #[should_panic(expected = "invalid ELS_POOL_WORKERS")]
    fn pool_workers_rejects_garbage() {
        let _ = parse_pool_workers("many");
    }

    #[test]
    #[should_panic(expected = "boom")]
    fn propagates_panics_with_explicit_workers() {
        let _ = parallel_map_workers(vec![1, 2, 3, 4], 4, |x| {
            if x == 3 {
                panic!("boom");
            }
            x
        });
    }
}
