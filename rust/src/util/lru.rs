//! Byte-budgeted LRU map ([`LruBytes`]), shared infrastructure for the
//! per-tenant operand caches (`coordinator::tenant`) and anything else
//! that caches by byte weight.
//!
//! Promoted out of `coordinator::arena` so its accounting invariants
//! can be property- and concurrency-tested as plain `util` code: after
//! any operation sequence, `live_bytes` equals the sum of resident
//! entry byte charges, `len` matches the map, and the budget holds
//! whenever more than one entry is resident. [`LruBytes::evict_all`]
//! is the forced-eviction hook the chaos battery's `cache:evict` fault
//! site drives.

use std::collections::BTreeMap;

struct LruEntry<V> {
    value: V,
    bytes: usize,
    tick: u64,
}

/// Byte-budgeted LRU map. Recency is a monotone tick stamped on every
/// `get` hit and `insert`; when the live byte total exceeds the budget,
/// the minimum-tick entry is evicted (but the most recent insert is
/// never evicted, so a single over-budget value still caches). Keys are
/// exact — the per-tenant operand caches key on canonical plaintext
/// coefficient words, because an approximate (hashed) key colliding
/// would silently substitute a *wrong operand* into an encrypted fit.
pub struct LruBytes<K: Ord + Clone, V> {
    entries: BTreeMap<K, LruEntry<V>>,
    budget_bytes: usize,
    live_bytes: usize,
    tick: u64,
    hits: u64,
    misses: u64,
    evictions: u64,
}

impl<K: Ord + Clone, V> LruBytes<K, V> {
    pub fn new(budget_bytes: usize) -> Self {
        LruBytes {
            entries: BTreeMap::new(),
            budget_bytes,
            live_bytes: 0,
            tick: 0,
            hits: 0,
            misses: 0,
            evictions: 0,
        }
    }

    fn next_tick(&mut self) -> u64 {
        self.tick += 1;
        self.tick
    }

    /// Look up `key`, bumping its recency on a hit.
    pub fn get(&mut self, key: &K) -> Option<&V> {
        let tick = self.tick + 1;
        match self.entries.get_mut(key) {
            Some(e) => {
                self.tick = tick;
                e.tick = tick;
                self.hits += 1;
                Some(&e.value)
            }
            None => {
                self.misses += 1;
                None
            }
        }
    }

    /// Insert (or replace) an entry charged at `bytes`, then evict
    /// least-recently-used entries until the budget holds again. The
    /// just-inserted entry is exempt from its own eviction pass.
    pub fn insert(&mut self, key: K, value: V, bytes: usize) {
        let tick = self.next_tick();
        if let Some(old) = self.entries.insert(key, LruEntry { value, bytes, tick }) {
            self.live_bytes -= old.bytes;
        }
        self.live_bytes += bytes;
        while self.live_bytes > self.budget_bytes && self.entries.len() > 1 {
            let victim = self
                .entries
                .iter()
                .min_by_key(|(_, e)| e.tick)
                .map(|(k, _)| k.clone())
                .expect("non-empty");
            if let Some(e) = self.entries.remove(&victim) {
                self.live_bytes -= e.bytes;
                self.evictions += 1;
            }
        }
    }

    /// Drop every resident entry, counting each as an eviction. The
    /// chaos `cache:evict` fault site calls this to simulate a cold
    /// cache mid-burst; correctness must not depend on residency.
    pub fn evict_all(&mut self) -> usize {
        let n = self.entries.len();
        self.entries.clear();
        self.live_bytes = 0;
        self.evictions += n as u64;
        n
    }

    /// Check the accounting invariants, panicking on violation:
    /// `live_bytes` equals the sum of resident entry charges, and when
    /// more than one entry is resident the byte budget holds.
    pub fn audit(&self) {
        let sum: usize = self.entries.values().map(|e| e.bytes).sum();
        assert_eq!(self.live_bytes, sum, "live_bytes diverged from resident entries");
        assert!(
            self.entries.len() <= 1 || self.live_bytes <= self.budget_bytes,
            "budget violated with {} entries / {} bytes (budget {})",
            self.entries.len(),
            self.live_bytes,
            self.budget_bytes
        );
    }

    pub fn len(&self) -> usize {
        self.entries.len()
    }

    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    pub fn live_bytes(&self) -> usize {
        self.live_bytes
    }

    pub fn budget_bytes(&self) -> usize {
        self.budget_bytes
    }

    /// `(hits, misses, evictions)` since construction.
    pub fn stats(&self) -> (u64, u64, u64) {
        (self.hits, self.misses, self.evictions)
    }
}

#[cfg(test)]
mod tests {
    use std::sync::Mutex;

    use super::*;
    use crate::util::prop::{gen, PropRunner};

    #[test]
    fn lru_evicts_oldest_under_byte_budget() {
        let mut lru: LruBytes<u32, &'static str> = LruBytes::new(100);
        lru.insert(1, "a", 40);
        lru.insert(2, "b", 40);
        lru.insert(3, "c", 40); // 120 > 100 ⇒ evict key 1
        assert_eq!(lru.len(), 2);
        assert!(lru.get(&1).is_none());
        assert_eq!(lru.get(&2), Some(&"b"));
        assert_eq!(lru.get(&3), Some(&"c"));
        assert_eq!(lru.live_bytes(), 80);
        let (hits, misses, evictions) = lru.stats();
        assert_eq!((hits, misses, evictions), (2, 1, 1));
    }

    #[test]
    fn lru_hit_bumps_recency() {
        let mut lru: LruBytes<u32, u32> = LruBytes::new(100);
        lru.insert(1, 10, 40);
        lru.insert(2, 20, 40);
        assert_eq!(lru.get(&1), Some(&10)); // key 1 is now the freshest
        lru.insert(3, 30, 40); // over budget ⇒ evict key 2, not key 1
        assert_eq!(lru.get(&2), None);
        assert_eq!(lru.get(&1), Some(&10));
        assert_eq!(lru.get(&3), Some(&30));
    }

    #[test]
    fn lru_single_oversized_entry_survives() {
        // One value larger than the whole budget must still cache (the
        // just-inserted entry is exempt from its own eviction pass).
        let mut lru: LruBytes<u32, u32> = LruBytes::new(10);
        lru.insert(1, 1, 50);
        assert_eq!(lru.len(), 1);
        assert_eq!(lru.get(&1), Some(&1));
        lru.insert(2, 2, 50); // displaces the previous oversized entry
        assert_eq!(lru.len(), 1);
        assert_eq!(lru.get(&2), Some(&2));
    }

    #[test]
    fn lru_replace_accounts_bytes_once() {
        let mut lru: LruBytes<u32, u32> = LruBytes::new(100);
        lru.insert(1, 10, 60);
        lru.insert(1, 11, 30);
        assert_eq!(lru.live_bytes(), 30);
        assert_eq!(lru.get(&1), Some(&11));
    }

    #[test]
    fn lru_evict_all_resets_accounting() {
        let mut lru: LruBytes<u32, u32> = LruBytes::new(1000);
        for k in 0..5 {
            lru.insert(k, k, 100);
        }
        assert_eq!(lru.evict_all(), 5);
        assert!(lru.is_empty());
        assert_eq!(lru.live_bytes(), 0);
        assert_eq!(lru.stats().2, 5, "forced evictions must be counted");
        lru.audit();
        // The cache keeps working after a forced flush.
        lru.insert(7, 7, 100);
        assert_eq!(lru.get(&7), Some(&7));
        lru.audit();
    }

    #[test]
    fn lru_accounting_matches_naive_model_under_random_ops() {
        // Model check: after any op sequence, residency and live_bytes
        // agree with a naive replay that tracks (key → bytes) and evicts
        // by the same recency rule.
        let mut run = PropRunner::new("lru_accounting_matches_naive_model", 200);
        run.run(|rng| {
            let budget = gen::int_in(rng, 50, 400) as usize;
            let mut lru: LruBytes<i64, i64> = LruBytes::new(budget);
            for _ in 0..gen::int_in(rng, 1, 60) {
                match gen::int_in(rng, 0, 3) {
                    0 | 1 => {
                        let k = gen::int_in(rng, 0, 12);
                        let b = gen::int_in(rng, 1, 120) as usize;
                        lru.insert(k, k, b);
                    }
                    2 => {
                        let _ = lru.get(&gen::int_in(rng, 0, 12));
                    }
                    _ => {
                        let _ = lru.evict_all();
                    }
                }
                lru.audit();
            }
        });
    }

    #[test]
    fn lru_accounting_survives_concurrent_insert_evict() {
        // The operand caches wrap each shard in a Mutex; this drives one
        // shard from several threads (inserts, hits, forced evictions)
        // and audits the accounting afterwards — the shape of the
        // concurrency the serving tier actually exercises.
        let lru = Mutex::new(LruBytes::<u64, u64>::new(4096));
        std::thread::scope(|s| {
            for t in 0..4u64 {
                let lru = &lru;
                s.spawn(move || {
                    for i in 0..500u64 {
                        let k = (t * 7 + i) % 64;
                        let mut g = lru.lock().unwrap();
                        match i % 5 {
                            0 => {
                                let _ = g.evict_all();
                            }
                            1 | 2 => g.insert(k, k, 64 + (k as usize % 128)),
                            _ => {
                                let _ = g.get(&k);
                            }
                        }
                        g.audit();
                    }
                });
            }
        });
        let g = lru.lock().unwrap();
        g.audit();
        let (hits, misses, evictions) = g.stats();
        assert!(hits + misses > 0);
        assert!(evictions > 0, "forced evictions must have occurred");
    }
}
