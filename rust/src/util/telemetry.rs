//! Flight recorder: zero-dependency structured tracing plus the
//! unified metrics registry (the repo's observability subsystem; same
//! in-tree discipline as `util::error`).
//!
//! # Span tracer
//!
//! Hot paths mark themselves with RAII guards:
//!
//! ```ignore
//! let _span = telemetry::span(Phase::NttForward);
//! ```
//!
//! When tracing is **disabled** (the default) that call is a single
//! relaxed atomic load returning `None` — no timestamp, no allocation,
//! no buffer write (counter-asserted by the test-suite). When enabled,
//! completed spans land in a per-thread buffer that flushes to a global
//! sink in [`FLUSH_AT`]-sized chunks (and on thread exit), so the sink
//! lock is touched once per chunk, never per span.
//!
//! Activation paths:
//! - `ELS_TRACE=<path>` — process-wide, read once by binary entry
//!   points via [`init_from_env`]; [`finish_env_trace`] writes the
//!   Chrome trace-event JSON there (open in `chrome://tracing` or
//!   Perfetto).
//! - [`Capture::begin`] — programmatic and exclusive, for tests and
//!   embedders. Tests must never mutate `ELS_TRACE` (setenv racing
//!   getenv across test threads is UB on glibc); this is the sanctioned
//!   in-process switch.
//!
//! # Metrics registry
//!
//! [`MetricsSnapshot`] gathers every counter the stack already keeps —
//! per-ring transforms/relins/scale-rounds/rotations, engine
//! ct/plain-mul counts, pool dispatches, trace totals, optionally the
//! coordinator's job counters + latency histogram — into one
//! diffable, deterministically-serialised JSON document. `fit`/
//! `predict` wrap it as a per-fit "op budget report"; the coordinator
//! wire protocol and the `els metrics` CLI expose it live.

use std::cell::RefCell;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Mutex, MutexGuard, OnceLock};
use std::time::Instant;

use crate::util::json::Json;

/// Every instrumented phase of the stack, bottom (ring transforms) to
/// top (serving). Single source of truth for trace names — mirrored by
/// `python/tools/trace_check.py`'s known-phase set.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Phase {
    /// Forward NTT of one polynomial (all residue planes).
    NttForward,
    /// Inverse NTT of one polynomial.
    NttInverse,
    /// Full-RNS fast base extension `Q → B ∪ {m_sk}`.
    BaseExtend,
    /// The `⌊t·v/q⌉` scale-and-round (either multiply backend).
    ScaleRound,
    /// Shenoy–Kumaresan conversion back to the Q basis.
    ShenoyConvert,
    /// Per-limb RNS gadget relinearisation of one degree-2 ciphertext.
    Relinearise,
    /// Galois automorphism + gadget key switch (rotations).
    GaloisKeySwitch,
    /// One `util::pool` worker lane executing its chunk.
    PoolWorker,
    /// One encrypted descent iteration (GD/VWT/NAG/CD, packed or not).
    DescentIteration,
    /// Coordinator admission check of one submitted job.
    JobAdmit,
    /// Job waiting for a concurrency slot (queue time).
    JobQueue,
    /// Job running its encrypted fit.
    JobExecute,
    /// Batcher dispatching one coalesced group batch to the backend.
    BatchDispatch,
    /// Service handling one wire request (decode → execute → reply).
    ServeReply,
}

impl Phase {
    /// Every phase, in pipeline order.
    pub const ALL: [Phase; 14] = [
        Phase::NttForward,
        Phase::NttInverse,
        Phase::BaseExtend,
        Phase::ScaleRound,
        Phase::ShenoyConvert,
        Phase::Relinearise,
        Phase::GaloisKeySwitch,
        Phase::PoolWorker,
        Phase::DescentIteration,
        Phase::JobAdmit,
        Phase::JobQueue,
        Phase::JobExecute,
        Phase::BatchDispatch,
        Phase::ServeReply,
    ];

    /// Stable snake_case trace name.
    pub fn name(self) -> &'static str {
        match self {
            Phase::NttForward => "ntt_forward",
            Phase::NttInverse => "ntt_inverse",
            Phase::BaseExtend => "base_extend",
            Phase::ScaleRound => "scale_round",
            Phase::ShenoyConvert => "shenoy_convert",
            Phase::Relinearise => "relinearise",
            Phase::GaloisKeySwitch => "galois_keyswitch",
            Phase::PoolWorker => "pool_worker",
            Phase::DescentIteration => "descent_iteration",
            Phase::JobAdmit => "job_admit",
            Phase::JobQueue => "job_queue",
            Phase::JobExecute => "job_execute",
            Phase::BatchDispatch => "batch_dispatch",
            Phase::ServeReply => "serve_reply",
        }
    }

    /// Chrome trace category (one lane of the stack).
    pub fn category(self) -> &'static str {
        match self {
            Phase::NttForward | Phase::NttInverse => "ring",
            Phase::BaseExtend
            | Phase::ScaleRound
            | Phase::ShenoyConvert
            | Phase::Relinearise
            | Phase::GaloisKeySwitch => "mul",
            Phase::PoolWorker => "pool",
            Phase::DescentIteration => "els",
            Phase::JobAdmit
            | Phase::JobQueue
            | Phase::JobExecute
            | Phase::BatchDispatch
            | Phase::ServeReply => "coordinator",
        }
    }
}

/// One completed span.
#[derive(Clone, Debug)]
pub struct Event {
    pub phase: Phase,
    /// Microseconds since the process trace epoch.
    pub start_us: u64,
    pub dur_us: u64,
    /// Dense per-thread id (assigned in first-record order).
    pub tid: u64,
}

/// The one word the hot path reads: tracing on/off.
static ENABLED: AtomicBool = AtomicBool::new(false);
/// Spans buffered since process start (monotone — with tracing
/// disabled this must not move; the zero-write acceptance hook).
static RECORDED: AtomicU64 = AtomicU64::new(0);
/// Spans discarded because the sink hit [`MAX_EVENTS`].
static DROPPED: AtomicU64 = AtomicU64::new(0);
static NEXT_TID: AtomicU64 = AtomicU64::new(1);

/// Global sink the per-thread buffers flush into.
static SINK: Mutex<Vec<Event>> = Mutex::new(Vec::new());

/// Serialises capture sessions (and lets the disabled-path test hold
/// off a concurrent capture) without ever touching the environment.
static SESSION: Mutex<()> = Mutex::new(());

/// Hard cap on buffered spans: a runaway trace degrades to counting
/// drops instead of exhausting memory.
const MAX_EVENTS: usize = 1 << 20;
/// Per-thread chunk size between sink flushes.
const FLUSH_AT: usize = 256;

fn epoch() -> Instant {
    static EPOCH: OnceLock<Instant> = OnceLock::new();
    *EPOCH.get_or_init(Instant::now)
}

struct LocalBuf {
    tid: u64,
    events: Vec<Event>,
}

impl LocalBuf {
    fn new() -> LocalBuf {
        LocalBuf {
            tid: NEXT_TID.fetch_add(1, Ordering::Relaxed),
            events: Vec::with_capacity(FLUSH_AT),
        }
    }

    fn flush(&mut self) {
        if self.events.is_empty() {
            return;
        }
        let mut sink = SINK.lock().unwrap_or_else(|e| e.into_inner());
        let room = MAX_EVENTS.saturating_sub(sink.len());
        let take = self.events.len().min(room);
        let dropped = self.events.len() - take;
        sink.extend(self.events.drain(..take));
        self.events.clear();
        if dropped > 0 {
            DROPPED.fetch_add(dropped as u64, Ordering::Relaxed);
        }
    }
}

impl Drop for LocalBuf {
    // Thread exit: whatever the lane buffered reaches the sink (every
    // `util::pool` fan-out joins its workers, so their spans are
    // visible by the time the dispatching call returns).
    fn drop(&mut self) {
        self.flush();
    }
}

thread_local! {
    static LOCAL: RefCell<LocalBuf> = RefCell::new(LocalBuf::new());
}

/// RAII span guard: records its phase + wall duration when dropped.
pub struct SpanGuard {
    phase: Phase,
    start: Instant,
}

impl Drop for SpanGuard {
    fn drop(&mut self) {
        // duration_since saturates to zero for spans that started
        // before the lazily-initialised epoch.
        let start_us = self.start.duration_since(epoch()).as_micros() as u64;
        let dur_us = self.start.elapsed().as_micros() as u64;
        RECORDED.fetch_add(1, Ordering::Relaxed);
        // TLS may already be torn down during thread exit; losing that
        // span beats panicking inside a destructor.
        let _ = LOCAL.try_with(|b| {
            let mut b = b.borrow_mut();
            let tid = b.tid;
            b.events.push(Event { phase: self.phase, start_us, dur_us, tid });
            if b.events.len() >= FLUSH_AT {
                b.flush();
            }
        });
    }
}

/// Open a span for `phase`. Disabled fast path: one relaxed load and
/// `None` — no clock read, no allocation, no buffer write.
#[inline]
pub fn span(phase: Phase) -> Option<SpanGuard> {
    if !ENABLED.load(Ordering::Relaxed) {
        return None;
    }
    epoch();
    Some(SpanGuard { phase, start: Instant::now() })
}

pub fn enabled() -> bool {
    ENABLED.load(Ordering::Relaxed)
}

/// Spans buffered since process start (monotone).
pub fn recorded_count() -> u64 {
    RECORDED.load(Ordering::Relaxed)
}

/// Spans dropped at the [`MAX_EVENTS`] cap since process start.
pub fn dropped_count() -> u64 {
    DROPPED.load(Ordering::Relaxed)
}

fn drain() -> Vec<Event> {
    let _ = LOCAL.try_with(|b| b.borrow_mut().flush());
    let mut sink = SINK.lock().unwrap_or_else(|e| e.into_inner());
    std::mem::take(&mut *sink)
}

/// Exclusive programmatic capture session — the sanctioned in-process
/// switch for tests and embedders (never mutate `ELS_TRACE` in-process).
pub struct Capture {
    _session: MutexGuard<'static, ()>,
}

impl Capture {
    /// Enable tracing, discarding stale spans still in flight from
    /// earlier sessions. Exclusive: concurrent captures serialise.
    pub fn begin() -> Capture {
        let session = SESSION.lock().unwrap_or_else(|e| e.into_inner());
        epoch();
        drain();
        ENABLED.store(true, Ordering::Relaxed);
        Capture { _session: session }
    }

    /// Disable tracing and return everything captured. The calling
    /// thread's buffer is flushed explicitly; pool workers flushed when
    /// they exited (fan-outs join before returning).
    pub fn finish(self) -> Trace {
        ENABLED.store(false, Ordering::Relaxed);
        Trace { events: drain() }
    }
}

/// Hold to keep tracing *disabled* (no capture can begin concurrently)
/// — the disabled-hot-path acceptance test runs under this.
pub fn exclusion() -> MutexGuard<'static, ()> {
    SESSION.lock().unwrap_or_else(|e| e.into_inner())
}

static ENV_PATH: OnceLock<Option<String>> = OnceLock::new();

/// Process-level activation: `ELS_TRACE=<path>` turns the recorder on
/// for the whole run. Only binary entry points call this — library
/// code and tests go through [`Capture`].
pub fn init_from_env() {
    let path = ENV_PATH.get_or_init(|| match std::env::var("ELS_TRACE") {
        Ok(p) if !p.is_empty() => Some(p),
        _ => None,
    });
    if path.is_some() {
        epoch();
        ENABLED.store(true, Ordering::Relaxed);
    }
}

/// Flush and write the `ELS_TRACE` Chrome trace file, if
/// [`init_from_env`] activated one. Returns the path written.
pub fn finish_env_trace() -> Option<String> {
    let path = ENV_PATH.get().and_then(|p| p.clone())?;
    ENABLED.store(false, Ordering::Relaxed);
    let trace = Trace { events: drain() };
    match std::fs::write(&path, trace.to_chrome_json().to_string_json()) {
        Ok(()) => Some(path),
        Err(e) => {
            eprintln!("[els] failed to write trace {path}: {e}");
            None
        }
    }
}

/// A completed capture, exportable as Chrome trace-event JSON
/// (loadable in `chrome://tracing` or Perfetto as-is).
pub struct Trace {
    pub events: Vec<Event>,
}

impl Trace {
    pub fn phase_count(&self, phase: Phase) -> usize {
        self.events.iter().filter(|e| e.phase == phase).count()
    }

    pub fn to_chrome_json(&self) -> Json {
        let events: Vec<Json> = self
            .events
            .iter()
            .map(|e| {
                Json::obj(vec![
                    ("name", Json::str(e.phase.name())),
                    ("cat", Json::str(e.phase.category())),
                    ("ph", Json::str("X")),
                    ("ts", Json::Num(e.start_us as f64)),
                    ("dur", Json::Num(e.dur_us as f64)),
                    ("pid", Json::Num(1.0)),
                    ("tid", Json::Num(e.tid as f64)),
                ])
            })
            .collect();
        Json::obj(vec![
            ("traceEvents", Json::Arr(events)),
            ("displayTimeUnit", Json::str("ms")),
            (
                "otherData",
                Json::obj(vec![
                    ("recorded", Json::Num(recorded_count() as f64)),
                    ("dropped", Json::Num(dropped_count() as f64)),
                ]),
            ),
        ])
    }
}

/// Counters of one [`RingContext`](crate::math::poly::RingContext).
#[derive(Clone, Debug, PartialEq)]
pub struct RingCounters {
    pub label: String,
    pub transforms: u64,
    pub relins: u64,
    pub scale_rounds: u64,
    pub rotations: u64,
}

/// Engine-level op counts (`runtime::backend::OpStats`).
#[derive(Clone, Debug, PartialEq, Default)]
pub struct EngineCounters {
    pub ct_muls: u64,
    pub plain_muls: u64,
    pub adds: u64,
    pub batches: u64,
}

/// Process-wide `util::pool` counters. Excluded from cross-worker
/// bit-identity: serial call sites legally skip the pool entirely.
#[derive(Clone, Debug, PartialEq, Default)]
pub struct PoolCounters {
    pub dispatches: u64,
    pub tasks: u64,
}

#[derive(Clone, Debug, PartialEq, Default)]
pub struct TraceCounters {
    pub enabled: bool,
    pub recorded: u64,
    pub dropped: u64,
}

/// Fault-injection registry counters (`util::faults`). `checked` is
/// probe traffic, `injected` the faults actually fired; both stay 0
/// (and `enabled` false) outside chaos runs — the counter-asserted
/// no-op contract.
#[derive(Clone, Debug, PartialEq, Default)]
pub struct FaultCounters {
    pub enabled: bool,
    pub checked: u64,
    pub injected: u64,
}

/// Write-ahead journal counters (`coordinator::journal`). Process-wide
/// like the pool/trace sections; all zero when no journal-backed
/// coordinator has run — the durability tier's no-op contract.
#[derive(Clone, Debug, PartialEq, Default)]
pub struct JournalCounters {
    pub records_written: u64,
    pub records_replayed: u64,
    pub records_truncated: u64,
    pub checkpoints_taken: u64,
    pub checkpoints_resumed: u64,
    pub append_errors: u64,
}

/// Serving-tier counters (present when snapshotting a coordinator).
#[derive(Clone, Debug, PartialEq)]
pub struct CoordinatorCounters {
    pub jobs_submitted: u64,
    pub jobs_completed: u64,
    pub jobs_rejected: u64,
    pub jobs_failed: u64,
    /// Deadline expiries (queued past deadline, or infeasible at submit).
    pub jobs_expired: u64,
    /// Bounded-queue rejections under load.
    pub jobs_overloaded: u64,
    /// Queued jobs bounced by a drain.
    pub jobs_cancelled: u64,
    /// Idempotent-token resubmissions answered without a second fit.
    pub jobs_deduped: u64,
    /// Self-describing latency histogram (bounds + counts + quantiles).
    pub latency: Json,
}

/// One unified, diffable snapshot of every counter in the stack.
///
/// Determinism contract (test-asserted): for a fixed workload the
/// `rings` section is bit-identical across `ELS_POOL_WORKERS` counts,
/// and the `engine` section additionally across mul backends (ring
/// transform counts legitimately differ between backends — they work
/// in different bases). `pool`/`trace` are process-global and excluded.
#[derive(Clone, Debug, PartialEq)]
pub struct MetricsSnapshot {
    /// Per-ring pipeline counters (labels `q`, `ext`, `big`).
    pub rings: Vec<RingCounters>,
    pub engine: EngineCounters,
    pub pool: PoolCounters,
    pub trace: TraceCounters,
    pub faults: FaultCounters,
    pub journal: JournalCounters,
    pub coordinator: Option<CoordinatorCounters>,
}

impl MetricsSnapshot {
    /// Snapshot every counter reachable from a context + engine stats.
    pub fn capture(
        ctx: &crate::fhe::FvContext,
        stats: &crate::runtime::backend::OpStats,
    ) -> MetricsSnapshot {
        let ring = |label: &str, r: &crate::math::poly::RingContext| RingCounters {
            label: label.to_string(),
            transforms: r.transform_count(),
            relins: r.relin_count(),
            scale_rounds: r.scale_round_count(),
            rotations: r.rotation_count(),
        };
        let (ct_muls, plain_muls, adds, batches) = stats.snapshot();
        MetricsSnapshot {
            rings: vec![
                ring("q", &ctx.ring_q),
                ring("ext", &ctx.ring_ext),
                ring("big", &ctx.ring_big),
            ],
            engine: EngineCounters { ct_muls, plain_muls, adds, batches },
            pool: PoolCounters {
                dispatches: crate::util::pool::dispatch_count(),
                tasks: crate::util::pool::dispatched_task_count(),
            },
            trace: TraceCounters {
                enabled: enabled(),
                recorded: recorded_count(),
                dropped: dropped_count(),
            },
            faults: FaultCounters {
                enabled: crate::util::faults::enabled(),
                checked: crate::util::faults::checked_total(),
                injected: crate::util::faults::injected_total(),
            },
            journal: JournalCounters {
                records_written: crate::coordinator::journal::records_written(),
                records_replayed: crate::coordinator::journal::records_replayed(),
                records_truncated: crate::coordinator::journal::records_truncated(),
                checkpoints_taken: crate::coordinator::journal::checkpoints_taken(),
                checkpoints_resumed: crate::coordinator::journal::checkpoints_resumed(),
                append_errors: crate::coordinator::journal::append_errors(),
            },
            coordinator: None,
        }
    }

    /// Attach the serving tier's counters.
    pub fn with_coordinator(
        mut self,
        m: &crate::coordinator::metrics::Metrics,
    ) -> MetricsSnapshot {
        self.coordinator = Some(CoordinatorCounters {
            jobs_submitted: m.jobs_submitted.load(Ordering::Relaxed),
            jobs_completed: m.jobs_completed.load(Ordering::Relaxed),
            jobs_rejected: m.jobs_rejected.load(Ordering::Relaxed),
            jobs_failed: m.jobs_failed.load(Ordering::Relaxed),
            jobs_expired: m.jobs_expired.load(Ordering::Relaxed),
            jobs_overloaded: m.jobs_overloaded.load(Ordering::Relaxed),
            jobs_cancelled: m.jobs_cancelled.load(Ordering::Relaxed),
            jobs_deduped: m.jobs_deduped.load(Ordering::Relaxed),
            latency: m.job_latency.to_json(),
        });
        self
    }

    /// Counter delta `self − earlier` (saturating). The trace `enabled`
    /// flag, the latency histogram and missing-in-`earlier` sections
    /// come from `self` unchanged.
    pub fn diff(&self, earlier: &MetricsSnapshot) -> MetricsSnapshot {
        let rings = self
            .rings
            .iter()
            .map(|r| {
                let base = earlier.rings.iter().find(|e| e.label == r.label);
                match base {
                    Some(b) => RingCounters {
                        label: r.label.clone(),
                        transforms: r.transforms.saturating_sub(b.transforms),
                        relins: r.relins.saturating_sub(b.relins),
                        scale_rounds: r.scale_rounds.saturating_sub(b.scale_rounds),
                        rotations: r.rotations.saturating_sub(b.rotations),
                    },
                    None => r.clone(),
                }
            })
            .collect();
        let coordinator = match (&self.coordinator, &earlier.coordinator) {
            (Some(c), Some(b)) => Some(CoordinatorCounters {
                jobs_submitted: c.jobs_submitted.saturating_sub(b.jobs_submitted),
                jobs_completed: c.jobs_completed.saturating_sub(b.jobs_completed),
                jobs_rejected: c.jobs_rejected.saturating_sub(b.jobs_rejected),
                jobs_failed: c.jobs_failed.saturating_sub(b.jobs_failed),
                jobs_expired: c.jobs_expired.saturating_sub(b.jobs_expired),
                jobs_overloaded: c.jobs_overloaded.saturating_sub(b.jobs_overloaded),
                jobs_cancelled: c.jobs_cancelled.saturating_sub(b.jobs_cancelled),
                jobs_deduped: c.jobs_deduped.saturating_sub(b.jobs_deduped),
                latency: c.latency.clone(),
            }),
            (c, _) => c.clone(),
        };
        MetricsSnapshot {
            rings,
            engine: EngineCounters {
                ct_muls: self.engine.ct_muls.saturating_sub(earlier.engine.ct_muls),
                plain_muls: self.engine.plain_muls.saturating_sub(earlier.engine.plain_muls),
                adds: self.engine.adds.saturating_sub(earlier.engine.adds),
                batches: self.engine.batches.saturating_sub(earlier.engine.batches),
            },
            pool: PoolCounters {
                dispatches: self.pool.dispatches.saturating_sub(earlier.pool.dispatches),
                tasks: self.pool.tasks.saturating_sub(earlier.pool.tasks),
            },
            trace: TraceCounters {
                enabled: self.trace.enabled,
                recorded: self.trace.recorded.saturating_sub(earlier.trace.recorded),
                dropped: self.trace.dropped.saturating_sub(earlier.trace.dropped),
            },
            faults: FaultCounters {
                enabled: self.faults.enabled,
                checked: self.faults.checked.saturating_sub(earlier.faults.checked),
                injected: self.faults.injected.saturating_sub(earlier.faults.injected),
            },
            journal: JournalCounters {
                records_written: self
                    .journal
                    .records_written
                    .saturating_sub(earlier.journal.records_written),
                records_replayed: self
                    .journal
                    .records_replayed
                    .saturating_sub(earlier.journal.records_replayed),
                records_truncated: self
                    .journal
                    .records_truncated
                    .saturating_sub(earlier.journal.records_truncated),
                checkpoints_taken: self
                    .journal
                    .checkpoints_taken
                    .saturating_sub(earlier.journal.checkpoints_taken),
                checkpoints_resumed: self
                    .journal
                    .checkpoints_resumed
                    .saturating_sub(earlier.journal.checkpoints_resumed),
                append_errors: self
                    .journal
                    .append_errors
                    .saturating_sub(earlier.journal.append_errors),
            },
            coordinator,
        }
    }

    /// Deterministic JSON document (BTreeMap key order throughout).
    pub fn to_json(&self) -> Json {
        let mut out = vec![
            ("schema", Json::str("els-metrics-v1")),
            ("rings", self.rings_json()),
            ("engine", self.engine_json()),
            (
                "pool",
                Json::obj(vec![
                    ("dispatches", Json::Num(self.pool.dispatches as f64)),
                    ("tasks", Json::Num(self.pool.tasks as f64)),
                ]),
            ),
            (
                "trace",
                Json::obj(vec![
                    ("enabled", Json::Bool(self.trace.enabled)),
                    ("recorded", Json::Num(self.trace.recorded as f64)),
                    ("dropped", Json::Num(self.trace.dropped as f64)),
                ]),
            ),
            (
                "faults",
                Json::obj(vec![
                    ("enabled", Json::Bool(self.faults.enabled)),
                    ("checked", Json::Num(self.faults.checked as f64)),
                    ("injected", Json::Num(self.faults.injected as f64)),
                ]),
            ),
            (
                "journal",
                Json::obj(vec![
                    ("records_written", Json::Num(self.journal.records_written as f64)),
                    ("records_replayed", Json::Num(self.journal.records_replayed as f64)),
                    ("records_truncated", Json::Num(self.journal.records_truncated as f64)),
                    ("checkpoints_taken", Json::Num(self.journal.checkpoints_taken as f64)),
                    (
                        "checkpoints_resumed",
                        Json::Num(self.journal.checkpoints_resumed as f64),
                    ),
                    ("append_errors", Json::Num(self.journal.append_errors as f64)),
                ]),
            ),
        ];
        if let Some(c) = &self.coordinator {
            out.push((
                "coordinator",
                Json::obj(vec![
                    ("jobs_submitted", Json::Num(c.jobs_submitted as f64)),
                    ("jobs_completed", Json::Num(c.jobs_completed as f64)),
                    ("jobs_rejected", Json::Num(c.jobs_rejected as f64)),
                    ("jobs_failed", Json::Num(c.jobs_failed as f64)),
                    ("jobs_expired", Json::Num(c.jobs_expired as f64)),
                    ("jobs_overloaded", Json::Num(c.jobs_overloaded as f64)),
                    ("jobs_cancelled", Json::Num(c.jobs_cancelled as f64)),
                    ("jobs_deduped", Json::Num(c.jobs_deduped as f64)),
                    ("latency", c.latency.clone()),
                ]),
            ));
        }
        Json::obj(out)
    }

    /// The `rings` section alone (the cross-worker identity surface).
    pub fn rings_json(&self) -> Json {
        Json::obj(
            self.rings
                .iter()
                .map(|r| {
                    (
                        r.label.as_str(),
                        Json::obj(vec![
                            ("transforms", Json::Num(r.transforms as f64)),
                            ("relins", Json::Num(r.relins as f64)),
                            ("scale_rounds", Json::Num(r.scale_rounds as f64)),
                            ("rotations", Json::Num(r.rotations as f64)),
                        ]),
                    )
                })
                .collect(),
        )
    }

    /// The `engine` section alone (the cross-backend identity surface).
    pub fn engine_json(&self) -> Json {
        Json::obj(vec![
            ("ct_muls", Json::Num(self.engine.ct_muls as f64)),
            ("plain_muls", Json::Num(self.engine.plain_muls as f64)),
            ("adds", Json::Num(self.engine.adds as f64)),
            ("batches", Json::Num(self.engine.batches as f64)),
        ])
    }
}

#[cfg(test)]
mod tests {
    use std::sync::Arc;

    use super::*;
    use crate::fhe::encoding::encode_int;
    use crate::fhe::keys::keygen;
    use crate::fhe::params::{FvParams, MulBackend};
    use crate::fhe::rng::ChaChaRng;
    use crate::fhe::{Ciphertext, FvContext};
    use crate::runtime::backend::{HeEngine, NativeEngine};

    fn setup(seed: u64) -> (Arc<FvContext>, crate::fhe::KeySet, Vec<(Ciphertext, Ciphertext)>) {
        let ctx = FvContext::new(FvParams::custom(256, 3, 24));
        let mut rng = ChaChaRng::from_seed(seed);
        let keys = keygen(&ctx, &mut rng);
        let pairs: Vec<(Ciphertext, Ciphertext)> = (1..=4i64)
            .map(|k| {
                (
                    ctx.encrypt(&encode_int(k, ctx.d()), &keys.pk, &mut rng),
                    ctx.encrypt(&encode_int(k + 1, ctx.d()), &keys.pk, &mut rng),
                )
            })
            .collect();
        (ctx, keys, pairs)
    }

    #[test]
    fn capture_exports_wellformed_chrome_trace() {
        let cap = Capture::begin();
        {
            let _a = span(Phase::DescentIteration);
            let _b = span(Phase::NttForward);
        }
        let worker = std::thread::spawn(|| {
            let _s = span(Phase::PoolWorker);
        });
        worker.join().unwrap();
        let trace = cap.finish();
        assert!(trace.phase_count(Phase::DescentIteration) >= 1);
        assert!(trace.phase_count(Phase::NttForward) >= 1);
        assert!(trace.phase_count(Phase::PoolWorker) >= 1);
        let json = trace.to_chrome_json();
        // Round-trips through the in-tree parser.
        let text = json.to_string_json();
        let back = Json::parse(&text).unwrap();
        let events = back.get("traceEvents").unwrap().as_arr().unwrap();
        assert!(!events.is_empty());
        for e in events {
            assert_eq!(e.get("ph").unwrap().as_str(), Some("X"));
            assert!(e.get("ts").unwrap().as_u64().is_some());
            assert!(e.get("dur").unwrap().as_u64().is_some());
            let name = e.get("name").unwrap().as_str().unwrap();
            assert!(Phase::ALL.iter().any(|p| p.name() == name), "unknown phase {name}");
        }
        // Spans recorded on a thread that died reached the sink via the
        // TLS destructor; tids are distinct lanes.
        let tids: std::collections::BTreeSet<u64> = trace.events.iter().map(|e| e.tid).collect();
        assert!(tids.len() >= 2, "worker lane must have its own tid");
    }

    #[test]
    fn disabled_hot_path_records_nothing() {
        // Hold the session lock so no concurrent capture enables
        // tracing mid-assertion (tests share the process).
        let _excl = exclusion();
        assert!(!enabled());
        let before = recorded_count();
        assert!(span(Phase::NttForward).is_none());
        assert!(span(Phase::Relinearise).is_none());
        // Drive the real instrumented hot path: a full ct×ct multiply
        // exercises NTT, base-extension/CRT, scale-round and relin
        // span sites. The recorder must not see a single event.
        let (ctx, keys, pairs) = setup(811);
        let engine = NativeEngine::new(ctx.clone(), Arc::new(keys.rk.clone()));
        let refs: Vec<(&Ciphertext, &Ciphertext)> = pairs.iter().map(|(a, b)| (a, b)).collect();
        let _ = engine.mul_pairs(&refs);
        assert_eq!(recorded_count(), before, "disabled tracing wrote to the ring buffer");
    }

    #[test]
    fn enabled_capture_sees_the_multiply_pipeline() {
        let (ctx, keys, pairs) = setup(812);
        let engine = NativeEngine::new(ctx.clone(), Arc::new(keys.rk.clone()));
        let refs: Vec<(&Ciphertext, &Ciphertext)> = pairs.iter().map(|(a, b)| (a, b)).collect();
        let cap = Capture::begin();
        let _ = engine.mul_pairs(&refs);
        let trace = cap.finish();
        assert!(trace.phase_count(Phase::NttForward) >= 1, "no forward NTT spans");
        assert!(trace.phase_count(Phase::Relinearise) >= pairs.len());
        assert!(trace.phase_count(Phase::ScaleRound) >= pairs.len());
        if ctx.params.mul_backend == MulBackend::FullRns {
            assert!(trace.phase_count(Phase::BaseExtend) >= 1);
            assert!(trace.phase_count(Phase::ShenoyConvert) >= 1);
        }
    }

    #[test]
    fn snapshot_diff_is_deterministic_and_sectioned() {
        let (ctx, keys, pairs) = setup(813);
        let engine = NativeEngine::new(ctx.clone(), Arc::new(keys.rk.clone()));
        let refs: Vec<(&Ciphertext, &Ciphertext)> = pairs.iter().map(|(a, b)| (a, b)).collect();
        let before = MetricsSnapshot::capture(&ctx, engine.stats());
        let _ = engine.mul_pairs(&refs);
        let after = MetricsSnapshot::capture(&ctx, engine.stats());
        let diff = after.diff(&before);
        assert_eq!(diff.engine.ct_muls, pairs.len() as u64);
        assert!(diff.rings[0].relins >= pairs.len() as u64);
        // Serialisation is deterministic: same snapshot, same bytes.
        assert_eq!(diff.to_json().to_string_json(), diff.to_json().to_string_json());
        let parsed = Json::parse(&diff.to_json().to_string_json()).unwrap();
        assert_eq!(parsed.get("schema").unwrap().as_str(), Some("els-metrics-v1"));
        assert!(parsed.get("rings").unwrap().get("q").is_some());
        let journal = parsed.get("journal").unwrap();
        assert!(journal.get("records_written").unwrap().as_u64().is_some());
        assert!(journal.get("checkpoints_resumed").unwrap().as_u64().is_some());
    }

    #[test]
    fn snapshot_diff_identical_across_worker_counts_and_backends() {
        // rings+engine sections are the determinism surface: same
        // workload → bit-identical diffs for workers 1/2/4 (same
        // backend), and bit-identical engine sections across backends.
        // One key/pair set serves every run (keys live in the Q basis;
        // ciphertexts are plain residue data — the parity-test idiom).
        let (ctx, keys, pairs) = setup(814);
        let rk = Arc::new(keys.rk.clone());
        let run = |ctx: &Arc<FvContext>, workers: usize| {
            let engine =
                NativeEngine::new(ctx.clone(), rk.clone()).with_pool_workers(workers);
            let refs: Vec<(&Ciphertext, &Ciphertext)> =
                pairs.iter().map(|(a, b)| (a, b)).collect();
            let before = MetricsSnapshot::capture(ctx, engine.stats());
            let _ = engine.mul_pairs(&refs);
            let after = MetricsSnapshot::capture(ctx, engine.stats());
            after.diff(&before)
        };
        let d1 = run(&ctx, 1);
        let d2 = run(&ctx, 2);
        let d4 = run(&ctx, 4);
        assert_eq!(
            d1.rings_json().to_string_json(),
            d2.rings_json().to_string_json(),
            "ring counters depend on worker count"
        );
        assert_eq!(d2.rings_json().to_string_json(), d4.rings_json().to_string_json());
        assert_eq!(d1.engine_json().to_string_json(), d4.engine_json().to_string_json());
        // Cross-backend: engine section identical (ring bases differ by
        // construction — rns works in B∪m_sk, the oracle in Q∪E).
        let ctx_big = ctx.clone().with_backend(MulBackend::ExactBigint);
        let ctx_rns = ctx_big.clone().with_backend(MulBackend::FullRns);
        let db = run(&ctx_big, 2);
        let dr = run(&ctx_rns, 2);
        assert_eq!(db.engine_json().to_string_json(), dr.engine_json().to_string_json());
    }
}
