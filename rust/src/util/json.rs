//! Minimal JSON (no serde offline): a recursive-descent parser and a
//! writer, covering the subset the project needs — `rns_meta.json`,
//! the coordinator wire protocol, and figure metadata.

use std::collections::BTreeMap;
use std::fmt::Write as _;

use crate::util::error::{anyhow, bail, Result};

/// A JSON value.
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn parse(s: &str) -> Result<Json> {
        let mut p = Parser { b: s.as_bytes(), i: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.i != p.b.len() {
            bail!("trailing characters at offset {}", p.i);
        }
        Ok(v)
    }

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    pub fn idx(&self, i: usize) -> Option<&Json> {
        match self {
            Json::Arr(a) => a.get(i),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_u64(&self) -> Option<u64> {
        self.as_f64().filter(|v| *v >= 0.0 && v.fract() == 0.0).map(|v| v as u64)
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_u64().map(|v| v as usize)
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    /// Required-field accessors with contextual errors.
    pub fn req(&self, key: &str) -> Result<&Json> {
        self.get(key).ok_or_else(|| anyhow!("missing field '{key}'"))
    }

    pub fn to_string_json(&self) -> String {
        let mut out = String::new();
        write_value(self, &mut out);
        out
    }

    /// Builder helpers.
    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    pub fn arr_u64(v: &[u64]) -> Json {
        Json::Arr(v.iter().map(|&x| Json::Num(x as f64)).collect())
    }

    pub fn arr_f64(v: &[f64]) -> Json {
        Json::Arr(v.iter().map(|&x| Json::Num(x)).collect())
    }

    pub fn str(s: &str) -> Json {
        Json::Str(s.to_string())
    }
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn skip_ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn peek(&self) -> Result<u8> {
        self.b.get(self.i).copied().ok_or_else(|| anyhow!("unexpected end of input"))
    }

    fn expect(&mut self, c: u8) -> Result<()> {
        if self.peek()? != c {
            bail!("expected '{}' at offset {}", c as char, self.i);
        }
        self.i += 1;
        Ok(())
    }

    fn value(&mut self) -> Result<Json> {
        self.skip_ws();
        match self.peek()? {
            b'{' => self.object(),
            b'[' => self.array(),
            b'"' => Ok(Json::Str(self.string()?)),
            b't' => self.literal("true", Json::Bool(true)),
            b'f' => self.literal("false", Json::Bool(false)),
            b'n' => self.literal("null", Json::Null),
            _ => self.number(),
        }
    }

    fn literal(&mut self, word: &str, v: Json) -> Result<Json> {
        if self.b[self.i..].starts_with(word.as_bytes()) {
            self.i += word.len();
            Ok(v)
        } else {
            bail!("invalid literal at offset {}", self.i);
        }
    }

    fn object(&mut self) -> Result<Json> {
        self.expect(b'{')?;
        let mut m = BTreeMap::new();
        self.skip_ws();
        if self.peek()? == b'}' {
            self.i += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.skip_ws();
            let k = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            let v = self.value()?;
            m.insert(k, v);
            self.skip_ws();
            match self.peek()? {
                b',' => self.i += 1,
                b'}' => {
                    self.i += 1;
                    return Ok(Json::Obj(m));
                }
                c => bail!("expected ',' or '}}', got '{}' at {}", c as char, self.i),
            }
        }
    }

    fn array(&mut self) -> Result<Json> {
        self.expect(b'[')?;
        let mut a = Vec::new();
        self.skip_ws();
        if self.peek()? == b']' {
            self.i += 1;
            return Ok(Json::Arr(a));
        }
        loop {
            a.push(self.value()?);
            self.skip_ws();
            match self.peek()? {
                b',' => self.i += 1,
                b']' => {
                    self.i += 1;
                    return Ok(Json::Arr(a));
                }
                c => bail!("expected ',' or ']', got '{}' at {}", c as char, self.i),
            }
        }
    }

    fn string(&mut self) -> Result<String> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            let c = self.peek()?;
            self.i += 1;
            match c {
                b'"' => return Ok(s),
                b'\\' => {
                    let e = self.peek()?;
                    self.i += 1;
                    match e {
                        b'"' => s.push('"'),
                        b'\\' => s.push('\\'),
                        b'/' => s.push('/'),
                        b'n' => s.push('\n'),
                        b't' => s.push('\t'),
                        b'r' => s.push('\r'),
                        b'b' => s.push('\u{8}'),
                        b'f' => s.push('\u{c}'),
                        b'u' => {
                            if self.i + 4 > self.b.len() {
                                bail!("bad \\u escape");
                            }
                            let hex = std::str::from_utf8(&self.b[self.i..self.i + 4])?;
                            let cp = u32::from_str_radix(hex, 16)?;
                            self.i += 4;
                            s.push(char::from_u32(cp).unwrap_or('\u{fffd}'));
                        }
                        _ => bail!("bad escape at {}", self.i),
                    }
                }
                _ => {
                    // Collect raw UTF-8 bytes.
                    let start = self.i - 1;
                    while self.i < self.b.len()
                        && self.b[self.i] != b'"'
                        && self.b[self.i] != b'\\'
                    {
                        self.i += 1;
                    }
                    s.push_str(std::str::from_utf8(&self.b[start..self.i])?);
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json> {
        let start = self.i;
        while self.i < self.b.len()
            && matches!(self.b[self.i], b'0'..=b'9' | b'-' | b'+' | b'.' | b'e' | b'E')
        {
            self.i += 1;
        }
        let s = std::str::from_utf8(&self.b[start..self.i])?;
        Ok(Json::Num(s.parse::<f64>().map_err(|_| anyhow!("bad number '{s}'"))?))
    }
}

fn write_value(v: &Json, out: &mut String) {
    match v {
        Json::Null => out.push_str("null"),
        Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
        Json::Num(n) => {
            if n.fract() == 0.0 && n.abs() < 9e15 {
                let _ = write!(out, "{}", *n as i64);
            } else {
                let _ = write!(out, "{n}");
            }
        }
        Json::Str(s) => {
            out.push('"');
            for c in s.chars() {
                match c {
                    '"' => out.push_str("\\\""),
                    '\\' => out.push_str("\\\\"),
                    '\n' => out.push_str("\\n"),
                    '\t' => out.push_str("\\t"),
                    '\r' => out.push_str("\\r"),
                    c if (c as u32) < 0x20 => {
                        let _ = write!(out, "\\u{:04x}", c as u32);
                    }
                    c => out.push(c),
                }
            }
            out.push('"');
        }
        Json::Arr(a) => {
            out.push('[');
            for (i, v) in a.iter().enumerate() {
                if i > 0 {
                    out.push(',');
                }
                write_value(v, out);
            }
            out.push(']');
        }
        Json::Obj(m) => {
            out.push('{');
            for (i, (k, v)) in m.iter().enumerate() {
                if i > 0 {
                    out.push(',');
                }
                write_value(&Json::Str(k.clone()), out);
                out.push(':');
                write_value(v, out);
            }
            out.push('}');
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse(" true ").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("-3.5e2").unwrap(), Json::Num(-350.0));
        assert_eq!(Json::parse("\"a\\nb\"").unwrap(), Json::Str("a\nb".into()));
    }

    #[test]
    fn parse_nested() {
        let j = Json::parse(r#"{"ops":[{"d":256,"primes":[1073479681,1073184769]}],"x":null}"#)
            .unwrap();
        let ops = j.get("ops").unwrap().as_arr().unwrap();
        assert_eq!(ops[0].get("d").unwrap().as_usize(), Some(256));
        assert_eq!(
            ops[0].get("primes").unwrap().idx(0).unwrap().as_u64(),
            Some(1_073_479_681)
        );
    }

    #[test]
    fn roundtrip() {
        let src = r#"{"a":[1,2.5,"x",true,null],"b":{"c":-7}}"#;
        let j = Json::parse(src).unwrap();
        let out = j.to_string_json();
        assert_eq!(Json::parse(&out).unwrap(), j);
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("12 34").is_err());
        assert!(Json::parse("'single'").is_err());
    }

    #[test]
    fn unicode_escape() {
        let j = Json::parse(r#""é""#).unwrap();
        assert_eq!(j, Json::Str("é".into()));
    }

    #[test]
    fn fuzz_roundtrip_property() {
        // Random nested JSON values must survive print -> parse exactly.
        use crate::fhe::rng::ChaChaRng;
        use crate::util::prop::PropRunner;
        fn gen_value(rng: &mut ChaChaRng, depth: usize) -> Json {
            match rng.uniform_below(if depth == 0 { 4 } else { 6 }) {
                0 => Json::Null,
                1 => Json::Bool(rng.next_u64() & 1 == 1),
                2 => Json::Num((rng.next_u64() % 1_000_000) as f64 - 500_000.0),
                3 => Json::Str(format!("s{}\n\"e", rng.next_u64() % 100)),
                4 => Json::Arr((0..rng.uniform_below(4)).map(|_| gen_value(rng, depth - 1)).collect()),
                _ => Json::Obj(
                    (0..rng.uniform_below(4))
                        .map(|i| (format!("k{i}"), gen_value(rng, depth - 1)))
                        .collect(),
                ),
            }
        }
        let mut run = PropRunner::new("json_fuzz", 300);
        run.run(|rng| {
            let v = gen_value(rng, 3);
            let text = v.to_string_json();
            assert_eq!(Json::parse(&text).unwrap(), v, "{text}");
        });
    }

    #[test]
    fn large_ints_preserved() {
        // primes < 2^30 are exactly representable in f64
        let j = Json::parse("1073479681").unwrap();
        assert_eq!(j.as_u64(), Some(1_073_479_681));
        assert_eq!(j.to_string_json(), "1073479681");
    }
}
