//! Offline-build substrates: errors, JSON, CLI, thread pool, prop/bench
//! harnesses, and the telemetry flight recorder.
pub mod bench;
pub mod cli;
pub mod error;
pub mod json;
pub mod pool;
pub mod prop;
pub mod telemetry;
