//! Offline-build substrates: errors, JSON, CLI, thread pool, prop/bench
//! harnesses, the telemetry flight recorder, deterministic fault
//! injection, and the byte-budgeted LRU.
pub mod bench;
pub mod cli;
pub mod error;
pub mod faults;
pub mod json;
pub mod lru;
pub mod pool;
pub mod prop;
pub mod telemetry;
