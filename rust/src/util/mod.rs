//! Offline-build substrates: errors, JSON, CLI, thread pool, prop/bench
//! harnesses.
pub mod bench;
pub mod cli;
pub mod error;
pub mod json;
pub mod pool;
pub mod prop;
