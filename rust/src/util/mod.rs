//! Offline-build substrates: JSON, CLI, thread pool, prop/bench harnesses.
pub mod bench;
pub mod cli;
pub mod json;
pub mod pool;
pub mod prop;
