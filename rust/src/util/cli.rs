//! Minimal command-line parsing (no clap offline): subcommand plus
//! `--key value` / `--flag` options.

use std::collections::BTreeMap;

use crate::util::error::{anyhow, bail, Result};

/// Parsed command line.
#[derive(Debug, Default)]
pub struct Args {
    pub command: Option<String>,
    opts: BTreeMap<String, String>,
    flags: Vec<String>,
}

impl Args {
    pub fn parse<I: Iterator<Item = String>>(mut argv: I) -> Result<Args> {
        let mut args = Args::default();
        // First non-option token is the subcommand.
        let mut pending: Option<String> = None;
        for tok in argv.by_ref() {
            if let Some(key) = pending.take() {
                if let Some(stripped) = tok.strip_prefix("--") {
                    // previous option was a flag
                    args.flags.push(key);
                    if let Some((k, v)) = stripped.split_once('=') {
                        args.opts.insert(k.to_string(), v.to_string());
                    } else {
                        pending = Some(stripped.to_string());
                    }
                } else {
                    args.opts.insert(key, tok);
                }
            } else if let Some(stripped) = tok.strip_prefix("--") {
                if let Some((k, v)) = stripped.split_once('=') {
                    args.opts.insert(k.to_string(), v.to_string());
                } else {
                    pending = Some(stripped.to_string());
                }
            } else if args.command.is_none() {
                args.command = Some(tok);
            } else {
                bail!("unexpected positional argument '{tok}'");
            }
        }
        if let Some(key) = pending {
            args.flags.push(key);
        }
        Ok(args)
    }

    pub fn get(&self, key: &str) -> Option<&str> {
        self.opts.get(key).map(|s| s.as_str())
    }

    pub fn req(&self, key: &str) -> Result<&str> {
        self.get(key).ok_or_else(|| anyhow!("missing required option --{key}"))
    }

    pub fn get_usize(&self, key: &str, default: usize) -> Result<usize> {
        match self.get(key) {
            None => Ok(default),
            Some(v) => v.parse().map_err(|_| anyhow!("--{key} expects an integer, got '{v}'")),
        }
    }

    pub fn get_u64(&self, key: &str, default: u64) -> Result<u64> {
        match self.get(key) {
            None => Ok(default),
            Some(v) => v.parse().map_err(|_| anyhow!("--{key} expects an integer, got '{v}'")),
        }
    }

    pub fn get_f64(&self, key: &str, default: f64) -> Result<f64> {
        match self.get(key) {
            None => Ok(default),
            Some(v) => v.parse().map_err(|_| anyhow!("--{key} expects a number, got '{v}'")),
        }
    }

    pub fn flag(&self, key: &str) -> bool {
        self.flags.iter().any(|f| f == key)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Args {
        Args::parse(s.split_whitespace().map(|t| t.to_string())).unwrap()
    }

    #[test]
    fn subcommand_options_flags() {
        let a = parse("figures --id fig2 --out results --all --n=100");
        assert_eq!(a.command.as_deref(), Some("figures"));
        assert_eq!(a.get("id"), Some("fig2"));
        assert_eq!(a.get("out"), Some("results"));
        assert_eq!(a.get("n"), Some("100"));
        assert!(a.flag("all"));
        assert!(!a.flag("missing"));
    }

    #[test]
    fn typed_getters() {
        let a = parse("x --n 42 --rho 0.7");
        assert_eq!(a.get_usize("n", 0).unwrap(), 42);
        assert_eq!(a.get_f64("rho", 0.0).unwrap(), 0.7);
        assert_eq!(a.get_u64("missing", 9).unwrap(), 9);
        assert!(a.get_usize("rho", 0).is_err());
    }

    #[test]
    fn trailing_flag_and_errors() {
        let a = parse("cmd --verbose");
        assert!(a.flag("verbose"));
        assert!(Args::parse(
            "cmd pos1 pos2".split_whitespace().map(|t| t.to_string())
        )
        .is_err());
        assert!(parse("cmd").req("x").is_err());
    }
}
