//! Minimal error-handling substrate (no `anyhow` offline).
//!
//! Mirrors the subset of the `anyhow` API the crate uses so the build
//! has zero crates.io dependencies:
//!
//! - [`Error`] — an opaque, message-carrying error type. Any
//!   `std::error::Error` converts into it via `?`.
//! - [`Result`] — `Result<T, Error>` alias with a defaultable error.
//! - [`anyhow!`] / [`bail!`] — format-style construction and early
//!   return.
//! - [`Context`] — `.context(...)` / `.with_context(...)` on both
//!   `Result` and `Option`, prepending a description to the cause.
//!
//! The context chain is flattened into one string eagerly (`"ctx: cause"`),
//! so `{e}` and `{e:#}` both print the full chain.

use std::fmt;

/// Opaque error: a flattened message chain.
pub struct Error {
    msg: String,
}

impl Error {
    /// Construct from anything displayable.
    pub fn msg<M: fmt::Display>(m: M) -> Error {
        Error { msg: m.to_string() }
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

// Like `anyhow::Error`, this type deliberately does NOT implement
// `std::error::Error`: that is what makes the blanket `From` below
// coherent next to core's reflexive `impl From<T> for T`.
impl<E: std::error::Error> From<E> for Error {
    fn from(e: E) -> Error {
        Error::msg(e)
    }
}

/// `Result` with [`Error`] as the default error type.
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Construct an [`Error`] from a format string (or any displayable).
#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::util::error::Error::msg(::std::format!($msg))
    };
    ($err:expr $(,)?) => {
        $crate::util::error::Error::msg($err)
    };
    ($fmt:expr, $($arg:tt)*) => {
        $crate::util::error::Error::msg(::std::format!($fmt, $($arg)*))
    };
}

/// Return early with an [`Error`] built as by [`anyhow!`].
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return ::std::result::Result::Err($crate::anyhow!($($arg)*))
    };
}

pub use crate::{anyhow, bail};

/// Attach context to the error branch of a `Result` or to a `None`.
pub trait Context<T> {
    /// Prepend `ctx` to the error message.
    fn context<C: fmt::Display>(self, ctx: C) -> Result<T>;

    /// Lazily computed variant of [`Context::context`].
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T>;
}

impl<T, E: fmt::Display> Context<T> for std::result::Result<T, E> {
    fn context<C: fmt::Display>(self, ctx: C) -> Result<T> {
        self.map_err(|e| Error::msg(format!("{ctx}: {e}")))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.map_err(|e| Error::msg(format!("{}: {e}", f())))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display>(self, ctx: C) -> Result<T> {
        self.ok_or_else(|| Error::msg(ctx))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn fails_io() -> Result<()> {
        std::fs::read_to_string("/definitely/not/a/file")?;
        Ok(())
    }

    #[test]
    fn question_mark_converts_std_errors() {
        let e = fails_io().unwrap_err();
        assert!(!e.to_string().is_empty());
        let e: Error = "x".parse::<u64>().unwrap_err().into();
        assert!(e.to_string().contains("invalid digit"));
    }

    #[test]
    fn macro_forms() {
        let plain = anyhow!("plain");
        assert_eq!(plain.to_string(), "plain");
        let key = "nu";
        let inline = anyhow!("missing option --{key}");
        assert_eq!(inline.to_string(), "missing option --nu");
        let args = anyhow!("{} + {}", 1, 2);
        assert_eq!(args.to_string(), "1 + 2");
        let wrapped = anyhow!(plain);
        assert_eq!(wrapped.to_string(), "plain");
    }

    #[test]
    fn bail_returns_early() {
        fn f(trigger: bool) -> Result<u32> {
            if trigger {
                bail!("boom {}", 42);
            }
            Ok(7)
        }
        assert_eq!(f(false).unwrap(), 7);
        assert_eq!(f(true).unwrap_err().to_string(), "boom 42");
    }

    #[test]
    fn context_chains_messages() {
        let r: std::result::Result<(), std::fmt::Error> = Err(std::fmt::Error);
        let e = r.context("writing header").unwrap_err();
        assert!(e.to_string().starts_with("writing header: "));
        let n: Option<u32> = None;
        assert_eq!(n.context("missing field 'd'").unwrap_err().to_string(), "missing field 'd'");
        let lazy: Option<u32> = None;
        let e = lazy.with_context(|| format!("missing {}", "x")).unwrap_err();
        assert_eq!(e.to_string(), "missing x");
        // Context on our own Error keeps chaining.
        let e = fails_io().context("loading keys").unwrap_err();
        assert!(e.to_string().starts_with("loading keys: "));
        // `{:#}` (anyhow chain format) is accepted and prints the chain.
        assert!(format!("{e:#}").starts_with("loading keys: "));
    }
}
