//! Deterministic fault injection for the serving stack.
//!
//! Chaos testing only proves something when the chaos is *repeatable*:
//! a failure found under `ELS_FAULTS=wire_write:partial_write:0.15:7`
//! reproduces bit-for-bit on every run, because each injection site
//! draws from a seeded counter-indexed splitmix64 stream instead of an
//! ambient RNG. The registry follows the `util::telemetry` design: a
//! relaxed-atomic `ENABLED` fast path that makes every probe a no-op
//! when no faults are armed (counter-asserted by tests), an exclusive
//! programmatic session for tests ([`FaultSession`] — never mutate
//! `ELS_FAULTS` in-process; `setenv` races are UB on glibc), and a
//! process-level [`init_from_env`] for binary entry points.
//!
//! Spec grammar (comma-separated):
//!
//! ```text
//! ELS_FAULTS=<site>:<kind>:<rate>:<seed>[,<site>:<kind>:<rate>:<seed>...]
//! ```
//!
//! where `site` is one of `wire_read`, `wire_write`, `lane`, `timer`,
//! `cache`, `batcher`, `journal`; `kind` is a site-appropriate fault kind (see
//! [`FaultKind`]); `rate` is a probability in `[0,1]`; and `seed` is a
//! u64. Each armed spec keeps its own draw counter, so two sites with
//! the same seed still see independent decision streams.

use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Mutex, MutexGuard, OnceLock};

/// Where a fault can be injected. Each variant marks one real seam in
/// the serving stack where production failures originate.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum FaultSite {
    /// Server-side request read (io error, mid-frame disconnect).
    WireRead,
    /// Server-side reply write (io error, partial write, disconnect).
    WireWrite,
    /// Executor lane task body (panic).
    Lane,
    /// Timer-wheel firing decision (late or spurious fire).
    Timer,
    /// Tenant operand cache lookup (forced eviction).
    Cache,
    /// Batcher dispatch of a coalesced group (backend failure).
    Batcher,
    /// Write-ahead journal append (io error, torn partial write).
    Journal,
}

/// All sites, in [`FaultSite::index`] order.
pub const ALL_SITES: [FaultSite; 7] = [
    FaultSite::WireRead,
    FaultSite::WireWrite,
    FaultSite::Lane,
    FaultSite::Timer,
    FaultSite::Cache,
    FaultSite::Batcher,
    FaultSite::Journal,
];

impl FaultSite {
    /// Dense index into the per-site counter arrays.
    pub fn index(self) -> usize {
        match self {
            FaultSite::WireRead => 0,
            FaultSite::WireWrite => 1,
            FaultSite::Lane => 2,
            FaultSite::Timer => 3,
            FaultSite::Cache => 4,
            FaultSite::Batcher => 5,
            FaultSite::Journal => 6,
        }
    }

    /// Spec-grammar name.
    pub fn as_str(self) -> &'static str {
        match self {
            FaultSite::WireRead => "wire_read",
            FaultSite::WireWrite => "wire_write",
            FaultSite::Lane => "lane",
            FaultSite::Timer => "timer",
            FaultSite::Cache => "cache",
            FaultSite::Batcher => "batcher",
            FaultSite::Journal => "journal",
        }
    }

    fn from_str(s: &str) -> Option<FaultSite> {
        ALL_SITES.iter().copied().find(|site| site.as_str() == s)
    }

    /// The fault kinds that make sense at this site.
    fn allows(self, kind: FaultKind) -> bool {
        use FaultKind::*;
        match self {
            FaultSite::WireRead => matches!(kind, IoError | Disconnect),
            FaultSite::WireWrite => matches!(kind, IoError | PartialWrite | Disconnect),
            FaultSite::Lane => matches!(kind, Panic),
            FaultSite::Timer => matches!(kind, Late | Spurious),
            FaultSite::Cache => matches!(kind, Evict),
            FaultSite::Batcher => matches!(kind, Fail),
            FaultSite::Journal => matches!(kind, IoError | TornWrite),
        }
    }
}

/// What happens when a fault fires at a site.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum FaultKind {
    /// Surface an `io::Error` from the read/write.
    IoError,
    /// Write only a prefix of the frame, then stop (truncated reply).
    PartialWrite,
    /// Drop the connection mid-frame without writing anything.
    Disconnect,
    /// Panic inside the lane task body.
    Panic,
    /// Suppress a due timer fire for one wheel pass (fires late).
    Late,
    /// Fire a timer before its deadline (spurious early fire).
    Spurious,
    /// Force-evict the tenant operand cache before the lookup.
    Evict,
    /// Fail the batched dispatch as if the backend errored.
    Fail,
    /// Persist only a prefix of the journal record (torn tail).
    TornWrite,
}

impl FaultKind {
    /// Spec-grammar name.
    pub fn as_str(self) -> &'static str {
        match self {
            FaultKind::IoError => "io_error",
            FaultKind::PartialWrite => "partial_write",
            FaultKind::Disconnect => "disconnect",
            FaultKind::Panic => "panic",
            FaultKind::Late => "late",
            FaultKind::Spurious => "spurious",
            FaultKind::Evict => "evict",
            FaultKind::Fail => "fail",
            FaultKind::TornWrite => "torn_write",
        }
    }

    fn from_str(s: &str) -> Option<FaultKind> {
        use FaultKind::*;
        [IoError, PartialWrite, Disconnect, Panic, Late, Spurious, Evict, Fail, TornWrite]
            .into_iter()
            .find(|k| k.as_str() == s)
    }
}

/// One armed fault: fire `kind` at `site` with probability `rate` per
/// probe, decided by the seeded per-spec draw stream.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct FaultSpec {
    pub site: FaultSite,
    pub kind: FaultKind,
    pub rate: f64,
    pub seed: u64,
}

/// Parse the `ELS_FAULTS` grammar. Pure so tests can exercise rejects
/// without touching process state.
pub fn parse_spec(s: &str) -> Result<Vec<FaultSpec>, String> {
    let mut specs = Vec::new();
    for part in s.split(',').map(str::trim).filter(|p| !p.is_empty()) {
        let fields: Vec<&str> = part.split(':').collect();
        let [site, kind, rate, seed] = fields[..] else {
            return Err(format!("fault spec `{part}`: want <site>:<kind>:<rate>:<seed>"));
        };
        let site = FaultSite::from_str(site)
            .ok_or_else(|| format!("fault spec `{part}`: unknown site `{site}`"))?;
        let kind = FaultKind::from_str(kind)
            .ok_or_else(|| format!("fault spec `{part}`: unknown kind `{kind}`"))?;
        if !site.allows(kind) {
            return Err(format!(
                "fault spec `{part}`: kind `{}` not valid at site `{}`",
                kind.as_str(),
                site.as_str()
            ));
        }
        let rate: f64 = rate
            .parse()
            .map_err(|_| format!("fault spec `{part}`: rate `{rate}` is not a number"))?;
        if !(0.0..=1.0).contains(&rate) {
            return Err(format!("fault spec `{part}`: rate {rate} outside [0,1]"));
        }
        let seed: u64 = seed
            .parse()
            .map_err(|_| format!("fault spec `{part}`: seed `{seed}` is not a u64"))?;
        specs.push(FaultSpec { site, kind, rate, seed });
    }
    Ok(specs)
}

/// splitmix64 of `seed + n` — the counter-indexed decision stream. Also
/// used by the client retry policy for seeded decorrelated jitter.
pub fn mix64(seed: u64, n: u64) -> u64 {
    let mut z = seed.wrapping_add(n.wrapping_mul(0x9e37_79b9_7f4a_7c15));
    z = (z ^ (z >> 30)).wrapping_mul(0xbf58_476d_1ce4_e5b9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94d0_49bb_1331_11eb);
    z ^ (z >> 31)
}

/// Draw `n` from the `seed` stream and compare against `rate`.
fn decide(seed: u64, n: u64, rate: f64) -> bool {
    if rate >= 1.0 {
        return true;
    }
    if rate <= 0.0 {
        return false;
    }
    // Threshold comparison on the full 64-bit draw keeps the decision
    // exact for the rates chaos specs actually use.
    mix64(seed, n) < (rate * u64::MAX as f64) as u64
}

/// One armed spec plus its private draw counter.
struct SiteState {
    spec: FaultSpec,
    draws: AtomicU64,
}

static ENABLED: AtomicBool = AtomicBool::new(false);
static PLAN: Mutex<Option<Vec<SiteState>>> = Mutex::new(None);
static SESSION: Mutex<()> = Mutex::new(());

// The const is only a repeat-expression seed for the static arrays
// below (the sanctioned pre-inline-const idiom), never borrowed itself.
#[allow(clippy::declare_interior_mutable_const)]
const ZERO: AtomicU64 = AtomicU64::new(0);
static CHECKED: [AtomicU64; 7] = [ZERO; 7];
static INJECTED: [AtomicU64; 7] = [ZERO; 7];

/// Probe a site. `None` on the (overwhelmingly common) no-fault path;
/// `Some(kind)` tells the caller which failure to act out. When the
/// registry is disabled this is a single relaxed atomic load — no
/// counters move, no locks are taken (the chaos no-op test asserts it).
pub fn check(site: FaultSite) -> Option<FaultKind> {
    if !ENABLED.load(Ordering::Relaxed) {
        return None;
    }
    CHECKED[site.index()].fetch_add(1, Ordering::Relaxed);
    let plan = PLAN.lock().unwrap_or_else(|e| e.into_inner());
    let states = plan.as_ref()?;
    for st in states.iter().filter(|st| st.spec.site == site) {
        let n = st.draws.fetch_add(1, Ordering::Relaxed);
        if decide(st.spec.seed, n, st.spec.rate) {
            INJECTED[site.index()].fetch_add(1, Ordering::Relaxed);
            return Some(st.spec.kind);
        }
    }
    None
}

/// Whether any faults are armed.
pub fn enabled() -> bool {
    ENABLED.load(Ordering::Relaxed)
}

/// Probes observed at `site` since process start.
pub fn checked_at(site: FaultSite) -> u64 {
    CHECKED[site.index()].load(Ordering::Relaxed)
}

/// Faults fired at `site` since process start.
pub fn injected_at(site: FaultSite) -> u64 {
    INJECTED[site.index()].load(Ordering::Relaxed)
}

/// Total probes observed across all sites.
pub fn checked_total() -> u64 {
    CHECKED.iter().map(|c| c.load(Ordering::Relaxed)).sum()
}

/// Total faults fired across all sites.
pub fn injected_total() -> u64 {
    INJECTED.iter().map(|c| c.load(Ordering::Relaxed)).sum()
}

/// Exclusive programmatic fault session — the sanctioned in-process
/// switch for tests (never mutate `ELS_FAULTS` in-process). Faults are
/// armed while the session lives and disarmed on drop; concurrent
/// sessions serialise on an internal mutex so chaos scenarios never
/// bleed into each other.
pub struct FaultSession {
    _session: MutexGuard<'static, ()>,
}

impl FaultSession {
    /// Arm `specs` exclusively until the returned guard drops.
    pub fn activate(specs: &[FaultSpec]) -> FaultSession {
        let session = SESSION.lock().unwrap_or_else(|e| e.into_inner());
        let states =
            specs.iter().map(|&spec| SiteState { spec, draws: AtomicU64::new(0) }).collect();
        *PLAN.lock().unwrap_or_else(|e| e.into_inner()) = Some(states);
        ENABLED.store(true, Ordering::Relaxed);
        FaultSession { _session: session }
    }
}

impl Drop for FaultSession {
    fn drop(&mut self) {
        ENABLED.store(false, Ordering::Relaxed);
        *PLAN.lock().unwrap_or_else(|e| e.into_inner()) = None;
    }
}

/// Hold to keep injection *disabled* (no session can arm concurrently)
/// — the disabled-hot-path acceptance test runs under this.
pub fn exclusion() -> MutexGuard<'static, ()> {
    SESSION.lock().unwrap_or_else(|e| e.into_inner())
}

static ENV_SPECS: OnceLock<Vec<FaultSpec>> = OnceLock::new();

/// Process-level activation: `ELS_FAULTS=<spec>` arms the registry for
/// the whole run. Only binary entry points (and the env-driven chaos
/// smoke test) call this — library code and tests go through
/// [`FaultSession`]. A malformed spec is a loud startup panic, not a
/// silently fault-free chaos run.
pub fn init_from_env() {
    let specs = ENV_SPECS.get_or_init(|| match std::env::var("ELS_FAULTS") {
        Ok(s) if !s.is_empty() => {
            parse_spec(&s).unwrap_or_else(|e| panic!("ELS_FAULTS: {e}"))
        }
        _ => Vec::new(),
    });
    if !specs.is_empty() {
        let states =
            specs.iter().map(|&spec| SiteState { spec, draws: AtomicU64::new(0) }).collect();
        *PLAN.lock().unwrap_or_else(|e| e.into_inner()) = Some(states);
        ENABLED.store(true, Ordering::Relaxed);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn spec_grammar_accepts_and_rejects() {
        let specs =
            parse_spec("wire_read:io_error:0.25:7, lane:panic:1:13,timer:late:0.5:17").unwrap();
        assert_eq!(specs.len(), 3);
        assert_eq!(
            specs[0],
            FaultSpec {
                site: FaultSite::WireRead,
                kind: FaultKind::IoError,
                rate: 0.25,
                seed: 7
            }
        );
        assert_eq!(specs[1].rate, 1.0);
        assert!(parse_spec("").unwrap().is_empty());
        // Structural rejects: wrong arity, unknown site/kind, kind not
        // valid at site, rate outside [0,1], non-numeric fields.
        assert!(parse_spec("wire_read:io_error:0.25").is_err());
        assert!(parse_spec("bogus:io_error:0.25:7").is_err());
        assert!(parse_spec("wire_read:bogus:0.25:7").is_err());
        assert!(parse_spec("journal:torn_write:0.2:7").is_ok());
        assert!(parse_spec("journal:io_error:0.2:7").is_ok());
        assert!(parse_spec("journal:panic:0.2:7").is_err());
        assert!(parse_spec("lane:io_error:0.25:7").is_err());
        assert!(parse_spec("lane:panic:1.5:7").is_err());
        assert!(parse_spec("lane:panic:x:7").is_err());
        assert!(parse_spec("lane:panic:0.5:x").is_err());
    }

    #[test]
    fn decisions_are_deterministic_and_rate_shaped() {
        // Same (seed, n) → same decision, always.
        for n in 0..64 {
            assert_eq!(decide(42, n, 0.3), decide(42, n, 0.3));
        }
        // Extremes are exact.
        assert!((0..32).all(|n| decide(9, n, 1.0)));
        assert!((0..32).all(|n| !decide(9, n, 0.0)));
        // A 30% rate over 10k draws lands near 3k — loose bounds, the
        // point is the stream is neither all-on nor all-off.
        let hits = (0..10_000).filter(|&n| decide(1234, n, 0.3)).count();
        assert!((2_000..4_000).contains(&hits), "30% rate drew {hits}/10000");
    }

    #[test]
    fn session_arms_and_disarms_with_counters() {
        let before_checked = checked_at(FaultSite::Cache);
        let before_injected = injected_at(FaultSite::Cache);
        {
            let _s = FaultSession::activate(&[FaultSpec {
                site: FaultSite::Cache,
                kind: FaultKind::Evict,
                rate: 1.0,
                seed: 5,
            }]);
            assert!(enabled());
            assert_eq!(check(FaultSite::Cache), Some(FaultKind::Evict));
            // Other sites stay quiet even while the session is live.
            assert_eq!(check(FaultSite::Lane), None);
        }
        assert!(!enabled());
        assert_eq!(check(FaultSite::Cache), None, "disarmed registry must not fire");
        assert_eq!(checked_at(FaultSite::Cache), before_checked + 1);
        assert_eq!(injected_at(FaultSite::Cache), before_injected + 1);
    }

    #[test]
    fn disabled_probe_is_counter_asserted_noop() {
        let _guard = exclusion();
        let (c, i) = (checked_total(), injected_total());
        for _ in 0..1000 {
            for site in ALL_SITES {
                assert_eq!(check(site), None);
            }
        }
        assert_eq!(checked_total(), c, "disabled probes must not move counters");
        assert_eq!(injected_total(), i);
    }

    #[test]
    fn draw_streams_are_independent_per_spec() {
        // Two specs at the same site with rate 1.0 and 0.0: the first
        // always answers, proving per-spec iteration order is stable;
        // replaying the session yields the identical decision sequence.
        let spec_on = FaultSpec {
            site: FaultSite::Timer,
            kind: FaultKind::Late,
            rate: 0.5,
            seed: 99,
        };
        let run = || {
            let _s = FaultSession::activate(&[spec_on]);
            (0..32).map(|_| check(FaultSite::Timer).is_some()).collect::<Vec<_>>()
        };
        let a = run();
        let b = run();
        assert_eq!(a, b, "replayed session must reproduce the decision stream");
        assert!(a.iter().any(|&x| x) && a.iter().any(|&x| !x));
    }
}
