//! Minimal property-based testing harness.
//!
//! `proptest`/`quickcheck` are not vendored in this offline build, so
//! this module provides the subset the test-suite needs: a deterministic
//! per-property RNG (seeded from the property name so failures are
//! reproducible), many-case execution with a case-index report on
//! failure, and helper generators.
//!
//! Usage:
//! ```ignore
//! let mut run = PropRunner::new("my_property", 500);
//! run.run(|rng| {
//!     let x = rng.next_u64();
//!     assert!(property_holds(x));
//! });
//! ```

use crate::fhe::rng::ChaChaRng;

/// Deterministic seed from a property name (FNV-1a).
fn seed_from_name(name: &str) -> u64 {
    let mut h = 0xcbf2_9ce4_8422_2325u64;
    for b in name.bytes() {
        h ^= b as u64;
        h = h.wrapping_mul(0x1000_0000_01b3);
    }
    h
}

/// Runs a closure against many deterministic random cases.
pub struct PropRunner {
    name: String,
    cases: usize,
    seed: u64,
}

impl PropRunner {
    pub fn new(name: &str, cases: usize) -> Self {
        let seed = std::env::var("ELS_PROP_SEED")
            .ok()
            .and_then(|s| s.parse().ok())
            .unwrap_or_else(|| seed_from_name(name));
        PropRunner { name: name.to_string(), cases, seed }
    }

    /// Execute the property once per case. Each case gets its own RNG
    /// stream so a failing case can be replayed in isolation.
    pub fn run<F: FnMut(&mut ChaChaRng)>(&mut self, mut prop: F) {
        for case in 0..self.cases {
            let mut rng = ChaChaRng::from_seed(self.seed ^ (case as u64).wrapping_mul(0x9e37_79b9_7f4a_7c15));
            let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
                prop(&mut rng);
            }));
            if let Err(e) = result {
                eprintln!(
                    "property '{}' failed at case {case}/{} (seed {:#x}); replay with ELS_PROP_SEED={}",
                    self.name, self.cases, self.seed, self.seed
                );
                std::panic::resume_unwind(e);
            }
        }
    }
}

/// Generator helpers shared across property tests.
pub mod gen {
    use crate::fhe::rng::ChaChaRng;

    /// Uniform integer in `[lo, hi]` inclusive.
    pub fn int_in(rng: &mut ChaChaRng, lo: i64, hi: i64) -> i64 {
        assert!(lo <= hi);
        let span = (hi - lo) as u64 + 1;
        lo + rng.uniform_below(span) as i64
    }

    /// Uniform choice from a slice.
    pub fn choice<'a, T>(rng: &mut ChaChaRng, items: &'a [T]) -> &'a T {
        &items[rng.uniform_below(items.len() as u64) as usize]
    }

    /// Vector of uniform residues mod `p`.
    pub fn residues(rng: &mut ChaChaRng, len: usize, p: u64) -> Vec<u64> {
        (0..len).map(|_| rng.uniform_below(p)).collect()
    }

    /// f64 in `[lo, hi)`.
    pub fn f64_in(rng: &mut ChaChaRng, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * rng.next_f64()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn runner_is_deterministic() {
        let mut seen1 = Vec::new();
        PropRunner::new("det_check", 5).run(|rng| seen1.push(rng.next_u64()));
        let mut seen2 = Vec::new();
        PropRunner::new("det_check", 5).run(|rng| seen2.push(rng.next_u64()));
        assert_eq!(seen1, seen2);
        assert_eq!(seen1.len(), 5);
    }

    #[test]
    fn gen_ranges() {
        let mut run = PropRunner::new("gen_ranges", 200);
        run.run(|rng| {
            let v = gen::int_in(rng, -5, 5);
            assert!((-5..=5).contains(&v));
            let f = gen::f64_in(rng, 2.0, 3.0);
            assert!((2.0..3.0).contains(&f));
            let r = gen::residues(rng, 8, 97);
            assert!(r.iter().all(|&x| x < 97));
        });
    }
}
