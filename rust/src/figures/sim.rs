//! Simulation-study figures (paper §6.1): Figures 1–4, Table 1,
//! supplementary Figure 1, and the Lemma-3 empirical validation.
//!
//! Workloads follow the paper exactly: standardised Gaussian designs,
//! equicorrelated for the correlation sweeps, φ = 2, error norms = RMS
//! deviation from the f64 OLS solution on the quantised data.

use std::path::{Path, PathBuf};

use crate::util::error::Result;

use crate::data::synth;
use crate::els::exact::QuantisedData;
use crate::els::float_ref::{
    cd_path, gd_path, gram_spectrum, nag_path, ols, rms, vwt_estimate,
};
use crate::els::mmd;
use crate::els::stepsize;
use crate::els::encrypted::Accel;
use crate::fhe::rng::ChaChaRng;

use super::{f, Csv};

/// Quantise-then-dequantise (the data the encrypted algorithm sees).
fn quantised(x: &[Vec<f64>], y: &[f64]) -> (Vec<Vec<f64>>, Vec<f64>) {
    QuantisedData::from_f64(x, y, 2).dequantised()
}

/// Figure 1: preconditioning smooths the ELS-GD convergence path.
/// [N = 100, P = 5, ρ = 0.1]
pub fn fig1(out: &Path) -> Result<Vec<PathBuf>> {
    let mut rng = ChaChaRng::from_seed(1001);
    let (x0, y0) = synth::correlated_regression(&mut rng, 100, 5, 0.1, 0.1);
    let (x, y) = quantised(&x0, &y0);
    let truth = ols(&x, &y);
    let mut csv = Csv::new(out, "fig1_paths.csv", "variant,k,beta1,beta2,err_rms");
    // Naive step: near the stability edge of λ_max — zig-zag path.
    let (_, lmax) = gram_spectrum(&x);
    for (variant, delta) in [
        ("naive", 1.9 / lmax),
        ("preconditioned", 1.0 / stepsize::nu_optimal(&x) as f64),
    ] {
        for (k, beta) in gd_path(&x, &y, delta, 40).iter().enumerate() {
            csv.row(&[
                variant.to_string(),
                (k + 1).to_string(),
                f(beta[0]),
                f(beta[1]),
                f(rms(beta, &truth)),
            ]);
        }
    }
    // OLS reference row (the full circles in the paper's plot).
    csv.row(&["ols".into(), "0".into(), f(truth[0]), f(truth[1]), f(0.0)]);
    Ok(vec![csv.finish()?])
}

/// Figure 2 left: ELS-CD vs ELS-GD error at fixed MMD;
/// right: VWT/GD error-norm ratios. [N = 100, P ∈ {5, 50}]
pub fn fig2(out: &Path) -> Result<Vec<PathBuf>> {
    let mut left = Csv::new(out, "fig2_left_cd_vs_gd.csv", "p,mmd,err_gd,err_cd");
    let mut right = Csv::new(out, "fig2_right_vwt_ratio.csv", "p,iters,err_gd,err_vwt,ratio");
    for p_vars in [5usize, 50] {
        let mut rng = ChaChaRng::from_seed(1002 + p_vars as u64);
        let (x0, y0) = synth::correlated_regression(&mut rng, 100, p_vars, 0.1, 0.1);
        let (x, y) = quantised(&x0, &y0);
        let truth = ols(&x, &y);
        let delta = 1.0 / stepsize::nu_optimal(&x) as f64;
        // Left: at MMD budget m, GD affords m/2 iterations (all P
        // coordinates each) while CD affords m/2 single-coordinate
        // updates — the paper's fixed-complexity comparison.
        let max_mmd = 24u32;
        let gd = gd_path(&x, &y, delta, mmd::iters_within_mmd(Accel::None, max_mmd));
        let cd = cd_path(&x, &y, delta, mmd::cd_updates_within_mmd(max_mmd));
        for m in (2..=max_mmd).step_by(2) {
            let gk = mmd::iters_within_mmd(Accel::None, m);
            let ck = mmd::cd_updates_within_mmd(m);
            left.row(&[
                p_vars.to_string(),
                m.to_string(),
                f(rms(&gd[gk - 1], &truth)),
                f(rms(&cd[ck - 1], &truth)),
            ]);
        }
        // Right: VWT ratio over K, in the oscillatory regime (Lemma 2)
        // where the averaging bites.
        let (_, lmax) = gram_spectrum(&x);
        let dv = 1.9 / lmax;
        for iters in 3..=14usize {
            let path = gd_path(&x, &y, dv, iters);
            let e_gd = rms(&path[iters - 1], &truth);
            let e_vwt = rms(&vwt_estimate(&path), &truth);
            right.row(&[
                p_vars.to_string(),
                iters.to_string(),
                f(e_gd),
                f(e_vwt),
                f(e_vwt / e_gd),
            ]);
        }
    }
    Ok(vec![left.finish()?, right.finish()?])
}

/// Figure 3: GD-VWT vs NAG convergence per iteration, ρ ∈ {0.3, 0.7}.
/// [N = 100, P = 5]
pub fn fig3(out: &Path) -> Result<Vec<PathBuf>> {
    let mut csv = Csv::new(out, "fig3_vwt_vs_nag.csv", "rho,k,err_gd,err_vwt,err_nag");
    for (seed, rho) in [(1003u64, 0.3), (1004, 0.7)] {
        let mut rng = ChaChaRng::from_seed(seed);
        let (x0, y0) = synth::correlated_regression(&mut rng, 100, 5, rho, 0.1);
        let (x, y) = quantised(&x0, &y0);
        let truth = ols(&x, &y);
        let (_, lmax) = gram_spectrum(&x);
        for k in 2..=16usize {
            let path = gd_path(&x, &y, 1.9 / lmax, k);
            let nag = nag_path(&x, &y, 1.0 / lmax, k);
            csv.row(&[
                format!("{rho}"),
                k.to_string(),
                f(rms(&path[k - 1], &truth)),
                f(rms(&vwt_estimate(&path), &truth)),
                f(rms(&nag[k - 1], &truth)),
            ]);
        }
    }
    Ok(vec![csv.finish()?])
}

/// Figure 4: error as a function of **MMD** (complexity-fair): at a
/// fixed depth budget VWT affords ⌊(m−1)/2⌋ iterations but NAG only
/// ⌊m/3⌋ — the paper's headline comparison. ρ ∈ {0.3, 0.7}.
pub fn fig4(out: &Path) -> Result<Vec<PathBuf>> {
    let mut csv = Csv::new(out, "fig4_error_vs_mmd.csv", "rho,mmd,iters_vwt,err_vwt,iters_nag,err_nag");
    for (seed, rho) in [(1005u64, 0.3), (1006, 0.7)] {
        let mut rng = ChaChaRng::from_seed(seed);
        let (x0, y0) = synth::correlated_regression(&mut rng, 100, 5, rho, 0.1);
        let (x, y) = quantised(&x0, &y0);
        let truth = ols(&x, &y);
        let (_, lmax) = gram_spectrum(&x);
        for budget in (6..=36u32).step_by(3) {
            let kv = mmd::iters_within_mmd(Accel::Vwt, budget).max(1);
            let kn = mmd::iters_within_mmd(Accel::Nag, budget).max(1);
            let path = gd_path(&x, &y, 1.9 / lmax, kv);
            let nag = nag_path(&x, &y, 1.0 / lmax, kn);
            csv.row(&[
                format!("{rho}"),
                budget.to_string(),
                kv.to_string(),
                f(rms(&vwt_estimate(&path), &truth)),
                kn.to_string(),
                f(rms(&nag[kn - 1], &truth)),
            ]);
        }
    }
    Ok(vec![csv.finish()?])
}

/// Table 1: MMD accounting.
pub fn tab1(out: &Path) -> Result<Vec<PathBuf>> {
    let mut csv = Csv::new(out, "tab1_mmd.csv", "algorithm,mmd_formula,mmd_at_k5,noise_depth_at_k5");
    csv.row(&[
        "preconditioned_gd".into(),
        "2K".into(),
        mmd::paper_mmd(Accel::None, 5).to_string(),
        mmd::noise_depth(5).to_string(),
    ]);
    csv.row(&[
        "vwt".into(),
        "2K+1".into(),
        mmd::paper_mmd(Accel::Vwt, 5).to_string(),
        (mmd::noise_depth(5)).to_string(),
    ]);
    csv.row(&[
        "nag".into(),
        "3K".into(),
        mmd::paper_mmd(Accel::Nag, 5).to_string(),
        mmd::noise_depth(5).to_string(),
    ]);
    csv.row(&[
        "cd_p5".into(),
        "2KP".into(),
        mmd::paper_mmd_cd(5, 5).to_string(),
        mmd::noise_depth_cd(25).to_string(),
    ]);
    Ok(vec![csv.finish()?])
}

/// Supplementary Figure 1: iterations to reduce the error by a factor e
/// grows linearly with P, at any correlation level.
pub fn sfig1(out: &Path) -> Result<Vec<PathBuf>> {
    let mut csv = Csv::new(out, "sfig1_iters_vs_p.csv", "rho,p,iters_per_efold");
    for rho in [0.0, 0.2, 0.5, 0.8] {
        for p_vars in [2usize, 5, 10, 20, 35, 50] {
            let mut rng = ChaChaRng::from_seed(1010 + (rho * 10.0) as u64 + p_vars as u64);
            let (x, _) = synth::correlated_regression(&mut rng, 200, p_vars, rho, 0.1);
            csv.row(&[
                format!("{rho}"),
                p_vars.to_string(),
                f(stepsize::iters_per_efold(&x)),
            ]);
        }
    }
    Ok(vec![csv.finish()?])
}

/// Lemma 3 validation: realised message degree/coefficient magnitudes
/// vs the lemma's stated bounds and our exact-constant tracker.
pub fn lemma3(out: &Path) -> Result<Vec<PathBuf>> {
    use crate::els::exact::gd_exact;
    use crate::fhe::params::{lemma3_coeff_bounds, lemma3_deg_bound, track_gd_growth};
    let mut csv = Csv::new(
        out,
        "lemma3_bounds.csv",
        "k,realised_value_bits,tracked_value_bits,lemma3_coeff_bits,lemma3_deg",
    );
    let mut rng = ChaChaRng::from_seed(1011);
    let (x0, y0) = synth::gaussian_regression(&mut rng, 30, 3, 0.2);
    let q = QuantisedData::from_f64(&x0, &y0, 2);
    let (xq, _) = q.dequantised();
    let nu = stepsize::nu_optimal(&xq);
    let iters = 5;
    let path = gd_exact(&q, nu, iters);
    let lemma = lemma3_coeff_bounds(30, 3, iters, 2);
    for k in 1..=iters {
        let realised = path.iterates[k - 1]
            .iter()
            .map(|b| b.mag.bit_len())
            .max()
            .unwrap_or(0);
        let g = track_gd_growth(30, 3, k, 2, nu);
        let tracked_value =
            g.coeff_bound.mul(&crate::math::bigint::BigUint::one().shl_bits(g.deg_bound + 1));
        csv.row(&[
            k.to_string(),
            realised.to_string(),
            tracked_value.bit_len().to_string(),
            lemma[k - 1].bit_len().to_string(),
            lemma3_deg_bound(k, 2).to_string(),
        ]);
        // The tracker must dominate realised values (asserted, not just
        // reported — this is the §4.5 guarantee).
        assert!(tracked_value.bit_len() >= realised, "bound violated at k={k}");
    }
    Ok(vec![csv.finish()?])
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmp() -> std::path::PathBuf {
        let d = std::env::temp_dir().join(format!("els-sim-{}", std::process::id()));
        std::fs::create_dir_all(&d).unwrap();
        d
    }

    #[test]
    fn fig2_shapes_hold() {
        // GD must beat CD at equal MMD (the paper's central claim), and
        // the VWT ratio must be < 1 for most K at P = 5.
        let dir = tmp();
        let paths = fig2(&dir).unwrap();
        let left = std::fs::read_to_string(&paths[0]).unwrap();
        let mut gd_wins = 0;
        let mut rows = 0;
        for line in left.lines().skip(1) {
            let c: Vec<&str> = line.split(',').collect();
            let (e_gd, e_cd): (f64, f64) = (c[2].parse().unwrap(), c[3].parse().unwrap());
            rows += 1;
            if e_gd <= e_cd {
                gd_wins += 1;
            }
        }
        assert!(gd_wins * 10 >= rows * 8, "GD should win ≥80% of rows: {gd_wins}/{rows}");
        let right = std::fs::read_to_string(&paths[1]).unwrap();
        let p5_ratios: Vec<f64> = right
            .lines()
            .skip(1)
            .filter(|l| l.starts_with("5,"))
            .map(|l| l.split(',').nth(4).unwrap().parse().unwrap())
            .collect();
        let below_one = p5_ratios.iter().filter(|&&r| r < 1.0).count();
        assert!(below_one * 10 >= p5_ratios.len() * 7, "VWT ratio < 1 mostly: {p5_ratios:?}");
    }

    #[test]
    fn fig4_vwt_beats_nag_at_fixed_mmd() {
        // Paper: ELS-GD-VWT typically outperforms ELS-NAG at fixed MMD
        // (ρ = 0.3); reversals appear only in high-correlation regimes.
        let dir = tmp();
        let paths = fig4(&dir).unwrap();
        let text = std::fs::read_to_string(&paths[0]).unwrap();
        let rows: Vec<Vec<String>> = text
            .lines()
            .skip(1)
            .filter(|l| l.starts_with("0.3,"))
            .map(|l| l.split(',').map(|s| s.to_string()).collect())
            .collect();
        let wins = rows
            .iter()
            .filter(|c| c[3].parse::<f64>().unwrap() <= c[5].parse::<f64>().unwrap())
            .count();
        assert!(wins * 10 >= rows.len() * 6, "VWT should mostly win at ρ=0.3: {wins}/{}", rows.len());
    }

    #[test]
    fn sfig1_linear_in_p() {
        let dir = tmp();
        let paths = sfig1(&dir).unwrap();
        let text = std::fs::read_to_string(&paths[0]).unwrap();
        // For ρ = 0.5 the efold iteration count must increase with P.
        let vals: Vec<f64> = text
            .lines()
            .skip(1)
            .filter(|l| l.starts_with("0.5,"))
            .map(|l| l.split(',').nth(2).unwrap().parse().unwrap())
            .collect();
        assert!(vals.last().unwrap() > vals.first().unwrap());
    }
}
