//! Computational-cost figures: Figure 5 (runtime and ciphertext memory
//! of ELS-GD as the multiplicative depth grows, P ∈ {2, 25}) and
//! supplementary Figure 2 (application runtimes/memory). These run the
//! **real encrypted pipeline** on the native backend and measure
//! wall-clock — absolute numbers reflect this testbed, shapes reflect
//! the paper (steep growth in MMD, linear in N and P at fixed MMD).

use std::path::{Path, PathBuf};
use std::sync::Arc;
use std::time::Instant;

use crate::util::error::Result;

use crate::data::{mood, synth};
use crate::els::encrypted::{decrypt_coefficients, fit, DatasetRef, FitConfig};
use crate::els::exact::{self, QuantisedData};
use crate::els::float_ref::linf;
use crate::els::model::encrypt_dataset;
use crate::els::stepsize::nu_optimal;
use crate::fhe::keys::keygen;
use crate::fhe::params::{plan, PlanRequest};
use crate::fhe::rng::ChaChaRng;
use crate::fhe::FvContext;
use crate::runtime::backend::NativeEngine;

use super::{f, Csv};

struct Cost {
    keygen_s: f64,
    encrypt_s: f64,
    fit_s: f64,
    data_bytes: usize,
    d: usize,
    q_bits: usize,
    correct: bool,
}

/// Run one encrypted GD problem and measure costs.
fn measure(seed: u64, n: usize, p: usize, iters: usize) -> Result<Cost> {
    let mut rng = ChaChaRng::from_seed(seed);
    let (x, y) = synth::gaussian_regression(&mut rng, n, p, 0.2);
    let q = QuantisedData::from_f64(&x, &y, 2);
    let (xq, _) = q.dequantised();
    let nu = nu_optimal(&xq);
    let params = plan(&PlanRequest::gd(n, p, iters, 2, nu))?;
    let ctx = FvContext::new(params);

    let t0 = Instant::now();
    let keys = keygen(&ctx, &mut rng);
    let keygen_s = t0.elapsed().as_secs_f64();

    let t0 = Instant::now();
    let data = encrypt_dataset(&ctx, &keys.pk, &q, &mut rng);
    let encrypt_s = t0.elapsed().as_secs_f64();
    let data_bytes = data.size_bytes();

    let engine = NativeEngine::new(ctx.clone(), Arc::new(keys.rk.clone()));
    let t0 = Instant::now();
    let fitted = fit(&engine, &DatasetRef::Scalar(&data), &FitConfig::gd(iters, nu))?.fit;
    let fit_s = t0.elapsed().as_secs_f64();

    let dec = decrypt_coefficients(&ctx, &keys.sk, &fitted);
    let expect = exact::gd_exact(&q, nu, iters).decode_last();
    Ok(Cost {
        keygen_s,
        encrypt_s,
        fit_s,
        data_bytes,
        d: ctx.d(),
        q_bits: ctx.q.bit_len(),
        correct: linf(&dec, &expect) < 1e-9,
    })
}

/// Figure 5: runtime (s) and encrypted data size vs MMD for
/// P ∈ {2, 25}. N is kept small and costs are also reported
/// per-100-observations (ciphertext count scales exactly linearly in N,
/// so the normalisation is exact for memory and near-exact for time).
pub fn fig5(out: &Path) -> Result<Vec<PathBuf>> {
    let n = 10usize;
    let mut csv = Csv::new(
        out,
        "fig5_costs.csv",
        "p,iters,mmd,d,q_bits,keygen_s,encrypt_s,fit_s,fit_s_per100obs,data_mb,data_mb_per100obs,correct",
    );
    for p_vars in [2usize, 25] {
        for iters in 1..=3usize {
            let c = measure(1201 + iters as u64, n, p_vars, iters)?;
            let scale = 100.0 / n as f64;
            let mb = c.data_bytes as f64 / (1024.0 * 1024.0);
            csv.row(&[
                p_vars.to_string(),
                iters.to_string(),
                (2 * iters).to_string(),
                c.d.to_string(),
                c.q_bits.to_string(),
                f(c.keygen_s),
                f(c.encrypt_s),
                f(c.fit_s),
                f(c.fit_s * scale),
                f(mb),
                f(mb * scale),
                c.correct.to_string(),
            ]);
        }
    }
    Ok(vec![csv.finish()?])
}

/// Supplementary Figure 2: application runtime and memory (mood app at
/// full size; prostate at reduced K for tractable CI runtime).
pub fn sfig2(out: &Path) -> Result<Vec<PathBuf>> {
    let mut csv = Csv::new(
        out,
        "sfig2_application_costs.csv",
        "application,n,p,iters,keygen_s,encrypt_s,fit_s,data_mb,correct",
    );
    // Mood: the paper's real size (N = 28, P = 2, K = 2).
    {
        let mut rng = ChaChaRng::from_seed(1301);
        let patient = &mood::cohort(&mut rng, 1)[0];
        let q = QuantisedData::from_f64(&patient.pre.0, &patient.pre.1, 2);
        let (xq, _) = q.dequantised();
        let nu = nu_optimal(&xq);
        let params = plan(&PlanRequest::gd(q.n(), q.p(), 2, 2, nu))?;
        let ctx = FvContext::new(params);
        let t0 = Instant::now();
        let keys = keygen(&ctx, &mut rng);
        let kg = t0.elapsed().as_secs_f64();
        let t0 = Instant::now();
        let data = encrypt_dataset(&ctx, &keys.pk, &q, &mut rng);
        let enc = t0.elapsed().as_secs_f64();
        let engine = NativeEngine::new(ctx.clone(), Arc::new(keys.rk.clone()));
        let t0 = Instant::now();
        let fitted = fit(&engine, &DatasetRef::Scalar(&data), &FitConfig::gd(2, nu))?.fit;
        let fit_s = t0.elapsed().as_secs_f64();
        let dec = decrypt_coefficients(&ctx, &keys.sk, &fitted);
        let expect = exact::gd_exact(&q, nu, 2).decode_last();
        csv.row(&[
            "mood_ar2".into(),
            q.n().to_string(),
            q.p().to_string(),
            "2".into(),
            f(kg),
            f(enc),
            f(fit_s),
            f(data.size_bytes() as f64 / (1024.0 * 1024.0)),
            (linf(&dec, &expect) < 1e-9).to_string(),
        ]);
    }
    // Prostate-like: N = 97, P = 8, K = 1 encrypted spot (K = 4 costs
    // are extrapolated by the fig5 depth curve; see EXPERIMENTS.md).
    {
        let c = measure(1302, 97, 8, 1)?;
        csv.row(&[
            "prostate".into(),
            "97".into(),
            "8".into(),
            "1".into(),
            f(c.keygen_s),
            f(c.encrypt_s),
            f(c.fit_s),
            f(c.data_bytes as f64 / (1024.0 * 1024.0)),
            c.correct.to_string(),
        ]);
    }
    Ok(vec![csv.finish()?])
}
