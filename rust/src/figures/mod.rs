//! Regeneration of every table and figure in the paper's evaluation
//! (§6) as CSV files — see DESIGN.md §5 for the experiment index.
//!
//! Convergence figures use the exact/f64 backends (FHE evaluation is
//! exact, validated by the integration suite, so convergence behaviour
//! is identical and reproduction is fast); the computational-cost
//! figures (fig5, sfig2) run the real encrypted pipeline and measure
//! wall-clock and ciphertext memory.

mod apps;
mod enc;
mod sim;

use std::path::{Path, PathBuf};

use crate::util::error::{bail, Result};

/// CSV writer helper.
pub(crate) struct Csv {
    path: PathBuf,
    buf: String,
}

impl Csv {
    pub fn new(dir: &Path, name: &str, header: &str) -> Self {
        let mut buf = String::from(header);
        buf.push('\n');
        Csv { path: dir.join(name), buf }
    }

    pub fn row(&mut self, fields: &[String]) {
        self.buf.push_str(&fields.join(","));
        self.buf.push('\n');
    }

    pub fn finish(self) -> Result<PathBuf> {
        std::fs::write(&self.path, self.buf)?;
        Ok(self.path)
    }
}

pub(crate) fn f(v: f64) -> String {
    format!("{v:.6e}")
}

/// All known experiment ids, in paper order.
pub const ALL_IDS: [&str; 12] = [
    "fig1", "fig2", "fig3", "fig4", "fig5", "tab1", "fig6", "fig7", "fig8",
    "sfig1", "sfig2", "lemma3",
];

/// Run one experiment; returns the written CSV paths.
pub fn run(id: &str, out: &Path) -> Result<Vec<PathBuf>> {
    std::fs::create_dir_all(out)?;
    match id {
        "fig1" => sim::fig1(out),
        "fig2" => sim::fig2(out),
        "fig3" => sim::fig3(out),
        "fig4" => sim::fig4(out),
        "fig5" => enc::fig5(out),
        "tab1" => sim::tab1(out),
        "fig6" => apps::fig6(out),
        "fig7" => apps::fig7(out),
        "fig8" => apps::fig8(out),
        "sfig1" => sim::sfig1(out),
        "sfig2" => enc::sfig2(out),
        "lemma3" => sim::lemma3(out),
        _ => bail!("unknown experiment id '{id}' (known: {})", ALL_IDS.join(", ")),
    }
}

/// Run every experiment.
pub fn run_all(out: &Path) -> Result<Vec<PathBuf>> {
    let mut paths = Vec::new();
    for id in ALL_IDS {
        eprintln!("[figures] running {id} ...");
        paths.extend(run(id, out)?);
    }
    Ok(paths)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn unknown_id_errors() {
        let tmp = std::env::temp_dir().join("els-fig-test");
        assert!(run("nope", &tmp).is_err());
    }

    #[test]
    fn cheap_figures_produce_csv() {
        let tmp = std::env::temp_dir().join(format!("els-fig-{}", std::process::id()));
        for id in ["tab1", "sfig1", "lemma3"] {
            let paths = run(id, &tmp).unwrap();
            for p in paths {
                let text = std::fs::read_to_string(&p).unwrap();
                assert!(text.lines().count() > 1, "{id}: empty CSV");
            }
        }
        let _ = std::fs::remove_dir_all(&tmp);
    }
}
