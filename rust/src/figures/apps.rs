//! Application figures (paper §6.2): mood stability (Figure 6) and
//! prostate cancer (Figures 7–8), on the synthetic structural
//! equivalents of the paper's datasets (DESIGN.md §6 Substitutions).

use std::path::{Path, PathBuf};

use crate::util::error::Result;

use crate::data::{mood, prostate};
use crate::els::exact::{gd_exact, vwt_exact, QuantisedData};
use crate::els::float_ref::{linf, nag_path, ols, ridge, ridge_df, rms};
use crate::els::model::quantise_ridge_augmented;
use crate::els::scaling::ratio_f64;
use crate::els::stepsize::nu_optimal;
use crate::fhe::rng::ChaChaRng;

use super::{f, Csv};

/// Figure 6: mood-stability AR(2) convergence pre/post treatment
/// (patient-level; the paper shows patient 8, we emit patient 0 of the
/// synthetic cohort). Exact encoded-integer backend: identical to the
/// encrypted run.
pub fn fig6(out: &Path) -> Result<Vec<PathBuf>> {
    let mut rng = ChaChaRng::from_seed(1101);
    let patient = &mood::cohort(&mut rng, 1)[0];
    let mut csv = Csv::new(
        out,
        "fig6_mood_convergence.csv",
        "phase,algorithm,k,beta_lag1,beta_lag2,linf_vs_ols",
    );
    for (phase, (x, y)) in [("pre", &patient.pre), ("post", &patient.post)] {
        let q = QuantisedData::from_f64(x, y, 2);
        let (xq, yq) = q.dequantised();
        let truth = ols(&xq, &yq);
        let nu = nu_optimal(&xq);
        let iters = 6;
        // Exact encrypted-equivalent GD path.
        let path = gd_exact(&q, nu, iters);
        for k in 1..=iters {
            let b = path.decode(k - 1);
            csv.row(&[
                phase.into(),
                "gd".into(),
                k.to_string(),
                f(b[0]),
                f(b[1]),
                f(linf(&b, &truth)),
            ]);
        }
        // VWT estimate at each K.
        for k in 2..=iters {
            let (acc, div) = vwt_exact(&q, nu, k);
            let b: Vec<f64> = acc.iter().map(|v| ratio_f64(v, &div)).collect();
            csv.row(&[
                phase.into(),
                "gd_vwt".into(),
                k.to_string(),
                f(b[0]),
                f(b[1]),
                f(linf(&b, &truth)),
            ]);
        }
        // NAG (f64, quantised data).
        for (k, b) in nag_path(&xq, &yq, 1.0 / nu as f64, iters).iter().enumerate() {
            csv.row(&[
                phase.into(),
                "nag".into(),
                (k + 1).to_string(),
                f(b[0]),
                f(b[1]),
                f(linf(b, &truth)),
            ]);
        }
        // OLS reference.
        csv.row(&[phase.into(), "ols".into(), "0".into(), f(truth[0]), f(truth[1]), f(0.0)]);
    }
    Ok(vec![csv.finish()?])
}

/// Figure 7: prostate convergence with and without regularisation
/// (α ∈ {0, 30}), N = 97, P = 8, ELS-GD-VWT.
pub fn fig7(out: &Path) -> Result<Vec<PathBuf>> {
    let mut rng = ChaChaRng::from_seed(1102);
    let (x, y) = prostate::paper_size(&mut rng);
    let mut csv = Csv::new(
        out,
        "fig7_prostate_convergence.csv",
        "alpha,algorithm,k,linf_vs_target,rms_vs_target",
    );
    for alpha in [0.0f64, 30.0] {
        let q = quantise_ridge_augmented(&x, &y, alpha, 2);
        let (xq, yq) = q.dequantised();
        // Target: RLS on the (quantised) original data = OLS on augmented.
        let target = ols(&xq, &yq);
        let nu = nu_optimal(&xq);
        for k in 1..=8usize {
            let b = gd_exact(&q, nu, k).decode_last();
            csv.row(&[
                format!("{alpha}"),
                "gd".into(),
                k.to_string(),
                f(linf(&b, &target)),
                f(rms(&b, &target)),
            ]);
            if k >= 2 {
                let (acc, div) = vwt_exact(&q, nu, k);
                let bv: Vec<f64> = acc.iter().map(|v| ratio_f64(v, &div)).collect();
                csv.row(&[
                    format!("{alpha}"),
                    "gd_vwt".into(),
                    k.to_string(),
                    f(linf(&bv, &target)),
                    f(rms(&bv, &target)),
                ]);
            }
        }
    }
    Ok(vec![csv.finish()?])
}

/// Figure 8: predictions for the prostate data under
/// α ∈ {0, 15, 30} at K = 4 (GD-VWT) vs the closed-form RLS
/// predictions, plus effective degrees of freedom df(α).
pub fn fig8(out: &Path) -> Result<Vec<PathBuf>> {
    let mut rng = ChaChaRng::from_seed(1103);
    let (x, y) = prostate::paper_size(&mut rng);
    let mut csv = Csv::new(
        out,
        "fig8_prostate_predictions.csv",
        "alpha,df,obs,y_true,yhat_rls,yhat_els_k4",
    );
    let mut summary = Csv::new(
        out,
        "fig8_summary.csv",
        "alpha,df,pred_rms_els_vs_rls,coef_rms_els_vs_rls",
    );
    for alpha in [0.0f64, 15.0, 30.0] {
        let q = quantise_ridge_augmented(&x, &y, alpha, 2);
        let (xq, yq) = q.dequantised();
        let n_orig = x.len();
        let df = ridge_df(&xq[..n_orig].to_vec(), alpha);
        // Closed-form RLS on the quantised original data.
        let rls = ridge(&xq[..n_orig].to_vec(), &yq[..n_orig].to_vec(), alpha);
        // ELS-GD-VWT at K = 4 (the paper's setting), exact backend.
        let nu = nu_optimal(&xq);
        let (acc, div) = vwt_exact(&q, nu, 4);
        let els: Vec<f64> = acc.iter().map(|v| ratio_f64(v, &div)).collect();
        let mut pred_se = 0.0;
        for i in 0..n_orig {
            let yr: f64 = xq[i].iter().zip(&rls).map(|(a, b)| a * b).sum();
            let ye: f64 = xq[i].iter().zip(&els).map(|(a, b)| a * b).sum();
            pred_se += (yr - ye) * (yr - ye);
            if i < 20 {
                csv.row(&[
                    format!("{alpha}"),
                    f(df),
                    i.to_string(),
                    f(yq[i]),
                    f(yr),
                    f(ye),
                ]);
            }
        }
        summary.row(&[
            format!("{alpha}"),
            f(df),
            f((pred_se / n_orig as f64).sqrt()),
            f(rms(&els, &rls)),
        ]);
    }
    Ok(vec![csv.finish()?, summary.finish()?])
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmp() -> std::path::PathBuf {
        let d = std::env::temp_dir().join(format!("els-apps-{}", std::process::id()));
        std::fs::create_dir_all(&d).unwrap();
        d
    }

    #[test]
    fn fig6_converges_within_paper_tolerance() {
        // Paper: mood fits converge within 2 iterations (‖β^[2]‖ gap
        // ≤ 0.04-ish). Allow a looser structural check: error shrinks
        // and is small by k = 6.
        let dir = tmp();
        let p = fig6(&dir).unwrap();
        let text = std::fs::read_to_string(&p[0]).unwrap();
        let gd_errs: Vec<f64> = text
            .lines()
            .filter(|l| l.starts_with("pre,gd,"))
            .map(|l| l.split(',').nth(5).unwrap().parse().unwrap())
            .collect();
        assert!(gd_errs.last().unwrap() < &0.1, "{gd_errs:?}");
        assert!(gd_errs.last().unwrap() < gd_errs.first().unwrap());
    }

    #[test]
    fn fig8_ridge_shrinks_df_and_predictions_close() {
        let dir = tmp();
        let p = fig8(&dir).unwrap();
        let text = std::fs::read_to_string(&p[1]).unwrap();
        let rows: Vec<Vec<f64>> = text
            .lines()
            .skip(1)
            .map(|l| l.split(',').map(|s| s.parse().unwrap()).collect())
            .collect();
        // df decreases with α; df(0) = P = 8.
        assert!((rows[0][1] - 8.0).abs() < 1e-6);
        assert!(rows[2][1] < rows[1][1] && rows[1][1] < rows[0][1]);
        // Paper: K=4 predictions close to RLS even where coefficients
        // haven't fully converged (regularised cases converge faster).
        assert!(rows[2][2] < 0.2, "α=30 prediction gap {}", rows[2][2]);
    }
}
