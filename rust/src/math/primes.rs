//! Primality testing and NTT-friendly prime generation.
//!
//! The RNS bases used throughout the library consist of primes
//! `p ≡ 1 (mod 2d)` with `p < 2^30`, generated **deterministically** in
//! descending order from `2^30`. The Python AOT pipeline
//! (`python/compile/rns.py`) mirrors this rule exactly so that compiled
//! XLA artifacts and the Rust runtime always agree on the basis;
//! `artifacts/rns_meta.json` is cross-checked at load time.

use super::modarith::{mulmod, powmod};

/// Deterministic Miller–Rabin for `u64` using the canonical 12-base set,
/// which is provably correct for all inputs below `3.3 × 10^24`.
pub fn is_prime(n: u64) -> bool {
    if n < 2 {
        return false;
    }
    for &p in &[2u64, 3, 5, 7, 11, 13, 17, 19, 23, 29, 31, 37] {
        if n == p {
            return true;
        }
        if n % p == 0 {
            return false;
        }
    }
    // n - 1 = d * 2^s with d odd
    let mut d = n - 1;
    let mut s = 0u32;
    while d & 1 == 0 {
        d >>= 1;
        s += 1;
    }
    'witness: for &a in &[2u64, 3, 5, 7, 11, 13, 17, 19, 23, 29, 31, 37] {
        let mut x = powmod(a, d, n);
        if x == 1 || x == n - 1 {
            continue;
        }
        for _ in 0..s - 1 {
            x = mulmod(x, x, n);
            if x == n - 1 {
                continue 'witness;
            }
        }
        return false;
    }
    true
}

/// Upper bound (exclusive) for RNS primes: keeping residues below `2^30`
/// guarantees that `a * b` of canonical residues stays below `2^60`,
/// which both the Rust native backend and the XLA `i64` kernels rely on.
pub const RNS_PRIME_BOUND: u64 = 1 << 30;

/// Generate the first `count` primes `p ≡ 1 (mod modulus)` strictly below
/// `below`, in **descending** order. Panics if the supply is exhausted
/// (cannot happen for the `d ≤ 2^14` rings used here).
pub fn ntt_primes_below(below: u64, modulus: u64, count: usize) -> Vec<u64> {
    let mut out = Vec::with_capacity(count);
    // Largest candidate ≡ 1 (mod modulus) strictly below `below`.
    let mut c = (below - 2) / modulus * modulus + 1;
    while out.len() < count {
        assert!(c > modulus, "prime supply exhausted (modulus {modulus})");
        if is_prime(c) {
            out.push(c);
        }
        c -= modulus;
    }
    out
}

/// The standard RNS basis for ring degree `d`: `count` primes
/// `p ≡ 1 (mod 2d)` descending from [`RNS_PRIME_BOUND`].
pub fn rns_basis_primes(d: usize, count: usize) -> Vec<u64> {
    assert!(d.is_power_of_two(), "ring degree must be a power of two");
    ntt_primes_below(RNS_PRIME_BOUND, 2 * d as u64, count)
}

/// Find a generator of the multiplicative group `Z_p^*` (p prime).
pub fn primitive_root(p: u64) -> u64 {
    // Factor p - 1 by trial division (fine for 30-bit primes).
    let mut n = p - 1;
    let mut factors = Vec::new();
    let mut f = 2u64;
    while f * f <= n {
        if n % f == 0 {
            factors.push(f);
            while n % f == 0 {
                n /= f;
            }
        }
        f += 1;
    }
    if n > 1 {
        factors.push(n);
    }
    'outer: for g in 2..p {
        for &q in &factors {
            if powmod(g, (p - 1) / q, p) == 1 {
                continue 'outer;
            }
        }
        return g;
    }
    unreachable!("no primitive root found for prime {p}");
}

/// A primitive `2d`-th root of unity ψ modulo `p` (requires
/// `p ≡ 1 mod 2d`). Satisfies `ψ^d ≡ -1 (mod p)`.
pub fn primitive_2d_root(p: u64, d: usize) -> u64 {
    let order = 2 * d as u64;
    assert_eq!((p - 1) % order, 0, "p must be ≡ 1 mod 2d");
    let g = primitive_root(p);
    let psi = powmod(g, (p - 1) / order, p);
    debug_assert_eq!(powmod(psi, d as u64, p), p - 1);
    psi
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn small_primes() {
        let known = [2u64, 3, 5, 7, 11, 13, 17, 19, 23, 29, 31, 37, 41, 43];
        for n in 0..45u64 {
            assert_eq!(is_prime(n), known.contains(&n), "n = {n}");
        }
    }

    #[test]
    fn known_composites_and_primes() {
        assert!(is_prime(998_244_353)); // 119 * 2^23 + 1
        assert!(is_prime((1 << 30) - 35)); // 2^30 - 35 is prime
        assert!(!is_prime(1 << 30));
        assert!(!is_prime(3_215_031_751)); // strong pseudoprime to bases 2,3,5,7
        assert!(is_prime(0xffff_ffff_ffff_ffc5)); // largest u64 prime
    }

    #[test]
    fn ntt_primes_have_right_residue() {
        for d in [256usize, 1024, 8192] {
            let ps = rns_basis_primes(d, 8);
            assert_eq!(ps.len(), 8);
            for w in ps.windows(2) {
                assert!(w[0] > w[1], "descending order");
            }
            for &p in &ps {
                assert!(is_prime(p));
                assert!(p < RNS_PRIME_BOUND);
                assert_eq!(p % (2 * d as u64), 1);
            }
        }
    }

    #[test]
    fn deterministic_generation() {
        // The Python mirror relies on this being stable.
        let a = rns_basis_primes(4096, 4);
        let b = rns_basis_primes(4096, 4);
        assert_eq!(a, b);
        // First prime below 2^30 with p ≡ 1 mod 8192:
        assert!(a[0] % 8192 == 1 && is_prime(a[0]));
    }

    #[test]
    fn roots_of_unity() {
        for d in [8usize, 256, 4096] {
            let p = rns_basis_primes(d, 1)[0];
            let psi = primitive_2d_root(p, d);
            assert_eq!(powmod(psi, d as u64, p), p - 1, "ψ^d = -1");
            assert_eq!(powmod(psi, 2 * d as u64, p), 1, "ψ^2d = 1");
        }
    }
}
