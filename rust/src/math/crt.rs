//! Residue number systems: CRT lift/reduce between residue planes and
//! big integers.
//!
//! Ciphertext polynomials live as one `u64` residue plane per prime
//! (`RnsBasis`). The BFV multiply needs exact integer arithmetic across
//! the basis (tensor products in an extended basis, and the `⌊t·v/q⌉`
//! scale-and-round), which is done by lifting coefficients through the
//! explicit CRT formula `v = Σ_i [x_i·ŷ_i]_{p_i} · M_i  (mod M)` with
//! `M_i = M/p_i`, `ŷ_i = M_i^{-1} mod p_i` — all precomputed here.

use super::bigint::{BigInt, BigUint};
use super::modarith::{invmod_prime, BarrettConstant, ShoupConstant};

/// A fixed RNS basis: pairwise-distinct primes and CRT precomputation.
#[derive(Clone, Debug)]
pub struct RnsBasis {
    /// The primes `p_i`.
    pub primes: Vec<u64>,
    /// `M = Π p_i`.
    pub modulus: BigUint,
    /// `M_i = M / p_i`.
    pub crt_m: Vec<BigUint>,
    /// `ŷ_i = (M/p_i)^{-1} mod p_i`.
    pub crt_inv: Vec<u64>,
    /// Shoup companions of `ŷ_i` — every per-coefficient CRT/gadget
    /// product `x·ŷ_i mod p_i` (the lift loop, `relin_digits`) is an
    /// invariant-operand multiply.
    pub crt_inv_shoup: Vec<ShoupConstant>,
    /// Barrett reciprocal per prime — the plane-wide division-free
    /// path for variable×variable products and accumulator flushes.
    pub barrett: Vec<BarrettConstant>,
    /// `⌊M/2⌋` — the symmetric-representative threshold for
    /// [`lift_signed`](Self::lift_signed). (The `M_i mod p_j` residue
    /// tables used by fast base extension live in
    /// [`crate::math::baseconv::BaseConverter`], which is keyed per
    /// source→target basis pair rather than per basis.)
    pub half_modulus: BigUint,
}

impl RnsBasis {
    pub fn new(primes: Vec<u64>) -> Self {
        assert!(!primes.is_empty());
        let mut modulus = BigUint::one();
        for &p in &primes {
            modulus = modulus.mul_u64(p);
        }
        let mut crt_m = Vec::with_capacity(primes.len());
        let mut crt_inv = Vec::with_capacity(primes.len());
        let mut crt_inv_shoup = Vec::with_capacity(primes.len());
        let mut barrett = Vec::with_capacity(primes.len());
        for &p in &primes {
            let (mi, rem) = modulus.div_rem_u64(p);
            debug_assert_eq!(rem, 0);
            let mi_mod_p = mi.mod_u64(p);
            let inv = invmod_prime(mi_mod_p, p);
            crt_m.push(mi);
            crt_inv.push(inv);
            crt_inv_shoup.push(ShoupConstant::new(inv, p));
            barrett.push(BarrettConstant::new(p));
        }
        let half_modulus = modulus.shr_bits(1);
        RnsBasis { primes, modulus, crt_m, crt_inv, crt_inv_shoup, barrett, half_modulus }
    }

    pub fn len(&self) -> usize {
        self.primes.len()
    }

    pub fn is_empty(&self) -> bool {
        self.primes.is_empty()
    }

    /// Total modulus bit length.
    pub fn bits(&self) -> usize {
        self.modulus.bit_len()
    }

    /// CRT-lift one coefficient (residue per prime) to its canonical
    /// representative in `[0, M)`.
    pub fn lift(&self, residues: &[u64]) -> BigUint {
        debug_assert_eq!(residues.len(), self.len());
        let mut acc = BigUint::zero();
        for i in 0..self.len() {
            let c = self.crt_inv_shoup[i].mul(residues[i]);
            acc.add_mul_u64(&self.crt_m[i], c);
        }
        // acc < Σ p_i · M_i = L · M, so a few subtractions suffice.
        while acc.cmp_big(&self.modulus) != std::cmp::Ordering::Less {
            acc = acc.sub(&self.modulus);
        }
        acc
    }

    /// CRT-lift to the symmetric representative in `(-M/2, M/2]`.
    pub fn lift_signed(&self, residues: &[u64]) -> BigInt {
        let v = self.lift(residues);
        if v.cmp_big(&self.half_modulus) == std::cmp::Ordering::Greater {
            BigInt { neg: true, mag: self.modulus.sub(&v) }
        } else {
            BigInt::from_biguint(v)
        }
    }

    /// Reduce an unsigned big integer into residue form.
    pub fn reduce(&self, v: &BigUint) -> Vec<u64> {
        self.primes.iter().map(|&p| v.mod_u64(p)).collect()
    }

    /// Reduce a signed big integer into canonical residue form.
    pub fn reduce_signed(&self, v: &BigInt) -> Vec<u64> {
        self.primes.iter().map(|&p| v.mod_u64(p)).collect()
    }

    /// Reduce an `i64` into canonical residue form.
    pub fn reduce_i64(&self, v: i64) -> Vec<u64> {
        self.primes
            .iter()
            .map(|&p| {
                let r = v.rem_euclid(p as i64);
                r as u64
            })
            .collect()
    }

    /// Concatenate two bases (`self ∪ other`); primes must be disjoint.
    pub fn join(&self, other: &RnsBasis) -> RnsBasis {
        let mut primes = self.primes.clone();
        for &p in &other.primes {
            assert!(!primes.contains(&p), "bases must be disjoint");
            primes.push(p);
        }
        RnsBasis::new(primes)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::math::modarith::mulmod;
    use crate::math::primes::rns_basis_primes;
    use crate::util::prop::{gen, PropRunner};

    fn basis(l: usize) -> RnsBasis {
        RnsBasis::new(rns_basis_primes(256, l))
    }

    #[test]
    fn lift_reduce_roundtrip_small() {
        let b = basis(3);
        for v in [0u64, 1, 12345, u64::MAX] {
            let big = BigUint::from_u64(v);
            let lifted = b.lift(&b.reduce(&big));
            assert_eq!(lifted, big.rem_big(&b.modulus));
        }
    }

    #[test]
    fn lift_reduce_roundtrip_property() {
        let b = basis(5);
        let mut run = PropRunner::new("crt_roundtrip", 300);
        run.run(|rng| {
            // Random value below M via random residues.
            let residues: Vec<u64> =
                b.primes.iter().map(|&p| rng.uniform_below(p)).collect();
            let v = b.lift(&residues);
            assert!(v.cmp_big(&b.modulus) == std::cmp::Ordering::Less);
            assert_eq!(b.reduce(&v), residues, "reduce(lift(x)) == x");
        });
    }

    #[test]
    fn signed_lift_symmetry() {
        let b = basis(4);
        let mut run = PropRunner::new("crt_signed", 300);
        run.run(|rng| {
            let v = gen::int_in(rng, -1_000_000_000, 1_000_000_000);
            let residues = b.reduce_i64(v);
            let lifted = b.lift_signed(&residues);
            assert_eq!(lifted.to_i128(), Some(v as i128));
        });
    }

    #[test]
    fn crt_is_ring_homomorphism() {
        // lift(a·b mod p_i per-plane) == a·b mod M.
        let b = basis(4);
        let mut run = PropRunner::new("crt_homomorphism", 200);
        run.run(|rng| {
            let ra: Vec<u64> = b.primes.iter().map(|&p| rng.uniform_below(p)).collect();
            let rb: Vec<u64> = b.primes.iter().map(|&p| rng.uniform_below(p)).collect();
            let prod: Vec<u64> = (0..b.len())
                .map(|i| mulmod(ra[i], rb[i], b.primes[i]))
                .collect();
            let va = b.lift(&ra);
            let vb = b.lift(&rb);
            let expect = va.mul(&vb).rem_big(&b.modulus);
            assert_eq!(b.lift(&prod), expect);
        });
    }

    #[test]
    fn join_disjoint_bases() {
        let q = RnsBasis::new(rns_basis_primes(256, 3));
        let all = rns_basis_primes(256, 7);
        let ext = RnsBasis::new(all[3..].to_vec());
        let joined = q.join(&ext);
        assert_eq!(joined.len(), 7);
        assert_eq!(joined.modulus, q.modulus.mul(&ext.modulus));
    }

    #[test]
    #[should_panic(expected = "disjoint")]
    fn join_rejects_overlap() {
        let b = basis(2);
        let _ = b.join(&b);
    }
}
