//! Polynomials in `R_q = Z_q[x]/(x^d + 1)` stored as RNS residue planes.
//!
//! A [`RingContext`] bundles the ring degree, an [`RnsBasis`] and the
//! per-prime NTT tables; an [`RnsPoly`] is one `u64` plane per prime.
//! Polynomials carry a representation flag: `Coeff` (power basis) or
//! `Ntt` (evaluation basis). Additions work in either representation
//! (element-wise in both); multiplications require `Ntt`.
//!
//! Representation is a *managed property*, not an implicit invariant:
//! [`ensure_ntt`](RingContext::ensure_ntt) /
//! [`ensure_coeff`](RingContext::ensure_coeff) convert lazily,
//! [`add_mixed`](RingContext::add_mixed) /
//! [`sub_mixed`](RingContext::sub_mixed) reconcile mixed-rep operands
//! (coercing toward `Ntt`, the steady-state residency of the encrypted
//! descent loops), and every forward/inverse transform bumps a
//! per-ring counter ([`transform_count`](RingContext::transform_count))
//! so tests can assert that cached operands and NTT-resident
//! ciphertexts really skip transforms.

use std::borrow::Cow;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;

use super::crt::RnsBasis;
use super::modarith::{addmod, negmod, submod, ShoupConstant};
use super::ntt::NttTable;
use crate::util::pool::parallel_map_workers;
use crate::util::telemetry;

/// Hard cap on the number of `acc_mul_ntt` terms an [`NttAccumulator`]
/// may absorb before [`acc_reduce`](RingContext::acc_reduce): plane
/// products of canonical residues are `< 2^60` (primes `< 2^30`), so
/// `2^68` terms would be safe — `2^32` is a comfortably conservative
/// bound that still dwarfs any realistic limb count. (`u64`, not
/// `usize`: `1 << 32` must stay representable on 32-bit targets.)
pub const MAX_NTT_ACC_TERMS: u64 = 1 << 32;

/// Representation of a polynomial's planes.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Rep {
    /// Power-basis coefficients.
    Coeff,
    /// NTT evaluation values.
    Ntt,
}

/// Shared ring precomputation: degree, basis, NTT tables.
#[derive(Debug)]
pub struct RingContext {
    pub d: usize,
    pub basis: RnsBasis,
    pub tables: Vec<NttTable>,
    /// Forward + inverse transforms performed through this ring (one
    /// count per polynomial, not per limb) — the test hook behind the
    /// cached-operand / NTT-residency transform-budget assertions.
    transforms: AtomicU64,
    /// Relinearisation pipelines performed over this ring (one count
    /// per relinearised ciphertext, not per gadget limb) — the hook
    /// behind the fused-inner-product budget tests: a GD iteration
    /// under `dot_pairs` must relinearise `n+p` times, not `2·n·p`.
    relins: AtomicU64,
    /// `⌊t·v/q⌉` scale-and-round pipelines performed over this ring
    /// (one count per 3-component tensor brought back to Q — either a
    /// single ciphertext product or a whole fused accumulation chunk).
    scale_rounds: AtomicU64,
    /// Galois rotations (automorphism + key-switch) performed over this
    /// ring (one count per rotated ciphertext) — the hook behind the
    /// packed inner-product budget tests: `slot_sum` must cost
    /// O(log d) rotations, not O(n) pipelines.
    rotations: AtomicU64,
}

impl RingContext {
    pub fn new(d: usize, primes: Vec<u64>) -> Arc<Self> {
        let tables = primes.iter().map(|&p| NttTable::new(p, d)).collect();
        Arc::new(RingContext {
            d,
            basis: RnsBasis::new(primes),
            tables,
            transforms: AtomicU64::new(0),
            relins: AtomicU64::new(0),
            scale_rounds: AtomicU64::new(0),
            rotations: AtomicU64::new(0),
        })
    }

    /// Total forward + inverse NTTs this ring has performed (whole-poly
    /// granularity). Monotone; diff two snapshots around an operation
    /// to measure its transform budget.
    pub fn transform_count(&self) -> u64 {
        self.transforms.load(Ordering::Relaxed)
    }

    /// Relinearisation pipelines performed over this ring (see the
    /// field doc); diff two snapshots to measure an operation's
    /// relinearisation budget.
    pub fn relin_count(&self) -> u64 {
        self.relins.load(Ordering::Relaxed)
    }

    /// Scale-and-round pipelines performed over this ring (see the
    /// field doc).
    pub fn scale_round_count(&self) -> u64 {
        self.scale_rounds.load(Ordering::Relaxed)
    }

    /// Record one relinearisation pipeline (called by the FV ops layer;
    /// lives here so the counter sits alongside [`transform_count`]).
    pub fn note_relin(&self) {
        self.relins.fetch_add(1, Ordering::Relaxed);
    }

    /// Record one scale-and-round pipeline.
    pub fn note_scale_round(&self) {
        self.scale_rounds.fetch_add(1, Ordering::Relaxed);
    }

    /// Galois rotations performed over this ring (see the field doc).
    pub fn rotation_count(&self) -> u64 {
        self.rotations.load(Ordering::Relaxed)
    }

    /// Record one Galois rotation (automorphism + key-switch).
    pub fn note_rotation(&self) {
        self.rotations.fetch_add(1, Ordering::Relaxed);
    }

    pub fn nlimbs(&self) -> usize {
        self.basis.len()
    }

    /// All-zero polynomial in coefficient representation.
    pub fn zero(&self) -> RnsPoly {
        RnsPoly {
            d: self.d,
            planes: vec![vec![0u64; self.d]; self.nlimbs()],
            rep: Rep::Coeff,
        }
    }

    /// Polynomial from signed coefficients (length ≤ d).
    pub fn from_signed_coeffs(&self, coeffs: &[i64]) -> RnsPoly {
        assert!(coeffs.len() <= self.d, "coefficient vector longer than ring degree");
        let mut poly = self.zero();
        for (l, &p) in self.basis.primes.iter().enumerate() {
            for (i, &c) in coeffs.iter().enumerate() {
                poly.planes[l][i] = c.rem_euclid(p as i64) as u64;
            }
        }
        poly
    }

    /// Forward NTT in place.
    pub fn ntt_forward(&self, poly: &mut RnsPoly) {
        self.ntt_forward_workers(poly, 1);
    }

    /// Inverse NTT in place.
    pub fn ntt_inverse(&self, poly: &mut RnsPoly) {
        self.ntt_inverse_workers(poly, 1);
    }

    /// Forward NTT with the limb planes fanned across up to `workers`
    /// threads. Bit-identical to the serial transform for any worker
    /// count (each plane is independent and order is preserved).
    pub fn ntt_forward_workers(&self, poly: &mut RnsPoly, workers: usize) {
        assert_eq!(poly.rep, Rep::Coeff, "poly already in NTT form");
        let _span = telemetry::span(telemetry::Phase::NttForward);
        self.transforms.fetch_add(1, Ordering::Relaxed);
        if workers <= 1 || self.nlimbs() == 1 {
            for (l, table) in self.tables.iter().enumerate() {
                table.forward(&mut poly.planes[l]);
            }
        } else {
            let planes = std::mem::take(&mut poly.planes);
            let jobs: Vec<(Vec<u64>, &NttTable)> =
                planes.into_iter().zip(self.tables.iter()).collect();
            poly.planes = parallel_map_workers(jobs, workers, |(mut pl, table)| {
                table.forward(&mut pl);
                pl
            });
        }
        poly.rep = Rep::Ntt;
    }

    /// Inverse NTT with the limb planes fanned across up to `workers`
    /// threads (see [`ntt_forward_workers`](Self::ntt_forward_workers)).
    pub fn ntt_inverse_workers(&self, poly: &mut RnsPoly, workers: usize) {
        assert_eq!(poly.rep, Rep::Ntt, "poly not in NTT form");
        let _span = telemetry::span(telemetry::Phase::NttInverse);
        self.transforms.fetch_add(1, Ordering::Relaxed);
        if workers <= 1 || self.nlimbs() == 1 {
            for (l, table) in self.tables.iter().enumerate() {
                table.inverse(&mut poly.planes[l]);
            }
        } else {
            let planes = std::mem::take(&mut poly.planes);
            let jobs: Vec<(Vec<u64>, &NttTable)> =
                planes.into_iter().zip(self.tables.iter()).collect();
            poly.planes = parallel_map_workers(jobs, workers, |(mut pl, table)| {
                table.inverse(&mut pl);
                pl
            });
        }
        poly.rep = Rep::Coeff;
    }

    /// Lazily bring a polynomial to NTT form (no-op when already there).
    pub fn ensure_ntt(&self, poly: &mut RnsPoly) {
        if poly.rep == Rep::Coeff {
            self.ntt_forward(poly);
        }
    }

    /// Lazily bring a polynomial to coefficient form (no-op when
    /// already there).
    pub fn ensure_coeff(&self, poly: &mut RnsPoly) {
        if poly.rep == Rep::Ntt {
            self.ntt_inverse(poly);
        }
    }

    /// Borrow `poly` if it is already in NTT form, else a converted
    /// clone — the read-only counterpart of [`ensure_ntt`](Self::ensure_ntt).
    pub fn ntt_form<'a>(&self, poly: &'a RnsPoly) -> Cow<'a, RnsPoly> {
        match poly.rep {
            Rep::Ntt => Cow::Borrowed(poly),
            Rep::Coeff => {
                let mut c = poly.clone();
                self.ntt_forward(&mut c);
                Cow::Owned(c)
            }
        }
    }

    /// Borrow `poly` if it is already in coefficient form, else a
    /// converted clone.
    pub fn coeff_form<'a>(&self, poly: &'a RnsPoly) -> Cow<'a, RnsPoly> {
        match poly.rep {
            Rep::Coeff => Cow::Borrowed(poly),
            Rep::Ntt => {
                let mut c = poly.clone();
                self.ntt_inverse(&mut c);
                Cow::Owned(c)
            }
        }
    }

    /// `a + b` (must share representation).
    pub fn add(&self, a: &RnsPoly, b: &RnsPoly) -> RnsPoly {
        assert_eq!(a.rep, b.rep);
        let mut out = a.clone();
        for (l, &p) in self.basis.primes.iter().enumerate() {
            for i in 0..self.d {
                out.planes[l][i] = addmod(out.planes[l][i], b.planes[l][i], p);
            }
        }
        out
    }

    pub fn add_assign(&self, a: &mut RnsPoly, b: &RnsPoly) {
        assert_eq!(a.rep, b.rep);
        for (l, &p) in self.basis.primes.iter().enumerate() {
            for i in 0..self.d {
                a.planes[l][i] = addmod(a.planes[l][i], b.planes[l][i], p);
            }
        }
    }

    /// `a - b` (must share representation).
    pub fn sub(&self, a: &RnsPoly, b: &RnsPoly) -> RnsPoly {
        assert_eq!(a.rep, b.rep);
        let mut out = a.clone();
        for (l, &p) in self.basis.primes.iter().enumerate() {
            for i in 0..self.d {
                out.planes[l][i] = submod(out.planes[l][i], b.planes[l][i], p);
            }
        }
        out
    }

    /// `a + b` with representation reconciliation: same-rep operands
    /// add directly (in whichever rep they share); mixed-rep operands
    /// coerce the `Coeff` side to `Ntt` (the NTT residency is the
    /// steady state of the descent loops, so the forward transform
    /// paid here is one a later multiply would have paid anyway).
    /// Exact in both domains — the NTT is a bijective linear map.
    pub fn add_mixed(&self, a: &RnsPoly, b: &RnsPoly) -> RnsPoly {
        if a.rep == b.rep {
            return self.add(a, b);
        }
        let (mut out, resident) = if a.rep == Rep::Ntt { (b.clone(), a) } else { (a.clone(), b) };
        self.ntt_forward(&mut out);
        self.add_assign(&mut out, resident);
        out
    }

    /// `a - b` with representation reconciliation (see
    /// [`add_mixed`](Self::add_mixed) for the coercion policy).
    pub fn sub_mixed(&self, a: &RnsPoly, b: &RnsPoly) -> RnsPoly {
        if a.rep == b.rep {
            return self.sub(a, b);
        }
        if a.rep == Rep::Coeff {
            let mut an = a.clone();
            self.ntt_forward(&mut an);
            self.sub(&an, b)
        } else {
            let mut bn = b.clone();
            self.ntt_forward(&mut bn);
            self.sub(a, &bn)
        }
    }

    /// `-a`.
    pub fn neg(&self, a: &RnsPoly) -> RnsPoly {
        let mut out = a.clone();
        for (l, &p) in self.basis.primes.iter().enumerate() {
            for x in out.planes[l].iter_mut() {
                *x = negmod(*x, p);
            }
        }
        out
    }

    /// Pointwise product (both operands must be in NTT form).
    pub fn mul_ntt(&self, a: &RnsPoly, b: &RnsPoly) -> RnsPoly {
        assert_eq!(a.rep, Rep::Ntt);
        assert_eq!(b.rep, Rep::Ntt);
        let mut out = a.clone();
        for (l, br) in self.basis.barrett.iter().enumerate() {
            for i in 0..self.d {
                out.planes[l][i] = br.mulmod(out.planes[l][i], b.planes[l][i]);
            }
        }
        out
    }

    /// `acc += a ∘ b` fused (NTT form) — inner-product accumulation
    /// with one Barrett reduction per product. For sums of many terms,
    /// prefer the lazy [`NttAccumulator`] (`acc_mul_ntt`/`acc_reduce`),
    /// which pays a single reduction per coefficient for the whole sum.
    pub fn mul_ntt_acc(&self, acc: &mut RnsPoly, a: &RnsPoly, b: &RnsPoly) {
        assert_eq!(acc.rep, Rep::Ntt);
        assert_eq!(a.rep, Rep::Ntt);
        assert_eq!(b.rep, Rep::Ntt);
        for (l, br) in self.basis.barrett.iter().enumerate() {
            let p = br.modulus();
            for i in 0..self.d {
                let prod = br.mulmod(a.planes[l][i], b.planes[l][i]);
                acc.planes[l][i] = addmod(acc.planes[l][i], prod, p);
            }
        }
    }

    /// Fresh all-zero lazy accumulator for NTT-domain inner products.
    pub fn ntt_accumulator(&self) -> NttAccumulator {
        NttAccumulator {
            d: self.d,
            planes: vec![vec![0u128; self.d]; self.nlimbs()],
            terms: 0,
        }
    }

    /// `acc += a ∘ b` with **no** modular reduction: canonical-residue
    /// products (`< 2^60`) are summed in `u128`, so the whole
    /// inner-product sum — e.g. all relinearisation limbs — costs one
    /// reduction per coefficient at [`acc_reduce`](Self::acc_reduce)
    /// instead of one per limb.
    pub fn acc_mul_ntt(&self, acc: &mut NttAccumulator, a: &RnsPoly, b: &RnsPoly) {
        assert_eq!(a.rep, Rep::Ntt);
        assert_eq!(b.rep, Rep::Ntt);
        assert_eq!(acc.d, self.d);
        assert!((acc.terms as u64) < MAX_NTT_ACC_TERMS, "NTT accumulator would overflow u128");
        for (l, plane) in acc.planes.iter_mut().enumerate() {
            let (pa, pb) = (&a.planes[l], &b.planes[l]);
            for i in 0..self.d {
                plane[i] += pa[i] as u128 * pb[i] as u128;
            }
        }
        acc.terms += 1;
    }

    /// Flush a lazy accumulator: one Barrett reduction per coefficient
    /// brings every plane back to canonical residues (NTT rep).
    pub fn acc_reduce(&self, acc: &NttAccumulator) -> RnsPoly {
        assert_eq!(acc.d, self.d);
        let mut out = self.zero();
        out.rep = Rep::Ntt;
        for (l, br) in self.basis.barrett.iter().enumerate() {
            for i in 0..self.d {
                out.planes[l][i] = br.reduce(acc.planes[l][i]);
            }
        }
        out
    }

    /// Full negacyclic product of two coefficient-form polynomials.
    pub fn polymul(&self, a: &RnsPoly, b: &RnsPoly) -> RnsPoly {
        let mut fa = a.clone();
        let mut fb = b.clone();
        self.ntt_forward(&mut fa);
        self.ntt_forward(&mut fb);
        let mut out = self.mul_ntt(&fa, &fb);
        self.ntt_inverse(&mut out);
        out
    }

    /// Multiply by a small scalar (same representation). The scalar is
    /// invariant across the plane, so one Shoup precompute per prime
    /// makes the per-coefficient loop division-free.
    pub fn mul_scalar(&self, a: &RnsPoly, s: u64) -> RnsPoly {
        let mut out = a.clone();
        for (l, &p) in self.basis.primes.iter().enumerate() {
            let sc = ShoupConstant::new(s % p, p);
            for x in out.planes[l].iter_mut() {
                *x = sc.mul(*x);
            }
        }
        out
    }

    /// Multiply by a scalar given in residue form (one canonical value
    /// per prime).
    pub fn mul_scalar_rns(&self, a: &RnsPoly, s: &[u64]) -> RnsPoly {
        assert_eq!(s.len(), self.nlimbs());
        let mut out = a.clone();
        for (l, &p) in self.basis.primes.iter().enumerate() {
            let sc = ShoupConstant::new(s[l], p);
            for x in out.planes[l].iter_mut() {
                *x = sc.mul(*x);
            }
        }
        out
    }

    /// Galois automorphism `x → x^g` (`g` odd) on a coefficient-form
    /// polynomial: coefficient `i` moves to index `(i·g) mod 2d`,
    /// negated when the index wraps past `d` (since `x^d = −1`). A ring
    /// homomorphism of `R_q`, applied plane-wise; the FV ops layer
    /// key-switches the result back under the original secret key.
    pub fn automorphism(&self, a: &RnsPoly, g: usize) -> RnsPoly {
        assert_eq!(a.rep, Rep::Coeff, "automorphism needs coefficient form");
        assert_eq!(g % 2, 1, "Galois element must be odd (a unit mod 2d)");
        let m = 2 * self.d;
        let mut out = self.zero();
        for (l, &p) in self.basis.primes.iter().enumerate() {
            for i in 0..self.d {
                let e = (i * (g % m)) % m;
                let v = a.planes[l][i];
                if e < self.d {
                    out.planes[l][e] = v;
                } else {
                    out.planes[l][e - self.d] = negmod(v, p);
                }
            }
        }
        out
    }

    /// Sample a uniform polynomial in `R_q` (coefficient rep).
    pub fn sample_uniform(&self, rng: &mut crate::fhe::rng::ChaChaRng) -> RnsPoly {
        let mut out = self.zero();
        for (l, &p) in self.basis.primes.iter().enumerate() {
            rng.fill_uniform_mod(&mut out.planes[l], p);
        }
        out
    }
}

/// A lazily-accumulated NTT-domain inner product: `u128` sums of
/// residue products per coefficient, reduced once by
/// [`RingContext::acc_reduce`]. Created by
/// [`RingContext::ntt_accumulator`]; the term counter enforces the
/// (enormous) `u128` headroom bound [`MAX_NTT_ACC_TERMS`].
#[derive(Clone, Debug)]
pub struct NttAccumulator {
    d: usize,
    planes: Vec<Vec<u128>>,
    terms: usize,
}

impl NttAccumulator {
    /// Number of `acc_mul_ntt` terms absorbed so far.
    pub fn terms(&self) -> usize {
        self.terms
    }

    /// True when this accumulator was built for a ring of `nplanes`
    /// limbs and degree `d` (scratch-reuse shape check).
    pub fn matches(&self, nplanes: usize, d: usize) -> bool {
        self.d == d && self.planes.len() == nplanes
    }

    /// Zero every plane and the term counter, keeping the allocation —
    /// how the fused inner-product scratch reuses accumulators across
    /// chunks instead of reallocating `nplanes·d` `u128` words each.
    pub fn reset(&mut self) {
        for plane in self.planes.iter_mut() {
            plane.fill(0);
        }
        self.terms = 0;
    }
}

/// One polynomial: `planes[l][i]` = coefficient i mod prime l.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct RnsPoly {
    pub d: usize,
    pub planes: Vec<Vec<u64>>,
    pub rep: Rep,
}

impl RnsPoly {
    pub fn is_zero(&self) -> bool {
        self.planes.iter().all(|pl| pl.iter().all(|&x| x == 0))
    }

    /// Approximate heap size in bytes (for the fig5 memory accounting).
    pub fn size_bytes(&self) -> usize {
        self.planes.len() * self.d * std::mem::size_of::<u64>()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fhe::rng::ChaChaRng;
    use crate::math::primes::rns_basis_primes;

    fn ctx(d: usize, l: usize) -> Arc<RingContext> {
        RingContext::new(d, rns_basis_primes(d, l))
    }

    #[test]
    fn add_sub_neg_roundtrip() {
        let ctx = ctx(64, 3);
        let mut rng = ChaChaRng::from_seed(11);
        let a = ctx.sample_uniform(&mut rng);
        let b = ctx.sample_uniform(&mut rng);
        let sum = ctx.add(&a, &b);
        assert_eq!(ctx.sub(&sum, &b), a);
        let z = ctx.add(&a, &ctx.neg(&a));
        assert!(z.is_zero());
    }

    #[test]
    fn polymul_matches_schoolbook_per_plane() {
        use crate::math::ntt::polymul_naive;
        let ctx = ctx(32, 2);
        let mut rng = ChaChaRng::from_seed(12);
        let a = ctx.sample_uniform(&mut rng);
        let b = ctx.sample_uniform(&mut rng);
        let c = ctx.polymul(&a, &b);
        for (l, &p) in ctx.basis.primes.iter().enumerate() {
            assert_eq!(c.planes[l], polymul_naive(&a.planes[l], &b.planes[l], p));
        }
    }

    #[test]
    fn signed_coeff_encoding() {
        let ctx = ctx(16, 2);
        let poly = ctx.from_signed_coeffs(&[-1, 0, 1, -5]);
        for (l, &p) in ctx.basis.primes.iter().enumerate() {
            assert_eq!(poly.planes[l][0], p - 1);
            assert_eq!(poly.planes[l][1], 0);
            assert_eq!(poly.planes[l][2], 1);
            assert_eq!(poly.planes[l][3], p - 5);
        }
    }

    #[test]
    fn mul_by_one_scalar_is_identity() {
        let ctx = ctx(32, 3);
        let mut rng = ChaChaRng::from_seed(13);
        let a = ctx.sample_uniform(&mut rng);
        assert_eq!(ctx.mul_scalar(&a, 1), a);
    }

    #[test]
    fn fused_accumulate_matches_separate() {
        let ctx = ctx(32, 2);
        let mut rng = ChaChaRng::from_seed(14);
        let mut a = ctx.sample_uniform(&mut rng);
        let mut b = ctx.sample_uniform(&mut rng);
        let mut c = ctx.sample_uniform(&mut rng);
        let mut d = ctx.sample_uniform(&mut rng);
        ctx.ntt_forward(&mut a);
        ctx.ntt_forward(&mut b);
        ctx.ntt_forward(&mut c);
        ctx.ntt_forward(&mut d);
        let mut acc = ctx.zero();
        acc.rep = Rep::Ntt;
        ctx.mul_ntt_acc(&mut acc, &a, &b);
        ctx.mul_ntt_acc(&mut acc, &c, &d);
        let expect = ctx.add(&ctx.mul_ntt(&a, &b), &ctx.mul_ntt(&c, &d));
        assert_eq!(acc, expect);
    }

    #[test]
    fn lazy_accumulator_matches_eager_path() {
        // The u128 lazy accumulator must agree with the per-term
        // reduced mul_ntt_acc across many limbs.
        let ctx = ctx(32, 3);
        let mut rng = ChaChaRng::from_seed(15);
        let mut lazy = ctx.ntt_accumulator();
        let mut eager = ctx.zero();
        eager.rep = Rep::Ntt;
        for _ in 0..8 {
            let mut a = ctx.sample_uniform(&mut rng);
            let mut b = ctx.sample_uniform(&mut rng);
            ctx.ntt_forward(&mut a);
            ctx.ntt_forward(&mut b);
            ctx.acc_mul_ntt(&mut lazy, &a, &b);
            ctx.mul_ntt_acc(&mut eager, &a, &b);
        }
        assert_eq!(lazy.terms(), 8);
        assert_eq!(ctx.acc_reduce(&lazy), eager);
    }

    #[test]
    fn lazy_accumulator_headroom_at_max_terms() {
        // Worst case per coefficient: MAX_NTT_ACC_TERMS products of
        // (2^30 − 1)² — the sum must fit u128 with room to spare.
        let max_prod = ((crate::math::primes::RNS_PRIME_BOUND - 1) as u128).pow(2);
        let total = max_prod.checked_mul(MAX_NTT_ACC_TERMS as u128);
        assert!(total.is_some(), "u128 accumulator bound violated");
        // And a dense worst-case accumulation reduces correctly.
        let ctx = ctx(4, 2);
        let mut worst = ctx.zero();
        worst.rep = Rep::Ntt;
        for (l, &p) in ctx.basis.primes.iter().enumerate() {
            for x in worst.planes[l].iter_mut() {
                *x = p - 1;
            }
        }
        let mut acc = ctx.ntt_accumulator();
        for _ in 0..100 {
            ctx.acc_mul_ntt(&mut acc, &worst, &worst);
        }
        let reduced = ctx.acc_reduce(&acc);
        for (l, &p) in ctx.basis.primes.iter().enumerate() {
            let expect = (100u128 * (p as u128 - 1) * (p as u128 - 1) % p as u128) as u64;
            assert!(reduced.planes[l].iter().all(|&x| x == expect));
        }
    }

    #[test]
    #[should_panic(expected = "left: Coeff")]
    fn mul_requires_ntt_form() {
        let ctx = ctx(16, 1);
        let a = ctx.zero();
        let _ = ctx.mul_ntt(&a, &a);
    }

    #[test]
    fn mixed_rep_add_sub_match_coeff_path() {
        let ctx = ctx(64, 3);
        let mut rng = ChaChaRng::from_seed(16);
        let a = ctx.sample_uniform(&mut rng);
        let b = ctx.sample_uniform(&mut rng);
        let sum_ref = ctx.add(&a, &b);
        let diff_ref = ctx.sub(&a, &b);
        // All four residency combinations must agree bit-for-bit after
        // normalising back to coefficient form.
        for (a_ntt, b_ntt) in [(false, false), (false, true), (true, false), (true, true)] {
            let mut av = a.clone();
            let mut bv = b.clone();
            if a_ntt {
                ctx.ntt_forward(&mut av);
            }
            if b_ntt {
                ctx.ntt_forward(&mut bv);
            }
            let mut sum = ctx.add_mixed(&av, &bv);
            ctx.ensure_coeff(&mut sum);
            assert_eq!(sum, sum_ref, "add a_ntt={a_ntt} b_ntt={b_ntt}");
            let mut diff = ctx.sub_mixed(&av, &bv);
            ctx.ensure_coeff(&mut diff);
            assert_eq!(diff, diff_ref, "sub a_ntt={a_ntt} b_ntt={b_ntt}");
        }
    }

    #[test]
    fn ensure_and_form_helpers_are_lazy() {
        let ctx = ctx(32, 2);
        let mut rng = ChaChaRng::from_seed(17);
        let a = ctx.sample_uniform(&mut rng);
        let t0 = ctx.transform_count();
        // Borrow path: already in the requested rep — zero transforms.
        assert!(matches!(ctx.coeff_form(&a), Cow::Borrowed(_)));
        assert_eq!(ctx.transform_count(), t0);
        // Convert path: one transform, original untouched.
        let an = ctx.ntt_form(&a);
        assert_eq!(an.rep, Rep::Ntt);
        assert_eq!(a.rep, Rep::Coeff);
        assert_eq!(ctx.transform_count(), t0 + 1);
        // ensure_* round trip is exact and counts both transforms.
        let mut v = a.clone();
        ctx.ensure_ntt(&mut v);
        ctx.ensure_ntt(&mut v); // no-op
        ctx.ensure_coeff(&mut v);
        assert_eq!(v, a);
        assert_eq!(ctx.transform_count(), t0 + 3);
    }

    #[test]
    fn automorphism_is_ring_homomorphism() {
        let ctx = ctx(16, 2);
        let mut rng = ChaChaRng::from_seed(19);
        let a = ctx.sample_uniform(&mut rng);
        let b = ctx.sample_uniform(&mut rng);
        for g in [1usize, 3, 9, 31] {
            let lhs = ctx.automorphism(&ctx.polymul(&a, &b), g);
            let rhs = ctx.polymul(&ctx.automorphism(&a, g), &ctx.automorphism(&b, g));
            assert_eq!(lhs, rhs, "g = {g}");
        }
        // σ_1 is the identity; σ_11 ∘ σ_3 = σ_33 = σ_1 (mod 2d = 32).
        assert_eq!(ctx.automorphism(&a, 1), a);
        assert_eq!(ctx.automorphism(&ctx.automorphism(&a, 3), 11), a);
    }

    #[test]
    fn plane_parallel_ntt_is_bit_identical() {
        let ctx = ctx(64, 4);
        let mut rng = ChaChaRng::from_seed(18);
        let a = ctx.sample_uniform(&mut rng);
        let mut serial = a.clone();
        ctx.ntt_forward_workers(&mut serial, 1);
        for workers in [2usize, 4, 8] {
            let mut par = a.clone();
            ctx.ntt_forward_workers(&mut par, workers);
            assert_eq!(par, serial, "forward workers = {workers}");
            ctx.ntt_inverse_workers(&mut par, workers);
            assert_eq!(par, a, "inverse workers = {workers}");
        }
    }
}
