//! Polynomials in `R_q = Z_q[x]/(x^d + 1)` stored as RNS residue planes.
//!
//! A [`RingContext`] bundles the ring degree, an [`RnsBasis`] and the
//! per-prime NTT tables; an [`RnsPoly`] is one `u64` plane per prime.
//! Polynomials carry a representation flag: `Coeff` (power basis) or
//! `Ntt` (evaluation basis). Additions work in either representation
//! (element-wise in both); multiplications require `Ntt`.

use std::sync::Arc;

use super::crt::RnsBasis;
use super::modarith::{addmod, mulmod, negmod, submod};
use super::ntt::NttTable;

/// Representation of a polynomial's planes.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Rep {
    /// Power-basis coefficients.
    Coeff,
    /// NTT evaluation values.
    Ntt,
}

/// Shared ring precomputation: degree, basis, NTT tables.
#[derive(Debug)]
pub struct RingContext {
    pub d: usize,
    pub basis: RnsBasis,
    pub tables: Vec<NttTable>,
}

impl RingContext {
    pub fn new(d: usize, primes: Vec<u64>) -> Arc<Self> {
        let tables = primes.iter().map(|&p| NttTable::new(p, d)).collect();
        Arc::new(RingContext { d, basis: RnsBasis::new(primes), tables })
    }

    pub fn nlimbs(&self) -> usize {
        self.basis.len()
    }

    /// All-zero polynomial in coefficient representation.
    pub fn zero(&self) -> RnsPoly {
        RnsPoly {
            d: self.d,
            planes: vec![vec![0u64; self.d]; self.nlimbs()],
            rep: Rep::Coeff,
        }
    }

    /// Polynomial from signed coefficients (length ≤ d).
    pub fn from_signed_coeffs(&self, coeffs: &[i64]) -> RnsPoly {
        assert!(coeffs.len() <= self.d, "coefficient vector longer than ring degree");
        let mut poly = self.zero();
        for (l, &p) in self.basis.primes.iter().enumerate() {
            for (i, &c) in coeffs.iter().enumerate() {
                poly.planes[l][i] = c.rem_euclid(p as i64) as u64;
            }
        }
        poly
    }

    /// Forward NTT in place.
    pub fn ntt_forward(&self, poly: &mut RnsPoly) {
        assert_eq!(poly.rep, Rep::Coeff, "poly already in NTT form");
        for (l, table) in self.tables.iter().enumerate() {
            table.forward(&mut poly.planes[l]);
        }
        poly.rep = Rep::Ntt;
    }

    /// Inverse NTT in place.
    pub fn ntt_inverse(&self, poly: &mut RnsPoly) {
        assert_eq!(poly.rep, Rep::Ntt, "poly not in NTT form");
        for (l, table) in self.tables.iter().enumerate() {
            table.inverse(&mut poly.planes[l]);
        }
        poly.rep = Rep::Coeff;
    }

    /// `a + b` (must share representation).
    pub fn add(&self, a: &RnsPoly, b: &RnsPoly) -> RnsPoly {
        assert_eq!(a.rep, b.rep);
        let mut out = a.clone();
        for (l, &p) in self.basis.primes.iter().enumerate() {
            for i in 0..self.d {
                out.planes[l][i] = addmod(out.planes[l][i], b.planes[l][i], p);
            }
        }
        out
    }

    pub fn add_assign(&self, a: &mut RnsPoly, b: &RnsPoly) {
        assert_eq!(a.rep, b.rep);
        for (l, &p) in self.basis.primes.iter().enumerate() {
            for i in 0..self.d {
                a.planes[l][i] = addmod(a.planes[l][i], b.planes[l][i], p);
            }
        }
    }

    /// `a - b` (must share representation).
    pub fn sub(&self, a: &RnsPoly, b: &RnsPoly) -> RnsPoly {
        assert_eq!(a.rep, b.rep);
        let mut out = a.clone();
        for (l, &p) in self.basis.primes.iter().enumerate() {
            for i in 0..self.d {
                out.planes[l][i] = submod(out.planes[l][i], b.planes[l][i], p);
            }
        }
        out
    }

    /// `-a`.
    pub fn neg(&self, a: &RnsPoly) -> RnsPoly {
        let mut out = a.clone();
        for (l, &p) in self.basis.primes.iter().enumerate() {
            for x in out.planes[l].iter_mut() {
                *x = negmod(*x, p);
            }
        }
        out
    }

    /// Pointwise product (both operands must be in NTT form).
    pub fn mul_ntt(&self, a: &RnsPoly, b: &RnsPoly) -> RnsPoly {
        assert_eq!(a.rep, Rep::Ntt);
        assert_eq!(b.rep, Rep::Ntt);
        let mut out = a.clone();
        for (l, &p) in self.basis.primes.iter().enumerate() {
            for i in 0..self.d {
                out.planes[l][i] = mulmod(out.planes[l][i], b.planes[l][i], p);
            }
        }
        out
    }

    /// `acc += a ∘ b` fused (NTT form) — inner-product accumulation.
    pub fn mul_ntt_acc(&self, acc: &mut RnsPoly, a: &RnsPoly, b: &RnsPoly) {
        assert_eq!(acc.rep, Rep::Ntt);
        assert_eq!(a.rep, Rep::Ntt);
        assert_eq!(b.rep, Rep::Ntt);
        for (l, &p) in self.basis.primes.iter().enumerate() {
            for i in 0..self.d {
                let prod = mulmod(a.planes[l][i], b.planes[l][i], p);
                acc.planes[l][i] = addmod(acc.planes[l][i], prod, p);
            }
        }
    }

    /// Full negacyclic product of two coefficient-form polynomials.
    pub fn polymul(&self, a: &RnsPoly, b: &RnsPoly) -> RnsPoly {
        let mut fa = a.clone();
        let mut fb = b.clone();
        self.ntt_forward(&mut fa);
        self.ntt_forward(&mut fb);
        let mut out = self.mul_ntt(&fa, &fb);
        self.ntt_inverse(&mut out);
        out
    }

    /// Multiply by a small scalar (same representation).
    pub fn mul_scalar(&self, a: &RnsPoly, s: u64) -> RnsPoly {
        let mut out = a.clone();
        for (l, &p) in self.basis.primes.iter().enumerate() {
            let sp = s % p;
            for x in out.planes[l].iter_mut() {
                *x = mulmod(*x, sp, p);
            }
        }
        out
    }

    /// Multiply by a scalar given in residue form (one value per prime).
    pub fn mul_scalar_rns(&self, a: &RnsPoly, s: &[u64]) -> RnsPoly {
        assert_eq!(s.len(), self.nlimbs());
        let mut out = a.clone();
        for (l, &p) in self.basis.primes.iter().enumerate() {
            for x in out.planes[l].iter_mut() {
                *x = mulmod(*x, s[l], p);
            }
        }
        out
    }

    /// Sample a uniform polynomial in `R_q` (coefficient rep).
    pub fn sample_uniform(&self, rng: &mut crate::fhe::rng::ChaChaRng) -> RnsPoly {
        let mut out = self.zero();
        for (l, &p) in self.basis.primes.iter().enumerate() {
            rng.fill_uniform_mod(&mut out.planes[l], p);
        }
        out
    }
}

/// One polynomial: `planes[l][i]` = coefficient i mod prime l.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct RnsPoly {
    pub d: usize,
    pub planes: Vec<Vec<u64>>,
    pub rep: Rep,
}

impl RnsPoly {
    pub fn is_zero(&self) -> bool {
        self.planes.iter().all(|pl| pl.iter().all(|&x| x == 0))
    }

    /// Approximate heap size in bytes (for the fig5 memory accounting).
    pub fn size_bytes(&self) -> usize {
        self.planes.len() * self.d * std::mem::size_of::<u64>()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fhe::rng::ChaChaRng;
    use crate::math::primes::rns_basis_primes;

    fn ctx(d: usize, l: usize) -> Arc<RingContext> {
        RingContext::new(d, rns_basis_primes(d, l))
    }

    #[test]
    fn add_sub_neg_roundtrip() {
        let ctx = ctx(64, 3);
        let mut rng = ChaChaRng::from_seed(11);
        let a = ctx.sample_uniform(&mut rng);
        let b = ctx.sample_uniform(&mut rng);
        let sum = ctx.add(&a, &b);
        assert_eq!(ctx.sub(&sum, &b), a);
        let z = ctx.add(&a, &ctx.neg(&a));
        assert!(z.is_zero());
    }

    #[test]
    fn polymul_matches_schoolbook_per_plane() {
        use crate::math::ntt::polymul_naive;
        let ctx = ctx(32, 2);
        let mut rng = ChaChaRng::from_seed(12);
        let a = ctx.sample_uniform(&mut rng);
        let b = ctx.sample_uniform(&mut rng);
        let c = ctx.polymul(&a, &b);
        for (l, &p) in ctx.basis.primes.iter().enumerate() {
            assert_eq!(c.planes[l], polymul_naive(&a.planes[l], &b.planes[l], p));
        }
    }

    #[test]
    fn signed_coeff_encoding() {
        let ctx = ctx(16, 2);
        let poly = ctx.from_signed_coeffs(&[-1, 0, 1, -5]);
        for (l, &p) in ctx.basis.primes.iter().enumerate() {
            assert_eq!(poly.planes[l][0], p - 1);
            assert_eq!(poly.planes[l][1], 0);
            assert_eq!(poly.planes[l][2], 1);
            assert_eq!(poly.planes[l][3], p - 5);
        }
    }

    #[test]
    fn mul_by_one_scalar_is_identity() {
        let ctx = ctx(32, 3);
        let mut rng = ChaChaRng::from_seed(13);
        let a = ctx.sample_uniform(&mut rng);
        assert_eq!(ctx.mul_scalar(&a, 1), a);
    }

    #[test]
    fn fused_accumulate_matches_separate() {
        let ctx = ctx(32, 2);
        let mut rng = ChaChaRng::from_seed(14);
        let mut a = ctx.sample_uniform(&mut rng);
        let mut b = ctx.sample_uniform(&mut rng);
        let mut c = ctx.sample_uniform(&mut rng);
        let mut d = ctx.sample_uniform(&mut rng);
        ctx.ntt_forward(&mut a);
        ctx.ntt_forward(&mut b);
        ctx.ntt_forward(&mut c);
        ctx.ntt_forward(&mut d);
        let mut acc = ctx.zero();
        acc.rep = Rep::Ntt;
        ctx.mul_ntt_acc(&mut acc, &a, &b);
        ctx.mul_ntt_acc(&mut acc, &c, &d);
        let expect = ctx.add(&ctx.mul_ntt(&a, &b), &ctx.mul_ntt(&c, &d));
        assert_eq!(acc, expect);
    }

    #[test]
    #[should_panic(expected = "left: Coeff")]
    fn mul_requires_ntt_form() {
        let ctx = ctx(16, 1);
        let a = ctx.zero();
        let _ = ctx.mul_ntt(&a, &a);
    }
}
