//! Fast RNS base conversion: moving values between residue bases
//! without per-coefficient big-integer CRT lifts.
//!
//! Two converters cover the full-RNS BFV multiply
//! ([`crate::fhe::rns_mul`]):
//!
//! - [`BaseConverter`] — the *forward* extension `Q → B ∪ {m_sk}`. The
//!   explicit CRT sum `Σ_i y_i·M_i` (with `y_i = [x_i·ŷ_i]_{p_i}` and
//!   the `M_i mod p_j` residue tables precomputed) overshoots the true
//!   value by `α·M` for some `0 ≤ α < L`; the overshoot is recovered by
//!   64-bit fixed-point accumulation of `Σ y_i/p_i` in `u128`, rounded
//!   to nearest, which simultaneously selects the **centered**
//!   representative in `(−M/2, M/2]`. The correction is exact whenever
//!   the value is at least `L·M/2^64` away from the ±M/2 boundary —
//!   a `≥ 2^56` relative margin — and a boundary miss only shifts the
//!   operand by one multiple of `M`, which the FV noise analysis
//!   absorbs (see `fhe/rns_mul.rs`).
//! - [`ShenoyConverter`] — the *exact* Shenoy–Kumaresan conversion
//!   back `B → Q`. The pipeline carries a redundant-modulus residue
//!   plane `m_sk` alongside `B`, so the overshoot is recovered with
//!   pure integer arithmetic: `α′ = [(Σ y_j·B_j − x)·B^{-1}]_{m_sk}`
//!   equals `α + [x < 0] ≤ L_B ≪ m_sk` exactly (the redundant modulus
//!   plays the γ-correction role). No fixed point, no boundary cases.
//!
//! Both are mirrored bit-for-bit by `python/compile/rns.py`
//! (`base_convert_signed`, `shenoy_convert`).

use super::modarith::{invmod_prime, mulmod, submod, BarrettConstant, ShoupConstant};
use crate::util::pool::parallel_map_workers;

/// Split `0..d` into up to `workers` contiguous ranges (all non-empty).
fn coeff_ranges(d: usize, workers: usize) -> Vec<(usize, usize)> {
    let chunk = d.div_ceil(workers.max(1));
    (0..workers.max(1))
        .map(|w| (w * chunk, d.min((w + 1) * chunk)))
        .filter(|(s, e)| s < e)
        .collect()
}

/// Shared fan-out scaffolding for both converters: split the
/// coefficient range across `workers`, give each worker its own
/// `y`/`out` scratch (length `n_src`/`n_tgt`), run `convert(c, y, out)`
/// per coefficient, and stitch the per-range columns back into the
/// plane-major `out_planes`. Output order is the input order, so the
/// result is bit-identical to a serial pass for any worker count.
fn fan_convert(
    d: usize,
    workers: usize,
    n_src: usize,
    n_tgt: usize,
    out_planes: &mut [Vec<u64>],
    convert: impl Fn(usize, &mut [u64], &mut [u64]) + Send + Sync,
) {
    let ranges = coeff_ranges(d, workers);
    let parts = parallel_map_workers(ranges.clone(), workers, |(s, e)| {
        let mut y = vec![0u64; n_src];
        let mut out = vec![0u64; n_tgt];
        let mut cols = vec![vec![0u64; e - s]; n_tgt];
        for c in s..e {
            convert(c, &mut y, &mut out);
            for (t, &v) in out.iter().enumerate() {
                cols[t][c - s] = v;
            }
        }
        cols
    });
    for ((s, e), cols) in ranges.into_iter().zip(parts) {
        for (t, col) in cols.into_iter().enumerate() {
            out_planes[t][s..e].copy_from_slice(&col);
        }
    }
}

/// Accumulator headroom: `Σ y_i·m_i < L·2^60` must fit `u128`, and the
/// fixed-point sum `Σ ⌊y_i·2^64/p_i⌋ < L·2^64` must too.
const MAX_SOURCE_LIMBS: usize = 256;

/// Product of a prime set modulo `m`, skipping index `skip`
/// (`usize::MAX` to include all). Avoids bigint at table-build time.
fn prod_mod(primes: &[u64], skip: usize, m: u64) -> u64 {
    let mut acc = 1u64 % m;
    for (i, &p) in primes.iter().enumerate() {
        if i != skip {
            acc = mulmod(acc, p % m, m);
        }
    }
    acc
}

/// Fast base extension with the fixed-point overshoot correction.
///
/// Converts the centered representative of a value given by its
/// residues in a source basis (product `M`) into residues modulo each
/// target prime. Source and target primes must be disjoint.
#[derive(Clone, Debug)]
pub struct BaseConverter {
    src: Vec<u64>,
    tgt: Vec<u64>,
    /// `ŷ_i = (M/p_i)^{-1} mod p_i` with Shoup companions — the
    /// invariant operand of the per-coefficient `x_i·ŷ_i` products.
    src_hat_inv: Vec<ShoupConstant>,
    /// Barrett reciprocal per source prime: exact `⌊y_i·2^64/p_i⌋`
    /// for the fixed-point α accumulation, no hardware division.
    src_barrett: Vec<BarrettConstant>,
    /// `m_table[i][t]` — residues of `M_i = M/p_i` mod each target
    /// prime (the table `crt.rs` reserves a doc slot for).
    m_table: Vec<Vec<u64>>,
    /// `M mod t` per target prime (Shoup form, multiplied by α).
    src_mod_tgt: Vec<ShoupConstant>,
    /// Barrett reciprocal per target prime (accumulator flush).
    tgt_barrett: Vec<BarrettConstant>,
}

impl BaseConverter {
    pub fn new(src: &[u64], tgt: &[u64]) -> Self {
        assert!(!src.is_empty() && !tgt.is_empty());
        assert!(src.len() <= MAX_SOURCE_LIMBS, "source basis too large");
        for p in src.iter().chain(tgt) {
            assert!(*p < 1 << 30, "RNS primes must stay below 2^30");
        }
        for t in tgt {
            assert!(!src.contains(t), "bases must be disjoint");
        }
        let src_hat_inv = (0..src.len())
            .map(|i| {
                ShoupConstant::new(invmod_prime(prod_mod(src, i, src[i]), src[i]), src[i])
            })
            .collect();
        let src_barrett = src.iter().map(|&p| BarrettConstant::new(p)).collect();
        let m_table = (0..src.len())
            .map(|i| tgt.iter().map(|&t| prod_mod(src, i, t)).collect())
            .collect();
        let src_mod_tgt = tgt
            .iter()
            .map(|&t| ShoupConstant::new(prod_mod(src, usize::MAX, t), t))
            .collect();
        let tgt_barrett = tgt.iter().map(|&t| BarrettConstant::new(t)).collect();
        BaseConverter {
            src: src.to_vec(),
            tgt: tgt.to_vec(),
            src_hat_inv,
            src_barrett,
            m_table,
            src_mod_tgt,
            tgt_barrett,
        }
    }

    /// Convert one coefficient. `y` is source-length scratch (avoids
    /// re-allocating inside the polynomial loop).
    #[inline]
    fn convert_one(&self, residues: impl Fn(usize) -> u64, y: &mut [u64], out: &mut [u64]) {
        // y_i = [x_i·ŷ_i]_{p_i}, accumulating Σ y_i/p_i in 64-bit
        // fixed point (each term exact to 2^-64, downward — the Barrett
        // div_rem quotient is bit-identical to the former `u128 /`).
        let mut s_fix: u128 = 0;
        for (i, sc) in self.src_hat_inv.iter().enumerate() {
            let yi = sc.mul(residues(i));
            y[i] = yi;
            s_fix += self.src_barrett[i].div_rem((yi as u128) << 64).0;
        }
        // Round to nearest: recovers the overshoot α and selects the
        // centered representative in one step.
        let alpha = ((s_fix + (1u128 << 63)) >> 64) as u64;
        for (t, &p) in self.tgt.iter().enumerate() {
            // Σ y_i·[M_i]_p in one u128 accumulator (products < 2^60,
            // ≤ 256 terms), single Barrett reduction at the end.
            let mut acc: u128 = 0;
            for (i, &yi) in y.iter().enumerate() {
                acc += yi as u128 * self.m_table[i][t] as u128;
            }
            let v = self.tgt_barrett[t].reduce(acc);
            out[t] = submod(v, self.src_mod_tgt[t].mul(alpha), p);
        }
    }

    /// Convert every coefficient of a plane-major polynomial
    /// (`src_planes[l][c]` = coefficient `c` mod source prime `l`) into
    /// the target planes.
    pub fn convert_signed(&self, src_planes: &[Vec<u64>], out_planes: &mut [Vec<u64>]) {
        assert_eq!(src_planes.len(), self.src.len());
        assert_eq!(out_planes.len(), self.tgt.len());
        let d = src_planes[0].len();
        let mut y = vec![0u64; self.src.len()];
        let mut out = vec![0u64; self.tgt.len()];
        for c in 0..d {
            self.convert_one(|i| src_planes[i][c], &mut y, &mut out);
            for (t, &v) in out.iter().enumerate() {
                out_planes[t][c] = v;
            }
        }
    }

    /// Single-value conversion (tests and the Python-mirror contract).
    pub fn convert_value(&self, residues: &[u64]) -> Vec<u64> {
        assert_eq!(residues.len(), self.src.len());
        let mut y = vec![0u64; self.src.len()];
        let mut out = vec![0u64; self.tgt.len()];
        self.convert_one(|i| residues[i], &mut y, &mut out);
        out
    }

    /// [`convert_signed`](Self::convert_signed) with the coefficient
    /// range fanned across up to `workers` threads (each conversion is
    /// per-coefficient independent, so the split is bit-identical to
    /// the serial pass for any worker count).
    pub fn convert_signed_workers(
        &self,
        src_planes: &[Vec<u64>],
        out_planes: &mut [Vec<u64>],
        workers: usize,
    ) {
        if workers <= 1 {
            return self.convert_signed(src_planes, out_planes);
        }
        assert_eq!(src_planes.len(), self.src.len());
        assert_eq!(out_planes.len(), self.tgt.len());
        let d = src_planes[0].len();
        fan_convert(d, workers, self.src.len(), self.tgt.len(), out_planes, |c, y, out| {
            self.convert_one(|i| src_planes[i][c], y, out)
        });
    }
}

/// Exact Shenoy–Kumaresan base conversion `B → tgt` using a redundant
/// modulus `m_sk` carried alongside the `B` planes.
///
/// The caller must guarantee `|x| < B/2` (the extension basis is sized
/// so the `⌊t·v/q⌉` output has ≥ 3 bits of slack) and supply `x mod
/// m_sk` exactly — both hold by construction in the multiply pipeline.
#[derive(Clone, Debug)]
pub struct ShenoyConverter {
    b: Vec<u64>,
    msk: u64,
    tgt: Vec<u64>,
    /// `(B/b_j)^{-1} mod b_j` (Shoup form — invariant operand).
    b_hat_inv: Vec<ShoupConstant>,
    /// `(B/b_j) mod m_sk`.
    b_hat_mod_msk: Vec<u64>,
    /// `b_hat_mod_tgt[j][t] = (B/b_j) mod tgt_t`.
    b_hat_mod_tgt: Vec<Vec<u64>>,
    /// `B^{-1} mod m_sk` (Shoup form).
    b_inv_mod_msk: ShoupConstant,
    /// `B mod tgt_t` (Shoup form, multiplied by α′).
    b_mod_tgt: Vec<ShoupConstant>,
    /// Barrett reciprocal of `m_sk` (redundant-plane accumulator flush).
    msk_barrett: BarrettConstant,
    /// Barrett reciprocal per target prime (accumulator flush).
    tgt_barrett: Vec<BarrettConstant>,
}

impl ShenoyConverter {
    pub fn new(b: &[u64], msk: u64, tgt: &[u64]) -> Self {
        assert!(!b.is_empty() && !tgt.is_empty());
        assert!(b.len() <= MAX_SOURCE_LIMBS, "source basis too large");
        assert!(!b.contains(&msk) && !tgt.contains(&msk), "m_sk must be fresh");
        for t in tgt {
            assert!(!b.contains(t), "bases must be disjoint");
        }
        let b_hat_inv = (0..b.len())
            .map(|j| ShoupConstant::new(invmod_prime(prod_mod(b, j, b[j]), b[j]), b[j]))
            .collect();
        let b_hat_mod_msk: Vec<u64> = (0..b.len()).map(|j| prod_mod(b, j, msk)).collect();
        let b_hat_mod_tgt = (0..b.len())
            .map(|j| tgt.iter().map(|&t| prod_mod(b, j, t)).collect())
            .collect();
        let b_inv_mod_msk =
            ShoupConstant::new(invmod_prime(prod_mod(b, usize::MAX, msk), msk), msk);
        let b_mod_tgt = tgt
            .iter()
            .map(|&t| ShoupConstant::new(prod_mod(b, usize::MAX, t), t))
            .collect();
        let msk_barrett = BarrettConstant::new(msk);
        let tgt_barrett = tgt.iter().map(|&t| BarrettConstant::new(t)).collect();
        ShenoyConverter {
            b: b.to_vec(),
            msk,
            tgt: tgt.to_vec(),
            b_hat_inv,
            b_hat_mod_msk,
            b_hat_mod_tgt,
            b_inv_mod_msk,
            b_mod_tgt,
            msk_barrett,
            tgt_barrett,
        }
    }

    #[inline]
    fn convert_one(
        &self,
        residues: impl Fn(usize) -> u64,
        res_msk: u64,
        y: &mut [u64],
        out: &mut [u64],
    ) {
        // y_j and the fast-conversion image of x at the redundant
        // modulus: Σ y_j·B_j ≡ x + (α + [x<0])·B (mod m_sk).
        let mut s_msk: u128 = 0;
        for (j, sc) in self.b_hat_inv.iter().enumerate() {
            let yj = sc.mul(residues(j));
            y[j] = yj;
            s_msk += yj as u128 * self.b_hat_mod_msk[j] as u128;
        }
        let s_msk = self.msk_barrett.reduce(s_msk);
        // γ-correction: the exact overshoot count, ≤ L_B ≪ m_sk.
        let alpha = self.b_inv_mod_msk.mul(submod(s_msk, res_msk, self.msk));
        debug_assert!(alpha as usize <= self.b.len(), "S-K overshoot out of range");
        for (t, &p) in self.tgt.iter().enumerate() {
            let mut acc: u128 = 0;
            for (j, &yj) in y.iter().enumerate() {
                acc += yj as u128 * self.b_hat_mod_tgt[j][t] as u128;
            }
            let v = self.tgt_barrett[t].reduce(acc);
            out[t] = submod(v, self.b_mod_tgt[t].mul(alpha), p);
        }
    }

    /// Convert plane-major `B` planes plus the `m_sk` plane into the
    /// target planes (exact for every coefficient).
    pub fn convert(
        &self,
        b_planes: &[Vec<u64>],
        msk_plane: &[u64],
        out_planes: &mut [Vec<u64>],
    ) {
        assert_eq!(b_planes.len(), self.b.len());
        assert_eq!(out_planes.len(), self.tgt.len());
        let d = msk_plane.len();
        let mut y = vec![0u64; self.b.len()];
        let mut out = vec![0u64; self.tgt.len()];
        for c in 0..d {
            self.convert_one(|j| b_planes[j][c], msk_plane[c], &mut y, &mut out);
            for (t, &v) in out.iter().enumerate() {
                out_planes[t][c] = v;
            }
        }
    }

    /// Single-value conversion (tests and the Python-mirror contract).
    pub fn convert_value(&self, residues: &[u64], res_msk: u64) -> Vec<u64> {
        assert_eq!(residues.len(), self.b.len());
        let mut y = vec![0u64; self.b.len()];
        let mut out = vec![0u64; self.tgt.len()];
        self.convert_one(|j| residues[j], res_msk, &mut y, &mut out);
        out
    }

    /// [`convert`](Self::convert) with the coefficient range fanned
    /// across up to `workers` threads (bit-identical for any count).
    pub fn convert_workers(
        &self,
        b_planes: &[Vec<u64>],
        msk_plane: &[u64],
        out_planes: &mut [Vec<u64>],
        workers: usize,
    ) {
        if workers <= 1 {
            return self.convert(b_planes, msk_plane, out_planes);
        }
        assert_eq!(b_planes.len(), self.b.len());
        assert_eq!(out_planes.len(), self.tgt.len());
        let d = msk_plane.len();
        fan_convert(d, workers, self.b.len(), self.tgt.len(), out_planes, |c, y, out| {
            self.convert_one(|j| b_planes[j][c], msk_plane[c], y, out)
        });
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::math::bigint::BigInt;
    use crate::math::crt::RnsBasis;
    use crate::math::primes::rns_basis_primes;
    use crate::util::prop::PropRunner;

    fn split(d: usize, l_src: usize, l_tgt: usize) -> (Vec<u64>, Vec<u64>, u64) {
        let all = rns_basis_primes(d, l_src + l_tgt + 1);
        (
            all[..l_src].to_vec(),
            all[l_src..l_src + l_tgt].to_vec(),
            all[l_src + l_tgt],
        )
    }

    #[test]
    fn forward_conversion_matches_signed_lift() {
        let (src, tgt, _) = split(256, 4, 5);
        let conv = BaseConverter::new(&src, &tgt);
        let basis = RnsBasis::new(src.clone());
        let tgt_basis = RnsBasis::new(tgt.clone());
        let mut run = PropRunner::new("baseconv_forward", 400);
        run.run(|rng| {
            // |x| < M/4 keeps the value inside the fixed-point guard
            // band (the pipeline's operands always have that headroom).
            let residues: Vec<u64> = src.iter().map(|&p| rng.uniform_below(p)).collect();
            let v = basis.lift(&residues).shr_bits(2);
            for neg in [false, true] {
                let x = BigInt { neg: neg && !v.is_zero(), mag: v.clone() };
                let got = conv.convert_value(&basis.reduce_signed(&x));
                assert_eq!(got, tgt_basis.reduce_signed(&x), "neg = {neg}");
            }
        });
    }

    #[test]
    fn forward_conversion_small_values_exact() {
        let (src, tgt, _) = split(256, 3, 4);
        let conv = BaseConverter::new(&src, &tgt);
        let basis = RnsBasis::new(src.clone());
        for v in [-1_000_000i64, -7, -1, 0, 1, 5, 123_456_789] {
            let got = conv.convert_value(&basis.reduce_i64(v));
            let expect: Vec<u64> =
                tgt.iter().map(|&p| v.rem_euclid(p as i64) as u64).collect();
            assert_eq!(got, expect, "v = {v}");
        }
    }

    #[test]
    fn shenoy_conversion_is_exact_everywhere() {
        let (b, tgt, msk) = split(256, 5, 3);
        let conv = ShenoyConverter::new(&b, msk, &tgt);
        let b_basis = RnsBasis::new(b.clone());
        let tgt_basis = RnsBasis::new(tgt.clone());
        let mut run = PropRunner::new("baseconv_shenoy", 400);
        run.run(|rng| {
            // Any value in (−B/2, B/2], including right at the
            // boundary — S-K has no boundary cases.
            let residues: Vec<u64> = b.iter().map(|&p| rng.uniform_below(p)).collect();
            let x = b_basis.lift_signed(&residues);
            let res_msk = x.mod_u64(msk);
            let got = conv.convert_value(&residues, res_msk);
            assert_eq!(got, tgt_basis.reduce_signed(&x));
        });
    }

    #[test]
    fn shenoy_handles_negative_extremes() {
        let (b, tgt, msk) = split(256, 4, 2);
        let conv = ShenoyConverter::new(&b, msk, &tgt);
        let b_basis = RnsBasis::new(b.clone());
        // −(B/2 − 1): deep negative, maximal overshoot correction.
        let half = b_basis.half_modulus.clone();
        let x = BigInt { neg: true, mag: half.sub(&crate::math::bigint::BigUint::one()) };
        let residues = b_basis.reduce_signed(&x);
        let got = conv.convert_value(&residues, x.mod_u64(msk));
        let expect: Vec<u64> = tgt.iter().map(|&p| x.mod_u64(p)).collect();
        assert_eq!(got, expect);
    }

    #[test]
    fn poly_conversion_matches_per_value() {
        let (src, tgt, msk) = split(64, 3, 3);
        let mut tgt_all = tgt.clone();
        tgt_all.push(msk);
        let conv = BaseConverter::new(&src, &tgt_all);
        let d = 64;
        let mut rng = crate::fhe::rng::ChaChaRng::from_seed(77);
        let src_planes: Vec<Vec<u64>> = src
            .iter()
            .map(|&p| (0..d).map(|_| rng.uniform_below(p)).collect())
            .collect();
        let mut out = vec![vec![0u64; d]; tgt_all.len()];
        conv.convert_signed(&src_planes, &mut out);
        for c in 0..d {
            let residues: Vec<u64> = (0..src.len()).map(|i| src_planes[i][c]).collect();
            let expect = conv.convert_value(&residues);
            for t in 0..tgt_all.len() {
                assert_eq!(out[t][c], expect[t], "coeff {c} target {t}");
            }
        }
    }

    #[test]
    fn worker_fanout_is_bit_identical() {
        // Both converters must produce the serial result for every
        // worker count, including counts beyond the coefficient range.
        let (src, tgt, msk) = split(64, 3, 3);
        let d = 64;
        let mut rng = crate::fhe::rng::ChaChaRng::from_seed(78);
        let fwd = {
            let mut tgt_all = tgt.clone();
            tgt_all.push(msk);
            BaseConverter::new(&src, &tgt_all)
        };
        let src_planes: Vec<Vec<u64>> = src
            .iter()
            .map(|&p| (0..d).map(|_| rng.uniform_below(p)).collect())
            .collect();
        let mut serial = vec![vec![0u64; d]; tgt.len() + 1];
        fwd.convert_signed(&src_planes, &mut serial);
        for workers in [2usize, 3, 7, 64, 100] {
            let mut par = vec![vec![0u64; d]; tgt.len() + 1];
            fwd.convert_signed_workers(&src_planes, &mut par, workers);
            assert_eq!(par, serial, "forward workers = {workers}");
        }
        // Shenoy: uniform B residues with the exact m_sk plane of their
        // signed lift (any value in (−B/2, B/2] is valid input).
        let back = ShenoyConverter::new(&tgt, msk, &src);
        let b_basis = RnsBasis::new(tgt.clone());
        let b_planes: Vec<Vec<u64>> = tgt
            .iter()
            .map(|&p| (0..d).map(|_| rng.uniform_below(p)).collect())
            .collect();
        let msk_plane: Vec<u64> = (0..d)
            .map(|c| {
                let residues: Vec<u64> =
                    (0..tgt.len()).map(|j| b_planes[j][c]).collect();
                b_basis.lift_signed(&residues).mod_u64(msk)
            })
            .collect();
        let mut back_serial = vec![vec![0u64; d]; src.len()];
        back.convert(&b_planes, &msk_plane, &mut back_serial);
        for workers in [2usize, 5, 64] {
            let mut par = vec![vec![0u64; d]; src.len()];
            back.convert_workers(&b_planes, &msk_plane, &mut par, workers);
            assert_eq!(par, back_serial, "shenoy workers = {workers}");
        }
    }

    #[test]
    #[should_panic(expected = "disjoint")]
    fn rejects_overlapping_bases() {
        let primes = rns_basis_primes(256, 3);
        let _ = BaseConverter::new(&primes, &primes);
    }
}
