//! Scalar modular arithmetic over `u64` moduli.
//!
//! All FV residue planes use primes `p < 2^31`, so products of canonical
//! residues fit comfortably in `u128`; these helpers are nevertheless
//! written to be correct for any `u64` modulus.

/// `(a + b) mod m`, assuming `a, b < m`.
#[inline(always)]
pub fn addmod(a: u64, b: u64, m: u64) -> u64 {
    debug_assert!(a < m && b < m);
    let s = a.wrapping_add(b);
    if s >= m || s < a {
        s.wrapping_sub(m)
    } else {
        s
    }
}

/// `(a - b) mod m`, assuming `a, b < m`.
#[inline(always)]
pub fn submod(a: u64, b: u64, m: u64) -> u64 {
    debug_assert!(a < m && b < m);
    if a >= b {
        a - b
    } else {
        a.wrapping_sub(b).wrapping_add(m)
    }
}

/// `(a * b) mod m` via a `u128` intermediate.
#[inline(always)]
pub fn mulmod(a: u64, b: u64, m: u64) -> u64 {
    ((a as u128 * b as u128) % m as u128) as u64
}

/// `-a mod m`, assuming `a < m`.
#[inline(always)]
pub fn negmod(a: u64, m: u64) -> u64 {
    if a == 0 {
        0
    } else {
        m - a
    }
}

/// `a^e mod m` by square-and-multiply.
pub fn powmod(mut a: u64, mut e: u64, m: u64) -> u64 {
    if m == 1 {
        return 0;
    }
    a %= m;
    let mut acc: u64 = 1;
    while e > 0 {
        if e & 1 == 1 {
            acc = mulmod(acc, a, m);
        }
        a = mulmod(a, a, m);
        e >>= 1;
    }
    acc
}

/// Modular inverse of `a` modulo prime `p` (Fermat). Panics if `a ≡ 0`.
pub fn invmod_prime(a: u64, p: u64) -> u64 {
    assert!(a % p != 0, "invmod_prime: zero has no inverse");
    powmod(a, p - 2, p)
}

/// Modular inverse for a general modulus via the extended Euclidean
/// algorithm. Returns `None` if `gcd(a, m) != 1`.
pub fn invmod(a: u64, m: u64) -> Option<u64> {
    let (mut old_r, mut r) = (a as i128, m as i128);
    let (mut old_s, mut s) = (1i128, 0i128);
    while r != 0 {
        let q = old_r / r;
        let tmp_r = old_r - q * r;
        old_r = r;
        r = tmp_r;
        let tmp_s = old_s - q * s;
        old_s = s;
        s = tmp_s;
    }
    if old_r != 1 {
        return None;
    }
    let mut inv = old_s % m as i128;
    if inv < 0 {
        inv += m as i128;
    }
    Some(inv as u64)
}

/// Centered (symmetric) representative of `a mod m` in
/// `(-m/2, m/2]`, returned as `i64`. Requires `m < 2^63`.
#[inline]
pub fn center(a: u64, m: u64) -> i64 {
    debug_assert!(a < m && m < (1 << 63));
    if a > m / 2 {
        a as i64 - m as i64
    } else {
        a as i64
    }
}

/// Canonical representative in `[0, m)` of a signed value.
#[inline]
pub fn from_signed(v: i64, m: u64) -> u64 {
    let r = v.rem_euclid(m as i64);
    r as u64
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn add_sub_roundtrip() {
        let m = 0xffff_fffb; // prime
        for &(a, b) in &[(0u64, 0u64), (1, m - 1), (m - 1, m - 1), (12345, 67890)] {
            let s = addmod(a % m, b % m, m);
            assert_eq!(submod(s, b % m, m), a % m);
        }
    }

    #[test]
    fn addmod_near_u64_max() {
        // Modulus close to u64::MAX exercises the wrap-detection branch.
        let m = u64::MAX - 58; // arbitrary large odd modulus
        assert_eq!(addmod(m - 1, m - 1, m), m - 2);
        assert_eq!(addmod(m - 1, 1, m), 0);
    }

    #[test]
    fn powmod_small_cases() {
        assert_eq!(powmod(2, 10, 1_000_003), 1024);
        assert_eq!(powmod(7, 0, 13), 1);
        assert_eq!(powmod(0, 5, 13), 0);
        assert_eq!(powmod(5, 1, 1), 0);
    }

    #[test]
    fn fermat_inverse() {
        let p = 998_244_353u64; // NTT prime
        for a in [1u64, 2, 3, 10, p - 1, 123_456_789] {
            let inv = invmod_prime(a, p);
            assert_eq!(mulmod(a, inv, p), 1);
        }
    }

    #[test]
    fn general_inverse() {
        assert_eq!(invmod(3, 10), Some(7));
        assert_eq!(invmod(2, 10), None);
        let m = 1u64 << 32;
        let a = 0x1234_5679; // odd -> invertible mod 2^32
        let inv = invmod(a, m).unwrap();
        assert_eq!(mulmod(a, inv, m), 1);
    }

    #[test]
    fn center_and_back() {
        let m = 101u64;
        for a in 0..m {
            let c = center(a, m);
            assert!(c > -(m as i64) / 2 - 1 && c <= m as i64 / 2);
            assert_eq!(from_signed(c, m), a);
        }
    }
}
