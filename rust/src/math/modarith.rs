//! Scalar modular arithmetic over `u64` moduli.
//!
//! All FV residue planes use primes `p < 2^31`, so products of canonical
//! residues fit comfortably in `u128`; these helpers are nevertheless
//! written to be correct for any `u64` modulus.
//!
//! Three reduction strategies coexist, chosen by what is invariant:
//!
//! - [`mulmod`] — the division-based fallback. Correct for any modulus;
//!   used only in cold setup code (table builds, key generation,
//!   primality testing), never in per-coefficient loops.
//! - **Shoup** ([`mulmod_shoup`], [`ShoupConstant`]) — when one operand
//!   `s` is invariant across a loop (twiddle factors, `M_i mod p_j`
//!   tables, `(q/q_i)^{-1}` gadget factors), precompute
//!   `⌊s·2^64/p⌋` once and every product costs one widening multiply
//!   plus two wrapping multiplies. The lazy variant returns `[0, 2p)`
//!   for the Harvey NTT butterflies.
//! - **Barrett** ([`BarrettConstant`]) — when only the *modulus* is
//!   invariant (variable×variable products, `u128` accumulator
//!   flushes), precompute `⌊2^128/m⌋` once and reduce any `u128` with
//!   two mul-highs and one conditional subtraction. Its `div_rem` also
//!   exposes the exact quotient, which replaces the `u128` divisions
//!   of the base-conversion fixed-point α machinery.
//!
//! The precompute math is mirrored bit-for-bit by
//! `python/compile/rns.py` (`shoup_precompute`, `barrett_constant`, …).

/// `(a + b) mod m`, assuming `a, b < m`.
#[inline(always)]
pub fn addmod(a: u64, b: u64, m: u64) -> u64 {
    debug_assert!(a < m && b < m);
    let s = a.wrapping_add(b);
    if s >= m || s < a {
        s.wrapping_sub(m)
    } else {
        s
    }
}

/// `(a - b) mod m`, assuming `a, b < m`.
#[inline(always)]
pub fn submod(a: u64, b: u64, m: u64) -> u64 {
    debug_assert!(a < m && b < m);
    if a >= b {
        a - b
    } else {
        a.wrapping_sub(b).wrapping_add(m)
    }
}

/// `(a * b) mod m` via a `u128` intermediate.
#[inline(always)]
pub fn mulmod(a: u64, b: u64, m: u64) -> u64 {
    ((a as u128 * b as u128) % m as u128) as u64
}

/// Widening 64×64 → 128 product.
#[inline(always)]
fn mul_wide(a: u64, b: u64) -> u128 {
    a as u128 * b as u128
}

/// `⌊s·2^64/p⌋` — the Shoup companion of an invariant operand `s`.
/// Requires `s < p < 2^63` (the headroom [`mulmod_shoup`] needs for its
/// single conditional subtraction).
pub fn shoup_precompute(s: u64, p: u64) -> u64 {
    assert!(s < p && p < 1 << 63, "shoup_precompute requires s < p < 2^63");
    (((s as u128) << 64) / p as u128) as u64
}

/// Shoup modular multiplication by a *precomputed* constant: given
/// `s_shoup = ⌊s·2^64/p⌋`, computes `x·s mod p` with one widening
/// multiply and no division (Harvey/Shoup). Valid for **any** `x`
/// (in particular the `[0, 4p)` lazy butterfly values), result in
/// `[0, p)`.
#[inline(always)]
pub fn mulmod_shoup(x: u64, s: u64, s_shoup: u64, p: u64) -> u64 {
    let r = mulmod_shoup_lazy(x, s, s_shoup, p);
    if r >= p {
        r - p
    } else {
        r
    }
}

/// The lazy Shoup product: same contract as [`mulmod_shoup`] but skips
/// the final conditional subtraction, returning a value in `[0, 2p)` —
/// the form the lazy-reduction NTT butterflies consume directly.
#[inline(always)]
pub fn mulmod_shoup_lazy(x: u64, s: u64, s_shoup: u64, p: u64) -> u64 {
    let q = (mul_wide(x, s_shoup) >> 64) as u64;
    x.wrapping_mul(s).wrapping_sub(q.wrapping_mul(p))
}

/// An invariant multiplicand bundled with its Shoup companion **and**
/// the modulus it was precomputed for (a companion is meaningless
/// under any other modulus, so carrying `p` removes a whole class of
/// mismatched-plane bugs) — the table-entry form used by the base
/// converters and the RNS-multiply precomputation (`NttTable` keeps
/// parallel `Vec<u64>` pairs instead, for its two-array butterfly
/// layout; both go through [`mulmod_shoup`]).
#[derive(Clone, Copy, Debug)]
pub struct ShoupConstant {
    s: u64,
    s_shoup: u64,
    p: u64,
}

impl ShoupConstant {
    /// Precompute the companion of `s` modulo `p` (`s < p < 2^63`).
    pub fn new(s: u64, p: u64) -> Self {
        ShoupConstant { s, s_shoup: shoup_precompute(s, p), p }
    }

    /// The raw constant `s`.
    #[inline(always)]
    pub fn value(&self) -> u64 {
        self.s
    }

    /// The modulus the companion was precomputed for.
    #[inline(always)]
    pub fn modulus(&self) -> u64 {
        self.p
    }

    /// `x·s mod p`, result in `[0, p)`.
    #[inline(always)]
    pub fn mul(&self, x: u64) -> u64 {
        mulmod_shoup(x, self.s, self.s_shoup, self.p)
    }

    /// `x·s mod p` lazily, result in `[0, 2p)`.
    #[inline(always)]
    pub fn mul_lazy(&self, x: u64) -> u64 {
        mulmod_shoup_lazy(x, self.s, self.s_shoup, self.p)
    }
}

/// Barrett reduction constants for a fixed modulus `m`: the 128-bit
/// reciprocal `r = ⌊2^128/m⌋` stored as hi/lo words. [`Self::reduce`]
/// maps any `u128` into `[0, m)` with two 64×64 mul-high blocks and a
/// single conditional subtraction — no hardware division. This is the
/// variable×variable counterpart of the Shoup path: use it when only
/// the modulus is loop-invariant (pointwise NTT products, flushing
/// `u128` accumulators).
#[derive(Clone, Copy, Debug)]
pub struct BarrettConstant {
    m: u64,
    r_hi: u64,
    r_lo: u64,
}

impl BarrettConstant {
    /// Requires `2 ≤ m < 2^62` (so the `< 2m` pre-correction remainder
    /// fits `u64`). Every RNS plane prime (`< 2^30`) qualifies.
    pub fn new(m: u64) -> Self {
        assert!(m >= 2 && m < 1 << 62, "Barrett modulus out of range");
        let r = if m.is_power_of_two() {
            1u128 << (128 - m.trailing_zeros())
        } else {
            // m ∤ 2^128, so ⌊(2^128 − 1)/m⌋ = ⌊2^128/m⌋.
            u128::MAX / m as u128
        };
        BarrettConstant { m, r_hi: (r >> 64) as u64, r_lo: r as u64 }
    }

    /// The modulus this constant reduces by.
    #[inline(always)]
    pub fn modulus(&self) -> u64 {
        self.m
    }

    /// `⌊x·r/2^128⌋` — exact, via the 128×128 mul-high. With
    /// `r = ⌊2^128/m⌋` this is `⌊x/m⌋` or `⌊x/m⌋ − 1`.
    #[inline(always)]
    fn quotient_estimate(&self, x: u128) -> u128 {
        let (x_hi, x_lo) = ((x >> 64) as u64, x as u64);
        let lo_lo = mul_wide(x_lo, self.r_lo);
        let hi_lo = mul_wide(x_hi, self.r_lo);
        let lo_hi = mul_wide(x_lo, self.r_hi);
        let hi_hi = mul_wide(x_hi, self.r_hi);
        let mid = (lo_lo >> 64) + (hi_lo & u64::MAX as u128) + (lo_hi & u64::MAX as u128);
        hi_hi + (hi_lo >> 64) + (lo_hi >> 64) + (mid >> 64)
    }

    /// `x mod m` for any `u128` (in particular products of canonical
    /// residues and lazy accumulator sums), result in `[0, m)`.
    #[inline(always)]
    pub fn reduce(&self, x: u128) -> u64 {
        let q = self.quotient_estimate(x);
        // q ∈ {⌊x/m⌋ − 1, ⌊x/m⌋}, so the remainder is < 2m < 2^63.
        let r = x.wrapping_sub(q.wrapping_mul(self.m as u128)) as u64;
        if r >= self.m {
            r - self.m
        } else {
            r
        }
    }

    /// Exact `(⌊x/m⌋, x mod m)` — division without hardware division.
    /// Replaces the `u128 /` in the base-conversion fixed-point
    /// accumulation (`⌊y_i·2^64/p_i⌋`) bit for bit.
    #[inline(always)]
    pub fn div_rem(&self, x: u128) -> (u128, u64) {
        let mut q = self.quotient_estimate(x);
        let mut r = x.wrapping_sub(q.wrapping_mul(self.m as u128)) as u64;
        if r >= self.m {
            r -= self.m;
            q += 1;
        }
        (q, r)
    }

    /// `(a·b) mod m` via the precomputed reciprocal.
    #[inline(always)]
    pub fn mulmod(&self, a: u64, b: u64) -> u64 {
        self.reduce(mul_wide(a, b))
    }
}

/// `-a mod m`, assuming `a < m`.
#[inline(always)]
pub fn negmod(a: u64, m: u64) -> u64 {
    if a == 0 {
        0
    } else {
        m - a
    }
}

/// `a^e mod m` by square-and-multiply.
pub fn powmod(mut a: u64, mut e: u64, m: u64) -> u64 {
    if m == 1 {
        return 0;
    }
    a %= m;
    let mut acc: u64 = 1;
    while e > 0 {
        if e & 1 == 1 {
            acc = mulmod(acc, a, m);
        }
        a = mulmod(a, a, m);
        e >>= 1;
    }
    acc
}

/// Modular inverse of `a` modulo prime `p` (Fermat). Panics if `a ≡ 0`.
pub fn invmod_prime(a: u64, p: u64) -> u64 {
    assert!(a % p != 0, "invmod_prime: zero has no inverse");
    powmod(a, p - 2, p)
}

/// Modular inverse for a general modulus via the extended Euclidean
/// algorithm. Returns `None` if `gcd(a, m) != 1`.
pub fn invmod(a: u64, m: u64) -> Option<u64> {
    let (mut old_r, mut r) = (a as i128, m as i128);
    let (mut old_s, mut s) = (1i128, 0i128);
    while r != 0 {
        let q = old_r / r;
        let tmp_r = old_r - q * r;
        old_r = r;
        r = tmp_r;
        let tmp_s = old_s - q * s;
        old_s = s;
        s = tmp_s;
    }
    if old_r != 1 {
        return None;
    }
    let mut inv = old_s % m as i128;
    if inv < 0 {
        inv += m as i128;
    }
    Some(inv as u64)
}

/// Centered (symmetric) representative of `a mod m` in
/// `(-m/2, m/2]`, returned as `i64`. Requires `m < 2^63`.
#[inline]
pub fn center(a: u64, m: u64) -> i64 {
    debug_assert!(a < m && m < (1 << 63));
    if a > m / 2 {
        a as i64 - m as i64
    } else {
        a as i64
    }
}

/// Canonical representative in `[0, m)` of a signed value.
#[inline]
pub fn from_signed(v: i64, m: u64) -> u64 {
    let r = v.rem_euclid(m as i64);
    r as u64
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn add_sub_roundtrip() {
        let m = 0xffff_fffb; // prime
        for &(a, b) in &[(0u64, 0u64), (1, m - 1), (m - 1, m - 1), (12345, 67890)] {
            let s = addmod(a % m, b % m, m);
            assert_eq!(submod(s, b % m, m), a % m);
        }
    }

    #[test]
    fn addmod_near_u64_max() {
        // Modulus close to u64::MAX exercises the wrap-detection branch.
        let m = u64::MAX - 58; // arbitrary large odd modulus
        assert_eq!(addmod(m - 1, m - 1, m), m - 2);
        assert_eq!(addmod(m - 1, 1, m), 0);
    }

    #[test]
    fn powmod_small_cases() {
        assert_eq!(powmod(2, 10, 1_000_003), 1024);
        assert_eq!(powmod(7, 0, 13), 1);
        assert_eq!(powmod(0, 5, 13), 0);
        assert_eq!(powmod(5, 1, 1), 0);
    }

    #[test]
    fn fermat_inverse() {
        let p = 998_244_353u64; // NTT prime
        for a in [1u64, 2, 3, 10, p - 1, 123_456_789] {
            let inv = invmod_prime(a, p);
            assert_eq!(mulmod(a, inv, p), 1);
        }
    }

    #[test]
    fn general_inverse() {
        assert_eq!(invmod(3, 10), Some(7));
        assert_eq!(invmod(2, 10), None);
        let m = 1u64 << 32;
        let a = 0x1234_5679; // odd -> invertible mod 2^32
        let inv = invmod(a, m).unwrap();
        assert_eq!(mulmod(a, inv, m), 1);
    }

    /// A uniformly random 31-bit prime in `[2^30, 2^31)`
    /// (advance-to-next-prime from a random odd start) — one bit above
    /// the 2^30 RNS production bound, so the headroom claims are
    /// exercised strictly beyond what the planes ever use.
    fn random_31bit_prime(rng: &mut crate::fhe::rng::ChaChaRng) -> u64 {
        let mut m = ((1u64 << 30) + rng.uniform_below(1 << 30)) | 1;
        while !crate::math::primes::is_prime(m) {
            m += 2;
        }
        m
    }

    #[test]
    fn barrett_matches_naive_mulmod() {
        use crate::util::prop::PropRunner;
        let mut run = PropRunner::new("barrett_mulmod", 300);
        run.run(|rng| {
            let m = random_31bit_prime(rng);
            let br = BarrettConstant::new(m);
            let (ra, rb) = (rng.uniform_below(m), rng.uniform_below(m));
            for &a in &[0u64, 1, m - 1, ra] {
                for &b in &[0u64, 1, m - 1, rb] {
                    assert_eq!(br.mulmod(a, b), mulmod(a, b, m), "a={a} b={b} m={m}");
                }
            }
        });
    }

    #[test]
    fn barrett_reduce_and_div_rem_any_u128() {
        use crate::util::prop::PropRunner;
        let mut run = PropRunner::new("barrett_div_rem", 300);
        run.run(|rng| {
            let m = random_31bit_prime(rng);
            let br = BarrettConstant::new(m);
            let x = ((rng.next_u64() as u128) << 64) | rng.next_u64() as u128;
            for &x in &[0u128, 1, m as u128 - 1, m as u128, u128::MAX, x] {
                assert_eq!(br.reduce(x) as u128, x % m as u128, "x={x} m={m}");
                let (q, r) = br.div_rem(x);
                assert_eq!(q, x / m as u128, "x={x} m={m}");
                assert_eq!(r as u128, x % m as u128);
            }
            // The fixed-point use: ⌊y·2^64/p⌋ for canonical y.
            let y = rng.uniform_below(m);
            assert_eq!(br.div_rem((y as u128) << 64).0, ((y as u128) << 64) / m as u128);
        });
    }

    #[test]
    fn shoup_matches_naive_mulmod() {
        use crate::util::prop::PropRunner;
        let mut run = PropRunner::new("shoup_mulmod", 300);
        run.run(|rng| {
            let m = random_31bit_prime(rng);
            let rs = rng.uniform_below(m);
            // Lazy butterflies feed operands up to 4p, so test x beyond m too.
            let rx = rng.uniform_below(4 * m);
            for &s in &[0u64, 1, m - 1, rs] {
                let sc = ShoupConstant::new(s, m);
                assert_eq!(sc.value(), s);
                for &x in &[0u64, 1, m - 1, rx] {
                    let expect = mulmod(x, s, m);
                    assert_eq!(sc.mul(x), expect, "x={x} s={s} m={m}");
                    let lazy = sc.mul_lazy(x);
                    assert!(lazy < 2 * m, "lazy Shoup must stay under 2p");
                    assert_eq!(lazy % m, expect);
                }
            }
        });
    }

    #[test]
    fn barrett_handles_power_of_two_and_range_edges() {
        for m in [2u64, 4, 1 << 31, (1 << 62) - 57, 3, (1 << 62) - 1] {
            let br = BarrettConstant::new(m);
            assert_eq!(br.modulus(), m);
            for x in [0u128, 1, m as u128, m as u128 * m as u128 + 5, u128::MAX] {
                assert_eq!(br.reduce(x) as u128, x % m as u128, "x={x} m={m}");
            }
        }
    }

    #[test]
    #[should_panic(expected = "Barrett modulus out of range")]
    fn barrett_rejects_oversized_modulus() {
        let _ = BarrettConstant::new(1 << 62);
    }

    #[test]
    #[should_panic(expected = "shoup_precompute requires")]
    fn shoup_rejects_non_canonical_operand() {
        let _ = ShoupConstant::new(17, 17);
    }

    #[test]
    fn center_and_back() {
        let m = 101u64;
        for a in 0..m {
            let c = center(a, m);
            assert!(c > -(m as i64) / 2 - 1 && c <= m as i64 / 2);
            assert_eq!(from_signed(c, m), a);
        }
    }
}
