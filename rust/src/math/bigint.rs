//! Arbitrary-precision integers on `u64` limbs (little-endian).
//!
//! The FV scheme needs exact arithmetic well beyond `u128`: the plaintext
//! modulus `t` is sized by the paper's Lemma 3 coefficient-growth bounds
//! (hundreds of bits for realistic `K`), `Δ = ⌊q/t⌋` mixes the two
//! moduli, and the BFV multiply performs an exact `⌊t·v/q⌉` rounding on
//! CRT-lifted tensor-product coefficients. No bignum crate is vendored,
//! so this module implements the required subset from scratch:
//! add/sub/mul, shifts, Knuth Algorithm-D division, small-divisor
//! helpers, decimal/bit conversions, and a signed wrapper.

use std::cmp::Ordering;
use std::fmt;

/// Unsigned arbitrary-precision integer. Canonical form: no trailing
/// zero limbs (`0` is the empty limb vector).
#[derive(Clone, PartialEq, Eq, Default)]
pub struct BigUint {
    limbs: Vec<u64>,
}

impl BigUint {
    pub fn zero() -> Self {
        BigUint { limbs: Vec::new() }
    }

    pub fn one() -> Self {
        BigUint { limbs: vec![1] }
    }

    pub fn from_u64(v: u64) -> Self {
        if v == 0 {
            Self::zero()
        } else {
            BigUint { limbs: vec![v] }
        }
    }

    pub fn from_u128(v: u128) -> Self {
        let lo = v as u64;
        let hi = (v >> 64) as u64;
        let mut s = BigUint { limbs: vec![lo, hi] };
        s.normalize();
        s
    }

    /// From little-endian limbs (normalizing).
    pub fn from_limbs(limbs: Vec<u64>) -> Self {
        let mut s = BigUint { limbs };
        s.normalize();
        s
    }

    pub fn limbs(&self) -> &[u64] {
        &self.limbs
    }

    fn normalize(&mut self) {
        while self.limbs.last() == Some(&0) {
            self.limbs.pop();
        }
    }

    pub fn is_zero(&self) -> bool {
        self.limbs.is_empty()
    }

    pub fn is_one(&self) -> bool {
        self.limbs.len() == 1 && self.limbs[0] == 1
    }

    /// Number of significant bits (0 for zero).
    pub fn bit_len(&self) -> usize {
        match self.limbs.last() {
            None => 0,
            Some(&top) => 64 * (self.limbs.len() - 1) + (64 - top.leading_zeros() as usize),
        }
    }

    /// Value of bit `i` (false beyond the top).
    pub fn bit(&self, i: usize) -> bool {
        let (limb, off) = (i / 64, i % 64);
        self.limbs.get(limb).map_or(false, |&l| (l >> off) & 1 == 1)
    }

    pub fn to_u64(&self) -> Option<u64> {
        match self.limbs.len() {
            0 => Some(0),
            1 => Some(self.limbs[0]),
            _ => None,
        }
    }

    pub fn to_u128(&self) -> Option<u128> {
        match self.limbs.len() {
            0 => Some(0),
            1 => Some(self.limbs[0] as u128),
            2 => Some(self.limbs[0] as u128 | (self.limbs[1] as u128) << 64),
            _ => None,
        }
    }

    pub fn cmp_big(&self, other: &Self) -> Ordering {
        if self.limbs.len() != other.limbs.len() {
            return self.limbs.len().cmp(&other.limbs.len());
        }
        for i in (0..self.limbs.len()).rev() {
            match self.limbs[i].cmp(&other.limbs[i]) {
                Ordering::Equal => continue,
                o => return o,
            }
        }
        Ordering::Equal
    }

    pub fn add(&self, other: &Self) -> Self {
        let n = self.limbs.len().max(other.limbs.len());
        let mut out = Vec::with_capacity(n + 1);
        let mut carry = 0u64;
        for i in 0..n {
            let a = *self.limbs.get(i).unwrap_or(&0);
            let b = *other.limbs.get(i).unwrap_or(&0);
            let (s1, c1) = a.overflowing_add(b);
            let (s2, c2) = s1.overflowing_add(carry);
            out.push(s2);
            carry = (c1 as u64) + (c2 as u64);
        }
        if carry > 0 {
            out.push(carry);
        }
        BigUint::from_limbs(out)
    }

    pub fn add_u64(&self, v: u64) -> Self {
        self.add(&BigUint::from_u64(v))
    }

    /// `self - other`; panics on underflow.
    pub fn sub(&self, other: &Self) -> Self {
        assert!(self.cmp_big(other) != Ordering::Less, "BigUint::sub underflow");
        let mut out = Vec::with_capacity(self.limbs.len());
        let mut borrow = 0u64;
        for i in 0..self.limbs.len() {
            let a = self.limbs[i];
            let b = *other.limbs.get(i).unwrap_or(&0);
            let (d1, b1) = a.overflowing_sub(b);
            let (d2, b2) = d1.overflowing_sub(borrow);
            out.push(d2);
            borrow = (b1 as u64) + (b2 as u64);
        }
        debug_assert_eq!(borrow, 0);
        BigUint::from_limbs(out)
    }

    /// Schoolbook product. Operands here are at most a few dozen limbs,
    /// where schoolbook beats fancier algorithms anyway.
    pub fn mul(&self, other: &Self) -> Self {
        if self.is_zero() || other.is_zero() {
            return Self::zero();
        }
        let mut out = vec![0u64; self.limbs.len() + other.limbs.len()];
        for (i, &a) in self.limbs.iter().enumerate() {
            if a == 0 {
                continue;
            }
            let mut carry = 0u128;
            for (j, &b) in other.limbs.iter().enumerate() {
                let cur = out[i + j] as u128 + a as u128 * b as u128 + carry;
                out[i + j] = cur as u64;
                carry = cur >> 64;
            }
            let mut k = i + other.limbs.len();
            while carry > 0 {
                let cur = out[k] as u128 + carry;
                out[k] = cur as u64;
                carry = cur >> 64;
                k += 1;
            }
        }
        BigUint::from_limbs(out)
    }

    pub fn mul_u64(&self, v: u64) -> Self {
        if v == 0 || self.is_zero() {
            return Self::zero();
        }
        let mut out = Vec::with_capacity(self.limbs.len() + 1);
        let mut carry = 0u128;
        for &a in &self.limbs {
            let cur = a as u128 * v as u128 + carry;
            out.push(cur as u64);
            carry = cur >> 64;
        }
        if carry > 0 {
            out.push(carry as u64);
        }
        BigUint::from_limbs(out)
    }

    /// Fused `self += a * b` (in place), the CRT-lift inner loop.
    pub fn add_mul_u64(&mut self, a: &Self, b: u64) {
        if b == 0 || a.is_zero() {
            return;
        }
        let n = a.limbs.len();
        if self.limbs.len() < n + 1 {
            self.limbs.resize(n + 1, 0);
        }
        let mut carry = 0u128;
        for i in 0..n {
            let cur = self.limbs[i] as u128 + a.limbs[i] as u128 * b as u128 + carry;
            self.limbs[i] = cur as u64;
            carry = cur >> 64;
        }
        let mut k = n;
        while carry > 0 {
            if k == self.limbs.len() {
                self.limbs.push(0);
            }
            let cur = self.limbs[k] as u128 + carry;
            self.limbs[k] = cur as u64;
            carry = cur >> 64;
            k += 1;
        }
        self.normalize();
    }

    pub fn shl_bits(&self, bits: usize) -> Self {
        if self.is_zero() {
            return Self::zero();
        }
        let (limb_shift, bit_shift) = (bits / 64, bits % 64);
        let mut out = vec![0u64; self.limbs.len() + limb_shift + 1];
        for (i, &l) in self.limbs.iter().enumerate() {
            if bit_shift == 0 {
                out[i + limb_shift] |= l;
            } else {
                out[i + limb_shift] |= l << bit_shift;
                out[i + limb_shift + 1] |= l >> (64 - bit_shift);
            }
        }
        BigUint::from_limbs(out)
    }

    pub fn shr_bits(&self, bits: usize) -> Self {
        let (limb_shift, bit_shift) = (bits / 64, bits % 64);
        if limb_shift >= self.limbs.len() {
            return Self::zero();
        }
        let n = self.limbs.len() - limb_shift;
        let mut out = vec![0u64; n];
        for i in 0..n {
            let lo = self.limbs[i + limb_shift];
            out[i] = if bit_shift == 0 {
                lo
            } else {
                let hi = *self.limbs.get(i + limb_shift + 1).unwrap_or(&0);
                (lo >> bit_shift) | (hi << (64 - bit_shift))
            };
        }
        BigUint::from_limbs(out)
    }

    /// Divide by a single limb; returns (quotient, remainder).
    pub fn div_rem_u64(&self, d: u64) -> (Self, u64) {
        assert!(d != 0, "division by zero");
        let mut q = vec![0u64; self.limbs.len()];
        let mut rem = 0u128;
        for i in (0..self.limbs.len()).rev() {
            let cur = (rem << 64) | self.limbs[i] as u128;
            q[i] = (cur / d as u128) as u64;
            rem = cur % d as u128;
        }
        (BigUint::from_limbs(q), rem as u64)
    }

    pub fn mod_u64(&self, d: u64) -> u64 {
        assert!(d != 0);
        let mut rem = 0u128;
        for &l in self.limbs.iter().rev() {
            rem = ((rem << 64) | l as u128) % d as u128;
        }
        rem as u64
    }

    /// Knuth Algorithm D long division; returns (quotient, remainder).
    pub fn div_rem(&self, divisor: &Self) -> (Self, Self) {
        assert!(!divisor.is_zero(), "division by zero");
        if self.cmp_big(divisor) == Ordering::Less {
            return (Self::zero(), self.clone());
        }
        if divisor.limbs.len() == 1 {
            let (q, r) = self.div_rem_u64(divisor.limbs[0]);
            return (q, BigUint::from_u64(r));
        }
        // Normalize so the divisor's top limb has its high bit set.
        let shift = divisor.limbs.last().unwrap().leading_zeros() as usize;
        let u_big = self.shl_bits(shift);
        let v = divisor.shl_bits(shift);
        let n = v.limbs.len();
        let mut u = u_big.limbs.clone();
        u.push(0); // u has len m + n + 1
        let m = u.len() - n - 1;
        let v_limbs = &v.limbs;
        let vn1 = v_limbs[n - 1];
        let vn2 = v_limbs[n - 2];
        let mut q = vec![0u64; m + 1];
        for j in (0..=m).rev() {
            // Estimate q̂ from the top two/three limbs.
            let num = ((u[j + n] as u128) << 64) | u[j + n - 1] as u128;
            let mut qhat = num / vn1 as u128;
            let mut rhat = num % vn1 as u128;
            loop {
                if qhat >> 64 != 0
                    || qhat * vn2 as u128 > ((rhat << 64) | u[j + n - 2] as u128)
                {
                    qhat -= 1;
                    rhat += vn1 as u128;
                    if rhat >> 64 == 0 {
                        continue;
                    }
                }
                break;
            }
            // Multiply-subtract q̂ · v from u[j .. j+n].
            let mut borrow = 0i128;
            let mut carry = 0u128;
            for i in 0..n {
                let p = qhat * v_limbs[i] as u128 + carry;
                carry = p >> 64;
                let sub = (u[j + i] as i128) - (p as u64 as i128) - borrow;
                u[j + i] = sub as u64; // wraps correctly (two's complement)
                borrow = if sub < 0 { 1 } else { 0 };
            }
            let sub = (u[j + n] as i128) - (carry as i128) - borrow;
            u[j + n] = sub as u64;
            let went_negative = sub < 0;
            q[j] = qhat as u64;
            if went_negative {
                // Add back one multiple of v.
                q[j] -= 1;
                let mut carry = 0u128;
                for i in 0..n {
                    let s = u[j + i] as u128 + v_limbs[i] as u128 + carry;
                    u[j + i] = s as u64;
                    carry = s >> 64;
                }
                u[j + n] = u[j + n].wrapping_add(carry as u64);
            }
        }
        let rem = BigUint::from_limbs(u[..n].to_vec()).shr_bits(shift);
        (BigUint::from_limbs(q), rem)
    }

    /// `⌊(self + divisor/2) / divisor⌋` — round-to-nearest division
    /// (ties away from zero), the BFV scale-and-round primitive.
    pub fn div_round(&self, divisor: &Self) -> Self {
        let half = divisor.shr_bits(1);
        self.add(&half).div_rem(divisor).0
    }

    /// `self mod m` for bigint modulus.
    pub fn rem_big(&self, m: &Self) -> Self {
        self.div_rem(m).1
    }

    /// True iff exactly one bit is set.
    pub fn is_power_of_two(&self) -> bool {
        !self.is_zero() && self.limbs.iter().map(|l| l.count_ones()).sum::<u32>() == 1
    }

    /// Extract `len ≤ 64` bits starting at bit `start` (little-endian),
    /// i.e. `(self >> start) & ((1 << len) - 1)` — the relinearisation
    /// digit-decomposition primitive.
    pub fn extract_bits(&self, start: usize, len: usize) -> u64 {
        debug_assert!(len >= 1 && len <= 64);
        let (limb, off) = (start / 64, start % 64);
        let lo = *self.limbs.get(limb).unwrap_or(&0) >> off;
        let word = if off == 0 {
            lo
        } else {
            lo | (self.limbs.get(limb + 1).copied().unwrap_or(0) << (64 - off))
        };
        if len == 64 {
            word
        } else {
            word & ((1u64 << len) - 1)
        }
    }

    /// 10^e.
    pub fn pow10(e: u32) -> Self {
        let mut out = Self::one();
        for _ in 0..e {
            out = out.mul_u64(10);
        }
        out
    }

    /// self^e (small exponents).
    pub fn pow(&self, e: u32) -> Self {
        let mut out = Self::one();
        for _ in 0..e {
            out = out.mul(self);
        }
        out
    }

    /// Approximate as `mantissa × 2^exp` with `mantissa ∈ [0.5, 1)`;
    /// exact for values below 2^53. Used only for final decode /
    /// reporting, never inside the exact arithmetic.
    pub fn to_f64_exp(&self) -> (f64, i64) {
        if self.is_zero() {
            return (0.0, 0);
        }
        let bits = self.bit_len();
        // Take the top 64 bits.
        let take = bits.min(64);
        let top = self.shr_bits(bits - take).to_u64().unwrap();
        let mant = top as f64 / (1u128 << take) as f64;
        (mant, bits as i64)
    }

    /// Lossy f64 value (may overflow to inf for huge numbers).
    pub fn to_f64(&self) -> f64 {
        let (m, e) = self.to_f64_exp();
        m * 2f64.powi(e.min(i32::MAX as i64) as i32)
    }

    /// Parse a decimal string (digits only).
    pub fn from_decimal(s: &str) -> Option<Self> {
        let mut out = Self::zero();
        for c in s.bytes() {
            if !c.is_ascii_digit() {
                return None;
            }
            out = out.mul_u64(10).add_u64((c - b'0') as u64);
        }
        Some(out)
    }

    pub fn to_decimal(&self) -> String {
        if self.is_zero() {
            return "0".to_string();
        }
        let mut digits = Vec::new();
        let mut cur = self.clone();
        while !cur.is_zero() {
            let (q, r) = cur.div_rem_u64(10_000_000_000_000_000_000); // 10^19
            if q.is_zero() {
                digits.push(format!("{r}"));
            } else {
                digits.push(format!("{r:019}"));
            }
            cur = q;
        }
        digits.reverse();
        digits.concat()
    }
}

impl fmt::Debug for BigUint {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "BigUint({})", self.to_decimal())
    }
}

impl fmt::Display for BigUint {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.to_decimal())
    }
}

impl PartialOrd for BigUint {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp_big(other))
    }
}

impl Ord for BigUint {
    fn cmp(&self, other: &Self) -> Ordering {
        self.cmp_big(other)
    }
}

/// Signed arbitrary-precision integer (sign + magnitude).
#[derive(Clone, PartialEq, Eq, Default)]
pub struct BigInt {
    /// True iff the value is strictly negative.
    pub neg: bool,
    pub mag: BigUint,
}

impl BigInt {
    pub fn zero() -> Self {
        BigInt { neg: false, mag: BigUint::zero() }
    }

    pub fn from_i64(v: i64) -> Self {
        BigInt { neg: v < 0, mag: BigUint::from_u64(v.unsigned_abs()) }
    }

    pub fn from_i128(v: i128) -> Self {
        BigInt { neg: v < 0, mag: BigUint::from_u128(v.unsigned_abs()) }
    }

    pub fn from_biguint(mag: BigUint) -> Self {
        BigInt { neg: false, mag }
    }

    pub fn is_zero(&self) -> bool {
        self.mag.is_zero()
    }

    fn canon(mut self) -> Self {
        if self.mag.is_zero() {
            self.neg = false;
        }
        self
    }

    pub fn neg_value(&self) -> Self {
        BigInt { neg: !self.neg && !self.is_zero(), mag: self.mag.clone() }
    }

    pub fn add(&self, other: &Self) -> Self {
        if self.neg == other.neg {
            BigInt { neg: self.neg, mag: self.mag.add(&other.mag) }.canon()
        } else {
            match self.mag.cmp_big(&other.mag) {
                Ordering::Equal => Self::zero(),
                Ordering::Greater => {
                    BigInt { neg: self.neg, mag: self.mag.sub(&other.mag) }.canon()
                }
                Ordering::Less => {
                    BigInt { neg: other.neg, mag: other.mag.sub(&self.mag) }.canon()
                }
            }
        }
    }

    pub fn sub(&self, other: &Self) -> Self {
        self.add(&other.neg_value())
    }

    pub fn mul(&self, other: &Self) -> Self {
        BigInt { neg: self.neg != other.neg, mag: self.mag.mul(&other.mag) }.canon()
    }

    pub fn mul_i64(&self, v: i64) -> Self {
        BigInt { neg: self.neg != (v < 0), mag: self.mag.mul_u64(v.unsigned_abs()) }.canon()
    }

    /// Round-to-nearest division (ties away from zero).
    pub fn div_round(&self, divisor: &BigUint) -> Self {
        BigInt { neg: self.neg, mag: self.mag.div_round(divisor) }.canon()
    }

    /// Canonical residue in `[0, m)`.
    pub fn rem_euclid_big(&self, m: &BigUint) -> BigUint {
        let r = self.mag.rem_big(m);
        if self.neg && !r.is_zero() {
            m.sub(&r)
        } else {
            r
        }
    }

    /// Canonical residue modulo a u64 prime.
    pub fn mod_u64(&self, p: u64) -> u64 {
        let r = self.mag.mod_u64(p);
        if self.neg && r != 0 {
            p - r
        } else {
            r
        }
    }

    pub fn cmp_big(&self, other: &Self) -> Ordering {
        match (self.neg, other.neg) {
            (false, true) => Ordering::Greater,
            (true, false) => Ordering::Less,
            (false, false) => self.mag.cmp_big(&other.mag),
            (true, true) => other.mag.cmp_big(&self.mag),
        }
    }

    pub fn abs_big(&self) -> BigUint {
        self.mag.clone()
    }

    pub fn to_i128(&self) -> Option<i128> {
        let m = self.mag.to_u128()?;
        if self.neg {
            if m > i128::MAX as u128 + 1 {
                None
            } else {
                Some((m as i128).wrapping_neg())
            }
        } else if m > i128::MAX as u128 {
            None
        } else {
            Some(m as i128)
        }
    }

    pub fn to_f64(&self) -> f64 {
        let v = self.mag.to_f64();
        if self.neg {
            -v
        } else {
            v
        }
    }
}

impl fmt::Debug for BigInt {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "BigInt({}{})", if self.neg { "-" } else { "" }, self.mag.to_decimal())
    }
}

impl fmt::Display for BigInt {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}{}", if self.neg { "-" } else { "" }, self.mag.to_decimal())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fhe::rng::ChaChaRng;
    use crate::util::prop::PropRunner;

    fn rand_big(rng: &mut ChaChaRng, max_limbs: usize) -> BigUint {
        let n = (rng.next_u64() as usize % max_limbs) + 1;
        BigUint::from_limbs((0..n).map(|_| rng.next_u64()).collect())
    }

    #[test]
    fn u128_roundtrip() {
        for v in [0u128, 1, u64::MAX as u128, u128::MAX, 1 << 64, (1 << 64) + 5] {
            assert_eq!(BigUint::from_u128(v).to_u128(), Some(v));
        }
    }

    #[test]
    fn add_sub_against_u128() {
        let mut run = PropRunner::new("bigint_add_sub", 500);
        run.run(|rng| {
            let a = rng.next_u64() as u128 | ((rng.next_u64() as u128) << 32);
            let b = rng.next_u64() as u128 | ((rng.next_u64() as u128) << 32);
            let (ba, bb) = (BigUint::from_u128(a), BigUint::from_u128(b));
            assert_eq!(ba.add(&bb).to_u128(), Some(a + b));
            let (hi, lo) = if a >= b { (a, b) } else { (b, a) };
            assert_eq!(
                BigUint::from_u128(hi).sub(&BigUint::from_u128(lo)).to_u128(),
                Some(hi - lo)
            );
        });
    }

    #[test]
    fn mul_against_u128() {
        let mut run = PropRunner::new("bigint_mul", 500);
        run.run(|rng| {
            let a = rng.next_u64();
            let b = rng.next_u64();
            assert_eq!(
                BigUint::from_u64(a).mul(&BigUint::from_u64(b)).to_u128(),
                Some(a as u128 * b as u128)
            );
        });
    }

    #[test]
    fn div_rem_identity_property() {
        // For random (a, b): a == q*b + r with r < b. This exercises the
        // Knuth-D corner cases (normalization, add-back) statistically.
        let mut run = PropRunner::new("bigint_divrem", 300);
        run.run(|rng| {
            let a = rand_big(rng, 8);
            let mut b = rand_big(rng, 4);
            if b.is_zero() {
                b = BigUint::one();
            }
            let (q, r) = a.div_rem(&b);
            assert!(r.cmp_big(&b) == Ordering::Less, "r < b");
            assert_eq!(q.mul(&b).add(&r), a, "a = q*b + r");
        });
    }

    #[test]
    fn div_rem_addback_case() {
        // A crafted case that triggers the rare "add back" branch:
        // u = 2^128 - 1, v = 2^64 + 3.
        let u = BigUint::from_limbs(vec![u64::MAX, u64::MAX]);
        let v = BigUint::from_limbs(vec![3, 1]);
        let (q, r) = u.div_rem(&v);
        assert_eq!(q.mul(&v).add(&r), u);
        assert!(r.cmp_big(&v) == Ordering::Less);
    }

    #[test]
    fn shifts() {
        let a = BigUint::from_u128(0xdead_beef_cafe_babe_1234);
        assert_eq!(a.shl_bits(64).shr_bits(64), a);
        assert_eq!(a.shl_bits(3).to_u128(), Some(0xdead_beef_cafe_babe_1234 << 3));
        assert_eq!(a.shr_bits(300), BigUint::zero());
    }

    #[test]
    fn div_round_ties() {
        // 7/2 -> 4 (ties away from zero... 3.5 rounds to 4)
        let r = BigUint::from_u64(7).div_round(&BigUint::from_u64(2));
        assert_eq!(r.to_u64(), Some(4));
        let r = BigUint::from_u64(6).div_round(&BigUint::from_u64(4));
        assert_eq!(r.to_u64(), Some(2)); // 1.5 -> 2
        let r = BigUint::from_u64(5).div_round(&BigUint::from_u64(4));
        assert_eq!(r.to_u64(), Some(1)); // 1.25 -> 1
    }

    #[test]
    fn decimal_roundtrip() {
        for s in ["0", "1", "18446744073709551616", "123456789012345678901234567890"] {
            let b = BigUint::from_decimal(s).unwrap();
            assert_eq!(b.to_decimal(), s);
        }
        assert_eq!(BigUint::pow10(20).to_decimal(), "100000000000000000000");
    }

    #[test]
    fn bit_len_and_bits() {
        assert_eq!(BigUint::zero().bit_len(), 0);
        assert_eq!(BigUint::from_u64(1).bit_len(), 1);
        assert_eq!(BigUint::from_u64(0xff).bit_len(), 8);
        let b = BigUint::one().shl_bits(200);
        assert_eq!(b.bit_len(), 201);
        assert!(b.bit(200) && !b.bit(199) && !b.bit(201));
    }

    #[test]
    fn to_f64_exp_accuracy() {
        let b = BigUint::from_decimal("12345678901234567890123456789").unwrap();
        let (m, e) = b.to_f64_exp();
        let approx = m * 2f64.powi(e as i32);
        let rel = (approx - 1.2345678901234568e28).abs() / 1.2345678901234568e28;
        assert!(rel < 1e-12, "rel error {rel}");
    }

    #[test]
    fn signed_arithmetic() {
        let a = BigInt::from_i64(-5);
        let b = BigInt::from_i64(3);
        assert_eq!(a.add(&b).to_i128(), Some(-2));
        assert_eq!(a.sub(&b).to_i128(), Some(-8));
        assert_eq!(a.mul(&b).to_i128(), Some(-15));
        assert_eq!(a.neg_value().to_i128(), Some(5));
        assert_eq!(BigInt::zero().neg_value().to_i128(), Some(0));
    }

    #[test]
    fn signed_property_vs_i128() {
        let mut run = PropRunner::new("bigint_signed", 500);
        run.run(|rng| {
            let a = rng.next_u64() as i64 as i128 >> (rng.next_u64() % 32);
            let b = rng.next_u64() as i64 as i128 >> (rng.next_u64() % 32);
            let (ba, bb) = (BigInt::from_i128(a), BigInt::from_i128(b));
            assert_eq!(ba.add(&bb).to_i128(), Some(a + b));
            assert_eq!(ba.sub(&bb).to_i128(), Some(a - b));
            assert_eq!(ba.mul(&bb).to_i128(), Some(a * b));
        });
    }

    #[test]
    fn rem_euclid_signed() {
        let m = BigUint::from_u64(7);
        assert_eq!(BigInt::from_i64(-1).rem_euclid_big(&m).to_u64(), Some(6));
        assert_eq!(BigInt::from_i64(-14).rem_euclid_big(&m).to_u64(), Some(0));
        assert_eq!(BigInt::from_i64(15).rem_euclid_big(&m).to_u64(), Some(1));
        assert_eq!(BigInt::from_i64(-15).mod_u64(7), 6);
    }

    #[test]
    fn div_round_signed() {
        // -7/2 -> -4 (away from zero)
        let r = BigInt::from_i64(-7).div_round(&BigUint::from_u64(2));
        assert_eq!(r.to_i128(), Some(-4));
    }
}
