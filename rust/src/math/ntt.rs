//! Negacyclic number-theoretic transform over `Z_p[x]/(x^d + 1)`.
//!
//! Implements the merged-twist radix-2 NTT of Longa & Naehrig: the
//! ψ-twisting that turns a cyclic convolution into a negacyclic one is
//! folded into the twiddle tables, so a forward transform, a pointwise
//! product and an inverse transform compute multiplication modulo
//! `x^d + 1` directly.
//!
//! Forward uses Cooley–Tukey butterflies with `ψ^bitrev(i)` twiddles;
//! inverse uses Gentleman–Sande with `ψ^{-bitrev(i)}` and a final scale
//! by `d^{-1}`. This matches the Pallas kernel in
//! `python/compile/kernels/ntt.py` stage for stage.
//!
//! Both transforms run with **lazy reduction** (Harvey): the forward
//! pass keeps values in `[0, 4p)` and the inverse in `[0, 2p)`, with
//! twiddle products via the lazy Shoup primitive
//! ([`mulmod_shoup_lazy`](super::modarith::mulmod_shoup_lazy)) and one
//! final correction pass instead of a reduction per butterfly. Both
//! entry points take and return **canonical** (`[0, p)`) planes, so
//! the lazy representation never escapes this module.

use super::modarith::{
    addmod, invmod_prime, mulmod, mulmod_shoup, mulmod_shoup_lazy, shoup_precompute, submod,
    BarrettConstant,
};
use super::primes::primitive_2d_root;

/// Precomputed tables for one `(p, d)` pair.
#[derive(Clone, Debug)]
pub struct NttTable {
    /// Prime modulus, `p ≡ 1 (mod 2d)`.
    pub p: u64,
    /// Ring degree (power of two).
    pub d: usize,
    /// `ψ^bitrev(i)` for the forward transform.
    psi_rev: Vec<u64>,
    /// Shoup companions `⌊ψ^bitrev(i)·2^64/p⌋`.
    psi_rev_shoup: Vec<u64>,
    /// `ψ^{-bitrev(i)}` for the inverse transform.
    psi_inv_rev: Vec<u64>,
    psi_inv_rev_shoup: Vec<u64>,
    /// `d^{-1} mod p` (+ Shoup companion).
    d_inv: u64,
    d_inv_shoup: u64,
    /// Barrett reciprocal of `p` for the pointwise-product loop.
    barrett: BarrettConstant,
}

fn bitrev(x: usize, bits: u32) -> usize {
    x.reverse_bits() >> (usize::BITS - bits)
}

impl NttTable {
    /// Build tables for degree `d` (power of two) and prime `p ≡ 1 mod 2d`.
    pub fn new(p: u64, d: usize) -> Self {
        assert!(d.is_power_of_two() && d >= 2);
        // The forward pass holds values in [0, 4p): 4p must fit u64.
        assert!(p < 1 << 62, "lazy-reduction NTT requires p < 2^62");
        let psi = primitive_2d_root(p, d);
        let psi_inv = invmod_prime(psi, p);
        let bits = d.trailing_zeros();
        let mut pow = vec![0u64; d];
        let mut pow_inv = vec![0u64; d];
        let (mut cur, mut cur_inv) = (1u64, 1u64);
        for i in 0..d {
            pow[i] = cur;
            pow_inv[i] = cur_inv;
            cur = mulmod(cur, psi, p);
            cur_inv = mulmod(cur_inv, psi_inv, p);
        }
        let mut psi_rev = vec![0u64; d];
        let mut psi_inv_rev = vec![0u64; d];
        for i in 0..d {
            let r = bitrev(i, bits);
            psi_rev[i] = pow[r];
            psi_inv_rev[i] = pow_inv[r];
        }
        let psi_rev_shoup = psi_rev.iter().map(|&s| shoup_precompute(s, p)).collect();
        let psi_inv_rev_shoup = psi_inv_rev.iter().map(|&s| shoup_precompute(s, p)).collect();
        let d_inv = invmod_prime(d as u64, p);
        NttTable {
            p,
            d,
            psi_rev,
            psi_rev_shoup,
            psi_inv_rev,
            psi_inv_rev_shoup,
            d_inv,
            d_inv_shoup: shoup_precompute(d_inv, p),
            barrett: BarrettConstant::new(p),
        }
    }

    /// In-place forward negacyclic NTT (coefficient → evaluation
    /// order). Lazy reduction: butterfly values live in `[0, 4p)`
    /// (operand conditionally brought under `2p`, twiddle product lazy
    /// in `[0, 2p)`), with a single correction pass at the end — input
    /// and output are canonical.
    pub fn forward(&self, a: &mut [u64]) {
        debug_assert_eq!(a.len(), self.d);
        let (p, n) = (self.p, self.d);
        let two_p = 2 * p;
        let mut t = n;
        let mut m = 1usize;
        while m < n {
            t >>= 1;
            for i in 0..m {
                let j1 = 2 * i * t;
                let s = self.psi_rev[m + i];
                let s_sh = self.psi_rev_shoup[m + i];
                for j in j1..j1 + t {
                    let mut u = a[j];
                    if u >= two_p {
                        u -= two_p;
                    }
                    let v = mulmod_shoup_lazy(a[j + t], s, s_sh, p);
                    debug_assert!(u < two_p && v < two_p);
                    a[j] = u + v;
                    a[j + t] = u + two_p - v;
                }
            }
            m <<= 1;
        }
        for x in a.iter_mut() {
            let mut v = *x;
            debug_assert!(v < 2 * two_p);
            if v >= two_p {
                v -= two_p;
            }
            if v >= p {
                v -= p;
            }
            *x = v;
        }
    }

    /// In-place inverse negacyclic NTT (evaluation → coefficient
    /// order). Lazy reduction: values live in `[0, 2p)` through the
    /// Gentleman–Sande stages; the final `d^{-1}` scale doubles as the
    /// canonicalising reduction.
    pub fn inverse(&self, a: &mut [u64]) {
        debug_assert_eq!(a.len(), self.d);
        let (p, n) = (self.p, self.d);
        let two_p = 2 * p;
        let mut t = 1usize;
        let mut m = n;
        while m > 1 {
            let h = m >> 1;
            let mut j1 = 0usize;
            for i in 0..h {
                let s = self.psi_inv_rev[h + i];
                let s_sh = self.psi_inv_rev_shoup[h + i];
                for j in j1..j1 + t {
                    let u = a[j];
                    let v = a[j + t];
                    debug_assert!(u < two_p && v < two_p);
                    let mut sum = u + v;
                    if sum >= two_p {
                        sum -= two_p;
                    }
                    a[j] = sum;
                    a[j + t] = mulmod_shoup_lazy(u + two_p - v, s, s_sh, p);
                }
                j1 += 2 * t;
            }
            t <<= 1;
            m = h;
        }
        for x in a.iter_mut() {
            // Full (non-lazy) Shoup: accepts the [0, 2p) input and
            // returns the canonical representative.
            *x = mulmod_shoup(*x, self.d_inv, self.d_inv_shoup, p);
        }
    }

    /// Negacyclic product `a * b mod (x^d + 1, p)` out of place.
    pub fn polymul(&self, a: &[u64], b: &[u64]) -> Vec<u64> {
        let mut fa = a.to_vec();
        let mut fb = b.to_vec();
        self.forward(&mut fa);
        self.forward(&mut fb);
        for i in 0..self.d {
            fa[i] = self.barrett.mulmod(fa[i], fb[i]);
        }
        self.inverse(&mut fa);
        fa
    }
}

/// Schoolbook negacyclic product — the O(d²) oracle used by tests (the
/// Python twin lives in `python/compile/kernels/ref.py`).
pub fn polymul_naive(a: &[u64], b: &[u64], p: u64) -> Vec<u64> {
    let d = a.len();
    assert_eq!(b.len(), d);
    let mut out = vec![0u64; d];
    for i in 0..d {
        if a[i] == 0 {
            continue;
        }
        for j in 0..d {
            let prod = mulmod(a[i], b[j], p);
            let k = i + j;
            if k < d {
                out[k] = addmod(out[k], prod, p);
            } else {
                out[k - d] = submod(out[k - d], prod, p); // x^d = -1
            }
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fhe::rng::ChaChaRng;
    use crate::math::primes::rns_basis_primes;

    fn rand_poly(rng: &mut ChaChaRng, d: usize, p: u64) -> Vec<u64> {
        (0..d).map(|_| rng.uniform_below(p)).collect()
    }

    #[test]
    fn forward_inverse_roundtrip() {
        let mut rng = ChaChaRng::from_seed(7);
        for d in [4usize, 64, 1024] {
            let p = rns_basis_primes(d, 1)[0];
            let t = NttTable::new(p, d);
            let a = rand_poly(&mut rng, d, p);
            let mut b = a.clone();
            t.forward(&mut b);
            assert_ne!(a, b, "transform should not be identity");
            t.inverse(&mut b);
            assert_eq!(a, b);
        }
    }

    #[test]
    fn matches_schoolbook() {
        let mut rng = ChaChaRng::from_seed(8);
        for d in [4usize, 16, 256] {
            let p = rns_basis_primes(d, 2)[1];
            let t = NttTable::new(p, d);
            let a = rand_poly(&mut rng, d, p);
            let b = rand_poly(&mut rng, d, p);
            assert_eq!(t.polymul(&a, &b), polymul_naive(&a, &b, p), "d = {d}");
        }
    }

    #[test]
    fn lazy_butterfly_bounds() {
        // The forward invariant (values < 4p, lazy Shoup outputs < 2p)
        // and the inverse invariant (values < 2p), checked analytically
        // for the largest RNS prime and then dynamically via the
        // debug_asserts in forward/inverse on extreme inputs.
        let d = 64usize;
        let p = rns_basis_primes(d, 1)[0]; // the largest prime < 2^30
        assert!(4u128 * p as u128 <= u64::MAX as u128, "4p must fit u64");
        // Lazy Shoup stays under 2p for the full lazy input range [0, 4p).
        let t = NttTable::new(p, d);
        for &s_idx in &[1usize, d / 2, d - 1] {
            let (s, s_sh) = (t.psi_rev[s_idx], t.psi_rev_shoup[s_idx]);
            for x in [0u64, 1, p - 1, 2 * p - 1, 4 * p - 1] {
                let lazy = mulmod_shoup_lazy(x, s, s_sh, p);
                assert!(lazy < 2 * p, "lazy product escaped [0, 2p)");
                assert_eq!(lazy % p, mulmod(x, s, p));
            }
        }
        // Extreme planes (all zeros, all p−1) round-trip canonically —
        // with debug_asserts on, this walks every butterfly bound.
        for fill in [0u64, p - 1] {
            let a = vec![fill; d];
            let mut b = a.clone();
            t.forward(&mut b);
            assert!(b.iter().all(|&x| x < p), "forward output must be canonical");
            t.inverse(&mut b);
            assert_eq!(a, b);
        }
    }

    #[test]
    fn lazy_bounds_across_basis_primes() {
        // Every prime of a realistic largest-q_count basis satisfies the
        // lazy headroom, and transforms agree with the schoolbook oracle
        // (i.e. laziness is invisible from outside the module).
        let d = 16usize;
        let mut rng = ChaChaRng::from_seed(77);
        for p in rns_basis_primes(d, 12) {
            assert!(4u128 * p as u128 <= u64::MAX as u128);
            let t = NttTable::new(p, d);
            let a = rand_poly(&mut rng, d, p);
            let b = rand_poly(&mut rng, d, p);
            assert_eq!(t.polymul(&a, &b), polymul_naive(&a, &b, p), "p = {p}");
        }
    }

    #[test]
    fn negacyclic_wraparound_sign() {
        // x^{d-1} * x = x^d = -1.
        let d = 8usize;
        let p = rns_basis_primes(d, 1)[0];
        let t = NttTable::new(p, d);
        let mut a = vec![0u64; d];
        let mut b = vec![0u64; d];
        a[d - 1] = 1;
        b[1] = 1;
        let c = t.polymul(&a, &b);
        let mut expect = vec![0u64; d];
        expect[0] = p - 1;
        assert_eq!(c, expect);
    }

    #[test]
    fn multiplication_by_constant() {
        let d = 16usize;
        let p = rns_basis_primes(d, 1)[0];
        let t = NttTable::new(p, d);
        let mut rng = ChaChaRng::from_seed(9);
        let a = rand_poly(&mut rng, d, p);
        let mut c = vec![0u64; d];
        c[0] = 3;
        let out = t.polymul(&a, &c);
        for i in 0..d {
            assert_eq!(out[i], mulmod(a[i], 3, p));
        }
    }

    #[test]
    fn linearity_property() {
        // NTT(a + b) == NTT(a) + NTT(b) pointwise.
        let d = 64usize;
        let p = rns_basis_primes(d, 1)[0];
        let t = NttTable::new(p, d);
        let mut rng = ChaChaRng::from_seed(10);
        let a = rand_poly(&mut rng, d, p);
        let b = rand_poly(&mut rng, d, p);
        let sum: Vec<u64> = (0..d).map(|i| addmod(a[i], b[i], p)).collect();
        let (mut fa, mut fb, mut fs) = (a.clone(), b.clone(), sum.clone());
        t.forward(&mut fa);
        t.forward(&mut fb);
        t.forward(&mut fs);
        for i in 0..d {
            assert_eq!(fs[i], addmod(fa[i], fb[i], p));
        }
    }
}
