//! Mathematical substrate for the FV cryptosystem.
//!
//! Everything the scheme needs that a big-number / NTT library would
//! normally provide, implemented from scratch (the build is offline and
//! no such crates are vendored):
//!
//! - [`modarith`] — `u64` modular arithmetic (`mulmod`, `powmod`,
//!   `invmod`) with `u128` intermediates, plus the division-free
//!   reduction primitives every hot loop uses: Shoup multiplication by
//!   invariant operands and 128-bit-reciprocal Barrett reduction.
//! - [`primes`] — deterministic Miller–Rabin and NTT-friendly prime
//!   generation (`p ≡ 1 mod 2d`), mirrored bit-for-bit by
//!   `python/compile/rns.py` so Rust and the AOT artifacts agree on the
//!   RNS basis.
//! - [`ntt`] — in-place negacyclic number-theoretic transform
//!   (Cooley–Tukey forward / Gentleman–Sande inverse with ψ-twisting
//!   folded into the tables, lazy-reduction butterflies in
//!   `[0, 4p)`/`[0, 2p)`).
//! - [`bigint`] — arbitrary-precision unsigned/signed integers (u64
//!   limbs) with Knuth-D division; used for CRT lifts, the BFV
//!   scale-and-round, and Lemma-3 bound arithmetic.
//! - [`crt`] — RNS bases: CRT lift/reduce between residue planes and
//!   big integers.
//! - [`baseconv`] — fast RNS base conversion (fixed-point-corrected
//!   forward extension, exact Shenoy–Kumaresan back conversion with a
//!   redundant modulus); the allocation-free substrate of the full-RNS
//!   multiply pipeline.
//! - [`poly`] — polynomials in `R_q = Z_q[x]/(x^d + 1)` stored as RNS
//!   residue planes.

pub mod baseconv;
pub mod bigint;
pub mod crt;
pub mod modarith;
pub mod ntt;
pub mod poly;
pub mod primes;
