//! # `els` — Encrypted Least Squares
//!
//! A three-layer Rust + JAX + Pallas reproduction of
//! *"Encrypted accelerated least squares regression"*
//! (Esperança, Aslett & Holmes, AISTATS 2017).
//!
//! The library fits ordinary and ridge least squares **directly on
//! ciphertexts** under a from-scratch implementation of the
//! Fan–Vercauteren (FV/BFV) fully homomorphic encryption scheme. The
//! paper's encrypted descent algorithms — ELS-GD, ELS-CD, ELS-NAG — and
//! the van Wijngaarden transformation (VWT) acceleration are first-class
//! features, and a coordinator serves batched encrypted regression jobs
//! with the homomorphic hot path dispatched either to a native Rust
//! backend or to AOT-compiled XLA executables (authored in JAX/Pallas,
//! loaded via PJRT).
//!
//! ## Layout
//!
//! - [`math`] — modular arithmetic, NTT, arbitrary-precision integers,
//!   RNS/CRT: the polynomial-ring substrate for FV.
//! - [`fhe`] — the FV cryptosystem: parameters (§4.5 of the paper),
//!   key generation, encryption, homomorphic operations, noise tracking.
//! - [`els`] — the paper's regression algorithms in three interchangeable
//!   backends (encrypted, exact encoded-integer simulation, f64).
//! - [`data`] — synthetic workload generators matching the paper's
//!   simulation studies and applications.
//! - [`runtime`] — homomorphic compute backends: native Rust and
//!   XLA/PJRT executing AOT artifacts.
//! - [`coordinator`] — the serving layer: job scheduling, dynamic
//!   batching of homomorphic ops, ciphertext arena, admission control.
//! - [`figures`] — regenerates every table and figure of the paper's
//!   evaluation as CSV.
//! - [`util`] — offline-build substrates: JSON, CLI parsing, thread
//!   pool, property-testing and benchmarking harnesses.

pub mod coordinator;
pub mod data;
pub mod els;
pub mod fhe;
pub mod figures;
pub mod math;
pub mod runtime;
pub mod util;
