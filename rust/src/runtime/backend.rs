//! The homomorphic compute seam: everything above this trait (ELS
//! drivers, coordinator) is backend-agnostic; everything below it
//! (native Rust NTT, XLA/PJRT batched artifacts) is interchangeable.
//!
//! The batching boundary is `mul_pairs`: one GD iteration emits all its
//! `2·N·P` ciphertext multiplications as a single call, which the
//! native engine fans across threads and the XLA engine lowers to
//! padded fixed-shape artifact executions.

use std::sync::Arc;
use std::sync::atomic::{AtomicU64, Ordering};

use crate::fhe::{Ciphertext, FvContext, MulBackend, Plaintext, RelinKey};
use crate::util::pool::parallel_map;

/// Operation counters (fig5 instrumentation and batching diagnostics).
#[derive(Default, Debug)]
pub struct OpStats {
    pub ct_muls: AtomicU64,
    pub plain_muls: AtomicU64,
    pub adds: AtomicU64,
    pub batches: AtomicU64,
}

impl OpStats {
    pub fn snapshot(&self) -> (u64, u64, u64, u64) {
        (
            self.ct_muls.load(Ordering::Relaxed),
            self.plain_muls.load(Ordering::Relaxed),
            self.adds.load(Ordering::Relaxed),
            self.batches.load(Ordering::Relaxed),
        )
    }
}

/// A homomorphic evaluation engine bound to one FV context + relin key.
pub trait HeEngine: Send + Sync {
    fn ctx(&self) -> &FvContext;

    /// Batched ciphertext×ciphertext multiplication (with
    /// relinearisation). The batching seam for XLA dispatch.
    fn mul_pairs(&self, pairs: &[(&Ciphertext, &Ciphertext)]) -> Vec<Ciphertext>;

    fn stats(&self) -> &OpStats;

    // Cheap ops with default implementations via the context.
    fn add(&self, a: &Ciphertext, b: &Ciphertext) -> Ciphertext {
        self.stats().adds.fetch_add(1, Ordering::Relaxed);
        self.ctx().add_ct(a, b)
    }

    fn sub(&self, a: &Ciphertext, b: &Ciphertext) -> Ciphertext {
        self.stats().adds.fetch_add(1, Ordering::Relaxed);
        self.ctx().sub_ct(a, b)
    }

    fn neg(&self, a: &Ciphertext) -> Ciphertext {
        self.ctx().neg_ct(a)
    }

    fn mul_plain(&self, a: &Ciphertext, pt: &Plaintext) -> Ciphertext {
        self.stats().plain_muls.fetch_add(1, Ordering::Relaxed);
        self.ctx().mul_plain(a, pt)
    }

    /// Convenience single multiplication.
    fn mul(&self, a: &Ciphertext, b: &Ciphertext) -> Ciphertext {
        self.mul_pairs(&[(a, b)]).pop().unwrap()
    }
}

/// Pure-Rust engine: thread-parallel `mul_ct` over the pair batch.
/// The arithmetic backend (full-RNS vs exact-bigint oracle) rides on
/// the context's [`MulBackend`]; [`NativeEngine::with_backend`]
/// overrides it at construction.
pub struct NativeEngine {
    pub ctx: Arc<FvContext>,
    pub rk: Arc<RelinKey>,
    stats: OpStats,
}

impl NativeEngine {
    pub fn new(ctx: Arc<FvContext>, rk: Arc<RelinKey>) -> Self {
        NativeEngine { ctx, rk, stats: OpStats::default() }
    }

    /// Build with an explicit multiply backend (parity tests, benches,
    /// the CLI's `--backend` flag). Keys stay valid across backends —
    /// they live entirely in the Q basis.
    pub fn with_backend(ctx: Arc<FvContext>, rk: Arc<RelinKey>, backend: MulBackend) -> Self {
        NativeEngine { ctx: ctx.with_backend(backend), rk, stats: OpStats::default() }
    }
}

impl HeEngine for NativeEngine {
    fn ctx(&self) -> &FvContext {
        &self.ctx
    }

    fn stats(&self) -> &OpStats {
        &self.stats
    }

    fn mul_pairs(&self, pairs: &[(&Ciphertext, &Ciphertext)]) -> Vec<Ciphertext> {
        self.stats.ct_muls.fetch_add(pairs.len() as u64, Ordering::Relaxed);
        self.stats.batches.fetch_add(1, Ordering::Relaxed);
        let ctx = &self.ctx;
        let rk = &self.rk;
        parallel_map(pairs.to_vec(), move |(a, b)| ctx.mul_ct(a, b, rk))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fhe::encoding::encode_int;
    use crate::fhe::keys::keygen;
    use crate::fhe::params::FvParams;
    use crate::fhe::rng::ChaChaRng;

    #[test]
    fn native_engine_batched_mul() {
        let ctx = FvContext::new(FvParams::custom(256, 3, 24));
        let mut rng = ChaChaRng::from_seed(201);
        let keys = keygen(&ctx, &mut rng);
        let engine = NativeEngine::new(ctx.clone(), Arc::new(keys.rk));
        let values = [(3i64, 5i64), (-7, 11), (100, -2), (0, 9)];
        let cts: Vec<(Ciphertext, Ciphertext)> = values
            .iter()
            .map(|&(a, b)| {
                (
                    ctx.encrypt(&encode_int(a, ctx.d()), &keys.pk, &mut rng),
                    ctx.encrypt(&encode_int(b, ctx.d()), &keys.pk, &mut rng),
                )
            })
            .collect();
        let pairs: Vec<(&Ciphertext, &Ciphertext)> =
            cts.iter().map(|(a, b)| (a, b)).collect();
        let out = engine.mul_pairs(&pairs);
        for (ct, &(a, b)) in out.iter().zip(values.iter()) {
            let pt = ctx.decrypt(ct, &keys.sk);
            assert_eq!(pt.eval_at_2().to_i128(), Some((a * b) as i128));
        }
        let (muls, _, _, batches) = engine.stats().snapshot();
        assert_eq!(muls, 4);
        assert_eq!(batches, 1);
    }
}
