//! The homomorphic compute seam: everything above this trait (ELS
//! drivers, coordinator) is backend-agnostic; everything below it
//! (native Rust NTT, XLA/PJRT batched artifacts) is interchangeable.
//!
//! The batching boundary is `mul_pairs`: one GD iteration emits all its
//! `2·N·P` ciphertext multiplications as a single call, which the
//! native engine fans across threads and the XLA engine lowers to
//! padded fixed-shape artifact executions.

use std::sync::Arc;
use std::sync::atomic::{AtomicU64, Ordering};

use crate::fhe::rns_mul::MulScratch;
use crate::fhe::{
    Ciphertext, Encoding, FvContext, GaloisKeys, MulBackend, Plaintext, PlaintextNtt, RelinKey,
};
use crate::util::error::Result;
use crate::util::pool::{parallel_map_with, pool_workers};

/// Operation counters (fig5 instrumentation and batching diagnostics).
#[derive(Default, Debug)]
pub struct OpStats {
    pub ct_muls: AtomicU64,
    pub plain_muls: AtomicU64,
    pub adds: AtomicU64,
    pub batches: AtomicU64,
}

impl OpStats {
    pub fn snapshot(&self) -> (u64, u64, u64, u64) {
        (
            self.ct_muls.load(Ordering::Relaxed),
            self.plain_muls.load(Ordering::Relaxed),
            self.adds.load(Ordering::Relaxed),
            self.batches.load(Ordering::Relaxed),
        )
    }
}

/// Minimum ring degree for the *intra*-multiply worker fan-out. Below
/// this, one NTT limb plane (`d·log d` butterflies) or base-conversion
/// chunk is only a few microseconds of work — less than a scoped-thread
/// spawn+join — so leftover budget would buy thread churn, not speed.
/// At `d ≥ 2048` a plane is tens of microseconds and the split pays.
/// Batch-level parallelism (and per-worker scratch reuse) is unaffected.
const INTRA_MUL_MIN_DEGREE: usize = 2048;

/// A homomorphic evaluation engine bound to one FV context + relin key.
pub trait HeEngine: Send + Sync {
    fn ctx(&self) -> &FvContext;

    /// Batched ciphertext×ciphertext multiplication (with
    /// relinearisation). The batching seam for XLA dispatch.
    fn mul_pairs(&self, pairs: &[(&Ciphertext, &Ciphertext)]) -> Vec<Ciphertext>;

    /// Batched **fused inner products**: one relinearised ciphertext
    /// `Σ_k a_k·b_k` per (non-empty) group. This is the primitive the
    /// encrypted descent loops emit — the algebra needs one
    /// relinearisation + scale-and-round per output *sum*, not per
    /// product, so a native implementation accumulates the degree-2
    /// tensors across the group and runs the expensive pipeline once
    /// (`n+p` pipelines per GD iteration instead of `2·n·p`).
    ///
    /// The default implementation degrades to one `mul_pairs` batch
    /// plus an add fold, so engines without a native fused path (the
    /// XLA backend, at present) keep working with identical semantics.
    fn dot_pairs(&self, groups: &[&[(&Ciphertext, &Ciphertext)]]) -> Vec<Ciphertext> {
        let flat: Vec<(&Ciphertext, &Ciphertext)> =
            groups.iter().flat_map(|g| g.iter().copied()).collect();
        let mut prods = self.mul_pairs(&flat).into_iter();
        groups
            .iter()
            .map(|g| {
                assert!(!g.is_empty(), "dot_pairs group must be non-empty");
                let mut acc = prods.next().unwrap();
                for _ in 1..g.len() {
                    acc = self.add(&acc, &prods.next().unwrap());
                }
                acc
            })
            .collect()
    }

    fn stats(&self) -> &OpStats;

    // Cheap ops with default implementations via the context.
    fn add(&self, a: &Ciphertext, b: &Ciphertext) -> Ciphertext {
        self.stats().adds.fetch_add(1, Ordering::Relaxed);
        self.ctx().add_ct(a, b)
    }

    fn sub(&self, a: &Ciphertext, b: &Ciphertext) -> Ciphertext {
        self.stats().adds.fetch_add(1, Ordering::Relaxed);
        self.ctx().sub_ct(a, b)
    }

    fn neg(&self, a: &Ciphertext) -> Ciphertext {
        self.ctx().neg_ct(a)
    }

    fn mul_plain(&self, a: &Ciphertext, pt: &Plaintext) -> Ciphertext {
        self.stats().plain_muls.fetch_add(1, Ordering::Relaxed);
        self.ctx().mul_plain(a, pt)
    }

    /// Cache a plaintext operand in NTT form for repeated
    /// [`mul_plain_prepared`](Self::mul_plain_prepared) calls — one
    /// forward transform total, `Arc`-shared.
    fn prepare_plaintext(&self, pt: &Plaintext) -> PlaintextNtt {
        self.ctx().prepare_plaintext(pt)
    }

    /// Plaintext multiply against a cached operand: zero plaintext
    /// transforms, ≤ 1 forward per non-resident ciphertext component,
    /// NTT-resident result.
    fn mul_plain_prepared(&self, a: &Ciphertext, m: &PlaintextNtt) -> Ciphertext {
        self.stats().plain_muls.fetch_add(1, Ordering::Relaxed);
        self.ctx().mul_plain_prepared(a, m)
    }

    /// Convenience single multiplication.
    fn mul(&self, a: &Ciphertext, b: &Ciphertext) -> Ciphertext {
        self.mul_pairs(&[(a, b)]).pop().unwrap()
    }

    /// Rotate both packed rows left by `steps` slots. The default
    /// degrades gracefully (the `dot_pairs` pattern): every rotation
    /// that is the identity permutation — zero steps, a full row
    /// cycle, or a scalar-encoded context whose single logical slot
    /// cannot move — returns the ciphertext unchanged; anything else
    /// is an error rather than a panic, so engines without Galois
    /// keys (the XLA stub, at present) keep compiling and working on
    /// the scalar path.
    fn rotate_rows(&self, ct: &Ciphertext, steps: usize) -> Result<Ciphertext> {
        let half = (self.ctx().d() / 2).max(1);
        if self.ctx().params.encoding == Encoding::Scalar || steps % half == 0 {
            return Ok(ct.clone());
        }
        crate::bail!(
            "engine has no rotation support (no Galois keys); \
             use NativeEngine::with_galois_keys"
        );
    }

    /// Sum every slot into every slot (`log₂(d/2) + 1` key-switches on
    /// a keyed engine). The scalar-encoding default is the mul-free
    /// identity — with one logical slot, the slot sum *is* the
    /// ciphertext — so scalar pipelines run unchanged on any engine.
    fn slot_sum(&self, ct: &Ciphertext) -> Result<Ciphertext> {
        if self.ctx().params.encoding == Encoding::Scalar {
            return Ok(ct.clone());
        }
        crate::bail!(
            "engine has no slot_sum support (no Galois keys); \
             use NativeEngine::with_galois_keys"
        );
    }
}

/// Pure-Rust engine: thread-parallel `mul_ct` over the pair batch.
/// The arithmetic backend (full-RNS vs exact-bigint oracle) rides on
/// the context's [`MulBackend`]; [`NativeEngine::with_backend`]
/// overrides it at construction.
///
/// The `mul_pairs` fan-out splits the worker budget (`ELS_POOL_WORKERS`
/// or `available_parallelism`, overridable per engine) two ways: up to
/// `len(pairs)` workers across the batch, and — on rings big enough to
/// amortise a thread spawn ([`INTRA_MUL_MIN_DEGREE`]) — any leftover
/// budget *inside* each multiply across its NTT limb planes and
/// base-conversion coefficient ranges, so a 1-pair batch on an 8-core
/// box still uses the cores. Each batch worker owns one reusable
/// [`MulScratch`], eliminating the per-call tensor/scale `Vec` churn.
/// Results are bit-identical and in input order for every worker count.
pub struct NativeEngine {
    pub ctx: Arc<FvContext>,
    pub rk: Arc<RelinKey>,
    /// Galois rotation keys; empty unless installed with
    /// [`with_galois_keys`](Self::with_galois_keys). Only packed
    /// pipelines need them — scalar fits never rotate.
    gk: Arc<GaloisKeys>,
    /// Explicit worker budget; `None` reads [`pool_workers`] per call.
    workers: Option<usize>,
    stats: OpStats,
}

impl NativeEngine {
    pub fn new(ctx: Arc<FvContext>, rk: Arc<RelinKey>) -> Self {
        NativeEngine {
            ctx,
            rk,
            gk: Arc::new(GaloisKeys::default()),
            workers: None,
            stats: OpStats::default(),
        }
    }

    /// Build with an explicit multiply backend (parity tests, benches,
    /// the CLI's `--backend` flag). Keys stay valid across backends —
    /// they live entirely in the Q basis.
    pub fn with_backend(ctx: Arc<FvContext>, rk: Arc<RelinKey>, backend: MulBackend) -> Self {
        NativeEngine::new(ctx.with_backend(backend), rk)
    }

    /// Install the Galois rotation keys (additive builder — existing
    /// `new(ctx, rk)` call sites stay valid). Required before
    /// `rotate_rows`/`slot_sum` do real work on a packed context.
    pub fn with_galois_keys(mut self, gk: Arc<GaloisKeys>) -> Self {
        self.gk = gk;
        self
    }

    /// Pin the worker budget (tests and controlled benches; production
    /// callers leave it on the `ELS_POOL_WORKERS` default).
    pub fn with_pool_workers(mut self, workers: usize) -> Self {
        self.workers = Some(workers.max(1));
        self
    }

    fn worker_budget(&self) -> usize {
        self.workers.unwrap_or_else(pool_workers)
    }

    /// Split the worker budget between batch-level fan-out over `items`
    /// work units and intra-multiply plane/range fan-out (the latter
    /// only on rings big enough to amortise a thread spawn).
    fn split_budget(&self, items: usize) -> (usize, usize) {
        let budget = self.worker_budget();
        let outer = budget.min(items.max(1));
        let inner = if self.ctx.ring_q.d >= INTRA_MUL_MIN_DEGREE {
            (budget / outer).max(1)
        } else {
            1
        };
        (outer, inner)
    }
}

impl HeEngine for NativeEngine {
    fn ctx(&self) -> &FvContext {
        &self.ctx
    }

    fn stats(&self) -> &OpStats {
        &self.stats
    }

    fn mul_pairs(&self, pairs: &[(&Ciphertext, &Ciphertext)]) -> Vec<Ciphertext> {
        self.stats.ct_muls.fetch_add(pairs.len() as u64, Ordering::Relaxed);
        self.stats.batches.fetch_add(1, Ordering::Relaxed);
        if pairs.is_empty() {
            return Vec::new();
        }
        let ctx = &self.ctx;
        let rk = &self.rk;
        // Split the budget: batch-level first (it parallelises the
        // whole multiply); leftover goes intra-multiply, but only on
        // rings where a plane/chunk outweighs a thread spawn.
        let (outer, inner) = self.split_budget(pairs.len());
        parallel_map_with(
            pairs.to_vec(),
            outer,
            // Empty holder: sized on first full-RNS use, free for the
            // bigint oracle backend (which never touches it).
            MulScratch::empty,
            move |scratch, (a, b)| ctx.mul_ct_with(a, b, rk, scratch, inner),
        )
    }

    fn dot_pairs(&self, groups: &[&[(&Ciphertext, &Ciphertext)]]) -> Vec<Ciphertext> {
        let total: u64 = groups.iter().map(|g| g.len() as u64).sum();
        self.stats.ct_muls.fetch_add(total, Ordering::Relaxed);
        self.stats.batches.fetch_add(1, Ordering::Relaxed);
        if groups.is_empty() {
            return Vec::new();
        }
        let ctx = &self.ctx;
        let rk = &self.rk;
        // Same two-way budget split as `mul_pairs`: groups fan across
        // the batch workers, leftover budget goes to the intra-group
        // plane/range fan-out on large rings. Each group's pipeline
        // (u128 tensor accumulation → one scale-and-round → one
        // relinearisation) runs on one worker, so results are
        // bit-identical and in input order for every worker count.
        let (outer, inner) = self.split_budget(groups.len());
        parallel_map_with(
            groups.to_vec(),
            outer,
            MulScratch::empty,
            move |scratch, g| ctx.dot_group_with(g, rk, scratch, inner),
        )
    }

    fn rotate_rows(&self, ct: &Ciphertext, steps: usize) -> Result<Ciphertext> {
        let half = (self.ctx.d() / 2).max(1);
        if self.ctx.params.encoding == Encoding::Scalar || steps % half == 0 {
            return Ok(ct.clone());
        }
        if self.gk.is_empty() {
            crate::bail!(
                "packed rotation requested but no Galois keys installed; \
                 build the engine with NativeEngine::with_galois_keys"
            );
        }
        Ok(self.ctx.rotate_rows(ct, steps, &self.gk))
    }

    fn slot_sum(&self, ct: &Ciphertext) -> Result<Ciphertext> {
        if self.ctx.params.encoding == Encoding::Scalar {
            return Ok(ct.clone());
        }
        if self.gk.is_empty() {
            crate::bail!(
                "packed slot_sum requested but no Galois keys installed; \
                 build the engine with NativeEngine::with_galois_keys"
            );
        }
        Ok(self.ctx.slot_sum(ct, &self.gk))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fhe::encoding::{encode_int, Encoder};
    use crate::fhe::keys::keygen;
    use crate::fhe::params::FvParams;
    use crate::fhe::rng::ChaChaRng;

    #[test]
    fn native_engine_batched_mul() {
        let ctx = FvContext::new(FvParams::custom(256, 3, 24));
        let mut rng = ChaChaRng::from_seed(201);
        let keys = keygen(&ctx, &mut rng);
        let engine = NativeEngine::new(ctx.clone(), Arc::new(keys.rk));
        let values = [(3i64, 5i64), (-7, 11), (100, -2), (0, 9)];
        let cts: Vec<(Ciphertext, Ciphertext)> = values
            .iter()
            .map(|&(a, b)| {
                (
                    ctx.encrypt(&encode_int(a, ctx.d()), &keys.pk, &mut rng),
                    ctx.encrypt(&encode_int(b, ctx.d()), &keys.pk, &mut rng),
                )
            })
            .collect();
        let pairs: Vec<(&Ciphertext, &Ciphertext)> =
            cts.iter().map(|(a, b)| (a, b)).collect();
        let out = engine.mul_pairs(&pairs);
        for (ct, &(a, b)) in out.iter().zip(values.iter()) {
            let pt = ctx.decrypt(ct, &keys.sk);
            assert_eq!(pt.eval_at_2().to_i128(), Some((a * b) as i128));
        }
        let (muls, _, _, batches) = engine.stats().snapshot();
        assert_eq!(muls, 4);
        assert_eq!(batches, 1);
    }

    #[test]
    fn mul_pairs_is_deterministic_across_worker_counts() {
        // A 16-pair batch must come back identical — order and bits —
        // for every worker budget (serial and the ELS_POOL_WORKERS CI
        // values 1/4/8 among them). At this toy degree the leftover
        // budget never goes intra-multiply (d=256 < INTRA_MUL_MIN_DEGREE);
        // the engine-level inner split is covered by
        // `intra_multiply_split_engages_on_large_rings` below, and the
        // plane/chunk fan-out itself by the rns_mul/poly/baseconv tests.
        let ctx = FvContext::new(FvParams::custom(256, 3, 24));
        let mut rng = ChaChaRng::from_seed(202);
        let keys = keygen(&ctx, &mut rng);
        let rk = Arc::new(keys.rk);
        let cts: Vec<(Ciphertext, Ciphertext)> = (0..16i64)
            .map(|k| {
                (
                    ctx.encrypt(&encode_int(3 * k - 7, ctx.d()), &keys.pk, &mut rng),
                    ctx.encrypt(&encode_int(11 - k, ctx.d()), &keys.pk, &mut rng),
                )
            })
            .collect();
        let pairs: Vec<(&Ciphertext, &Ciphertext)> =
            cts.iter().map(|(a, b)| (a, b)).collect();
        let reference = NativeEngine::new(ctx.clone(), rk.clone())
            .with_pool_workers(1)
            .mul_pairs(&pairs);
        for workers in [4usize, 8, 3, 16, 32] {
            let engine =
                NativeEngine::new(ctx.clone(), rk.clone()).with_pool_workers(workers);
            let out = engine.mul_pairs(&pairs);
            assert_eq!(out.len(), reference.len());
            for (i, (got, want)) in out.iter().zip(&reference).enumerate() {
                assert_eq!(got.polys, want.polys, "pair {i}, workers {workers}");
                assert_eq!(got.ct_depth, want.ct_depth);
            }
        }
        // The env-var path takes the same code (worker_budget() →
        // pool_workers() → the identical fan-out); CI exercises it by
        // running this whole suite under ELS_POOL_WORKERS=1. Never
        // set_var here — mutating the env races concurrent test
        // threads reading it (UB on glibc).
        let out = NativeEngine::new(ctx.clone(), rk.clone()).mul_pairs(&pairs);
        for (got, want) in out.iter().zip(&reference) {
            assert_eq!(got.polys, want.polys, "ambient worker budget");
        }
    }

    #[test]
    fn dot_pairs_matches_fold_across_backends_workers_and_shapes() {
        // The satellite parity battery: dot_pairs must decrypt
        // identically to the fold of mul_pairs-plus-adds on both
        // multiply backends, for worker counts 1/2/4 and group shapes
        // singleton / whole-batch / ragged — and be bit-identical
        // across worker counts.
        for backend in [MulBackend::FullRns, MulBackend::ExactBigint] {
            let ctx = FvContext::new(FvParams::custom(256, 3, 24)).with_backend(backend);
            let mut rng = ChaChaRng::from_seed(204);
            let keys = keygen(&ctx, &mut rng);
            let rk = Arc::new(keys.rk.clone());
            let vals: Vec<(i64, i64)> = (0..8i64).map(|k| (2 * k - 5, 7 - 3 * k)).collect();
            let cts: Vec<(Ciphertext, Ciphertext)> = vals
                .iter()
                .map(|&(a, b)| {
                    (
                        ctx.encrypt(&encode_int(a, ctx.d()), &keys.pk, &mut rng),
                        ctx.encrypt(&encode_int(b, ctx.d()), &keys.pk, &mut rng),
                    )
                })
                .collect();
            let pairs: Vec<(&Ciphertext, &Ciphertext)> =
                cts.iter().map(|(a, b)| (a, b)).collect();
            for shape in [vec![1usize], vec![8], vec![2, 5, 1]] {
                let mut groups: Vec<&[(&Ciphertext, &Ciphertext)]> = Vec::new();
                let mut bounds = Vec::new();
                let mut cursor = 0usize;
                for &len in &shape {
                    groups.push(&pairs[cursor..cursor + len]);
                    bounds.push((cursor, cursor + len));
                    cursor += len;
                }
                let serial =
                    NativeEngine::new(ctx.clone(), rk.clone()).with_pool_workers(1);
                // Reference: the default-impl semantics — one mul_pairs
                // batch per group, folded with adds.
                let folds: Vec<Ciphertext> = groups
                    .iter()
                    .map(|g| {
                        let prods = serial.mul_pairs(g);
                        let mut acc = prods[0].clone();
                        for p in &prods[1..] {
                            acc = serial.add(&acc, p);
                        }
                        acc
                    })
                    .collect();
                let reference = serial.dot_pairs(&groups);
                for workers in [1usize, 2, 4] {
                    let engine =
                        NativeEngine::new(ctx.clone(), rk.clone()).with_pool_workers(workers);
                    let out = engine.dot_pairs(&groups);
                    assert_eq!(out.len(), groups.len());
                    for (gi, got) in out.iter().enumerate() {
                        assert_eq!(
                            got.polys, reference[gi].polys,
                            "{backend:?} shape {shape:?} group {gi}: \
                             bits differ at {workers} workers"
                        );
                        let dec = ctx.decrypt(got, &keys.sk);
                        assert_eq!(
                            dec,
                            ctx.decrypt(&folds[gi], &keys.sk),
                            "{backend:?} shape {shape:?} group {gi}: fused vs fold"
                        );
                        let (s, e) = bounds[gi];
                        let expect: i128 =
                            vals[s..e].iter().map(|&(a, b)| a as i128 * b as i128).sum();
                        assert_eq!(dec.eval_at_2().to_i128(), Some(expect));
                    }
                }
            }
            // Singleton groups are mul_pairs, bit for bit — the
            // batcher routes mul_pairs through the group seam on the
            // strength of this.
            let engine = NativeEngine::new(ctx.clone(), rk.clone()).with_pool_workers(2);
            let singles: Vec<&[(&Ciphertext, &Ciphertext)]> = pairs.chunks(1).collect();
            let via_dot = engine.dot_pairs(&singles);
            let via_mul = engine.mul_pairs(&pairs);
            for (i, (a, b)) in via_dot.iter().zip(&via_mul).enumerate() {
                assert_eq!(a.polys, b.polys, "{backend:?}: singleton group {i}");
                assert_eq!(a.ct_depth, b.ct_depth);
            }
        }
    }

    #[test]
    fn dot_pairs_default_impl_matches_native() {
        // A wrapper that deliberately refuses to override dot_pairs
        // must still produce decrypt-identical group sums through the
        // mul_pairs + add-fold default — the XLA degradation contract.
        struct Fallback(NativeEngine);
        impl HeEngine for Fallback {
            fn ctx(&self) -> &FvContext {
                self.0.ctx()
            }
            fn stats(&self) -> &OpStats {
                self.0.stats()
            }
            fn mul_pairs(&self, pairs: &[(&Ciphertext, &Ciphertext)]) -> Vec<Ciphertext> {
                self.0.mul_pairs(pairs)
            }
        }
        let ctx = FvContext::new(FvParams::custom(256, 3, 24));
        let mut rng = ChaChaRng::from_seed(205);
        let keys = keygen(&ctx, &mut rng);
        let rk = Arc::new(keys.rk);
        let cts: Vec<(Ciphertext, Ciphertext)> = (0..5i64)
            .map(|k| {
                (
                    ctx.encrypt(&encode_int(k + 1, ctx.d()), &keys.pk, &mut rng),
                    ctx.encrypt(&encode_int(2 * k - 3, ctx.d()), &keys.pk, &mut rng),
                )
            })
            .collect();
        let pairs: Vec<(&Ciphertext, &Ciphertext)> = cts.iter().map(|(a, b)| (a, b)).collect();
        let groups: Vec<&[(&Ciphertext, &Ciphertext)]> = vec![&pairs[..2], &pairs[2..]];
        let native = NativeEngine::new(ctx.clone(), rk.clone());
        let fallback = Fallback(NativeEngine::new(ctx.clone(), rk.clone()));
        let a = native.dot_pairs(&groups);
        let b = fallback.dot_pairs(&groups);
        assert_eq!(a.len(), b.len());
        for (i, (x, y)) in a.iter().zip(&b).enumerate() {
            assert_eq!(
                ctx.decrypt(x, &keys.sk),
                ctx.decrypt(y, &keys.sk),
                "group {i}: native fused vs default fold"
            );
        }
        // Empty input is a no-op on both paths.
        assert!(native.dot_pairs(&[]).is_empty());
        assert!(fallback.dot_pairs(&[]).is_empty());
    }

    #[test]
    fn engine_rotation_defaults_degrade_gracefully() {
        // The satellite contract, mirroring dot_pairs' default-impl
        // pattern: engines that never override rotate_rows/slot_sum
        // (the XLA stub) must stay correct on scalar contexts (identity
        // is the right answer with one logical slot) and fail loudly —
        // an Err, not a panic — when a packed pipeline asks them to
        // actually rotate.
        struct NoRotate(NativeEngine);
        impl HeEngine for NoRotate {
            fn ctx(&self) -> &FvContext {
                self.0.ctx()
            }
            fn stats(&self) -> &OpStats {
                self.0.stats()
            }
            fn mul_pairs(&self, pairs: &[(&Ciphertext, &Ciphertext)]) -> Vec<Ciphertext> {
                self.0.mul_pairs(pairs)
            }
        }
        // Scalar context: defaults are identities everywhere.
        let ctx = FvContext::new(FvParams::custom(256, 3, 24));
        let mut rng = ChaChaRng::from_seed(206);
        let keys = keygen(&ctx, &mut rng);
        let engine = NoRotate(NativeEngine::new(ctx.clone(), Arc::new(keys.rk.clone())));
        let ct = ctx.encrypt(&encode_int(42, ctx.d()), &keys.pk, &mut rng);
        let rot = engine.rotate_rows(&ct, 3).expect("scalar rotation is the identity");
        assert_eq!(ctx.decrypt(&rot, &keys.sk), ctx.decrypt(&ct, &keys.sk));
        let sum = engine.slot_sum(&ct).expect("scalar slot_sum is the identity");
        assert_eq!(ctx.decrypt(&sum, &keys.sk), ctx.decrypt(&ct, &keys.sk));
        // Packed context, keyless default: identity rotations still
        // succeed, real ones surface as errors on both the default
        // impl and a keyless NativeEngine.
        let pctx = FvContext::new(FvParams::custom_packed(256, 3, 24).unwrap());
        let mut prng = ChaChaRng::from_seed(207);
        let pkeys = keygen(&pctx, &mut prng);
        let prk = Arc::new(pkeys.rk.clone());
        let vals: Vec<i64> = (0..pctx.d() as i64).collect();
        let pct = pctx.encrypt(&pctx.encoder().encode_vec(&vals), &pkeys.pk, &mut prng);
        let keyless = NoRotate(NativeEngine::new(pctx.clone(), prk.clone()));
        assert!(keyless.rotate_rows(&pct, 0).is_ok(), "zero steps never needs keys");
        assert!(keyless.rotate_rows(&pct, pctx.d() / 2).is_ok(), "full cycle is the identity");
        assert!(keyless.rotate_rows(&pct, 3).is_err(), "real rotation needs keys");
        assert!(keyless.slot_sum(&pct).is_err(), "packed slot_sum needs keys");
        let native_keyless = NativeEngine::new(pctx.clone(), prk.clone());
        assert!(native_keyless.rotate_rows(&pct, 3).is_err());
        assert!(native_keyless.slot_sum(&pct).is_err());
        // Keyed native engine: matches the ops-layer rotation bit for
        // bit and sums every slot.
        let keyed = NativeEngine::new(pctx.clone(), prk.clone())
            .with_galois_keys(Arc::new(pkeys.gk.clone()));
        let rot = keyed.rotate_rows(&pct, 5).expect("keyed rotation");
        assert_eq!(rot.polys, pctx.rotate_rows(&pct, 5, &pkeys.gk).polys);
        let summed = keyed.slot_sum(&pct).expect("keyed slot_sum");
        let total: i128 = vals.iter().map(|&v| v as i128).sum();
        let got = pctx.encoder().decode_vec(&pctx.decrypt(&summed, &pkeys.sk), pctx.d());
        assert!(got.iter().all(|v| v.to_i128() == Some(total)));
    }

    #[test]
    fn intra_multiply_split_engages_on_large_rings() {
        // Above INTRA_MUL_MIN_DEGREE the engine hands leftover budget
        // to the intra-multiply fan-out (outer = pairs, inner =
        // budget/outer > 1). A 2-pair batch at budget 8 (inner 4) must
        // be bit-identical to the fully serial run — this is the only
        // test that drives the inner>1 branch *through the engine's
        // split arithmetic* rather than calling mul_no_relin_rns_with
        // directly.
        let ctx = FvContext::new(FvParams::custom(2048, 2, 20));
        assert!(ctx.d() >= super::INTRA_MUL_MIN_DEGREE);
        let mut rng = ChaChaRng::from_seed(203);
        let keys = keygen(&ctx, &mut rng);
        let rk = Arc::new(keys.rk);
        let cts: Vec<(Ciphertext, Ciphertext)> = (0..2i64)
            .map(|k| {
                (
                    ctx.encrypt(&encode_int(5 + k, ctx.d()), &keys.pk, &mut rng),
                    ctx.encrypt(&encode_int(-9 * k - 1, ctx.d()), &keys.pk, &mut rng),
                )
            })
            .collect();
        let pairs: Vec<(&Ciphertext, &Ciphertext)> =
            cts.iter().map(|(a, b)| (a, b)).collect();
        let reference = NativeEngine::new(ctx.clone(), rk.clone())
            .with_pool_workers(1)
            .mul_pairs(&pairs);
        let split = NativeEngine::new(ctx.clone(), rk.clone())
            .with_pool_workers(8)
            .mul_pairs(&pairs);
        for (i, (got, want)) in split.iter().zip(&reference).enumerate() {
            assert_eq!(got.polys, want.polys, "pair {i} under inner split");
        }
    }
}
