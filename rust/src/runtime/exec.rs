//! In-tree async event-loop runtime: a small executor with a ready
//! queue and fixed worker lanes, a hashed timer wheel for deadlines,
//! and a one-shot completion event. Zero crates.io dependencies — the
//! same discipline as `util::error`.
//!
//! The coordinator used to spawn one OS thread per job; under
//! saturation that is thousands of stacks and an unbounded thread
//! herd. [`Executor`] replaces it with N named lanes draining a shared
//! ready queue, [`TimerWheel`] fires job deadlines without a thread
//! per timer, and [`Event`] gives each waiter an O(1)-wakeup
//! completion signal (one condvar per job, not a global broadcast).

use std::collections::VecDeque;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::time::{Duration, Instant};

use crate::util::faults::{self, FaultKind, FaultSite};

type Task = Box<dyn FnOnce() + Send + 'static>;

struct ExecShared {
    queue: Mutex<VecDeque<Task>>,
    cv: Condvar,
    shutdown: AtomicBool,
    task_panics: AtomicU64,
}

/// Fixed-lane task executor. `spawn` enqueues a closure on the shared
/// ready queue; the lanes drain it FIFO. Bounding and fairness live in
/// the coordinator (which decides *what* to enqueue) — the executor
/// itself is a plain ready-queue so it can also serve timers, replies
/// and any other deferred work.
pub struct Executor {
    shared: Arc<ExecShared>,
    lanes: Vec<std::thread::JoinHandle<()>>,
}

impl Executor {
    pub fn new(name: &str, lanes: usize) -> Self {
        let lanes = lanes.max(1);
        let shared = Arc::new(ExecShared {
            queue: Mutex::new(VecDeque::new()),
            cv: Condvar::new(),
            shutdown: AtomicBool::new(false),
            task_panics: AtomicU64::new(0),
        });
        let handles = (0..lanes)
            .map(|i| {
                let sh = Arc::clone(&shared);
                std::thread::Builder::new()
                    .name(format!("{name}-lane-{i}"))
                    .spawn(move || lane_loop(&sh))
                    .expect("spawn executor lane")
            })
            .collect();
        Executor { shared, lanes: handles }
    }

    pub fn lanes(&self) -> usize {
        self.lanes.len()
    }

    /// Enqueue a task on the ready queue. Returns `false` (the task is
    /// rejected, not silently dropped) once shutdown has begun — the
    /// caller decides how to resolve the work it could not hand off.
    /// Tasks already queued at shutdown still run: the lanes drain the
    /// queue before exiting, so every accepted task is executed.
    #[must_use = "a false return means the task was rejected, not enqueued"]
    pub fn spawn(&self, f: impl FnOnce() + Send + 'static) -> bool {
        if self.shared.shutdown.load(Ordering::Acquire) {
            return false;
        }
        self.shared.queue.lock().unwrap().push_back(Box::new(f));
        self.shared.cv.notify_one();
        true
    }

    /// Begin shutdown without joining the lanes: new `spawn`s are
    /// rejected from this point on, while already-queued tasks drain.
    /// Idempotent; `Drop` still joins.
    pub fn shutdown(&self) {
        self.shared.shutdown.store(true, Ordering::Release);
        self.shared.cv.notify_all();
    }

    /// Crash-simulation shutdown: reject new spawns AND drop every
    /// queued task *without running it* — the executor analogue of the
    /// process dying with work on the ready queue. Tasks already
    /// executing on lanes run to completion (threads cannot be
    /// preempted); `Drop` still joins. The graceful path is
    /// [`shutdown`](Self::shutdown), which drains instead of dropping.
    pub fn abort(&self) {
        self.shared.shutdown.store(true, Ordering::Release);
        self.shared.queue.lock().unwrap().clear();
        self.shared.cv.notify_all();
    }

    /// Tasks whose closure panicked (caught; the lane survives).
    pub fn task_panics(&self) -> u64 {
        self.shared.task_panics.load(Ordering::Relaxed)
    }
}

impl Drop for Executor {
    fn drop(&mut self) {
        self.shutdown();
        for h in self.lanes.drain(..) {
            let _ = h.join();
        }
    }
}

fn lane_loop(sh: &ExecShared) {
    loop {
        let task = {
            let mut q = sh.queue.lock().unwrap();
            loop {
                if let Some(t) = q.pop_front() {
                    break Some(t);
                }
                if sh.shutdown.load(Ordering::Acquire) {
                    break None;
                }
                q = sh.cv.wait(q).unwrap();
            }
        };
        match task {
            // A panicking task must not take its lane (and every task
            // queued behind it) down with it: catch, count, continue.
            // Job-level failure reporting is the coordinator's business
            // — it wraps engine work in its own catch_unwind.
            Some(t) => {
                if std::panic::catch_unwind(std::panic::AssertUnwindSafe(t)).is_err() {
                    sh.task_panics.fetch_add(1, Ordering::Relaxed);
                }
            }
            None => return, // shutdown with an empty queue: lane exits
        }
    }
}

// ---- timer wheel --------------------------------------------------------

struct TimerEntry {
    deadline: Instant,
    cancelled: Arc<AtomicBool>,
    f: Task,
}

struct WheelShared {
    slots: Mutex<Vec<Vec<TimerEntry>>>,
    cv: Condvar,
    shutdown: AtomicBool,
    start: Instant,
    granularity: Duration,
    scheduled: AtomicU64,
    fired: AtomicU64,
    cancelled: AtomicU64,
    callback_panics: AtomicU64,
}

/// Cancellation handle for a scheduled timer. Dropping the handle does
/// NOT cancel the timer (fire-and-forget is the common case).
pub struct TimerHandle {
    cancelled: Arc<AtomicBool>,
}

impl TimerHandle {
    pub fn cancel(&self) {
        self.cancelled.store(true, Ordering::Release);
    }

    pub fn is_cancelled(&self) -> bool {
        self.cancelled.load(Ordering::Acquire)
    }
}

/// Hashed single-level timer wheel: `NSLOTS` buckets of `granularity`
/// width, one tick thread. Entries keep their absolute deadline, so a
/// deadline further out than one lap simply stays in its bucket until
/// the lap that owns it (checked against `Instant::now()` each visit).
/// Expired callbacks run on the wheel thread — keep them tiny (the
/// coordinator's expiry callback just flips job state and notifies).
pub struct TimerWheel {
    shared: Arc<WheelShared>,
    tick: Option<std::thread::JoinHandle<()>>,
}

const NSLOTS: usize = 64;

impl TimerWheel {
    pub fn new(name: &str, granularity: Duration) -> Self {
        let granularity = granularity.max(Duration::from_millis(1));
        let shared = Arc::new(WheelShared {
            slots: Mutex::new((0..NSLOTS).map(|_| Vec::new()).collect()),
            cv: Condvar::new(),
            shutdown: AtomicBool::new(false),
            start: Instant::now(),
            granularity,
            scheduled: AtomicU64::new(0),
            fired: AtomicU64::new(0),
            cancelled: AtomicU64::new(0),
            callback_panics: AtomicU64::new(0),
        });
        let sh = Arc::clone(&shared);
        let tick = std::thread::Builder::new()
            .name(format!("{name}-timer"))
            .spawn(move || wheel_loop(&sh))
            .expect("spawn timer wheel");
        TimerWheel { shared, tick: Some(tick) }
    }

    fn slot_of(&self, deadline: Instant) -> usize {
        let offset = deadline.saturating_duration_since(self.shared.start);
        let ticks = offset.as_nanos() / self.shared.granularity.as_nanos().max(1);
        (ticks as usize) % NSLOTS
    }

    /// Schedule `f` to run at (or shortly after) `deadline`. Firing
    /// resolution is one granularity tick. Returns a handle whose
    /// `cancel()` makes the wheel drop the entry instead of firing it.
    pub fn schedule(&self, deadline: Instant, f: impl FnOnce() + Send + 'static) -> TimerHandle {
        let cancelled = Arc::new(AtomicBool::new(false));
        let entry = TimerEntry {
            deadline,
            cancelled: Arc::clone(&cancelled),
            f: Box::new(f),
        };
        let slot = self.slot_of(deadline);
        self.shared.slots.lock().unwrap()[slot].push(entry);
        self.shared.scheduled.fetch_add(1, Ordering::Relaxed);
        self.shared.cv.notify_one();
        TimerHandle { cancelled }
    }

    /// Entries currently parked in the wheel (scheduled, not yet fired
    /// or reaped) — the leak counter the chaos battery asserts on.
    pub fn live_entries(&self) -> usize {
        self.shared.slots.lock().unwrap().iter().map(Vec::len).sum()
    }

    /// `(scheduled, fired, cancelled, callback_panics)` since start.
    pub fn counts(&self) -> (u64, u64, u64, u64) {
        (
            self.shared.scheduled.load(Ordering::Relaxed),
            self.shared.fired.load(Ordering::Relaxed),
            self.shared.cancelled.load(Ordering::Relaxed),
            self.shared.callback_panics.load(Ordering::Relaxed),
        )
    }
}

impl Drop for TimerWheel {
    fn drop(&mut self) {
        self.shared.shutdown.store(true, Ordering::Release);
        self.shared.cv.notify_all();
        if let Some(h) = self.tick.take() {
            let _ = h.join();
        }
        // Entries still parked at drop resolve deterministically as
        // *cancelled*, never silently vanish: each handle's flag flips
        // so `is_cancelled()` observers see the resolution, and the
        // cancelled counter accounts for every scheduled entry
        // (scheduled == fired + cancelled once the wheel is gone).
        let mut slots = self.shared.slots.lock().unwrap();
        for bucket in slots.iter_mut() {
            for entry in bucket.drain(..) {
                entry.cancelled.store(true, Ordering::Release);
                self.shared.cancelled.fetch_add(1, Ordering::Relaxed);
            }
        }
    }
}

fn wheel_loop(sh: &WheelShared) {
    let mut cursor = 0usize;
    loop {
        if sh.shutdown.load(Ordering::Acquire) {
            return;
        }
        let now = Instant::now();
        let mut due: Vec<TimerEntry> = Vec::new();
        {
            let mut slots = sh.slots.lock().unwrap();
            // Visit every slot each pass: with 64 slots this is cheap,
            // and it makes firing independent of cursor/lap alignment
            // (entries hash to a slot only to bound per-bucket scans).
            for _ in 0..NSLOTS {
                let bucket = &mut slots[cursor % NSLOTS];
                let mut i = 0;
                while i < bucket.len() {
                    if bucket[i].cancelled.load(Ordering::Acquire) {
                        bucket.swap_remove(i);
                        sh.cancelled.fetch_add(1, Ordering::Relaxed);
                    } else if bucket[i].deadline <= now {
                        // Chaos `timer:late`: hold a due entry for one
                        // more pass — it fires next visit, proving
                        // consumers tolerate delayed expiry.
                        if faults::check(FaultSite::Timer) == Some(FaultKind::Late) {
                            i += 1;
                        } else {
                            due.push(bucket.swap_remove(i));
                        }
                    } else if faults::check(FaultSite::Timer) == Some(FaultKind::Spurious) {
                        // Chaos `timer:spurious`: fire before the
                        // deadline — consumers must re-check real time,
                        // never trust the wheel's word alone.
                        due.push(bucket.swap_remove(i));
                    } else {
                        i += 1;
                    }
                }
                cursor = cursor.wrapping_add(1);
            }
            if due.is_empty() {
                let (guard, _) = sh.cv.wait_timeout(slots, sh.granularity).unwrap();
                drop(guard);
            }
        }
        for entry in due {
            sh.fired.fetch_add(1, Ordering::Relaxed);
            // A panicking expiry callback must not kill the tick thread
            // (every later deadline would silently never fire).
            if std::panic::catch_unwind(std::panic::AssertUnwindSafe(entry.f)).is_err() {
                sh.callback_panics.fetch_add(1, Ordering::Relaxed);
            }
        }
    }
}

// ---- one-shot completion event ------------------------------------------

/// One-shot event: `notify()` flips the state exactly once; waiters
/// block on a dedicated condvar so a completion wakes only the waiters
/// of *this* event. `checks` counts state inspections performed by
/// waiters — the O(1)-wakeup regression test reads it to prove a long
/// wait is not spinning (a healthy wait checks a handful of times, a
/// broadcast-woken or polling wait checks once per unrelated event).
pub struct Event {
    state: Mutex<bool>,
    cv: Condvar,
    checks: AtomicU64,
}

impl Event {
    pub fn new() -> Self {
        Event { state: Mutex::new(false), cv: Condvar::new(), checks: AtomicU64::new(0) }
    }

    pub fn notify(&self) {
        let mut done = self.state.lock().unwrap();
        *done = true;
        self.cv.notify_all();
    }

    pub fn is_set(&self) -> bool {
        *self.state.lock().unwrap()
    }

    /// Wait until notified or `timeout` elapses. Returns `true` if the
    /// event fired.
    pub fn wait_timeout(&self, timeout: Duration) -> bool {
        let deadline = Instant::now() + timeout;
        let mut done = self.state.lock().unwrap();
        self.checks.fetch_add(1, Ordering::Relaxed);
        while !*done {
            let now = Instant::now();
            if now >= deadline {
                return false;
            }
            let (guard, _) = self.cv.wait_timeout(done, deadline - now).unwrap();
            done = guard;
            self.checks.fetch_add(1, Ordering::Relaxed);
        }
        true
    }

    /// Number of state inspections waiters have performed so far.
    pub fn checks(&self) -> u64 {
        self.checks.load(Ordering::Relaxed)
    }
}

impl Default for Event {
    fn default() -> Self {
        Self::new()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicUsize;

    #[test]
    fn executor_runs_tasks_on_all_lanes() {
        let exec = Executor::new("t", 3);
        assert_eq!(exec.lanes(), 3);
        let counter = Arc::new(AtomicUsize::new(0));
        let done = Arc::new(Event::new());
        let total = 24;
        for _ in 0..total {
            let c = Arc::clone(&counter);
            let d = Arc::clone(&done);
            assert!(exec.spawn(move || {
                if c.fetch_add(1, Ordering::SeqCst) + 1 == total {
                    d.notify();
                }
            }));
        }
        assert!(done.wait_timeout(Duration::from_secs(10)));
        assert_eq!(counter.load(Ordering::SeqCst), total);
    }

    #[test]
    fn executor_drop_drains_queue_before_join() {
        let counter = Arc::new(AtomicUsize::new(0));
        {
            let exec = Executor::new("drain", 2);
            for _ in 0..16 {
                let c = Arc::clone(&counter);
                assert!(exec.spawn(move || {
                    c.fetch_add(1, Ordering::SeqCst);
                }));
            }
            // Drop joins the lanes; all enqueued tasks must have run.
        }
        assert_eq!(counter.load(Ordering::SeqCst), 16);
    }

    #[test]
    fn executor_rejects_spawn_after_shutdown_but_drains_queued() {
        // The shutdown contract: accepted work runs, new work is
        // rejected loudly — nothing is silently dropped either way.
        let counter = Arc::new(AtomicUsize::new(0));
        let exec = Executor::new("reject", 1);
        for _ in 0..8 {
            let c = Arc::clone(&counter);
            assert!(exec.spawn(move || {
                c.fetch_add(1, Ordering::SeqCst);
            }));
        }
        exec.shutdown();
        let c = Arc::clone(&counter);
        assert!(
            !exec.spawn(move || {
                c.fetch_add(100, Ordering::SeqCst);
            }),
            "spawn after shutdown must be rejected"
        );
        drop(exec);
        assert_eq!(counter.load(Ordering::SeqCst), 8, "queued tasks ran, rejected task did not");
    }

    #[test]
    fn executor_abort_drops_queued_tasks_without_running() {
        // The crash contract is the inverse of the drain contract:
        // nothing on the ready queue runs after an abort.
        let counter = Arc::new(AtomicUsize::new(0));
        let exec = Executor::new("abort", 1);
        let gate = Arc::new(Event::new());
        // Park the single lane on a gated task, queue work behind it.
        let g = Arc::clone(&gate);
        assert!(exec.spawn(move || {
            g.wait_timeout(Duration::from_secs(30));
        }));
        for _ in 0..8 {
            let c = Arc::clone(&counter);
            assert!(exec.spawn(move || {
                c.fetch_add(1, Ordering::SeqCst);
            }));
        }
        exec.abort();
        let c = Arc::clone(&counter);
        assert!(!exec.spawn(move || {
            c.fetch_add(100, Ordering::SeqCst);
        }));
        gate.notify();
        drop(exec); // joins the lane
        assert_eq!(counter.load(Ordering::SeqCst), 0, "aborted queue must not run");
    }

    #[test]
    fn executor_lane_survives_task_panic() {
        let exec = Executor::new("panic", 1);
        let _ = exec.spawn(|| panic!("injected task panic"));
        // The single lane must still be alive to run the next task.
        let ev = Arc::new(Event::new());
        let e = Arc::clone(&ev);
        assert!(exec.spawn(move || e.notify()));
        assert!(ev.wait_timeout(Duration::from_secs(10)), "lane died with the panicking task");
        assert_eq!(exec.task_panics(), 1);
    }

    #[test]
    fn timer_fires_after_deadline_and_cancel_suppresses() {
        let wheel = TimerWheel::new("t", Duration::from_millis(2));
        let fired = Arc::new(AtomicUsize::new(0));
        let ev = Arc::new(Event::new());
        let (f, e) = (Arc::clone(&fired), Arc::clone(&ev));
        wheel.schedule(Instant::now() + Duration::from_millis(10), move || {
            f.fetch_add(1, Ordering::SeqCst);
            e.notify();
        });
        let f2 = Arc::clone(&fired);
        let h = wheel.schedule(Instant::now() + Duration::from_millis(10), move || {
            f2.fetch_add(100, Ordering::SeqCst);
        });
        h.cancel();
        assert!(h.is_cancelled());
        assert!(ev.wait_timeout(Duration::from_secs(10)));
        // Give the cancelled entry's slot a few laps to prove silence.
        std::thread::sleep(Duration::from_millis(50));
        assert_eq!(fired.load(Ordering::SeqCst), 1);
    }

    #[test]
    fn timer_survives_wheel_laps() {
        // Deadline far beyond one lap (64 slots × 1ms): the entry must
        // stay parked until its absolute deadline passes.
        let wheel = TimerWheel::new("lap", Duration::from_millis(1));
        let ev = Arc::new(Event::new());
        let e = Arc::clone(&ev);
        let t0 = Instant::now();
        wheel.schedule(t0 + Duration::from_millis(150), move || e.notify());
        assert!(ev.wait_timeout(Duration::from_secs(10)));
        assert!(t0.elapsed() >= Duration::from_millis(150));
    }

    #[test]
    fn timer_drop_resolves_pending_entries_as_cancelled() {
        let fired = Arc::new(AtomicUsize::new(0));
        let handle;
        {
            let wheel = TimerWheel::new("droppy", Duration::from_millis(5));
            let f = Arc::clone(&fired);
            handle = wheel.schedule(Instant::now() + Duration::from_secs(3600), move || {
                f.fetch_add(1, Ordering::SeqCst);
            });
            assert_eq!(wheel.live_entries(), 1);
            // Wheel drops here with the entry still parked.
        }
        assert!(handle.is_cancelled(), "drop must resolve parked entries as cancelled");
        assert_eq!(fired.load(Ordering::SeqCst), 0);
    }

    #[test]
    fn timer_accounting_balances() {
        // One fires, one is cancelled (reaped on a later pass), one
        // stays parked: scheduled == fired + cancelled + live.
        let wheel = TimerWheel::new("acct", Duration::from_millis(2));
        let ev = Arc::new(Event::new());
        let e = Arc::clone(&ev);
        wheel.schedule(Instant::now() + Duration::from_millis(5), move || e.notify());
        let h = wheel.schedule(Instant::now() + Duration::from_secs(3600), || {});
        h.cancel();
        let _parked = wheel.schedule(Instant::now() + Duration::from_secs(3600), || {});
        assert!(ev.wait_timeout(Duration::from_secs(10)));
        let t0 = Instant::now();
        loop {
            let (scheduled, fired, cancelled, _) = wheel.counts();
            if fired == 1 && cancelled == 1 {
                assert_eq!(scheduled, 3);
                assert_eq!(wheel.live_entries(), 1);
                break;
            }
            assert!(t0.elapsed() < Duration::from_secs(10), "cancelled entry never reaped");
            std::thread::sleep(Duration::from_millis(2));
        }
    }

    #[test]
    fn timer_callback_panic_does_not_kill_wheel() {
        let wheel = TimerWheel::new("cbpanic", Duration::from_millis(2));
        wheel.schedule(Instant::now() + Duration::from_millis(4), || {
            panic!("injected timer callback panic")
        });
        let ev = Arc::new(Event::new());
        let e = Arc::clone(&ev);
        wheel.schedule(Instant::now() + Duration::from_millis(20), move || e.notify());
        assert!(ev.wait_timeout(Duration::from_secs(10)), "wheel thread died with the panic");
        let (_, fired, _, panics) = wheel.counts();
        assert_eq!(panics, 1);
        assert_eq!(fired, 2);
        assert_eq!(wheel.live_entries(), 0);
    }

    #[test]
    fn event_wakeup_is_constant_checks() {
        let ev = Arc::new(Event::new());
        let e = Arc::clone(&ev);
        let waiter = std::thread::spawn(move || e.wait_timeout(Duration::from_secs(30)));
        std::thread::sleep(Duration::from_millis(30));
        ev.notify();
        assert!(waiter.join().unwrap());
        // One check on entry, one after the single wakeup (± a spurious
        // wake): far below anything resembling a poll loop.
        assert!(ev.checks() <= 4, "waiter performed {} state checks", ev.checks());
    }

    #[test]
    fn event_timeout_returns_false() {
        let ev = Event::new();
        assert!(!ev.wait_timeout(Duration::from_millis(20)));
        assert!(!ev.is_set());
        ev.notify();
        assert!(ev.is_set());
        assert!(ev.wait_timeout(Duration::from_millis(1)));
    }

    #[test]
    fn event_double_notify_is_idempotent() {
        // The recovery paths (panic reclaim, drain bounce, expiry) may
        // race to complete the same job event; a second notify must be
        // a harmless no-op, never a panic or a state flip.
        let ev = Arc::new(Event::new());
        ev.notify();
        ev.notify();
        assert!(ev.is_set());
        let waiters: Vec<_> = (0..4)
            .map(|_| {
                let e = Arc::clone(&ev);
                std::thread::spawn(move || e.wait_timeout(Duration::from_secs(10)))
            })
            .collect();
        for w in waiters {
            assert!(w.join().unwrap());
        }
    }

    #[test]
    fn event_wait_after_complete_returns_immediately() {
        let ev = Event::new();
        ev.notify();
        let t0 = Instant::now();
        assert!(ev.wait_timeout(Duration::from_secs(30)));
        assert!(t0.elapsed() < Duration::from_secs(1), "wait after complete must not block");
        // A completed event costs exactly one state check per wait.
        let before = ev.checks();
        assert!(ev.wait_timeout(Duration::from_secs(30)));
        assert_eq!(ev.checks(), before + 1);
    }
}
