//! XLA/PJRT compute engine: executes the AOT-compiled `polymul`
//! artifacts (authored in JAX/Pallas, see `python/compile/`) from the
//! Rust hot path.
//!
//! The engine implements [`HeEngine`](crate::runtime::backend::HeEngine)
//! at the `mul_pairs` batching seam (always via the exact-bigint
//! tensor basis — the artifact set predates the full-RNS native
//! pipeline; lowering the base-conversion path to XLA is an open
//! ROADMAP item): a batch of ciphertext multiplications becomes
//!   1. CRT lifts Q → Q∪E (Rust, thread-parallel),
//!   2. one padded, fixed-shape `polymul` dispatch per batch segment
//!      for the 4·B tensor-product products (XLA),
//!   3. exact t/q scale-and-round (Rust, thread-parallel),
//!   4. one `polymul` dispatch stream for the 2ℓ·B relinearisation
//!      digit products (XLA), accumulated in Rust.
//!
//! Keys stay **NTT-resident** in the engine: the relinearisation key
//! is stored exactly as keygen produced it and only lowered to
//! coefficient form — once, lazily — at the artifact boundary when the
//! first `mul_pairs` batch dispatches (ROADMAP PR-4 follow-up).
//! `dot_pairs` (fused inner products) has no XLA lowering yet and
//! rides the trait default (`mul_pairs` + add fold); lowering the
//! tensor accumulation into the artifact stream is the next open item.
//!
//! PJRT handles are not `Send`/`Sync` at the type level (raw pointers);
//! all access is serialised behind one mutex, and the CPU PJRT plugin
//! itself is thread-safe, so sharing the engine across coordinator
//! threads is sound.
//!
//! The PJRT bindings (`xla` crate) are not vendorable in the offline
//! build, so the real engine sits behind the `xla` cargo feature; the
//! default build ships an API-compatible stub whose constructor returns
//! an error. Callers that probe for the backend (the benches, the
//! `serve_e2e` example) fall back to the native engine; the CLI's
//! explicit `--xla` flag propagates the error and exits, since the user
//! asked for a backend that isn't available.

#[cfg(feature = "xla")]
mod imp {
    use std::collections::HashMap;
    use std::path::Path;
    use std::sync::atomic::Ordering;
    use std::sync::{Arc, Mutex, OnceLock};

    use crate::fhe::{Ciphertext, FvContext, RelinKey};
    use crate::math::poly::{Rep, RingContext, RnsPoly};
    use crate::runtime::artifacts::ArtifactDir;
    use crate::runtime::backend::{HeEngine, OpStats};
    use crate::util::error::{bail, Context, Result};
    use crate::util::pool::parallel_map;

    struct XlaInner {
        client: xla::PjRtClient,
        /// Compiled executable cache keyed by (d, nlimb, batch).
        exes: HashMap<(usize, usize, usize), xla::PjRtLoadedExecutable>,
        registry: ArtifactDir,
    }

    /// The XLA-backed homomorphic engine.
    pub struct XlaEngine {
        pub ctx: Arc<FvContext>,
        /// The relinearisation key, NTT-resident as keygen produced it.
        /// Construction no longer pays `2ℓ` inverse transforms up
        /// front: the key stays hot for any native-path reuse and is
        /// only lowered at the artifact boundary (below).
        rk: RelinKey,
        /// Relinearisation key digits in *coefficient* form — the
        /// representation the `polymul` artifacts take. Converted
        /// lazily, once, on the first `mul_pairs` dispatch; an engine
        /// that is constructed but never multiplies (backend probes,
        /// capability checks) pays zero key transforms.
        rk_coeff: OnceLock<Vec<(RnsPoly, RnsPoly)>>,
        inner: Mutex<XlaInner>,
        stats: OpStats,
    }

    // SAFETY: every use of the non-Send PJRT handles goes through
    // `self.inner` (a Mutex); the PJRT CPU plugin is thread-safe.
    unsafe impl Send for XlaEngine {}
    unsafe impl Sync for XlaEngine {}

    impl XlaEngine {
        /// Build from an FV context, relin key and artifact directory.
        pub fn new(ctx: Arc<FvContext>, rk: &RelinKey, artifact_dir: &Path) -> Result<Self> {
            let registry = ArtifactDir::load(artifact_dir)?;
            // Check the two rings this context needs are covered.
            for (ring, what) in
                [(&ctx.ring_q, "Q basis"), (&ctx.ring_big, "tensor basis")]
            {
                if registry.variants("polymul", ring.d, ring.nlimbs()).is_empty() {
                    bail!(
                        "no polymul artifact for d={} l={} ({what}); extend the \
                         manifest in python/compile/aot.py and re-run `make artifacts`",
                        ring.d,
                        ring.nlimbs()
                    );
                }
            }
            let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
            Ok(XlaEngine {
                ctx,
                rk: rk.clone(),
                rk_coeff: OnceLock::new(),
                inner: Mutex::new(XlaInner { client, exes: HashMap::new(), registry }),
                stats: OpStats::default(),
            })
        }

        /// The coefficient-form relinearisation key limbs, converted on
        /// first use (the artifact boundary is the only place the NTT
        /// residency must be given up).
        fn rk_coeff(&self) -> &Vec<(RnsPoly, RnsPoly)> {
            self.rk_coeff.get_or_init(|| {
                let ring = &self.ctx.ring_q;
                self.rk
                    .b_ntt
                    .iter()
                    .zip(&self.rk.a_ntt)
                    .map(|(b, a)| {
                        let mut bc = b.clone();
                        let mut ac = a.clone();
                        ring.ntt_inverse(&mut bc);
                        ring.ntt_inverse(&mut ac);
                        (bc, ac)
                    })
                    .collect()
            })
        }

        /// Execute a batch of negacyclic polynomial products on XLA.
        /// Operands must be coefficient-form polynomials of `ring`.
        pub fn polymul_batch(
            &self,
            ring: &RingContext,
            jobs: &[(&RnsPoly, &RnsPoly)],
        ) -> Result<Vec<RnsPoly>> {
            if jobs.is_empty() {
                return Ok(Vec::new());
            }
            let (d, nlimb) = (ring.d, ring.nlimbs());
            let mut inner = self.inner.lock().unwrap();
            let sizes: Vec<usize> = inner
                .registry
                .variants("polymul", d, nlimb)
                .iter()
                .map(|m| m.batch)
                .collect();
            let plan = ArtifactDir::plan_batches(&sizes, jobs.len());
            let mut out = Vec::with_capacity(jobs.len());
            let mut cursor = 0usize;
            for (batch, used) in plan {
                // Compile (or fetch) the executable for this batch size.
                let key = (d, nlimb, batch);
                if !inner.exes.contains_key(&key) {
                    let meta = inner
                        .registry
                        .variants("polymul", d, nlimb)
                        .into_iter()
                        .find(|m| m.batch == batch)
                        .unwrap()
                        .clone();
                    let proto = xla::HloModuleProto::from_text_file(
                        meta.path.to_str().context("artifact path not UTF-8")?,
                    )
                    .with_context(|| format!("parsing {:?}", meta.path))?;
                    let comp = xla::XlaComputation::from_proto(&proto);
                    let exe = inner
                        .client
                        .compile(&comp)
                        .with_context(|| format!("compiling {:?}", meta.path))?;
                    inner.exes.insert(key, exe);
                }
                // Pack operands as i64 [batch, nlimb, d] (zero-padded).
                let pack = |side: usize| -> xla::Literal {
                    let mut data = vec![0i64; batch * nlimb * d];
                    for (bi, job) in jobs[cursor..cursor + used].iter().enumerate() {
                        let poly = if side == 0 { job.0 } else { job.1 };
                        debug_assert_eq!(poly.rep, Rep::Coeff);
                        for l in 0..nlimb {
                            let dst =
                                &mut data[(bi * nlimb + l) * d..(bi * nlimb + l + 1) * d];
                            for (x, &v) in dst.iter_mut().zip(&poly.planes[l]) {
                                *x = v as i64;
                            }
                        }
                    }
                    xla::Literal::vec1(&data)
                        .reshape(&[batch as i64, nlimb as i64, d as i64])
                        .expect("reshape literal")
                };
                let a_lit = pack(0);
                let b_lit = pack(1);
                let exe = inner.exes.get(&key).unwrap();
                let result = exe
                    .execute::<xla::Literal>(&[a_lit, b_lit])
                    .context("executing polymul artifact")?[0][0]
                    .to_literal_sync()?
                    .to_tuple1()?;
                let flat = result.to_vec::<i64>()?;
                self.stats.batches.fetch_add(1, Ordering::Relaxed);
                for bi in 0..used {
                    let mut poly = ring.zero();
                    for l in 0..nlimb {
                        let src = &flat[(bi * nlimb + l) * d..(bi * nlimb + l + 1) * d];
                        for (dst, &v) in poly.planes[l].iter_mut().zip(src) {
                            debug_assert!(v >= 0);
                            *dst = v as u64;
                        }
                    }
                    out.push(poly);
                }
                cursor += used;
            }
            Ok(out)
        }
    }

    impl HeEngine for XlaEngine {
        fn ctx(&self) -> &FvContext {
            &self.ctx
        }

        fn stats(&self) -> &OpStats {
            &self.stats
        }

        fn mul_pairs(&self, pairs: &[(&Ciphertext, &Ciphertext)]) -> Vec<Ciphertext> {
            if pairs.is_empty() {
                return Vec::new();
            }
            self.stats.ct_muls.fetch_add(pairs.len() as u64, Ordering::Relaxed);
            let ctx = &self.ctx;
            // 1. CRT-lift all four components of every pair
            //    (thread-parallel). NTT-resident components are lazily
            //    brought back to coefficient form first — the artifacts
            //    take power-basis inputs.
            let lifted: Vec<[RnsPoly; 4]> = parallel_map(pairs.to_vec(), |(a, b)| {
                assert_eq!(a.len(), 2, "operands must be relinearised");
                assert_eq!(b.len(), 2);
                let rq = &ctx.ring_q;
                [
                    ctx.q_to_big(rq.coeff_form(&a.polys[0]).as_ref()),
                    ctx.q_to_big(rq.coeff_form(&a.polys[1]).as_ref()),
                    ctx.q_to_big(rq.coeff_form(&b.polys[0]).as_ref()),
                    ctx.q_to_big(rq.coeff_form(&b.polys[1]).as_ref()),
                ]
            });
            // 2. Tensor products: 4 polymuls per pair in one XLA stream.
            let jobs: Vec<(&RnsPoly, &RnsPoly)> = lifted
                .iter()
                .flat_map(|q| {
                    [(&q[0], &q[2]), (&q[0], &q[3]), (&q[1], &q[2]), (&q[1], &q[3])]
                })
                .collect();
            let prods = self
                .polymul_batch(&ctx.ring_big, &jobs)
                .expect("XLA polymul dispatch failed");
            // 3. Scale-and-round back to Q (thread-parallel).
            let scaled: Vec<[RnsPoly; 3]> = parallel_map(
                prods.chunks(4).map(|c| c.to_vec()).collect::<Vec<_>>(),
                |c| {
                    let c1 = ctx.ring_big.add(&c[1], &c[2]);
                    [
                        ctx.scale_round_to_q(&c[0]),
                        ctx.scale_round_to_q(&c1),
                        ctx.scale_round_to_q(&c[3]),
                    ]
                },
            );
            // The XLA path has no fused inner-product lowering yet
            // (dot_pairs degrades to this mul_pairs + add fold via the
            // trait default): one scale-round and one relinearisation
            // pipeline per pair, recorded on the ring counters so the
            // budget accounting stays comparable with the native path.
            for _ in 0..scaled.len() {
                ctx.ring_q.note_scale_round();
                ctx.ring_q.note_relin();
            }
            // 4. Relinearisation: digit products through XLA, accumulated
            //    in Rust.
            let digits: Vec<Vec<RnsPoly>> = parallel_map(
                scaled.iter().map(|s| s[2].clone()).collect::<Vec<_>>(),
                |c2| ctx.relin_digits(&c2),
            );
            let rk_coeff = self.rk_coeff();
            let relin_jobs: Vec<(&RnsPoly, &RnsPoly)> = digits
                .iter()
                .flat_map(|ds| {
                    ds.iter().zip(rk_coeff).flat_map(|(dj, (bj, aj))| {
                        [(dj, bj), (dj, aj)]
                    })
                })
                .collect();
            let relin_prods = self
                .polymul_batch(&ctx.ring_q, &relin_jobs)
                .expect("XLA relin dispatch failed");
            let ell = ctx.relin_ndigits;
            let ring = &ctx.ring_q;
            scaled
                .iter()
                .enumerate()
                .map(|(i, s)| {
                    let mut c0 = s[0].clone();
                    let mut c1 = s[1].clone();
                    let base = i * 2 * ell;
                    for j in 0..ell {
                        ring.add_assign(&mut c0, &relin_prods[base + 2 * j]);
                        ring.add_assign(&mut c1, &relin_prods[base + 2 * j + 1]);
                    }
                    let mut ct = Ciphertext::new(vec![c0, c1]);
                    ct.ct_depth = pairs[i].0.ct_depth.max(pairs[i].1.ct_depth) + 1;
                    ct
                })
                .collect()
        }
    }
}

#[cfg(not(feature = "xla"))]
mod imp {
    use std::path::Path;
    use std::sync::Arc;

    use crate::fhe::{Ciphertext, FvContext, RelinKey};
    use crate::math::poly::{RingContext, RnsPoly};
    use crate::runtime::backend::{HeEngine, OpStats};
    use crate::util::error::{bail, Result};

    /// Stub engine for builds without PJRT bindings. Construction always
    /// fails, so callers fall back to
    /// [`NativeEngine`](crate::runtime::backend::NativeEngine); the type
    /// still implements the full engine surface so call sites compile
    /// unchanged.
    pub struct XlaEngine {
        /// Public for parity with the `xla`-feature engine's surface.
        pub ctx: Arc<FvContext>,
        stats: OpStats,
    }

    impl XlaEngine {
        /// Always errors: the `xla` feature (and its vendored PJRT
        /// bindings) are required for the real engine.
        pub fn new(_ctx: Arc<FvContext>, _rk: &RelinKey, artifact_dir: &Path) -> Result<Self> {
            bail!(
                "XLA/PJRT backend not compiled in (artifact dir {artifact_dir:?}); \
                 rebuild with `--features xla` and vendored PJRT bindings, or use \
                 the native backend"
            )
        }

        /// Stub of the batched polynomial product.
        pub fn polymul_batch(
            &self,
            _ring: &RingContext,
            _jobs: &[(&RnsPoly, &RnsPoly)],
        ) -> Result<Vec<RnsPoly>> {
            bail!("XLA/PJRT backend not compiled in")
        }
    }

    impl HeEngine for XlaEngine {
        fn ctx(&self) -> &FvContext {
            &self.ctx
        }

        fn stats(&self) -> &OpStats {
            &self.stats
        }

        fn mul_pairs(&self, _pairs: &[(&Ciphertext, &Ciphertext)]) -> Vec<Ciphertext> {
            unreachable!("stub XlaEngine cannot be constructed")
        }
    }
}

pub use imp::XlaEngine;
