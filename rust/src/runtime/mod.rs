//! Homomorphic compute backends.
//!
//! - [`backend`] — the `HeEngine` trait (the ELS↔runtime seam) and the
//!   native Rust engine.
//! - [`artifacts`] — AOT artifact registry (`rns_meta.json` index with
//!   deterministic-prime cross-checks).
//! - [`exec`] — in-tree async event-loop runtime (executor lanes,
//!   timer wheel, one-shot events) for the serving tier.
//! - [`pjrt`] — the XLA/PJRT engine executing the JAX/Pallas-authored
//!   `polymul` artifacts.

pub mod artifacts;
pub mod backend;
pub mod exec;
pub mod pjrt;

pub use artifacts::ArtifactDir;
pub use backend::{HeEngine, NativeEngine, OpStats};
pub use exec::{Event, Executor, TimerHandle, TimerWheel};
pub use pjrt::XlaEngine;
