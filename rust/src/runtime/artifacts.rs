//! AOT artifact registry: discovers `artifacts/*.hlo.txt` via
//! `rns_meta.json` and cross-checks that the prime bases baked into the
//! compiled graphs match the Rust generator (they are produced by
//! mirrored deterministic rules; a mismatch means a stale or foreign
//! artifact directory and must fail loudly).

use std::path::{Path, PathBuf};

use crate::util::error::{bail, Context, Result};

use crate::math::primes::rns_basis_primes;
use crate::util::json::Json;

/// One compiled artifact.
#[derive(Clone, Debug)]
pub struct ArtifactMeta {
    pub op: String,
    pub d: usize,
    pub nlimb: usize,
    pub batch: usize,
    pub path: PathBuf,
}

/// The artifact directory index.
#[derive(Debug, Default)]
pub struct ArtifactDir {
    pub entries: Vec<ArtifactMeta>,
}

impl ArtifactDir {
    /// Load and validate `dir/rns_meta.json`.
    pub fn load(dir: &Path) -> Result<Self> {
        let meta_path = dir.join("rns_meta.json");
        let text = std::fs::read_to_string(&meta_path)
            .with_context(|| format!("reading {meta_path:?} (run `make artifacts`)"))?;
        let json = Json::parse(&text).context("parsing rns_meta.json")?;
        let mut entries = Vec::new();
        for op in json.req("ops")?.as_arr().context("ops must be an array")? {
            let d = op.req("d")?.as_usize().context("d")?;
            let nlimb = op.req("nlimb")?.as_usize().context("nlimb")?;
            let batch = op.req("batch")?.as_usize().context("batch")?;
            let file = op.req("file")?.as_str().context("file")?.to_string();
            let primes: Vec<u64> = op
                .req("primes")?
                .as_arr()
                .context("primes")?
                .iter()
                .filter_map(|p| p.as_u64())
                .collect();
            // Cross-check the deterministic prime rule.
            let expect = rns_basis_primes(d, nlimb);
            if primes != expect {
                bail!(
                    "artifact {file}: baked primes disagree with the Rust \
                     generator for d={d}, l={nlimb} — stale artifacts?"
                );
            }
            let path = dir.join(&file);
            if !path.exists() {
                bail!("artifact file missing: {path:?}");
            }
            entries.push(ArtifactMeta {
                op: op.req("op")?.as_str().context("op")?.to_string(),
                d,
                nlimb,
                batch,
                path,
            });
        }
        Ok(ArtifactDir { entries })
    }

    /// All batch variants for an (op, d, nlimb), sorted ascending by
    /// batch size.
    pub fn variants(&self, op: &str, d: usize, nlimb: usize) -> Vec<&ArtifactMeta> {
        let mut v: Vec<&ArtifactMeta> = self
            .entries
            .iter()
            .filter(|e| e.op == op && e.d == d && e.nlimb == nlimb)
            .collect();
        v.sort_by_key(|e| e.batch);
        v
    }

    /// Greedy batch plan: cover `n` jobs with available batch sizes.
    /// Full batches use the largest size; the remainder uses the
    /// smallest size that covers it in one (padded) launch — one padded
    /// launch beats many tiny exact ones. Returns (batch, count_used)
    /// segments in dispatch order.
    pub fn plan_batches(sizes: &[usize], mut n: usize) -> Vec<(usize, usize)> {
        assert!(!sizes.is_empty());
        let mut sorted = sizes.to_vec();
        sorted.sort_unstable();
        let largest = *sorted.last().unwrap();
        let mut plan = Vec::new();
        while n > 0 {
            if let Some(&s) = sorted.iter().find(|&&s| s >= n) {
                plan.push((s, n));
                n = 0;
            } else {
                plan.push((largest, largest));
                n -= largest;
            }
        }
        plan
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn batch_planning() {
        // jobs=70 with sizes {1,8,32}: 32+32+... greedy
        let plan = ArtifactDir::plan_batches(&[1, 8, 32], 70);
        let total: usize = plan.iter().map(|&(_, used)| used).sum();
        assert_eq!(total, 70);
        assert_eq!(plan[0], (32, 32));
        assert_eq!(plan[1], (32, 32));
        assert_eq!(plan[2], (8, 6)); // 6 jobs in an 8-batch (2 padded)
    }

    #[test]
    fn batch_planning_padding_small() {
        let plan = ArtifactDir::plan_batches(&[8], 3);
        assert_eq!(plan, vec![(8, 3)]);
        let plan = ArtifactDir::plan_batches(&[4, 16], 1);
        assert_eq!(plan, vec![(4, 1)]);
    }

    #[test]
    fn load_real_artifacts_if_present() {
        // Integration-style: only runs when `make artifacts` has run.
        let dir = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        if !dir.join("rns_meta.json").exists() {
            eprintln!("SKIPPED: artifacts not built (run `make artifacts`)");
            return;
        }
        let reg = ArtifactDir::load(&dir).unwrap();
        assert!(!reg.entries.is_empty());
        let v = reg.variants("polymul", 256, 7);
        assert!(!v.is_empty(), "expected d256 l7 polymul artifacts");
        for w in v.windows(2) {
            assert!(w[0].batch < w[1].batch);
        }
    }
}
