//! Retrying wire client: capped exponential backoff with decorrelated
//! jitter.
//!
//! [`RetryPolicy`] turns `(max_attempts, base_ms, cap_ms, seed)` into a
//! deterministic backoff schedule using the *decorrelated jitter*
//! recurrence — `sleep[i] = uniform(base, 3·sleep[i-1])`, capped — with
//! the uniform draws taken from the same counter-indexed splitmix64
//! stream the fault registry uses ([`faults::mix64`]). Two clients with
//! different seeds desynchronise (no retry storms); the same seed
//! replays the exact schedule, which is what makes the policy testable
//! without sleeping.
//!
//! [`RetryingClient`] wraps [`Client`] and retries **only** error codes
//! the protocol marks retryable ([`ErrorCode::retryable`]: `transport`
//! and `overloaded`). Everything else — `bad_request`, `job_failed`,
//! `deadline_exceeded`, `shutting_down`, … — passes through on first
//! sight: retrying a deterministic rejection is just load. On a
//! transport error the cached connection is dropped and redialled on
//! the next attempt.
//!
//! Retried submission is only safe when it is idempotent, so
//! [`RetryingClient::submit`] *requires* a token: if the first attempt
//! was admitted but its reply was lost, the resubmit re-attaches to the
//! original job instead of fitting twice.

use std::time::Duration;

use crate::coordinator::job::JobId;
use crate::coordinator::protocol::{WireError, WireResult};
use crate::coordinator::service::Client;
use crate::els::encrypted::{EncryptedFit, FitConfig};
use crate::els::model::EncryptedDataset;
use crate::util::faults;
use crate::util::json::Json;

/// Backoff policy: attempts, base/cap delays and the jitter seed.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct RetryPolicy {
    /// Total attempts (first try + retries); at least 1.
    pub max_attempts: u32,
    /// Lower bound of every backoff draw, ms.
    pub base_ms: u64,
    /// Upper bound (cap) of every backoff draw, ms.
    pub cap_ms: u64,
    /// Seed for the decorrelated-jitter draw stream.
    pub seed: u64,
}

impl RetryPolicy {
    pub fn new(max_attempts: u32, base_ms: u64, cap_ms: u64, seed: u64) -> RetryPolicy {
        RetryPolicy {
            max_attempts: max_attempts.max(1),
            base_ms: base_ms.max(1),
            cap_ms: cap_ms.max(base_ms.max(1)),
            seed,
        }
    }

    /// The full backoff schedule: `max_attempts - 1` sleeps (one
    /// between each pair of attempts), fully determined by the seed.
    pub fn backoff_schedule(&self) -> Vec<Duration> {
        let mut schedule = Vec::with_capacity(self.max_attempts.saturating_sub(1) as usize);
        let mut prev = self.base_ms;
        for i in 0..self.max_attempts.saturating_sub(1) {
            // Decorrelated jitter: uniform in [base, min(cap, 3*prev)].
            let hi = prev.saturating_mul(3).min(self.cap_ms).max(self.base_ms);
            let span = hi - self.base_ms + 1;
            let sleep = self.base_ms + faults::mix64(self.seed, i as u64) % span;
            schedule.push(Duration::from_millis(sleep));
            prev = sleep;
        }
        schedule
    }
}

impl Default for RetryPolicy {
    /// 5 attempts, 10ms..2s, fixed seed — override the seed per client
    /// in production so retries desynchronise.
    fn default() -> RetryPolicy {
        RetryPolicy::new(5, 10, 2000, 0x9e37_79b9_7f4a_7c15)
    }
}

/// A [`Client`] wrapper that redials and retries retryable failures
/// according to a [`RetryPolicy`].
pub struct RetryingClient {
    addr: String,
    client: Option<Client>,
    schedule: Vec<Duration>,
    retries: u64,
    sleeper: Box<dyn FnMut(Duration) + Send>,
}

impl RetryingClient {
    pub fn new(addr: &str, policy: RetryPolicy) -> RetryingClient {
        RetryingClient {
            addr: addr.to_string(),
            client: None,
            schedule: policy.backoff_schedule(),
            retries: 0,
            sleeper: Box::new(std::thread::sleep),
        }
    }

    /// Replace the sleep function — tests pass a recorder so backoff
    /// behaviour is asserted without wall-clock waits.
    pub fn with_sleeper(mut self, sleeper: impl FnMut(Duration) + Send + 'static) -> Self {
        self.sleeper = Box::new(sleeper);
        self
    }

    /// Retries performed so far (across all operations).
    pub fn retries(&self) -> u64 {
        self.retries
    }

    /// Run `op` against a (re)dialled connection, retrying per policy.
    fn with_retry<T>(&mut self, mut op: impl FnMut(&mut Client) -> WireResult<T>) -> WireResult<T> {
        let attempts = self.schedule.len() + 1;
        let mut last: Option<WireError> = None;
        for attempt in 0..attempts {
            if attempt > 0 {
                let pause = self.schedule[attempt - 1];
                (self.sleeper)(pause);
                self.retries += 1;
            }
            let res = match self.client.as_mut() {
                Some(c) => op(c),
                None => match Client::connect(&self.addr) {
                    Ok(mut c) => {
                        let r = op(&mut c);
                        self.client = Some(c);
                        r
                    }
                    Err(e) => Err(e),
                },
            };
            match res {
                Ok(v) => return Ok(v),
                Err(e) if e.code.retryable() => {
                    if e.code == crate::coordinator::protocol::ErrorCode::Transport {
                        // The connection is suspect — redial next time.
                        self.client = None;
                    }
                    last = Some(e);
                }
                Err(e) => return Err(e),
            }
        }
        Err(last.expect("at least one attempt ran"))
    }

    pub fn ping(&mut self) -> WireResult<()> {
        self.with_retry(|c| c.ping())
    }

    /// Retried submission. The token is mandatory: a retry after a lost
    /// reply re-attaches to the job the first attempt created, so the
    /// engine never fits the same submission twice.
    pub fn submit(
        &mut self,
        data: &EncryptedDataset,
        cfg: &FitConfig,
        cd_updates: Option<usize>,
        tenant: Option<&str>,
        deadline_ms: Option<u64>,
        token: &str,
    ) -> WireResult<JobId> {
        self.with_retry(|c| {
            c.submit_opts(data, cfg, cd_updates, tenant, deadline_ms, Some(token))
        })
    }

    /// Wait for and fetch a fit. Safe to retry: the server peeks (the
    /// job stays tracked until acked), so a retry after a lost reply
    /// re-reads the same result.
    pub fn result(&mut self, ctx: &crate::fhe::FvContext, id: JobId) -> WireResult<EncryptedFit> {
        self.with_retry(|c| c.result(ctx, id))
    }

    pub fn ack(&mut self, id: JobId) -> WireResult<bool> {
        self.with_retry(|c| c.ack(id))
    }

    pub fn health(&mut self) -> WireResult<Json> {
        self.with_retry(|c| c.health())
    }

    pub fn metrics_full(&mut self) -> WireResult<Json> {
        self.with_retry(|c| c.metrics_full())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::protocol::ErrorCode;
    use std::io::{BufRead, BufReader, Write};
    use std::net::TcpListener;
    use std::sync::{Arc, Mutex};

    #[test]
    fn backoff_schedule_is_deterministic_jittered_and_capped() {
        let p = RetryPolicy::new(8, 10, 200, 42);
        let a = p.backoff_schedule();
        let b = p.backoff_schedule();
        assert_eq!(a, b, "same seed must replay the same schedule");
        assert_eq!(a.len(), 7);
        for d in &a {
            let ms = d.as_millis() as u64;
            assert!((10..=200).contains(&ms), "draw {ms}ms escaped [base, cap]");
        }
        let other = RetryPolicy::new(8, 10, 200, 43).backoff_schedule();
        assert_ne!(a, other, "different seeds must desynchronise");
        // Not a fixed ladder: at least two distinct values with this
        // seed (a degenerate all-equal schedule means the jitter died).
        let distinct: std::collections::BTreeSet<_> = a.iter().collect();
        assert!(distinct.len() > 1, "schedule {a:?} has no jitter");
    }

    #[test]
    fn single_attempt_policy_has_no_backoff() {
        assert!(RetryPolicy::new(1, 10, 100, 7).backoff_schedule().is_empty());
        // Constructor clamps a zero-attempt request up to one attempt.
        assert_eq!(RetryPolicy::new(0, 10, 100, 7).max_attempts, 1);
    }

    #[test]
    fn connect_refused_retries_to_exhaustion_with_the_planned_pauses() {
        // Reserve a port, then free it: every dial refuses.
        let addr = {
            let l = TcpListener::bind("127.0.0.1:0").unwrap();
            l.local_addr().unwrap().to_string()
        };
        let policy = RetryPolicy::new(4, 5, 50, 99);
        let expected = policy.backoff_schedule();
        let slept: Arc<Mutex<Vec<Duration>>> = Arc::new(Mutex::new(Vec::new()));
        let rec = slept.clone();
        let mut client = RetryingClient::new(&addr, policy)
            .with_sleeper(move |d| rec.lock().unwrap().push(d));
        let err = client.ping().expect_err("nothing is listening");
        assert_eq!(err.code, ErrorCode::Transport);
        assert!(err.message.starts_with("connect-refused: "), "got '{}'", err.message);
        assert_eq!(client.retries(), 3, "4 attempts = 3 retries");
        assert_eq!(*slept.lock().unwrap(), expected, "pauses must follow the schedule");
    }

    #[test]
    fn non_retryable_errors_pass_through_without_retry() {
        // A fake server that answers every request with bad_request.
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap().to_string();
        let server = std::thread::spawn(move || {
            let (stream, _) = listener.accept().unwrap();
            let mut reader = BufReader::new(stream.try_clone().unwrap());
            let mut w = stream;
            let mut line = String::new();
            if reader.read_line(&mut line).unwrap() > 0 {
                let reply = b"{\"v\":1,\"ok\":false,\"code\":\"bad_request\",\"error\":\"nope\"}\n";
                w.write_all(reply).unwrap();
            }
        });
        let mut client = RetryingClient::new(&addr, RetryPolicy::new(5, 5, 50, 1))
            .with_sleeper(|_| panic!("must not sleep for a non-retryable error"));
        let err = client.ping().expect_err("server said bad_request");
        assert_eq!(err.code, ErrorCode::BadRequest);
        assert_eq!(client.retries(), 0);
        server.join().unwrap();
    }

    #[test]
    fn transport_failure_mid_stream_redials_and_recovers() {
        // First connection dies before replying; the second serves a
        // real pong. The retrying client must land on Ok with exactly
        // one retry.
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap().to_string();
        let server = std::thread::spawn(move || {
            // conn 1: read the request, close without replying.
            let (stream, _) = listener.accept().unwrap();
            {
                let mut reader = BufReader::new(stream.try_clone().unwrap());
                let mut line = String::new();
                reader.read_line(&mut line).unwrap();
            }
            drop(stream);
            // conn 2: serve a pong.
            let (stream, _) = listener.accept().unwrap();
            let mut reader = BufReader::new(stream.try_clone().unwrap());
            let mut w = stream;
            let mut line = String::new();
            if reader.read_line(&mut line).unwrap() > 0 {
                w.write_all(b"{\"v\":1,\"ok\":true}\n").unwrap();
            }
        });
        let mut client =
            RetryingClient::new(&addr, RetryPolicy::new(3, 5, 50, 2)).with_sleeper(|_| {});
        client.ping().expect("second attempt must succeed");
        assert_eq!(client.retries(), 1);
        server.join().unwrap();
    }
}
